"""Elastic / fault-tolerant training orchestration.

The contract with the cluster scheduler at 1000+-node scale:

* every job step is **deterministic given (params, opt_state, data_step)** —
  the data pipeline is seeded by step index, so restart = restore + replay;
* node failure → the launcher reforms the mesh from the survivors (or a new
  allocation), restores the latest checkpoint **resharded onto the new
  mesh** (Checkpointer.restore with new shardings), and resumes;
* stragglers: synchronous steps with a per-step deadline; a step exceeding
  ``straggler_factor``× the trailing-median step time flags the slowest host
  for replacement at the next checkpoint boundary (here: recorded in the
  journal — the single-process build can only simulate the signal);
* the serving path re-dispatches query shards whose workers miss their
  deadline (see launch/serve.py) — the RIG is runtime state and is simply
  rebuilt, which is exactly the paper's "no persistence" property.

``ElasticTrainer`` packages that loop so tests can kill/resume/resize it
deterministically.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from .checkpoint import Checkpointer


@dataclass
class ElasticConfig:
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    async_save: bool = True


class StepJournal:
    """Rolling step-time stats + straggler flags (host-side telemetry)."""

    def __init__(self, window: int = 64):
        self.times: List[float] = []
        self.window = window
        self.flags: List[int] = []

    def record(self, step: int, dt: float, factor: float) -> bool:
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        med = float(np.median(self.times))
        straggler = len(self.times) >= 8 and dt > factor * med
        if straggler:
            self.flags.append(step)
        return straggler


class ElasticTrainer:
    """step_fn: (state, batch) -> (state, metrics); state is any pytree
    with the optimizer step retrievable via ``get_step(state)``."""

    def __init__(self, step_fn: Callable, make_batch: Callable[[int], Any],
                 init_state: Callable[[], Any], cfg: ElasticConfig,
                 get_step: Callable[[Any], int],
                 shardings: Optional[Any] = None):
        self.step_fn = step_fn
        self.make_batch = make_batch
        self.init_state = init_state
        self.cfg = cfg
        self.get_step = get_step
        self.shardings = shardings
        self.ckpt = Checkpointer(cfg.checkpoint_dir, keep=cfg.keep)
        self.journal = StepJournal()
        self.state = None

    # ------------------------------------------------------------ lifecycle
    def start_or_resume(self):
        template = self.init_state()
        latest = self.ckpt.latest_step()
        if latest is not None:
            self.state, meta = self.ckpt.restore(template, step=latest,
                                                 shardings=self.shardings)
            return {"resumed": True, "step": latest}
        self.state = template
        return {"resumed": False, "step": 0}

    def run(self, n_steps: int, fail_at: Optional[int] = None) -> Dict:
        """Run up to ``n_steps`` *total* optimizer steps.  ``fail_at``
        injects a simulated crash (raises) after that step — the test
        harness then constructs a fresh trainer (optionally with a different
        mesh/shardings) and calls start_or_resume()."""
        assert self.state is not None, "call start_or_resume() first"
        metrics_log = []
        while True:
            step = int(self.get_step(self.state))
            if step >= n_steps:
                break
            batch = self.make_batch(step)         # seeded by step => replayable
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            jax.block_until_ready(jax.tree.leaves(self.state)[0])
            dt = time.perf_counter() - t0
            self.journal.record(step, dt, self.cfg.straggler_factor)
            metrics_log.append({k: float(v) for k, v in metrics.items()})
            new_step = int(self.get_step(self.state))
            if new_step % self.cfg.checkpoint_every == 0:
                if self.cfg.async_save:
                    self.ckpt.save_async(new_step, self.state)
                else:
                    self.ckpt.save(new_step, self.state)
            if fail_at is not None and new_step >= fail_at:
                self.ckpt.wait()
                raise SimulatedFailure(new_step)
        self.ckpt.wait()
        final = int(self.get_step(self.state))
        if not self.ckpt.all_steps() or self.ckpt.latest_step() != final:
            self.ckpt.save(final, self.state)
        return {"final_step": final, "metrics": metrics_log,
                "straggler_flags": list(self.journal.flags)}


class SimulatedFailure(RuntimeError):
    def __init__(self, step: int):
        super().__init__(f"simulated node failure at step {step}")
        self.step = step
