"""Gradient compression for bandwidth-bound data parallelism.

int8 block-quantized gradients with **error feedback** (residual carrying):
the classic distributed-optimization trick — quantize g + residual, send the
int8 payload + per-block scales over the wire (8x less all-reduce traffic
than fp32 at the cost of one extra buffer), and keep the quantization error
in the residual so the optimizer sees an unbiased long-run signal.

On a real mesh the quantize happens *before* the data-parallel psum (the
all-reduce then moves int8); in this single-process framework the compressor
is a pluggable grads-transform for ``make_train_step`` and the collective
placement is exercised by the dry-run (see launch/train.py --compress-grads).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(x: jax.Array, block: int = 256):
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blk = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blk), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blk / jnp.maximum(scale, 1e-12)), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize(q: jax.Array, scale: jax.Array, shape, block: int = 256):
    deq = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return deq[:n].reshape(shape)


def make_int8_compressor(block: int = 256, mean_axis: Optional[str] = None):
    """Returns compressor(grads, err_state) -> (grads', err_state').

    ``mean_axis``: when called inside shard_map / pmap, the int8 payload is
    psum-ed over this named axis (the compressed all-reduce); otherwise the
    transform is local (quantize → dequantize with error feedback).
    """

    def compress(grads, err):
        if err is None:
            err = init_error_state(grads)

        def one(g, e):
            target = g.astype(jnp.float32) + e
            q, scale = _quantize(target, block)
            if mean_axis is not None:
                q32 = jax.lax.psum(q.astype(jnp.int32), mean_axis)
                n = jax.lax.psum(jnp.ones(()), mean_axis)
                deq = _dequantize(q32.astype(jnp.float32) / n, scale,
                                  g.shape, block)
            else:
                deq = _dequantize(q, scale, g.shape, block)
            new_e = target - deq
            return deq.astype(g.dtype), new_e

        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(err)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
                jax.tree.unflatten(treedef, [o[1] for o in outs]))

    return compress


def compression_ratio(params, block: int = 256) -> float:
    """Wire bytes int8+scales vs fp32."""
    def bytes_of(p):
        n = p.size
        blocks = -(-n // block)
        return n + 4 * blocks, 4 * n
    sizes = [bytes_of(p) for p in jax.tree.leaves(params)]
    comp = sum(s[0] for s in sizes)
    full = sum(s[1] for s in sizes)
    return comp / full
