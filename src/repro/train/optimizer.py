"""AdamW + LR schedules + gradient clipping, from scratch (no optax).

State is a plain pytree ``{"step", "m", "v"}`` mirroring the params tree, so
it checkpoints/reshards with the same machinery as params and shards with
the same partition specs (ZeRO-style: optimizer state inherits the params'
sharding, which the configs set to fsdp+tp).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"         # cosine | linear | constant
    min_lr_ratio: float = 0.1


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_ratio) * t
    else:
        decay = jnp.ones(())
    return cfg.lr * warm * decay


def init_state(params) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"step": jnp.zeros((), jnp.int32), "m": zeros,
            "v": jax.tree.map(jnp.copy, zeros)}


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(sum(jax.tree.leaves(sq)))


def apply_updates(params, grads, state, cfg: AdamWConfig,
                  decay_mask: Optional[Any] = None):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else jnp.ones(())
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, wd_on):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat, vhat = m / bc1, v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * wd_on * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    if decay_mask is None:
        # default: decay matrices, not vectors/scalars (norms, biases)
        decay_mask = jax.tree.map(lambda p: float(p.ndim >= 2), params)
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_d = jax.tree.leaves(decay_mask)
    outs = [upd(p, g, m, v, d) for p, g, m, v, d in
            zip(flat_p, flat_g, flat_m, flat_v, flat_d)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])
    return new_p, {"step": step, "m": new_m, "v": new_v}, \
        {"lr": lr, "grad_norm": gnorm}


def make_train_step(loss_fn: Callable, cfg: AdamWConfig,
                    compressor=None) -> Callable:
    """Generic train step: (params, opt_state, batch) -> (params, state,
    metrics).  ``compressor`` optionally transforms grads (e.g. int8
    quantize/dequantize with error feedback — see train.compression)."""

    def step(params, opt_state, batch, comp_state=None):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if compressor is not None:
            grads, comp_state = compressor(grads, comp_state)
        params, opt_state, metrics = apply_updates(params, grads, opt_state, cfg)
        metrics["loss"] = loss
        if compressor is not None:
            return params, opt_state, comp_state, metrics
        return params, opt_state, metrics

    return step
