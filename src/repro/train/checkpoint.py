"""Lightweight fault-tolerant checkpointing (no orbax dependency).

Design points for the 1000+-node story:

* **atomic**: write to ``<dir>/.tmp-<step>`` then ``os.replace`` — a crash
  mid-save never corrupts the latest checkpoint;
* **async**: ``save_async`` snapshots device arrays to host (cheap) and does
  the serialization on a worker thread, so the training loop keeps stepping;
* **reshardable restore**: checkpoints store the *global* (unsharded) arrays
  keyed by pytree path; ``restore`` device_puts onto whatever mesh/sharding
  the *new* job provides — elastic resizes and mesh-shape changes just work
  (see train.elastic);
* **retention**: ``keep`` most-recent checkpoints are retained, the rest
  garbage-collected.
"""

from __future__ import annotations

import json
import os
import re
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> Dict[str, Any]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_part(p) for p in path)
        out[key] = leaf
    return out


def _part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _unflatten_into(template, flat: Dict[str, Any]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, tmpl in paths:
        key = _SEP.join(_part(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append((key, tmpl))
    return treedef, leaves


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree, extra: Optional[dict] = None) -> str:
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        return self._write(step, host, extra or {})

    def save_async(self, step: int, tree, extra: Optional[dict] = None):
        self.wait()
        if self._error:
            err, self._error = self._error, None
            raise err
        # snapshot to host memory synchronously (device buffers may change)
        host = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                self._write(step, host, extra or {})
            except BaseException as e:   # surfaced on next save/wait
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_tree, extra: dict) -> str:
        flat = _flatten(host_tree)
        # npz round-trips only native numpy dtypes; widen ml_dtypes (bf16,
        # fp8) to float32 on disk — restore() casts back to the template.
        flat = {k: (np.asarray(v, dtype=np.float32)
                    if v.dtype.kind == "V" or v.dtype.name not in
                    np.sctypeDict else np.asarray(v))
                for k, v in flat.items()}
        tmp = os.path.join(self.dir, f".tmp-{step}-{os.getpid()}")
        final = os.path.join(self.dir, f"step_{step:010d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: v for k, v in flat.items()})
        meta = {"step": step, "time": time.time(), "extra": extra,
                "keys": sorted(flat.keys())}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            # same-step overwrite: replace atomically via a rename dance
            os.replace(os.path.join(tmp, "arrays.npz"),
                       os.path.join(final, "arrays.npz"))
            os.replace(os.path.join(tmp, "meta.json"),
                       os.path.join(final, "meta.json"))
            os.rmdir(tmp)
        else:
            os.replace(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            path = os.path.join(self.dir, f"step_{s:010d}")
            for f in os.listdir(path):
                os.unlink(os.path.join(path, f))
            os.rmdir(path)

    # -------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``template``.

        ``shardings``: optional pytree (matching template) of
        ``jax.sharding.Sharding`` — arrays are placed with ``device_put``
        onto the *current* mesh, enabling cross-mesh restores.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        treedef, keyed = _unflatten_into(template, dict(data))
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(keyed))
        leaves = []
        for (key, tmpl), shard in zip(keyed, shard_leaves):
            arr = data[key]
            want_shape = tuple(getattr(tmpl, "shape", arr.shape))
            if tuple(arr.shape) != want_shape:
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != {want_shape}")
            dtype = getattr(tmpl, "dtype", arr.dtype)
            arr = jax.numpy.asarray(arr).astype(dtype)   # handles bf16 etc.
            leaves.append(jax.device_put(arr, shard) if shard is not None
                          else arr)
        tree = jax.tree.unflatten(treedef, leaves)
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        return tree, meta
