from .checkpoint import Checkpointer
from .compression import (compression_ratio, init_error_state,
                          make_int8_compressor)
from .elastic import ElasticConfig, ElasticTrainer, SimulatedFailure
from .optimizer import AdamWConfig, apply_updates, init_state, make_train_step

__all__ = [
    "AdamWConfig", "init_state", "apply_updates", "make_train_step",
    "Checkpointer", "make_int8_compressor", "init_error_state",
    "compression_ratio", "ElasticTrainer", "ElasticConfig", "SimulatedFailure",
]
