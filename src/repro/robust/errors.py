"""Typed error taxonomy for resource-governed execution.

Every failure mode the engine can surface has one exception class with a
stable ``status`` string, so callers (and the serving layer) branch on
semantics, not on message text:

===================  ====================  =====================================
class                status                meaning / recovery
===================  ====================  =====================================
DeadlineExceeded     deadline_exceeded     budget deadline hit; the partial
                                           prefix already enumerated is valid
ResourceExhausted    resource_exhausted    a memory cap was blown before any
                                           degradation could absorb it
DeviceFailure        device_failure        device dispatch failed after
                                           retries; recompute on host
BreakerOpen          breaker_open          circuit breaker is open — the
                                           device is not even attempted
InjectedFault        injected_fault        deterministic chaos-test fault
                                           (``repro.robust.faults``)
AdmissionError       rejected              refused at submit (admission
                                           control / backpressure)
===================  ====================  =====================================

``TransientError`` marks the retryable subset: recovery is *recompute* (the
RIG is runtime state, never persisted — the paper's key property), so a
bounded re-attempt is always safe.  ``DeadlineExceeded`` and
``ResourceExhausted`` are deliberately **not** transient: retrying cannot
beat the same deadline or fit the same cap.
"""

from __future__ import annotations

__all__ = ["QueryError", "DeadlineExceeded", "ResourceExhausted",
           "TransientError", "DeviceFailure", "BreakerOpen",
           "InjectedFault", "AdmissionError"]


class QueryError(Exception):
    """Base of every typed execution error; ``status`` is the stable
    machine-readable discriminator mirrored into ``EngineStats.status``."""

    status = "error"


class DeadlineExceeded(QueryError):
    """The budget's monotonic deadline passed.  Raised only in
    ``raise_on_error`` mode; otherwise execution stops cooperatively and
    the partial result carries this status."""

    status = "deadline_exceeded"


class ResourceExhausted(QueryError):
    """A hard memory cap (e.g. ``Budget.max_rig_bytes``) was exceeded where
    no degradation step could absorb it."""

    status = "resource_exhausted"


class TransientError(QueryError):
    """Retryable failure: a bounded recompute (``Budget.max_attempts``)
    is the correct recovery."""

    status = "transient"


class DeviceFailure(TransientError):
    """A device dispatch failed after the breaker's in-call retries; the
    caller falls back to the host path."""

    status = "device_failure"


class BreakerOpen(QueryError):
    """The device circuit breaker is open: the dispatch was refused without
    touching the device (host-only routing until a half-open probe
    succeeds).  Not transient — retrying immediately would hit the same
    open breaker."""

    status = "breaker_open"


class InjectedFault(TransientError):
    """Deterministic fault fired by :mod:`repro.robust.faults` at a named
    injection site."""

    status = "injected_fault"

    def __init__(self, site: str, call_no: int = 0):
        super().__init__(f"injected fault at site {site!r} (call #{call_no})")
        self.site = site
        self.call_no = call_no


class AdmissionError(QueryError):
    """Request refused at submission (queue backpressure, malformed query,
    or an already-expired budget)."""

    status = "rejected"
