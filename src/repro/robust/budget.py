"""Per-query execution budget: deadline, memory caps, attempt bound.

A :class:`Budget` is a declarative *template* (safe to share, e.g. one per
server); :meth:`Budget.start` arms a private copy against the monotonic
clock for one query.  The armed copy travels with the query through
``Engine.execute`` / ``execute_stream`` / ``execute_many`` into the MJoin
generator loops and the RIG expansion, which check it **cooperatively** at
slab / level / edge boundaries:

* **deadline** — ``deadline_s`` relative seconds, armed via
  ``time.monotonic()`` (never wall clock: an NTP step must not expire or
  resurrect a query).  Enumeration loops that notice expiry stop cleanly
  and mark the partial prefix (``status="deadline_exceeded"``); phases
  with no partial result (label build, RIG expansion) raise
  :class:`~repro.robust.errors.DeadlineExceeded`.  A blown deadline is
  noticed within one slab / block of work, so total latency is bounded by
  ``deadline + one slab``.
* **memory** — ``max_rig_bytes`` caps the materialized RIG adjacency
  (blown → :class:`ResourceExhausted`: the RIG is required, nothing can
  degrade).  ``max_frontier_rows`` tightens the frontier enumerator's
  level-width bound and ``max_slab_bytes`` its per-slab gather transient —
  both *degrade* (smaller slabs, then backtracking) rather than fail.
* **attempts** — ``max_attempts`` bounds recompute retries on
  :class:`TransientError` (recovery is always recompute, never state
  repair — the RIG is runtime state).

``raise_on_error=True`` switches partial-result statuses into raised typed
errors (servers usually prefer statuses; tests and strict callers the
exceptions).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from .errors import DeadlineExceeded, ResourceExhausted

__all__ = ["Budget"]


@dataclass
class Budget:
    deadline_s: Optional[float] = None      # relative; armed by start()
    max_rig_bytes: Optional[int] = None     # RIG adjacency cap (hard)
    max_frontier_rows: Optional[int] = None  # frontier level cap (degrades)
    max_slab_bytes: Optional[int] = None    # per-slab gather cap (degrades)
    max_attempts: int = 1                   # transient-failure recomputes
    raise_on_error: bool = False            # typed raise vs partial status

    # --- armed runtime state (not part of the template's identity) ------
    _deadline_at: Optional[float] = field(default=None, repr=False,
                                          compare=False)
    _clock: Callable[[], float] = field(default=time.monotonic, repr=False,
                                        compare=False)
    _rig_bytes: int = field(default=0, repr=False, compare=False)

    # ------------------------------------------------------------- arming
    def start(self, clock: Optional[Callable[[], float]] = None) -> "Budget":
        """Arm a fresh copy for one query.  The template itself is never
        mutated, so one ``Budget`` can govern a whole server's traffic."""
        clk = clock or time.monotonic
        armed = Budget(deadline_s=self.deadline_s,
                       max_rig_bytes=self.max_rig_bytes,
                       max_frontier_rows=self.max_frontier_rows,
                       max_slab_bytes=self.max_slab_bytes,
                       max_attempts=self.max_attempts,
                       raise_on_error=self.raise_on_error)
        armed._clock = clk
        if self.deadline_s is not None:
            armed._deadline_at = clk() + self.deadline_s
        return armed

    @property
    def armed(self) -> bool:
        return self._deadline_at is not None

    # ------------------------------------------------------------ deadline
    def remaining_s(self) -> Optional[float]:
        """Seconds until the deadline (None when no deadline armed)."""
        if self._deadline_at is None:
            return None
        return self._deadline_at - self._clock()

    def expired(self) -> bool:
        """Cheap cooperative check: one monotonic read + compare."""
        return (self._deadline_at is not None
                and self._clock() >= self._deadline_at)

    def check_deadline(self, site: str = "") -> None:
        """Raise :class:`DeadlineExceeded` when expired — for phases that
        cannot produce a partial result (label build, RIG expansion)."""
        if self.expired():
            raise DeadlineExceeded(
                f"budget deadline ({self.deadline_s:.4g}s) exceeded"
                + (f" at {site}" if site else ""))

    # -------------------------------------------------------------- memory
    def charge_rig(self, nbytes: int, site: str = "rig") -> None:
        """Account RIG adjacency memory; raise :class:`ResourceExhausted`
        the moment the cumulative total would exceed the cap."""
        self._rig_bytes += int(nbytes)
        if (self.max_rig_bytes is not None
                and self._rig_bytes > self.max_rig_bytes):
            raise ResourceExhausted(
                f"{site}: {self._rig_bytes} bytes exceeds budget "
                f"max_rig_bytes={self.max_rig_bytes}")

    def frontier_cap(self, default: int) -> int:
        """Effective frontier level-width bound (budget tightens only)."""
        if self.max_frontier_rows is None:
            return default
        return min(default, self.max_frontier_rows)

    def slab_cap_rows(self, bytes_per_row: int) -> Optional[int]:
        """Max frontier slab rows under ``max_slab_bytes`` (None = no cap)."""
        if self.max_slab_bytes is None:
            return None
        return max(1, self.max_slab_bytes // max(1, bytes_per_row))
