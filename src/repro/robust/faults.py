"""Deterministic fault injection for the chaos test suite.

Production code marks its failure-prone boundaries with a named *site*
call::

    from ..robust import faults
    faults.maybe_fail("rig_expand")

When no plan is installed (the normal case) this is one module-global load
plus a ``None`` check — nothing is allocated, counted, or locked, so the
sites cost nothing on the warm path.  Tests install a plan::

    with faults.inject(faults.nth("device_dispatch", 1)):
        ...   # the 1st device dispatch raises InjectedFault

Triggers are **deterministic**: ``nth`` fires on exact (1-based) call
numbers, ``every`` on every k-th call, and ``probability`` draws from its
own seeded RNG — the same seed always fails the same calls, so every chaos
test replays exactly.

Sites wired through the stack:

* ``device_dispatch`` — inside :meth:`CircuitBreaker.call`, i.e. every
  governed device dispatch (vmapped matcher, intersect-kernel slabs);
* ``label_build``     — cold per-graph label construction;
* ``rig_expand``      — per query edge during RIG node expansion;
* ``journal_dispatch``— the server's batch dispatch (simulated worker
  death: requests stay journaled and are re-dispatched).
"""

from __future__ import annotations

import random
import threading
from typing import Dict, Optional, Sequence

from .errors import InjectedFault

__all__ = ["SITES", "FaultSpec", "FaultPlan", "nth", "every", "probability",
           "inject", "install", "uninstall", "maybe_fail", "call_count"]

SITES = ("device_dispatch", "label_build", "rig_expand", "journal_dispatch")


class FaultSpec:
    """One site's trigger rule.  Exactly one of ``nth_calls`` /
    ``every_k`` / ``p`` is set; ``times`` bounds total fires (None =
    unbounded)."""

    def __init__(self, site: str, *, nth_calls: Sequence[int] = (),
                 every_k: Optional[int] = None, p: Optional[float] = None,
                 seed: int = 0, times: Optional[int] = None):
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r} "
                             f"(expected one of {SITES})")
        self.site = site
        self.nth_calls = frozenset(int(n) for n in nth_calls)
        self.every_k = every_k
        self.p = p
        self.times = times
        self.fired = 0
        self._rng = random.Random(seed)

    def should_fire(self, call_no: int) -> bool:
        if self.times is not None and self.fired >= self.times:
            return False
        if self.nth_calls:
            hit = call_no in self.nth_calls
        elif self.every_k is not None:
            hit = call_no % self.every_k == 0
        elif self.p is not None:
            hit = self._rng.random() < self.p
        else:
            hit = True                       # unconditional
        if hit:
            self.fired += 1
        return hit


def nth(site: str, *call_nos: int, times: Optional[int] = None) -> FaultSpec:
    """Fire on the given 1-based call numbers at ``site``."""
    return FaultSpec(site, nth_calls=call_nos or (1,), times=times)


def every(site: str, k: int = 1, times: Optional[int] = None) -> FaultSpec:
    """Fire on every ``k``-th call at ``site`` (k=1: every call)."""
    return FaultSpec(site, every_k=k, times=times)


def probability(site: str, p: float, seed: int = 0,
                times: Optional[int] = None) -> FaultSpec:
    """Fire with probability ``p`` per call, from a private seeded RNG
    (deterministic per seed)."""
    return FaultSpec(site, p=p, seed=seed, times=times)


class FaultPlan:
    """An installed set of specs plus per-site call counters."""

    def __init__(self, specs: Sequence[FaultSpec]):
        self.specs: Dict[str, FaultSpec] = {}
        for s in specs:
            if s.site in self.specs:
                raise ValueError(f"duplicate spec for site {s.site!r}")
            self.specs[s.site] = s
        self.calls: Dict[str, int] = {s: 0 for s in SITES}
        self._lock = threading.Lock()

    def check(self, site: str) -> None:
        with self._lock:
            self.calls[site] = n = self.calls.get(site, 0) + 1
            spec = self.specs.get(site)
            fire = spec is not None and spec.should_fire(n)
        if fire:
            raise InjectedFault(site, n)


_PLAN: Optional[FaultPlan] = None


def maybe_fail(site: str) -> None:
    """The production-side hook: free when no plan is installed."""
    plan = _PLAN
    if plan is not None:
        plan.check(site)


def install(*specs: FaultSpec) -> FaultPlan:
    """Install a plan (replacing any previous one); returns it so tests
    can read call counters.  Prefer the :func:`inject` context manager."""
    global _PLAN
    _PLAN = plan = FaultPlan(specs)
    return plan


def uninstall() -> None:
    global _PLAN
    _PLAN = None


class inject:
    """``with faults.inject(spec, ...) as plan:`` — scoped installation."""

    def __init__(self, *specs: FaultSpec):
        self.specs = specs
        self.plan: Optional[FaultPlan] = None

    def __enter__(self) -> FaultPlan:
        self.plan = install(*self.specs)
        return self.plan

    def __exit__(self, *exc) -> None:
        uninstall()


def call_count(site: str) -> int:
    """Calls seen at ``site`` under the currently-installed plan (0 when
    none installed) — lets tests assert a site was actually exercised."""
    plan = _PLAN
    return 0 if plan is None else plan.calls.get(site, 0)
