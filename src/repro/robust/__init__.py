# Resource-governed execution: per-query budgets (deadline / memory /
# attempts), a typed error taxonomy, a device circuit breaker with
# retry+backoff, and a deterministic fault-injection harness.  The RIG is
# runtime state (never persisted), so every recovery here is *recompute* —
# cancel, degrade, or retry — never state repair.
from . import faults
from .breaker import CLOSED, HALF_OPEN, OPEN, CircuitBreaker
from .budget import Budget
from .errors import (AdmissionError, BreakerOpen, DeadlineExceeded,
                     DeviceFailure, InjectedFault, QueryError,
                     ResourceExhausted, TransientError)

__all__ = [
    "Budget", "CircuitBreaker", "CLOSED", "HALF_OPEN", "OPEN",
    "QueryError", "DeadlineExceeded", "ResourceExhausted", "TransientError",
    "DeviceFailure", "BreakerOpen", "InjectedFault", "AdmissionError",
    "faults",
]
