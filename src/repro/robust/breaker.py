"""Device circuit breaker: retry with backoff, then route host-only.

Wraps every device dispatch (the vmapped JaxGM matcher, the ``intersect``
Pallas kernel slabs) behind one :class:`CircuitBreaker`:

* **closed** — dispatches flow through.  A failing call is retried in
  place with capped exponential backoff plus deterministic jitter (seeded
  RNG, so tests replay); after the in-call retries are spent the call
  raises :class:`DeviceFailure` and the caller recomputes on the host.
* **open** — after ``failure_threshold`` *consecutive* failed calls the
  breaker refuses dispatches outright (:class:`BreakerOpen`, raised before
  the device is touched), so a wedged or crashing device stops costing
  timeouts.  Callers treat it exactly like ``DeviceFailure``: host
  fallback.
* **half-open** — once ``reset_after_s`` (monotonic) has passed, exactly
  one probe call is let through.  Success closes the breaker; failure
  re-opens it and restarts the window.

The breaker is cross-query state: one per :class:`Engine` (bound to its
metrics registry as the ``engine_breaker_state`` gauge — 0 closed,
1 half-open, 2 open — and the ``engine_device_retries`` counter).  The
clock and sleep are injectable so chaos tests drive state transitions
without real waiting.
"""

from __future__ import annotations

import random
import time
from typing import Callable, Optional

from . import faults
from .errors import BreakerOpen, DeviceFailure

__all__ = ["CircuitBreaker", "CLOSED", "HALF_OPEN", "OPEN", "STATE_VALUES"]

CLOSED = "closed"
HALF_OPEN = "half_open"
OPEN = "open"
# gauge encoding (engine_breaker_state)
STATE_VALUES = {CLOSED: 0, HALF_OPEN: 1, OPEN: 2}


class CircuitBreaker:
    def __init__(self, *, failure_threshold: int = 3,
                 reset_after_s: float = 30.0, max_retries: int = 2,
                 backoff_base_s: float = 0.05, backoff_cap_s: float = 2.0,
                 jitter: float = 0.25, seed: int = 0,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        self.failure_threshold = failure_threshold
        self.reset_after_s = reset_after_s
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self.jitter = jitter
        self.clock = clock
        self.sleep = sleep
        self._rng = random.Random(seed)
        self.state = CLOSED
        self.consecutive_failures = 0
        self.retries = 0                  # total in-call retry attempts
        self.opened = 0                   # open transitions (observability)
        self._opened_at: Optional[float] = None
        self._probe_inflight = False
        self._gauge = None
        self._retry_counter = None
        self._recorder = None

    # -------------------------------------------------------------- metrics
    def bind_metrics(self, registry, prefix: str = "engine_"
                     ) -> "CircuitBreaker":
        """Mirror state/retries into ``<prefix>breaker_state`` (gauge) and
        ``<prefix>device_retries`` (counter) of ``registry``."""
        self._gauge = registry.gauge(prefix + "breaker_state")
        self._gauge.set(STATE_VALUES[self.state])
        self._retry_counter = registry.counter(prefix + "device_retries")
        return self

    def bind_recorder(self, recorder) -> "CircuitBreaker":
        """Land every state transition in a flight recorder
        (:class:`repro.obs.flight.FlightRecorder`) as a ``BreakerEvent``;
        a transition to ``open`` additionally triggers the recorder's
        armed incident auto-dump — the ring buffer at that moment holds
        exactly the requests that led up to the trip."""
        self._recorder = recorder
        return self

    def _set_state(self, state: str) -> None:
        old = self.state
        self.state = state
        if self._gauge is not None:
            self._gauge.set(STATE_VALUES[state])
        if self._recorder is not None and old != state:
            from ..obs.events import BreakerEvent
            self._recorder.record(BreakerEvent(
                old_state=old, new_state=state,
                consecutive_failures=self.consecutive_failures))
            if state == OPEN:
                self._recorder.maybe_autodump("breaker_open")

    # ------------------------------------------------------------ state API
    def allow(self) -> bool:
        """Would a dispatch be admitted right now?  Transitions
        open -> half-open when the reset window has passed (the next
        :meth:`call` becomes the probe)."""
        if self.state == OPEN:
            if (self._opened_at is not None
                    and self.clock() - self._opened_at >= self.reset_after_s):
                self._set_state(HALF_OPEN)
                self._probe_inflight = False
            else:
                return False
        if self.state == HALF_OPEN and self._probe_inflight:
            return False
        return True

    def record_success(self) -> None:
        self.consecutive_failures = 0
        self._probe_inflight = False
        if self.state != CLOSED:
            self._set_state(CLOSED)

    def record_failure(self) -> None:
        self.consecutive_failures += 1
        probe_failed = self.state == HALF_OPEN
        self._probe_inflight = False
        if (probe_failed
                or self.consecutive_failures >= self.failure_threshold):
            if self.state != OPEN:
                self.opened += 1
            self._set_state(OPEN)
            self._opened_at = self.clock()

    # ------------------------------------------------------------- dispatch
    def _backoff_s(self, attempt: int) -> float:
        base = min(self.backoff_cap_s, self.backoff_base_s * (2 ** attempt))
        return base * (1.0 + self.jitter * self._rng.random())

    def call(self, fn: Callable[[], object], *,
             site: str = "device_dispatch", budget=None):
        """Run one governed device dispatch.

        Raises :class:`BreakerOpen` without touching the device when the
        breaker is open (and no probe is due); otherwise runs ``fn`` with
        up to ``max_retries`` in-place retries (capped exponential backoff
        + jitter, never sleeping past the budget's deadline) and raises
        :class:`DeviceFailure` when all attempts fail.  The named fault
        site fires once per attempt, so injected faults exercise exactly
        this retry/breaker path.
        """
        if not self.allow():
            raise BreakerOpen(
                f"device breaker open ({self.consecutive_failures} "
                f"consecutive failures); host-only until a probe succeeds")
        if self.state == HALF_OPEN:
            self._probe_inflight = True
        attempts = 1 if self.state == HALF_OPEN else 1 + self.max_retries
        last: Optional[BaseException] = None
        for attempt in range(attempts):
            if attempt > 0:
                self.retries += 1
                if self._retry_counter is not None:
                    self._retry_counter.inc()
                delay = self._backoff_s(attempt - 1)
                if budget is not None:
                    rem = budget.remaining_s()
                    if rem is not None:
                        if rem <= 0:
                            break             # deadline gone: stop retrying
                        delay = min(delay, rem)
                self.sleep(delay)
            try:
                faults.maybe_fail(site)
                out = fn()
            except Exception as e:            # noqa: BLE001 - any dispatch
                last = e                      # failure opens/retries
                self.record_failure()
                if self.state == OPEN:
                    break
                continue
            self.record_success()
            return out
        raise DeviceFailure(
            f"device dispatch failed after {attempts} attempt(s): "
            f"{type(last).__name__}: {last}") from last
