"""Optional-``hypothesis`` shim for the test suite.

The property-based tests use hypothesis when it is installed (the ``test``
extra); on a bare interpreter the same modules must still import and run
their example-based tests.  Importing ``given``/``settings``/``st`` from
here instead of ``hypothesis`` makes the property tests skip cleanly when
the dependency is missing::

    from repro.testing import HAVE_HYPOTHESIS, given, settings, st

``st`` is a stub whose strategy constructors accept anything and return
placeholders — the decorated test is marked ``skip`` before any strategy
is ever drawn from.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # bare interpreter
    HAVE_HYPOTHESIS = False
    import pytest

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -e '.[test]')"
            )(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        """Accepts any strategy construction; never actually drawn from."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None
            return _strategy

    st = _StrategyStub()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
