"""Pallas-TPU API compatibility.

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams`` in newer
jax releases; the kernels import the name from here so they run on both.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
