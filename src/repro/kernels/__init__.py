# TPU compute hot-spots of the paper's pipeline, as Pallas kernels:
#   bitmm     — packed-bit boolean matmul (the paper's §5.5 bitset batch op,
#               re-tiled for VMEM + MXU)            -> bitmm.py
#   closure   — packed boolean matrix squaring (descendant-edge substrate,
#               replaces CPU BFL probes)            -> closure.py
#   intersect — k-way AND + popcount (MJoin multiway candidate step)
#                                                   -> intersect.py
# ops.py dispatches pallas / blocked-jnp / reference; ref.py holds oracles.
from . import ops, packed, ref
from .ops import bitmm, closure_step, intersect, transitive_closure

__all__ = ["ops", "packed", "ref", "bitmm", "closure_step", "intersect",
           "transitive_closure"]
