"""``bitmm`` — bit-packed boolean matmul Pallas TPU kernel.

The workhorse of the TPU-adapted matcher: computes ``Y = f(A · X)`` where
``A`` is a 0/1 matrix stored bit-packed (uint32 words, 32x less HBM traffic
than bf16) and ``X`` is a small dense 0/1 right operand (e.g. the FB
candidate matrix transposed, B = number of query nodes).

TPU adaptation of the paper's roaring-bitmap ``bitBat`` batch op (§5.5):
instead of word-wise AND/OR on a scalar core, each grid step unpacks a
``(bm, bk)`` tile of A *in VMEM* (shift+mask against a 32-lane iota) and
feeds the MXU with a dense bf16 tile; the epilogue applies either

* ``threshold`` — ``Y = (A@X) > 0``   (existence semantics: simulation), or
* ``sum``       — ``Y = A@X``         (count semantics: GNN sum-aggregation).

Grid: ``(M/bm, K/bk)`` with the contraction dimension innermost
(``arbitrary``), accumulating into a VMEM scratch tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

WORD = 32


def _bitmm_kernel(a_ref, x_ref, o_ref, acc_ref, *, threshold: bool):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    words = a_ref[...]                                     # (bm, bk/32) uint32
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, WORD), 2)
    bits = (words[:, :, None] >> shifts) & jnp.uint32(1)   # (bm, bk/32, 32)
    a_dense = bits.reshape(words.shape[0], -1).astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(a_dense, x_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(1) - 1)
    def _epilogue():
        acc = acc_ref[...]
        if threshold:
            o_ref[...] = (acc > 0).astype(o_ref.dtype)
        else:
            o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("threshold", "bm", "bk", "interpret"))
def bitmm_pallas(a_words: jax.Array, x: jax.Array, *, threshold: bool = True,
                 bm: int = 256, bk: int = 1024,
                 interpret: bool = False) -> jax.Array:
    """Y = f(unpack(a_words) @ x).

    a_words: uint32 (M, K/32); x: (K, B) float/bool; Y: (M, B) float32.
    M % bm == 0 and K % bk == 0 are required (pad upstream); B is kept whole
    (it is small — query width), padded to the lane count by the caller if
    needed.
    """
    m, wk = a_words.shape
    kdim, b = x.shape
    assert wk * WORD == kdim, (wk, kdim)
    bm = min(bm, m)
    bk = min(bk, kdim)
    assert m % bm == 0 and kdim % bk == 0, (m, bm, kdim, bk)
    grid = (m // bm, kdim // bk)
    return pl.pallas_call(
        functools.partial(_bitmm_kernel, threshold=threshold),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk // WORD), lambda i, k: (i, k)),
            pl.BlockSpec((bk, b), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bm, b), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, b), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, b), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a_words, x)
