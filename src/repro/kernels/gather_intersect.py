"""``gather_intersect`` — fused row-gather + K-way AND + popcount kernel.

The resident-RIG enumerator (``repro.core.mjoin``, method
``frontier-device-resident``) keeps every packed RIG adjacency matrix
concatenated into one device-resident uint32 matrix ``(R, W)``.  A level
dispatch then needs only the ``(F, K)`` int32 *row indices* of the
constraint rows — this kernel gathers those rows out of the resident
matrix, AND-reduces them across K, and popcounts each result row, all on
device.  Compared to the ``intersect`` kernel it replaces the host-side
``(F, K, W)`` gather + transfer with an ``(F, K)`` index upload: the slab
traffic drops from ``F*K*W*4`` bytes to ``F*K*4`` bytes per dispatch.

Grid: ``(F/bf,)`` with the index block scalar-prefetched into SMEM so row
addresses are known before the body runs; each program issues ``bf*K``
async copies from the resident matrix (``pltpu.ANY`` — HBM for large
matrices) into a VMEM scratch, waits, then reduces.  K is static and
unrolled.  Outputs stay padded to the grid (callers slice rows on the
host side); AND rows are sliced to the level's true lane count ``w32``
inside the jit so the device-to-host copy is exact.

The ``gather_intersect_xla`` variant is the same contraction expressed as
a plain XLA gather + AND + ``population_count`` — the default executor on
non-TPU backends, where it beats both the Pallas interpreter (by orders
of magnitude) and the host path (the resident matrix never leaves the
device).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_intersect_kernel(idx_ref, mat_ref, and_ref, cnt_ref, rows_vmem,
                             sems, *, bf: int, k_rows: int):
    i = pl.program_id(0)
    base = i * bf
    # one DMA per (frontier row, constraint): resident row idx[base+r, c]
    # lands in scratch slot r*K + c.  Start all copies, then wait — the
    # issue loop overlaps with in-flight transfers.
    copies = []
    for r in range(bf):
        for c in range(k_rows):
            row = idx_ref[base + r, c]
            slot = r * k_rows + c
            copies.append(pltpu.make_async_copy(
                mat_ref.at[pl.ds(row, 1), :],
                rows_vmem.at[pl.ds(slot, 1), :],
                sems.at[slot]))
    for dma in copies:
        dma.start()
    for dma in copies:
        dma.wait()
    tile = rows_vmem[...].reshape(bf, k_rows, rows_vmem.shape[-1])
    acc = tile[:, 0]
    for c in range(1, k_rows):                 # K is static and small
        acc = acc & tile[:, c]
    and_ref[...] = acc
    pc = jax.lax.population_count(acc).astype(jnp.int32)
    cnt_ref[...] = pc.sum(axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("w32", "bf", "interpret"))
def gather_intersect_pallas(matrix: jax.Array, idx: jax.Array, *, w32: int,
                            bf: int = 8, interpret: bool = False):
    """matrix: uint32 (R, W) resident; idx: int32 (F, K) row indices ->
    (and_rows uint32 (Fp, w32), counts int32 (Fp,)) with Fp = F rounded up
    to ``bf`` (callers pad F themselves to bound retraces and slice rows
    back; padding index rows should point at an all-zero resident row).

    ``w32`` is the level's true lane count: AND rows are cut to it before
    leaving the device.  Counts are exact regardless — resident rows are
    zero beyond their own true width, so padding lanes AND to zero.
    """
    f, k_rows = idx.shape
    _, w = matrix.shape
    fp = -(-f // bf) * bf
    if fp != f:
        idx = jnp.pad(idx, ((0, fp - f), (0, 0)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(fp // bf,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=[
            pl.BlockSpec((bf, w), lambda i, idx_ref: (i, 0)),
            pl.BlockSpec((bf, 1), lambda i, idx_ref: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bf * k_rows, w), jnp.uint32),
            pltpu.SemaphoreType.DMA((bf * k_rows,)),
        ])
    and_rows, counts = pl.pallas_call(
        functools.partial(_gather_intersect_kernel, bf=bf, k_rows=k_rows),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((fp, w), jnp.uint32),
            jax.ShapeDtypeStruct((fp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(idx, matrix)
    return and_rows[:, :w32], counts[:, 0]


@functools.partial(jax.jit, static_argnames=("w32",))
def gather_intersect_xla(matrix: jax.Array, idx: jax.Array, *, w32: int):
    """XLA expression of the same fused contraction (non-TPU executor).

    Same contract as :func:`gather_intersect_pallas` minus the grid
    rounding: returns ``(and_rows (F, w32), counts (F,))`` for the full
    (caller-padded) F.
    """
    rows = matrix[idx]                         # (F, K, W) device gather
    acc = rows[:, 0]
    for c in range(1, rows.shape[1]):
        acc = acc & rows[:, c]
    counts = jax.lax.population_count(acc).astype(jnp.int32).sum(axis=1)
    return acc[:, :w32], counts


@functools.partial(jax.jit, static_argnames=("n_i", "size"))
def expand_pairs(and_rows: jax.Array, *, n_i: int, size: int):
    """Device-side frontier expansion: set bits of ``and_rows`` (uint32
    ``(F, w32)``, little-endian lanes) -> the first ``size`` (row, column)
    pairs in row-major (= lexicographic) order, as int32 vectors.

    ``size`` is a static page bound: callers bucket it (and slice the
    valid prefix themselves) so the number of retraces stays logarithmic.
    The dense unpack + nonzero happens on device — the host receives only
    the compact pair page instead of an ``(F, n_i)`` boolean slab.
    """
    f, w = and_rows.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((and_rows[:, :, None] >> shifts) & jnp.uint32(1)) != 0
    bits = bits.reshape(f, w * 32)[:, :n_i]
    (flat,) = jnp.nonzero(bits.reshape(-1), size=size, fill_value=0)
    rid = (flat // n_i).astype(jnp.int32)
    cid = (flat % n_i).astype(jnp.int32)
    return rid, cid
