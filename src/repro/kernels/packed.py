"""JAX-side packed-bitset utilities (uint32 words).

Device-side mirror of ``repro.core.bitset`` (which uses uint64 + numpy).
TPU vector registers operate on 32-bit lanes, so the device path packs into
``uint32``: bit ``i`` of a universe lives in word ``i >> 5``, position
``i & 31`` (little-endian), matching the unpack order used inside the Pallas
kernels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

WORD = 32


def n_words(n: int) -> int:
    return (n + WORD - 1) // WORD


def pad_to_words(n: int) -> int:
    return n_words(n) * WORD


def pack(mask: jax.Array) -> jax.Array:
    """bool (..., n) -> uint32 (..., ceil(n/32)), little-endian bit order."""
    n = mask.shape[-1]
    pad = (-n) % WORD
    if pad:
        mask = jnp.concatenate(
            [mask, jnp.zeros(mask.shape[:-1] + (pad,), dtype=mask.dtype)], -1)
    m = mask.reshape(mask.shape[:-1] + (-1, WORD)).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32))
    return (m * weights).sum(axis=-1).astype(jnp.uint32)


def unpack(words: jax.Array, n: int | None = None) -> jax.Array:
    """uint32 (..., W) -> bool (..., n)."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    out = bits.reshape(words.shape[:-1] + (-1,)).astype(jnp.bool_)
    return out if n is None else out[..., :n]


def popcount(words: jax.Array) -> jax.Array:
    """Per-element popcount; reduce with .sum() as needed."""
    return jax.lax.population_count(words.astype(jnp.uint32)).astype(jnp.int32)


def pack_numpy_u64_to_u32(words64: np.ndarray) -> np.ndarray:
    """Reinterpret the host path's packed uint64 words as device uint32 words
    (little-endian layouts are bit-compatible)."""
    return np.ascontiguousarray(words64).view(np.uint32)
