"""Dispatching wrappers around the Pallas kernels.

Every op takes ``impl``:

* ``"pallas"``     — the TPU kernel (compiled on TPU; ``interpret=True``
                     execution elsewhere, used by the correctness sweeps),
* ``"blocked"``    — memory-lean pure-jnp implementation that unpacks one
                     K-block at a time (lax.scan); this is what the multi-pod
                     dry-run lowers (identical math, no Pallas dependency,
                     never materializes the full unpacked matrix),
* ``"reference"``  — the ref.py oracle (materializes; small inputs only),
* ``"auto"``       — pallas on TPU backends, blocked otherwise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import packed, ref
from .bitmm import bitmm_pallas
from .closure import closure_step_pallas
from .intersect import intersect_pallas

WORD = 32


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "blocked"
    return impl


# ------------------------------------------------------------------- bitmm
@functools.partial(jax.jit, static_argnames=("threshold", "block_k", "unroll"))
def _bitmm_blocked(a_words, x, threshold: bool = True, block_k: int = 4096,
                   unroll: bool = False):
    """``unroll=True`` replaces the chunk scan with a python loop — the
    dry-run cost-calibration mode (HLO cost analysis counts scan bodies
    once; see launch/dryrun.py)."""
    m, w = a_words.shape
    k, b = x.shape
    block_k = min(block_k, k)
    assert k % block_k == 0, (k, block_k)
    nk = k // block_k
    wk = block_k // WORD

    def body_chunk(acc, aw, xc):
        a_dense = packed.unpack(aw).astype(jnp.bfloat16)          # (m, block_k)
        return acc + jnp.dot(a_dense, xc.astype(jnp.bfloat16),
                             preferred_element_type=jnp.float32)

    acc = jnp.zeros((m, b), jnp.float32)
    if unroll:
        # §Perf H7: slice chunks in place — the scan path's stacked
        # (nk, m, wk) transpose copy doubles the matrix's HBM footprint.
        for i in range(nk):
            acc = body_chunk(acc, jax.lax.dynamic_slice_in_dim(
                a_words, i * wk, wk, axis=1),
                jax.lax.dynamic_slice_in_dim(x, i * block_k, block_k, axis=0))
    else:
        a_chunks = a_words.reshape(m, nk, wk).transpose(1, 0, 2)  # (nk, m, wk)
        x_chunks = x.reshape(nk, block_k, b)

        def body(acc, operands):
            aw, xc = operands
            return body_chunk(acc, aw, xc), None

        acc, _ = jax.lax.scan(body, acc, (a_chunks, x_chunks))
    return (acc > 0) if threshold else acc


def bitmm(a_words: jax.Array, x: jax.Array, *, threshold: bool = True,
          impl: str = "auto", **kw) -> jax.Array:
    """Y = f(unpack(a_words) @ x); see kernels/bitmm.py."""
    impl = _resolve(impl)
    if impl == "reference":
        return ref.bitmm_ref(a_words, x, threshold=threshold)
    if impl == "blocked":
        return _bitmm_blocked(a_words, x, threshold=threshold,
                              **{k: v for k, v in kw.items() if k == "block_k"})
    out = bitmm_pallas(a_words, x, threshold=threshold,
                       interpret=not _on_tpu(), **kw)
    return (out > 0) if threshold else out


# ------------------------------------------------------------ closure step
@jax.jit
def _closure_step_blocked(r_words):
    n, w = r_words.shape
    dense = packed.unpack(r_words, n)            # (N, N) bool — CPU-scale only
    r2 = (dense.astype(jnp.float32) @ dense.astype(jnp.float32)) > 0
    return packed.pack(r2 | dense)


def closure_step(r_words: jax.Array, *, impl: str = "auto", **kw) -> jax.Array:
    impl = _resolve(impl)
    if impl == "reference":
        return ref.closure_step_ref(r_words)
    if impl == "blocked":
        return _closure_step_blocked(r_words)
    return closure_step_pallas(r_words, interpret=not _on_tpu(), **kw)


def transitive_closure(adj_words: jax.Array, *, impl: str = "auto",
                       n_steps: int | None = None, **kw) -> jax.Array:
    import math
    n = adj_words.shape[0]
    steps = n_steps if n_steps is not None else max(1, math.ceil(math.log2(max(n, 2))))
    r = adj_words
    for _ in range(steps):
        r = closure_step(r, impl=impl, **kw)
    return r


# --------------------------------------------------------------- intersect
def intersect(rows: jax.Array, *, impl: str = "auto", **kw):
    """rows uint32 (F, K, W) -> (and_rows (F, W), counts (F,))."""
    impl = _resolve(impl)
    if impl in ("reference", "blocked"):
        return ref.intersect_ref(rows)
    return intersect_pallas(rows, interpret=not _on_tpu(), **kw)
