"""``closure_step`` — packed boolean matrix squaring Pallas TPU kernel.

One round of ``R' = R | (R·R > 0)`` with *both* operands and the output kept
bit-packed (uint32).  Repeated ⌈log₂ diameter⌉ times this yields the
transitive closure — the descendant-edge substrate of the TPU path, replacing
the paper's CPU-oriented BFL probes with MXU work (see DESIGN.md §5.2).

Per grid step (i, j, k):
  * unpack tile R[i,k] -> (bm, bk) bf16, R[k,j] -> (bk, bn),
  * MXU matmul accumulate into a VMEM f32 scratch,
  * final k: OR with the original R[i,j] tile and *repack* to uint32.

Grid: (M/bm, N_words/wn, K/bk), contraction innermost.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

WORD = 32


def _unpack_tile(words):
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, WORD), 2)
    bits = (words[:, :, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(words.shape[0], -1)


def _pack_tile(bits):
    # bits: (bm, bn) int/bool -> (bm, bn/32) uint32
    bm, bn = bits.shape
    w = bits.reshape(bm, bn // WORD, WORD).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jax.lax.broadcasted_iota(
        jnp.uint32, (1, 1, WORD), 2))
    return (w * weights).sum(axis=-1).astype(jnp.uint32)


def _closure_kernel(ra_ref, rb_ref, rc_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = _unpack_tile(ra_ref[...]).astype(jnp.float32)     # (bm, bk)
    b = _unpack_tile(rb_ref[...]).astype(jnp.float32)     # (bk, bn)
    acc_ref[...] += jax.lax.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        new_bits = acc_ref[...] > 0                        # (bm, bn) bool
        orig = rc_ref[...]                                 # (bm, bn/32) uint32
        o_ref[...] = orig | _pack_tile(new_bits)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def closure_step_pallas(r_words: jax.Array, *, bm: int = 256, bn: int = 1024,
                        bk: int = 1024, interpret: bool = False) -> jax.Array:
    """R' = R | (R·R > 0); r_words uint32 (N, N/32) -> same shape."""
    n, wn_total = r_words.shape
    assert wn_total * WORD == n, "closure requires a square packed matrix"
    bm = min(bm, n)
    bn = min(bn, n)
    bk = min(bk, n)
    assert n % bm == 0 and n % bn == 0 and n % bk == 0
    grid = (n // bm, n // bn, n // bk)
    wn = bn // WORD
    return pl.pallas_call(
        _closure_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk // WORD), lambda i, j, k: (i, k)),   # R[i,k]
            pl.BlockSpec((bk, wn), lambda i, j, k: (k, j)),           # R[k,j]
            pl.BlockSpec((bm, wn), lambda i, j, k: (i, j)),           # R[i,j]
        ],
        out_specs=pl.BlockSpec((bm, wn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, wn_total), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r_words, r_words, r_words)


def transitive_closure(adj_words: jax.Array, n_steps: int | None = None,
                       step_fn=None, **kw) -> jax.Array:
    """Full closure by repeated squaring: ⌈log2(N)⌉ rounds reach any diameter.

    ``step_fn`` defaults to :func:`closure_step_pallas`; pass
    ``ref.closure_step_ref`` (or the blocked jnp variant in ops.py) on CPU.
    """
    import math
    n = adj_words.shape[0]
    steps = n_steps if n_steps is not None else max(1, math.ceil(math.log2(max(n, 2))))
    fn = step_fn or (lambda r: closure_step_pallas(r, **kw))
    r = adj_words
    for _ in range(steps):
        r = fn(r)
    return r
