"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps shapes and
dtypes and asserts allclose (exact for the integer/boolean kernels) against
these functions.  They are also usable as slow fallbacks on CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import packed


def bitmm_ref(a_words: jax.Array, x: jax.Array, *,
              threshold: bool = True) -> jax.Array:
    """Boolean matmul with a bit-packed left operand.

    a_words: uint32 (M, K/32) — packed 0/1 matrix rows.
    x:       (K, B) float or bool — dense right operand.
    returns  (M, B): ``threshold=True`` -> bool (any-path exists: (A@x) > 0);
             ``threshold=False`` -> float32 counts (A @ x)  [GNN sum-agg].
    """
    a = packed.unpack(a_words).astype(jnp.float32)          # (M, K)
    y = a @ x.astype(jnp.float32)
    return (y > 0) if threshold else y


def closure_step_ref(r_words: jax.Array) -> jax.Array:
    """One boolean-squaring step of transitive closure on packed rows:
    R' = R | (R·R > 0), packed uint32 (N, N/32) -> same shape."""
    n = r_words.shape[0]
    r = packed.unpack(r_words, n).astype(jnp.float32)       # (N, N)
    r2 = (r @ r) > 0
    return packed.pack(r2 | (r > 0))


def intersect_ref(rows: jax.Array) -> tuple[jax.Array, jax.Array]:
    """K-way AND + popcount.

    rows: uint32 (F, K, W) — per item, K packed rows to intersect.
    returns (and_rows uint32 (F, W), counts int32 (F,)).
    """
    acc = rows[:, 0]
    for i in range(1, rows.shape[1]):
        acc = acc & rows[:, i]
    counts = packed.popcount(acc).sum(axis=-1)
    return acc, counts


def segsum_ref(edge_src: jax.Array, edge_dst: jax.Array, feats: jax.Array,
               n_nodes: int) -> jax.Array:
    """Edge-index message passing oracle: out[d] = Σ_{(s,d)∈E} feats[s].

    The production path is ``jax.ops.segment_sum``; this oracle recomputes
    it with an explicit scatter-add for kernel tests.
    """
    msgs = feats[edge_src]
    return jnp.zeros((n_nodes, feats.shape[-1]), feats.dtype).at[edge_dst].add(msgs)
