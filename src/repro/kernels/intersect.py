"""``intersect`` — k-way bitset AND + popcount Pallas TPU kernel.

The MJoin candidate step (Alg. 5, lines 5–7): for a frontier of F partial
matches, each constrained by K packed adjacency rows (gathered upstream),
produce the intersected candidate bitset and its cardinality.  Keeping the
AND-reduce + popcount fused avoids a (F, N) boolean round-trip through HBM.

Grid: (F/bf, W/bw); the K axis is tiny (number of bound neighbours of the
current query node, ≤ max degree of the pattern) and is unrolled in-kernel.
Counts are accumulated across W blocks in a VMEM scratch and written on the
last block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams


def _intersect_kernel(rows_ref, and_ref, cnt_ref, acc_ref, *, k_rows: int):
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    tile = rows_ref[...]                       # (bf, K, bw) uint32
    acc = tile[:, 0]
    for i in range(1, k_rows):                 # K is static and small
        acc = acc & tile[:, i]
    and_ref[...] = acc
    pc = jax.lax.population_count(acc).astype(jnp.int32)   # (bf, bw)
    acc_ref[...] += pc.sum(axis=1, keepdims=True)

    @pl.when(w == pl.num_programs(1) - 1)
    def _done():
        cnt_ref[...] = acc_ref[...]


@jax.jit
def intersect_xla(rows: jax.Array):
    """XLA expression of the same fused AND-reduce + popcount.

    rows: uint32 (F, K, W) -> (and_rows uint32 (F, W), counts int32 (F,)).
    The default executor on non-TPU backends, where it beats the Pallas
    interpreter by orders of magnitude while keeping the contraction on
    the device runtime (shapes are identical, so results are too).
    """
    acc = rows[:, 0]
    for i in range(1, rows.shape[1]):
        acc = acc & rows[:, i]
    counts = jax.lax.population_count(acc).astype(jnp.int32).sum(axis=1)
    return acc, counts


@functools.partial(jax.jit, static_argnames=("bf", "bw", "interpret"))
def intersect_pallas(rows: jax.Array, *, bf: int = 128, bw: int = 512,
                     interpret: bool = False):
    """rows: uint32 (F, K, W) -> (and_rows uint32 (F, W), counts int32 (F,)).

    Shapes need not be block multiples: inputs are zero-padded up to the
    grid (zero rows AND to zero and popcount to zero, so padding never
    perturbs real counts) and outputs sliced back.
    """
    f, k_rows, w = rows.shape
    bf = min(bf, f)
    bw = min(bw, w)
    fp = -(-f // bf) * bf
    wp = -(-w // bw) * bw
    if (fp, wp) != (f, w):
        rows = jnp.pad(rows, ((0, fp - f), (0, 0), (0, wp - w)))
    grid = (fp // bf, wp // bw)
    and_rows, counts = pl.pallas_call(
        functools.partial(_intersect_kernel, k_rows=k_rows),
        grid=grid,
        in_specs=[pl.BlockSpec((bf, k_rows, bw), lambda i, j: (i, 0, j))],
        out_specs=[
            pl.BlockSpec((bf, bw), lambda i, j: (i, j)),
            pl.BlockSpec((bf, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((fp, wp), jnp.uint32),
            jax.ShapeDtypeStruct((fp, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((bf, 1), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rows)
    return and_rows[:f, :w], counts[:f, 0]
