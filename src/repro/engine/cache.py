"""Cross-query caches: per-graph label structures + LRU plan cache.

The paper's key property is that the RIG is *runtime* state — built per
query, never persisted.  What IS worth persisting across queries are the
graph-side artifacts every query re-uses:

* the reachability labeling (SCC condensation + packed closure — the BFL
  stand-in of §7.1) and its transpose,
* the packed adjacency bit-matrices (both directions),
* DFS interval labels (§5.5 early expansion termination),
* graph statistics for the planner.

``GraphContext`` owns those for one resident graph and builds them exactly
once (``label_builds`` counts constructions so tests and benchmarks can
prove the warm path skips them).  ``LRUCache`` is the generic bounded map
used for the plan / RIG-stats cache keyed by canonical query form.
"""

from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Hashable, List, Optional, Tuple

from ..core.graph import DataGraph
from ..core.reachability import IntervalLabels
from ..core.simulation import EdgeOracle
from ..robust import faults
from .stats import GraphStats

__all__ = ["LRUCache", "GraphContext"]


class LRUCache:
    """Bounded least-recently-used map with hit/miss/eviction counters.

    Counters are plain ints by default; ``bind_metrics(registry, name)``
    additionally mirrors them onto registry counters
    (``cache_hits{cache=name}`` etc.) so engine-wide snapshots see them —
    the ints stay authoritative for existing callers.

    ``on_evict(key, value)`` is invoked for every entry leaving the cache
    involuntarily — capacity eviction, ``drop_where`` and ``clear`` — so
    values owning external resources (device-resident RIG matrices) are
    torn down the moment their entry dies instead of leaking until GC."""

    def __init__(self, capacity: int = 256, *, metrics=None,
                 name: str = "", on_evict=None):
        assert capacity > 0
        self.capacity = capacity
        self._d: "OrderedDict[Hashable, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.on_evict = on_evict
        self._c_hits = self._c_misses = self._c_evictions = None
        if metrics is not None:
            self.bind_metrics(metrics, name or "lru")

    def bind_metrics(self, registry, name: str) -> "LRUCache":
        self._c_hits = registry.counter("cache_hits", cache=name)
        self._c_misses = registry.counter("cache_misses", cache=name)
        self._c_evictions = registry.counter("cache_evictions", cache=name)
        self._c_hits.value = self.hits
        self._c_misses.value = self.misses
        self._c_evictions.value = self.evictions
        return self

    def get(self, key: Hashable, default: Any = None) -> Any:
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            if self._c_hits is not None:
                self._c_hits.inc()
            return self._d[key]
        self.misses += 1
        if self._c_misses is not None:
            self._c_misses.inc()
        return default

    def put(self, key: Hashable, value: Any) -> None:
        if key in self._d:
            self._d.move_to_end(key)
        self._d[key] = value
        while len(self._d) > self.capacity:
            k, v = self._d.popitem(last=False)
            self.evictions += 1
            if self._c_evictions is not None:
                self._c_evictions.inc()
            if self.on_evict is not None:
                self.on_evict(k, v)

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._d

    def drop_where(self, pred) -> int:
        """Remove entries whose key matches ``pred``; returns the count."""
        dead = [k for k in self._d if pred(k)]
        for k in dead:
            v = self._d.pop(k)
            if self.on_evict is not None:
                self.on_evict(k, v)
        return len(dead)

    def clear(self) -> None:
        items = list(self._d.items())
        self._d.clear()
        if self.on_evict is not None:
            for k, v in items:
                self.on_evict(k, v)


@dataclass
class GraphContext:
    """Per-resident-graph state: label structures, statistics, matchers.

    ``ensure_labels()`` builds the reachability labeling, packed adjacency
    and interval labels on first call and is a no-op afterwards; the engine
    calls it on every execution and reports the hit/miss in per-query stats.
    """

    graph: DataGraph
    stats: GraphStats = field(init=False)
    oracle: Optional[EdgeOracle] = field(default=None, init=False)
    intervals: Optional[IntervalLabels] = field(default=None, init=False)
    label_builds: int = field(default=0, init=False)
    label_build_s: float = field(default=0.0, init=False)
    # (phase name, duration_s) for the most recent cold build — lets the
    # engine attach real child spans to the "labels" span after the fact
    label_phases: List[Tuple[str, float]] = field(default_factory=list,
                                                  init=False)

    def __post_init__(self) -> None:
        self.stats = GraphStats.collect(self.graph)

    @property
    def labels_ready(self) -> bool:
        return self.oracle is not None

    def ensure_labels(self) -> bool:
        """Build the per-graph label structures once.  Returns ``True`` when
        they were already resident (a label-cache hit).

        The build is transactional: nothing is assigned to ``self`` until
        every structure exists, so a mid-build failure (device fault, the
        ``label_build`` injection site) leaves the context cleanly cold and
        the next call rebuilds from scratch — recompute, not repair.
        """
        if self.labels_ready:
            return True
        faults.maybe_fail("label_build")
        t0 = time.perf_counter()
        oracle = EdgeOracle(self.graph)         # builds ReachabilityIndex
        oracle._reach.bits_t()                  # ancestor rows (backward sim)
        t1 = time.perf_counter()
        self.graph.adj_bits()
        self.graph.adj_bits_t()
        t2 = time.perf_counter()
        intervals = IntervalLabels.build(self.graph)
        t3 = time.perf_counter()
        self.oracle = oracle                    # commit point
        self.intervals = intervals
        self.label_phases = [("reachability", t1 - t0),
                             ("adjacency", t2 - t1),
                             ("intervals", t3 - t2)]
        self.label_builds += 1
        self.label_build_s += t3 - t0
        return False
