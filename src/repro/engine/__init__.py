# The query-facing engine subsystem in front of the RIG/MJoin core: a
# textual hybrid-pattern query language (parser + pretty-printer), a
# statistics-driven planner choosing backend / simulation algorithm / check
# method per query, and an Engine facade with cross-query caches (per-graph
# reachability/interval labels, LRU plan + RIG-stats cache), batched
# execution, and observability (per-query span traces via
# ``execute(..., profile=True)``, a per-engine metrics registry, and
# ``explain()`` plan trees — see ``repro.obs``).
from ..obs import (MetricsRegistry, Span, Tracer, prometheus_text,
                   render_trace, trace_to_json)
from .cache import GraphContext, LRUCache
from .canonical import canonical_form, canonical_key
from .engine import (Engine, EngineOptions, EngineResult, EngineStats,
                     EngineStream)
from .language import QueryParseError, Vocab, fmt, parse
from .planner import DeviceCaps, Plan, Planner
from .stats import GraphStats, RigStats
from ..robust import (AdmissionError, BreakerOpen, Budget, CircuitBreaker,
                      DeadlineExceeded, DeviceFailure, InjectedFault,
                      QueryError, ResourceExhausted, TransientError)

__all__ = [
    "Engine", "EngineOptions", "EngineResult", "EngineStats", "EngineStream",
    "Vocab", "QueryParseError", "parse", "fmt",
    "canonical_form", "canonical_key",
    "Plan", "Planner", "DeviceCaps",
    "GraphStats", "RigStats", "GraphContext", "LRUCache",
    "Span", "Tracer", "MetricsRegistry",
    "render_trace", "trace_to_json", "prometheus_text",
    "Budget", "CircuitBreaker",
    "QueryError", "DeadlineExceeded", "ResourceExhausted", "TransientError",
    "DeviceFailure", "BreakerOpen", "InjectedFault", "AdmissionError",
]
