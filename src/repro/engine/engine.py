"""``Engine`` — the query-facing facade over the RIG/MJoin core.

Pipeline per query::

    text ──parse──▶ PatternQuery ──TR+canonicalize──▶ key
         ──plan-cache──▶ Plan (backend, sim algo, check method, ordering,
                               enum method, streaming chunk size)
         ──label-cache──▶ resident reachability/adjacency/interval labels
         ──execute──▶ host GM  or  device JaxGM
         ──execute_stream──▶ chunked lazy enumeration (host or
                             device-resident data path)
         ──execute_many──▶ per-graph groups, canonical-form dedup, one
                           vmapped device dispatch + one micro-batched
                           frontier scheduler per group

Cross-query state (everything the paper's per-query pipeline would
otherwise recompute):

* **label cache** — one :class:`GraphContext` per resident graph holds the
  reachability labeling, packed adjacency and DFS interval labels; built
  once, shared by every subsequent query on that graph;
* **plan / RIG-stats cache** — an LRU keyed by the canonical form of the
  transitively-reduced query; repeat queries skip planning and are
  re-planned against *observed* RIG sizes (tiny RIG -> host enumeration).

The RIG itself remains runtime state, rebuilt per query — the paper's
defining property; the engine only hoists the graph-side indexes and the
per-query *decisions* out of the hot path.
"""

from __future__ import annotations

import itertools
import time
import warnings
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.graph import DataGraph
from ..core.matcher import GM, MatchResult, MatchStream
from ..core.mjoin import DEFAULT_LIMIT, device_intersector
from ..core.query import PatternQuery
from ..obs.events import QueryEvent
from ..obs.export import prometheus_text, render_trace
from ..obs.flight import FlightRecorder
from ..obs.ledger import get_ledger
from ..obs.metrics import MetricsRegistry
from ..obs.trace import NULL_TRACER, Span, Tracer
from ..obs.window import WindowedAggregator
from ..robust import Budget, CircuitBreaker
from ..robust.errors import (BreakerOpen, DeadlineExceeded, DeviceFailure,
                             QueryError, TransientError)
from .cache import GraphContext, LRUCache
from .canonical import canonical_key
from .language import Vocab, fmt, parse
from .planner import DEVICE, HOST, DeviceCaps, Plan, Planner
from .stats import (ESTIMATE_QUANTITIES, Calibration, EstimateRecord,
                    RigStats)

__all__ = ["EngineOptions", "EngineStats", "EngineResult", "EngineStream",
           "Engine"]

QueryLike = Union[str, PatternQuery]
RequestLike = Union[QueryLike, Tuple[QueryLike, DataGraph]]

_UNSET = object()

_TPU_AVAILABLE: Optional[bool] = None


def _tpu_available() -> bool:
    global _TPU_AVAILABLE
    if _TPU_AVAILABLE is None:
        try:
            import jax
            _TPU_AVAILABLE = jax.default_backend() == "tpu"
        except Exception:
            _TPU_AVAILABLE = False
    return _TPU_AVAILABLE


@dataclass
class EngineOptions:
    # device matcher caps (see DeviceCaps)
    max_q: int = 8
    max_e: int = 16
    capacity: int = 4096
    device_min_nodes: int = 512
    device_impl: str = "auto"          # jaxgm kernel impl: auto|reference|...
    exact_sim: bool = True             # device sim to fixpoint (host-equal)
    # engine knobs
    plan_cache_size: int = 256
    max_resident_graphs: int = 8
    force_backend: Optional[str] = None   # "host" | "device" | None
    force_enum: Optional[str] = None      # fixed enum_method | None (planned)
    # route the frontier enumerator's AND+popcount through the Pallas
    # intersect kernel: None = auto (only on real TPU backends — the
    # interpreter fallback is orders of magnitude slower than numpy)
    frontier_device: Optional[bool] = None
    # device-memory budget for resident RIG uploads: a frontier-device
    # query whose estimated packed adjacency fits is planned as
    # frontier-device-resident (index stays on device, host ships only
    # per-level index vectors)
    resident_max_bytes: int = 1 << 30
    limit: Optional[int] = DEFAULT_LIMIT
    materialize: bool = True
    # resource governance (PR 7): the default per-query Budget *template*
    # (armed per execution; None = ungoverned) and the engine's device
    # circuit breaker (None = a default CircuitBreaker; shared by every
    # device dispatch this engine issues)
    budget: Optional[Budget] = None
    breaker: Optional[CircuitBreaker] = None
    # serving telemetry (PR 9): always-on per-request event records in a
    # bounded flight recorder plus windowed QPS/error-rate/quantile series.
    # ``telemetry=False`` disables recording entirely (the A/B lever for
    # the profile-smoke overhead gate; the recorder objects still exist).
    telemetry: bool = True
    flight_capacity: int = 2048
    exemplar_k: int = 8              # slowest-k full-trace exemplars
    window_s: float = 10.0           # sliding-window width (seconds)
    n_windows: int = 6               # closed windows retained

    def caps(self) -> DeviceCaps:
        fd = self.frontier_device
        if fd is None:
            fd = _tpu_available()
        return DeviceCaps(max_q=self.max_q, max_e=self.max_e,
                          capacity=self.capacity,
                          min_graph_nodes=self.device_min_nodes,
                          frontier_device=fd,
                          resident_max_bytes=self.resident_max_bytes)


@dataclass
class EngineStats:
    """Per-query execution record.

    ``sim_passes`` is the measured pass count on the host backend, the
    fixed pass budget on the truncated device path, and 0 (not tracked) on
    the exact-sim device path.
    """

    backend: str = HOST
    count: int = 0
    parse_s: float = 0.0
    plan_s: float = 0.0
    exec_s: float = 0.0
    total_s: float = 0.0
    plan_cache_hit: bool = False
    label_cache_hit: bool = False
    overflow_fallback: bool = False
    sim_passes: int = 0
    rig_nodes: int = 0
    rig_edges: int = 0
    truncated: bool = False
    enum_method: str = "backtrack"   # strategy that ran (device: jaxgm's)
    # transfer ledger (PR 10): bytes this query moved host<->device and the
    # device-resident RIG footprint it executed against (0 off-device)
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    resident_bytes: int = 0
    # resource governance (PR 7): ``status`` is the stable outcome string
    # ("ok", or the error taxonomy's status — "deadline_exceeded",
    # "resource_exhausted", "transient", ...); ``partial`` marks a
    # correctly-truncated prefix result; ``degradations`` the ladder steps
    # taken (host-intersect / chunked-slabs / backtrack / host) in order;
    # ``attempts`` counts executions including transient-failure retries.
    status: str = "ok"
    error_type: str = ""             # exception class when status != "ok"
    partial: bool = False
    deadline_exceeded: bool = False
    degradations: List[str] = field(default_factory=list)
    attempts: int = 1
    # streaming (execute_stream)
    streamed: bool = False
    chunks: int = 0                  # result chunks yielded
    chunk_size: int = 0              # planned/requested chunk rows
    # batching (execute_many)
    shared_exec: bool = False        # answered by a duplicate in the batch
    # engine-wide plan-cache counters, snapshotted atomically at *prepare*
    # time — i.e. right after this query's own cache access, not when it
    # finished.  Concurrent streams finalizing out of order therefore see
    # their own consistent cut instead of whatever the cache holds later.
    query_id: int = 0
    plan_cache_hits: int = 0
    plan_cache_misses: int = 0
    plan_cache_evictions: int = 0


@dataclass
class EngineResult:
    count: int
    tuples: Optional[np.ndarray]
    query: PatternQuery            # the executed (transitively-reduced) query
    plan: Plan
    stats: EngineStats
    key: str
    trace: Optional[Span] = None   # span tree when profile=True, else None


class EngineStream:
    """Lazy result stream returned by :meth:`Engine.execute_stream`.

    Iterate for ``(chunk, q.n)`` int64 ndarray chunks (global node ids,
    query-node order) in the same lexicographic order as one-shot
    ``execute``; every chunk except the last has exactly ``chunk_size``
    rows.  Enumeration advances only as chunks are consumed — stopping
    early (``close()``, or just abandoning the iterator after a ``break``)
    never visits the tail, and hitting ``limit`` cuts the final chunk at
    exactly ``limit`` rows with ``stats.truncated`` set.

    ``stats`` and ``count`` are live during iteration; when the stream is
    exhausted (or closed) the engine records timings, plan-cache counters
    and — only on natural completion — the observed RIG statistics that
    feed re-planning.
    """

    def __init__(self, engine: "Engine", entry: "_PlanEntry",
                 match: MatchStream, stats: "EngineStats",
                 query: PatternQuery, key: str, tracer=None):
        self.engine = engine
        self.match = match
        self.query = query
        self.plan = entry.plan
        self.key = key
        self.stats = stats
        self.trace: Optional[Span] = None   # set on finalize when profiled
        self._entry = entry
        self._tracer = tracer
        self._it = iter(match)
        self._finalized = False

    def __iter__(self) -> "EngineStream":
        return self

    def __next__(self):
        try:
            chunk = next(self._it)
        except StopIteration:
            self._finalize(completed=True)
            raise
        except BaseException:
            # satellite fix (PR 7): a mid-iteration failure — an injected
            # fault, a raise-mode DeadlineExceeded, a consumer-driven
            # GeneratorExit — must still close the suspended MJoin state
            # and record stats/metrics exactly once before propagating
            self.match.close()
            self._finalize(completed=False)
            raise
        self.stats.chunks += 1
        return chunk

    def __enter__(self) -> "EngineStream":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Stop early: drops the suspended enumeration state and records
        stats for the consumed prefix (no RIG-stats observation — a
        partial count must not feed re-planning)."""
        self.match.close()
        self._finalize(completed=False)

    @property
    def count(self) -> int:
        return self.match.count

    def _finalize(self, completed: bool) -> None:
        if self._finalized:
            return
        self._finalized = True
        m = self.match
        # an early-closed stream's partial count must not feed re-planning
        self.engine._observe_host(self._entry, self.stats, m,
                                  observe=completed)
        self.stats.exec_s = m.matching_s + m.enumerate_s
        self.engine.counters["stream_queries"] += 1
        self.engine._finish(self.stats, m.count)
        tr = self._tracer
        if tr is not None and tr.enabled:
            # enumeration ran lazily across the consumer's iteration — the
            # span is synthesized from the stream's accumulated timings
            tr.add("enumerate", duration_s=m.enumerate_s,
                   method=self.stats.enum_method, results=m.count,
                   chunks=self.stats.chunks, completed=completed,
                   truncated=self.stats.truncated)
            tr.add("materialize", streamed=True, chunks=self.stats.chunks,
                   chunk_size=self.stats.chunk_size)
            self.trace = tr.finish()
        self.engine._record_event(self.stats, self.key, m.count,
                                  trace_root=self.trace)


@dataclass
class _PlanEntry:
    """One cached plan plus everything warm repeats of the query reuse:
    observed RIG statistics (re-planning), the planner's committed
    estimates with their observed reconciliation (EXPLAIN ANALYZE), the
    per-graph calibration the ratios feed, and — for resident-planned
    queries — the uploaded device executor, so repeats skip the re-upload.
    The plan cache's ``on_evict`` closes ``resident`` (crediting the
    ledger) the moment the entry leaves the cache."""

    plan: Plan
    rig: RigStats = field(default_factory=RigStats)
    est: EstimateRecord = field(default_factory=EstimateRecord)
    cal: Optional[Calibration] = None
    resident: Optional[object] = field(default=None, repr=False)


_RESIDENT_EPOCH = itertools.count()


class _Resident:
    """A registered graph: context + lazily-created matchers.

    ``epoch`` is a process-unique token used in plan-cache keys instead of
    ``id(graph)`` — a new graph allocated at a recycled address must not
    inherit an evicted graph's plans or RIG statistics.
    """

    def __init__(self, graph: DataGraph, options: EngineOptions,
                 label_names=None):
        self.ctx = GraphContext(graph)
        self.epoch = next(_RESIDENT_EPOCH)
        self.options = options
        self.vocab = Vocab.for_graph(graph, names=label_names)
        self.planner = Planner(self.ctx.stats, caps=options.caps(),
                               force_backend=options.force_backend,
                               force_enum=options.force_enum)
        self._gm: Optional[GM] = None
        self._jgm = None
        self._jgm_error: Optional[str] = None

    def gm(self) -> GM:
        if self._gm is None:
            self.ctx.ensure_labels()
            self._gm = GM(self.ctx.graph)
            self._gm.oracle = self.ctx.oracle     # share the label cache
            self._gm.intervals = self.ctx.intervals   # §5.5 interval path
        return self._gm

    def jgm(self):
        """Device matcher, or ``None`` if the device path is unavailable
        (then the caller re-routes to the host; the error is kept on
        ``_jgm_error`` and surfaced through ``Engine.cache_info``)."""
        if self._jgm is None and self._jgm_error is None:
            try:
                from ..jaxgm import JaxGM
                o = self.options
                self._jgm = JaxGM(self.ctx.graph, max_q=o.max_q,
                                  max_e=o.max_e, capacity=o.capacity,
                                  exact_sim=o.exact_sim, impl=o.device_impl,
                                  use_transitive_reduction=False)
            except Exception as e:
                self._jgm_error = f"{type(e).__name__}: {e}"
                warnings.warn(
                    f"device matcher unavailable, queries re-route to the "
                    f"host backend: {self._jgm_error}", RuntimeWarning,
                    stacklevel=2)
        return self._jgm


_ENGINE_COUNTERS = (
    "queries", "host_exec", "device_exec", "overflow_fallbacks",
    "label_builds", "stream_queries", "shared_exec",
    "frontier_batches", "frontier_batch_dispatches",
    # resource governance (PR 7); engine_device_retries and the
    # engine_breaker_state gauge are bound by the CircuitBreaker itself
    "deadline_exceeded", "budget_degradations", "transient_retries",
    # resident enumerator (PR 8): uploads (cache misses), fused
    # gather+AND+popcount dispatches, and sub-threshold slabs kept on host
    "resident_uploads", "resident_dispatches", "small_frontier_host_routed",
)


class _CounterView:
    """Dict-compatible facade over the engine's registry-backed counters.

    ``Engine.counters`` predates the metrics registry; existing callers do
    ``eng.counters["queries"] += 1`` and read it like a dict.  The values
    now live in :class:`~repro.obs.metrics.Counter` objects (series
    ``engine_<name>``), so registry snapshots and the Prometheus exporter
    see them — this view keeps the old surface working on top.
    """

    def __init__(self, registry: MetricsRegistry, names=_ENGINE_COUNTERS,
                 prefix: str = "engine_"):
        self._registry = registry
        self._prefix = prefix
        self._c = {n: registry.counter(prefix + n) for n in names}

    def _counter(self, key: str):
        c = self._c.get(key)
        if c is None:
            c = self._c[key] = self._registry.counter(self._prefix + key)
        return c

    def __getitem__(self, key: str) -> int:
        return self._c[key].value

    def __setitem__(self, key: str, value: int) -> None:
        self._counter(key).value = int(value)

    def __contains__(self, key) -> bool:
        return key in self._c

    def __iter__(self):
        return iter(self._c)

    def __len__(self) -> int:
        return len(self._c)

    def keys(self):
        return self._c.keys()

    def values(self):
        return [c.value for c in self._c.values()]

    def items(self):
        return [(k, c.value) for k, c in self._c.items()]

    def get(self, key: str, default=None):
        c = self._c.get(key)
        return default if c is None else c.value

    def copy(self) -> Dict[str, int]:
        return dict(self.items())

    def __eq__(self, other) -> bool:
        if isinstance(other, dict):
            return dict(self.items()) == other
        return NotImplemented

    def __repr__(self) -> str:
        return repr(dict(self.items()))


class Engine:
    """Query engine bound to one (or a few) resident data graphs."""

    def __init__(self, graph: Optional[DataGraph] = None, *,
                 options: Optional[EngineOptions] = None,
                 label_names=None):
        self.options = options or EngineOptions()
        self._residents: "OrderedDict[int, _Resident]" = OrderedDict()
        # per-engine metrics registry: counters/caches/histograms below all
        # live here, so snapshot()/metrics_text() is one consistent view
        self.metrics = MetricsRegistry()
        # memory & transfer ledger (PR 10): the process-wide ledger is
        # published into this registry at snapshot/exposition time; the
        # plan cache's eviction hook credits it when a cached resident
        # executor is torn down
        self.ledger = get_ledger()
        self._plan_cache = LRUCache(self.options.plan_cache_size,
                                    on_evict=self._evict_plan_entry)
        self._plan_cache.bind_metrics(self.metrics, "plan")
        # memo: reduced-query structure -> canonical key, so the exact
        # (up to n! permutations) canonicalization runs once per distinct
        # query structure, not on every plan-cache hit
        self._canon_memo = LRUCache(4 * self.options.plan_cache_size)
        self._canon_memo.bind_metrics(self.metrics, "canon")
        self.default_graph = graph
        self.counters = _CounterView(self.metrics)
        # serving telemetry (PR 9): one bounded flight recorder + one
        # sliding-window aggregator per engine, armed on every request in
        # all three execution modes.  ``telemetry`` is a live toggle (the
        # profile-smoke overhead gate flips it for same-process A/B).
        self.telemetry = self.options.telemetry
        self.flight = FlightRecorder(capacity=self.options.flight_capacity,
                                     exemplar_k=self.options.exemplar_k)
        self.windows = WindowedAggregator(window_s=self.options.window_s,
                                          n_windows=self.options.n_windows)
        # one breaker per engine, shared by every device dispatch and
        # mirrored into engine_breaker_state / engine_device_retries;
        # state transitions also land in the flight recorder (a transition
        # to open triggers the armed auto-dump)
        self.breaker = (self.options.breaker or CircuitBreaker())
        self.breaker.bind_metrics(self.metrics)
        self.breaker.bind_recorder(self.flight)
        self._qid = itertools.count(1)
        # histogram objects held directly: the hot path must not pay a
        # registry lookup per observation
        h = self.metrics.histogram
        self._h_parse = h("query_phase_seconds", phase="parse")
        self._h_plan = h("query_phase_seconds", phase="plan")
        self._h_exec = h("query_phase_seconds", phase="exec")
        self._h_total = h("query_phase_seconds", phase="total")
        self._h_rig_nodes = h("rig_nodes")
        self._h_rig_edges = h("rig_edges")
        self._h_sim_passes = h("sim_passes")
        self._h_results = h("result_count")
        # resident-RIG upload footprint (observed once per fresh upload)
        self._h_resident_bytes = h("resident_bytes")
        # planner accountability (PR 10): observed/estimated ratio per
        # quantity (1.0 = the planner was exactly right), fed on every
        # observed execution; plus the bytes freed by plan-cache evictions
        # tearing down cached resident executors
        self._h_misest = {q: h("planner_misestimation_ratio", quantity=q)
                          for q in ESTIMATE_QUANTITIES}
        self._c_resident_evicted = self.metrics.counter(
            "cache_resident_evicted_bytes")
        if graph is not None:
            self.register(graph, label_names=label_names)

    def _evict_plan_entry(self, key, entry) -> None:
        """Plan-cache teardown: an entry leaving the cache (capacity
        eviction, resident-graph eviction, clear) releases the device
        executor it cached — the ledger is credited by ``close()`` and the
        freed bytes land on ``cache_resident_evicted_bytes``."""
        ex = getattr(entry, "resident", None)
        if ex is None:
            return
        entry.resident = None
        try:
            freed = ex.close()
        except Exception:
            return
        if freed:
            self._c_resident_evicted.inc(freed)

    # ------------------------------------------------------------ residency
    def register(self, graph: DataGraph, label_names=None) -> GraphContext:
        """Make ``graph`` resident (idempotent).  Returns its context."""
        key = id(graph)
        if key not in self._residents:
            self._residents[key] = _Resident(graph, self.options,
                                             label_names=label_names)
            # ledger attribution key: every transfer/allocation this graph
            # causes is charged under it.  Callers (e.g. the server's
            # per-tenant rollups) may pre-stamp their own key; the epoch
            # default only fills the gap.
            if not getattr(graph, "graph_key", None):
                graph.graph_key = f"g{self._residents[key].epoch}"
            while len(self._residents) > self.options.max_resident_graphs:
                _, dead = self._residents.popitem(last=False)
                # epochs are never reused, so the evicted graph's plan
                # entries are unreachable — free their cache slots
                self._plan_cache.drop_where(lambda k: k[0] == dead.epoch)
        elif label_names is not None:
            self._residents[key].vocab = Vocab.for_graph(graph,
                                                         names=label_names)
        self._residents.move_to_end(key)
        if self.default_graph is None:
            self.default_graph = graph
        return self._residents[key].ctx

    def _resident(self, graph: Optional[DataGraph]) -> _Resident:
        g = graph if graph is not None else self.default_graph
        if g is None:
            raise ValueError("no resident graph: pass graph= or construct "
                             "Engine(graph)")
        self.register(g)
        return self._residents[id(g)]

    def context(self, graph: Optional[DataGraph] = None) -> GraphContext:
        return self._resident(graph).ctx

    # ------------------------------------------------------------- language
    @property
    def vocab(self) -> Vocab:
        """The default graph's label vocabulary (each resident graph keeps
        its own; ``parse``/``format`` accept ``graph=`` to select it)."""
        if self.default_graph is not None:
            return self._resident(None).vocab
        return Vocab()

    def parse(self, text: str, name: str = "",
              graph: Optional[DataGraph] = None) -> PatternQuery:
        vocab = (self._resident(graph).vocab
                 if (graph is not None or self.default_graph is not None)
                 else Vocab())
        return parse(text, vocab=vocab, name=name)

    def format(self, q: PatternQuery,
               graph: Optional[DataGraph] = None) -> str:
        vocab = (self._resident(graph).vocab
                 if (graph is not None or self.default_graph is not None)
                 else Vocab())
        return fmt(q, vocab=vocab)

    # ------------------------------------------------------------- planning
    def _prepare(self, query: QueryLike, res: _Resident,
                 stats: EngineStats, trace=NULL_TRACER):
        """parse (if text) + TR + canonical key + plan-cache lookup."""
        stats.query_id = next(self._qid)
        t0 = time.perf_counter()
        with trace.span("parse") as psp:
            q = (parse(query, vocab=res.vocab) if isinstance(query, str)
                 else query)
            if trace.enabled:
                psp.set(text=isinstance(query, str), n=q.n,
                        edges=len(q.edges))
        stats.parse_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        with trace.span("canonicalize") as csp:
            qr = q.transitive_reduction()
            raw = (tuple(qr.labels),
                   tuple((e.src, e.dst, e.kind) for e in qr.edges))
            ckey = self._canon_memo.get(raw)
            memo_hit = ckey is not None
            if ckey is None:
                ckey = canonical_key(qr, reduce=False)
                self._canon_memo.put(raw, ckey)
            if trace.enabled:
                csp.set(key=ckey, memo_hit=memo_hit,
                        reduced_edges=len(qr.edges))
        with trace.span("plan") as sp:
            key = (res.epoch, ckey)
            entry: Optional[_PlanEntry] = self._plan_cache.get(key)
            if entry is None:
                plan = res.planner.plan(qr)
                entry = _PlanEntry(plan=plan,
                                   est=EstimateRecord(est=plan.estimates()),
                                   cal=res.planner.calibration)
                self._plan_cache.put(key, entry)
            else:
                stats.plan_cache_hit = True
                entry.plan = res.planner.refine(entry.plan, qr, entry.rig)
                # the refined plan's estimates are the committed ones this
                # execution is accountable to
                entry.est.est = entry.plan.estimates()
            if trace.enabled:
                p = entry.plan
                sp.set(cached=stats.plan_cache_hit, backend=p.backend,
                       enum_method=p.enum_method, ordering=p.ordering,
                       sim_algo=p.sim_algo, est_cost=p.est_cost,
                       est_card=p.est_card, reasons=list(p.reasons))
        stats.plan_s = time.perf_counter() - t0
        # satellite fix: snapshot the engine-wide plan-cache counters *now*,
        # right after this query's own cache access — streams finalizing
        # later must not see other queries' interleaved accesses
        stats.plan_cache_hits = self._plan_cache.hits
        stats.plan_cache_misses = self._plan_cache.misses
        stats.plan_cache_evictions = self._plan_cache.evictions
        return qr, key[1], entry

    def explain(self, query: QueryLike,
                graph: Optional[DataGraph] = None) -> str:
        """The plan the engine would run, as a static lifecycle tree (does
        not execute).  Output is stable across repeat calls once the plan
        is cached (the first call may plan fresh; later calls refine
        against the same observed statistics and print identically)."""
        res = self._resident(graph)
        stats = EngineStats()
        qr, key, entry = self._prepare(query, res, stats)
        p = entry.plan
        cached = "cached" if stats.plan_cache_hit else "fresh"
        lines = [
            f"query {key}  [{cached} plan]",
            f"├─ parse        nodes={qr.n} edges={len(qr.edges)}",
            f"├─ plan         backend={p.backend} enum={p.enum_method} "
            f"ordering={p.ordering} sim={p.sim_algo}"
            f"(passes={p.sim_passes}) check={p.check_method} "
            f"chunk={p.chunk_size}",
        ]
        for r in p.reasons:
            lines.append(f"│     · {r}")
        lines.append("├─ labels       "
                     + ("resident" if res.ctx.labels_ready
                        else "cold (built on first execute)"))
        rig_line = (f"├─ rig          est_cost={p.est_cost:.4g} "
                    f"est_card={p.est_card:.4g}")
        if entry.rig.observations:
            rig_line += (f"  observed: nodes={entry.rig.rig_nodes} "
                         f"edges={entry.rig.rig_edges} "
                         f"count={entry.rig.count}")
        lines.append(rig_line)
        lines.append(f"└─ enumerate    method={p.enum_method} "
                     f"limit={self.options.limit}")
        return "\n".join(lines)

    def explain_analyze(self, query: QueryLike,
                        graph: Optional[DataGraph] = None,
                        materialize: Optional[bool] = None,
                        budget=_UNSET) -> str:
        """EXPLAIN ANALYZE: *execute* the query, then render the plan with
        its committed estimates reconciled against what the execution
        observed — per-quantity estimate/observed/ratio rows, which planner
        decisions would flip under the observed statistics, and the bytes
        the execution moved (per-query plus the graph's ledger rollup)."""
        res = self._resident(graph)
        result = self.execute(query, graph=graph, materialize=materialize,
                              budget=budget)
        # re-prepare (a guaranteed plan-cache hit) to fetch the entry the
        # execution just reconciled
        qr, key, entry = self._prepare(query, res, EngineStats())
        p, st = entry.plan, result.stats
        cached = "warm" if st.plan_cache_hit else "cold"
        lines = [
            f"query {key}  [analyzed: {cached} plan, backend={st.backend} "
            f"enum={st.enum_method} count={result.count} "
            f"status={st.status}]",
            f"├─ plan         backend={p.backend} enum={p.enum_method} "
            f"ordering={p.ordering} sim={p.sim_algo} chunk={p.chunk_size}",
        ]
        for r in p.reasons:
            lines.append(f"│     · {r}")
        lines.append("├─ estimates    (observed / estimated; "
                     "x1 = planner exactly right)")
        for quantity, est, obs, ratio in entry.est.rows():
            obs_s = "-" if obs is None else f"{obs:.6g}"
            ratio_s = "-" if ratio is None else f"x{ratio:.3g}"
            lines.append(f"│     {quantity:<15} est={est:<12.6g} "
                         f"obs={obs_s:<12} {ratio_s}")
        decisions = res.planner.analyze(p, qr, entry.est)
        if decisions:
            lines.append("├─ decisions")
            for name, planned, observed, flips in decisions:
                mark = "WOULD FLIP" if flips else "holds"
                lines.append(f"│     {name:<22} planned: {planned}  "
                             f"observed: {observed}  [{mark}]")
        lines.append(f"├─ transfers    h2d={st.h2d_bytes} B  "
                     f"d2h={st.d2h_bytes} B  "
                     f"resident={st.resident_bytes} B")
        roll = self.ledger.rollup(getattr(res.ctx.graph, "graph_key", "-"))
        lines.append(f"└─ graph ledger h2d={roll['h2d_bytes']} B  "
                     f"d2h={roll['d2h_bytes']} B  "
                     f"resident_live={roll['resident_live_bytes']} B  "
                     f"watermark={roll['resident_watermark_bytes']} B")
        return "\n".join(lines)

    # ------------------------------------------------------------ execution
    def _arm_budget(self, budget) -> Optional[Budget]:
        """Resolve a per-call ``budget=`` argument: ``_UNSET`` falls back to
        the engine-wide template, ``None`` disables governance, anything
        else is armed fresh (the template itself is never mutated)."""
        if budget is _UNSET:
            budget = self.options.budget
        return None if budget is None else budget.start()

    def _governance(self, stats: EngineStats, m, observe: bool) -> bool:
        """Fold one match's governance outcome (deadline flag, degradation
        ladder steps) into per-query stats and the engine counters; returns
        the possibly-downgraded ``observe`` (a deadline partial must not
        feed RIG-stats re-planning)."""
        degr = list(getattr(m, "degradations", ()) or ())
        for d in degr:
            if d not in stats.degradations:
                stats.degradations.append(d)
                self.counters["budget_degradations"] += 1
        if getattr(m, "deadline_exceeded", False):
            stats.deadline_exceeded = True
            stats.partial = True
            stats.status = "deadline_exceeded"
            self.counters["deadline_exceeded"] += 1
            return False
        return observe

    def _account_estimates(self, entry: _PlanEntry, **observed) -> None:
        """Reconcile one observed execution against the plan's committed
        estimates: per-quantity obs/est ratios land in the entry's
        :class:`EstimateRecord` (EXPLAIN ANALYZE), the registry's
        misestimation histograms, and the graph's :class:`Calibration`
        (which scales this graph's future fresh estimates)."""
        ratios = entry.est.record(**observed)
        for quantity, r in ratios.items():
            hist = self._h_misest.get(quantity)
            if hist is not None:
                hist.observe(r)
        if entry.cal is not None and ratios:
            entry.cal.record(ratios)

    def _harvest_resident(self, entry: _PlanEntry, m) -> None:
        """Move a match's device-resident RIG executor (if the resident
        enumerator ran) from the throwaway RIG onto the plan-cache entry,
        so the next execution of the same canonical query skips the
        re-upload.  A replaced executor is closed (ledger credited)."""
        rig = getattr(m, "rig", None)
        ex = getattr(rig, "resident", None) if rig is not None else None
        if ex is None or getattr(ex, "closed", False):
            return
        rig.resident = None
        old = entry.resident
        if old is not None and old is not ex:
            try:
                old.close()
            except Exception:
                pass
        entry.resident = ex

    def _observe_host(self, entry: _PlanEntry, stats: EngineStats,
                      m, observe: bool = True) -> None:
        """Record one host execution (one-shot, streamed, or batched) into
        per-query stats and — unless ``observe=False`` (e.g. an early-closed
        stream) — the plan entry's observed RIG statistics."""
        stats.backend = HOST
        stats.sim_passes = m.sim_passes
        stats.rig_nodes = m.rig_nodes
        stats.rig_edges = m.rig_edges
        stats.truncated = m.truncated
        stats.enum_method = m.enum_method
        stats.h2d_bytes = getattr(m, "h2d_bytes", 0)
        stats.d2h_bytes = getattr(m, "d2h_bytes", 0)
        self._harvest_resident(entry, m)
        uploads = getattr(m, "resident_uploads", 0)
        if uploads:
            self.counters["resident_uploads"] += uploads
            self._h_resident_bytes.observe(getattr(m, "resident_bytes", 0))
        dispatches = getattr(m, "resident_dispatches", 0)
        if dispatches:
            self.counters["resident_dispatches"] += dispatches
        routed = getattr(m, "small_frontier_host_routed", 0)
        if routed:
            self.counters["small_frontier_host_routed"] += routed
        # the resident footprint this query executed against: the fresh
        # upload when it paid one, else the warm executor it reused
        rb = getattr(m, "resident_bytes", 0)
        if not rb and stats.enum_method == "frontier-device-resident":
            rb = getattr(entry.resident, "nbytes", 0) or 0
        stats.resident_bytes = rb
        observe = self._governance(stats, m, observe)
        if observe:
            entry.rig.observe(rig_nodes=m.rig_nodes, rig_edges=m.rig_edges,
                              sim_passes=m.sim_passes,
                              matching_s=m.matching_s,
                              enumerate_s=m.enumerate_s, count=m.count)
            self._h_rig_nodes.observe(m.rig_nodes)
            self._h_rig_edges.observe(m.rig_edges)
            self._h_sim_passes.observe(m.sim_passes)
            self._h_results.observe(m.count)
            obs = dict(cardinality=float(m.count),
                       rig_nodes=float(m.rig_nodes),
                       rig_edges=float(m.rig_edges))
            if rb:
                obs["resident_bytes"] = float(rb)
            self._account_estimates(entry, **obs)
        self.counters["host_exec"] += 1

    def _arm_transfer_attribution(self, res: _Resident, entry: _PlanEntry,
                                  opts) -> None:
        """Pre-dispatch ledger/residency wiring for one host execution:
        hand the entry's cached device executor to ``prepare_rig`` (warm
        repeats skip the re-upload) and stamp the shared slab intersector
        with this graph's ledger key so its h2d/d2h charges attribute to
        the right graph."""
        opts.resident_executor = entry.resident
        if entry.plan.enum_method == "frontier-device":
            try:
                isect = device_intersector()
            except Exception:
                isect = None
            if isect is not None:
                isect.ledger_key = getattr(res.ctx.graph, "graph_key", "-")

    def _run_host(self, res: _Resident, qr: PatternQuery, entry: _PlanEntry,
                  stats: EngineStats, materialize: bool,
                  trace=NULL_TRACER, budget=None) -> MatchResult:
        """One governed host attempt; transient failures (injected faults,
        device losses surfacing as :class:`TransientError`) are retried
        here up to ``budget.max_attempts`` — recompute is the only recovery
        the RIG needs."""
        opts = entry.plan.gm_options(limit=self.options.limit,
                                     materialize=materialize,
                                     budget=budget, breaker=self.breaker)
        self._arm_transfer_attribution(res, entry, opts)
        attempts = 1 if budget is None else max(1, budget.max_attempts)
        for attempt in range(1, attempts + 1):
            stats.attempts = max(stats.attempts, attempt)
            try:
                m = res.gm().match(qr, options=opts, trace=trace)
                break
            except TransientError:
                if attempt >= attempts:
                    raise
                self.counters["transient_retries"] += 1
        self._observe_host(entry, stats, m)
        return m

    def _post_device(self, res: _Resident, qr: PatternQuery,
                     entry: _PlanEntry, stats: EngineStats, dev,
                     materialize: bool, trace=NULL_TRACER,
                     dispatch_s: float = 0.0, budget=None):
        """Common handling of one device result: stats, RIG-stats
        observation, and exact host fallback on capacity overflow.
        Returns ``(count, tuples)``.  ``dispatch_s`` is this query's share
        of the device dispatch, used only to synthesize trace spans (the
        vmapped matcher does not split its phases)."""
        stats.backend = DEVICE
        stats.enum_method = "jaxgm-frontier"    # device matcher's enumerator
        # exact_sim runs the device fixpoint loop, whose pass count is not
        # surfaced; 0 = "not tracked" (the truncated mode reports its budget)
        jgm = res.jgm()
        stats.sim_passes = 0 if jgm.exact_sim else jgm.n_passes
        stats.rig_nodes = int(np.sum(dev.fb_sizes))
        self.counters["device_exec"] += 1
        if dev.overflowed:
            if trace.enabled:
                trace.add("device_attempt", duration_s=dispatch_s,
                          overflowed=True, rig_nodes=stats.rig_nodes)
            # the host re-run records the real rig/enumerate/materialize
            # spans for this query
            m = self._run_host(res, qr, entry, stats, materialize,
                               trace=trace, budget=budget)
            stats.backend = DEVICE          # device ran; host completed
            stats.overflow_fallback = True
            self.counters["overflow_fallbacks"] += 1
            return m.count, m.tuples
        if trace.enabled:
            # the vmapped matcher fuses selection and enumeration into one
            # dispatch: the rig/materialize spans are structural markers,
            # the measured share lands on enumerate
            trace.add("rig", device=True, rig_nodes=stats.rig_nodes,
                      fb_sizes=[int(x) for x in dev.fb_sizes])
            trace.add("enumerate", duration_s=dispatch_s,
                      method="jaxgm-frontier", results=int(dev.count))
            trace.add("materialize",
                      materialized=dev.tuples is not None)
        entry.rig.observe(rig_nodes=stats.rig_nodes, rig_edges=0,
                          sim_passes=stats.sim_passes,
                          matching_s=0.0, enumerate_s=0.0, count=dev.count)
        self._h_rig_nodes.observe(stats.rig_nodes)
        self._h_results.observe(dev.count)
        # the vmapped matcher reports no RIG edge count — only reconcile
        # the quantities the device path actually observes
        self._account_estimates(entry, cardinality=float(dev.count),
                                rig_nodes=float(stats.rig_nodes))
        return dev.count, dev.tuples

    def _finish(self, stats: EngineStats, count: int,
                t_start: Optional[float] = None) -> None:
        """``t_start=None`` (batch members): per-query total is the sum of
        this query's own phases, not wall time since the batch began."""
        stats.count = count
        stats.total_s = (time.perf_counter() - t_start if t_start is not None
                         else stats.parse_s + stats.plan_s + stats.exec_s)
        self._h_parse.observe(stats.parse_s)
        self._h_plan.observe(stats.plan_s)
        self._h_exec.observe(stats.exec_s)
        self._h_total.observe(stats.total_s)
        self.counters["queries"] += 1

    @staticmethod
    def _exemplar_trace(stats: EngineStats, root: Optional[Span]):
        """Span tree for a tail-sampled exemplar: the real lifecycle tree
        when the query was profiled, otherwise one synthesized from the
        phase timings every query measures anyway — so slow/failed
        requests always carry *some* tree without ``profile=True``
        overhead on the rest of the traffic."""
        if root is not None:
            return root.to_dict()
        attrs = {"status": stats.status, "backend": stats.backend,
                 "synthesized": True}
        if stats.error_type:
            attrs["error"] = stats.error_type
        return {
            "name": "query", "duration_s": stats.total_s, "attrs": attrs,
            "children": [
                {"name": "parse", "duration_s": stats.parse_s},
                {"name": "plan", "duration_s": stats.plan_s},
                {"name": "exec", "duration_s": stats.exec_s,
                 "attrs": {"enum_method": stats.enum_method,
                           "degradations": list(stats.degradations)}},
            ],
        }

    def _record_event(self, stats: EngineStats, key: str, count: int,
                      trace_root: Optional[Span] = None) -> None:
        """Serving telemetry for one finished request (every execution
        mode funnels through here): one structured event in the flight
        recorder — with tail-based exemplar consideration — plus the
        phase observations for the windowed QPS/error-rate/quantile
        series.  A no-op when ``self.telemetry`` is off."""
        if not self.telemetry:
            return
        ev = QueryEvent.from_stats(stats, key=key, count=count)
        self.flight.record_query(
            ev, trace_provider=lambda: self._exemplar_trace(stats,
                                                            trace_root))
        self.windows.observe(
            {"parse": stats.parse_s, "plan": stats.plan_s,
             "exec": stats.exec_s, "total": stats.total_s},
            error=stats.status != "ok")

    def _ensure_labels(self, res: _Resident, stats: EngineStats,
                       trace=NULL_TRACER, budget=None) -> None:
        """Label-cache access with its lifecycle span (per-phase children
        on a cold build, ``cached=True`` on a hit).  A transient failure
        mid-build leaves the context cleanly cold (the build is
        transactional), so the retry here simply rebuilds."""
        attempts = 1 if budget is None else max(1, budget.max_attempts)
        with trace.span("labels") as lsp:
            for attempt in range(1, attempts + 1):
                stats.attempts = max(stats.attempts, attempt)
                try:
                    stats.label_cache_hit = res.ctx.ensure_labels()
                    break
                except TransientError:
                    if attempt >= attempts:
                        raise
                    self.counters["transient_retries"] += 1
            if trace.enabled:
                lsp.set(cached=stats.label_cache_hit)
                if not stats.label_cache_hit:
                    for name, dur in res.ctx.label_phases:
                        trace.add(name, duration_s=dur)
        if not stats.label_cache_hit:
            self.counters["label_builds"] += 1

    def execute(self, query: QueryLike, *,
                graph: Optional[DataGraph] = None,
                materialize: Optional[bool] = None,
                profile: bool = False, budget=_UNSET) -> EngineResult:
        """Plan and run one query; returns count/tuples + plan + stats.
        ``profile=True`` additionally records the full lifecycle span tree
        (parse → canonicalize → plan → labels → rig → enumerate →
        materialize) on ``result.trace``.

        ``budget`` (a :class:`repro.robust.Budget` template; defaults to
        ``options.budget``, ``None`` = ungoverned) bounds this execution:
        a deadline blown during enumeration returns the correctly-truncated
        prefix with ``stats.status == "deadline_exceeded"``; one blown in a
        non-enumerable phase (labels, RIG build) or a resource cap returns
        an empty result carrying the typed status — unless
        ``budget.raise_on_error``, in which case the typed
        :class:`~repro.robust.QueryError` propagates instead.
        """
        t_start = time.perf_counter()
        res = self._resident(graph)
        stats = EngineStats()
        trace = Tracer("query") if profile else NULL_TRACER
        b = self._arm_budget(budget)
        # parse/plan first: malformed text must not pay a cold label build
        qr, key, entry = self._prepare(query, res, stats, trace=trace)
        mat = self.options.materialize if materialize is None else materialize

        t0 = time.perf_counter()
        count, tuples = 0, None
        try:
            self._ensure_labels(res, stats, trace=trace, budget=b)
            t0 = time.perf_counter()
            if entry.plan.backend == DEVICE and res.jgm() is not None:
                try:
                    dev = self.breaker.call(
                        lambda: res.jgm().match(qr, materialize=mat),
                        budget=b)
                    count, tuples = self._post_device(
                        res, qr, entry, stats, dev, mat, trace=trace,
                        dispatch_s=time.perf_counter() - t0, budget=b)
                except (DeviceFailure, BreakerOpen):
                    # bottom of the ladder: recompute the query on the host
                    if "host" not in stats.degradations:
                        stats.degradations.append("host")
                        self.counters["budget_degradations"] += 1
                    m = self._run_host(res, qr, entry, stats, mat,
                                       trace=trace, budget=b)
                    count, tuples = m.count, m.tuples
            else:
                m = self._run_host(res, qr, entry, stats, mat, trace=trace,
                                   budget=b)
                count, tuples = m.count, m.tuples
            if (b is not None and b.raise_on_error
                    and stats.deadline_exceeded):
                raise DeadlineExceeded(
                    f"deadline exceeded after {count} result(s)")
        except QueryError as e:
            if b is not None and b.raise_on_error:
                raise
            stats.status = e.status
            stats.error_type = type(e).__name__
            stats.partial = True
            if isinstance(e, DeadlineExceeded):
                stats.deadline_exceeded = True
                self.counters["deadline_exceeded"] += 1
            tuples = (np.empty((0, qr.n), dtype=np.int64) if mat else None)
        stats.exec_s = time.perf_counter() - t0
        self._finish(stats, count, t_start)
        root = trace.finish()
        if root is not None:
            root.set(key=key, backend=stats.backend, count=count,
                     status=stats.status)
            if stats.error_type:
                root.set(error=stats.error_type)
        self._record_event(stats, key, count, trace_root=root)
        return EngineResult(count=count, tuples=tuples, query=qr,
                            plan=entry.plan, stats=stats, key=key,
                            trace=root)

    def execute_stream(self, query: QueryLike, *,
                       graph: Optional[DataGraph] = None,
                       chunk_size: Optional[int] = None,
                       limit=_UNSET, profile: bool = False,
                       budget=_UNSET) -> EngineStream:
        """Plan one query and enumerate its results *lazily*, in fixed-size
        chunks — the facade over :meth:`GM.match_stream` /
        :func:`repro.core.mjoin.iter_tuples`.

        Planning, label-cache handling and RIG construction run eagerly
        (node selection is existence checking, not enumeration); the MJoin
        enumeration itself advances only as the returned
        :class:`EngineStream` is consumed, so an early-stopping consumer
        never pays for the tail.  ``chunk_size=None`` uses the planner's
        choice (estimated — and, on repeat queries, observed — result
        cardinality); ``limit`` defaults to ``options.limit``.  Streaming
        honours the plan's enum_method, including the device-capable paths:
        ``frontier-device`` ships per-level slabs to the ``intersect``
        kernel, and ``frontier-device-resident`` enumerates against the
        device-resident RIG with lazily-consumed fixed-size result pages —
        chunks stay byte-identical to host order either way.  Only the
        vmapped whole-device matcher has no incremental mode (see ROADMAP).
        """
        res = self._resident(graph)
        stats = EngineStats(streamed=True)
        trace = Tracer("query") if profile else NULL_TRACER
        b = self._arm_budget(budget)
        # parse/plan first: malformed text must not pay a cold label build
        qr, key, entry = self._prepare(query, res, stats, trace=trace)
        self._ensure_labels(res, stats, trace=trace, budget=b)
        lim = self.options.limit if limit is _UNSET else limit
        chunk = chunk_size if chunk_size is not None else \
            entry.plan.chunk_size
        stats.chunk_size = chunk
        opts = entry.plan.gm_options(limit=lim, materialize=True,
                                     budget=b, breaker=self.breaker)
        self._arm_transfer_attribution(res, entry, opts)
        # setup (RIG build) is eager: a transient fault here is retried,
        # a typed QueryError propagates to the caller — there is no stream
        # to hand back yet.  Once iteration starts, a blown deadline ends
        # the stream after its partial prefix instead.
        attempts = 1 if b is None else max(1, b.max_attempts)
        for attempt in range(1, attempts + 1):
            stats.attempts = max(stats.attempts, attempt)
            try:
                m = res.gm().match_stream(qr, options=opts, chunk_size=chunk,
                                          trace=trace)
                break
            except TransientError:
                if attempt >= attempts:
                    raise
                self.counters["transient_retries"] += 1
        return EngineStream(self, entry, m, stats, qr, key,
                            tracer=trace if profile else None)

    def execute_many(self, queries: Sequence[RequestLike], *,
                     graph: Optional[DataGraph] = None,
                     profile: bool = False,
                     budget=_UNSET) -> List[EngineResult]:
        """Batched execution with cross-request sharing.

        Each item is query text, a :class:`PatternQuery`, or a
        ``(query, graph)`` pair (mixing resident graphs in one batch).
        Requests are grouped per resident graph; within a group the engine

        1. parses and plans *everything* first (a malformed query raises
           before any cold label build is paid),
        2. builds the graph's label structures once,
        3. answers requests with the same canonical key from one execution
           (``stats.shared_exec`` on the copies),
        4. runs device-planned queries through one vmapped dispatch, and
           host ``frontier-device`` queries through one fused scheduler
           that micro-batches their per-level ``(F, K, W)`` constraint
           gathers into a single ``(ΣF, K, W)`` slab per round; remaining
           host queries run sequentially.
        """
        items: List[Tuple[QueryLike, Optional[DataGraph]]] = []
        for item in queries:
            if isinstance(item, tuple):
                q, g = item
                items.append((q, g))
            else:
                items.append((item, graph))
        # group indices per resident graph (registration happens here, so
        # group order follows first appearance in the batch)
        groups: "OrderedDict[int, Tuple[_Resident, List[int]]]" = \
            OrderedDict()
        residents: List[_Resident] = []
        for i, (_, g) in enumerate(items):
            res = self._resident(g)
            groups.setdefault(id(res), (res, []))[1].append(i)
            residents.append(res)
        # parse/plan the whole batch first (admission control); each
        # request gets its own armed copy of the budget template — one slow
        # request blowing its deadline must not cancel its batch-mates
        prepared = []
        for i, (q, _) in enumerate(items):
            stats = EngineStats()
            trace = Tracer("query") if profile else NULL_TRACER
            qr, key, entry = self._prepare(q, residents[i], stats,
                                           trace=trace)
            prepared.append((qr, key, entry, stats, trace,
                             self._arm_budget(budget)))
        results: List[Optional[EngineResult]] = [None] * len(items)
        for res, idxs in groups.values():
            self._execute_group(res, idxs, prepared, results)
        return results    # type: ignore[return-value]

    def _finish_trace(self, tr, key: str, stats: EngineStats,
                      count: int) -> Optional[Span]:
        root = tr.finish()
        if root is not None:
            root.set(key=key, backend=stats.backend, count=count)
        return root

    def _execute_group(self, res: _Resident, idxs: List[int],
                       prepared, results) -> None:
        """Run one resident graph's share of an ``execute_many`` batch."""
        t0 = time.perf_counter()
        label_hit = res.ctx.ensure_labels()
        build_s = time.perf_counter() - t0
        if not label_hit:
            self.counters["label_builds"] += 1
        for j, i in enumerate(idxs):
            # resident for every query after the first in this group
            hit = label_hit or j > 0
            prepared[i][3].label_cache_hit = hit
            tr = prepared[i][4]
            if tr.enabled:
                sp = tr.add("labels", duration_s=0.0 if hit else build_s,
                            cached=hit)
                if not hit:
                    for name, dur in res.ctx.label_phases:
                        sp.children.append(Span(name, duration_s=dur))

        # dedup by canonical key: the first occurrence executes, the rest
        # are answered from its result (all batch members share the same
        # counting-mode options, so the result is identical by definition)
        rep_of: Dict[str, int] = {}
        dups: Dict[int, List[int]] = {}
        reps: List[int] = []
        for i in idxs:
            key = prepared[i][1]
            if key in rep_of:
                dups.setdefault(rep_of[key], []).append(i)
            else:
                rep_of[key] = i
                reps.append(i)

        lane = {i: prepared[i][2].plan.batch_group() for i in reps}
        device_idx = [i for i in reps if lane[i] == "device"]
        fd_idx = [i for i in reps if lane[i] == "frontier-device"]

        jgm = res.jgm() if device_idx else None
        if jgm is not None and len(device_idx) >= 2:
            t0 = time.perf_counter()
            try:
                batch = self.breaker.call(
                    lambda: jgm.match_batch(
                        [prepared[i][0] for i in device_idx]))
            except (DeviceFailure, BreakerOpen):
                # whole-batch device loss: every member degrades to the
                # host singles lane below (recompute, not repair)
                for i in device_idx:
                    stats = prepared[i][3]
                    if "host" not in stats.degradations:
                        stats.degradations.append("host")
                        self.counters["budget_degradations"] += 1
                batch = None
                device_idx = []
            if batch is not None:
                dt = time.perf_counter() - t0
                for i, dev in zip(device_idx, batch):
                    qr, key, entry, stats, tr, b = prepared[i]
                    t1 = time.perf_counter()
                    count, _ = self._post_device(
                        res, qr, entry, stats, dev,
                        materialize=False, trace=tr,
                        dispatch_s=dt / len(device_idx), budget=b)
                    # this query's share of the batched dispatch, plus any
                    # host overflow-fallback time it caused individually
                    stats.exec_s = (dt / len(device_idx)
                                    + time.perf_counter() - t1)
                    self._finish(stats, count)
                    results[i] = EngineResult(
                        count=count, tuples=None, query=qr, plan=entry.plan,
                        stats=stats, key=key,
                        trace=self._finish_trace(tr, key, stats, count))
                device_idx = []

        if len(fd_idx) >= 2:
            # micro-batched frontier lane: one fused (ΣF, K, W) slab per
            # scheduler round across all queries in the lane (the intersect
            # kernel when jax is present, fused numpy otherwise)
            t0 = time.perf_counter()
            gm_opts = [prepared[i][2].plan.gm_options(
                limit=self.options.limit, materialize=False,
                budget=prepared[i][5], breaker=self.breaker)
                for i in fd_idx]
            for o, i in zip(gm_opts, fd_idx):
                self._arm_transfer_attribution(res, prepared[i][2], o)
            ms, dispatches = res.gm().match_batch_frontier(
                [prepared[i][0] for i in fd_idx], gm_opts,
                intersector=device_intersector(),
                traces=[prepared[i][4] for i in fd_idx])
            dt = time.perf_counter() - t0
            self.counters["frontier_batches"] += 1
            self.counters["frontier_batch_dispatches"] += dispatches
            for i, m in zip(fd_idx, ms):
                qr, key, entry, stats, tr, b = prepared[i]
                self._observe_host(entry, stats, m)
                stats.exec_s = dt / len(fd_idx)   # share of the fused run
                self._finish(stats, m.count)
                if tr.enabled:
                    # the rig span was recorded live by prepare_rig; the
                    # enumeration ran inside the fused scheduler, so its
                    # span is this query's accounted share
                    tr.add("enumerate", duration_s=m.enumerate_s,
                           method=m.enum_method, results=m.count,
                           fused_batch=True, dispatches=dispatches)
                    tr.add("materialize", materialized=False)
                results[i] = EngineResult(
                    count=m.count, tuples=None, query=qr, plan=entry.plan,
                    stats=stats, key=key,
                    trace=self._finish_trace(tr, key, stats, m.count))
            fd_idx = []

        for i in reps:
            if results[i] is not None:
                continue
            qr, key, entry, stats, tr, b = prepared[i]
            t0 = time.perf_counter()
            try:
                if i in device_idx and jgm is not None:
                    # singleton device query: non-batched dispatch
                    try:
                        dev = self.breaker.call(
                            lambda: jgm.match(qr, materialize=False),
                            budget=b)
                        count, _ = self._post_device(
                            res, qr, entry, stats, dev, materialize=False,
                            trace=tr, dispatch_s=time.perf_counter() - t0,
                            budget=b)
                    except (DeviceFailure, BreakerOpen):
                        if "host" not in stats.degradations:
                            stats.degradations.append("host")
                            self.counters["budget_degradations"] += 1
                        m = self._run_host(res, qr, entry, stats,
                                           materialize=False, trace=tr,
                                           budget=b)
                        count = m.count
                else:
                    m = self._run_host(res, qr, entry, stats,
                                       materialize=False, trace=tr,
                                       budget=b)
                    count = m.count
            except QueryError as e:
                if b is not None and b.raise_on_error:
                    raise
                stats.status = e.status
                stats.error_type = type(e).__name__
                stats.partial = True
                if isinstance(e, DeadlineExceeded):
                    stats.deadline_exceeded = True
                    self.counters["deadline_exceeded"] += 1
                count = 0
            stats.exec_s = time.perf_counter() - t0
            self._finish(stats, count)
            results[i] = EngineResult(
                count=count, tuples=None, query=qr, plan=entry.plan,
                stats=stats, key=key,
                trace=self._finish_trace(tr, key, stats, count))

        # fan the representatives' answers out to their duplicates
        for rep, dlist in dups.items():
            src = results[rep]
            for i in dlist:
                qr, key, entry, stats, tr, b = prepared[i]
                stats.shared_exec = True
                stats.backend = src.stats.backend
                stats.sim_passes = src.stats.sim_passes
                stats.rig_nodes = src.stats.rig_nodes
                stats.rig_edges = src.stats.rig_edges
                stats.truncated = src.stats.truncated
                stats.enum_method = src.stats.enum_method
                # shared answers share the representative's outcome too
                stats.status = src.stats.status
                stats.error_type = src.stats.error_type
                stats.partial = src.stats.partial
                stats.deadline_exceeded = src.stats.deadline_exceeded
                stats.degradations = list(src.stats.degradations)
                stats.exec_s = 0.0
                self.counters["shared_exec"] += 1
                self._finish(stats, src.count)
                if tr.enabled:
                    # answered from the representative's execution — the
                    # lifecycle phases are structural markers on this copy
                    # (the labels span was already recorded with the group)
                    tr.add("rig", shared=True,
                           rig_nodes=src.stats.rig_nodes)
                    tr.add("enumerate", shared=True, results=src.count,
                           method=src.stats.enum_method)
                    tr.add("materialize", shared=True)
                results[i] = EngineResult(
                    count=src.count, tuples=None, query=qr, plan=entry.plan,
                    stats=stats, key=key,
                    trace=self._finish_trace(tr, key, stats, src.count))

        # serving telemetry: one event per batch member (duplicates too —
        # a served request is a served request), emitted after the whole
        # group resolved so shared answers carry their final stats
        for i in idxs:
            r = results[i]
            self._record_event(r.stats, r.key, r.count, trace_root=r.trace)

    # ------------------------------------------------------------- insight
    def metrics_snapshot(self, prefix: Optional[str] = None
                         ) -> Dict[str, object]:
        """Atomic point-in-time copy of every engine metric (counters,
        cache series, phase/size histograms) — see
        :meth:`repro.obs.metrics.MetricsRegistry.snapshot`.  The transfer
        ledger is published into the registry first, so ``ledger_*`` series
        reflect this instant."""
        self.ledger.publish(self.metrics)
        return self.metrics.snapshot(prefix)

    def metrics_text(self) -> str:
        """Prometheus-style text exposition of the engine registry."""
        self.ledger.publish(self.metrics)
        return prometheus_text(self.metrics)

    @staticmethod
    def render_trace(span: Span, **kw) -> str:
        """Render a ``result.trace`` span tree for the terminal."""
        return render_trace(span, **kw)

    def cache_info(self) -> Dict[str, int]:
        info = {
            "plan_entries": len(self._plan_cache),
            "plan_hits": self._plan_cache.hits,
            "plan_misses": self._plan_cache.misses,
            "plan_evictions": self._plan_cache.evictions,
            "resident_graphs": len(self._residents),
            "label_builds": self.counters["label_builds"],
        }
        errors = [r._jgm_error for r in self._residents.values()
                  if r._jgm_error]
        if errors:
            info["device_errors"] = "; ".join(errors)
        return info
