"""``Engine`` — the query-facing facade over the RIG/MJoin core.

Pipeline per query::

    text ──parse──▶ PatternQuery ──TR+canonicalize──▶ key
         ──plan-cache──▶ Plan (backend, sim algo, check method, ordering)
         ──label-cache──▶ resident reachability/adjacency/interval labels
         ──execute──▶ host GM  or  device JaxGM (batched in execute_many)

Cross-query state (everything the paper's per-query pipeline would
otherwise recompute):

* **label cache** — one :class:`GraphContext` per resident graph holds the
  reachability labeling, packed adjacency and DFS interval labels; built
  once, shared by every subsequent query on that graph;
* **plan / RIG-stats cache** — an LRU keyed by the canonical form of the
  transitively-reduced query; repeat queries skip planning and are
  re-planned against *observed* RIG sizes (tiny RIG -> host enumeration).

The RIG itself remains runtime state, rebuilt per query — the paper's
defining property; the engine only hoists the graph-side indexes and the
per-query *decisions* out of the hot path.
"""

from __future__ import annotations

import itertools
import time
import warnings
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from ..core.graph import DataGraph
from ..core.matcher import GM, MatchResult
from ..core.mjoin import DEFAULT_LIMIT
from ..core.query import PatternQuery
from .cache import GraphContext, LRUCache
from .canonical import canonical_key
from .language import Vocab, fmt, parse
from .planner import DEVICE, HOST, DeviceCaps, Plan, Planner
from .stats import RigStats

__all__ = ["EngineOptions", "EngineStats", "EngineResult", "Engine"]

QueryLike = Union[str, PatternQuery]

_TPU_AVAILABLE: Optional[bool] = None


def _tpu_available() -> bool:
    global _TPU_AVAILABLE
    if _TPU_AVAILABLE is None:
        try:
            import jax
            _TPU_AVAILABLE = jax.default_backend() == "tpu"
        except Exception:
            _TPU_AVAILABLE = False
    return _TPU_AVAILABLE


@dataclass
class EngineOptions:
    # device matcher caps (see DeviceCaps)
    max_q: int = 8
    max_e: int = 16
    capacity: int = 4096
    device_min_nodes: int = 512
    device_impl: str = "auto"          # jaxgm kernel impl: auto|reference|...
    exact_sim: bool = True             # device sim to fixpoint (host-equal)
    # engine knobs
    plan_cache_size: int = 256
    max_resident_graphs: int = 8
    force_backend: Optional[str] = None   # "host" | "device" | None
    # route the frontier enumerator's AND+popcount through the Pallas
    # intersect kernel: None = auto (only on real TPU backends — the
    # interpreter fallback is orders of magnitude slower than numpy)
    frontier_device: Optional[bool] = None
    limit: Optional[int] = DEFAULT_LIMIT
    materialize: bool = True

    def caps(self) -> DeviceCaps:
        fd = self.frontier_device
        if fd is None:
            fd = _tpu_available()
        return DeviceCaps(max_q=self.max_q, max_e=self.max_e,
                          capacity=self.capacity,
                          min_graph_nodes=self.device_min_nodes,
                          frontier_device=fd)


@dataclass
class EngineStats:
    """Per-query execution record.

    ``sim_passes`` is the measured pass count on the host backend, the
    fixed pass budget on the truncated device path, and 0 (not tracked) on
    the exact-sim device path.
    """

    backend: str = HOST
    count: int = 0
    parse_s: float = 0.0
    plan_s: float = 0.0
    exec_s: float = 0.0
    total_s: float = 0.0
    plan_cache_hit: bool = False
    label_cache_hit: bool = False
    overflow_fallback: bool = False
    sim_passes: int = 0
    rig_nodes: int = 0
    rig_edges: int = 0
    truncated: bool = False
    enum_method: str = "backtrack"   # strategy that ran (device: jaxgm's)


@dataclass
class EngineResult:
    count: int
    tuples: Optional[np.ndarray]
    query: PatternQuery            # the executed (transitively-reduced) query
    plan: Plan
    stats: EngineStats
    key: str


@dataclass
class _PlanEntry:
    plan: Plan
    rig: RigStats = field(default_factory=RigStats)


_RESIDENT_EPOCH = itertools.count()


class _Resident:
    """A registered graph: context + lazily-created matchers.

    ``epoch`` is a process-unique token used in plan-cache keys instead of
    ``id(graph)`` — a new graph allocated at a recycled address must not
    inherit an evicted graph's plans or RIG statistics.
    """

    def __init__(self, graph: DataGraph, options: EngineOptions,
                 label_names=None):
        self.ctx = GraphContext(graph)
        self.epoch = next(_RESIDENT_EPOCH)
        self.options = options
        self.vocab = Vocab.for_graph(graph, names=label_names)
        self.planner = Planner(self.ctx.stats, caps=options.caps(),
                               force_backend=options.force_backend)
        self._gm: Optional[GM] = None
        self._jgm = None
        self._jgm_error: Optional[str] = None

    def gm(self) -> GM:
        if self._gm is None:
            self.ctx.ensure_labels()
            self._gm = GM(self.ctx.graph)
            self._gm.oracle = self.ctx.oracle     # share the label cache
            self._gm.intervals = self.ctx.intervals   # §5.5 interval path
        return self._gm

    def jgm(self):
        """Device matcher, or ``None`` if the device path is unavailable
        (then the caller re-routes to the host; the error is kept on
        ``_jgm_error`` and surfaced through ``Engine.cache_info``)."""
        if self._jgm is None and self._jgm_error is None:
            try:
                from ..jaxgm import JaxGM
                o = self.options
                self._jgm = JaxGM(self.ctx.graph, max_q=o.max_q,
                                  max_e=o.max_e, capacity=o.capacity,
                                  exact_sim=o.exact_sim, impl=o.device_impl,
                                  use_transitive_reduction=False)
            except Exception as e:
                self._jgm_error = f"{type(e).__name__}: {e}"
                warnings.warn(
                    f"device matcher unavailable, queries re-route to the "
                    f"host backend: {self._jgm_error}", RuntimeWarning,
                    stacklevel=2)
        return self._jgm


class Engine:
    """Query engine bound to one (or a few) resident data graphs."""

    def __init__(self, graph: Optional[DataGraph] = None, *,
                 options: Optional[EngineOptions] = None,
                 label_names=None):
        self.options = options or EngineOptions()
        self._residents: "OrderedDict[int, _Resident]" = OrderedDict()
        self._plan_cache = LRUCache(self.options.plan_cache_size)
        # memo: reduced-query structure -> canonical key, so the exact
        # (up to n! permutations) canonicalization runs once per distinct
        # query structure, not on every plan-cache hit
        self._canon_memo = LRUCache(4 * self.options.plan_cache_size)
        self.default_graph = graph
        self.counters: Dict[str, int] = {
            "queries": 0, "host_exec": 0, "device_exec": 0,
            "overflow_fallbacks": 0, "label_builds": 0,
        }
        if graph is not None:
            self.register(graph, label_names=label_names)

    # ------------------------------------------------------------ residency
    def register(self, graph: DataGraph, label_names=None) -> GraphContext:
        """Make ``graph`` resident (idempotent).  Returns its context."""
        key = id(graph)
        if key not in self._residents:
            self._residents[key] = _Resident(graph, self.options,
                                             label_names=label_names)
            while len(self._residents) > self.options.max_resident_graphs:
                _, dead = self._residents.popitem(last=False)
                # epochs are never reused, so the evicted graph's plan
                # entries are unreachable — free their cache slots
                self._plan_cache.drop_where(lambda k: k[0] == dead.epoch)
        elif label_names is not None:
            self._residents[key].vocab = Vocab.for_graph(graph,
                                                         names=label_names)
        self._residents.move_to_end(key)
        if self.default_graph is None:
            self.default_graph = graph
        return self._residents[key].ctx

    def _resident(self, graph: Optional[DataGraph]) -> _Resident:
        g = graph if graph is not None else self.default_graph
        if g is None:
            raise ValueError("no resident graph: pass graph= or construct "
                             "Engine(graph)")
        self.register(g)
        return self._residents[id(g)]

    def context(self, graph: Optional[DataGraph] = None) -> GraphContext:
        return self._resident(graph).ctx

    # ------------------------------------------------------------- language
    @property
    def vocab(self) -> Vocab:
        """The default graph's label vocabulary (each resident graph keeps
        its own; ``parse``/``format`` accept ``graph=`` to select it)."""
        if self.default_graph is not None:
            return self._resident(None).vocab
        return Vocab()

    def parse(self, text: str, name: str = "",
              graph: Optional[DataGraph] = None) -> PatternQuery:
        vocab = (self._resident(graph).vocab
                 if (graph is not None or self.default_graph is not None)
                 else Vocab())
        return parse(text, vocab=vocab, name=name)

    def format(self, q: PatternQuery,
               graph: Optional[DataGraph] = None) -> str:
        vocab = (self._resident(graph).vocab
                 if (graph is not None or self.default_graph is not None)
                 else Vocab())
        return fmt(q, vocab=vocab)

    # ------------------------------------------------------------- planning
    def _prepare(self, query: QueryLike, res: _Resident,
                 stats: EngineStats):
        """parse (if text) + TR + canonical key + plan-cache lookup."""
        t0 = time.perf_counter()
        q = (parse(query, vocab=res.vocab) if isinstance(query, str)
             else query)
        stats.parse_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        qr = q.transitive_reduction()
        raw = (tuple(qr.labels),
               tuple((e.src, e.dst, e.kind) for e in qr.edges))
        ckey = self._canon_memo.get(raw)
        if ckey is None:
            ckey = canonical_key(qr, reduce=False)
            self._canon_memo.put(raw, ckey)
        key = (res.epoch, ckey)
        entry: Optional[_PlanEntry] = self._plan_cache.get(key)
        if entry is None:
            entry = _PlanEntry(plan=res.planner.plan(qr))
            self._plan_cache.put(key, entry)
        else:
            stats.plan_cache_hit = True
            entry.plan = res.planner.refine(entry.plan, qr, entry.rig)
        stats.plan_s = time.perf_counter() - t0
        return qr, key[1], entry

    def explain(self, query: QueryLike,
                graph: Optional[DataGraph] = None) -> str:
        """The plan the engine would run, as text (does not execute)."""
        res = self._resident(graph)
        stats = EngineStats()
        qr, key, entry = self._prepare(query, res, stats)
        cached = "cached" if stats.plan_cache_hit else "fresh"
        return f"{key} -> {entry.plan.explain()} ({cached})"

    # ------------------------------------------------------------ execution
    def _run_host(self, res: _Resident, qr: PatternQuery, entry: _PlanEntry,
                  stats: EngineStats, materialize: bool) -> MatchResult:
        opts = entry.plan.gm_options(limit=self.options.limit,
                                     materialize=materialize)
        m = res.gm().match(qr, options=opts)
        stats.backend = HOST
        stats.sim_passes = m.sim_passes
        stats.rig_nodes = m.rig_nodes
        stats.rig_edges = m.rig_edges
        stats.truncated = m.truncated
        stats.enum_method = m.enum_method
        entry.rig.observe(rig_nodes=m.rig_nodes, rig_edges=m.rig_edges,
                          sim_passes=m.sim_passes, matching_s=m.matching_s,
                          enumerate_s=m.enumerate_s, count=m.count)
        self.counters["host_exec"] += 1
        return m

    def _post_device(self, res: _Resident, qr: PatternQuery,
                     entry: _PlanEntry, stats: EngineStats, dev,
                     materialize: bool):
        """Common handling of one device result: stats, RIG-stats
        observation, and exact host fallback on capacity overflow.
        Returns ``(count, tuples)``."""
        stats.backend = DEVICE
        stats.enum_method = "jaxgm-frontier"    # device matcher's enumerator
        # exact_sim runs the device fixpoint loop, whose pass count is not
        # surfaced; 0 = "not tracked" (the truncated mode reports its budget)
        jgm = res.jgm()
        stats.sim_passes = 0 if jgm.exact_sim else jgm.n_passes
        stats.rig_nodes = int(np.sum(dev.fb_sizes))
        self.counters["device_exec"] += 1
        if dev.overflowed:
            m = self._run_host(res, qr, entry, stats, materialize)
            stats.backend = DEVICE          # device ran; host completed
            stats.overflow_fallback = True
            self.counters["overflow_fallbacks"] += 1
            return m.count, m.tuples
        entry.rig.observe(rig_nodes=stats.rig_nodes, rig_edges=0,
                          sim_passes=stats.sim_passes,
                          matching_s=0.0, enumerate_s=0.0, count=dev.count)
        return dev.count, dev.tuples

    def _finish(self, stats: EngineStats, count: int,
                t_start: Optional[float] = None) -> None:
        """``t_start=None`` (batch members): per-query total is the sum of
        this query's own phases, not wall time since the batch began."""
        stats.count = count
        stats.total_s = (time.perf_counter() - t_start if t_start is not None
                         else stats.parse_s + stats.plan_s + stats.exec_s)
        self.counters["queries"] += 1

    def execute(self, query: QueryLike, *,
                graph: Optional[DataGraph] = None,
                materialize: Optional[bool] = None) -> EngineResult:
        """Plan and run one query; returns count/tuples + plan + stats."""
        t_start = time.perf_counter()
        res = self._resident(graph)
        stats = EngineStats()
        # parse/plan first: malformed text must not pay a cold label build
        qr, key, entry = self._prepare(query, res, stats)
        stats.label_cache_hit = res.ctx.ensure_labels()
        if not stats.label_cache_hit:
            self.counters["label_builds"] += 1
        mat = self.options.materialize if materialize is None else materialize

        t0 = time.perf_counter()
        if entry.plan.backend == DEVICE and res.jgm() is not None:
            dev = res.jgm().match(qr, materialize=mat)
            count, tuples = self._post_device(res, qr, entry, stats, dev, mat)
        else:
            m = self._run_host(res, qr, entry, stats, mat)
            count, tuples = m.count, m.tuples
        stats.exec_s = time.perf_counter() - t0
        self._finish(stats, count, t_start)
        return EngineResult(count=count, tuples=tuples, query=qr,
                            plan=entry.plan, stats=stats, key=key)

    def execute_many(self, queries: Sequence[QueryLike], *,
                     graph: Optional[DataGraph] = None
                     ) -> List[EngineResult]:
        """Batched execution: device-planned queries go through the vmapped
        device matcher in one dispatch; the rest run on the host."""
        res = self._resident(graph)
        # parse/plan the whole batch first: a malformed query raises before
        # any cold label build is paid
        prepared = []
        for query in queries:
            stats = EngineStats()
            qr, key, entry = self._prepare(query, res, stats)
            prepared.append((qr, key, entry, stats))
        label_hit = res.ctx.ensure_labels()
        if not label_hit:
            self.counters["label_builds"] += 1
        for i, (_, _, _, stats) in enumerate(prepared):
            # resident for every query after the first in this batch
            stats.label_cache_hit = label_hit or i > 0

        device_idx = [i for i, (_, _, e, _) in enumerate(prepared)
                      if e.plan.backend == DEVICE]
        results: List[Optional[EngineResult]] = [None] * len(prepared)

        jgm = res.jgm() if len(device_idx) else None
        if jgm is not None and len(device_idx) >= 2:
            t0 = time.perf_counter()
            batch = jgm.match_batch([prepared[i][0] for i in device_idx])
            dt = time.perf_counter() - t0
            for i, dev in zip(device_idx, batch):
                qr, key, entry, stats = prepared[i]
                t1 = time.perf_counter()
                count, _ = self._post_device(res, qr, entry, stats, dev,
                                             materialize=False)
                # this query's share of the batched dispatch, plus any host
                # overflow-fallback time it caused individually
                stats.exec_s = (dt / len(device_idx)
                                + time.perf_counter() - t1)
                self._finish(stats, count)
                results[i] = EngineResult(count=count, tuples=None, query=qr,
                                          plan=entry.plan, stats=stats,
                                          key=key)
            device_idx = []

        for i, (qr, key, entry, stats) in enumerate(prepared):
            if results[i] is not None:
                continue
            t0 = time.perf_counter()
            if i in device_idx and jgm is not None:
                # singleton device query: non-batched dispatch
                dev = jgm.match(qr, materialize=False)
                count, _ = self._post_device(res, qr, entry, stats, dev,
                                             materialize=False)
            else:
                m = self._run_host(res, qr, entry, stats, materialize=False)
                count = m.count
            stats.exec_s = time.perf_counter() - t0
            self._finish(stats, count)
            results[i] = EngineResult(count=count, tuples=None, query=qr,
                                      plan=entry.plan, stats=stats, key=key)
        return results    # type: ignore[return-value]

    # ------------------------------------------------------------- insight
    def cache_info(self) -> Dict[str, int]:
        info = {
            "plan_entries": len(self._plan_cache),
            "plan_hits": self._plan_cache.hits,
            "plan_misses": self._plan_cache.misses,
            "plan_evictions": self._plan_cache.evictions,
            "resident_graphs": len(self._residents),
            "label_builds": self.counters["label_builds"],
        }
        errors = [r._jgm_error for r in self._residents.values()
                  if r._jgm_error]
        if errors:
            info["device_errors"] = "; ".join(errors)
        return info
