"""Graph- and query-level statistics feeding the planner's cost model.

``GraphStats`` is collected once per resident graph (O(n + m), cached in
the :class:`~repro.engine.cache.GraphContext`); ``RigStats`` is observed
per executed query and stored in the plan cache so repeat queries can be
re-planned against measured RIG sizes instead of estimates.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

import numpy as np

from ..core.graph import DataGraph
from ..core.query import CHILD, PatternQuery

__all__ = ["GraphStats", "RigStats"]


@dataclass
class GraphStats:
    n: int
    n_edges: int
    num_labels: int
    avg_degree: float
    max_out_degree: int
    label_counts: Dict[int, int]

    @classmethod
    def collect(cls, graph: DataGraph) -> "GraphStats":
        odeg = graph.out_degree()
        return cls(
            n=graph.n,
            n_edges=graph.n_edges,
            num_labels=graph.num_labels,
            avg_degree=graph.avg_degree,
            max_out_degree=int(odeg.max()) if graph.n else 0,
            label_counts={l: len(ix) for l, ix in graph.inverted.items()},
        )

    # ------------------------------------------------------------ estimates
    def match_set_size(self, label: int) -> int:
        """|ms(q)| = |I_label| exactly (the inverted lists are exact)."""
        return self.label_counts.get(int(label), 0)

    def reach_set_size(self) -> float:
        """Crude estimate of the average ≺-set size: a branching process
        ``d + d² + d³`` capped at n.  Good enough to rank child vs
        descendant edge costs; refined by observed RigStats on repeats."""
        d = self.avg_degree
        return float(min(self.n, d + d * d + d * d * d))

    def edge_fanout(self, kind: int) -> float:
        return self.avg_degree if kind == CHILD else self.reach_set_size()

    def estimate_cost(self, q: PatternQuery) -> float:
        """Unitless cost of matching ``q``: simulation work (sum of match
        sets, once per edge per pass) plus an expansion/enumeration term
        (per-edge occurrence estimates)."""
        ms = [self.match_set_size(l) for l in q.labels]
        sim = float(sum(ms)) * max(q.m, 1)
        expand = 0.0
        for e in q.edges:
            sel = ms[e.dst] / max(self.n, 1)          # label selectivity
            expand += ms[e.src] * self.edge_fanout(e.kind) * sel
        return sim + expand

    def estimate_cardinality(self, q: PatternQuery) -> float:
        """Occurrence-count estimate under edge independence."""
        card = 1.0
        for l in q.labels:
            card *= max(self.match_set_size(l), 0)
        for e in q.edges:
            p = self.edge_fanout(e.kind) / max(self.n, 1)
            card *= min(p, 1.0)
        return card


@dataclass
class RigStats:
    """Observed runtime-index-graph statistics for one executed query."""

    rig_nodes: int = 0
    rig_edges: int = 0
    sim_passes: int = 0
    matching_s: float = 0.0
    enumerate_s: float = 0.0
    count: int = 0
    observations: int = 0

    def observe(self, *, rig_nodes: int, rig_edges: int, sim_passes: int,
                matching_s: float, enumerate_s: float, count: int) -> None:
        self.rig_nodes = rig_nodes
        self.rig_edges = rig_edges
        self.sim_passes = sim_passes
        self.matching_s = matching_s
        self.enumerate_s = enumerate_s
        self.count = count
        self.observations += 1
