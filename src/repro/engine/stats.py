"""Graph- and query-level statistics feeding the planner's cost model.

``GraphStats`` is collected once per resident graph (O(n + m), cached in
the :class:`~repro.engine.cache.GraphContext`); ``RigStats`` is observed
per executed query and stored in the plan cache so repeat queries can be
re-planned against measured RIG sizes instead of estimates.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.graph import DataGraph
from ..core.query import CHILD, PatternQuery

__all__ = ["GraphStats", "RigStats", "EstimateRecord", "Calibration",
           "ESTIMATE_QUANTITIES"]


@dataclass
class GraphStats:
    n: int
    n_edges: int
    num_labels: int
    avg_degree: float
    max_out_degree: int
    label_counts: Dict[int, int]

    @classmethod
    def collect(cls, graph: DataGraph) -> "GraphStats":
        odeg = graph.out_degree()
        return cls(
            n=graph.n,
            n_edges=graph.n_edges,
            num_labels=graph.num_labels,
            avg_degree=graph.avg_degree,
            max_out_degree=int(odeg.max()) if graph.n else 0,
            label_counts={l: len(ix) for l, ix in graph.inverted.items()},
        )

    # ------------------------------------------------------------ estimates
    def match_set_size(self, label: int) -> int:
        """|ms(q)| = |I_label| exactly (the inverted lists are exact)."""
        return self.label_counts.get(int(label), 0)

    def reach_set_size(self) -> float:
        """Crude estimate of the average ≺-set size: a branching process
        ``d + d² + d³`` capped at n.  Good enough to rank child vs
        descendant edge costs; refined by observed RigStats on repeats."""
        d = self.avg_degree
        return float(min(self.n, d + d * d + d * d * d))

    def edge_fanout(self, kind: int) -> float:
        return self.avg_degree if kind == CHILD else self.reach_set_size()

    def estimate_cost(self, q: PatternQuery) -> float:
        """Unitless cost of matching ``q``: simulation work (sum of match
        sets, once per edge per pass) plus an expansion/enumeration term
        (per-edge occurrence estimates)."""
        ms = [self.match_set_size(l) for l in q.labels]
        sim = float(sum(ms)) * max(q.m, 1)
        expand = 0.0
        for e in q.edges:
            sel = ms[e.dst] / max(self.n, 1)          # label selectivity
            expand += ms[e.src] * self.edge_fanout(e.kind) * sel
        return sim + expand

    def estimate_cardinality(self, q: PatternQuery) -> float:
        """Occurrence-count estimate under edge independence."""
        card = 1.0
        for l in q.labels:
            card *= max(self.match_set_size(l), 0)
        for e in q.edges:
            p = self.edge_fanout(e.kind) / max(self.n, 1)
            card *= min(p, 1.0)
        return card

    def estimate_rig_nodes(self, q: PatternQuery) -> float:
        """Pre-simulation RIG node bound: Σ|ms(q_i)| (double simulation can
        only shrink the candidate sets, so this is an upper estimate)."""
        return float(sum(self.match_set_size(l) for l in q.labels))

    def estimate_rig_edges(self, q: PatternQuery) -> float:
        """Per query edge: each src candidate contributes its expected
        label-selective fanout into cos(dst), capped by |ms(dst)|."""
        ms = [self.match_set_size(l) for l in q.labels]
        total = 0.0
        for e in q.edges:
            sel = ms[e.dst] / max(self.n, 1)
            total += ms[e.src] * min(self.edge_fanout(e.kind) * sel,
                                     float(ms[e.dst]))
        return total


@dataclass
class RigStats:
    """Observed runtime-index-graph statistics for one executed query."""

    rig_nodes: int = 0
    rig_edges: int = 0
    sim_passes: int = 0
    matching_s: float = 0.0
    enumerate_s: float = 0.0
    count: int = 0
    observations: int = 0

    def observe(self, *, rig_nodes: int, rig_edges: int, sim_passes: int,
                matching_s: float, enumerate_s: float, count: int) -> None:
        self.rig_nodes = rig_nodes
        self.rig_edges = rig_edges
        self.sim_passes = sim_passes
        self.matching_s = matching_s
        self.enumerate_s = enumerate_s
        self.count = count
        self.observations += 1


#: Quantities the planner commits estimates for and execution reconciles.
ESTIMATE_QUANTITIES = ("cardinality", "rig_nodes", "rig_edges",
                       "resident_bytes")


@dataclass
class EstimateRecord:
    """Planner estimate-vs-observed accountability for one cached plan.

    Created with the plan's committed estimates; every execution records
    the observed values and yields per-quantity misestimation ratios
    (observed / estimated) for the registry histograms and the per-graph
    :class:`Calibration`.  Last-value semantics on ``obs`` (mirroring
    :class:`RigStats`), cumulative ``observations``.
    """

    est: Dict[str, float] = field(default_factory=dict)
    obs: Dict[str, float] = field(default_factory=dict)
    observations: int = 0

    def record(self, **observed: float) -> Dict[str, float]:
        """Record observed values; returns ``{quantity: obs/est}`` for
        every quantity with a positive committed estimate (a ratio of 1.0
        means the planner was exactly right)."""
        ratios: Dict[str, float] = {}
        for quantity, value in observed.items():
            if value is None:
                continue
            self.obs[quantity] = float(value)
            est = self.est.get(quantity, 0.0)
            if est > 0:
                ratios[quantity] = float(value) / est
        self.observations += 1
        return ratios

    def ratio(self, quantity: str) -> Optional[float]:
        est = self.est.get(quantity, 0.0)
        if est <= 0 or quantity not in self.obs:
            return None
        return self.obs[quantity] / est

    def rows(self) -> List[Tuple[str, float, Optional[float],
                                 Optional[float]]]:
        """``(quantity, estimate, observed, ratio)`` for rendering."""
        out = []
        for quantity in ESTIMATE_QUANTITIES:
            if quantity not in self.est and quantity not in self.obs:
                continue
            out.append((quantity, self.est.get(quantity, 0.0),
                        self.obs.get(quantity), self.ratio(quantity)))
        return out


class Calibration:
    """Per-graph misestimation medians (bounded ratio windows).

    The planner multiplies fresh estimates by the median observed
    ``obs/est`` ratio of the same quantity on the same graph, so warm
    traffic self-corrects systematic bias (e.g. the independence
    assumption under- or over-counting on this graph's label structure)
    without per-query state.  Medians are clamped to ``[0.01, 100]`` so a
    single pathological ratio cannot poison future plans.
    """

    WINDOW = 64
    CLAMP = (0.01, 100.0)

    def __init__(self) -> None:
        self._ratios: Dict[str, List[float]] = {}

    def record(self, ratios: Dict[str, float]) -> None:
        for quantity, r in ratios.items():
            win = self._ratios.setdefault(quantity, [])
            win.append(float(r))
            if len(win) > self.WINDOW:
                del win[:len(win) - self.WINDOW]

    def median(self, quantity: str) -> Optional[float]:
        win = self._ratios.get(quantity)
        if not win:
            return None
        lo, hi = self.CLAMP
        return float(min(max(np.median(win), lo), hi))

    def observations(self, quantity: str) -> int:
        return len(self._ratios.get(quantity, ()))
