"""Query canonicalization for cross-query caching (planner / RIG stats).

Two isomorphic hybrid patterns (same labels and edge kinds under a node
renaming) have identical optimal plans, so plan-cache keys are computed on a
*canonical form*: the transitive reduction (§4) with nodes renumbered into a
deterministic order.

For small queries (n <= 6, i.e. <= 720 permutations) the canonical order is
exact — minimum over all node permutations of the (labels, edges) encoding.
Larger patterns fall back to iterated color refinement (1-WL) with lexicographic
tie-breaking; that is deterministic (same query text -> same key, so the
cache stays correct) but may assign two isomorphic queries different keys,
costing only a duplicate cache entry.

The same caveat applies to *cyclic* patterns under ``reduce=True``: the
transitive reduction of a cyclic graph is not unique, so two isomorphic
cyclic queries may reduce to non-isomorphic forms and get different keys.
For acyclic patterns (the common case) the reduction is unique and the
key is a true isomorphism invariant — asserted property-based in
``tests/engine/test_planner.py``.
"""

from __future__ import annotations

from itertools import permutations
from typing import List, Tuple

from ..core.query import PatternQuery, QueryEdge

__all__ = ["canonical_form", "canonical_key", "EXACT_MAX_NODES"]

EXACT_MAX_NODES = 6


def _encode(labels: List[int],
            edges: List[Tuple[int, int, int]]) -> Tuple:
    return (tuple(labels), tuple(sorted(edges)))


def _apply(q: PatternQuery, perm: Tuple[int, ...]) -> Tuple:
    """perm[old_index] = new_index."""
    labels = [0] * q.n
    for old, new in enumerate(perm):
        labels[new] = q.labels[old]
    edges = [(perm[e.src], perm[e.dst], e.kind) for e in q.edges]
    return _encode(labels, edges)


def _refined_order(q: PatternQuery) -> Tuple[int, ...]:
    """Deterministic node order from 1-WL color refinement; ties broken by
    original index (stable, text-deterministic)."""
    colors: List[Tuple] = [
        (q.labels[v],
         tuple(sorted((e.kind, q.labels[e.dst]) for e in q.out_edges(v))),
         tuple(sorted((e.kind, q.labels[e.src]) for e in q.in_edges(v))))
        for v in range(q.n)
    ]
    for _ in range(q.n):
        nxt = [
            (colors[v],
             tuple(sorted((e.kind, colors[e.dst]) for e in q.out_edges(v))),
             tuple(sorted((e.kind, colors[e.src]) for e in q.in_edges(v))))
            for v in range(q.n)
        ]
        if len(set(nxt)) == len(set(colors)):
            break
        colors = nxt
    order = sorted(range(q.n), key=lambda v: (colors[v], v))
    perm = [0] * q.n
    for new, old in enumerate(order):
        perm[old] = new
    return tuple(perm)


def canonical_form(q: PatternQuery,
                   reduce: bool = True) -> Tuple[PatternQuery, Tuple[int, ...]]:
    """Return ``(canonical_query, perm)`` with ``perm[old] = new``.

    ``reduce=True`` first applies the transitive reduction, so queries that
    differ only by redundant descendant edges share a canonical form.
    """
    if reduce:
        q = q.transitive_reduction()
    if q.n <= EXACT_MAX_NODES:
        best = None
        best_perm: Tuple[int, ...] = tuple(range(q.n))
        for perm in permutations(range(q.n)):
            enc = _apply(q, perm)
            if best is None or enc < best:
                best, best_perm = enc, perm
        perm = best_perm
    else:
        perm = _refined_order(q)
    labels_enc, edges_enc = _apply(q, perm)
    cq = PatternQuery(labels=list(labels_enc),
                      edges=[QueryEdge(*e) for e in edges_enc])
    return cq, perm


def canonical_key(q: PatternQuery, reduce: bool = True) -> str:
    """Stable string key for plan / RIG-stats caches."""
    cq, _ = canonical_form(q, reduce=reduce)
    labels = ",".join(map(str, cq.labels))
    edges = " ".join(f"{e.src}{'/' if e.kind == 0 else '//'}{e.dst}"
                     for e in cq.edges)
    return f"n{cq.n}|l[{labels}]|e[{edges}]"
