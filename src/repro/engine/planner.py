"""Query planner: canonical form -> backend / algorithm / check-method.

The matcher core exposes several interchangeable execution choices that the
paper ablates (Figs. 8-11); the planner picks them per query from
:class:`~repro.engine.stats.GraphStats` instead of hard-coding one variant:

* **backend** — host ``GM`` (``repro.core``) vs device ``JaxGM``
  (``repro.jaxgm``).  The device pipeline pays a dispatch/compile overhead
  and works on padded tensors, so it wins on large resident graphs and
  batch traffic; small graphs and over-wide queries stay on the host.
* **simulation algorithm** — ``bas`` for trivially small patterns (the
  Dag+Δ bookkeeping costs more than it saves), ``dagmap`` otherwise
  (Fig. 8(b): change-flag skipping is the best variant).
* **check method** — ``bitbat`` (batched bitset ops) unless the graph is so
  large and the match sets so sparse that per-candidate ``bititer`` touches
  fewer words.
* **ordering** — ``jo`` (the paper's default search ordering).
* **enum method** — ``backtrack`` (one tuple at a time, constant space) vs
  ``frontier`` (batched level-synchronous enumeration) vs
  ``frontier-device`` (frontier with the AND+popcount step on the
  ``intersect`` Pallas kernel) vs ``frontier-device-resident`` (the RIG
  adjacency uploaded once and both gather+AND and pair expansion on
  device, host ships only index vectors — picked when the estimated
  resident footprint fits ``DeviceCaps.resident_max_bytes``).  Frontier
  wins when the enumeration visits many partial assignments; tiny answer
  sets stay on backtracking.

Plans are cached by canonical query key; on repeat executions the observed
``RigStats`` re-plan the backend *and* the enum method (e.g. a query whose
RIG collapsed to a few nodes is cheaper on the host even on a big graph; a
query observed to enumerate many results moves to the frontier path).
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import List, Optional, Tuple

from ..core.matcher import GMOptions
from ..core.mjoin import DEFAULT_LIMIT
from ..core.query import PatternQuery
from ..core.slabgeom import round_up
from .stats import Calibration, EstimateRecord, GraphStats, RigStats

__all__ = ["DeviceCaps", "Plan", "Planner"]

HOST = "host"
DEVICE = "device"


@dataclass(frozen=True)
class DeviceCaps:
    """Static limits of the device matcher (query padding + frontier)."""

    max_q: int = 8
    max_e: int = 16
    capacity: int = 4096
    min_graph_nodes: int = 512    # below this, dispatch overhead dominates
    frontier_device: bool = False  # route frontier ANDs through the kernel
    # device-memory budget for a resident RIG upload; a frontier-device
    # query whose estimated packed adjacency fits stays fully on device
    # (frontier-device-resident), larger ones ship per-level slabs
    resident_max_bytes: int = 1 << 30


@dataclass
class Plan:
    backend: str                   # "host" | "device"
    sim_algo: str                  # bas | dag | dagmap | none
    check_method: str              # binsearch | bititer | bitbat
    ordering: str = "jo"
    enum_method: str = "backtrack"  # see repro.core.mjoin.ENUM_METHODS
    sim_passes: Optional[int] = 4
    chunk_size: int = 1024         # streaming chunk rows (execute_stream)
    # device slabs below this row count are host-routed (padded dispatch
    # floor); set for device enum methods, 0 for host methods
    small_frontier_rows: int = 0
    est_cost: float = 0.0
    est_card: float = 0.0
    # committed size estimates (PR 10): reconciled against observed values
    # by the engine's EstimateRecord, audited via Engine.explain_analyze
    est_rig_nodes: float = 0.0
    est_rig_edges: float = 0.0
    est_resident_bytes: int = 0
    reasons: Tuple[str, ...] = ()

    def estimates(self) -> dict:
        """The committed estimates, keyed like ESTIMATE_QUANTITIES."""
        return {"cardinality": self.est_card,
                "rig_nodes": self.est_rig_nodes,
                "rig_edges": self.est_rig_edges,
                "resident_bytes": float(self.est_resident_bytes)}

    def batch_group(self) -> str:
        """Execution lane for cross-request batching in ``execute_many``:
        requests in the same lane on the same resident graph share one
        dispatch (vmapped device matcher / fused frontier slabs).  The
        resident enumerator shares the frontier-device lane — batching is
        per-level either way, only the slab transport differs."""
        if self.backend == DEVICE:
            return "device"
        if self.enum_method in ("frontier-device", "frontier-device-resident"):
            return "frontier-device"
        return "host"

    def gm_options(self, *, limit: Optional[int] = DEFAULT_LIMIT,
                   materialize: bool = False,
                   max_tuples: int = 1_000_000,
                   budget=None, breaker=None) -> GMOptions:
        """Host-matcher options realizing this plan.  The engine hands the
        matcher an already-reduced query, so TR is off here; ``budget`` /
        ``breaker`` carry the engine's per-query governance down into the
        matcher (see :mod:`repro.robust`)."""
        return GMOptions(use_transitive_reduction=False,
                         sim_algo=self.sim_algo, sim_passes=self.sim_passes,
                         check_method=self.check_method,
                         ordering=self.ordering,
                         enum_method=self.enum_method, limit=limit,
                         materialize=materialize, max_tuples=max_tuples,
                         small_frontier_rows=self.small_frontier_rows,
                         budget=budget, breaker=breaker)

    def explain(self) -> str:
        why = "; ".join(self.reasons) if self.reasons else "defaults"
        return (f"backend={self.backend} sim={self.sim_algo} "
                f"check={self.check_method} order={self.ordering} "
                f"enum={self.enum_method} "
                f"est_cost={self.est_cost:.3g} est_card={self.est_card:.3g} "
                f"[{why}]")


# The cost (in the unitless GraphStats scale) below which a repeat query's
# observed RIG makes host enumeration a sure win over a device dispatch.
TINY_RIG_NODES = 64
# Sparse-match-set threshold for preferring per-candidate iteration over
# whole-matrix batched bitset checks.
SPARSE_GRAPH_NODES = 1 << 16
SPARSE_MS_FRACTION = 1e-3
# Estimated-answer-set size above which the batched frontier enumerator
# beats one-tuple-at-a-time backtracking on the first execution ...
FRONTIER_EST_RESULTS = 4096
# ... and observed RIG/result sizes that re-pick it on repeat executions.
FRONTIER_RIG_NODES = 512
FRONTIER_MIN_RESULTS = 2048
# Streaming chunk-size bounds: small answer sets stream in small chunks
# (low first-chunk latency), large ones in big chunks (amortized rechunk
# and conversion overhead).
STREAM_CHUNK_MIN = 64
STREAM_CHUNK_MAX = 8192
STREAM_TARGET_CHUNKS = 16          # aim for ~this many chunks per result set
# Device slabs below this many rows lose to the host intersect: the device
# pads every dispatch to a >= 128-row tile (see repro.core.slabgeom), so a
# handful of real rows pays the full floor (BENCH_mjoin small-slab rows).
SMALL_FRONTIER_HOST_ROWS = 128


class Planner:
    def __init__(self, stats: GraphStats, caps: Optional[DeviceCaps] = None,
                 force_backend: Optional[str] = None,
                 force_enum: Optional[str] = None):
        self.stats = stats
        self.caps = caps or DeviceCaps()
        self.force_backend = force_backend
        self.force_enum = force_enum
        # per-graph misestimation medians (the planner is per resident
        # graph): the engine records observed/estimated ratios here and
        # plan()/refine() scale fresh estimates by them, so warm traffic
        # self-corrects systematic estimator bias
        self.calibration = Calibration()

    # ------------------------------------------------------------- backend
    def _pick_backend(self, q: PatternQuery,
                      reasons: List[str]) -> str:
        if self.force_backend is not None:
            reasons.append(f"backend forced to {self.force_backend}")
            return self.force_backend
        if q.n > self.caps.max_q or q.m > self.caps.max_e:
            reasons.append(
                f"query ({q.n} nodes / {q.m} edges) exceeds device caps "
                f"({self.caps.max_q}/{self.caps.max_e})")
            return HOST
        if self.stats.n < self.caps.min_graph_nodes:
            reasons.append(
                f"graph ({self.stats.n} nodes) below device threshold "
                f"({self.caps.min_graph_nodes}): dispatch overhead dominates")
            return HOST
        reasons.append("query fits device caps and graph is large")
        return DEVICE

    # ------------------------------------------------------------ sim algo
    def _pick_sim(self, q: PatternQuery, reasons: List[str]) -> str:
        if q.m <= 2:
            reasons.append("tiny pattern: FBSimBas (no Dag+Δ bookkeeping)")
            return "bas"
        reasons.append("dagmap simulation (change-flag convergence)")
        return "dagmap"

    # -------------------------------------------------------- check method
    def _pick_check(self, q: PatternQuery, reasons: List[str]) -> str:
        ms = [self.stats.match_set_size(l) for l in q.labels]
        avg_ms = sum(ms) / max(len(ms), 1)
        if (self.stats.n > SPARSE_GRAPH_NODES
                and avg_ms < SPARSE_MS_FRACTION * self.stats.n):
            reasons.append("huge graph + sparse match sets: bititer")
            return "bititer"
        reasons.append("bitbat batch checking")
        return "bitbat"

    # --------------------------------------------------------- enum method
    def _est_resident_bytes(self, q: PatternQuery) -> int:
        """Upper estimate of the packed RIG adjacency a resident upload
        would pin on device: cos sizes bounded by the exact match-set
        sizes, lane width padded as :func:`pack_resident_rig` pads it."""
        ms = [self.stats.match_set_size(l) for l in q.labels]
        w_lanes = round_up(max((max(ms, default=0) + 31) // 32, 128), 128)
        rows = 1 + sum(ms[e.src] + ms[e.dst] for e in q.edges)
        return rows * w_lanes * 4

    def _calibrated(self, quantity: str, est: float,
                    reasons: Optional[List[str]] = None) -> float:
        """Scale a fresh estimate by the graph's observed misestimation
        median for the same quantity (identity while cold)."""
        r = self.calibration.median(quantity)
        if r is None or r == 1.0:
            return est
        if reasons is not None:
            reasons.append(
                f"{quantity} estimate calibrated x{r:.3g} (median of "
                f"{self.calibration.observations(quantity)} observed "
                f"ratios)")
        return est * r

    def _frontier_kind(self, q: PatternQuery,
                       reasons: Optional[List[str]] = None) -> str:
        if not self.caps.frontier_device:
            return "frontier"
        est = int(self._calibrated("resident_bytes",
                                   self._est_resident_bytes(q), reasons))
        if est <= self.caps.resident_max_bytes:
            if reasons is not None:
                reasons.append(
                    f"estimated resident RIG ({est} B) fits device budget "
                    f"({self.caps.resident_max_bytes} B): index stays "
                    f"on device")
            return "frontier-device-resident"
        if reasons is not None:
            reasons.append(
                f"estimated resident RIG ({est} B) exceeds device budget "
                f"({self.caps.resident_max_bytes} B): per-level slabs")
        return "frontier-device"

    def _pick_enum(self, q: PatternQuery, reasons: List[str],
                   est_card: Optional[float] = None) -> str:
        if self.force_enum is not None:
            reasons.append(f"enum method forced to {self.force_enum}")
            return self.force_enum
        if est_card is None:
            est_card = self.stats.estimate_cardinality(q)
        if est_card >= FRONTIER_EST_RESULTS:
            reasons.append(
                f"estimated answer set >= {FRONTIER_EST_RESULTS}: "
                f"batched frontier enumeration")
            return self._frontier_kind(q, reasons)
        reasons.append("small estimated answer set: backtracking enumeration")
        return "backtrack"

    # ----------------------------------------------------------- chunk size
    def pick_chunk_size(self, expected_results: float) -> int:
        """Streaming chunk rows for an (estimated or observed) result count:
        the power of two nearest ``expected / STREAM_TARGET_CHUNKS``,
        clamped to [STREAM_CHUNK_MIN, STREAM_CHUNK_MAX]."""
        target = max(expected_results, 1.0) / STREAM_TARGET_CHUNKS
        c = STREAM_CHUNK_MIN
        while c < target and c < STREAM_CHUNK_MAX:
            c *= 2
        return c

    # ----------------------------------------------------------------- API
    def plan(self, q: PatternQuery) -> Plan:
        """Plan an (already transitively-reduced) query."""
        reasons: List[str] = []
        backend = self._pick_backend(q, reasons)
        sim = self._pick_sim(q, reasons)
        check = self._pick_check(q, reasons)
        est_card = self._calibrated(
            "cardinality", self.stats.estimate_cardinality(q), reasons)
        enum = self._pick_enum(q, reasons, est_card)
        return Plan(backend=backend, sim_algo=sim, check_method=check,
                    enum_method=enum,
                    chunk_size=self.pick_chunk_size(est_card),
                    small_frontier_rows=(
                        SMALL_FRONTIER_HOST_ROWS
                        if enum in ("frontier-device",
                                    "frontier-device-resident") else 0),
                    est_cost=self.stats.estimate_cost(q),
                    est_card=est_card,
                    est_rig_nodes=self.stats.estimate_rig_nodes(q),
                    est_rig_edges=self.stats.estimate_rig_edges(q),
                    est_resident_bytes=self._est_resident_bytes(q),
                    reasons=tuple(reasons))

    def refine(self, plan: Plan, q: PatternQuery,
               rig: RigStats) -> Plan:
        """Re-plan from observed RIG statistics (repeat executions)."""
        if rig.observations:
            # observed result counts re-pick the streaming chunk size
            chunk = self.pick_chunk_size(rig.count)
            if chunk != plan.chunk_size:
                plan = replace(plan, chunk_size=chunk)
        if self.force_backend is not None:
            return plan
        if (plan.backend == DEVICE and rig.observations
                and rig.rig_nodes <= TINY_RIG_NODES):
            plan = replace(
                plan, backend=HOST,
                reasons=plan.reasons + (
                    f"observed RIG has {rig.rig_nodes} nodes "
                    f"(<= {TINY_RIG_NODES}): host enumeration wins",))
        if self.force_enum is not None:
            return plan
        if rig.observations and plan.enum_method == "backtrack" and (
                rig.rig_nodes >= FRONTIER_RIG_NODES
                or rig.count >= FRONTIER_MIN_RESULTS):
            kind = self._frontier_kind(q)
            plan = replace(
                plan, enum_method=kind,
                small_frontier_rows=(SMALL_FRONTIER_HOST_ROWS
                                     if kind != "frontier" else 0),
                reasons=plan.reasons + (
                    f"observed RIG has {rig.rig_nodes} nodes / "
                    f"{rig.count} results: frontier enumeration",))
        elif (rig.observations
              and plan.enum_method in ("frontier", "frontier-device",
                                       "frontier-device-resident")
              and rig.rig_nodes < TINY_RIG_NODES
              and rig.count < FRONTIER_MIN_RESULTS):
            plan = replace(
                plan, enum_method="backtrack", small_frontier_rows=0,
                reasons=plan.reasons + (
                    f"observed tiny RIG ({rig.rig_nodes} nodes, "
                    f"{rig.count} results): backtracking wins",))
        return plan

    def analyze(self, plan: Plan, q: PatternQuery,
                est: EstimateRecord) -> List[Tuple[str, str, str, bool]]:
        """Which planner decisions would flip under observed stats.

        Returns ``(decision, planned, under_observed, flips)`` rows for the
        backend, the enum method, and resident eligibility, re-evaluating
        each decision rule with the :class:`EstimateRecord`'s observed
        values in place of the estimates (the same rules ``refine`` applies
        on warm traffic).  Forced choices never flip.
        """
        obs = est.obs
        rig_nodes = obs.get("rig_nodes")
        count = obs.get("cardinality")
        rows: List[Tuple[str, str, str, bool]] = []

        backend = plan.backend
        if (self.force_backend is None and backend == DEVICE
                and rig_nodes is not None
                and rig_nodes <= TINY_RIG_NODES):
            backend = HOST
        rows.append(("backend", plan.backend, backend,
                     backend != plan.backend))

        enum = plan.enum_method
        if self.force_enum is None and rig_nodes is not None \
                and count is not None:
            if enum == "backtrack" and (rig_nodes >= FRONTIER_RIG_NODES
                                        or count >= FRONTIER_MIN_RESULTS):
                enum = self._frontier_kind(q)
            elif (enum != "backtrack" and rig_nodes < TINY_RIG_NODES
                  and count < FRONTIER_MIN_RESULTS):
                enum = "backtrack"
        rows.append(("enum_method", plan.enum_method, enum,
                     enum != plan.enum_method))

        if self.caps.frontier_device:
            cap = self.caps.resident_max_bytes
            planned_fit = plan.est_resident_bytes <= cap
            observed = obs.get("resident_bytes")
            obs_fit = (observed <= cap) if observed else planned_fit
            rows.append((
                "resident_eligibility",
                f"est {plan.est_resident_bytes} B "
                f"{'<=' if planned_fit else '>'} cap {cap} B",
                (f"observed {int(observed)} B "
                 f"{'<=' if obs_fit else '>'} cap {cap} B"
                 if observed else "no resident execution observed"),
                obs_fit != planned_fit))
        return rows
