"""Textual hybrid-pattern query language: lexer, parser, pretty-printer.

Grammar (whitespace-insensitive)::

    query    :=  segment (',' segment)*
    segment  :=  node (edge node)*
    node     :=  '(' NAME (':' LABEL)? ')'
    edge     :=  '-/->' | '-//->' | '<-/-' | '<-//-'
    NAME     :=  [A-Za-z_][A-Za-z0-9_]*
    LABEL    :=  [A-Za-z_][A-Za-z0-9_]*

``-/->`` is a *child* edge (edge-to-edge mapping, ``p/q``) and ``-//->`` a
*descendant* edge (edge-to-path mapping, ``p//q``); the ``<-``-forms are the
same edges written right-to-left.  A node must carry a label on its first
mention; later mentions may repeat it (checked) or omit it::

    (a:Person)-/->(b:City)-//->(c:Country), (a)-//->(c)

Query-node indices are assigned in order of first appearance, so a query
round-trips exactly through :func:`fmt` / :func:`parse`.

String labels are mapped onto the int label space of the data graph through
a :class:`Vocab`; labels without an explicit name spell ``L<i>``.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

from ..core.query import CHILD, DESC, PatternQuery, QueryEdge

__all__ = ["Vocab", "QueryParseError", "parse", "fmt", "node_name"]

_GENERIC_LABEL = re.compile(r"^L(\d+)$")


class Vocab:
    """Bidirectional mapping between string label names and int label ids.

    Labels without an explicit name round-trip through the generic spelling
    ``L<i>``.  When ``num_labels`` is set (e.g. from a resident graph), any
    label outside the graph's label space is rejected at parse time.
    """

    def __init__(self,
                 names: Union[None, Sequence[str], Mapping[str, int]] = None,
                 num_labels: Optional[int] = None):
        self.num_labels = num_labels
        self._to_int: Dict[str, int] = {}
        self._to_str: Dict[int, str] = {}
        if names is not None:
            if isinstance(names, Mapping):
                for name, idx in names.items():
                    self.add(name, int(idx))
            else:
                for idx, name in enumerate(names):
                    self.add(name, idx)

    @classmethod
    def for_graph(cls, graph,
                  names: Union[None, Sequence[str], Mapping[str, int]] = None
                  ) -> "Vocab":
        return cls(names=names, num_labels=graph.num_labels)

    def add(self, name: str, idx: int) -> None:
        if _NAME.fullmatch(name) is None:
            raise ValueError(f"label name {name!r} is not a valid identifier "
                             f"([A-Za-z_][A-Za-z0-9_]*): fmt() output would "
                             f"not parse back")
        m = _GENERIC_LABEL.match(name)
        if m and int(m.group(1)) != idx:
            raise ValueError(f"label name {name!r} shadows the generic "
                             f"spelling of label id {m.group(1)} but maps "
                             f"to id {idx}")
        if self.num_labels is not None and not (0 <= idx < self.num_labels):
            raise ValueError(f"label id {idx} outside label space "
                             f"[0, {self.num_labels})")
        self._to_int[name] = idx
        self._to_str[idx] = name

    def encode(self, name: str) -> int:
        """Label name -> int id.  Raises ``KeyError`` if unknown."""
        if name in self._to_int:
            return self._to_int[name]
        m = _GENERIC_LABEL.match(name)
        if m:
            idx = int(m.group(1))
            if self.num_labels is None or idx < self.num_labels:
                return idx
        raise KeyError(name)

    def decode(self, idx: int) -> str:
        return self._to_str.get(int(idx), f"L{int(idx)}")

    def known_names(self) -> List[str]:
        return sorted(self._to_int)


class QueryParseError(ValueError):
    """Parse failure with position information and a caret display."""

    def __init__(self, msg: str, text: str, pos: int):
        self.msg, self.text, self.pos = msg, text, pos
        super().__init__(self.__str__())

    def __str__(self) -> str:
        line = self.text.replace("\n", " ")
        return f"{self.msg}\n  {line}\n  {' ' * self.pos}^"


# ------------------------------------------------------------------- lexer
_EDGE_TOKENS: List[Tuple[str, Tuple[int, bool]]] = [
    # token -> (kind, reversed)
    ("-//->", (DESC, False)),
    ("-/->", (CHILD, False)),
    ("<-//-", (DESC, True)),
    ("<-/-", (CHILD, True)),
]
_NAME = re.compile(r"[A-Za-z_][A-Za-z0-9_]*")


@dataclass
class _Token:
    kind: str          # 'lparen' | 'rparen' | 'colon' | 'comma' | 'edge' | 'name'
    pos: int
    text: str = ""
    edge: Tuple[int, bool] = (CHILD, False)


def _lex(text: str) -> List[_Token]:
    toks: List[_Token] = []
    i, n = 0, len(text)
    while i < n:
        c = text[i]
        if c.isspace():
            i += 1
            continue
        if c == "(":
            toks.append(_Token("lparen", i))
            i += 1
        elif c == ")":
            toks.append(_Token("rparen", i))
            i += 1
        elif c == ":":
            toks.append(_Token("colon", i))
            i += 1
        elif c == ",":
            toks.append(_Token("comma", i))
            i += 1
        else:
            for tok, edge in _EDGE_TOKENS:
                if text.startswith(tok, i):
                    toks.append(_Token("edge", i, tok, edge))
                    i += len(tok)
                    break
            else:
                m = _NAME.match(text, i)
                if m:
                    toks.append(_Token("name", i, m.group(0)))
                    i = m.end()
                else:
                    raise QueryParseError(
                        f"unexpected character {c!r} (expected a node "
                        f"'(name:Label)', an edge '-/->' / '-//->', or ',')",
                        text, i)
    return toks


# ------------------------------------------------------------------ parser
class _Parser:
    def __init__(self, text: str, vocab: Vocab):
        self.text = text
        self.vocab = vocab
        self.toks = _lex(text)
        self.i = 0
        self.index: Dict[str, int] = {}      # node name -> query-node index
        self.labels: List[int] = []
        self.edges: List[Tuple[int, int, int]] = []

    def _err(self, msg: str, pos: Optional[int] = None) -> QueryParseError:
        if pos is None:
            pos = (self.toks[self.i].pos if self.i < len(self.toks)
                   else len(self.text))
        return QueryParseError(msg, self.text, pos)

    def _peek(self) -> Optional[_Token]:
        return self.toks[self.i] if self.i < len(self.toks) else None

    _PUNCT = {"lparen": "'('", "rparen": "')'", "colon": "':'",
              "comma": "','"}

    def _expect(self, kind: str, what: str) -> _Token:
        t = self._peek()
        if t is None:
            got = "end of query"
        elif t.text:
            got = repr(t.text)
        else:
            got = self._PUNCT.get(t.kind, t.kind)
        if t is None or t.kind != kind:
            raise self._err(f"expected {what}, got {got}")
        self.i += 1
        return t

    def _node(self) -> int:
        self._expect("lparen", "'('")
        name_tok = self._expect("name", "a node name")
        name = name_tok.text
        label_tok = None
        if self._peek() and self._peek().kind == "colon":
            self.i += 1
            label_tok = self._expect("name", "a label name after ':'")
        self._expect("rparen", "')'")

        label: Optional[int] = None
        if label_tok is not None:
            try:
                label = self.vocab.encode(label_tok.text)
            except KeyError:
                known = self.vocab.known_names()
                hint = f" (known labels: {', '.join(known)})" if known else \
                       " (use L0, L1, ... for unnamed labels)"
                raise self._err(f"unknown label {label_tok.text!r}{hint}",
                                label_tok.pos) from None
        if name in self.index:
            q = self.index[name]
            if label is not None and label != self.labels[q]:
                raise self._err(
                    f"node {name!r} relabeled: was "
                    f"{self.vocab.decode(self.labels[q])!r}, "
                    f"now {label_tok.text!r}", label_tok.pos)
            return q
        if label is None:
            raise self._err(
                f"node {name!r} needs a label on first mention, e.g. "
                f"({name}:SomeLabel)", name_tok.pos)
        q = len(self.labels)
        self.index[name] = q
        self.labels.append(label)
        return q

    def _segment(self) -> None:
        src = self._node()
        while True:
            t = self._peek()
            if t is None or t.kind != "edge":
                return
            self.i += 1
            dst = self._node()
            kind, reversed_ = t.edge
            a, b = (dst, src) if reversed_ else (src, dst)
            if a == b:
                raise self._err("self-loop pattern edges are not supported",
                                t.pos)
            self.edges.append((a, b, kind))
            src = dst

    def run(self) -> PatternQuery:
        if not self.toks:
            raise self._err("empty query", 0)
        self._segment()
        while self._peek() is not None:
            self._expect("comma", "',' between segments")
            self._segment()
        return PatternQuery(labels=self.labels,
                            edges=[QueryEdge(*e) for e in self.edges])


def parse(text: str, vocab: Optional[Vocab] = None,
          name: str = "") -> PatternQuery:
    """Parse query text into a :class:`PatternQuery`.

    Node indices follow first appearance in the text; labels go through
    ``vocab`` (default: the generic ``L<i>`` spelling only).
    """
    q = _Parser(text, vocab or Vocab()).run()
    q.name = name
    return q


# ----------------------------------------------------------- pretty-printer
def node_name(i: int) -> str:
    """Canonical node names: a..z then n26, n27, ..."""
    return chr(ord("a") + i) if i < 26 else f"n{i}"


_EDGE_STR = {CHILD: "-/->", DESC: "-//->"}


def fmt(q: PatternQuery, vocab: Optional[Vocab] = None) -> str:
    """Pretty-print ``q`` so that ``parse(fmt(q))`` reproduces it exactly
    (same node indexing, labels and edges; ``name`` is not serialized).

    Edges are emitted as maximal chains.  If chaining alone would mention
    nodes out of index order (which would re-index them on parse), node
    declarations are prepended in index order.
    """
    vocab = vocab or Vocab()
    if q.n == 0:
        raise ValueError("cannot format an empty query")

    # greedy chain decomposition over the canonical (sorted) edge order
    unused = list(q.edges)
    chains: List[List[QueryEdge]] = []
    while unused:
        chain = [unused.pop(0)]
        while True:
            tail = chain[-1].dst
            nxt = next((e for e in unused if e.src == tail), None)
            if nxt is None:
                break
            unused.remove(nxt)
            chain.append(nxt)
        chains.append(chain)

    appearance: List[int] = []
    seen = set()

    def _appear(v: int) -> None:
        if v not in seen:
            seen.add(v)
            appearance.append(v)

    for chain in chains:
        _appear(chain[0].src)
        for e in chain:
            _appear(e.dst)
    in_order = (appearance == sorted(appearance)
                and len(appearance) == q.n)

    segments: List[str] = []
    emitted = set()

    def _node(v: int) -> str:
        if v in emitted:
            return f"({node_name(v)})"
        emitted.add(v)
        return f"({node_name(v)}:{vocab.decode(q.labels[v])})"

    if not in_order:
        # declare every node first, in index order, then chains by reference
        segments.extend(_node(v) for v in range(q.n))
    for chain in chains:
        parts = [_node(chain[0].src)]
        for e in chain:
            parts.append(_EDGE_STR[e.kind])
            parts.append(_node(e.dst))
        segments.append("".join(parts))
    return ", ".join(segments)
