"""PartitionSpec conventions for the production meshes.

Meshes come from ``repro.launch.mesh``: ``("data", "model")`` per pod, with
a leading ``"pod"`` axis across pods.  The conventions here:

* **batch axes** — activations/batches shard their leading dimension over
  every data-parallel axis present (``("pod", "data")`` ∩ mesh axes);
  parameters are replicated across pods.
* **LM params** — Megatron-style tensor parallelism over ``"model"``
  (column-parallel in-projections, row-parallel out-projections, vocab
  -sharded embedding/lm_head) combined with FSDP-style sharding of the
  other weight dimension over ``"data"``.  Per-layer weights are stacked
  with a leading ``n_layers`` dim, which is never sharded (it is scanned).
  ``configs.base`` overrides the kv projections when GQA head padding does
  not divide the TP degree.
* **GNN params** — small MLPs: replicated; batches shard nodes/edges.
* **DIN params** — the embedding tables are the big tensors: row-sharded
  over ``"model"``; the attention/output MLPs are tiny and replicated.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["batch_axes", "lm_param_specs", "lm_batch_specs",
           "gnn_param_specs", "gnn_batch_specs",
           "din_param_specs", "din_batch_specs"]


def batch_axes(mesh: Mesh):
    """Data-parallel mesh axes, as one PartitionSpec entry for the leading
    batch/node dimension: ("pod", "data") restricted to the mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _is_shape(x) -> bool:
    return isinstance(x, tuple)


# ------------------------------------------------------------------ LM (TP)
def lm_param_specs(cfg, mesh: Mesh) -> Dict[str, Any]:
    """Specs mirroring ``models.transformer.param_shapes``.

    Column-parallel (out-dim over "model", in-dim over "data"): wq/wk/wv,
    w1/w3 and shared-expert in-projections; row-parallel (in-dim over
    "model", out-dim over "data"): wo, w2.  Vocab dims shard over "model".
    Norms and the router replicate; biases follow their projection's
    out-dim.  The leading stacked-layer dim stays unsharded.
    """
    col = P(None, "data", "model")          # (layer, in, out): out-parallel
    row = P(None, "model", "data")          # (layer, in, out): in-parallel
    layer_specs: Dict[str, P] = {
        "ln1": P(None, None), "ln2": P(None, None),
        "wq": col, "wk": col, "wv": col, "wo": row,
        "bq": P(None, "model"), "bk": P(None, "model"),
        "bv": P(None, "model"),
        "w1": col, "w3": col, "w2": row,
        # MoE: experts replicate over the mesh (the dry-run measures the
        # dense shards; expert parallelism is an open item)
        "router": P(None, None, None),
        "we1": P(None, None, "data", "model"),
        "we3": P(None, None, "data", "model"),
        "we2": P(None, None, "model", "data"),
        "ws1": col, "ws3": col, "ws2": row,
    }
    import repro.models.transformer as tf_mod

    shapes = tf_mod.param_shapes(cfg)
    layers = {k: layer_specs.get(k, P(*([None] * len(v))))
              for k, v in shapes["layers"].items()}
    return {
        "embed": P("model", None),          # vocab-sharded
        "final_ln": P(None),
        "lm_head": P(None, "model"),        # vocab-sharded output
        "layers": layers,
    }


def lm_batch_specs(mesh: Mesh) -> Dict[str, P]:
    baxes = batch_axes(mesh)
    return {"tokens": P(baxes, None), "labels": P(baxes, None)}


# ---------------------------------------------------------------------- GNN
def gnn_param_specs(cfg, mesh: Mesh):
    """GNN weights are small — replicate everything (structure mirrors
    ``models.gnn.param_shapes``)."""
    import repro.models.gnn as gnn_mod

    return jax.tree.map(lambda s: P(*([None] * len(s))),
                        gnn_mod.param_shapes(cfg), is_leaf=_is_shape)


def gnn_batch_specs(mesh: Mesh, batch) -> Dict[str, P]:
    """Node/edge arrays shard their leading dimension over the batch axes
    (padded upstream to multiples of 512, see ``configs.base``)."""
    baxes = batch_axes(mesh)
    return {k: P(baxes, *([None] * (len(v.shape) - 1)))
            for k, v in batch.items()}


# ---------------------------------------------------------------------- DIN
def din_param_specs(cfg, mesh: Mesh):
    """Embedding tables row-shard over "model"; the MLPs replicate."""
    import repro.models.recsys as din_mod

    def spec(name: str, shape) -> P:
        if name.endswith("_table"):
            return P("model", *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))

    shapes = din_mod.param_shapes(cfg)
    return {k: spec(k, v) for k, v in shapes.items()}


def din_batch_specs(mesh: Mesh, batch) -> Dict[str, P]:
    baxes = batch_axes(mesh)
    return {k: P(baxes, *([None] * (len(v.shape) - 1)))
            for k, v in batch.items()}
