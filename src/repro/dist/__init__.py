# Distribution helpers shared by the config/dry-run framework: the
# PartitionSpec conventions for every model family live in ``sharding``.
from . import sharding

__all__ = ["sharding"]
