"""Distributed GNN training step (§Perf hillclimb for the GNN family).

Hypothesis H3: the baseline pjit auto-sharding of edge-index message
passing scatters across *data* shards every layer (all-to-all-heavy: the
compiler reshuffles (E, d) message tensors), and leaves the ``model`` axis
idle.  Restructure with an explicit shard_map over ALL mesh axes:

* nodes row-partitioned over (pod, data, model) — N/512 rows per device;
* edges arrive **partitioned by destination shard** (loader contract: the
  sampler already emits dst-sorted edges), with dst indices local and src
  indices global;
* per layer: one tiled ``all_gather`` of the (N, d) feature matrix →
  local gather + local segment_sum → local MLP;
* gradients ``psum`` once per step.

Collective volume per layer = the feature matrix (N·d·4 B), independent of
E — vs the baseline's per-edge traffic (E ≫ N for products: 61.8M edges vs
2.4M nodes).  Graph-partition locality (METIS-style halo exchange instead
of full gather) is the next rung and is noted in EXPERIMENTS.md.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..jaxgm.compat import shard_map
from ..train import optimizer as opt_mod
from . import gnn as gnn_mod


def _all_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)


def sharded_train_step(cfg: gnn_mod.GNNConfig, mesh: Mesh,
                       ocfg: opt_mod.AdamWConfig):
    """Returns (step_fn, batch_specs) for gin/sage; batch leaves carry
    *local-shape* semantics inside shard_map (global = local × n_shards)."""
    axes = _all_axes(mesh)

    def local_forward(params, batch, n_total):
        h = gnn_mod.mlp_apply(params["proj"],
                              batch["node_feat"].astype(cfg.dtype))
        for i in range(cfg.n_layers):
            h_all = jax.lax.all_gather(h, axes, axis=0, tiled=True)  # (N, d)
            msg = jnp.take(h_all, batch["edge_src"], axis=0)
            agg = jax.ops.segment_sum(msg, batch["edge_dst"],
                                      num_segments=h.shape[0])
            if cfg.arch == "gin":
                eps = params[f"eps{i}"][0]
                h = gnn_mod.mlp_apply(params[f"mlp{i}"],
                                      (1.0 + eps) * h + agg, final_act=True)
            else:
                if cfg.aggregator == "mean":
                    deg = jax.ops.segment_sum(
                        jnp.ones_like(batch["edge_dst"], h.dtype),
                        batch["edge_dst"], num_segments=h.shape[0])
                    agg = agg / jnp.maximum(deg, 1.0)[:, None]
                h = jax.nn.relu(gnn_mod.mlp_apply(params[f"self{i}"], h)
                                + gnn_mod.mlp_apply(params[f"neigh{i}"], agg))
                h = h / jnp.maximum(
                    jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)
        return gnn_mod.mlp_apply(params["head"], h)

    def local_loss(params, batch):
        out = local_forward(params, batch, None)
        logp = jax.nn.log_softmax(out.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, batch["labels"][:, None], -1)[:, 0]
        mask = batch["node_mask"].astype(jnp.float32)
        if "train_mask" in batch:
            mask = mask * batch["train_mask"].astype(jnp.float32)
        num = jax.lax.psum((nll * mask).sum(), axes)
        den = jax.lax.psum(mask.sum(), axes)
        return num / jnp.maximum(den, 1.0)

    def local_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(local_loss)(params, batch)
        grads = jax.lax.pmean(grads, axes)      # data-parallel reduce
        params, opt_state, _ = opt_mod.apply_updates(params, grads,
                                                     opt_state, ocfg)
        return params, opt_state, loss

    batch_spec = {
        "node_feat": P(axes, None), "edge_src": P(axes),
        "edge_dst": P(axes), "labels": P(axes),
        "node_mask": P(axes), "train_mask": P(axes),
    }
    pspec = jax.tree.map(lambda _: P(), gnn_mod.param_shapes(cfg),
                         is_leaf=lambda x: isinstance(x, tuple))
    opt_spec = {"step": P(), "m": pspec, "v": pspec}

    step = shard_map(
        local_step, mesh=mesh,
        in_specs=(pspec, opt_spec, batch_spec),
        out_specs=(pspec, opt_spec, P()),
        check_vma=False)
    return step, batch_spec
