"""DIN (Deep Interest Network) recsys substrate [arXiv:1706.06978].

Huge sparse embedding tables → target attention over the user-behaviour
sequence → small MLP.  JAX has no native EmbeddingBag: lookups are
``jnp.take`` + ``jax.ops.segment_sum``-style reductions, built here as a
first-class part of the system; the tables row-shard over the model axis
(see dist.sharding) and ``retrieval_cand`` scores 10⁶ candidates with one
batched einsum, never a loop.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DINConfig:
    name: str
    embed_dim: int = 18
    seq_len: int = 100
    n_items: int = 1_000_000
    n_cates: int = 10_000
    n_user_feats: int = 8            # small profile fields
    user_feat_vocab: int = 1_000
    attn_mlp: Tuple[int, ...] = (80, 40)
    mlp: Tuple[int, ...] = (200, 80)
    dtype: Any = jnp.float32


def param_shapes(cfg: DINConfig) -> Dict[str, Any]:
    d = cfg.embed_dim
    pair = 2 * d                     # item ⊕ cate embedding
    s: Dict[str, Any] = {
        "item_table": (cfg.n_items, d),
        "cate_table": (cfg.n_cates, d),
        "user_table": (cfg.user_feat_vocab, d),
    }
    # attention MLP over [hist, target, hist*target, hist-target]
    dims = (4 * pair,) + cfg.attn_mlp + (1,)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        s[f"attn_w{i}"] = (a, b)
        s[f"attn_b{i}"] = (b,)
    # final MLP over [user_profile, interest, target, interest*target]
    d_in = cfg.n_user_feats * d + 3 * pair
    dims = (d_in,) + cfg.mlp + (1,)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        s[f"mlp_w{i}"] = (a, b)
        s[f"mlp_b{i}"] = (b,)
    return s


def abstract_params(cfg: DINConfig):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s, cfg.dtype),
                        param_shapes(cfg),
                        is_leaf=lambda x: isinstance(x, tuple))


def init_params(cfg: DINConfig, key: jax.Array):
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree.flatten(shapes,
                                     is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(flat))

    def one(k, s):
        if len(s) == 1:
            return jnp.zeros(s, cfg.dtype)
        scale = 0.01 if s[0] > 10_000 else 1.0 / np.sqrt(s[0])
        return (jax.random.normal(k, s, jnp.float32) * scale).astype(cfg.dtype)

    return jax.tree.unflatten(treedef, [one(k, s) for k, s in zip(keys, flat)])


# -------------------------------------------------------------- embedding
def embedding_bag(table: jax.Array, ids: jax.Array,
                  mask: jax.Array | None = None, combine: str = "none"):
    """EmbeddingBag built from gather + reduce (no native op in JAX).

    ids (..., L) -> (..., L, d) or reduced (..., d) for combine=sum/mean.
    """
    e = jnp.take(table, ids, axis=0)
    if mask is not None:
        e = e * mask[..., None].astype(e.dtype)
    if combine == "sum":
        return e.sum(axis=-2)
    if combine == "mean":
        denom = (mask.sum(-1, keepdims=True) if mask is not None
                 else jnp.full(e.shape[:-2] + (1,), e.shape[-2]))
        return e.sum(axis=-2) / jnp.maximum(denom, 1.0)
    return e


def _mlp(p, prefix, x, n, act=jax.nn.sigmoid):
    for i in range(n):
        x = x @ p[f"{prefix}_w{i}"] + p[f"{prefix}_b{i}"]
        if i < n - 1:
            x = act(x)
    return x


def _n_layers(p, prefix):
    return len([k for k in p if k.startswith(f"{prefix}_w")])


def target_attention(p, hist: jax.Array, target: jax.Array,
                     mask: jax.Array) -> jax.Array:
    """DIN local activation unit.

    hist (..., L, 2d) · target (..., 2d) -> interest (..., 2d).
    Attention scores from MLP([h, t, h*t, h-t]); masked positions zeroed
    (DIN uses un-normalized sigmoid-ish weights, not softmax).
    """
    t = jnp.broadcast_to(target[..., None, :], hist.shape)
    feats = jnp.concatenate([hist, t, hist * t, hist - t], axis=-1)
    scores = _mlp(p, "attn", feats, _n_layers(p, "attn"))[..., 0]
    scores = jnp.where(mask > 0, scores, -1e30)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(hist.dtype)
    w = jnp.where(mask > 0, w, 0.0)
    return jnp.einsum("...l,...ld->...d", w, hist)


def forward(params, batch, cfg: DINConfig):
    """batch: item_id (B,), cate_id (B,), hist_items (B, L), hist_cates
    (B, L), hist_mask (B, L), user_feats (B, n_user_feats) -> logits (B,)."""
    p = params
    tgt = jnp.concatenate([
        embedding_bag(p["item_table"], batch["item_id"]),
        embedding_bag(p["cate_table"], batch["cate_id"]),
    ], axis=-1)                                            # (B, 2d)
    hist = jnp.concatenate([
        embedding_bag(p["item_table"], batch["hist_items"]),
        embedding_bag(p["cate_table"], batch["hist_cates"]),
    ], axis=-1)                                            # (B, L, 2d)
    interest = target_attention(p, hist, tgt, batch["hist_mask"])
    user = embedding_bag(p["user_table"], batch["user_feats"])  # (B, U, d)
    user = user.reshape(user.shape[0], -1)
    x = jnp.concatenate([user, interest, tgt, interest * tgt], axis=-1)
    return _mlp(p, "mlp", x, _n_layers(p, "mlp"))[:, 0]


def loss_fn(params, batch, cfg: DINConfig):
    logits = forward(params, batch, cfg)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def retrieval_scores(params, batch, cfg: DINConfig):
    """Score one user against a flat candidate set (retrieval_cand shape).

    batch: hist_items/hist_cates/hist_mask (1, L), user_feats (1, U),
    cand_items (C,), cand_cates (C,) -> scores (C,).
    One batched attention+MLP over all candidates — no loop.
    """
    p = params
    hist = jnp.concatenate([
        embedding_bag(p["item_table"], batch["hist_items"]),
        embedding_bag(p["cate_table"], batch["hist_cates"]),
    ], axis=-1)[0]                                         # (L, 2d)
    cand = jnp.concatenate([
        embedding_bag(p["item_table"], batch["cand_items"]),
        embedding_bag(p["cate_table"], batch["cand_cates"]),
    ], axis=-1)                                            # (C, 2d)
    mask = jnp.broadcast_to(batch["hist_mask"][0][None, :],
                            (cand.shape[0], hist.shape[0]))
    hist_b = jnp.broadcast_to(hist[None], (cand.shape[0],) + hist.shape)
    interest = target_attention(p, hist_b, cand, mask)     # (C, 2d)
    user = embedding_bag(p["user_table"], batch["user_feats"])[0].reshape(-1)
    user_b = jnp.broadcast_to(user[None], (cand.shape[0], user.shape[0]))
    x = jnp.concatenate([user_b, interest, cand, interest * cand], axis=-1)
    return _mlp(params, "mlp", x, _n_layers(params, "mlp"))[:, 0]
