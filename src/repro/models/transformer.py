"""Decoder-only LM substrate: dense and MoE transformers.

Covers the five assigned LM architectures (yi-34b, qwen1.5-4b, qwen2-7b,
grok-1-314b, deepseek-moe-16b): GQA with optional QKV bias, RoPE, RMSNorm,
SwiGLU FFN, and an MoE block with shared + routed experts (top-k, grouped
sort-based dispatch with per-group capacity — no (T, E, C) dispatch tensor).

Layers are stacked and iterated with ``lax.scan`` so the HLO stays one
layer deep (essential for 512-device dry-run compile times).  Parameters
are plain nested dicts of arrays; ``abstract_params`` builds the matching
ShapeDtypeStruct tree for allocation-free lowering; ``partition_specs``
mirrors the tree with PartitionSpecs (see repro.dist.sharding for the
logical rules).

Mesh-divisibility: attention head counts are padded up to a multiple of the
tensor-parallel axis (zero-initialized extra heads — mathematically inert
but they do consume FLOPs; the roofline section reports this overhead via
the MODEL_FLOPS/HLO_FLOPs ratio).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0                  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    # MoE (n_experts == 0 -> dense FFN)
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # padding for tensor parallelism (applied by pad_for_mesh)
    pad_heads_to: int = 0
    pad_kv_to: int = 0
    dtype: Any = jnp.bfloat16
    remat: bool = True
    # unroll=True replaces the layer scan with a python loop — used by the
    # dry-run's cost *calibration* passes (HLO cost analysis counts a scan
    # body once; unrolled small-L lowerings extrapolate exactly).
    unroll: bool = False
    # --- beyond-paper §Perf options (baseline keeps both off) -------------
    # chunked online-softmax attention (flash-style): never materializes the
    # (B, H, T, T) score matrix; kv_chunk is the K/V tile length.
    flash_attention: bool = False
    kv_chunk: int = 1024
    # chunked cross-entropy: computes lm_head logits + log-softmax per
    # sequence chunk, never materializing (B, T, V) f32.
    chunked_loss: bool = False
    loss_chunk: int = 512
    # §Perf H10: mesh axis names to pin the activations' batch dim to at
    # every layer boundary (with_sharding_constraint).  Without it GSPMD can
    # propagate a weight-stationary layout into the layer scan (batch
    # replicated, d_model sharded) and activation temps blow up ~n_data×.
    shard_activations: Tuple[str, ...] = ()

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def hq(self) -> int:
        return max(self.n_heads, self.pad_heads_to)

    @property
    def hkv(self) -> int:
        return max(self.n_kv, self.pad_kv_to)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def pad_for_mesh(self, tp: int) -> "LMConfig":
        """Pad head counts up to a multiple of the TP degree."""
        def up(x):
            return int(math.ceil(x / tp) * tp) if x % tp else x
        return dataclasses.replace(self, pad_heads_to=up(self.n_heads),
                                   pad_kv_to=up(self.n_kv))

    def n_params(self) -> int:
        """True (unpadded) parameter count."""
        d, v, l = self.d_model, self.vocab, self.n_layers
        attn = d * self.n_heads * self.head_dim * 2 \
            + d * self.n_kv * self.head_dim * 2
        if self.is_moe:
            ffn = 3 * d * self.d_ff_expert * (self.n_experts + self.n_shared) \
                + d * self.n_experts
        else:
            ffn = 3 * d * self.d_ff
        return l * (attn + ffn + 2 * d) + 2 * v * d + d

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared)."""
        if not self.is_moe:
            return self.n_params()
        d, l = self.d_model, self.n_layers
        attn = d * self.n_heads * self.head_dim * 2 \
            + d * self.n_kv * self.head_dim * 2
        ffn = 3 * d * self.d_ff_expert * (self.top_k + self.n_shared) \
            + d * self.n_experts
        return l * (attn + ffn + 2 * d) + 2 * self.vocab * d + d


# ------------------------------------------------------------------ params
def _layer_shapes(cfg: LMConfig) -> Dict[str, Tuple[int, ...]]:
    d, hd = cfg.d_model, cfg.head_dim
    s: Dict[str, Tuple[int, ...]] = {
        "ln1": (d,), "ln2": (d,),
        "wq": (d, cfg.hq * hd), "wk": (d, cfg.hkv * hd),
        "wv": (d, cfg.hkv * hd), "wo": (cfg.hq * hd, d),
    }
    if cfg.qkv_bias:
        s |= {"bq": (cfg.hq * hd,), "bk": (cfg.hkv * hd,),
              "bv": (cfg.hkv * hd,)}
    if cfg.is_moe:
        s |= {
            "router": (d, cfg.n_experts),
            "we1": (cfg.n_experts, d, cfg.d_ff_expert),
            "we3": (cfg.n_experts, d, cfg.d_ff_expert),
            "we2": (cfg.n_experts, cfg.d_ff_expert, d),
        }
        if cfg.n_shared:
            ds = cfg.n_shared * cfg.d_ff_expert
            s |= {"ws1": (d, ds), "ws3": (d, ds), "ws2": (ds, d)}
    else:
        s |= {"w1": (d, cfg.d_ff), "w3": (d, cfg.d_ff), "w2": (cfg.d_ff, d)}
    return s


def param_shapes(cfg: LMConfig) -> Dict[str, Any]:
    l = cfg.n_layers
    return {
        "embed": (cfg.vocab, cfg.d_model),
        "final_ln": (cfg.d_model,),
        "lm_head": (cfg.d_model, cfg.vocab),
        "layers": {k: (l, *v) for k, v in _layer_shapes(cfg).items()},
    }


def abstract_params(cfg: LMConfig):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s, cfg.dtype),
                        param_shapes(cfg),
                        is_leaf=lambda x: isinstance(x, tuple))


def init_params(cfg: LMConfig, key: jax.Array):
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree.flatten(shapes, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(flat))

    def init_one(k, shape):
        if len(shape) == 1 or (len(shape) == 2 and shape[0] == cfg.n_layers):
            return jnp.ones(shape, cfg.dtype)            # norms
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        return (jax.random.normal(k, shape, jnp.float32)
                / np.sqrt(fan_in)).astype(cfg.dtype)

    leaves = [init_one(k, s) for k, s in zip(keys, flat)]
    params = jax.tree.unflatten(treedef, leaves)
    # biases start at zero; padded heads stay inert because wq/wk/wv columns
    # beyond the true head count are zeroed below.
    for name in ("bq", "bk", "bv"):
        if name in params["layers"]:
            params["layers"][name] = jnp.zeros_like(params["layers"][name])
    hd = cfg.head_dim
    if cfg.hq > cfg.n_heads:
        params["layers"]["wq"] = params["layers"]["wq"].at[
            ..., cfg.n_heads * hd:].set(0)
        params["layers"]["wo"] = params["layers"]["wo"].at[
            :, cfg.n_heads * hd:, :].set(0)
    if cfg.hkv > cfg.n_kv:
        for nm in ("wk", "wv"):
            params["layers"][nm] = params["layers"][nm].at[
                ..., cfg.n_kv * hd:].set(0)
    return params


# ------------------------------------------------------------------- layers
def rmsnorm(x, w, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope(x, positions, theta):
    # x: (..., T, H, hd); positions: (..., T)
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs     # (..., T, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1).astype(x.dtype)


def _flash_attention(q, k, v, *, kv_chunk: int):
    """Causal online-softmax attention over K/V chunks (flash-style).

    Hypothesis H1 (§Perf): the baseline materializes (B, Hkv, G, T, T)
    scores — ~T/kv_chunk × more HBM traffic than needed; streaming the KV
    with a running (max, denom) drops the memory term by ~T/kv_chunk and
    removes the dominant temp buffer.  Same math (exact softmax), so
    answers are bitwise-close (f32 accumulation in both paths).
    """
    b, tq, hq, hd = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, tq, hkv, group, hd)
    n_chunks = tk // kv_chunk
    kc = k.reshape(b, n_chunks, kv_chunk, hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, hkv, hd).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(tq)

    def body(carry, inputs):
        acc, m, denom = carry                   # (b,tq,hkv,g,hd),(b,tq,hkv,g)
        ci, (kb, vb) = inputs
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kb).astype(jnp.float32)
        s = s / np.sqrt(hd)
        kpos = ci * kv_chunk + jnp.arange(kv_chunk)
        mask = qpos[:, None] >= kpos[None, :]   # (tq, kv_chunk)
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        acc = acc * alpha[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(q.dtype), vb).astype(jnp.float32)
        denom = denom * alpha + p.sum(axis=-1)
        return (acc, m_new, denom), None

    acc0 = jnp.zeros((b, tq, hkv, group, hd), jnp.float32)
    m0 = jnp.full((b, tq, hkv, group), -1e30, jnp.float32)
    d0 = jnp.zeros((b, tq, hkv, group), jnp.float32)
    # §Perf H11: checkpoint the chunk body — otherwise autodiff saves each
    # chunk's (b, tq, h, g, kv_chunk) probability tensor for the backward
    # pass, resurrecting most of the memory flash attention removed.
    (acc, m, denom), _ = jax.lax.scan(
        jax.checkpoint(body), (acc0, m0, d0),
        (jnp.arange(n_chunks), (kc, vc)))
    out = acc / jnp.maximum(denom, 1e-30)[..., None]
    return out.astype(q.dtype).reshape(b, tq, hq, hd)


def _attention(q, k, v, *, causal: bool, q_offset=None):
    # q: (B, Tq, Hq, hd); k/v: (B, Tk, Hkv, hd); GQA via head grouping
    b, tq, hq, hd = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, tq, hkv, group, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd)
    if causal:
        qpos = jnp.arange(tq)[:, None] + (0 if q_offset is None else q_offset)
        mask = qpos >= jnp.arange(tk)[None, :]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, tq, hq, hd)


def attention_block(x, layer, cfg: LMConfig, positions, cache=None,
                    layer_idx=None):
    """Returns (attn_out, new_cache_entry).  cache: dict with k/v
    (B, T_max, Hkv, hd) and current length (decode path)."""
    b, t, d = x.shape
    hd = cfg.head_dim
    q = x @ layer["wq"]
    k = x @ layer["wk"]
    v = x @ layer["wv"]
    if cfg.qkv_bias:
        q, k, v = q + layer["bq"], k + layer["bk"], v + layer["bv"]
    q = q.reshape(b, t, cfg.hq, hd)
    k = k.reshape(b, t, cfg.hkv, hd)
    v = v.reshape(b, t, cfg.hkv, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache is None:
        if cfg.flash_attention and t % cfg.kv_chunk == 0 and t > cfg.kv_chunk:
            out = _flash_attention(q, k, v, kv_chunk=cfg.kv_chunk)
        else:
            out = _attention(q, k, v, causal=True)
        new_cache = (k, v)
    else:
        ck, cv, length = cache                    # (B, Tmax, Hkv, hd) ×2, int
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, length, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, length, axis=1)
        tk = ck.shape[1]
        # mask out positions beyond current length + t
        scores_mask = jnp.arange(tk) < (length + t)
        group = cfg.hq // cfg.hkv
        qg = q.reshape(b, t, cfg.hkv, group, hd)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, ck).astype(jnp.float32)
        scores = scores / np.sqrt(hd)
        qpos = jnp.arange(t)[:, None] + length
        causal = qpos >= jnp.arange(tk)[None, :]
        scores = jnp.where((causal & scores_mask[None, :])[None, None, None],
                           scores, -1e30)
        probs = jax.nn.softmax(scores, -1).astype(x.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, cv).reshape(b, t, cfg.hq, hd)
        new_cache = (ck, cv)
    return out.reshape(b, t, cfg.hq * hd) @ layer["wo"], new_cache


def dense_ffn(x, layer):
    return (jax.nn.silu(x @ layer["w1"]) * (x @ layer["w3"])) @ layer["w2"]


def moe_ffn(x, layer, cfg: LMConfig):
    """Shared experts + routed top-k with grouped sort-based dispatch.

    x: (B, T, d) — each (batch) row is a dispatch group, so the sort and the
    capacity are local to the group (and to its data shard).
    """
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(math.ceil(t * k / e * cfg.capacity_factor))

    logits = (x @ layer["router"]).astype(jnp.float32)       # (B, T, E)
    gate, sel = jax.lax.top_k(logits, k)                     # (B, T, k)
    gate = jax.nn.softmax(gate, axis=-1).astype(x.dtype)

    def group_dispatch(xg, selg, gateg):
        # xg: (T, d); selg/gateg: (T, k)
        flat_e = selg.reshape(-1)                            # (T*k,)
        flat_g = gateg.reshape(-1)
        tok = jnp.arange(t * k) // k
        order = jnp.argsort(flat_e, stable=True)
        se, sg, stok = flat_e[order], flat_g[order], tok[order]
        start = jnp.searchsorted(se, se, side="left")
        pos = jnp.arange(t * k) - start                      # rank within expert
        keep = pos < cap
        slot = jnp.where(keep, se * cap + pos, e * cap)      # overflow -> waste slot
        buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].add(
            jnp.where(keep[:, None], xg[stok], 0))
        h = buf[:e * cap].reshape(e, cap, d)
        h = jnp.einsum("ecd,edf->ecf", h, layer["we1"])
        h3 = jnp.einsum("ecd,edf->ecf", buf[:e * cap].reshape(e, cap, d),
                        layer["we3"])
        h = jax.nn.silu(h) * h3
        out_e = jnp.einsum("ecf,efd->ecd", h, layer["we2"]).reshape(e * cap, d)
        y = jnp.zeros((t, d), x.dtype).at[stok].add(
            jnp.where(keep[:, None], out_e[jnp.clip(slot, 0, e * cap - 1)]
                      * sg[:, None], 0))
        return y

    y = jax.vmap(group_dispatch)(x, sel, gate)
    if cfg.n_shared:
        y = y + (jax.nn.silu(x @ layer["ws1"]) * (x @ layer["ws3"])) @ layer["ws2"]
    return y


def _constrain(x, cfg: LMConfig):
    if cfg.shard_activations:
        from jax.sharding import PartitionSpec as P
        spec = P(cfg.shard_activations, *([None] * (x.ndim - 1)))
        try:
            x = jax.lax.with_sharding_constraint(x, spec)
        except (ValueError, RuntimeError):
            pass   # no mesh in context (single-device calibration lowering)
    return x


def _layer_fn(x, layer, cfg: LMConfig, positions, cache=None):
    x = _constrain(x, cfg)
    h, new_cache = attention_block(rmsnorm(x, layer["ln1"], cfg.norm_eps),
                                   layer, cfg, positions, cache)
    x = x + h
    xn = rmsnorm(x, layer["ln2"], cfg.norm_eps)
    x = x + (moe_ffn(xn, layer, cfg) if cfg.is_moe else dense_ffn(xn, layer))
    return _constrain(x, cfg), new_cache


# ------------------------------------------------------------------ forward
def forward(params, tokens, cfg: LMConfig):
    """tokens (B, T) -> logits (B, T, vocab).  Training/prefill path."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)

    def body(x, layer):
        fn = _layer_fn
        if cfg.remat:
            fn = jax.checkpoint(_layer_fn, static_argnums=(2,))
        x, _ = fn(x, layer, cfg, positions)
        return x, None

    if cfg.unroll:
        for i in range(cfg.n_layers):
            layer = jax.tree.map(lambda p: p[i], params["layers"])
            x, _ = body(x, layer)
    else:
        x, _ = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    return (x @ params["lm_head"]).astype(jnp.float32)


def forward_hidden(params, tokens, cfg: LMConfig):
    """Transformer trunk without the LM head: (B, T) -> (B, T, d)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)

    def body(x, layer):
        fn = _layer_fn
        if cfg.remat:
            fn = jax.checkpoint(_layer_fn, static_argnums=(2,))
        x, _ = fn(x, layer, cfg, positions)
        return x, None

    if cfg.unroll:
        for i in range(cfg.n_layers):
            layer = jax.tree.map(lambda p: p[i], params["layers"])
            x, _ = body(x, layer)
    else:
        x, _ = jax.lax.scan(body, x, params["layers"])
    return rmsnorm(x, params["final_ln"], cfg.norm_eps)


def _chunked_ce(hidden, lm_head, labels, mask, chunk: int):
    """Hypothesis H2 (§Perf): the (B, T, V) f32 logits buffer dominates the
    loss memory; streaming T-chunks through lm_head + log-softmax keeps only
    (B, chunk, V) alive.  jax.checkpoint makes the bwd recompute per chunk."""
    b, t, d = hidden.shape
    n = t // chunk
    hc = hidden.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)
    mc = mask.reshape(b, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def one(h, l, m):
        logits = (h @ lm_head).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, l[..., None], axis=-1)[..., 0]
        return (nll * m).sum()

    def body(acc, inp):
        h, l, m = inp
        return acc + one(h, l, m), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc, mc))
    return total


def loss_fn(params, batch, cfg: LMConfig):
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    if cfg.chunked_loss and labels.shape[1] % cfg.loss_chunk == 0 \
            and labels.shape[1] > cfg.loss_chunk:
        hidden = forward_hidden(params, batch["tokens"], cfg)
        total = _chunked_ce(hidden, params["lm_head"], labels, mask,
                            cfg.loss_chunk)
        return total / jnp.maximum(mask.sum(), 1.0)
    logits = forward(params, batch["tokens"], cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ------------------------------------------------------------------- decode
def init_cache(cfg: LMConfig, batch: int, max_len: int):
    shape = (cfg.n_layers, batch, max_len, cfg.hkv, cfg.head_dim)
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype),
            "length": jnp.zeros((), jnp.int32)}


def decode_step(params, cache, tokens, cfg: LMConfig):
    """One decode step: tokens (B, 1) + cache -> (logits (B, 1, V), cache).

    The layer scan carries the cache slabs; the KV cache sequence axis is
    what the decode shapes shard over the model axis (see dist.sharding).
    """
    b, t = tokens.shape
    length = cache["length"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t)) + length

    def body(x, scanned):
        layer, ck, cv = scanned
        x, (nk, nv) = _layer_fn(x, layer, cfg, positions,
                                cache=(ck, cv, length))
        return x, (nk, nv)

    if cfg.unroll:
        nk_list, nv_list = [], []
        for i in range(cfg.n_layers):
            layer = jax.tree.map(lambda p: p[i], params["layers"])
            x, (nk, nv) = body(x, (layer, cache["k"][i], cache["v"][i]))
            nk_list.append(nk)
            nv_list.append(nv)
        nks, nvs = jnp.stack(nk_list), jnp.stack(nv_list)
    else:
        x, (nks, nvs) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                               cache["v"]))
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    return logits, {"k": nks, "v": nvs, "length": length + t}
