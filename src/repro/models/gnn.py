"""GNN substrate: GIN, GraphSAGE, SchNet, GraphCast (encoder-processor-decoder).

Message passing is built on the edge-index → ``jax.ops.segment_sum`` scatter
(JAX has no CSR SpMM; this IS the system per the assignment).  A uniform
``GraphBatch`` dict feeds all four architectures:

  node_feat (N, F) · edge_src (E,) · edge_dst (E,) · edge_feat (E, Fe)?
  node_mask (N,)   · graph_ids (N,)?  (batched small graphs)
  labels (N,) / graph_targets (G, ...)

GraphCast uses the extended fields (mesh_feat, g2m_src/dst, m2g_src/dst,
mesh_src/dst) — the grid frontend is a stub per the assignment: input_specs
provide precomputed per-node feature vectors.

All shapes are static; padded edges point at a sink node (index N-1 with
node_mask false) so sampled/ragged batches lower cleanly.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class GNNConfig:
    name: str
    arch: str                       # gin | sage | schnet | graphcast
    n_layers: int
    d_hidden: int
    d_feat: int
    n_classes: int = 16
    aggregator: str = "sum"         # sum | mean
    # gin
    learnable_eps: bool = True
    # schnet
    n_rbf: int = 300
    cutoff: float = 10.0
    # graphcast
    n_vars: int = 227
    mesh_refinement: int = 6
    dtype: Any = jnp.float32


# --------------------------------------------------------------- primitives
def mlp_shapes(dims) -> Dict[str, Tuple[int, ...]]:
    out = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        out[f"w{i}"] = (a, b)
        out[f"b{i}"] = (b,)
    return out


def mlp_apply(p: Dict[str, jax.Array], x: jax.Array, act=jax.nn.relu,
              final_act: bool = False) -> jax.Array:
    n = len([k for k in p if k.startswith("w")])
    for i in range(n):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n - 1 or final_act:
            x = act(x)
    return x


def aggregate(messages: jax.Array, edge_dst: jax.Array, n_nodes: int,
              kind: str) -> jax.Array:
    s = jax.ops.segment_sum(messages, edge_dst, num_segments=n_nodes)
    if kind == "mean":
        deg = jax.ops.segment_sum(jnp.ones_like(edge_dst, messages.dtype),
                                  edge_dst, num_segments=n_nodes)
        s = s / jnp.maximum(deg, 1.0)[:, None]
    return s


# ------------------------------------------------------------------ shapes
def param_shapes(cfg: GNNConfig) -> Dict[str, Any]:
    d, f = cfg.d_hidden, cfg.d_feat
    if cfg.arch == "gin":
        s: Dict[str, Any] = {"proj": mlp_shapes([f, d])}
        for i in range(cfg.n_layers):
            s[f"mlp{i}"] = mlp_shapes([d, d, d])
            s[f"eps{i}"] = (1,)
        s["head"] = mlp_shapes([d, cfg.n_classes])
        return s
    if cfg.arch == "sage":
        s = {"proj": mlp_shapes([f, d])}
        for i in range(cfg.n_layers):
            s[f"self{i}"] = mlp_shapes([d, d])
            s[f"neigh{i}"] = mlp_shapes([d, d])
        s["head"] = mlp_shapes([d, cfg.n_classes])
        return s
    if cfg.arch == "schnet":
        s = {"embed": mlp_shapes([f, d])}
        for i in range(cfg.n_layers):
            s[f"filter{i}"] = mlp_shapes([cfg.n_rbf, d, d])
            s[f"in{i}"] = mlp_shapes([d, d])
            s[f"out{i}"] = mlp_shapes([d, d, d])
        s["head"] = mlp_shapes([d, d // 2, 1])
        return s
    if cfg.arch == "graphcast":
        d_edge = 4                       # stub edge geometry features
        d_mesh = 3                       # stub mesh-node geometry features
        s = {
            "grid_enc": mlp_shapes([cfg.n_vars, d, d]),
            "mesh_enc": mlp_shapes([d_mesh, d, d]),
            "g2m_edge": mlp_shapes([2 * d + d_edge, d, d]),
            "g2m_node": mlp_shapes([2 * d, d, d]),
            "m2g_edge": mlp_shapes([2 * d + d_edge, d, d]),
            "m2g_node": mlp_shapes([2 * d, d, d]),
            "decoder": mlp_shapes([d, d, cfg.n_vars]),
        }
        for i in range(cfg.n_layers):
            s[f"pe{i}"] = mlp_shapes([2 * d + d_edge, d, d])   # edge update
            s[f"pn{i}"] = mlp_shapes([2 * d, d, d])            # node update
        return s
    raise ValueError(cfg.arch)


def abstract_params(cfg: GNNConfig):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s, cfg.dtype),
                        param_shapes(cfg),
                        is_leaf=lambda x: isinstance(x, tuple))


def init_params(cfg: GNNConfig, key: jax.Array):
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree.flatten(shapes,
                                     is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(flat))

    def one(k, s):
        if len(s) == 1:
            return jnp.zeros(s, cfg.dtype)
        return (jax.random.normal(k, s, jnp.float32)
                / np.sqrt(s[0])).astype(cfg.dtype)

    return jax.tree.unflatten(treedef, [one(k, s) for k, s in zip(keys, flat)])


# ----------------------------------------------------------------- forwards
def _readout(h, batch):
    """Graph-level mean pooling when the batch carries graph_ids."""
    n_graphs = (batch["graph_targets"].shape[0] if "graph_targets" in batch
                else batch["graph_labels"].shape[0])
    masked = jnp.where(batch["node_mask"][:, None], h, 0.0)
    s = jax.ops.segment_sum(masked, batch["graph_ids"], num_segments=n_graphs)
    cnt = jax.ops.segment_sum(batch["node_mask"].astype(h.dtype),
                              batch["graph_ids"], num_segments=n_graphs)
    return s / jnp.maximum(cnt, 1.0)[:, None]


def _gin_forward(p, batch, cfg):
    n = batch["node_feat"].shape[0]
    h = mlp_apply(p["proj"], batch["node_feat"].astype(cfg.dtype))
    for i in range(cfg.n_layers):
        msg = h[batch["edge_src"]]
        agg = aggregate(msg, batch["edge_dst"], n, "sum")
        eps = p[f"eps{i}"][0]
        h = mlp_apply(p[f"mlp{i}"], (1.0 + eps) * h + agg, final_act=True)
    if "graph_ids" in batch:
        return mlp_apply(p["head"], _readout(h, batch))
    return mlp_apply(p["head"], h)


def _sage_forward(p, batch, cfg):
    n = batch["node_feat"].shape[0]
    h = mlp_apply(p["proj"], batch["node_feat"].astype(cfg.dtype))
    for i in range(cfg.n_layers):
        msg = h[batch["edge_src"]]
        agg = aggregate(msg, batch["edge_dst"], n, cfg.aggregator)
        h = jax.nn.relu(mlp_apply(p[f"self{i}"], h)
                        + mlp_apply(p[f"neigh{i}"], agg))
        h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)
    if "graph_ids" in batch:
        return mlp_apply(p["head"], _readout(h, batch))
    return mlp_apply(p["head"], h)


def _rbf(dist, cfg):
    centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
    gamma = cfg.n_rbf / cfg.cutoff
    return jnp.exp(-gamma * jnp.square(dist[:, None] - centers[None, :]))


def _schnet_forward(p, batch, cfg):
    n = batch["node_feat"].shape[0]
    h = mlp_apply(p["embed"], batch["node_feat"].astype(cfg.dtype))
    rbf = _rbf(batch["edge_feat"][:, 0].astype(cfg.dtype), cfg)   # distances
    for i in range(cfg.n_layers):
        w = mlp_apply(p[f"filter{i}"], rbf)                 # (E, d) cfconv
        src_h = mlp_apply(p[f"in{i}"], h)[batch["edge_src"]]
        agg = aggregate(src_h * w, batch["edge_dst"], n, "sum")
        h = h + mlp_apply(p[f"out{i}"], agg)
    atom_e = mlp_apply(p["head"], h)                        # (N, 1)
    atom_e = jnp.where(batch["node_mask"][:, None], atom_e, 0.0)
    if "graph_ids" in batch:
        n_graphs = batch["graph_targets"].shape[0]
        return jax.ops.segment_sum(atom_e[:, 0], batch["graph_ids"],
                                   num_segments=n_graphs)
    return atom_e[:, 0]


def _interaction(edge_p, node_p, h, src, dst, efeat, n, cfg):
    e_in = jnp.concatenate([h[src], h[dst], efeat], -1)
    m = mlp_apply(edge_p, e_in)
    agg = aggregate(m, dst, n, "sum")
    return h + mlp_apply(node_p, jnp.concatenate([h, agg], -1))


def _graphcast_forward(p, batch, cfg):
    ng = batch["node_feat"].shape[0]                        # grid nodes
    nm = batch["mesh_feat"].shape[0]                        # mesh nodes
    hg = mlp_apply(p["grid_enc"], batch["node_feat"].astype(cfg.dtype))
    hm = mlp_apply(p["mesh_enc"], batch["mesh_feat"].astype(cfg.dtype))
    # encode: grid -> mesh
    e_in = jnp.concatenate([hg[batch["g2m_src"]], hm[batch["g2m_dst"]],
                            batch["g2m_feat"].astype(cfg.dtype)], -1)
    m = mlp_apply(p["g2m_edge"], e_in)
    agg = aggregate(m, batch["g2m_dst"], nm, "sum")
    hm = hm + mlp_apply(p["g2m_node"], jnp.concatenate([hm, agg], -1))
    # process: message passing on the (multi-)mesh
    for i in range(cfg.n_layers):
        hm = _interaction(p[f"pe{i}"], p[f"pn{i}"], hm, batch["mesh_src"],
                          batch["mesh_dst"], batch["mesh_efeat"].astype(cfg.dtype),
                          nm, cfg)
    # decode: mesh -> grid
    e_in = jnp.concatenate([hm[batch["m2g_src"]], hg[batch["m2g_dst"]],
                            batch["m2g_feat"].astype(cfg.dtype)], -1)
    m = mlp_apply(p["m2g_edge"], e_in)
    agg = aggregate(m, batch["m2g_dst"], ng, "sum")
    hg = hg + agg
    return mlp_apply(p["decoder"], hg)                      # (Ng, n_vars)


FORWARDS = {"gin": _gin_forward, "sage": _sage_forward,
            "schnet": _schnet_forward, "graphcast": _graphcast_forward}


def forward(params, batch, cfg: GNNConfig):
    return FORWARDS[cfg.arch](params, batch, cfg)


def loss_fn(params, batch, cfg: GNNConfig):
    out = forward(params, batch, cfg)
    if cfg.arch == "schnet":
        if "graph_targets" in batch:
            return jnp.mean(jnp.square(out - batch["graph_targets"]))
        mask = batch["node_mask"]
        return jnp.sum(jnp.square(out) * mask) / jnp.maximum(mask.sum(), 1)
    if cfg.arch == "graphcast":
        err = jnp.square(out - batch["labels"].astype(out.dtype))
        mask = batch["node_mask"][:, None]
        return jnp.sum(err * mask) / jnp.maximum(mask.sum() * out.shape[-1], 1)
    if "graph_ids" in batch:
        # graph classification (molecule shape on gin/sage)
        logp = jax.nn.log_softmax(out.astype(jnp.float32), -1)
        labels = batch["graph_labels"]
        nll = -jnp.take_along_axis(logp, labels[:, None], -1)[:, 0]
        return nll.mean()
    # node classification
    logp = jax.nn.log_softmax(out.astype(jnp.float32), -1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], -1)[:, 0]
    mask = batch["node_mask"].astype(jnp.float32)
    if "train_mask" in batch:
        mask = mask * batch["train_mask"].astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
