"""Architecture config framework: one object per assigned architecture.

Every arch exposes:

* ``shapes``         — the assigned (shape_name → params) cells;
* ``smoke_*``        — a REDUCED same-family config + one real forward/train
                       step on CPU (used by tests/models/test_smoke.py);
* ``build_dryrun``   — (step_fn, abstract inputs, in_shardings) for a given
                       (shape, mesh): the allocation-free lowering unit of
                       the multi-pod dry-run;
* ``model_flops``    — the analytic MODEL_FLOPS for §Roofline
                       (6·N·D dense / 6·N_active·D MoE; per-family formulas
                       for GNN/recsys).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..dist import sharding as shd
from ..models import gnn as gnn_mod
from ..models import recsys as din_mod
from ..models import transformer as tf_mod
from ..train import optimizer as opt_mod

OPT = opt_mod.AdamWConfig()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _pad512(n: int) -> int:
    """Pad a node/edge count up to a multiple of 512 so the leading dim
    shards evenly on every production mesh (the real loaders pad batches
    the same way; masks neutralize the padding)."""
    return int(-(-n // 512) * 512)


def _shardings(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


@dataclass
class DryRunUnit:
    """Everything jax.jit(...).lower(...) needs for one cell."""
    name: str
    step_fn: Callable
    args: Tuple[Any, ...]              # abstract ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any = None
    donate: Tuple[int, ...] = ()


class ArchConfig:
    arch_id: str = ""
    family: str = ""
    shapes: Dict[str, Dict[str, Any]] = {}

    def build_dryrun(self, shape: str, mesh: Mesh) -> DryRunUnit:
        raise NotImplementedError

    def smoke(self, seed: int = 0) -> Dict[str, Any]:
        """Run one reduced forward/train step; return metrics for asserts."""
        raise NotImplementedError

    def model_flops(self, shape: str) -> float:
        raise NotImplementedError


# ===================================================================== LM ===
LM_SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


class LMArch(ArchConfig):
    family = "lm"
    shapes = LM_SHAPES

    def __init__(self, cfg: tf_mod.LMConfig):
        self.cfg = cfg
        self.arch_id = cfg.name

    # ------------------------------------------------------------ smoke
    def smoke_config(self) -> tf_mod.LMConfig:
        c = self.cfg
        return dataclasses.replace(
            c, n_layers=2, d_model=64, n_heads=4, n_kv=max(1, min(c.n_kv, 2)),
            d_ff=128, vocab=256, d_head=16,
            n_experts=min(c.n_experts, 4), top_k=min(c.top_k, 2),
            n_shared=min(c.n_shared, 1),
            d_ff_expert=64 if c.n_experts else 0,
            dtype=jnp.float32, pad_heads_to=0, pad_kv_to=0)

    def smoke(self, seed: int = 0) -> Dict[str, Any]:
        cfg = self.smoke_config()
        params = tf_mod.init_params(cfg, jax.random.key(seed))
        rng = np.random.default_rng(seed)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                       jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                       jnp.int32)}
        loss, grads = jax.value_and_grad(tf_mod.loss_fn)(params, batch, cfg)
        opt = opt_mod.init_state(params)
        params2, opt2, m = opt_mod.apply_updates(params, grads, opt, OPT)
        logits = tf_mod.forward(params, batch["tokens"], cfg)
        # decode one step
        cache = tf_mod.init_cache(cfg, 2, 32)
        dec_logits, cache = tf_mod.decode_step(params, cache,
                                               batch["tokens"][:, :1], cfg)
        return {"loss": float(loss), "logits_shape": tuple(logits.shape),
                "decode_shape": tuple(dec_logits.shape),
                "grad_norm": float(m["grad_norm"]),
                "finite": bool(jnp.isfinite(loss))
                and all(bool(jnp.isfinite(g).all())
                        for g in jax.tree.leaves(grads))}

    # ----------------------------------------------------------- dry-run
    def _mesh_cfg(self, mesh: Mesh) -> tf_mod.LMConfig:
        tp = mesh.shape["model"]
        c = self.cfg
        hq = int(math.ceil(c.n_heads / tp) * tp)
        hkv = c.n_kv
        if hq % hkv:                     # keep GQA grouping integral
            hkv = next(d for d in range(hkv, hq + 1) if hq % d == 0)
        return dataclasses.replace(c, pad_heads_to=hq, pad_kv_to=hkv)

    def build_dryrun(self, shape: str, mesh: Mesh, *,
                     layers_override: Optional[int] = None,
                     unroll: bool = False,
                     variant: str = "baseline") -> DryRunUnit:
        """``layers_override``+``unroll`` are the dry-run *calibration* mode:
        HLO cost analysis counts a scan body once, so launch/dryrun.py lowers
        unrolled L=2 and L=4 variants and extrapolates per-layer costs to the
        true depth (exact — layers are homogeneous).

        ``variant`` selects §Perf configurations:
          * ``flash``           — chunked online-softmax attention
          * ``flash+chunkloss`` — + streamed lm_head cross-entropy
        """
        sp = self.shapes[shape]
        cfg = self._mesh_cfg(mesh)
        if variant.startswith("flash"):
            cfg = dataclasses.replace(cfg, flash_attention=True)
        if "chunkloss" in variant:
            cfg = dataclasses.replace(cfg, chunked_loss=True, loss_chunk=512)
        if "wsc" in variant:
            cfg = dataclasses.replace(
                cfg, shard_activations=shd.batch_axes(mesh))
        if layers_override is not None:
            cfg = dataclasses.replace(cfg, n_layers=layers_override,
                                      unroll=unroll)
        pspecs = shd.lm_param_specs(cfg, mesh)
        # kv projections: shard out-dim only when the padded kv head count
        # divides the TP degree (else replicate — GQA kv is small)
        tp = mesh.shape["model"]
        if cfg.hkv % tp:
            pspecs["layers"]["wk"] = P(None, "data", None)
            pspecs["layers"]["wv"] = P(None, "data", None)
            if cfg.qkv_bias:
                pspecs["layers"]["bk"] = P(None, None)
                pspecs["layers"]["bv"] = P(None, None)
        params = tf_mod.abstract_params(cfg)
        b = sp["global_batch"]

        if sp["kind"] == "train":
            opt_abs = {
                "step": _sds((), jnp.int32),
                "m": jax.tree.map(lambda p: _sds(p.shape, jnp.float32), params),
                "v": jax.tree.map(lambda p: _sds(p.shape, jnp.float32), params),
            }
            opt_specs = {"step": P(),
                         "m": pspecs, "v": pspecs}
            batch = {"tokens": _sds((b, sp["seq_len"]), jnp.int32),
                     "labels": _sds((b, sp["seq_len"]), jnp.int32)}
            bspecs = shd.lm_batch_specs(mesh)

            def train_step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(tf_mod.loss_fn)(
                    params, batch, cfg)
                params, opt_state, metrics = opt_mod.apply_updates(
                    params, grads, opt_state, OPT)
                return params, opt_state, loss

            return DryRunUnit(
                name=f"{self.arch_id}:{shape}", step_fn=train_step,
                args=(params, opt_abs, batch),
                in_shardings=(_shardings(mesh, pspecs),
                              _shardings(mesh, opt_specs),
                              _shardings(mesh, bspecs)),
                donate=(0, 1))

        if sp["kind"] == "prefill":
            batch = {"tokens": _sds((b, sp["seq_len"]), jnp.int32)}
            bspecs = {"tokens": P(shd.batch_axes(mesh), None)}

            def prefill_step(params, batch):
                logits = tf_mod.forward(params, batch["tokens"], cfg)
                return logits[:, -1, :]        # serving returns last-token

            return DryRunUnit(
                name=f"{self.arch_id}:{shape}", step_fn=prefill_step,
                args=(params, batch),
                in_shardings=(_shardings(mesh, pspecs),
                              _shardings(mesh, bspecs)))

        # decode: one new token against a seq_len KV cache
        cache = {
            "k": _sds((cfg.n_layers, b, sp["seq_len"], cfg.hkv, cfg.head_dim),
                      cfg.dtype),
            "v": _sds((cfg.n_layers, b, sp["seq_len"], cfg.hkv, cfg.head_dim),
                      cfg.dtype),
            "length": _sds((), jnp.int32),
        }
        baxes = shd.batch_axes(mesh)
        n_data = int(np.prod([mesh.shape[a] for a in baxes]))
        kv_heads_ax = "model" if cfg.hkv % tp == 0 else None
        if b % n_data == 0 and b >= n_data:
            cspec = P(None, baxes, None if kv_heads_ax else "model",
                      kv_heads_ax, None)
        else:
            # small-batch long-context: shard the KV sequence axis instead
            cspec = P(None, None, baxes + (("model",) if not kv_heads_ax
                                           else ()), kv_heads_ax, None)
        cache_specs = {"k": cspec, "v": cspec, "length": P()}
        tok_spec = {"tokens": P(baxes if b % n_data == 0 and b >= n_data
                                else None, None)}
        tokens = {"tokens": _sds((b, 1), jnp.int32)}

        def decode(params, cache, batch):
            logits, cache = tf_mod.decode_step(params, cache,
                                               batch["tokens"], cfg)
            return logits, cache

        return DryRunUnit(
            name=f"{self.arch_id}:{shape}", step_fn=decode,
            args=(params, cache, tokens),
            in_shardings=(_shardings(mesh, pspecs),
                          _shardings(mesh, cache_specs),
                          _shardings(mesh, tok_spec)),
            donate=(1,))

    def model_flops(self, shape: str) -> float:
        sp = self.shapes[shape]
        n_active = self.cfg.n_active_params()
        if sp["kind"] == "train":
            tokens = sp["seq_len"] * sp["global_batch"]
            return 6.0 * n_active * tokens
        if sp["kind"] == "prefill":
            tokens = sp["seq_len"] * sp["global_batch"]
            return 2.0 * n_active * tokens
        # decode: one token per sequence + attention over the cache
        c = self.cfg
        attn = (2 * 2 * c.n_layers * sp["seq_len"] * c.n_kv * c.head_dim
                * (c.n_heads // c.n_kv))
        return sp["global_batch"] * (2.0 * n_active + attn)


# ==================================================================== GNN ===
GNN_SHAPES = {
    "full_graph_sm": dict(kind="train", n_nodes=2708, n_edges=10556,
                          d_feat=1433),
    "minibatch_lg": dict(kind="train_sampled", n_nodes=232965,
                         n_edges=114615892, batch_nodes=1024,
                         fanout=(15, 10), d_feat=602),
    "ogb_products": dict(kind="train", n_nodes=2449029, n_edges=61859140,
                         d_feat=100),
    "molecule": dict(kind="train_batched", n_nodes=30, n_edges=64, batch=128,
                     d_feat=16),
}


class GNNArch(ArchConfig):
    family = "gnn"
    shapes = GNN_SHAPES

    def __init__(self, cfg: gnn_mod.GNNConfig):
        self.cfg = cfg
        self.arch_id = cfg.name

    def _shape_cfg(self, sp) -> gnn_mod.GNNConfig:
        return dataclasses.replace(self.cfg, d_feat=sp["d_feat"])

    def smoke_config(self) -> gnn_mod.GNNConfig:
        return dataclasses.replace(self.cfg, n_layers=2, d_hidden=16,
                                   d_feat=8, n_rbf=16, n_vars=6, n_classes=4)

    def smoke(self, seed: int = 0) -> Dict[str, Any]:
        from ..data import gnn_data
        cfg = self.smoke_config()
        params = gnn_mod.init_params(cfg, jax.random.key(seed))
        if cfg.arch == "schnet":
            batch = gnn_data.schnet_batch(10, 24, cfg.d_feat, batch=3,
                                          seed=seed)
        elif cfg.arch == "graphcast":
            batch = gnn_data.graphcast_batch(24, 8, cfg.n_vars, 32, 24, 24,
                                             seed=seed)
        else:
            batch = gnn_data.full_graph_batch(24, 60, cfg.d_feat,
                                              cfg.n_classes, seed=seed)
        batch = jax.tree.map(jnp.asarray, batch)
        loss, grads = jax.value_and_grad(gnn_mod.loss_fn)(params, batch, cfg)
        out = gnn_mod.forward(params, batch, cfg)
        return {"loss": float(loss), "out_shape": tuple(np.shape(out)),
                "finite": bool(jnp.isfinite(loss))
                and all(bool(jnp.isfinite(g).all())
                        for g in jax.tree.leaves(grads))}

    def _abstract_batch(self, shape: str):
        sp = self.shapes[shape]
        cfg = self._shape_cfg(sp)
        f32, i32 = jnp.float32, jnp.int32
        if self.cfg.arch == "graphcast":
            ng = sp.get("n_nodes", 1024)
            if sp["kind"] == "train_batched":
                ng = sp["n_nodes"] * sp["batch"]
            ne = sp["n_edges"] * sp.get("batch", 1)
            if sp["kind"] == "train_sampled":
                ng, ne = 166_000, 166_000
            ng, ne = _pad512(ng), _pad512(ne)
            nm = _pad512(max(ng // 4, 512))
            return {
                "node_feat": _sds((ng, cfg.n_vars), f32),
                "mesh_feat": _sds((nm, 3), f32),
                "g2m_src": _sds((ne,), i32), "g2m_dst": _sds((ne,), i32),
                "g2m_feat": _sds((ne, 4), f32),
                "mesh_src": _sds((ne,), i32), "mesh_dst": _sds((ne,), i32),
                "mesh_efeat": _sds((ne, 4), f32),
                "m2g_src": _sds((ne,), i32), "m2g_dst": _sds((ne,), i32),
                "m2g_feat": _sds((ne, 4), f32),
                "node_mask": _sds((ng,), jnp.bool_),
                "labels": _sds((ng, cfg.n_vars), f32),
            }, cfg
        if sp["kind"] == "train_batched":      # molecule
            n = _pad512(sp["n_nodes"] * sp["batch"])
            e = _pad512(sp["n_edges"] * sp["batch"])
            batch = {
                "node_feat": _sds((n, sp["d_feat"]), f32),
                "edge_src": _sds((e,), i32), "edge_dst": _sds((e,), i32),
                "node_mask": _sds((n,), jnp.bool_),
                "graph_ids": _sds((n,), i32),
            }
            if self.cfg.arch == "schnet":
                batch["edge_feat"] = _sds((e, 1), f32)
                batch["graph_targets"] = _sds((sp["batch"],), f32)
            else:
                batch["graph_labels"] = _sds((sp["batch"],), i32)
            return batch, cfg
        if sp["kind"] == "train_sampled":
            pad_nodes = _pad512(sp["batch_nodes"]
                                * (1 + sp["fanout"][0]
                                   + sp["fanout"][0] * sp["fanout"][1]))
            pad_edges = pad_nodes
            batch = {
                "node_feat": _sds((pad_nodes, sp["d_feat"]), f32),
                "edge_src": _sds((pad_edges,), i32),
                "edge_dst": _sds((pad_edges,), i32),
                "labels": _sds((pad_nodes,), i32),
                "node_mask": _sds((pad_nodes,), jnp.bool_),
                "train_mask": _sds((pad_nodes,), jnp.bool_),
            }
            if self.cfg.arch == "schnet":
                batch["edge_feat"] = _sds((pad_edges, 1), f32)
                batch.pop("labels")
            return batch, cfg
        # full graph
        n, e = _pad512(sp["n_nodes"]), _pad512(sp["n_edges"])
        batch = {
            "node_feat": _sds((n, sp["d_feat"]), f32),
            "edge_src": _sds((e,), i32), "edge_dst": _sds((e,), i32),
            "labels": _sds((n,), i32),
            "node_mask": _sds((n,), jnp.bool_),
            "train_mask": _sds((n,), jnp.bool_),
        }
        if self.cfg.arch == "schnet":
            batch["edge_feat"] = _sds((e, 1), f32)
            batch.pop("labels")
        return batch, cfg

    def build_dryrun(self, shape: str, mesh: Mesh, *,
                     variant: str = "baseline") -> DryRunUnit:
        batch, cfg = self._abstract_batch(shape)
        if variant == "shardmap" and cfg.arch in ("gin", "sage"):
            return self._build_shardmap(shape, mesh, batch, cfg)
        params = gnn_mod.abstract_params(cfg)
        pspecs = shd.gnn_param_specs(cfg, mesh)
        bspecs = shd.gnn_batch_specs(mesh, batch)
        opt_abs = {
            "step": _sds((), jnp.int32),
            "m": jax.tree.map(lambda p: _sds(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: _sds(p.shape, jnp.float32), params),
        }
        opt_specs = {"step": P(), "m": pspecs, "v": pspecs}

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(gnn_mod.loss_fn)(
                params, batch, cfg)
            params, opt_state, _ = opt_mod.apply_updates(
                params, grads, opt_state, OPT)
            return params, opt_state, loss

        return DryRunUnit(
            name=f"{self.arch_id}:{shape}", step_fn=train_step,
            args=(params, opt_abs, batch),
            in_shardings=(_shardings(mesh, pspecs),
                          _shardings(mesh, opt_specs),
                          _shardings(mesh, bspecs)),
            donate=(0, 1))

    def _build_shardmap(self, shape: str, mesh: Mesh, batch, cfg) -> DryRunUnit:
        """§Perf variant: explicit shard_map message passing with
        dst-partitioned edges (see models.gnn_dist)."""
        from ..models import gnn_dist
        step, bspec_tree = gnn_dist.sharded_train_step(cfg, mesh, OPT)
        batch = {k: v for k, v in batch.items() if k in bspec_tree}
        params = gnn_mod.abstract_params(cfg)
        pspecs = shd.gnn_param_specs(cfg, mesh)
        opt_abs = {
            "step": _sds((), jnp.int32),
            "m": jax.tree.map(lambda p: _sds(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: _sds(p.shape, jnp.float32), params),
        }
        opt_specs = {"step": P(), "m": pspecs, "v": pspecs}
        bspecs = {k: bspec_tree[k] for k in batch}
        return DryRunUnit(
            name=f"{self.arch_id}:{shape}:shardmap", step_fn=step,
            args=(params, opt_abs, batch),
            in_shardings=(_shardings(mesh, pspecs),
                          _shardings(mesh, opt_specs),
                          _shardings(mesh, bspecs)),
            donate=(0, 1))

    def model_flops(self, shape: str) -> float:
        batch, cfg = self._abstract_batch(shape)
        d = cfg.d_hidden
        if cfg.arch == "graphcast":
            ne = batch["mesh_src"].shape[0]
            ng = batch["node_feat"].shape[0]
            per_edge = 2 * (2 * d + 4) * d + 2 * d * d
            per_node = 2 * (2 * d) * d + 2 * d * d
            fwd = cfg.n_layers * (ne * per_edge
                                  + batch["mesh_feat"].shape[0] * per_node) \
                + ng * 2 * cfg.n_vars * d * 2
            return 3 * fwd
        n = batch["node_feat"].shape[0]
        e = batch["edge_src"].shape[0]
        per_layer = n * (2 * d * d * 2) + e * d * 2
        if cfg.arch == "schnet":
            per_layer += e * (2 * cfg.n_rbf * d + 2 * d * d)
        fwd = cfg.n_layers * per_layer \
            + n * 2 * batch["node_feat"].shape[1] * d
        return 3 * fwd                       # fwd + bwd ≈ 3x fwd


# ================================================================= recsys ===
DIN_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}


class DINArch(ArchConfig):
    family = "recsys"
    shapes = DIN_SHAPES

    def __init__(self, cfg: din_mod.DINConfig):
        self.cfg = cfg
        self.arch_id = cfg.name

    def smoke_config(self) -> din_mod.DINConfig:
        return dataclasses.replace(self.cfg, n_items=1000, n_cates=50,
                                   seq_len=12, user_feat_vocab=40)

    def smoke(self, seed: int = 0) -> Dict[str, Any]:
        from ..data import recsys_data
        cfg = self.smoke_config()
        params = din_mod.init_params(cfg, jax.random.key(seed))
        batch = jax.tree.map(jnp.asarray, recsys_data.din_batch(
            8, cfg.seq_len, cfg.n_items, cfg.n_cates, cfg.n_user_feats,
            cfg.user_feat_vocab, step=0, seed=seed))
        loss, grads = jax.value_and_grad(din_mod.loss_fn)(params, batch, cfg)
        rb = jax.tree.map(jnp.asarray, recsys_data.retrieval_batch(
            cfg.seq_len, cfg.n_items, cfg.n_cates, cfg.n_user_feats,
            cfg.user_feat_vocab, n_candidates=64, seed=seed))
        scores = din_mod.retrieval_scores(params, rb, cfg)
        return {"loss": float(loss), "scores_shape": tuple(scores.shape),
                "finite": bool(jnp.isfinite(loss))
                and bool(jnp.isfinite(scores).all())
                and all(bool(jnp.isfinite(g).all())
                        for g in jax.tree.leaves(grads))}

    def _abstract_batch(self, shape: str):
        sp = self.shapes[shape]
        cfg = self.cfg
        i32, f32 = jnp.int32, jnp.float32
        if sp["kind"] == "retrieval":
            c = sp["n_candidates"]
            return {
                "hist_items": _sds((1, cfg.seq_len), i32),
                "hist_cates": _sds((1, cfg.seq_len), i32),
                "hist_mask": _sds((1, cfg.seq_len), f32),
                "user_feats": _sds((1, cfg.n_user_feats), i32),
                "cand_items": _sds((c,), i32),
                "cand_cates": _sds((c,), i32),
            }
        b = sp["batch"]
        batch = {
            "item_id": _sds((b,), i32), "cate_id": _sds((b,), i32),
            "hist_items": _sds((b, cfg.seq_len), i32),
            "hist_cates": _sds((b, cfg.seq_len), i32),
            "hist_mask": _sds((b, cfg.seq_len), f32),
            "user_feats": _sds((b, cfg.n_user_feats), i32),
        }
        if sp["kind"] == "train":
            batch["label"] = _sds((b,), f32)
        return batch

    def build_dryrun(self, shape: str, mesh: Mesh) -> DryRunUnit:
        sp = self.shapes[shape]
        cfg = self.cfg
        params = din_mod.abstract_params(cfg)
        pspecs = shd.din_param_specs(cfg, mesh)
        batch = self._abstract_batch(shape)
        bspecs = shd.din_batch_specs(mesh, batch)
        if sp["kind"] == "retrieval":
            # candidates shard over the batch axes (10⁶ is not divisible by
            # 512; 16/32-way splits evenly); the single user replicates
            baxes = shd.batch_axes(mesh)
            bspecs = {k: (P(baxes) if k.startswith("cand_")
                          else P(*([None] * len(v.shape))))
                      for k, v in batch.items()}

            def retrieval(params, batch):
                return din_mod.retrieval_scores(params, batch, cfg)

            return DryRunUnit(
                name=f"{self.arch_id}:{shape}", step_fn=retrieval,
                args=(params, batch),
                in_shardings=(_shardings(mesh, pspecs),
                              _shardings(mesh, bspecs)))
        if sp["kind"] == "serve":
            def serve(params, batch):
                return din_mod.forward(params, batch, cfg)
            return DryRunUnit(
                name=f"{self.arch_id}:{shape}", step_fn=serve,
                args=(params, batch),
                in_shardings=(_shardings(mesh, pspecs),
                              _shardings(mesh, bspecs)))
        opt_abs = {
            "step": _sds((), jnp.int32),
            "m": jax.tree.map(lambda p: _sds(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: _sds(p.shape, jnp.float32), params),
        }
        opt_specs = {"step": P(), "m": pspecs, "v": pspecs}

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(din_mod.loss_fn)(
                params, batch, cfg)
            params, opt_state, _ = opt_mod.apply_updates(
                params, grads, opt_state, OPT)
            return params, opt_state, loss

        return DryRunUnit(
            name=f"{self.arch_id}:{shape}", step_fn=train_step,
            args=(params, opt_abs, batch),
            in_shardings=(_shardings(mesh, pspecs),
                          _shardings(mesh, opt_specs),
                          _shardings(mesh, bspecs)),
            donate=(0, 1))

    def model_flops(self, shape: str) -> float:
        sp = self.shapes[shape]
        cfg = self.cfg
        d = cfg.embed_dim
        pair = 2 * d
        attn_in = 4 * pair
        attn = attn_in * cfg.attn_mlp[0] + cfg.attn_mlp[0] * cfg.attn_mlp[1] \
            + cfg.attn_mlp[1]
        d_in = cfg.n_user_feats * d + 3 * pair
        mlp = d_in * cfg.mlp[0] + cfg.mlp[0] * cfg.mlp[1] + cfg.mlp[1]
        per_ex = 2 * (cfg.seq_len * attn + mlp)
        if sp["kind"] == "retrieval":
            return sp["n_candidates"] * per_ex
        mult = 3 if sp["kind"] == "train" else 1
        return mult * sp["batch"] * per_ex
