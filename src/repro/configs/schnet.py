"""schnet: continuous-filter convolutions, 3 interactions, 300 RBF,
cutoff 10 Å [arXiv:1706.08566].  Geometry (edge distances) comes from the
input pipeline (neighbor-list stub)."""
from ..models.gnn import GNNConfig
from .base import GNNArch

CONFIG = GNNArch(GNNConfig(
    name="schnet", arch="schnet", n_layers=3, d_hidden=64, d_feat=16,
    n_rbf=300, cutoff=10.0, aggregator="sum",
))
