"""grok-1-314b: MoE LM, 8 experts top-2, GQA kv=8 [hf:xai-org/grok-1]."""
import jax.numpy as jnp

from ..models.transformer import LMConfig
from .base import LMArch

CONFIG = LMArch(LMConfig(
    name="grok-1-314b", n_layers=64, d_model=6144, n_heads=48, n_kv=8,
    d_ff=32768, vocab=131072, d_head=128, qkv_bias=False,
    n_experts=8, top_k=2, n_shared=0, d_ff_expert=32768,
    dtype=jnp.bfloat16,
))
