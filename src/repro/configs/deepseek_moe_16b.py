"""deepseek-moe-16b: fine-grained MoE — 2 shared + 64 routed top-6
[arXiv:2401.06066]."""
import jax.numpy as jnp

from ..models.transformer import LMConfig
from .base import LMArch

CONFIG = LMArch(LMConfig(
    name="deepseek-moe-16b", n_layers=28, d_model=2048, n_heads=16, n_kv=16,
    d_ff=1408, vocab=102400, d_head=128, qkv_bias=False,
    n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408,
    dtype=jnp.bfloat16,
))
