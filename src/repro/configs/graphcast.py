"""graphcast: encoder-processor-decoder mesh GNN, 16 layers, d=512,
n_vars=227 [arXiv:2212.12794].  Grid frontend is a stub per assignment —
input_specs supply precomputed per-node feature vectors."""
from ..models.gnn import GNNConfig
from .base import GNNArch

CONFIG = GNNArch(GNNConfig(
    name="graphcast", arch="graphcast", n_layers=16, d_hidden=512,
    d_feat=227, n_vars=227, mesh_refinement=6, aggregator="sum",
))
