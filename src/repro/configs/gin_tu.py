"""gin-tu: Graph Isomorphism Network, 5 layers, sum aggregator, learnable
eps [arXiv:1810.00826]."""
from ..models.gnn import GNNConfig
from .base import GNNArch

CONFIG = GNNArch(GNNConfig(
    name="gin-tu", arch="gin", n_layers=5, d_hidden=64, d_feat=1433,
    aggregator="sum", learnable_eps=True,
))
