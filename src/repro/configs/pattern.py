"""The paper's own workload as a first-class architecture: ``rig_gm``.

Shapes (beyond the 40 assigned cells — these are the paper-technique cells):

* serve_1m   — gm_serve_step: batch of 32 hybrid queries against a 2²⁰-node
               packed graph (double simulation ×4 + RIG stats + candidate
               compaction) on the full mesh;
* serve_4m   — same with a 2²² graph (512 GB packed — 1 GB/chip, stresses
               the memory term);
* closure_256k — one distributed boolean-squaring round of the reachability
               index build at 2¹⁸ nodes (compute-term stress; the production
               closure build runs ~log₂(diameter) of these offline);
* sim_pass_1m — a single isolated simulation pass (the §Perf iteration unit).
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..jaxgm import distributed as dist
from ..jaxgm.encoding import QueryTensor
from ..kernels import packed
from .base import ArchConfig, DryRunUnit, _sds

MAX_Q, MAX_E = 8, 16


def _qt_specs(batch: int):
    i32 = jnp.int32
    return QueryTensor(
        labels=_sds((batch, MAX_Q), i32),
        edge_src=_sds((batch, MAX_E), i32),
        edge_dst=_sds((batch, MAX_E), i32),
        edge_kind=_sds((batch, MAX_E), i32),
        n_nodes=_sds((batch,), i32),
        n_edges=_sds((batch,), i32),
    )


class PatternArch(ArchConfig):
    family = "pattern"
    arch_id = "rig_gm"
    shapes = {
        "serve_1m": dict(kind="serve", n_pad=1 << 20, batch=32, passes=4),
        "serve_4m": dict(kind="serve", n_pad=1 << 22, batch=32, passes=4),
        "closure_256k": dict(kind="closure", n_pad=1 << 18),
        "sim_pass_1m": dict(kind="sim", n_pad=1 << 20, batch=32),
    }

    def smoke(self, seed: int = 0) -> Dict[str, Any]:
        # the jaxgm test-suite covers this path exhaustively; the smoke here
        # just runs the full pipeline on a tiny graph
        from ..data.graphs import random_labeled_graph
        from ..data.queries import random_query_from_graph
        from ..jaxgm import JaxGM
        from ..core import match
        g = random_labeled_graph(60, avg_degree=2.2, n_labels=4, seed=seed)
        q = random_query_from_graph(g, 4, qtype="H", seed=seed + 1)
        jgm = JaxGM(g, block=128, capacity=4096, exact_sim=True,
                    impl="reference")
        dev = jgm.match(q)
        host = match(g, q, limit=None)
        return {"count": dev.count, "host_count": host.count,
                "finite": dev.count == host.count and not dev.overflowed}

    def build_dryrun(self, shape: str, mesh: Mesh, *,
                     variant: str = "baseline",
                     unroll: bool = False) -> DryRunUnit:
        """variants (§Perf): ``packy`` — bit-pack Y before its all-gather;
        ``b128`` — 4× query batch (amortizes matrix reads per query);
        ``bk1024`` — smaller unpack chunks; ``best`` — packy+b128.

        ``unroll=False`` (default) is the deployable artifact: the blocked
        matmul scans its chunks, so XLA reuses one chunk's unpack buffers
        (§Perf H9 — the unrolled form peaks at 39-105 GB of live unpack
        temporaries).  ``unroll=True`` is the cost-calibration lowering
        (HLO cost analysis counts scan bodies once)."""
        sp = dict(self.shapes[shape])
        if variant in ("b128", "best"):
            sp["batch"] = 128
        pack_y = variant in ("packy", "best")
        block_k = 1024 if variant in ("bk1024", "best") else 4096
        n_pad = sp["n_pad"]
        w = n_pad // 32
        row_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

        if sp["kind"] == "closure":
            # one distributed squaring round R' = R | (R·R > 0), packed in/out.
            # XLA auto-partitions the (N, N) boolean intermediate.
            r = _sds((n_pad, w), jnp.uint32)
            rspec = NamedSharding(mesh, P(row_axes, "model"))

            def closure_round(r_words):
                dense = packed.unpack(r_words, n_pad)
                sq = (dense.astype(jnp.bfloat16) @ dense.astype(jnp.bfloat16)
                      ).astype(jnp.float32) > 0
                return packed.pack(sq | dense)

            return DryRunUnit(name=f"{self.arch_id}:{shape}",
                              step_fn=closure_round, args=(r,),
                              in_shardings=(rspec,))

        specs = dist.graph_specs(n_pad, mesh)
        qts = _qt_specs(sp["batch"])
        qt_shardings = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), qts)

        if sp["kind"] == "sim":
            def sim_pass(mats, labels, qts):
                return dist.sharded_double_simulation(
                    mats, labels, qts, mesh, n_passes=1, unroll=unroll,
                    pack_y=pack_y, block_k=block_k)
        else:
            def sim_pass(mats, labels, qts):
                return dist.gm_serve_step(mats, labels, qts, mesh,
                                          n_passes=sp["passes"], top_k=4096,
                                          unroll=unroll, pack_y=pack_y,
                                          block_k=block_k)

        return DryRunUnit(
            name=f"{self.arch_id}:{shape}", step_fn=sim_pass,
            args=(specs.mats, specs.labels, qts),
            in_shardings=(specs.mats_sharding, specs.labels_sharding,
                          qt_shardings))

    def model_flops(self, shape: str) -> float:
        sp = self.shapes[shape]
        n = sp["n_pad"]
        if sp["kind"] == "closure":
            return 2.0 * n * n * n
        passes = sp.get("passes", 1)
        b = sp["batch"]
        # 4 boolean matmuls (N × N × B·max_q) per pass (+1 stats pass)
        per_pass = 4 * 2.0 * n * n * (b * MAX_Q)
        extra = 2 * 2.0 * n * n * (b * MAX_Q) if sp["kind"] == "serve" else 0
        return passes * per_pass + extra
