"""qwen2-7b: dense LM, GQA kv=4, QKV bias [arXiv:2407.10671]."""
import jax.numpy as jnp

from ..models.transformer import LMConfig
from .base import LMArch

CONFIG = LMArch(LMConfig(
    name="qwen2-7b", n_layers=28, d_model=3584, n_heads=28, n_kv=4,
    d_ff=18944, vocab=152064, d_head=128, qkv_bias=True,
    dtype=jnp.bfloat16,
))
