"""graphsage-reddit: 2 layers, mean aggregator, fanout 25-10
[arXiv:1706.02216].  minibatch_lg exercises the real neighbour sampler
(repro.data.sampler)."""
from ..models.gnn import GNNConfig
from .base import GNNArch

CONFIG = GNNArch(GNNConfig(
    name="graphsage-reddit", arch="sage", n_layers=2, d_hidden=128,
    d_feat=602, aggregator="mean",
))
