"""Architecture registry: ``get_config(arch_id)`` / ``all_arch_ids()``.

The ten assigned architectures plus the paper's own workload (rig_gm).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

_MODULES: Dict[str, str] = {
    # LM family
    "yi-34b": ".yi_34b",
    "qwen1.5-4b": ".qwen1_5_4b",
    "qwen2-7b": ".qwen2_7b",
    "grok-1-314b": ".grok_1_314b",
    "deepseek-moe-16b": ".deepseek_moe_16b",
    # GNN family
    "gin-tu": ".gin_tu",
    "graphcast": ".graphcast",
    "schnet": ".schnet",
    "graphsage-reddit": ".graphsage_reddit",
    # recsys
    "din": ".din",
    # the paper's workload
    "rig_gm": ".pattern",
}


def all_arch_ids(include_pattern: bool = True) -> List[str]:
    ids = list(_MODULES)
    if not include_pattern:
        ids.remove("rig_gm")
    return ids


ASSIGNED = all_arch_ids(include_pattern=False)


def get_config(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch_id], __package__)
    if arch_id == "rig_gm":
        return mod.PatternArch()
    return mod.CONFIG
