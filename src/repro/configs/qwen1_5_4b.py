"""qwen1.5-4b: dense LM with QKV bias (MHA: kv == heads) [hf:Qwen/Qwen1.5]."""
import jax.numpy as jnp

from ..models.transformer import LMConfig
from .base import LMArch

CONFIG = LMArch(LMConfig(
    name="qwen1.5-4b", n_layers=40, d_model=2560, n_heads=20, n_kv=20,
    d_ff=6912, vocab=151936, d_head=128, qkv_bias=True,
    dtype=jnp.bfloat16,
))
