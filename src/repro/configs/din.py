"""din: Deep Interest Network — embed_dim 18, seq 100, attn MLP 80-40,
MLP 200-80, target attention interaction [arXiv:1706.06978]."""
from ..models.recsys import DINConfig
from .base import DINArch

CONFIG = DINArch(DINConfig(
    name="din", embed_dim=18, seq_len=100, attn_mlp=(80, 40), mlp=(200, 80),
    n_items=1_000_000, n_cates=10_000, n_user_feats=8, user_feat_vocab=1_024,
))
