"""yi-34b: llama-arch dense LM with GQA [arXiv:2403.04652; hf]."""
import jax.numpy as jnp

from ..models.transformer import LMConfig
from .base import LMArch

CONFIG = LMArch(LMConfig(
    name="yi-34b", n_layers=60, d_model=7168, n_heads=56, n_kv=8,
    d_ff=20480, vocab=64000, d_head=128, qkv_bias=False,
    dtype=jnp.bfloat16,
))
