"""Synthetic GraphBatch builders for the four assigned GNN shapes.

``input_specs`` in the configs use the same shape logic with
ShapeDtypeStructs (no allocation); these builders create small *real*
batches for smoke tests and the runnable examples.
"""

from __future__ import annotations

from typing import Dict

import numpy as np


def full_graph_batch(n_nodes: int, n_edges: int, d_feat: int,
                     n_classes: int = 16, seed: int = 0,
                     with_labels: bool = True) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    out = {
        "node_feat": rng.standard_normal((n_nodes, d_feat)).astype(np.float32),
        "edge_src": rng.integers(0, n_nodes, n_edges).astype(np.int32),
        "edge_dst": rng.integers(0, n_nodes, n_edges).astype(np.int32),
        "node_mask": np.ones(n_nodes, bool),
    }
    if with_labels:
        out["labels"] = rng.integers(0, n_classes, n_nodes).astype(np.int32)
        out["train_mask"] = rng.random(n_nodes) < 0.5
    return out


def schnet_batch(n_nodes: int, n_edges: int, d_feat: int, batch: int = 1,
                 cutoff: float = 10.0, seed: int = 0) -> Dict[str, np.ndarray]:
    """Batched molecules: ``batch`` graphs of n_nodes/n_edges each, flattened
    with graph_ids; edge_feat[:, 0] = interatomic distance (the modality
    frontend stub supplies geometry)."""
    rng = np.random.default_rng(seed)
    N, E = n_nodes * batch, n_edges * batch
    src = rng.integers(0, n_nodes, E)
    dst = rng.integers(0, n_nodes, E)
    offs = np.repeat(np.arange(batch) * n_nodes, n_edges)
    return {
        "node_feat": rng.standard_normal((N, d_feat)).astype(np.float32),
        "edge_src": (src + offs).astype(np.int32),
        "edge_dst": (dst + offs).astype(np.int32),
        "edge_feat": (rng.random((E, 1)) * cutoff).astype(np.float32),
        "node_mask": np.ones(N, bool),
        "graph_ids": np.repeat(np.arange(batch), n_nodes).astype(np.int32),
        "graph_targets": rng.standard_normal(batch).astype(np.float32),
    }


def graphcast_batch(n_grid: int, n_mesh: int, n_vars: int,
                    mesh_edges: int, g2m_edges: int, m2g_edges: int,
                    d_mesh: int = 3, seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    d_edge = 4
    return {
        "node_feat": rng.standard_normal((n_grid, n_vars)).astype(np.float32),
        "mesh_feat": rng.standard_normal((n_mesh, d_mesh)).astype(np.float32),
        "g2m_src": rng.integers(0, n_grid, g2m_edges).astype(np.int32),
        "g2m_dst": rng.integers(0, n_mesh, g2m_edges).astype(np.int32),
        "g2m_feat": rng.standard_normal((g2m_edges, d_edge)).astype(np.float32),
        "mesh_src": rng.integers(0, n_mesh, mesh_edges).astype(np.int32),
        "mesh_dst": rng.integers(0, n_mesh, mesh_edges).astype(np.int32),
        "mesh_efeat": rng.standard_normal((mesh_edges, d_edge)).astype(np.float32),
        "m2g_src": rng.integers(0, n_mesh, m2g_edges).astype(np.int32),
        "m2g_dst": rng.integers(0, n_grid, m2g_edges).astype(np.int32),
        "m2g_feat": rng.standard_normal((m2g_edges, d_edge)).astype(np.float32),
        "node_mask": np.ones(n_grid, bool),
        "labels": rng.standard_normal((n_grid, n_vars)).astype(np.float32),
    }
