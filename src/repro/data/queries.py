"""Pattern-query workload generators (paper §7.1 "Queries", Fig. 3).

Query sets come in three flavours by edge type — C (child-only), H (hybrid:
each edge descendant with probability 0.5), D (descendant-only) — and four
structural classes: *acyclic*, *cyclic*, *clique* and *combo* (undirected
view has >2 cycles).  We provide the Fig.-3-style templates plus random
queries sampled from connected subgraphs of a target data graph (guarantees
a non-trivial answer, as the paper's biology query sets do).
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from ..core.graph import DataGraph
from ..core.query import CHILD, DESC, PatternQuery, QueryEdge


# ------------------------------------------------------------ Fig.3 templates
# Each template: (name, class, n_nodes, directed edge list).
TEMPLATES: List[tuple] = [
    # acyclic: paths / trees / dags without undirected cycles
    ("T0_path3",   "acyclic", 3, [(0, 1), (1, 2)]),
    ("T1_path4",   "acyclic", 4, [(0, 1), (1, 2), (2, 3)]),
    ("T2_star4",   "acyclic", 4, [(0, 1), (0, 2), (0, 3)]),
    ("T3_tree5",   "acyclic", 5, [(0, 1), (0, 2), (1, 3), (1, 4)]),
    ("T4_tree6",   "acyclic", 6, [(0, 1), (0, 2), (1, 3), (2, 4), (2, 5)]),
    # cyclic: exactly one/two undirected cycles
    ("T5_tri",     "cyclic", 3, [(0, 1), (1, 2), (0, 2)]),
    ("T6_diamond", "cyclic", 4, [(0, 1), (0, 2), (1, 3), (2, 3)]),
    ("T7_square",  "cyclic", 4, [(0, 1), (1, 2), (2, 3), (0, 3)]),
    ("T8_house",   "cyclic", 5, [(0, 1), (0, 2), (1, 3), (2, 3), (3, 4)]),
    ("T9_cyc5",    "cyclic", 5, [(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)]),
    # cliques (directed acyclically: i -> j for i < j)
    ("T10_cl4",    "clique", 4, [(i, j) for i in range(4) for j in range(i + 1, 4)]),
    ("T11_cl5",    "clique", 5, [(i, j) for i in range(5) for j in range(i + 1, 5)]),
    # combo: > 2 undirected cycles, mixed
    ("T12_combo6", "combo", 6, [(0, 1), (0, 2), (1, 2), (1, 3), (2, 4),
                                (3, 4), (3, 5), (4, 5)]),
    ("T13_combo7", "combo", 7, [(0, 1), (0, 2), (1, 3), (2, 3), (3, 4),
                                (4, 5), (4, 6), (5, 6), (1, 4)]),
    ("T14_combo8", "combo", 8, [(0, 1), (1, 2), (2, 3), (0, 3), (2, 4),
                                (4, 5), (5, 6), (4, 6), (6, 7), (3, 6)]),
]


def _assign_kinds(edges: Sequence[tuple], qtype: str,
                  rng: np.random.Generator) -> List[QueryEdge]:
    out = []
    for (s, d) in edges:
        if qtype == "C":
            k = CHILD
        elif qtype == "D":
            k = DESC
        elif qtype == "H":
            k = DESC if rng.random() < 0.5 else CHILD
        else:
            raise ValueError(f"unknown query type {qtype}")
        out.append(QueryEdge(s, d, k))
    return out


def query_from_template(template_idx: int, graph: DataGraph, qtype: str = "H",
                        seed: int = 0) -> PatternQuery:
    """Instantiate a Fig.-3 template: pick node labels from frequent labels
    of the target graph (so match sets are non-trivial)."""
    name, cls, n, edges = TEMPLATES[template_idx % len(TEMPLATES)]
    rng = np.random.default_rng(seed + 1000 * template_idx)
    label_ids = np.array(sorted(graph.inverted.keys()))
    freqs = np.array([len(graph.inverted[int(l)]) for l in label_ids],
                     dtype=np.float64)
    p = freqs / freqs.sum()
    labels = rng.choice(label_ids, size=n, p=p)
    return PatternQuery(labels=[int(l) for l in labels],
                        edges=_assign_kinds(edges, qtype, rng),
                        name=f"{name}_{qtype}")


def template_queries(graph: DataGraph, qtype: str = "H", seed: int = 0,
                     classes: Optional[Sequence[str]] = None) -> List[PatternQuery]:
    out = []
    for i, (name, cls, n, edges) in enumerate(TEMPLATES):
        if classes and cls not in classes:
            continue
        out.append(query_from_template(i, graph, qtype=qtype, seed=seed))
    return out


def random_query_from_graph(graph: DataGraph, n_nodes: int, qtype: str = "H",
                            extra_edge_prob: float = 0.3,
                            seed: int = 0) -> PatternQuery:
    """Random query sampled as a connected subgraph of the data graph (the
    paper's biology query sets [42] are built this way) — guarantees at
    least one occurrence *before* edge-kind assignment; descendant edges can
    only widen the answer, so the query stays satisfiable."""
    rng = np.random.default_rng(seed)
    for _attempt in range(64):
        start = int(rng.integers(0, graph.n))
        nodes = [start]
        seen = {start}
        frontier = [start]
        while len(nodes) < n_nodes and frontier:
            v = frontier.pop(int(rng.integers(0, len(frontier))))
            nbrs = np.concatenate([graph.children(v), graph.parents(v)])
            rng.shuffle(nbrs)
            for w in nbrs:
                w = int(w)
                if w not in seen:
                    seen.add(w)
                    nodes.append(w)
                    frontier.append(w)
                    if len(nodes) >= n_nodes:
                        break
        if len(nodes) >= n_nodes:
            break
    nodes = nodes[:n_nodes]
    pos = {v: i for i, v in enumerate(nodes)}
    node_set = set(nodes)
    edges = []
    for v in nodes:
        for w in graph.children(v):
            if int(w) in node_set:
                edges.append((pos[v], pos[int(w)]))
    # keep it connected but not complete: sample a spanning set + extras
    edges = sorted(set(edges))
    if not edges:
        return random_query_from_graph(graph, n_nodes, qtype,
                                       extra_edge_prob, seed + 1)
    keep = []
    connected = {edges[0][0]}
    pool = list(edges)
    progress = True
    while progress:
        progress = False
        for e in pool:
            if e in keep:
                continue
            if e[0] in connected or e[1] in connected:
                keep.append(e)
                connected |= {e[0], e[1]}
                progress = True
    for e in pool:
        if e not in keep and rng.random() < extra_edge_prob:
            keep.append(e)
    used = sorted({x for e in keep for x in e})
    remap = {v: i for i, v in enumerate(used)}
    keep = [(remap[a], remap[b]) for a, b in keep]
    labels = [int(graph.labels[nodes[v]]) for v in used]
    return PatternQuery(labels=labels,
                        edges=_assign_kinds(keep, qtype, rng),
                        name=f"rand{n_nodes}_{qtype}_s{seed}")
