"""Synthetic LM data pipeline — deterministic, seeded by step index.

Streams (tokens, labels) batches with enough structure for a small model's
loss to fall well below the unigram entropy (bigram-chain generator with
Zipf marginals + repeated motifs), so end-to-end training examples show real
learning on CPU.  Determinism-by-step is what makes checkpoint-restart
replay exact (see train.elastic).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass
class TokenPipelineConfig:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    motif_len: int = 8
    motif_prob: float = 0.3


class TokenPipeline:
    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        # sparse bigram transition structure: each token has ~8 likely successors
        self.succ = rng.integers(0, v, size=(v, 8))
        self.motifs = rng.integers(0, v, size=(16, cfg.motif_len))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, t = cfg.batch, cfg.seq_len
        seq = np.empty((b, t + 1), dtype=np.int32)
        seq[:, 0] = rng.integers(0, cfg.vocab, size=b)
        choice = rng.integers(0, 8, size=(b, t))
        explore = rng.random((b, t)) < 0.1
        randtok = rng.integers(0, cfg.vocab, size=(b, t))
        for i in range(t):
            nxt = self.succ[seq[:, i], choice[:, i]]
            seq[:, i + 1] = np.where(explore[:, i], randtok[:, i], nxt)
        # splice motifs (copy patterns)
        n_motifs = int(b * cfg.motif_prob)
        if n_motifs:
            rows = rng.integers(0, b, size=n_motifs)
            offs = rng.integers(0, max(t - cfg.motif_len, 1), size=n_motifs)
            which = rng.integers(0, len(self.motifs), size=n_motifs)
            for r, o, w in zip(rows, offs, which):
                seq[r, o:o + cfg.motif_len] = self.motifs[w]
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
