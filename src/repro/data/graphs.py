"""Synthetic labeled data-graph generators.

The paper evaluates on nine SNAP graphs (Table 1) spanning |V| 3.1K..876K,
average degree 2.6..36.9, and 3..307 labels.  Those datasets are not
available offline, so the benchmark harness regenerates graphs matching the
*structural profile* of each (size, average degree, label count, label skew)
with three topology families:

* ``uniform``   — Erdős–Rényi-style random edges,
* ``powerlaw``  — preferential-attachment out-edges (heavy-tail in-degree,
  like the social/web graphs),
* ``dag``       — edges oriented low→high id (enables the interval-label
  early-termination path).

Labels are Zipf-distributed (the SNAP label sets are highly skewed).
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from ..core.graph import DataGraph, graph_from_edge_list


def random_labeled_graph(n: int, avg_degree: float = 4.0, n_labels: int = 8,
                         kind: str = "powerlaw", label_skew: float = 1.2,
                         seed: int = 0) -> DataGraph:
    rng = np.random.default_rng(seed)
    n_edges = int(n * avg_degree)

    if kind == "uniform":
        src = rng.integers(0, n, size=n_edges)
        dst = rng.integers(0, n, size=n_edges)
    elif kind == "dag":
        a = rng.integers(0, n, size=n_edges)
        b = rng.integers(0, n, size=n_edges)
        src, dst = np.minimum(a, b), np.maximum(a, b)
    elif kind == "powerlaw":
        src = rng.integers(0, n, size=n_edges)
        # preferential attachment on destinations: sample from a Zipf-ish
        # rank distribution over a random permutation of nodes
        ranks = (rng.pareto(1.5, size=n_edges) * 3).astype(np.int64) % n
        perm = rng.permutation(n)
        dst = perm[ranks]
    else:
        raise ValueError(f"unknown graph kind: {kind}")

    keep = src != dst
    edges = np.stack([src[keep], dst[keep]], axis=1)

    # Zipf labels
    w = 1.0 / np.arange(1, n_labels + 1) ** label_skew
    w /= w.sum()
    labels = rng.choice(n_labels, size=n, p=w)
    return graph_from_edge_list(edges, labels, num_labels=n_labels)


# structural profiles of the paper's Table 1 datasets (|V|, |E|, |L|),
# scaled down by `scale` for laptop-class reproduction runs.
PAPER_PROFILES: Dict[str, tuple] = {
    "yeast":    (3_112, 12_519, 71, "uniform"),
    "human":    (4_674, 86_282, 44, "uniform"),
    "hprd":     (9_460, 34_998, 307, "uniform"),
    "epinions": (75_879, 508_837, 20, "powerlaw"),
    "dblp":     (317_080, 1_049_866, 20, "uniform"),
    "email":    (265_214, 420_045, 20, "powerlaw"),
    "amazon":   (403_394, 3_387_388, 3, "uniform"),
    "berkstan": (685_230, 7_600_595, 5, "powerlaw"),
    "google":   (875_713, 5_105_039, 5, "powerlaw"),
}


def paper_profile_graph(name: str, scale: float = 1.0, seed: int = 0) -> DataGraph:
    v, e, l, kind = PAPER_PROFILES[name]
    n = max(int(v * scale), 64)
    return random_labeled_graph(n=n, avg_degree=e / v, n_labels=l,
                                kind=kind, seed=seed)
