from .graphs import random_labeled_graph
from .queries import (query_from_template, random_query_from_graph,
                      template_queries)

__all__ = ["random_labeled_graph", "template_queries",
           "query_from_template", "random_query_from_graph"]
