"""Synthetic click-log batches for DIN (seeded by step — replayable)."""

from __future__ import annotations

from typing import Dict

import numpy as np


def din_batch(batch: int, seq_len: int, n_items: int, n_cates: int,
              n_user_feats: int, user_feat_vocab: int, step: int = 0,
              seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng((seed, step))
    hist_len = rng.integers(1, seq_len + 1, size=batch)
    mask = (np.arange(seq_len)[None, :] < hist_len[:, None])
    hist_items = rng.integers(0, n_items, (batch, seq_len)).astype(np.int32)
    item_id = rng.integers(0, n_items, batch).astype(np.int32)
    # learnable signal: click iff target shares category with recent history
    cate_of = lambda items: (np.asarray(items, np.uint64) * np.uint64(2654435761)
                             % np.uint64(n_cates)).astype(np.int32)
    hist_cates = cate_of(hist_items)
    cate_id = cate_of(item_id)
    overlap = (hist_cates == cate_id[:, None]) & mask
    label = (overlap.sum(1) > 0).astype(np.float32)
    # inject noise
    flip = rng.random(batch) < 0.1
    label = np.where(flip, 1 - label, label)
    return {
        "item_id": item_id, "cate_id": cate_id,
        "hist_items": np.where(mask, hist_items, 0).astype(np.int32),
        "hist_cates": np.where(mask, hist_cates, 0).astype(np.int32),
        "hist_mask": mask.astype(np.float32),
        "user_feats": rng.integers(0, user_feat_vocab,
                                   (batch, n_user_feats)).astype(np.int32),
        "label": label,
    }


def retrieval_batch(seq_len: int, n_items: int, n_cates: int,
                    n_user_feats: int, user_feat_vocab: int,
                    n_candidates: int, seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    base = din_batch(1, seq_len, n_items, n_cates, n_user_feats,
                     user_feat_vocab, step=0, seed=seed)
    cand = rng.integers(0, n_items, n_candidates).astype(np.int32)
    return {
        "hist_items": base["hist_items"], "hist_cates": base["hist_cates"],
        "hist_mask": base["hist_mask"], "user_feats": base["user_feats"],
        "cand_items": cand,
        "cand_cates": (np.asarray(cand, np.uint64) * np.uint64(2654435761)
                       % np.uint64(n_cates)).astype(np.int32),
    }
