"""GraphSAGE neighbour sampler (the real sampler minibatch_lg requires).

Uniform fan-out sampling over CSR adjacency, layered (e.g. 15-10): seeds →
up to f1 neighbours each → up to f2 neighbours of those.  Produces a
self-contained padded ``GraphBatch`` (static shapes) whose first
``len(seeds)`` nodes are the seeds; padding edges point at a masked sink.
Deterministic per (seed, step).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray
    indices: np.ndarray
    feats: np.ndarray          # (N, F)
    labels: np.ndarray         # (N,)

    @property
    def n(self) -> int:
        return len(self.indptr) - 1


def random_csr_graph(n: int, avg_deg: float, d_feat: int, n_classes: int,
                     seed: int = 0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, src + 1, 1)
    np.cumsum(indptr, out=indptr)
    feats = rng.standard_normal((n, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, size=n).astype(np.int32)
    return CSRGraph(indptr=indptr, indices=dst.astype(np.int64),
                    feats=feats, labels=labels)


def sample_blocks(g: CSRGraph, seeds: np.ndarray, fanouts: Sequence[int],
                  rng: np.random.Generator
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (nodes, edge_src_local, edge_dst_local): a node-induced
    sampled subgraph whose first len(seeds) entries are the seeds."""
    nodes: List[int] = list(map(int, seeds))
    local = {v: i for i, v in enumerate(nodes)}
    esrc: List[int] = []
    edst: List[int] = []
    frontier = list(map(int, seeds))
    for f in fanouts:
        nxt: List[int] = []
        for v in frontier:
            nbrs = g.indices[g.indptr[v]:g.indptr[v + 1]]
            if len(nbrs) == 0:
                continue
            take = nbrs if len(nbrs) <= f else rng.choice(nbrs, size=f,
                                                          replace=False)
            for w in map(int, take):
                if w not in local:
                    local[w] = len(nodes)
                    nodes.append(w)
                    nxt.append(w)
                # message flows neighbour -> node being refined
                esrc.append(local[w])
                edst.append(local[v])
        frontier = nxt
    return (np.asarray(nodes, np.int64), np.asarray(esrc, np.int64),
            np.asarray(edst, np.int64))


def sampled_batch(g: CSRGraph, batch_nodes: int, fanouts: Sequence[int],
                  step: int, seed: int = 0, pad_nodes: int | None = None,
                  pad_edges: int | None = None) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng((seed, step))
    seeds = rng.choice(g.n, size=batch_nodes, replace=False)
    nodes, esrc, edst = sample_blocks(g, seeds, fanouts, rng)

    # worst-case static shapes
    if pad_nodes is None:
        pad_nodes = batch_nodes
        for f in fanouts:
            pad_nodes += pad_nodes * f
        pad_nodes = min(pad_nodes, batch_nodes * int(np.prod(fanouts)) * 2)
    if pad_edges is None:
        pad_edges = pad_nodes
    pad_nodes = max(pad_nodes, len(nodes) + 1)
    pad_edges = max(pad_edges, len(esrc))

    sink = pad_nodes - 1
    node_feat = np.zeros((pad_nodes, g.feats.shape[1]), np.float32)
    node_feat[:len(nodes)] = g.feats[nodes]
    labels = np.zeros(pad_nodes, np.int32)
    labels[:len(nodes)] = g.labels[nodes]
    node_mask = np.zeros(pad_nodes, bool)
    node_mask[:len(nodes)] = True
    train_mask = np.zeros(pad_nodes, bool)
    train_mask[:batch_nodes] = True                 # loss on seeds only
    src = np.full(pad_edges, sink, np.int32)
    dst = np.full(pad_edges, sink, np.int32)
    src[:len(esrc)] = esrc
    dst[:len(edst)] = edst
    return {"node_feat": node_feat, "edge_src": src, "edge_dst": dst,
            "labels": labels, "node_mask": node_mask, "train_mask": train_mask}
