"""Production mesh builders.

Functions (never module-level constants) so that importing this module
never touches jax device state — the dry-run must set XLA flags before the
first device query.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly simulated) local devices."""
    return jax.make_mesh((data, model), ("data", "model"))
