"""Training driver: any LM/GNN/recsys arch at a *runnable* scale on the
local device(s), with the full production runtime — AdamW, checkpointing,
crash-resume, optional int8 gradient compression, straggler journal.

This is the same code path the cluster launcher would run per host; the
mesh is whatever the local process exposes (set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to exercise the
distributed layout on CPU).

Usage:
  python -m repro.launch.train --arch qwen2-7b --steps 200 --scale smoke \
      [--resume] [--compress-grads] [--ckpt-dir /tmp/repro_ckpt]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..train import (AdamWConfig, ElasticConfig, ElasticTrainer,
                     make_int8_compressor)
from ..train import optimizer as opt
from ..train.compression import init_error_state


def build_lm(arch, args):
    from ..data.tokens import TokenPipeline, TokenPipelineConfig
    from ..models import transformer as tf
    cfg = arch.smoke_config() if args.scale == "smoke" else arch.cfg
    pipe = TokenPipeline(TokenPipelineConfig(
        vocab=cfg.vocab, batch=args.batch, seq_len=args.seq_len,
        seed=args.seed))
    loss = lambda p, b: tf.loss_fn(p, b, cfg)
    init = lambda: tf.init_params(cfg, jax.random.key(args.seed))
    batch_fn = lambda i: jax.tree.map(jnp.asarray, pipe.batch_at(i))
    return cfg, init, loss, batch_fn


def build_gnn(arch, args):
    from ..data.sampler import random_csr_graph, sampled_batch
    from ..models import gnn
    cfg = arch.smoke_config() if args.scale == "smoke" else arch.cfg
    g = random_csr_graph(2048, avg_deg=8, d_feat=cfg.d_feat,
                         n_classes=cfg.n_classes, seed=args.seed)
    loss = lambda p, b: gnn.loss_fn(p, b, cfg)
    init = lambda: gnn.init_params(cfg, jax.random.key(args.seed))
    batch_fn = lambda i: jax.tree.map(jnp.asarray, sampled_batch(
        g, 64, (8, 4), i, seed=args.seed))
    return cfg, init, loss, batch_fn


def build_din(arch, args):
    from ..data.recsys_data import din_batch
    from ..models import recsys
    cfg = arch.smoke_config() if args.scale == "smoke" else arch.cfg
    loss = lambda p, b: recsys.loss_fn(p, b, cfg)
    init = lambda: recsys.init_params(cfg, jax.random.key(args.seed))
    batch_fn = lambda i: jax.tree.map(jnp.asarray, din_batch(
        args.batch, cfg.seq_len, cfg.n_items, cfg.n_cates,
        cfg.n_user_feats, cfg.user_feat_vocab, step=i, seed=args.seed))
    return cfg, init, loss, batch_fn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a simulated crash (test fault tolerance)")
    args = ap.parse_args()

    arch = get_config(args.arch)
    builder = {"lm": build_lm, "gnn": build_gnn,
               "recsys": build_din}[arch.family]
    cfg, init_params, loss_fn, batch_fn = builder(arch, args)

    ocfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                       total_steps=args.steps, weight_decay=0.01)
    compressor = make_int8_compressor() if args.compress_grads else None

    def init_state():
        params = init_params()
        state = {"params": params, "opt": opt.init_state(params)}
        if compressor:
            state["err"] = init_error_state(params)
        return state

    @jax.jit
    def step(state, batch):
        grads = jax.grad(loss_fn)(state["params"], batch)
        loss = loss_fn(state["params"], batch)
        if compressor:
            grads, err = compressor(grads, state["err"])
        params, ostate, m = opt.apply_updates(state["params"], grads,
                                              state["opt"], ocfg)
        new = {"params": params, "opt": ostate}
        if compressor:
            new["err"] = err
        m["loss"] = loss
        return new, m

    trainer = ElasticTrainer(
        step_fn=step, make_batch=batch_fn, init_state=init_state,
        cfg=ElasticConfig(checkpoint_dir=args.ckpt_dir,
                          checkpoint_every=args.ckpt_every),
        get_step=lambda s: int(s["opt"]["step"]))
    info = trainer.start_or_resume()
    print(f"[train] {args.arch} family={arch.family} resumed={info['resumed']}"
          f" from step {info['step']}")
    t0 = time.time()
    out = trainer.run(args.steps, fail_at=args.fail_at)
    losses = [m["loss"] for m in out["metrics"]]
    print(f"[train] done: step={out['final_step']} "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({time.time() - t0:.1f}s, stragglers={out['straggler_flags']})")


if __name__ == "__main__":
    main()
