"""Pattern-query serving driver — the paper-kind end-to-end application.

A batched query server over one resident data graph, driven through the
``repro.engine`` subsystem: requests (textual queries or ``PatternQuery``
objects) arrive, are micro-batched, planned per query (device matcher for
fitting queries, host GM for over-wide ones) and answered with counts.
Production behaviours:

* **request journal** — every request is journaled before dispatch; a worker
  failure (the ``journal_dispatch`` fault site, or an engine-level
  transient) re-dispatches from the journal.  The RIG is runtime state (the
  paper's key property), so recovery is recompute, not state repair;
* **bounded retries** — a request is re-dispatched at most ``max_attempts``
  times; one that keeps failing goes terminal (``status="failed"``,
  ``server_failed`` counter) instead of looping forever;
* **straggler mitigation** — per-batch deadline (monotonic clock); batches
  that blow the deadline are split and retried;
* **admission control** — malformed query text is rejected at submit with
  the parser's error message; ``queue_limit`` bounds the journal backlog
  (excess submissions are rejected with an :class:`AdmissionError`
  message); over-wide queries are not rejected but planned onto the host;
* **resource governance** — an optional per-request
  :class:`~repro.robust.Budget` template rides into the engine: deadline
  partials are served as terminal results (retrying the same budget would
  blow the same deadline), transient failures are re-dispatched;
* **cross-query caching** — the engine's per-graph label cache means the
  reachability index is built once at server start, and its plan cache
  means repeat query shapes skip planning;
* **observability** — ``profile=True`` records one lifecycle span tree per
  request (``Request.trace``); server counters live in the engine's
  metrics registry (``server_*`` series), so ``metrics_text()`` is one
  Prometheus-style dump covering engine, caches and server.  The engine's
  always-on serving telemetry rides along: every request (and every
  server-side rejection / journal re-dispatch / give-up) lands in the
  bounded flight recorder, ``--stats-interval N`` prints a windowed
  QPS/p50/p95/p99/error-rate line every N seconds, and ``--flight-dump
  PATH`` writes the JSONL dump at exit (incident auto-dumps — breaker
  open, deadline-rate spike — are armed to the same path).

Usage:
  python -m repro.launch.serve --n-queries 64 --graph-nodes 2000 \
      [--deadline-ms 50] [--profile] [--metrics] \
      [--stats-interval 2] [--flight-dump FLIGHT_serve.jsonl]
"""

from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

from ..core.query import PatternQuery
from ..data.graphs import random_labeled_graph
from ..data.queries import random_query_from_graph
from ..engine import Engine, EngineOptions, QueryParseError, render_trace
from ..engine.engine import _CounterView
from ..obs import ServerEvent, Span
from ..robust import Budget, InjectedFault, TransientError, faults

_SERVER_COUNTERS = ("served", "redispatched", "rejected", "failed",
                    "host_fallback")

# terminal request states (everything else re-enters the pending pool)
_TERMINAL = ("done", "failed")


@dataclass
class Request:
    rid: int
    query: PatternQuery
    # monotonic, never wall clock: an NTP step must not age the queue
    submitted: float = field(default_factory=time.monotonic)
    attempts: int = 0
    done: bool = False
    status: str = "queued"          # queued | done | failed
    outcome: str = ""               # engine status of the served result
    count: Optional[int] = None
    overflowed: bool = False
    backend: str = ""
    error: str = ""                 # last failure detail (retries, give-up)
    trace: Optional[Span] = None    # lifecycle span tree (profiling servers)


class QueryServer:
    def __init__(self, graph, *, max_q=8, max_e=16, batch_size=16,
                 capacity=4096, deadline_s=30.0, max_attempts=3,
                 impl="reference", engine: Optional[Engine] = None,
                 profile: bool = False, budget: Optional[Budget] = None,
                 queue_limit: Optional[int] = None,
                 tenant: Optional[str] = None):
        self.graph = graph
        # ledger attribution: every device transfer/allocation this graph
        # causes is charged under its key — a caller-supplied tenant name
        # makes ``ledger_rollup()`` a per-tenant accounting surface
        # (pre-stamped before engine registration, which would otherwise
        # assign an anonymous epoch key)
        if tenant is not None:
            graph.graph_key = tenant
        self.tenant = getattr(graph, "graph_key", None)
        # device_min_nodes=0: the server is the device-serving driver, so
        # any query that fits the device caps goes through the vmapped
        # matcher regardless of graph size; wide queries plan onto the host.
        self.engine = engine or Engine(graph, options=EngineOptions(
            max_q=max_q, max_e=max_e, capacity=capacity, device_min_nodes=0,
            device_impl=impl, exact_sim=True, materialize=False))
        self.batch_size = batch_size
        self.deadline_s = deadline_s
        self.max_attempts = max_attempts
        self.profile = profile
        self.budget = budget            # per-request template (armed by the
        self.queue_limit = queue_limit  # engine for each batch member)
        self.journal: Dict[int, Request] = {}
        self.rejected: Dict[int, str] = {}      # rid -> rejection message
        # server counters share the engine's registry (series server_*), so
        # one metrics dump covers the whole serving stack; the dict-style
        # surface (stats["served"] += 1) is unchanged
        self.stats = _CounterView(self.engine.metrics,
                                  names=_SERVER_COUNTERS, prefix="server_")
        # server-side lifecycle actions (rejections, journal re-dispatches,
        # terminal give-ups) land in the engine's flight recorder next to
        # the per-request query events, so one dump tells the whole story
        self.flight = self.engine.flight

    def metrics_text(self) -> str:
        """Prometheus-style dump of engine + cache + server series."""
        return self.engine.metrics_text()

    def ledger_rollup(self) -> Dict[str, int]:
        """This tenant's device-memory/transfer account: cumulative h2d
        and d2h bytes charged under the served graph's ledger key, its
        live device-resident footprint, and that footprint's watermark."""
        key = self.tenant or getattr(self.graph, "graph_key", None)
        return self.engine.ledger.rollup(key if key else "-")

    def stats_line(self) -> str:
        """One windowed-telemetry summary line (QPS, error rate,
        p50/p95/p99 of total latency) from the engine's sliding windows."""
        return self.engine.windows.summary_line()

    def _record_server_event(self, action: str, r: "Request",
                             detail: str = "") -> None:
        if self.engine.telemetry:
            self.flight.record(ServerEvent(action=action, rid=r.rid,
                                           attempts=r.attempts,
                                           detail=detail or r.error))

    def submit(self, rid: int, query: Union[str, PatternQuery]) -> bool:
        """Journal a request.  Admission control happens here: malformed
        query text is rejected with the caret-annotated parse error, and a
        full queue (``queue_limit`` pending requests) rejects rather than
        buffering unboundedly — both recorded in ``self.rejected[rid]``."""
        if (self.queue_limit is not None
                and len(self._pending()) >= self.queue_limit):
            self.rejected[rid] = (f"queue full ({self.queue_limit} pending "
                                  f"requests); resubmit later")
            self.stats["rejected"] += 1
            if self.engine.telemetry:
                self.flight.record(ServerEvent(action="reject", rid=rid,
                                               detail=self.rejected[rid]))
            return False
        if isinstance(query, str):
            try:
                query = self.engine.parse(query)
            except QueryParseError as e:
                self.rejected[rid] = str(e)
                self.stats["rejected"] += 1
                if self.engine.telemetry:
                    self.flight.record(ServerEvent(action="reject", rid=rid,
                                                   detail="parse error"))
                return False
        self.journal[rid] = Request(rid=rid, query=query)
        return True

    def _pending(self) -> List[Request]:
        """Live requests, marking give-ups terminal as a side effect: a
        request whose attempts are spent becomes ``status="failed"``
        (``server_failed``) instead of circulating forever."""
        out = []
        for r in self.journal.values():
            if r.status in _TERMINAL:
                continue
            if r.attempts >= self.max_attempts:
                r.status = "failed"
                r.error = (r.error
                           or f"gave up after {r.attempts} attempt(s)")
                self.stats["failed"] += 1
                self._record_server_event("failed", r)
                continue
            out.append(r)
        return out

    def step(self, fail: bool = False) -> int:
        """Serve one micro-batch; ``fail=True`` (or a ``journal_dispatch``
        injected fault) simulates a worker dying mid-batch — the requests
        stay journaled, the attempt is spent, and the next step
        re-dispatches them."""
        batch = self._pending()[:self.batch_size]
        if not batch:
            return 0
        for r in batch:
            r.attempts += 1
        if fail:                              # worker loss: nothing returns
            self.stats["redispatched"] += len(batch)
            for r in batch:
                self._record_server_event("redispatch", r,
                                          detail="simulated worker loss")
            return 0
        try:
            faults.maybe_fail("journal_dispatch")
        except InjectedFault as e:            # simulated worker death
            for r in batch:
                r.error = str(e)
                self._record_server_event("redispatch", r)
            self.stats["redispatched"] += len(batch)
            return 0
        t0 = time.monotonic()
        try:
            results = self.engine.execute_many(
                [r.query for r in batch], profile=self.profile,
                budget=self.budget)
        except TransientError as e:
            # an engine-level transient lost the whole batch: requests are
            # still journaled, so the next step recomputes them
            for r in batch:
                r.error = str(e)
                self._record_server_event("redispatch", r)
            self.stats["redispatched"] += len(batch)
            return 0
        dt = time.monotonic() - t0
        if dt > self.deadline_s and len(batch) > 1:
            # straggler batch: split next time.  A deadline miss is a
            # re-dispatch, not a lost attempt (the results were produced,
            # just late — e.g. a cold-start compile), so roll attempts back.
            self.batch_size = max(1, self.batch_size // 2)
            self.stats["redispatched"] += len(batch)
            for r in batch:
                r.attempts -= 1
                self._record_server_event("redispatch", r,
                                          detail="straggler batch split")
            return 0
        served = 0
        for r, res in zip(batch, results):
            st = res.stats.status
            if st == "transient":
                # the engine exhausted its own recompute attempts for this
                # request; spend a server attempt and try again (or go
                # terminal once max_attempts is hit)
                r.error = "transient engine failure"
                self.stats["redispatched"] += 1
                self._record_server_event("redispatch", r)
                continue
            # everything else — including a deadline partial — is terminal:
            # re-running the same budget would blow the same deadline
            r.count = res.count
            r.overflowed = res.stats.overflow_fallback
            r.backend = res.stats.backend
            r.outcome = st
            r.trace = res.trace
            if res.stats.overflow_fallback:
                self.stats["host_fallback"] += 1
            r.done = True
            r.status = "done"
            self.stats["served"] += 1
            served += 1
        return served

    def drain(self, max_rounds: int = 100) -> None:
        for _ in range(max_rounds):
            if not self._pending():
                break
            self.step()
        self._pending()       # final sweep: mark any give-ups terminal


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph-nodes", type=int, default=1000)
    ap.add_argument("--n-queries", type=int, default=32)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request budget deadline in ms (0 = none)")
    ap.add_argument("--profile", action="store_true",
                    help="record and print one lifecycle span tree "
                         "per request")
    ap.add_argument("--metrics", action="store_true",
                    help="print the Prometheus-style metrics dump "
                         "after draining")
    ap.add_argument("--stats-interval", type=float, default=0.0,
                    help="print a windowed QPS/p50/p95/p99/error-rate "
                         "summary line every N seconds while serving "
                         "(0 = off)")
    ap.add_argument("--flight-dump", default=None, metavar="PATH",
                    help="dump the flight recorder (per-request event "
                         "records + tail-sampled exemplars) as JSONL "
                         "after draining; incident auto-dumps are armed "
                         "to the same path while serving")
    args = ap.parse_args()

    graph = random_labeled_graph(args.graph_nodes, avg_degree=3.0,
                                 n_labels=8, seed=args.seed)
    budget = (Budget(deadline_s=args.deadline_ms / 1000.0, max_attempts=2)
              if args.deadline_ms > 0 else None)
    server = QueryServer(graph, batch_size=args.batch_size,
                         profile=args.profile, budget=budget)
    if args.flight_dump:
        server.flight.arm_autodump(args.flight_dump)
    qtypes = ["C", "H", "D"]
    n = 0
    for i in range(args.n_queries):
        q = random_query_from_graph(graph, 3 + i % 3, qtype=qtypes[i % 3],
                                    seed=args.seed + i)
        n += int(server.submit(i, q))
    t0 = time.monotonic()
    next_stats = (t0 + args.stats_interval if args.stats_interval > 0
                  else None)
    for _ in range(100):                      # bounded drain with stats
        if not server._pending():
            break
        server.step()
        now = time.monotonic()
        if next_stats is not None and now >= next_stats:
            print(f"[serve] {server.stats_line()}")
            next_stats = now + args.stats_interval
    server.drain()                            # final sweep / give-ups
    dt = time.monotonic() - t0
    if args.stats_interval > 0:
        print(f"[serve] {server.stats_line()}")
    counts = [server.journal[i].count for i in sorted(server.journal)]
    print(f"[serve] {n} queries in {dt:.2f}s "
          f"({n / max(dt, 1e-9):.1f} qps) stats={server.stats} "
          f"engine={server.engine.cache_info()}")
    print(f"[serve] counts: {counts[:10]}{'...' if len(counts) > 10 else ''}")
    if args.profile:
        for rid in sorted(server.journal):
            r = server.journal[rid]
            if r.trace is not None:
                print(f"[serve] --- request {rid} ---")
                print(render_trace(r.trace))
    if args.metrics:
        print("[serve] --- metrics ---")
        print(server.metrics_text())
    if args.flight_dump:
        lines = server.flight.dump_jsonl(args.flight_dump, reason="exit")
        print(f"[serve] wrote flight-recorder dump: {args.flight_dump} "
              f"({lines} lines, {server.flight.recorded} recorded)")


if __name__ == "__main__":
    main()
