import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ must precede any jax-importing import (see dryrun.py).

"""§Perf hillclimbing driver: lower baseline + variants for the three
chosen cells, record all three roofline terms per iteration, append to
results/perf_iterations.json.

  python -m repro.launch.hillclimb [--out results/perf_iterations.json]
"""

import argparse
import json
import time
import traceback

import jax
import numpy as np

from ..configs import get_config
from .dryrun import _cost_of, _global_cost, collective_census, lm_calibrated_cost
from .mesh import make_production_mesh

PEAK, HBM, LINK = 197e12, 819e9, 50e9

# (arch, shape, mesh, variant, hypothesis)
CELLS = [
    # --- cell 1: yi-34b train_4k — worst LM roofline fraction, memory-bound
    ("yi-34b", "train_4k", "pod", "baseline",
     "baseline: dense T×T attention + full (B,T,V) f32 logits"),
    ("yi-34b", "train_4k", "pod", "flash",
     "H1: streaming KV chunks (online softmax) removes the (B,H,T,T) score "
     "buffer -> memory term drops ~T/chunk on the attention share"),
    ("yi-34b", "train_4k", "pod", "flash+chunkloss",
     "H2: streaming lm_head CE removes the (B,T,V) f32 logits buffer "
     "-> remaining memory term drops toward the parameter/activation floor"),
    ("yi-34b", "train_4k", "pod", "flash+chunkloss+wsc",
     "H10: pin activations' batch dim to the data axes at every layer "
     "boundary — GSPMD had propagated a weight-stationary layout into the "
     "scan (batch REPLICATED, d_model sharded): temp should fall ~16x"),
    ("yi-34b", "train_4k", "pod", "flash+chunkloss+wsc+ckptchunk",
     "H11: checkpoint the flash chunk body — autodiff was saving each "
     "chunk's probability tensor for bwd (~17 GB x chunks x live layers); "
     "recompute-in-bwd drops the residual temp toward the carry floor"),
    # --- extension: the validated LM chain on two more train cells
    ("qwen2-7b", "train_4k", "pod", "baseline",
     "baseline for comparison (memory-bound, 24.3% roofline)"),
    ("qwen2-7b", "train_4k", "pod", "flash+chunkloss+wsc+ckptchunk",
     "H1+H2+H10+H11 transferred: same memory-bound profile as yi"),
    ("grok-1-314b", "train_4k", "pod", "baseline",
     "baseline for comparison — the one COMPUTE-bound LM train cell: "
     "prediction: the memory-term chain helps little here (cross-check)"),
    ("grok-1-314b", "train_4k", "pod", "flash+chunkloss+wsc+ckptchunk",
     "H12: on a compute-bound cell the chain should move memory/collective "
     "terms but NOT the roofline fraction (bound stays compute)"),
    # --- cell 2: gin-tu ogb_products — most collective-bound cell
    ("gin-tu", "ogb_products", "pod", "baseline",
     "baseline: pjit auto-sharding scatters (E,d) messages across shards"),
    ("gin-tu", "ogb_products", "pod", "shardmap",
     "H3: dst-partitioned edges + one tiled all-gather of the (N,d) feature "
     "matrix per layer -> collective volume independent of E (N·d vs E·d)"),
    # --- cell 3: rig_gm serve_1m — the paper-technique cell, memory-bound
    ("rig_gm", "serve_1m", "pod", "baseline",
     "baseline: bf16 unpack (already 2x better than f32), bool Y gather"),
    ("rig_gm", "serve_1m", "pod", "packy",
     "H4: Y is bits; pack to uint32 before the all-gather -> 8x less wire"),
    ("rig_gm", "serve_1m", "pod", "b128",
     "H5: 4x query batch amortizes the packed-matrix reads -> per-query "
     "memory term ~4x lower (matrix traffic dominates and is batch-invariant)"),
    ("rig_gm", "serve_1m", "pod", "bk1024",
     "H8: 4x smaller unpack chunks shrink the live unpack temporaries "
     "(the 39 GB HBM peak) ~4x; HBM *traffic* unchanged — on TPU the "
     "Pallas bitmm removes these temporaries entirely (VMEM-only unpack)"),
    ("rig_gm", "serve_1m", "pod", "scan-artifact",
     "H9: deploy the SCANNED blocked matmul (buffers reused across chunk "
     "iterations) and keep the unrolled form for cost counting only -> "
     "temp drops from 39 GB to the per-chunk working set; fits 16 GB"),
    ("rig_gm", "serve_1m", "pod", "best",
     "H4+H5+H9 combined: packed Y + 128-query batch + scanned chunks"),
]


def lower_cell(arch_id, shape, mesh_kind, variant):
    cfg = get_config(arch_id)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_dev = int(np.prod(list(mesh.shape.values())))
    unit = cfg.build_dryrun(shape, mesh, variant=variant) \
        if variant != "baseline" else cfg.build_dryrun(shape, mesh)
    jitted = jax.jit(unit.step_fn, in_shardings=unit.in_shardings,
                     donate_argnums=unit.donate)
    with mesh, jax.set_mesh(mesh):
        compiled = jitted.lower(*unit.args).compile()
    mem = compiled.memory_analysis()
    census = collective_census(compiled.as_text())
    wire = sum(v["wire_bytes"] for v in census.values())
    # calibrated global flops/bytes
    if cfg.family == "lm":
        def build(shape_, mesh_, layers_override=None, unroll=False):
            return cfg.build_dryrun(shape_, mesh_,
                                    layers_override=layers_override,
                                    unroll=unroll, variant=variant)
        import types
        proxy = types.SimpleNamespace(build_dryrun=build, cfg=cfg.cfg)
        cal = lm_calibrated_cost(proxy, shape, mesh, n_dev)
        flops_dev = cal["flops"]
        bytes_dev = cal["bytes accessed"]
    elif cfg.family == "pattern":
        unit_u = cfg.build_dryrun(shape, mesh, variant=variant, unroll=True)
        jit_u = jax.jit(unit_u.step_fn, in_shardings=unit_u.in_shardings)
        with mesh, jax.set_mesh(mesh):
            comp_u = jit_u.lower(*unit_u.args).compile()
        c = _cost_of(comp_u)
        flops_dev, bytes_dev = c["flops"], c["bytes accessed"]
    else:
        c = _global_cost(unit)
        flops_dev = c["flops"] / n_dev
        bytes_dev = c["bytes accessed"] / n_dev
    batch_scale = 4.0 if variant in ("b128", "best") else 1.0  # per-query
    terms = {
        "t_compute_s": flops_dev / PEAK / batch_scale,
        "t_memory_s": bytes_dev / HBM / batch_scale,
        "t_collective_s": wire / LINK / batch_scale,
    }
    dominant = max(terms, key=terms.get)
    model = cfg.model_flops(shape)
    bound = max(terms.values())
    return {
        "arch": arch_id, "shape": shape, "mesh": mesh_kind,
        "variant": variant, "terms": terms, "dominant": dominant,
        "bound_s": bound,
        "roofline_fraction": model / (n_dev * PEAK * bound) if bound else 0,
        "memory": {
            "args_GB": mem.argument_size_in_bytes / 1e9,
            "temp_GB": mem.temp_size_in_bytes / 1e9,
            "out_GB": mem.output_size_in_bytes / 1e9,
            "fits_16GB": (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                          + mem.output_size_in_bytes
                          - mem.alias_size_in_bytes) < 16e9,
        },
        "wire_bytes_per_dev": wire,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/perf_iterations.json")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"], r["variant"])
            for r in results if "terms" in r}
    for (arch, shape, mesh_kind, variant, hyp) in CELLS:
        if args.only and arch != args.only:
            continue
        key = (arch, shape, mesh_kind, variant)
        if key in done:
            print(f"[skip] {key}")
            continue
        print(f"[perf] {arch} × {shape} × {variant} ...", flush=True)
        t0 = time.time()
        try:
            rec = lower_cell(arch, shape, mesh_kind, variant)
            rec["hypothesis"] = hyp
            rec["wall_s"] = round(time.time() - t0, 1)
            t = rec["terms"]
            print(f"  compute={t['t_compute_s']:.3e} "
                  f"memory={t['t_memory_s']:.3e} "
                  f"coll={t['t_collective_s']:.3e} "
                  f"dominant={rec['dominant']} "
                  f"fits={rec['memory']['fits_16GB']}", flush=True)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                   "variant": variant, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-3000:]}
            print(f"  ERROR {e}", flush=True)
        results = [r for r in results
                   if (r["arch"], r["shape"], r["mesh"],
                       r["variant"]) != key]
        results.append(rec)
        json.dump(results, open(args.out, "w"), indent=1)


if __name__ == "__main__":
    main()
