import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing import: jax locks the device count at
#   first backend initialization.  (Only the dry-run wants 512 placeholder
#   devices — tests and benches see the real device count.)

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh).

For each cell:
  * build the step function + abstract inputs from the arch config,
  * ``jax.jit(step, in_shardings=...).lower(*specs).compile()`` on the
    production mesh (16×16 single-pod / 2×16×16 multi-pod),
  * record ``memory_analysis()`` (fits-per-device proof),
    ``cost_analysis()`` (FLOPs/bytes), and the collective-byte census parsed
    from the compiled HLO — the inputs to §Roofline.

Results append incrementally to a JSON manifest so long sweeps are
restartable (``--skip-existing``).

Usage:
  python -m repro.launch.dryrun [--arch yi-34b] [--shape train_4k]
      [--mesh pod|multipod|both] [--out results/dryrun.json]
"""

import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from ..configs import all_arch_ids, get_config
from .mesh import make_production_mesh

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _wire_bytes(kind: str, out_bytes: int, g: int) -> float:
    """Per-device wire traffic estimate from the op's *output* shape (the
    partitioned HLO prints per-device shapes; operands carry no inline type).
    Ring-algorithm costs with group size g:
      all-gather       recv (g-1)/g · out
      all-reduce       2 · (g-1)/g · out           (reduce-scatter + AG)
      reduce-scatter   send (g-1) · out            (out = in/g)
      all-to-all       (g-1)/g · out
      collective-permute  out
    """
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return out_bytes * (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * out_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return float(out_bytes) * (g - 1)
    if kind == "all-to-all":
        return out_bytes * (g - 1) / g
    return float(out_bytes)


def collective_census(hlo_text: str) -> dict:
    """Per-device collective census from partitioned HLO text."""
    out = {k: {"count": 0, "output_bytes": 0, "wire_bytes": 0.0}
           for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.*)$", s)
        if not m:
            continue
        body = m.group(1)
        # the op name immediately precedes its operand parens; tuple-shaped
        # outputs put "(" first, so match "<kind>(" anywhere in the body.
        km = re.search(
            r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(-start|-done)?\(", body)
        if not km or km.group(2) == "-done":  # -start/-done pairs count once
            continue
        kind = km.group(1)
        op_pos = km.start()
        out_shapes = _SHAPE_RE.findall(body[:op_pos])
        out_bytes = sum(_shape_bytes(d, s) for d, s in out_shapes)
        gm = _GROUP_RE.search(body)
        g = int(gm.group(2)) if gm else 2     # conservative default
        if kind == "collective-permute":
            g = 2
        out[kind]["count"] += 1
        out[kind]["output_bytes"] += out_bytes
        out[kind]["wire_bytes"] += _wire_bytes(kind, out_bytes, g)
    return out


def _cost_of(compiled) -> dict:
    cost_list = compiled.cost_analysis()
    cost = cost_list if isinstance(cost_list, dict) else (
        cost_list[0] if cost_list else {})
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes accessed": float(cost.get("bytes accessed", 0.0)),
    }


def _global_cost(unit) -> dict:
    """Lower the step on a SINGLE abstract device (no partitioner) and read
    the whole-program cost.  Rationale: on the partitioned module, GSPMD's
    windowed-einsum rewrites turn large sharded matmuls into while loops
    whose bodies HloCostAnalysis counts once — undercounting FLOPs by the
    trip count.  The unpartitioned module has no such loops; per-device
    cost = global / n_devices (flop-balanced sharding)."""
    jitted = jax.jit(unit.step_fn)
    compiled = jitted.lower(*unit.args).compile()
    return _cost_of(compiled)


def lm_calibrated_cost(cfg, shape: str, mesh, n_dev: int) -> dict:
    """Global-cost extrapolation over depth: HLO cost analysis counts a
    lax.scan body once, so lower *unrolled* L=2 and L=4 single-device
    variants; everything linear in depth extrapolates exactly:
        total(L) = fixed + per_layer · L,  per_layer = (C4 - C2) / 2.
    """
    c2 = _global_cost(cfg.build_dryrun(shape, mesh, layers_override=2,
                                       unroll=True))
    c4 = _global_cost(cfg.build_dryrun(shape, mesh, layers_override=4,
                                       unroll=True))
    L = cfg.cfg.n_layers
    out = {}
    for key in ("flops", "bytes accessed"):
        per_layer = (c4[key] - c2[key]) / 2.0
        glob = max(c2[key] - 2 * per_layer + L * per_layer, 0.0)
        out[key] = glob / n_dev            # per-device share
        out[key + "_global"] = glob
    return out


def run_cell(arch_id: str, shape: str, mesh_kind: str,
             calibrate: bool = True) -> dict:
    cfg = get_config(arch_id)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_dev = int(np.prod(list(mesh.shape.values())))
    unit = cfg.build_dryrun(shape, mesh)
    t0 = time.time()
    jitted = jax.jit(unit.step_fn, in_shardings=unit.in_shardings,
                     out_shardings=unit.out_shardings,
                     donate_argnums=unit.donate)
    with mesh, jax.set_mesh(mesh):
        lowered = jitted.lower(*unit.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_rec = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        mem_rec[k] = int(getattr(mem, k, 0) or 0)
    cost_list = compiled.cost_analysis()
    cost = cost_list if isinstance(cost_list, dict) else (
        cost_list[0] if cost_list else {})
    cost_rec = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "bytes accessed", "transcendentals",
                 "bytes accessed operand 0 {}", "optimal_seconds")}
    census = collective_census(compiled.as_text())

    rec = {
        "arch": arch_id, "shape": shape, "mesh": mesh_kind,
        "n_devices": n_dev,
        "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem_rec,
        "cost": cost_rec,
        "collectives": census,
        "collective_wire_bytes_per_device": sum(
            v["wire_bytes"] for v in census.values()),
        "model_flops": float(cfg.model_flops(shape)),
    }
    # --- cost calibration (see _global_cost / lm_calibrated_cost) ---------
    # pattern cells are shard_map with unrolled chunk loops: the partitioned
    # per-device numbers above are already correct.  LM/GNN/recsys cells go
    # through the partitioner (windowed einsums) — recompute their
    # flops/bytes from unpartitioned lowerings.
    if calibrate and cfg.family == "lm":
        cal = lm_calibrated_cost(cfg, shape, mesh, n_dev)
        rec["cost_calibrated"] = cal
        rec["cost"]["flops"] = cal["flops"]
        rec["cost"]["bytes accessed"] = cal["bytes accessed"]
    elif calibrate and cfg.family in ("gnn", "recsys"):
        cal = _global_cost(unit)
        rec["cost_calibrated"] = {k: v / n_dev for k, v in cal.items()}
        rec["cost"]["flops"] = cal["flops"] / n_dev
        rec["cost"]["bytes accessed"] = cal["bytes accessed"] / n_dev
    elif calibrate and cfg.family == "pattern":
        # the artifact scans its matmul chunks (memory-lean); cost comes
        # from an unrolled lowering that counts every chunk.
        unit_u = cfg.build_dryrun(shape, mesh, unroll=True)
        jit_u = jax.jit(unit_u.step_fn, in_shardings=unit_u.in_shardings)
        with mesh, jax.set_mesh(mesh):
            comp_u = jit_u.lower(*unit_u.args).compile()
        cal = _cost_of(comp_u)
        rec["cost_calibrated"] = cal
        rec["cost"]["flops"] = cal["flops"]
        rec["cost"]["bytes accessed"] = cal["bytes accessed"]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--include-pattern", action="store_true", default=True)
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") == "ok"}

    archs = [args.arch] if args.arch else all_arch_ids()
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    for arch_id in archs:
        cfg = get_config(arch_id)
        shapes = [args.shape] if args.shape else list(cfg.shapes)
        for shape in shapes:
            for mesh_kind in meshes:
                key = (arch_id, shape, mesh_kind)
                if args.skip_existing and key in done:
                    print(f"[skip] {key}")
                    continue
                print(f"[dryrun] {arch_id} × {shape} × {mesh_kind} ...",
                      flush=True)
                try:
                    rec = run_cell(arch_id, shape, mesh_kind)
                    print(f"  ok: compile={rec['compile_s']}s "
                          f"flops/dev={rec['cost'].get('flops', 0):.3e} "
                          f"coll_B/dev={rec['collective_wire_bytes_per_device']:.3e}",
                          flush=True)
                except Exception as e:  # record failures; they are bugs
                    rec = {"arch": arch_id, "shape": shape,
                           "mesh": mesh_kind, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    print(f"  ERROR: {type(e).__name__}: {e}", flush=True)
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"]) != key]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"[dryrun] manifest: {args.out} — {n_ok}/{len(results)} ok")


if __name__ == "__main__":
    main()
