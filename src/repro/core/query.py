"""Hybrid graph pattern queries (Def. 3.3) and transitive reduction (§4).

A query is a small directed graph; every node carries a label; every edge is
either a *child* edge ``p/q`` (edge-to-edge mapping) or a *descendant* edge
``p//q`` (edge-to-path mapping).  §4 of the paper minimizes the number of
expensive descendant edges via transitive reduction under the inference
rules::

    (IR1)  x/y            ⊢  x//y
    (IR2)  x//y, y//z     ⊢  x//z
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence, Tuple

import numpy as np

CHILD = 0
DESC = 1

_KIND_STR = {CHILD: "/", DESC: "//"}


@dataclass(frozen=True)
class QueryEdge:
    src: int
    dst: int
    kind: int  # CHILD or DESC

    def __repr__(self) -> str:
        return f"{self.src}{_KIND_STR[self.kind]}{self.dst}"


@dataclass
class PatternQuery:
    """A connected, directed, node-labeled hybrid pattern."""

    labels: List[int]
    edges: List[QueryEdge]
    name: str = ""

    def __post_init__(self) -> None:
        es = []
        for e in self.edges:
            if not isinstance(e, QueryEdge):
                e = QueryEdge(int(e[0]), int(e[1]), int(e[2]))
            assert 0 <= e.src < self.n and 0 <= e.dst < self.n
            assert e.src != e.dst, "self-loop pattern edges are not supported"
            es.append(e)
        # dedup: a child edge subsumes a descendant edge on the same pair
        seen: dict[Tuple[int, int], int] = {}
        for e in es:
            key = (e.src, e.dst)
            seen[key] = min(seen.get(key, DESC + 1), e.kind)
        self.edges = [QueryEdge(s, d, k) for (s, d), k in sorted(seen.items())]

    # ------------------------------------------------------------------ views
    @property
    def n(self) -> int:
        return len(self.labels)

    @property
    def m(self) -> int:
        return len(self.edges)

    def out_edges(self, q: int) -> List[QueryEdge]:
        return [e for e in self.edges if e.src == q]

    def in_edges(self, q: int) -> List[QueryEdge]:
        return [e for e in self.edges if e.dst == q]

    def neighbors(self, q: int) -> List[int]:
        out = set()
        for e in self.edges:
            if e.src == q:
                out.add(e.dst)
            if e.dst == q:
                out.add(e.src)
        return sorted(out)

    def adjacency(self) -> np.ndarray:
        a = np.zeros((self.n, self.n), dtype=bool)
        for e in self.edges:
            a[e.src, e.dst] = True
        return a

    def is_connected(self) -> bool:
        if self.n == 0:
            return True
        a = self.adjacency()
        und = a | a.T
        seen = np.zeros(self.n, dtype=bool)
        stack = [0]
        seen[0] = True
        while stack:
            v = stack.pop()
            for w in np.nonzero(und[v])[0]:
                if not seen[w]:
                    seen[w] = True
                    stack.append(int(w))
        return bool(seen.all())

    def is_dag(self) -> bool:
        return self.topological_order() is not None

    def topological_order(self):
        """Kahn.  None if cyclic."""
        indeg = np.zeros(self.n, dtype=np.int64)
        for e in self.edges:
            indeg[e.dst] += 1
        order = [q for q in range(self.n) if indeg[q] == 0]
        head = 0
        while head < len(order):
            v = order[head]
            head += 1
            for e in self.out_edges(v):
                indeg[e.dst] -= 1
                if indeg[e.dst] == 0:
                    order.append(e.dst)
        return order if len(order) == self.n else None

    # --------------------------------------------------- closure / reduction
    def reachable_matrix(self, skip: QueryEdge | None = None) -> np.ndarray:
        """Boolean (n, n): r[x, y] = a (simple) directed path x -> y exists,
        optionally ignoring one edge.  Path length >= 1."""
        a = np.zeros((self.n, self.n), dtype=bool)
        for e in self.edges:
            if skip is not None and e == skip:
                continue
            a[e.src, e.dst] = True
        r = a.copy()
        for _ in range(self.n):
            nxt = r | (r @ a)
            if (nxt == r).all():
                break
            r = nxt
        return r

    def full_form(self) -> "PatternQuery":
        """The closure of the query under IR1/IR2 (§4, Fig. 2(b)): add a
        descendant edge for every inferable reachability relationship."""
        r = self.reachable_matrix()
        edges = list(self.edges)
        existing = {(e.src, e.dst) for e in self.edges}
        for x in range(self.n):
            for y in range(self.n):
                if x != y and r[x, y] and (x, y) not in existing:
                    edges.append(QueryEdge(x, y, DESC))
        return PatternQuery(labels=list(self.labels), edges=edges,
                            name=self.name + "+full")

    def transitive_reduction(self) -> "PatternQuery":
        """Remove redundant *descendant* edges (Def. 4.1): a descendant edge
        (x, y) is transitive if a directed path x -> y exists that does not
        use it.  Child edges are never removed (they constrain more).

        Edges are examined in a canonical order and the reachability test is
        recomputed after each removal so that two edges cannot "justify" each
        other's removal (matters only for cyclic patterns, where the
        reduction is not unique — we return one valid reduction).
        """
        edges = list(self.edges)
        changed = True
        while changed:
            changed = False
            for e in sorted((e for e in edges if e.kind == DESC),
                            key=lambda e: (e.src, e.dst)):
                q = PatternQuery(labels=list(self.labels),
                                 edges=[x for x in edges if x != e])
                if q.reachable_matrix()[e.src, e.dst]:
                    edges = q.edges
                    changed = True
                    break
        return PatternQuery(labels=list(self.labels), edges=edges,
                            name=(self.name + "+tr") if self.name else "tr")

    # ----------------------------------------------------- dag decomposition
    def dag_decomposition(self):
        """Split edges into a spanning DAG + back-edge set Δ (Alg. 3 line 4).

        DFS-based: an edge closing a cycle w.r.t. the DFS (i.e. pointing into
        the current stack) goes to Δ; everything else to the DAG part.
        """
        color = [0] * self.n   # 0 white, 1 gray, 2 black
        dag_edges: List[QueryEdge] = []
        back_edges: List[QueryEdge] = []
        out = {q: self.out_edges(q) for q in range(self.n)}

        def dfs(root: int):
            stack = [(root, 0)]
            color[root] = 1
            while stack:
                v, i = stack[-1]
                if i < len(out[v]):
                    stack[-1] = (v, i + 1)
                    e = out[v][i]
                    if color[e.dst] == 1:
                        back_edges.append(e)
                    else:
                        dag_edges.append(e)
                        if color[e.dst] == 0:
                            color[e.dst] = 1
                            stack.append((e.dst, 0))
                else:
                    color[v] = 2
                    stack.pop()

        for q in range(self.n):
            if color[q] == 0:
                dfs(q)
        # The DAG part might still be cyclic through cross edges in rare
        # multi-root cases; verify and demote offenders.
        dag = PatternQuery(labels=list(self.labels), edges=dag_edges)
        while not dag.is_dag():
            # demote one edge on a cycle
            for e in list(dag.edges):
                test = PatternQuery(labels=list(self.labels),
                                    edges=[x for x in dag.edges if x != e])
                rm = test.reachable_matrix()
                if rm[e.dst, e.src]:   # e closes a cycle
                    back_edges.append(e)
                    dag = test
                    break
            else:
                break
        return dag, back_edges

    # --------------------------------------------------------------- pretty
    def __repr__(self) -> str:
        lab = ",".join(map(str, self.labels))
        ed = " ".join(map(repr, self.edges))
        return f"PatternQuery<{self.name}|labels=[{lab}]|{ed}>"


def query(labels: Sequence[int], edges: Sequence[Tuple[int, int, int]],
          name: str = "") -> PatternQuery:
    return PatternQuery(labels=list(labels),
                        edges=[QueryEdge(*e) for e in edges], name=name)


def paper_example_query() -> PatternQuery:
    """Query Q of Fig. 1(b): A -> B (child), C -> B (child), A // C, B // D,
    D // E, C // E  (labels a=0, b=1, c=2, d=3, e=4)."""
    return query(
        labels=[0, 1, 2, 3, 4],
        edges=[(0, 1, CHILD), (2, 1, CHILD), (0, 2, DESC),
               (1, 3, DESC), (3, 4, DESC), (2, 4, DESC)],
        name="fig1b",
    )
