"""GM — the paper's end-to-end graph-pattern matcher (§5 + §6).

Pipeline: transitive reduction → double simulation (node selection) →
RIG expansion → JO search ordering → MJoin enumeration.  Options expose the
paper's ablation variants:

* ``GM``     — everything on (dagmap simulation, transitive reduction, JO);
* ``GM-S``   — no node pre-filtering (that is the default: the paper only
  adds pre-filtering for C-queries where noted);
* ``GM-F``   — pre-filtering *instead of* double simulation (Fig. 9);
* ``GM-NR``  — no transitive reduction (Fig. 11).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from .graph import DataGraph
from .mjoin import DEFAULT_LIMIT, MJoinResult, mjoin
from .ordering import get_order
from .query import PatternQuery
from .rig import RIG, SimAlgo, build_rig
from .simulation import EdgeOracle


@dataclass
class GMOptions:
    use_transitive_reduction: bool = True
    sim_algo: SimAlgo = "dagmap"         # bas | dag | dagmap | none
    sim_passes: Optional[int] = 4        # paper's N=4 truncation; None = exact
    use_prefilter: bool = False
    check_method: str = "bitbat"         # binsearch | bititer | bitbat
    ordering: str = "jo"                 # jo | ri | bj
    enum_method: str = "backtrack"       # backtrack | frontier | frontier-device
    expand_method: str = "bitset"        # bitset | interval (§5.5 early term.)
    limit: Optional[int] = DEFAULT_LIMIT
    materialize: bool = True
    max_tuples: int = 1_000_000


@dataclass
class MatchResult:
    count: int
    tuples: Optional[np.ndarray]
    order: List[int]
    rig_nodes: int
    rig_edges: int
    matching_s: float          # TR + simulation + RIG + ordering
    enumerate_s: float
    total_s: float
    sim_passes: int
    truncated: bool
    enum_method: str = "backtrack"       # strategy that actually ran
    rig: Optional[RIG] = field(default=None, repr=False)


class GM:
    """Reusable matcher bound to one data graph (shares the reachability
    index and packed adjacency across queries — those are *data* indexes;
    the RIG itself is rebuilt per query, as in the paper)."""

    def __init__(self, graph: DataGraph, options: Optional[GMOptions] = None,
                 intervals=None):
        self.graph = graph
        self.options = options or GMOptions()
        self.oracle = EdgeOracle(graph)
        # DFS interval labels for the §5.5 early-expansion-termination path
        # (expand_method="interval"); the engine shares its per-graph labels
        self.intervals = intervals

    def match(self, q: PatternQuery,
              options: Optional[GMOptions] = None) -> MatchResult:
        opt = options or self.options
        if opt.expand_method == "interval" and self.intervals is None:
            from .reachability import IntervalLabels
            self.intervals = IntervalLabels.build(self.graph)
        t0 = time.perf_counter()
        if opt.use_transitive_reduction:
            q = q.transitive_reduction()
        rig = build_rig(self.graph, q, self.oracle,
                        sim_algo=opt.sim_algo, sim_passes=opt.sim_passes,
                        use_prefilter=opt.use_prefilter,
                        check_method=opt.check_method,
                        expand_method=opt.expand_method,
                        intervals=self.intervals)
        if rig.is_empty():
            t1 = time.perf_counter()
            return MatchResult(
                count=0,
                tuples=np.empty((0, q.n), dtype=np.int64) if opt.materialize else None,
                order=list(range(q.n)), rig_nodes=rig.n_nodes(), rig_edges=0,
                matching_s=t1 - t0, enumerate_s=0.0, total_s=t1 - t0,
                sim_passes=rig.sim.passes if rig.sim else 0, truncated=False,
                enum_method=opt.enum_method, rig=rig)
        order = get_order(rig, opt.ordering)
        t1 = time.perf_counter()
        res: MJoinResult = mjoin(rig, order, limit=opt.limit,
                                 materialize=opt.materialize,
                                 max_tuples=opt.max_tuples,
                                 method=opt.enum_method)
        t2 = time.perf_counter()
        return MatchResult(
            count=res.count, tuples=res.tuples, order=order,
            rig_nodes=rig.n_nodes(), rig_edges=rig.n_edges(),
            matching_s=t1 - t0, enumerate_s=t2 - t1, total_s=t2 - t0,
            sim_passes=rig.sim.passes if rig.sim else 0,
            truncated=res.stats.truncated, enum_method=res.stats.method,
            rig=rig)


def match(graph: DataGraph, q: PatternQuery, **kwargs) -> MatchResult:
    """One-shot convenience wrapper."""
    return GM(graph, GMOptions(**kwargs)).match(q)
