"""GM — the paper's end-to-end graph-pattern matcher (§5 + §6).

Pipeline: transitive reduction → double simulation (node selection) →
RIG expansion → JO search ordering → MJoin enumeration.  Options expose the
paper's ablation variants:

* ``GM``     — everything on (dagmap simulation, transitive reduction, JO);
* ``GM-S``   — no node pre-filtering (that is the default: the paper only
  adds pre-filtering for C-queries where noted);
* ``GM-F``   — pre-filtering *instead of* double simulation (Fig. 9);
* ``GM-NR``  — no transitive reduction (Fig. 11).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from .graph import DataGraph
from .mjoin import (DEFAULT_LIMIT, MJoinResult, MJoinStream, iter_tuples,
                    mjoin, mjoin_batched)
from .ordering import get_order
from .query import PatternQuery
from .rig import RIG, SimAlgo, build_rig
from .simulation import EdgeOracle
from ..obs.trace import NULL_TRACER


@dataclass
class GMOptions:
    use_transitive_reduction: bool = True
    sim_algo: SimAlgo = "dagmap"         # bas | dag | dagmap | none
    sim_passes: Optional[int] = 4        # paper's N=4 truncation; None = exact
    use_prefilter: bool = False
    check_method: str = "bitbat"         # binsearch | bititer | bitbat
    ordering: str = "jo"                 # jo | ri | bj
    enum_method: str = "backtrack"       # see repro.core.mjoin.ENUM_METHODS
    expand_method: str = "bitset"        # bitset | interval (§5.5 early term.)
    limit: Optional[int] = DEFAULT_LIMIT
    materialize: bool = True
    max_tuples: int = 1_000_000
    # device slabs below this many rows are routed through the host
    # intersect (padded-dispatch floor makes them device-unprofitable);
    # 0 = off.  The planner sets this for engine-planned device queries.
    small_frontier_rows: int = 0
    # resource governance (PR 7): an *armed* repro.robust.Budget governing
    # this match (deadline / RIG memory / frontier caps) and the engine's
    # shared device CircuitBreaker; None = ungoverned (zero overhead)
    budget: Optional[object] = field(default=None, repr=False, compare=False)
    breaker: Optional[object] = field(default=None, repr=False, compare=False)
    # warm-path reuse (PR 10): a cached device-resident executor
    # (jaxgm.frontier.ResidentIntersector) from a previous enumeration of
    # the same (graph, canonical query).  Attached to the freshly built RIG
    # when its shape fingerprint matches, skipping the re-upload; a
    # mismatch is ignored (a fresh upload happens as usual).
    resident_executor: Optional[object] = field(default=None, repr=False,
                                                compare=False)


@dataclass
class MatchResult:
    count: int
    tuples: Optional[np.ndarray]
    order: List[int]
    rig_nodes: int
    rig_edges: int
    matching_s: float          # TR + simulation + RIG + ordering
    enumerate_s: float
    total_s: float
    sim_passes: int
    truncated: bool
    enum_method: str = "backtrack"       # strategy that actually ran
    deadline_exceeded: bool = False      # budget deadline cut enumeration
    degradations: List[str] = field(default_factory=list)
    # resident-path observability (frontier-device-resident only; zero else)
    resident_uploads: int = 0            # RIG matrices uploaded (0 = cached)
    resident_bytes: int = 0              # resident matrix footprint
    resident_dispatches: int = 0         # fused gather+AND device dispatches
    small_frontier_host_routed: int = 0  # slabs host-routed below threshold
    # transfer ledger (PR 10): host<->device bytes this match moved
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    rig: Optional[RIG] = field(default=None, repr=False)


@dataclass
class MatchStream:
    """Streaming counterpart of :class:`MatchResult`.

    Iterate for ``(chunk, q.n)`` int64 tuple chunks (global ids, query-node
    order) in one-shot lexicographic order; the RIG front half has already
    run (``matching_s``), enumeration advances lazily as chunks are
    consumed.  ``count`` / ``truncated`` / ``enum_method`` are live views
    of the underlying :class:`~repro.core.mjoin.MJoinStream` and are final
    once iteration ends."""

    query: PatternQuery
    stream: MJoinStream
    order: List[int]
    rig_nodes: int
    rig_edges: int
    matching_s: float
    sim_passes: int
    rig: Optional[RIG] = field(default=None, repr=False)

    def __iter__(self):
        return iter(self.stream)

    def close(self) -> None:
        self.stream.close()

    @property
    def count(self) -> int:
        return self.stream.count

    @property
    def truncated(self) -> bool:
        return self.stream.stats.truncated

    @property
    def enum_method(self) -> str:
        return self.stream.stats.method

    @property
    def enumerate_s(self) -> float:
        return self.stream.stats.enumerate_s

    @property
    def deadline_exceeded(self) -> bool:
        return self.stream.stats.deadline_exceeded

    @property
    def degradations(self) -> List[str]:
        return self.stream.stats.degradations

    @property
    def resident_uploads(self) -> int:
        return self.stream.stats.resident_uploads

    @property
    def resident_bytes(self) -> int:
        return self.stream.stats.resident_bytes

    @property
    def resident_dispatches(self) -> int:
        st = self.stream.stats
        return st.device_calls if st.method == "frontier-device-resident" \
            else 0

    @property
    def small_frontier_host_routed(self) -> int:
        return self.stream.stats.small_frontier_host_routed

    @property
    def h2d_bytes(self) -> int:
        return self.stream.stats.h2d_bytes

    @property
    def d2h_bytes(self) -> int:
        return self.stream.stats.d2h_bytes


class GM:
    """Reusable matcher bound to one data graph (shares the reachability
    index and packed adjacency across queries — those are *data* indexes;
    the RIG itself is rebuilt per query, as in the paper)."""

    def __init__(self, graph: DataGraph, options: Optional[GMOptions] = None,
                 intervals=None):
        self.graph = graph
        self.options = options or GMOptions()
        self.oracle = EdgeOracle(graph)
        # DFS interval labels for the §5.5 early-expansion-termination path
        # (expand_method="interval"); the engine shares its per-graph labels
        self.intervals = intervals

    def prepare_rig(self, q: PatternQuery,
                    options: Optional[GMOptions] = None,
                    trace=NULL_TRACER):
        """The matching front half shared by every consumption mode:
        TR + double simulation + RIG expansion + search ordering.

        Returns ``(q, rig, order, matching_s)`` — ``q`` already reduced and
        ``order`` the enumeration order (identity for an empty RIG)."""
        opt = options or self.options
        if opt.expand_method == "interval" and self.intervals is None:
            from .reachability import IntervalLabels
            self.intervals = IntervalLabels.build(self.graph)
        t0 = time.perf_counter()
        with trace.span("rig") as sp:
            if opt.use_transitive_reduction:
                q = q.transitive_reduction()
            rig = build_rig(self.graph, q, self.oracle,
                            sim_algo=opt.sim_algo, sim_passes=opt.sim_passes,
                            use_prefilter=opt.use_prefilter,
                            check_method=opt.check_method,
                            expand_method=opt.expand_method,
                            intervals=self.intervals, trace=trace,
                            budget=opt.budget)
            ex = opt.resident_executor
            if (ex is not None and rig.resident is None
                    and not getattr(ex, "closed", False)):
                from ..jaxgm.frontier import resident_fingerprint
                if getattr(ex, "fingerprint",
                           None) == resident_fingerprint(rig):
                    rig.resident = ex     # warm reuse: skip the re-upload
            with trace.span("order") as osp:
                order = (list(range(q.n)) if rig.is_empty()
                         else get_order(rig, opt.ordering))
                osp.set(ordering=opt.ordering, order=list(order))
            if trace.enabled:
                sp.set(rig_nodes=rig.n_nodes(),
                       rig_edges=0 if rig.is_empty() else rig.n_edges(),
                       empty=rig.is_empty())
        return q, rig, order, time.perf_counter() - t0

    def match(self, q: PatternQuery,
              options: Optional[GMOptions] = None,
              trace=NULL_TRACER) -> MatchResult:
        opt = options or self.options
        q, rig, order, matching_s = self.prepare_rig(q, opt, trace=trace)
        t1 = time.perf_counter()
        res: MJoinResult = mjoin(rig, order, limit=opt.limit,
                                 materialize=opt.materialize,
                                 max_tuples=opt.max_tuples,
                                 method=opt.enum_method, trace=trace,
                                 budget=opt.budget, breaker=opt.breaker,
                                 small_frontier_rows=opt.small_frontier_rows)
        t2 = time.perf_counter()
        st = res.stats
        return MatchResult(
            count=res.count, tuples=res.tuples, order=order,
            rig_nodes=rig.n_nodes(),
            rig_edges=0 if rig.is_empty() else rig.n_edges(),
            matching_s=matching_s, enumerate_s=t2 - t1,
            total_s=matching_s + (t2 - t1),
            sim_passes=rig.sim.passes if rig.sim else 0,
            truncated=st.truncated,
            enum_method=(opt.enum_method if rig.is_empty() else st.method),
            deadline_exceeded=st.deadline_exceeded,
            degradations=st.degradations,
            resident_uploads=st.resident_uploads,
            resident_bytes=st.resident_bytes,
            resident_dispatches=(st.device_calls
                                 if st.method == "frontier-device-resident"
                                 else 0),
            small_frontier_host_routed=st.small_frontier_host_routed,
            h2d_bytes=st.h2d_bytes, d2h_bytes=st.d2h_bytes,
            rig=rig)

    def match_stream(self, q: PatternQuery,
                     options: Optional[GMOptions] = None,
                     chunk_size: int = 1024,
                     trace=NULL_TRACER) -> "MatchStream":
        """Streaming counterpart of :meth:`match`: the RIG is built eagerly
        (node selection is existence-checking, not enumeration) but the
        MJoin enumeration is lazy — iterate the returned
        :class:`MatchStream` for ``(chunk_size, q.n)`` tuple chunks in the
        same lexicographic order as one-shot matching."""
        opt = options or self.options
        q, rig, order, matching_s = self.prepare_rig(q, opt, trace=trace)
        stream = iter_tuples(rig, order, chunk_size=chunk_size,
                             limit=opt.limit, method=opt.enum_method,
                             budget=opt.budget, breaker=opt.breaker,
                             small_frontier_rows=opt.small_frontier_rows)
        return MatchStream(query=q, stream=stream, order=order,
                           rig_nodes=rig.n_nodes(),
                           rig_edges=0 if rig.is_empty() else rig.n_edges(),
                           matching_s=matching_s,
                           sim_passes=rig.sim.passes if rig.sim else 0,
                           rig=rig)

    def match_batch_frontier(self, queries: List[PatternQuery],
                             options: Optional[List[GMOptions]] = None,
                             *, intersector=None, traces=None):
        """Counting-mode batch with cross-query micro-batched frontier
        dispatches: every query's RIG is built on the host, then all
        enumerations run under one scheduler that fuses their per-level
        ``(F, K, W)`` constraint gathers into a single ``(ΣF, K, W)`` slab
        per round (one ``intersect`` dispatch shared by the whole batch —
        see :func:`repro.core.mjoin.mjoin_batched`).

        Returns ``(results, dispatches)``; per-query counts equal
        ``match(q, materialize=False)``."""
        opts = options or [self.options] * len(queries)
        trs = traces or [NULL_TRACER] * len(queries)
        jobs, metas, budgets = [], [], []
        breaker = None
        for q, opt, tr in zip(queries, opts, trs):
            q, rig, order, matching_s = self.prepare_rig(q, opt, trace=tr)
            jobs.append((rig, order, opt.limit))
            metas.append((q, rig, order, matching_s))
            budgets.append(opt.budget)
            breaker = breaker or opt.breaker
        mj, dispatches = mjoin_batched(
            jobs, intersector=intersector,
            budgets=budgets if any(b is not None for b in budgets) else None,
            breaker=breaker)
        out = []
        for (q, rig, order, matching_s), res in zip(metas, mj):
            out.append(MatchResult(
                count=res.count, tuples=None, order=order,
                rig_nodes=rig.n_nodes(),
                rig_edges=0 if rig.is_empty() else rig.n_edges(),
                matching_s=matching_s, enumerate_s=res.stats.enumerate_s,
                total_s=matching_s + res.stats.enumerate_s,
                sim_passes=rig.sim.passes if rig.sim else 0,
                truncated=res.stats.truncated,
                enum_method=res.stats.method,
                deadline_exceeded=res.stats.deadline_exceeded,
                degradations=res.stats.degradations,
                resident_uploads=res.stats.resident_uploads,
                resident_bytes=res.stats.resident_bytes,
                small_frontier_host_routed=(
                    res.stats.small_frontier_host_routed),
                h2d_bytes=res.stats.h2d_bytes,
                d2h_bytes=res.stats.d2h_bytes, rig=rig))
        return out, dispatches


def match(graph: DataGraph, q: PatternQuery, **kwargs) -> MatchResult:
    """One-shot convenience wrapper."""
    return GM(graph, GMOptions(**kwargs)).match(q)
