"""Double simulation (§5.2-§5.4): FBSimBas, FBSimDag and FBSim (Dag+Δ).

The double simulation ``FB`` of query Q by graph G is the largest relation
S ⊆ V_Q × V_G preserving labels plus, for every query edge, the *forward*
(outgoing) and *backward* (incoming) child/descendant constraints.  We
compute it by pruning from the match sets ``ms(q)`` (label inverted lists)
until fixpoint — or until a pass budget is exhausted (§5.5 recommends N=4;
truncation keeps ``FB`` a sound over-approximation, which is all BuildRIG
needs).

Three candidate-check implementations are provided, mirroring Fig. 8(a):

* ``binsearch`` — per-node binary search on sorted CSR adjacency rows,
* ``bititer``   — per-node packed-word AND against the candidate bitset,
* ``bitbat``    — whole-list batched bitset op (the paper's §5.5 batch
  checking; a boolean matrix-vector product over packed rows).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Literal, Optional

import numpy as np

from . import bitset
from .graph import DataGraph
from .query import CHILD, DESC, PatternQuery, QueryEdge

CheckMethod = Literal["binsearch", "bititer", "bitbat"]


# ------------------------------------------------------------------- oracle
@dataclass
class EdgeOracle:
    """Match-set oracle for query edges (child -> adjacency, desc -> ≺).

    Packed row accessors return the set of forward/backward *matches* of a
    node w.r.t. an edge kind; these are exactly the adjacency lists of the
    (maximal) RIG and the operands of every bitset op in §5.5.
    """

    graph: DataGraph
    _reach: object = field(default=None, repr=False)

    def __post_init__(self):
        if self._reach is None:
            self._reach = self.graph.reachability()

    # --- packed rows -------------------------------------------------------
    def fwd_row(self, v: int, kind: int) -> np.ndarray:
        """Packed successors of v under the edge kind (children or ≺-set)."""
        if kind == CHILD:
            return self.graph.adj_bits()[v]
        return self._reach.reach_bits[v]

    def bwd_row(self, v: int, kind: int) -> np.ndarray:
        """Packed predecessors of v under the edge kind."""
        if kind == CHILD:
            return self.graph.adj_bits_t()[v]
        return self._reach.bits_t()[v]

    def fwd_matrix(self, kind: int) -> np.ndarray:
        return self.graph.adj_bits() if kind == CHILD else self._reach.reach_bits

    def bwd_matrix(self, kind: int) -> np.ndarray:
        return self.graph.adj_bits_t() if kind == CHILD else self._reach.bits_t()

    # --- scalar checks -----------------------------------------------------
    def is_match(self, u: int, v: int, kind: int) -> bool:
        if kind == CHILD:
            return self.graph.has_edge(u, v)
        return self._reach.reaches(u, v)


def match_sets(graph: DataGraph, q: PatternQuery) -> List[np.ndarray]:
    """ms(q) for every query node, as packed bitsets over V_G."""
    return [graph.label_bits(l) for l in q.labels]


# ------------------------------------------------- single-constraint pruning
def _prune_once(fb_keep: np.ndarray, other: np.ndarray, matrix: np.ndarray,
                rows_of, method: CheckMethod, graph: DataGraph,
                kind: int, forward: bool, oracle: EdgeOracle) -> np.ndarray:
    """Keep v ∈ fb_keep iff row(v) ∩ other ≠ ∅.  Returns new packed fb_keep.

    ``matrix`` is the packed fwd/bwd row matrix matching direction+kind.
    """
    n = graph.n
    if method == "bitbat":
        # whole-pass batched op: survivors = { v : matrix[v] ∩ other ≠ ∅ }
        alive = bitset.matvec_any(matrix, other)           # bool (n,)
        return fb_keep & bitset.pack(alive)
    cand = bitset.to_indices(fb_keep, n)
    if method == "bititer":
        keep = fb_keep.copy()
        for v in cand:
            if not bitset.intersect_any(matrix[v], other):
                bitset.clear_bit(keep, int(v))
        return keep
    # binsearch: sorted-list membership per neighbour (CSR for child edges;
    # for descendant edges fall back to packed check — the paper's setting
    # uses the reachability index there, not binary search).
    keep = fb_keep.copy()
    other_idx = bitset.to_indices(other, n)
    for v in cand:
        ok = False
        if kind == CHILD:
            row = (graph.children(int(v)) if forward else graph.parents(int(v)))
            if len(row) and len(other_idx):
                pos = np.searchsorted(row, other_idx)
                pos = np.clip(pos, 0, len(row) - 1)
                ok = bool((row[pos] == other_idx).any())
        else:
            ok = bitset.intersect_any(matrix[v], other)
        if not ok:
            bitset.clear_bit(keep, int(v))
    return keep


# ----------------------------------------------------------------- FBSimBas
@dataclass
class SimResult:
    fb: List[np.ndarray]          # packed FB(q) per query node
    passes: int
    converged: bool
    pruned: int                   # total nodes pruned from the match sets
    checks: int = 0               # constraint evaluations (for benchmarks)


def fb_sim_bas(graph: DataGraph, q: PatternQuery, oracle: Optional[EdgeOracle] = None,
               max_passes: Optional[int] = None,
               method: CheckMethod = "bitbat",
               fb0: Optional[List[np.ndarray]] = None) -> SimResult:
    """Algorithm 1 — baseline double-simulation fixpoint.

    Visits query edges in arbitrary (given) order; each pass runs
    forwardPrune then backwardPrune over *all* edges.
    """
    oracle = oracle or EdgeOracle(graph)
    fb = [b.copy() for b in (fb0 or match_sets(graph, q))]
    initial = sum(bitset.count(b) for b in fb)
    passes = 0
    checks = 0
    converged = False
    limit = max_passes if max_passes is not None else 10 * (q.n + 1) * graph.n
    while passes < limit:
        passes += 1
        changed = False
        # forwardPrune: for each edge (qi, qj), prune v from FB(qi) lacking a
        # qualifying successor in FB(qj).
        for e in q.edges:
            new = _prune_once(fb[e.src], fb[e.dst], oracle.fwd_matrix(e.kind),
                              None, method, graph, e.kind, True, oracle)
            checks += 1
            if not np.array_equal(new, fb[e.src]):
                fb[e.src] = new
                changed = True
        # backwardPrune
        for e in q.edges:
            new = _prune_once(fb[e.dst], fb[e.src], oracle.bwd_matrix(e.kind),
                              None, method, graph, e.kind, False, oracle)
            checks += 1
            if not np.array_equal(new, fb[e.dst]):
                fb[e.dst] = new
                changed = True
        if not changed:
            converged = True
            break
    final = sum(bitset.count(b) for b in fb)
    return SimResult(fb=fb, passes=passes, converged=converged,
                     pruned=initial - final, checks=checks)


# ----------------------------------------------------------------- FBSimDag
def fb_sim_dag(graph: DataGraph, q: PatternQuery, oracle: Optional[EdgeOracle] = None,
               max_passes: Optional[int] = None,
               method: CheckMethod = "bitbat",
               fb0: Optional[List[np.ndarray]] = None,
               use_change_flags: bool = True) -> SimResult:
    """Algorithm 2 — exploit DAG structure: each pass is one bottom-up
    (reverse topological) forward sweep + one top-down backward sweep.

    ``use_change_flags`` enables the §5.5 convergence speedup: an edge
    constraint is re-checked only if the other endpoint's candidate set
    changed in the previous sweep ("DagMap" in Fig. 8(b)).
    """
    oracle = oracle or EdgeOracle(graph)
    topo = q.topological_order()
    assert topo is not None, "fb_sim_dag requires a DAG pattern"
    fb = [b.copy() for b in (fb0 or match_sets(graph, q))]
    initial = sum(bitset.count(b) for b in fb)
    dirty = [True] * q.n         # change flags per query node
    passes = 0
    checks = 0
    converged = False
    limit = max_passes if max_passes is not None else 10 * (q.n + 1) * graph.n
    while passes < limit:
        passes += 1
        changed = False
        next_dirty = [False] * q.n
        # forwardSim: reverse topological order, outgoing edges
        for qi in reversed(topo):
            for e in q.out_edges(qi):
                if use_change_flags and not (dirty[e.dst] or dirty[e.src]):
                    continue
                new = _prune_once(fb[qi], fb[e.dst], oracle.fwd_matrix(e.kind),
                                  None, method, graph, e.kind, True, oracle)
                checks += 1
                if not np.array_equal(new, fb[qi]):
                    fb[qi] = new
                    changed = True
                    next_dirty[qi] = True
        # backwardSim: topological order, incoming edges
        for qi in topo:
            for e in q.in_edges(qi):
                if use_change_flags and not (dirty[e.src] or next_dirty[e.src]
                                             or dirty[qi] or next_dirty[qi]):
                    continue
                new = _prune_once(fb[qi], fb[e.src], oracle.bwd_matrix(e.kind),
                                  None, method, graph, e.kind, False, oracle)
                checks += 1
                if not np.array_equal(new, fb[qi]):
                    fb[qi] = new
                    changed = True
                    next_dirty[qi] = True
        dirty = next_dirty
        if not changed:
            converged = True
            break
    final = sum(bitset.count(b) for b in fb)
    return SimResult(fb=fb, passes=passes, converged=converged,
                     pruned=initial - final, checks=checks)


# -------------------------------------------------------------------- FBSim
def fb_sim(graph: DataGraph, q: PatternQuery, oracle: Optional[EdgeOracle] = None,
           max_passes: Optional[int] = None,
           method: CheckMethod = "bitbat",
           use_change_flags: bool = True) -> SimResult:
    """Algorithm 3 — Dag+Δ: decompose Q into a DAG plus back edges, iterate
    (FBSimDag on the DAG part; FBSimBas sweeps on Δ) until stable."""
    oracle = oracle or EdgeOracle(graph)
    if q.is_dag():
        return fb_sim_dag(graph, q, oracle, max_passes=max_passes, method=method,
                          use_change_flags=use_change_flags)
    q_dag, back = q.dag_decomposition()
    delta = PatternQuery(labels=list(q.labels), edges=back) if back else None
    fb = match_sets(graph, q)
    initial = sum(bitset.count(b) for b in fb)
    passes = 0
    checks = 0
    converged = False
    outer_limit = max_passes if max_passes is not None else 10 * (q.n + 1) * graph.n
    while passes < outer_limit:
        passes += 1
        before = [b.copy() for b in fb]
        r1 = fb_sim_dag(graph, q_dag, oracle, max_passes=max_passes, method=method,
                        fb0=fb, use_change_flags=use_change_flags)
        fb = r1.fb
        checks += r1.checks
        if delta is not None:
            r2 = fb_sim_bas(graph, delta, oracle, max_passes=max_passes,
                            method=method, fb0=fb)
            fb = r2.fb
            checks += r2.checks
        if all(np.array_equal(a, b) for a, b in zip(before, fb)):
            converged = True
            break
    final = sum(bitset.count(b) for b in fb)
    return SimResult(fb=fb, passes=passes, converged=converged,
                     pruned=initial - final, checks=checks)
