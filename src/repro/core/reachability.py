"""Node-reachability substrate (Def. 3.2 and §5.5).

The paper plugs in *any* reachability labeling scheme; its experiments use
BFL (Bloom Filter Labeling [39]) plus plain adjacency for child edges.  We
provide three interchangeable components:

``ReachabilityIndex``
    SCC condensation + packed-bit transitive closure over the condensation
    DAG.  Exact, O(n·E/64) time, n²/64 bytes.  This powers the *bitset batch*
    operations (matvec-style existence checks and adjacency-row intersection)
    that the device path accelerates with the ``bitmm`` kernel.

``IntervalLabels``
    DFS (begin, end) intervals on a DAG — used for the paper's *early
    expansion termination* (§5.5): within a DAG, ``u`` cannot reach ``v``
    whenever ``u.end < v.begin``.

``BFL``
    A faithful-in-spirit Bloom Filter Labeling: per-node k-bit bloom
    summaries of the reachable set, computed bottom-up over the condensation
    DAG, used as a *negative* filter in front of a guided DFS.  Probe-style
    API (``reaches(u, v)``) like the original; no false negatives.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from . import bitset
from .graph import DataGraph


# --------------------------------------------------------------------------- SCC
def strongly_connected_components(graph: DataGraph):
    """Iterative Tarjan.  Returns (comp_id per node, n_comps).

    Component ids are numbered in *reverse topological order of the
    condensation* (i.e. comp(u) >= comp(v) whenever u can reach v in distinct
    components gets comp(u) > comp(v) after the flip below we instead
    guarantee topological order: comp(u) < comp(v) => u cannot be reached
    from v).  We post-process to a forward topological numbering.
    """
    n = graph.n
    index = np.full(n, -1, dtype=np.int64)
    low = np.zeros(n, dtype=np.int64)
    on_stack = np.zeros(n, dtype=bool)
    comp = np.full(n, -1, dtype=np.int64)
    stack: list[int] = []
    next_index = 0
    n_comps = 0

    indptr, indices = graph.fwd_indptr, graph.fwd_indices

    for root in range(n):
        if index[root] != -1:
            continue
        # each frame: (node, next child pointer)
        work = [(root, indptr[root])]
        index[root] = low[root] = next_index
        next_index += 1
        stack.append(root)
        on_stack[root] = True
        while work:
            v, ptr = work[-1]
            if ptr < indptr[v + 1]:
                work[-1] = (v, ptr + 1)
                w = indices[ptr]
                if index[w] == -1:
                    index[w] = low[w] = next_index
                    next_index += 1
                    stack.append(int(w))
                    on_stack[w] = True
                    work.append((int(w), indptr[w]))
                elif on_stack[w]:
                    low[v] = min(low[v], index[w])
            else:
                work.pop()
                if work:
                    pv = work[-1][0]
                    low[pv] = min(low[pv], low[v])
                if low[v] == index[v]:
                    while True:
                        w = stack.pop()
                        on_stack[w] = False
                        comp[w] = n_comps
                        if w == v:
                            break
                    n_comps += 1
    # Tarjan emits components in reverse topological order -> flip.
    comp = (n_comps - 1) - comp
    return comp, n_comps


# ------------------------------------------------------- condensation utils
def _condensation_csr(comp: np.ndarray, n_comps: int, edges: np.ndarray,
                      reverse: bool = False):
    """Deduplicated condensation-DAG adjacency as CSR ``(indptr, succs)``.

    Vectorized: maps every data edge to its component pair, drops
    intra-component pairs, and dedupes with one ``np.unique`` over the
    pair array (no per-edge Python loop).
    """
    if len(edges) == 0:
        return np.zeros(n_comps + 1, dtype=np.int64), \
            np.empty(0, dtype=np.int64)
    cs = comp[edges[:, 0]]
    cd = comp[edges[:, 1]]
    if reverse:
        cs, cd = cd, cs
    keep = cs != cd
    pairs = np.unique(np.stack([cs[keep], cd[keep]], axis=1), axis=0)
    indptr = np.searchsorted(pairs[:, 0], np.arange(n_comps + 1))
    return indptr, pairs[:, 1]


def _self_loop_mask(graph: DataGraph) -> np.ndarray:
    mask = np.zeros(graph.n, dtype=bool)
    if graph.n_edges:
        sl = graph.edges[:, 0] == graph.edges[:, 1]
        mask[graph.edges[sl, 0]] = True
    return mask


# ------------------------------------------------------------------- closure
@dataclass
class ReachabilityIndex:
    """Exact reachability via condensation + packed closure.

    ``reach_bits`` is a packed bit matrix (n, W): row u = set of nodes v with
    u ≺ v (strict per Def. 3.2 — v reachable by a path of length >= 1; a node
    reaches itself only if it lies on a cycle).
    """

    n: int
    comp: np.ndarray              # (n,) component id, topologically numbered
    reach_bits: np.ndarray        # (n, W) packed, node-level closure
    comp_sizes: np.ndarray = None  # (n_comps,) members per component
    reach_bits_t: Optional[np.ndarray] = None   # transpose, built lazily

    @staticmethod
    def build(graph: DataGraph) -> "ReachabilityIndex":
        n = graph.n
        comp, n_comps = strongly_connected_components(graph)
        comp_sizes = np.bincount(comp, minlength=n_comps)

        W = bitset.n_words(n)
        # members packed per component — one vectorized bit scatter
        cmembers = np.zeros((n_comps, W), dtype=np.uint64)
        if n:
            v = np.arange(n)
            np.bitwise_or.at(cmembers, (comp, v >> 6),
                             np.uint64(1) << (v & 63).astype(np.uint64))

        indptr, succs = _condensation_csr(comp, n_comps, graph.edges)
        # components whose members are self-reachable: non-trivial SCCs and
        # singleton components carrying a self loop
        has_loop = np.zeros(n_comps, dtype=bool)
        has_loop[comp[_self_loop_mask(graph)]] = True
        own = (comp_sizes > 1) | has_loop

        # creach[c] = packed set of *data nodes* reachable from component c,
        # including c's own members iff it is cyclic — strictness handled
        # at node level below.  Reverse topological order = descending id.
        creach = np.zeros((n_comps, W), dtype=np.uint64)
        for c in range(n_comps - 1, -1, -1):
            row = succs[indptr[c]:indptr[c + 1]]
            if len(row):
                acc = np.bitwise_or.reduce(creach[row] | cmembers[row],
                                           axis=0)
            else:
                acc = np.zeros(W, dtype=np.uint64)
            if own[c]:
                acc |= cmembers[c]
            creach[c] = acc

        reach = creach[comp]  # (n, W): every node inherits its component row
        return ReachabilityIndex(n=n, comp=comp, reach_bits=reach,
                                 comp_sizes=comp_sizes)

    # ------------------------------------------------------------- interface
    def reaches(self, u: int, v: int) -> bool:
        """u ≺ v (Def. 3.2)."""
        return bitset.get(self.reach_bits[u], v)

    def reach_row(self, u: int) -> np.ndarray:
        """Packed descendant set of u."""
        return self.reach_bits[u]

    def bits_t(self) -> np.ndarray:
        """Packed *ancestor* rows (transpose), built lazily and cached."""
        if self.reach_bits_t is None:
            dense = bitset.unpack(self.reach_bits, self.n)
            self.reach_bits_t = bitset.pack(dense.T)
        return self.reach_bits_t

    def dense(self) -> np.ndarray:
        return bitset.unpack(self.reach_bits, self.n)


# ------------------------------------------------------------ interval labels
@dataclass
class IntervalLabels:
    """DFS (begin, end) intervals (paper §5.5, early expansion termination).

    Guarantee used: if ``end[u] < begin[v]`` then u does not reach v.
    (The converse does not hold — it is a pruning filter only.)

    Built on the SCC *condensation* DAG, with every node inheriting its
    component's interval — this keeps the guarantee sound on arbitrary
    digraphs (within one SCC ``begin <= end`` always holds, so the filter
    never prunes a cyclic pair), which BuildRIG's interval expansion path
    relies on.
    """

    begin: np.ndarray
    end: np.ndarray

    @staticmethod
    def build(graph: DataGraph) -> "IntervalLabels":
        comp, n_comps = strongly_connected_components(graph)
        indptr, succs = _condensation_csr(comp, n_comps, graph.edges)

        begin = np.full(n_comps, -1, dtype=np.int64)
        end = np.full(n_comps, -1, dtype=np.int64)
        clock = 0
        indeg = np.zeros(n_comps, dtype=np.int64)
        if len(succs):
            indeg += np.bincount(succs, minlength=n_comps)
        roots = np.nonzero(indeg == 0)[0]
        visited = np.zeros(n_comps, dtype=bool)
        for root in (*roots, *range(n_comps)):
            if visited[root]:
                continue
            stack = [(int(root), int(indptr[root]))]
            visited[root] = True
            begin[root] = clock
            clock += 1
            while stack:
                v, ptr = stack[-1]
                if ptr < indptr[v + 1]:
                    stack[-1] = (v, ptr + 1)
                    w = int(succs[ptr])
                    if not visited[w]:
                        visited[w] = True
                        begin[w] = clock
                        clock += 1
                        stack.append((w, int(indptr[w])))
                else:
                    stack.pop()
                    end[v] = clock
                    clock += 1
        # propagate: end must cover all descendants even via cross edges.
        # Component ids are topologically numbered, so one descending-id
        # max-fold makes the filter exact on the condensation.
        for c in range(n_comps - 1, -1, -1):
            row = succs[indptr[c]:indptr[c + 1]]
            if len(row):
                end[c] = max(int(end[c]), int(end[row].max()))
        return IntervalLabels(begin=begin[comp], end=end[comp])

    def cannot_reach(self, u: int, v: int) -> bool:
        return bool(self.end[u] < self.begin[v])


# ----------------------------------------------------------------------- BFL
@dataclass
class BFL:
    """Bloom Filter Labeling (Su et al. [39]) — probe-style reachability.

    Each node gets a k-bit bloom summary ``Lout`` of its reachable set (and
    ``Lin`` of its ancestor set), computed bottom-up (top-down) over the
    condensation.  ``reaches`` first applies the two bloom *negative* filters
    and a topological-order filter, then falls back to a bloom-guided DFS.
    Exact (no false negatives by construction; DFS resolves false positives).
    """

    n: int
    bits: int
    comp: np.ndarray
    hash_: np.ndarray          # (n,) node hash in [0, bits)
    lout: np.ndarray           # (n, bits/64) packed bloom of descendants
    lin: np.ndarray            # (n, bits/64) packed bloom of ancestors
    topo: np.ndarray           # (n,) topological rank of the node's component
    graph: DataGraph
    comp_sizes: np.ndarray = None   # (n_comps,) members per component
    self_loop: np.ndarray = None    # (n,) node has a self loop

    stats_probes: int = 0
    stats_dfs: int = 0

    @staticmethod
    def build(graph: DataGraph, bits: int = 256, seed: int = 0) -> "BFL":
        n = graph.n
        comp, n_comps = strongly_connected_components(graph)
        comp_sizes = np.bincount(comp, minlength=n_comps)
        rng = np.random.default_rng(seed)
        hash_ = rng.integers(0, bits, size=n, dtype=np.int64)
        W = bits // 64
        assert bits % 64 == 0

        # component-level bloom of member hashes — one vectorized scatter
        cbloom_out = np.zeros((n_comps, W), dtype=np.uint64)
        if n:
            np.bitwise_or.at(
                cbloom_out, (comp, hash_ >> 6),
                np.uint64(1) << (hash_ & 63).astype(np.uint64))
        cbloom_in = cbloom_out.copy()

        indptr, succs = _condensation_csr(comp, n_comps, graph.edges)
        rptr, preds = _condensation_csr(comp, n_comps, graph.edges,
                                        reverse=True)
        for c in range(n_comps - 1, -1, -1):
            row = succs[indptr[c]:indptr[c + 1]]
            if len(row):
                cbloom_out[c] |= np.bitwise_or.reduce(cbloom_out[row],
                                                      axis=0)
        for c in range(n_comps):
            row = preds[rptr[c]:rptr[c + 1]]
            if len(row):
                cbloom_in[c] |= np.bitwise_or.reduce(cbloom_in[row], axis=0)

        return BFL(n=n, bits=bits, comp=comp, hash_=hash_,
                   lout=cbloom_out[comp], lin=cbloom_in[comp],
                   topo=comp.astype(np.int64), graph=graph,
                   comp_sizes=comp_sizes, self_loop=_self_loop_mask(graph))

    def _bloom_neg(self, u: int, v: int) -> bool:
        """True => definitely NOT reachable."""
        hv = self.hash_[v]
        if not (self.lout[u, hv >> 6] >> np.uint64(hv & 63)) & np.uint64(1):
            return True
        hu = self.hash_[u]
        if not (self.lin[v, hu >> 6] >> np.uint64(hu & 63)) & np.uint64(1):
            return True
        return False

    def reaches(self, u: int, v: int) -> bool:
        self.stats_probes += 1
        cu, cv = self.comp[u], self.comp[v]
        if cu == cv:
            # same SCC: reachable iff the SCC is non-trivial or self-loop.
            # Component sizes are precomputed in build — this probe used to
            # rescan the whole comp array (O(n) per reaches call).
            if u == v:
                return bool(self.self_loop[u]) or self.comp_sizes[cu] >= 2
            return bool(self.comp_sizes[cu] >= 2)
        if self.topo[u] > self.topo[v]:   # topological filter
            return False
        if self._bloom_neg(u, v):
            return False
        # bloom-guided DFS over the data graph
        self.stats_dfs += 1
        seen = set([u])
        stack = [u]
        while stack:
            x = stack.pop()
            for w in self.graph.children(int(x)):
                w = int(w)
                if w == v:
                    return True
                if w in seen:
                    continue
                if self.topo[w] > self.topo[v]:
                    continue
                if self._bloom_neg(w, v):
                    continue
                seen.add(w)
                stack.append(w)
        return False
