"""Node-reachability substrate (Def. 3.2 and §5.5).

The paper plugs in *any* reachability labeling scheme; its experiments use
BFL (Bloom Filter Labeling [39]) plus plain adjacency for child edges.  We
provide three interchangeable components:

``ReachabilityIndex``
    SCC condensation + packed-bit transitive closure over the condensation
    DAG.  Exact, O(n·E/64) time, n²/64 bytes.  This powers the *bitset batch*
    operations (matvec-style existence checks and adjacency-row intersection)
    that the device path accelerates with the ``bitmm`` kernel.

``IntervalLabels``
    DFS (begin, end) intervals on a DAG — used for the paper's *early
    expansion termination* (§5.5): within a DAG, ``u`` cannot reach ``v``
    whenever ``u.end < v.begin``.

``BFL``
    A faithful-in-spirit Bloom Filter Labeling: per-node k-bit bloom
    summaries of the reachable set, computed bottom-up over the condensation
    DAG, used as a *negative* filter in front of a guided DFS.  Probe-style
    API (``reaches(u, v)``) like the original; no false negatives.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from . import bitset
from .graph import DataGraph


# --------------------------------------------------------------------------- SCC
def strongly_connected_components(graph: DataGraph):
    """Iterative Tarjan.  Returns (comp_id per node, n_comps).

    Component ids are numbered in *reverse topological order of the
    condensation* (i.e. comp(u) >= comp(v) whenever u can reach v in distinct
    components gets comp(u) > comp(v) after the flip below we instead
    guarantee topological order: comp(u) < comp(v) => u cannot be reached
    from v).  We post-process to a forward topological numbering.
    """
    n = graph.n
    index = np.full(n, -1, dtype=np.int64)
    low = np.zeros(n, dtype=np.int64)
    on_stack = np.zeros(n, dtype=bool)
    comp = np.full(n, -1, dtype=np.int64)
    stack: list[int] = []
    next_index = 0
    n_comps = 0

    indptr, indices = graph.fwd_indptr, graph.fwd_indices

    for root in range(n):
        if index[root] != -1:
            continue
        # each frame: (node, next child pointer)
        work = [(root, indptr[root])]
        index[root] = low[root] = next_index
        next_index += 1
        stack.append(root)
        on_stack[root] = True
        while work:
            v, ptr = work[-1]
            if ptr < indptr[v + 1]:
                work[-1] = (v, ptr + 1)
                w = indices[ptr]
                if index[w] == -1:
                    index[w] = low[w] = next_index
                    next_index += 1
                    stack.append(int(w))
                    on_stack[w] = True
                    work.append((int(w), indptr[w]))
                elif on_stack[w]:
                    low[v] = min(low[v], index[w])
            else:
                work.pop()
                if work:
                    pv = work[-1][0]
                    low[pv] = min(low[pv], low[v])
                if low[v] == index[v]:
                    while True:
                        w = stack.pop()
                        on_stack[w] = False
                        comp[w] = n_comps
                        if w == v:
                            break
                    n_comps += 1
    # Tarjan emits components in reverse topological order -> flip.
    comp = (n_comps - 1) - comp
    return comp, n_comps


# ------------------------------------------------------------------- closure
@dataclass
class ReachabilityIndex:
    """Exact reachability via condensation + packed closure.

    ``reach_bits`` is a packed bit matrix (n, W): row u = set of nodes v with
    u ≺ v (strict per Def. 3.2 — v reachable by a path of length >= 1; a node
    reaches itself only if it lies on a cycle).
    """

    n: int
    comp: np.ndarray              # (n,) component id, topologically numbered
    reach_bits: np.ndarray        # (n, W) packed, node-level closure
    reach_bits_t: Optional[np.ndarray] = None   # transpose, built lazily

    @staticmethod
    def build(graph: DataGraph) -> "ReachabilityIndex":
        n = graph.n
        comp, n_comps = strongly_connected_components(graph)

        # --- condensation DAG edges + member lists
        members: list[list[int]] = [[] for _ in range(n_comps)]
        for v in range(n):
            members[comp[v]].append(v)

        W = bitset.n_words(n)
        # creach[c] = packed set of *data nodes* reachable from component c,
        # including c's own members iff |c| > 1 (cycle) — strictness handled
        # at node level below.
        creach = np.zeros((n_comps, W), dtype=np.uint64)
        csucc: list[set] = [set() for _ in range(n_comps)]
        if graph.n_edges:
            cs = comp[graph.edges[:, 0]]
            cd = comp[graph.edges[:, 1]]
            for a, b in zip(cs, cd):
                if a != b:
                    csucc[a].add(int(b))

        # members packed per component
        cmembers = np.zeros((n_comps, W), dtype=np.uint64)
        for c in range(n_comps):
            cmembers[c] = bitset.from_indices(np.array(members[c]), n)

        # reverse topological order = descending component id
        for c in range(n_comps - 1, -1, -1):
            acc = np.zeros(W, dtype=np.uint64)
            for s in csucc[c]:
                acc |= creach[s] | cmembers[s]
            if len(members[c]) > 1:
                acc |= cmembers[c]
            else:
                # single-node component: self-reachable iff self loop
                v = members[c][0]
                if graph.has_edge(v, v):
                    acc |= cmembers[c]
            creach[c] = acc

        reach = creach[comp]  # (n, W): every node inherits its component row
        return ReachabilityIndex(n=n, comp=comp, reach_bits=reach)

    # ------------------------------------------------------------- interface
    def reaches(self, u: int, v: int) -> bool:
        """u ≺ v (Def. 3.2)."""
        return bitset.get(self.reach_bits[u], v)

    def reach_row(self, u: int) -> np.ndarray:
        """Packed descendant set of u."""
        return self.reach_bits[u]

    def bits_t(self) -> np.ndarray:
        """Packed *ancestor* rows (transpose), built lazily and cached."""
        if self.reach_bits_t is None:
            dense = bitset.unpack(self.reach_bits, self.n)
            self.reach_bits_t = bitset.pack(dense.T)
        return self.reach_bits_t

    def dense(self) -> np.ndarray:
        return bitset.unpack(self.reach_bits, self.n)


# ------------------------------------------------------------ interval labels
@dataclass
class IntervalLabels:
    """DFS (begin, end) intervals on a DAG (paper §5.5, early termination).

    Guarantee used: if ``end[u] < begin[v]`` then u does not reach v.
    (The converse does not hold — it is a pruning filter only.)
    """

    begin: np.ndarray
    end: np.ndarray

    @staticmethod
    def build(graph: DataGraph) -> "IntervalLabels":
        n = graph.n
        begin = np.full(n, -1, dtype=np.int64)
        end = np.full(n, -1, dtype=np.int64)
        clock = 0
        indptr, indices = graph.fwd_indptr, graph.fwd_indices
        roots = [v for v in range(n) if graph.bwd_indptr[v] == graph.bwd_indptr[v + 1]]
        visited = np.zeros(n, dtype=bool)
        for root in (roots + list(range(n))):
            if visited[root]:
                continue
            stack = [(int(root), int(indptr[root]))]
            visited[root] = True
            begin[root] = clock
            clock += 1
            while stack:
                v, ptr = stack[-1]
                if ptr < indptr[v + 1]:
                    stack[-1] = (v, ptr + 1)
                    w = int(indices[ptr])
                    if not visited[w]:
                        visited[w] = True
                        begin[w] = clock
                        clock += 1
                        stack.append((w, int(indptr[w])))
                else:
                    stack.pop()
                    end[v] = clock
                    clock += 1
        # propagate: end must cover all descendants even via cross edges.
        # One reverse-topological max-fold makes the filter exact on DAGs.
        order = np.argsort(begin)  # begin times are a valid DFS order
        for v in order[::-1]:
            ch = indices[indptr[v]:indptr[v + 1]]
            if len(ch):
                end[v] = max(int(end[v]), int(end[ch].max()))
        return IntervalLabels(begin=begin, end=end)

    def cannot_reach(self, u: int, v: int) -> bool:
        return bool(self.end[u] < self.begin[v])


# ----------------------------------------------------------------------- BFL
@dataclass
class BFL:
    """Bloom Filter Labeling (Su et al. [39]) — probe-style reachability.

    Each node gets a k-bit bloom summary ``Lout`` of its reachable set (and
    ``Lin`` of its ancestor set), computed bottom-up (top-down) over the
    condensation.  ``reaches`` first applies the two bloom *negative* filters
    and a topological-order filter, then falls back to a bloom-guided DFS.
    Exact (no false negatives by construction; DFS resolves false positives).
    """

    n: int
    bits: int
    comp: np.ndarray
    hash_: np.ndarray          # (n,) node hash in [0, bits)
    lout: np.ndarray           # (n, bits/64) packed bloom of descendants
    lin: np.ndarray            # (n, bits/64) packed bloom of ancestors
    topo: np.ndarray           # (n,) topological rank of the node's component
    graph: DataGraph

    stats_probes: int = 0
    stats_dfs: int = 0

    @staticmethod
    def build(graph: DataGraph, bits: int = 256, seed: int = 0) -> "BFL":
        n = graph.n
        comp, n_comps = strongly_connected_components(graph)
        rng = np.random.default_rng(seed)
        hash_ = rng.integers(0, bits, size=n, dtype=np.int64)
        W = bits // 64
        assert bits % 64 == 0

        self_bloom = np.zeros((n, W), dtype=np.uint64)
        np.bitwise_or.at(
            self_bloom, (np.arange(n), hash_ >> 6),
            np.uint64(1) << (hash_ & 63).astype(np.uint64))

        # component-level aggregation
        cbloom_out = np.zeros((n_comps, W), dtype=np.uint64)
        cbloom_in = np.zeros((n_comps, W), dtype=np.uint64)
        for v in range(n):
            cbloom_out[comp[v]] |= self_bloom[v]
            cbloom_in[comp[v]] |= self_bloom[v]
        csucc: list[set] = [set() for _ in range(n_comps)]
        cpred: list[set] = [set() for _ in range(n_comps)]
        if graph.n_edges:
            for a, b in zip(comp[graph.edges[:, 0]], comp[graph.edges[:, 1]]):
                if a != b:
                    csucc[int(a)].add(int(b))
                    cpred[int(b)].add(int(a))
        for c in range(n_comps - 1, -1, -1):
            for s in csucc[c]:
                cbloom_out[c] |= cbloom_out[s]
        for c in range(n_comps):
            for p in cpred[c]:
                cbloom_in[c] |= cbloom_in[p]

        return BFL(n=n, bits=bits, comp=comp, hash_=hash_,
                   lout=cbloom_out[comp], lin=cbloom_in[comp],
                   topo=comp.astype(np.int64), graph=graph)

    def _bloom_neg(self, u: int, v: int) -> bool:
        """True => definitely NOT reachable."""
        hv = self.hash_[v]
        if not (self.lout[u, hv >> 6] >> np.uint64(hv & 63)) & np.uint64(1):
            return True
        hu = self.hash_[u]
        if not (self.lin[v, hu >> 6] >> np.uint64(hu & 63)) & np.uint64(1):
            return True
        return False

    def reaches(self, u: int, v: int) -> bool:
        self.stats_probes += 1
        cu, cv = self.comp[u], self.comp[v]
        if cu == cv:
            # same SCC: reachable iff the SCC is non-trivial or self-loop
            if u == v:
                return self.graph.has_edge(u, u) or _scc_nontrivial(self.comp, cu)
            return _scc_nontrivial(self.comp, cu)
        if self.topo[u] > self.topo[v]:   # topological filter
            return False
        if self._bloom_neg(u, v):
            return False
        # bloom-guided DFS over the data graph
        self.stats_dfs += 1
        seen = set([u])
        stack = [u]
        while stack:
            x = stack.pop()
            for w in self.graph.children(int(x)):
                w = int(w)
                if w == v:
                    return True
                if w in seen:
                    continue
                if self.topo[w] > self.topo[v]:
                    continue
                if self._bloom_neg(w, v):
                    continue
                seen.add(w)
                stack.append(w)
        return False


def _scc_nontrivial(comp: np.ndarray, c: int) -> bool:
    # an SCC is non-trivial iff it has >= 2 members
    return int((comp == c).sum()) >= 2
