"""Brute-force homomorphism oracle — test reference only.

Independent of the production code paths: reachability comes from networkx
``descendants`` (memoized) and candidate sets from raw label scans; the
enumeration is plain nested backtracking over match sets with per-edge
checks.  Exponential; use on graphs of at most a few hundred nodes.
"""

from __future__ import annotations

from functools import lru_cache
from typing import List, Optional, Set, Tuple

import networkx as nx
import numpy as np

from .graph import DataGraph
from .query import CHILD, PatternQuery


def brute_force_answers(graph: DataGraph, q: PatternQuery,
                        limit: Optional[int] = None) -> np.ndarray:
    """All occurrence tuples of q on graph, shape (k, q.n), query-node order."""
    g = nx.DiGraph()
    g.add_nodes_from(range(graph.n))
    g.add_edges_from(map(tuple, graph.edges))

    # u ≺ v: a path of length >= 1 from u to v; hence u ≺ u iff u lies on a
    # cycle (nx.descendants never includes the source, so patch that case
    # via SCCs — required for IR2 transitivity soundness).
    on_cycle = set()
    for scc in nx.strongly_connected_components(g):
        if len(scc) > 1:
            on_cycle |= scc
    on_cycle |= {u for u in g.nodes if g.has_edge(u, u)}

    @lru_cache(maxsize=None)
    def desc(u: int) -> frozenset:
        d = set(nx.descendants(g, u))
        if u in on_cycle:
            d.add(u)
        return frozenset(d)

    def reaches(u: int, v: int) -> bool:
        return v in desc(u)

    cands: List[np.ndarray] = [graph.inverted_list(l) for l in q.labels]
    if any(len(c) == 0 for c in cands):
        return np.empty((0, q.n), dtype=np.int64)

    # order query nodes so each (after the first) touches an earlier one
    order = [0]
    rest = set(range(1, q.n))
    while rest:
        nxt = next((r for r in sorted(rest)
                    if any(s in order for s in q.neighbors(r))), None)
        if nxt is None:
            nxt = min(rest)
        order.append(nxt)
        rest.discard(nxt)

    edge_checks: List[List[Tuple[int, int, bool]]] = [[] for _ in range(q.n)]
    pos = {qi: i for i, qi in enumerate(order)}
    for e in q.edges:
        later = max(pos[e.src], pos[e.dst])
        edge_checks[later].append((e.src, e.dst, e.kind == CHILD))

    out: List[List[int]] = []
    assign = [-1] * q.n

    def ok(level: int) -> bool:
        for (s, d, is_child) in edge_checks[level]:
            u, v = assign[s], assign[d]
            if is_child:
                if not g.has_edge(u, v):
                    return False
            else:
                if not reaches(u, v):
                    return False
        return True

    def rec(level: int) -> bool:
        if level == q.n:
            out.append(list(assign))
            return not (limit is not None and len(out) >= limit)
        qi = order[level]
        for v in cands[qi]:
            assign[qi] = int(v)
            if ok(level) and not rec(level + 1):
                return False
        assign[qi] = -1
        return True

    rec(0)
    if not out:
        return np.empty((0, q.n), dtype=np.int64)
    return np.array(out, dtype=np.int64)


def answer_set(tuples: np.ndarray) -> Set[tuple]:
    return set(map(tuple, np.asarray(tuples, dtype=np.int64)))
