# The paper's primary contribution — the host-faithful implementation of the
# RIG-based graph pattern matching system (GM): data graph + reachability
# substrate, transitive reduction, double simulation, RIG construction,
# search ordering and the MJoin worst-case-optimal enumerator, plus the JM
# and TM baselines the paper compares against.  The TPU-adapted twin lives
# in ``repro.jaxgm``.
from .graph import DataGraph, graph_from_edge_list, paper_example_graph
from .matcher import GM, GMOptions, MatchResult, MatchStream, match
from .mjoin import (ENUM_METHODS, MJoinResult, MJoinStats, MJoinStream,
                    iter_tuples, mjoin, mjoin_batched)
from .ordering import get_order
from .query import CHILD, DESC, PatternQuery, QueryEdge, paper_example_query, query
from .rig import RIG, build_rig, prefilter
from .simulation import EdgeOracle, fb_sim, fb_sim_bas, fb_sim_dag, match_sets

__all__ = [
    "DataGraph", "graph_from_edge_list", "paper_example_graph",
    "PatternQuery", "QueryEdge", "CHILD", "DESC", "query", "paper_example_query",
    "EdgeOracle", "fb_sim", "fb_sim_bas", "fb_sim_dag", "match_sets",
    "RIG", "build_rig", "prefilter", "get_order", "mjoin",
    "MJoinResult", "MJoinStats", "MJoinStream", "ENUM_METHODS",
    "iter_tuples", "mjoin_batched",
    "GM", "GMOptions", "MatchResult", "MatchStream", "match",
]
