from .jm import JMBudgetExceeded, jm_match
from .tm import TMTimeout, tm_match

__all__ = ["jm_match", "tm_match", "JMBudgetExceeded", "TMTimeout"]
