"""TM — the tree-based baseline (§7.1; DagStackD/[46]-style).

Pick a spanning tree Q_T of Q; evaluate Q_T level by level (each tree edge
is a parent→child extension join over its occurrence list); then filter the
tree solutions against the reachability constraints of the non-tree edges.

Faithful to the described weakness: the set of *tree* solutions is fully
materialized before non-tree filtering, so queries whose spanning tree is
unselective blow up — a row budget emulates the paper's TM timeouts
(``TMTimeout``).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from .. import bitset
from ..graph import DataGraph
from ..query import PatternQuery, QueryEdge
from ..rig import prefilter
from ..simulation import EdgeOracle


class TMTimeout(RuntimeError):
    """Tree-solution budget blown (the paper's TM timeout failure mode)."""


@dataclass
class TMResult:
    count: int
    tuples: np.ndarray
    tree_edges: List[QueryEdge]
    nontree_edges: List[QueryEdge]
    tree_solutions: int
    total_s: float


def spanning_tree(q: PatternQuery) -> Tuple[List[QueryEdge], List[QueryEdge]]:
    """BFS spanning tree over the undirected view, preferring child edges
    (cheaper to evaluate) as tree edges."""
    seen = {0}
    tree: List[QueryEdge] = []
    frontier = [0]
    edges = sorted(q.edges, key=lambda e: e.kind)   # child edges first
    while frontier:
        nxt = []
        for v in frontier:
            for e in edges:
                if e in tree:
                    continue
                other = None
                if e.src == v and e.dst not in seen:
                    other = e.dst
                elif e.dst == v and e.src not in seen:
                    other = e.src
                if other is not None:
                    tree.append(e)
                    seen.add(other)
                    nxt.append(other)
        frontier = nxt
    nontree = [e for e in q.edges if e not in tree]
    return tree, nontree


def tm_match(graph: DataGraph, q: PatternQuery,
             budget_rows: int = 5_000_000,
             use_prefilter: bool = True) -> TMResult:
    t0 = time.perf_counter()
    oracle = EdgeOracle(graph)
    fb = prefilter(graph, q) if use_prefilter else \
        [graph.label_bits(l) for l in q.labels]
    tree, nontree = spanning_tree(q)
    n = graph.n

    # --- evaluate the tree pattern: extension joins along tree edges -------
    tuples = bitset.to_indices(fb[0], n).reshape(-1, 1)
    cols = [0]
    for e in tree:
        anchored_src = e.src in cols
        key = e.src if anchored_src else e.dst
        new = e.dst if anchored_src else e.src
        ki = cols.index(key)
        other_bits = fb[new]
        out = []
        total = 0
        row_cache: Dict[int, np.ndarray] = {}
        for r in tuples:
            v = int(r[ki])
            if v not in row_cache:
                packed = (oracle.fwd_row(v, e.kind) if anchored_src
                          else oracle.bwd_row(v, e.kind)) & other_bits
                row_cache[v] = bitset.to_indices(packed, n)
            ext = row_cache[v]
            total += len(ext)
            if total > budget_rows:
                raise TMTimeout(f"tree solutions > {budget_rows} rows")
            for w in ext:
                out.append(np.concatenate([r, [w]]))
        tuples = (np.stack(out).astype(np.int64) if out
                  else np.empty((0, len(cols) + 1), dtype=np.int64))
        cols = cols + [new]
        if len(tuples) == 0:
            break
    tree_solutions = len(tuples)

    # --- filter non-tree edges ---------------------------------------------
    if len(tuples) and nontree:
        keep = np.ones(len(tuples), dtype=bool)
        for e in nontree:
            si, di = cols.index(e.src), cols.index(e.dst)
            for i in range(len(tuples)):
                if keep[i] and not oracle.is_match(int(tuples[i, si]),
                                                   int(tuples[i, di]), e.kind):
                    keep[i] = False
        tuples = tuples[keep]

    if len(tuples):
        perm = [cols.index(i) for i in range(q.n)]
        tuples = np.unique(tuples[:, perm], axis=0)
    else:
        tuples = np.empty((0, q.n), dtype=np.int64)
    return TMResult(count=len(tuples), tuples=tuples, tree_edges=tree,
                    nontree_edges=nontree, tree_solutions=tree_solutions,
                    total_s=time.perf_counter() - t0)
