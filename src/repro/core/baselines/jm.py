"""JM — the join-based baseline (§7.1; R-Join-style [11]).

Decompose Q into binary relationships (its edges); materialize the
occurrence relation of every edge on G; pick an optimized left-deep plan by
exhaustive dynamic programming over estimated join costs; evaluate as a
sequence of binary hash joins.

Deliberately faithful to the described weaknesses: the per-edge relations
and every intermediate result are fully materialized, so dense/descendant
queries explode — a configurable row budget emulates the paper's
out-of-memory failures deterministically (reported as ``JMBudgetExceeded``).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from .. import bitset
from ..graph import DataGraph
from ..query import PatternQuery
from ..rig import prefilter
from ..simulation import EdgeOracle


class JMBudgetExceeded(RuntimeError):
    """Intermediate-result budget blown (the paper's JM OOM failure mode)."""


@dataclass
class JMResult:
    count: int
    tuples: np.ndarray
    plan: List[int]                 # edge order
    plans_enumerated: int
    max_intermediate: int
    total_s: float


def _edge_relation(graph: DataGraph, oracle: EdgeOracle, e, fb) -> np.ndarray:
    """Materialize ms(e) restricted to prefiltered candidate sets: (k, 2)."""
    n = graph.n
    src_idx = bitset.to_indices(fb[e.src], n)
    dst_bits = fb[e.dst]
    rows = []
    for v in src_idx:
        row = oracle.fwd_row(int(v), e.kind) & dst_bits
        idx = bitset.to_indices(row, n)
        if len(idx):
            rows.append(np.stack([np.full(len(idx), v, dtype=np.int64), idx], 1))
    if not rows:
        return np.empty((0, 2), dtype=np.int64)
    return np.concatenate(rows, axis=0)


def _hash_join(left: np.ndarray, left_cols: List[int],
               rel: np.ndarray, e_src: int, e_dst: int,
               budget: int) -> Tuple[np.ndarray, List[int]]:
    """Join a tuple relation with a binary edge relation."""
    have_src = e_src in left_cols
    have_dst = e_dst in left_cols
    if have_src and have_dst:
        i, j = left_cols.index(e_src), left_cols.index(e_dst)
        pairs = set(map(tuple, rel))
        keep = np.fromiter(((int(r[i]), int(r[j])) in pairs for r in left),
                           dtype=bool, count=len(left))
        return left[keep], left_cols
    if have_src or have_dst:
        key_col = e_src if have_src else e_dst
        new_col = e_dst if have_src else e_src
        ki = left_cols.index(key_col)
        rel_key = rel[:, 0] if have_src else rel[:, 1]
        rel_val = rel[:, 1] if have_src else rel[:, 0]
        buckets: Dict[int, List[int]] = {}
        for k, v in zip(rel_key, rel_val):
            buckets.setdefault(int(k), []).append(int(v))
        out = []
        total = 0
        for r in left:
            vs = buckets.get(int(r[ki]))
            if not vs:
                continue
            total += len(vs)
            if total > budget:
                raise JMBudgetExceeded(f"intermediate > {budget} rows")
            for v in vs:
                out.append(np.concatenate([r, [v]]))
        new = (np.stack(out) if out
               else np.empty((left.shape[1] + 1, 0)).T.astype(np.int64))
        return new.astype(np.int64), left_cols + [new_col]
    # cartesian (disconnected plan step)
    total = len(left) * len(rel)
    if total > budget:
        raise JMBudgetExceeded(f"cartesian {total} rows > {budget}")
    li = np.repeat(np.arange(len(left)), len(rel))
    ri = np.tile(np.arange(len(rel)), len(left))
    new = np.concatenate([left[li], rel[ri]], axis=1)
    return new.astype(np.int64), left_cols + [e_src, e_dst]


def jm_match(graph: DataGraph, q: PatternQuery,
             budget_rows: int = 5_000_000,
             use_prefilter: bool = True,
             max_plans: int = 5_000_000) -> JMResult:
    t0 = time.perf_counter()
    oracle = EdgeOracle(graph)
    fb = prefilter(graph, q) if use_prefilter else \
        [graph.label_bits(l) for l in q.labels]

    rels = [_edge_relation(graph, oracle, e, fb) for e in q.edges]
    sizes = np.array([max(len(r), 1) for r in rels], dtype=np.float64)
    m = len(q.edges)
    cos_size = np.array([max(bitset.count(b), 1) for b in fb], dtype=np.float64)
    sel = [len(rels[i]) / (cos_size[q.edges[i].src] * cos_size[q.edges[i].dst])
           for i in range(m)]

    # --- exhaustive DP over left-deep edge orders (R-Join style) -----------
    plans_enumerated = 0
    best: Dict[frozenset, Tuple[float, float, frozenset, List[int]]] = {}
    for i in range(m):
        nodes = frozenset({q.edges[i].src, q.edges[i].dst})
        best[frozenset([i])] = (sizes[i], sizes[i], nodes, [i])
    for k in range(1, m):
        for subset in [s for s in list(best) if len(s) == k]:
            cost, card, nodes, order = best[subset]
            for nxt in range(m):
                if nxt in subset:
                    continue
                e = q.edges[nxt]
                overlap = len(nodes & {e.src, e.dst})
                if overlap == 0 and k < m - 1:
                    continue
                if overlap == 2:
                    ncard = card * sel[nxt]
                elif overlap == 1:
                    newn = e.dst if e.src in nodes else e.src
                    ncard = card * cos_size[newn] * sel[nxt]
                else:
                    ncard = card * sizes[nxt]
                plans_enumerated += 1
                if plans_enumerated > max_plans:
                    raise JMBudgetExceeded("plan enumeration exceeded budget")
                key = subset | {nxt}
                ncost = cost + ncard
                if key not in best or ncost < best[key][0]:
                    best[key] = (ncost, ncard,
                                 nodes | {e.src, e.dst}, order + [nxt])
    plan = best[frozenset(range(m))][3]

    # --- execute ------------------------------------------------------------
    e0 = q.edges[plan[0]]
    tuples, cols = rels[plan[0]].copy(), [e0.src, e0.dst]
    max_inter = len(tuples)
    for ei in plan[1:]:
        e = q.edges[ei]
        tuples, cols = _hash_join(tuples, cols, rels[ei], e.src, e.dst,
                                  budget_rows)
        max_inter = max(max_inter, len(tuples))
    # project to query-node order (isolated query nodes cannot occur: Q is
    # connected and every node touches an edge)
    perm = [cols.index(i) for i in range(q.n)]
    tuples = tuples[:, perm] if len(tuples) else np.empty((0, q.n), np.int64)
    tuples = np.unique(tuples, axis=0) if len(tuples) else tuples
    return JMResult(count=len(tuples), tuples=tuples, plan=plan,
                    plans_enumerated=plans_enumerated,
                    max_intermediate=max_inter,
                    total_s=time.perf_counter() - t0)
