"""Data graph representation (Definition 3.1).

A directed, node-labeled graph ``G = (V, E)`` with a finite label alphabet.
The structure keeps:

* CSR adjacency in both directions (children / parents — Def. 3.2),
* per-label inverted lists ``I_a`` (the match sets ``ms(q)`` of query nodes),
* optional packed-bit adjacency and reachability matrices for the bitset
  batch operations of §5.5, built lazily and cached.

The host-faithful algorithms (``repro.core``) operate on this structure; the
TPU path (``repro.jaxgm``) consumes its packed exports.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from . import bitset


@dataclass
class DataGraph:
    n: int
    labels: np.ndarray                 # int32 (n,)
    num_labels: int
    edges: np.ndarray                  # int64 (E, 2), deduplicated, no self loops req.

    # --- derived (filled in __post_init__) ---
    fwd_indptr: np.ndarray = field(init=False)
    fwd_indices: np.ndarray = field(init=False)
    bwd_indptr: np.ndarray = field(init=False)
    bwd_indices: np.ndarray = field(init=False)
    inverted: Dict[int, np.ndarray] = field(init=False)

    _adj_bits: Optional[np.ndarray] = field(default=None, init=False, repr=False)
    _adj_bits_t: Optional[np.ndarray] = field(default=None, init=False, repr=False)
    _reach: Optional["object"] = field(default=None, init=False, repr=False)

    def __post_init__(self) -> None:
        self.labels = np.asarray(self.labels, dtype=np.int32)
        assert self.labels.shape == (self.n,)
        edges = np.asarray(self.edges, dtype=np.int64).reshape(-1, 2)
        if edges.size:
            edges = np.unique(edges, axis=0)
        self.edges = edges
        self.fwd_indptr, self.fwd_indices = _csr(edges[:, 0], edges[:, 1], self.n)
        self.bwd_indptr, self.bwd_indices = _csr(edges[:, 1], edges[:, 0], self.n)
        self.inverted = {
            int(l): np.nonzero(self.labels == l)[0]
            for l in np.unique(self.labels)
        }

    # ------------------------------------------------------------------ basics
    @property
    def n_edges(self) -> int:
        return int(self.edges.shape[0])

    @property
    def avg_degree(self) -> float:
        return self.n_edges / max(self.n, 1)

    def children(self, v: int) -> np.ndarray:
        return self.fwd_indices[self.fwd_indptr[v]:self.fwd_indptr[v + 1]]

    def parents(self, v: int) -> np.ndarray:
        return self.bwd_indices[self.bwd_indptr[v]:self.bwd_indptr[v + 1]]

    def out_degree(self) -> np.ndarray:
        return np.diff(self.fwd_indptr)

    def in_degree(self) -> np.ndarray:
        return np.diff(self.bwd_indptr)

    def inverted_list(self, label: int) -> np.ndarray:
        """``I_a``: nodes whose label is ``label`` (sorted)."""
        return self.inverted.get(int(label), np.empty(0, dtype=np.int64))

    def has_edge(self, u: int, v: int) -> bool:
        row = self.children(u)
        i = np.searchsorted(row, v)
        return bool(i < len(row) and row[i] == v)

    # ------------------------------------------------------- packed bit views
    def adj_bits(self) -> np.ndarray:
        """Packed forward adjacency rows: uint64 (n, W); row v = children(v)."""
        if self._adj_bits is None:
            self._adj_bits = _pack_csr(self.fwd_indptr, self.fwd_indices, self.n)
        return self._adj_bits

    def adj_bits_t(self) -> np.ndarray:
        """Packed backward adjacency rows: row v = parents(v)."""
        if self._adj_bits_t is None:
            self._adj_bits_t = _pack_csr(self.bwd_indptr, self.bwd_indices, self.n)
        return self._adj_bits_t

    def reachability(self):
        """Lazily-built reachability oracle (see ``repro.core.reachability``)."""
        if self._reach is None:
            from .reachability import ReachabilityIndex
            self._reach = ReachabilityIndex.build(self)
        return self._reach

    def label_mask(self, label: int) -> np.ndarray:
        mask = np.zeros(self.n, dtype=bool)
        lst = self.inverted_list(label)
        mask[lst] = True
        return mask

    def label_bits(self, label: int) -> np.ndarray:
        return bitset.from_indices(self.inverted_list(label), self.n)

    # ---------------------------------------------------------------- exports
    def adjacency_matrix(self) -> np.ndarray:
        """Dense boolean adjacency (n, n) — small-graph oracles only."""
        a = np.zeros((self.n, self.n), dtype=bool)
        if self.n_edges:
            a[self.edges[:, 0], self.edges[:, 1]] = True
        return a


def _csr(src: np.ndarray, dst: np.ndarray, n: int):
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, dst.astype(np.int64)


def _pack_csr(indptr: np.ndarray, indices: np.ndarray, n: int) -> np.ndarray:
    out = np.zeros((n, bitset.n_words(n)), dtype=np.uint64)
    # vectorized scatter of bits
    rows = np.repeat(np.arange(n), np.diff(indptr))
    cols = indices
    if len(cols):
        words = cols >> 6
        shifts = (cols & 63).astype(np.uint64)
        np.bitwise_or.at(out, (rows, words), np.uint64(1) << shifts)
    return out


def graph_from_edge_list(edges, labels, num_labels: Optional[int] = None) -> DataGraph:
    labels = np.asarray(labels, dtype=np.int32)
    n = len(labels)
    if num_labels is None:
        num_labels = int(labels.max()) + 1 if n else 0
    return DataGraph(n=n, labels=labels, num_labels=num_labels,
                     edges=np.asarray(edges, dtype=np.int64).reshape(-1, 2))


def paper_example_graph() -> DataGraph:
    """The data graph of Fig. 1(a).

    Labels a,b,c,d,e -> 0..4.  Node ids: a1..a5 = 0..4, b1..b4 = 5..8,
    c1..c3 = 9..11, d1 = 12, e1 = 13.  The edge set reproduces the figure's
    topology closely enough to exercise every code path (child edges,
    multi-hop descendant paths, shared children); exact-figure fidelity is
    not required by any test that uses it as an oracle input.
    """
    labels = [0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 3, 4]
    a1, a2, a3, a4, a5, b1, b2, b3, b4, c1, c2, c3, d1, e1 = range(14)
    edges = [
        (a1, b1), (a1, b2), (c1, b2), (a2, b2), (a2, c1), (c1, a3),
        (a3, b3), (b2, d1), (b1, c2), (d1, c2), (c2, e1), (b3, c3),
        (c3, e1), (a4, b4), (b4, c3), (a5, b4), (d1, a4),
    ]
    return graph_from_edge_list(edges, labels, num_labels=5)
