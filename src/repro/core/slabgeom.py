"""Device slab padding geometry — pure integer math, no jax dependency.

The device intersectors (:mod:`repro.jaxgm.frontier`) never dispatch the
logical slab shapes the enumerator produces: ``(F, K, W)`` gather slabs
are padded to kernel block multiples (F to the next power of two >= 128,
K to a power of two with AND-identity rows, W to a multiple of 128 uint32
lanes), and resident-path dispatches pad F the same way.  Budget
enforcement must charge the *padded* allocation — on small or ragged
slabs the padding can exceed the logical size by more than 2x, so a cap
computed from logical bytes would not actually bound device memory.

This module is the single source of truth for that geometry: the
enumerator (``repro.core.mjoin``, jax-free) uses it to tighten slab
heights under ``Budget.max_slab_bytes``, and the jax executors use the
same functions to size and account their real allocations.
"""

from __future__ import annotations

from typing import Tuple

LANE_BYTES = 4          # kernels operate on uint32 lanes
MIN_ROWS = 128          # F padding floor (bounds retraces to O(log F))
MIN_LANES = 128         # W padding unit, in uint32 lanes


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def pow2_at_least(x: int, floor: int = MIN_ROWS) -> int:
    p = floor
    while p < x:
        p *= 2
    return p


def padded_slab_shape(f: int, k: int, w64: int) -> Tuple[int, int, int]:
    """Device shape (rows, constraints, uint32 lanes) actually allocated
    for a logical ``(f, k, w64)`` uint64 gather slab."""
    return (pow2_at_least(f), pow2_at_least(k, floor=1),
            round_up(max(2 * w64, MIN_LANES), MIN_LANES))


def padded_slab_bytes(f: int, k: int, w64: int) -> int:
    """Bytes the device intersector allocates for a logical slab."""
    fp, kp, wp = padded_slab_shape(f, k, w64)
    return fp * kp * wp * LANE_BYTES


def padded_rows_cap(max_bytes: int, k: int, w64: int, at_most: int) -> int:
    """Largest slab height whose *padded* allocation fits ``max_bytes``,
    capped at ``at_most``.  Returns 0 when even the minimal (128-row)
    padded dispatch exceeds the cap — the caller must route that level
    through the host intersect instead."""
    if padded_slab_bytes(1, k, w64) > max_bytes:
        return 0
    fp = MIN_ROWS
    while fp < at_most and padded_slab_bytes(fp * 2, k, w64) <= max_bytes:
        fp *= 2
    return min(fp, at_most)


def resident_dispatch_bytes(f: int, k: int, w_lanes: int) -> int:
    """Per-dispatch device transient of the resident gather-intersect
    path: the padded ``(F, K)`` int32 index upload plus the padded
    ``(F, W)`` AND output and ``(F,)`` counts (the resident matrix itself
    is a one-time upload, charged separately)."""
    fp = pow2_at_least(f)
    return fp * (k + w_lanes + 1) * LANE_BYTES


def resident_rows_cap(max_bytes: int, k: int, w_lanes: int,
                      at_most: int) -> int:
    """Resident-path analogue of :func:`padded_rows_cap`."""
    if resident_dispatch_bytes(1, k, w_lanes) > max_bytes:
        return 0
    fp = MIN_ROWS
    while fp < at_most and resident_dispatch_bytes(fp * 2, k,
                                                   w_lanes) <= max_bytes:
        fp *= 2
    return min(fp, at_most)
