"""Runtime Index Graph (Def. 5.1) and BuildRIG (Alg. 4, §5.5).

A RIG of query Q over graph G is a k-partite graph: one independent node set
``cos(q)`` per query node with ``os(q) ⊆ cos(q) ⊆ ms(q)``, and, per query
edge (p, q), exactly the query-edge occurrences between surviving candidates.
It losslessly encodes all homomorphisms from Q to G (Prop. 5.1) and is built
on-the-fly per query — never persisted.

BuildRIG = *node selection* (double simulation — existence semantics)
followed by *node expansion* (materialize adjacency — all-matches semantics).

Layout: the RIG is stored *candidate-locally*.  Per query node q, ``cos(q)``
is remapped onto the compact id space ``0..|cos(q)|-1`` (``cand[q][i]`` is
the data-graph node of local id ``i``, sorted ascending), and every query
edge's adjacency is one contiguous packed bit **matrix**
``uint64[|cos(src)|, n_words(|cos(dst)|)]`` — row i = the dst-local
successor set of src-local candidate i.  Compared to a dict of
full-universe bitsets this shrinks every row universe from |V_G| to
|cos(q)|, removes all dict lookups from the MJoin hot loop, and makes the
per-level constraint rows a single ``matrix[frontier]`` gather.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Literal, Optional

import numpy as np

from . import bitset
from .graph import DataGraph
from .query import CHILD, DESC, PatternQuery
from .reachability import IntervalLabels
from .simulation import (EdgeOracle, SimResult, fb_sim, fb_sim_bas,
                         match_sets)
from ..obs.trace import NULL_TRACER
from ..robust import faults

SimAlgo = Literal["bas", "dag", "dagmap", "none"]


@dataclass
class RIG:
    """Materialized runtime index graph (compact candidate-local layout).

    ``fwd[e]`` is a packed bit matrix ``(|cos(src)|, n_words(|cos(dst)|))``:
    row i = RIG successors of src candidate ``cand[src][i]`` w.r.t. query
    edge ``e``, expressed as *dst-local* ids.  ``bwd[e]`` is its packed
    transpose.  Rows are already restricted to both endpoints' candidate
    sets, so MJoin candidate generation is a pure multiway AND of gathered
    rows — ``cos`` itself is the all-ones set in local space.
    """

    query: PatternQuery
    n_graph: int
    cand: List[np.ndarray]         # cos(q) as sorted data-node ids:
                                   #   local id -> global node
    fwd: List[np.ndarray]          # per edge: uint64 (|cos(src)|, W_dst)
    bwd: List[np.ndarray]          # per edge: uint64 (|cos(dst)|, W_src)
    sim: Optional[SimResult] = None
    build_select_s: float = 0.0
    build_expand_s: float = 0.0
    # device-resident executor handle (jaxgm.frontier.ResidentIntersector),
    # built lazily on first frontier-device-resident enumeration and cached
    # here so repeated enumerations over one RIG upload the index only once
    resident: Optional[object] = field(default=None, repr=False)
    # ledger attribution key for device transfers / resident footprint
    # (the owning graph's identity; "-" = anonymous)
    graph_key: str = "-"

    def cos_indices(self, q: int) -> np.ndarray:
        return self.cand[q]

    def cos_size(self, q: int) -> int:
        return len(self.cand[q])

    def n_nodes(self) -> int:
        return sum(len(c) for c in self.cand)

    def edge_count(self, e: int) -> int:
        """Number of RIG edges materialized for query edge ``e``."""
        return bitset.count(self.fwd[e])

    def n_edges(self) -> int:
        return sum(self.edge_count(e) for e in range(len(self.fwd)))

    def size(self) -> int:
        """Paper's graph-size metric: |nodes| + |edges|."""
        return self.n_nodes() + self.n_edges()

    def is_empty(self) -> bool:
        return any(len(c) == 0 for c in self.cand)

    def release_resident(self) -> int:
        """Deterministically tear down the cached device-resident executor
        (if any), crediting the transfer ledger; returns bytes freed."""
        res, self.resident = self.resident, None
        if res is not None and hasattr(res, "close"):
            return res.close()
        return 0


# ----------------------------------------------------------- node prefilter
def prefilter(graph: DataGraph, q: PatternQuery) -> List[np.ndarray]:
    """Structural node pre-filtering [10, 49] applied by the paper to JM/TM
    (and to GM variants in Fig. 9): prune v from ms(q) unless its local
    degrees can satisfy q's child-edge fan-in/out, and for descendant edges
    unless v has any successor/predecessor at all when q does.
    """
    # Homomorphisms are not injective, so the sound degree bound is the
    # number of *distinct labels* among child-edge neighbours (targets with
    # different labels must map to different data nodes), not the edge count.
    out_child_labels = [set() for _ in range(q.n)]
    in_child_labels = [set() for _ in range(q.n)]
    out_any = np.zeros(q.n, dtype=np.int64)
    in_any = np.zeros(q.n, dtype=np.int64)
    for e in q.edges:
        out_any[e.src] += 1
        in_any[e.dst] += 1
        if e.kind == CHILD:
            out_child_labels[e.src].add(q.labels[e.dst])
            in_child_labels[e.dst].add(q.labels[e.src])
    odeg = graph.out_degree()
    ideg = graph.in_degree()
    fb = []
    for qi in range(q.n):
        mask = graph.label_mask(q.labels[qi])
        mask &= odeg >= len(out_child_labels[qi])
        mask &= ideg >= len(in_child_labels[qi])
        if out_any[qi] > 0:
            mask &= odeg >= 1
        if in_any[qi] > 0:
            mask &= ideg >= 1
        fb.append(bitset.pack(mask))
    return fb


# ------------------------------------------------------------------ BuildRIG
def build_rig(graph: DataGraph, q: PatternQuery,
              oracle: Optional[EdgeOracle] = None,
              sim_algo: SimAlgo = "dagmap",
              sim_passes: Optional[int] = 4,
              use_prefilter: bool = False,
              check_method: str = "bitbat",
              expand_method: Literal["bitset", "interval"] = "bitset",
              intervals: Optional[IntervalLabels] = None,
              trace=NULL_TRACER, budget=None) -> RIG:
    """Algorithm 4.

    sim_algo:
      * ``bas``    — FBSimBas (arbitrary edge order)
      * ``dag``    — FBSim (Dag+Δ) without change-flag skipping
      * ``dagmap`` — FBSim (Dag+Δ) + §5.5 convergence optimizations (default)
      * ``none``   — skip double simulation (GM-F variant: prefilter only)
    sim_passes: pass budget (paper fixes N=4); None = exact fixpoint.

    ``budget`` (an armed :class:`repro.robust.Budget`) makes the build a
    governed phase: the deadline is checked and the materialized adjacency
    bytes charged against ``max_rig_bytes`` per query edge, raising
    :class:`DeadlineExceeded` / :class:`ResourceExhausted` *before* the
    next edge is gathered.  The RIG is never persisted, so an abandoned
    build costs nothing to recover from — the caller simply recomputes.
    """
    oracle = oracle or EdgeOracle(graph)

    # ---- phase (a): node selection
    t0 = time.perf_counter()
    sim: Optional[SimResult] = None
    with trace.span("select") as sp:
        if use_prefilter:
            fb0 = prefilter(graph, q)
        else:
            fb0 = match_sets(graph, q)
        if sim_algo == "none":
            cos = fb0
        else:
            if sim_algo == "bas":
                sim = fb_sim_bas(graph, q, oracle, max_passes=sim_passes,
                                 method=check_method, fb0=fb0)
            elif sim_algo == "dag":
                sim = fb_sim(graph, q, oracle, max_passes=sim_passes,
                             method=check_method, use_change_flags=False)
            else:
                sim = fb_sim(graph, q, oracle, max_passes=sim_passes,
                             method=check_method, use_change_flags=True)
            cos = sim.fb
            if use_prefilter:
                cos = [a & b for a, b in zip(cos, fb0)]
        n = graph.n
        cand = [bitset.to_indices(c, n) for c in cos]
        if trace.enabled:
            sp.set(sim_algo=sim_algo,
                   sim_passes=sim.passes if sim else 0,
                   converged=sim.converged if sim else True,
                   pruned=sim.pruned if sim else 0,
                   cand_sizes=[len(c) for c in cand])
    t1 = time.perf_counter()

    # ---- phase (b): node expansion — one batched gather + column-compact
    # per query edge: rows = oracle matrix gathered at all src candidates,
    # restricted to dst candidates by the column gather itself (selecting
    # exactly the dst-candidate columns IS the AND against cos(dst)).
    fwd: List[np.ndarray] = []
    bwd: List[np.ndarray] = []
    expand_sp = trace.span("expand").__enter__()
    for ei, e in enumerate(q.edges):
        faults.maybe_fail("rig_expand")
        if budget is not None:
            budget.check_deadline(f"rig_expand[{ei}]")
        src_idx, dst_idx = cand[e.src], cand[e.dst]
        s_n, d_n = len(src_idx), len(dst_idx)
        if s_n == 0 or d_n == 0:
            fwd.append(np.zeros((s_n, bitset.n_words(d_n)), dtype=np.uint64))
            bwd.append(np.zeros((d_n, bitset.n_words(s_n)), dtype=np.uint64))
            continue
        mat = oracle.fwd_matrix(e.kind)
        if (expand_method == "interval" and intervals is not None
                and e.kind == DESC):
            # §5.5 early expansion termination on compact ids: a src
            # candidate v can only reach dst candidates with
            # begin <= end[v], so rows whose plausible prefix is empty are
            # skipped outright — never gathered or unpacked.  The oracle
            # rows are exact, so no further interval masking is needed
            # (and the surviving rows stay packed and chunk-bounded).
            begins = np.sort(intervals.begin[dst_idx])
            hi = np.searchsorted(begins, intervals.end[src_idx],
                                 side="right")
            f = np.zeros((s_n, bitset.n_words(d_n)), dtype=np.uint64)
            live = np.nonzero(hi > 0)[0]
            if len(live):
                f[live] = bitset.gather_columns(mat, src_idx[live],
                                                dst_idx, n)
        else:
            f = bitset.gather_columns(mat, src_idx, dst_idx, n)
        b = bitset.transpose(f, d_n)
        if budget is not None:
            budget.charge_rig(f.nbytes + b.nbytes, f"rig_expand[{ei}]")
        fwd.append(f)
        bwd.append(b)
    rig = RIG(query=q, n_graph=n, cand=cand, fwd=fwd, bwd=bwd, sim=sim,
              graph_key=getattr(graph, "graph_key", "-"))
    if trace.enabled:      # per-edge RIG edge counts cost a popcount each
        expand_sp.set(expand_method=expand_method,
                      edge_counts=[rig.edge_count(e)
                                   for e in range(len(fwd))],
                      rig_nodes=rig.n_nodes())
    expand_sp.__exit__(None, None, None)
    t2 = time.perf_counter()

    rig.build_select_s = t1 - t0
    rig.build_expand_s = t2 - t1
    return rig
