"""Runtime Index Graph (Def. 5.1) and BuildRIG (Alg. 4, §5.5).

A RIG of query Q over graph G is a k-partite graph: one independent node set
``cos(q)`` per query node with ``os(q) ⊆ cos(q) ⊆ ms(q)``, and, per query
edge (p, q), exactly the query-edge occurrences between surviving candidates.
It losslessly encodes all homomorphisms from Q to G (Prop. 5.1) and is built
on-the-fly per query — never persisted.

BuildRIG = *node selection* (double simulation — existence semantics)
followed by *node expansion* (materialize adjacency — all-matches semantics).
During expansion the outgoing/incoming edges of every candidate are indexed
by query edge, enabling the multiway adjacency-list intersections of MJoin.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Literal, Optional

import numpy as np

from . import bitset
from .graph import DataGraph
from .query import CHILD, DESC, PatternQuery
from .reachability import IntervalLabels
from .simulation import (EdgeOracle, SimResult, fb_sim, fb_sim_bas,
                         match_sets)

SimAlgo = Literal["bas", "dag", "dagmap", "none"]


@dataclass
class RIG:
    """Materialized runtime index graph.

    ``fwd[e][v]`` / ``bwd[e][u]`` are packed bitsets: the RIG adjacency of a
    candidate w.r.t. query edge index ``e`` — already restricted to the
    candidate sets of both endpoints, so MJoin candidate generation is a pure
    multiway AND of these rows (plus ``cos``).
    """

    query: PatternQuery
    n_graph: int
    cos: List[np.ndarray]                    # packed candidate sets per q-node
    fwd: List[Dict[int, np.ndarray]]         # per edge: src candidate -> row
    bwd: List[Dict[int, np.ndarray]]         # per edge: dst candidate -> row
    sim: Optional[SimResult] = None
    build_select_s: float = 0.0
    build_expand_s: float = 0.0

    def cos_indices(self, q: int) -> np.ndarray:
        return bitset.to_indices(self.cos[q], self.n_graph)

    def cos_size(self, q: int) -> int:
        return bitset.count(self.cos[q])

    def n_nodes(self) -> int:
        return sum(self.cos_size(q) for q in range(self.query.n))

    def n_edges(self) -> int:
        return sum(sum(bitset.count(row) for row in d.values()) for d in self.fwd)

    def size(self) -> int:
        """Paper's graph-size metric: |nodes| + |edges|."""
        return self.n_nodes() + self.n_edges()

    def is_empty(self) -> bool:
        return any(self.cos_size(q) == 0 for q in range(self.query.n))


# ----------------------------------------------------------- node prefilter
def prefilter(graph: DataGraph, q: PatternQuery) -> List[np.ndarray]:
    """Structural node pre-filtering [10, 49] applied by the paper to JM/TM
    (and to GM variants in Fig. 9): prune v from ms(q) unless its local
    degrees can satisfy q's child-edge fan-in/out, and for descendant edges
    unless v has any successor/predecessor at all when q does.
    """
    # Homomorphisms are not injective, so the sound degree bound is the
    # number of *distinct labels* among child-edge neighbours (targets with
    # different labels must map to different data nodes), not the edge count.
    out_child_labels = [set() for _ in range(q.n)]
    in_child_labels = [set() for _ in range(q.n)]
    out_any = np.zeros(q.n, dtype=np.int64)
    in_any = np.zeros(q.n, dtype=np.int64)
    for e in q.edges:
        out_any[e.src] += 1
        in_any[e.dst] += 1
        if e.kind == CHILD:
            out_child_labels[e.src].add(q.labels[e.dst])
            in_child_labels[e.dst].add(q.labels[e.src])
    odeg = graph.out_degree()
    ideg = graph.in_degree()
    fb = []
    for qi in range(q.n):
        mask = graph.label_mask(q.labels[qi])
        mask &= odeg >= len(out_child_labels[qi])
        mask &= ideg >= len(in_child_labels[qi])
        if out_any[qi] > 0:
            mask &= odeg >= 1
        if in_any[qi] > 0:
            mask &= ideg >= 1
        fb.append(bitset.pack(mask))
    return fb


# ------------------------------------------------------------------ BuildRIG
def build_rig(graph: DataGraph, q: PatternQuery,
              oracle: Optional[EdgeOracle] = None,
              sim_algo: SimAlgo = "dagmap",
              sim_passes: Optional[int] = 4,
              use_prefilter: bool = False,
              check_method: str = "bitbat",
              expand_method: Literal["bitset", "interval"] = "bitset",
              intervals: Optional[IntervalLabels] = None) -> RIG:
    """Algorithm 4.

    sim_algo:
      * ``bas``    — FBSimBas (arbitrary edge order)
      * ``dag``    — FBSim (Dag+Δ) without change-flag skipping
      * ``dagmap`` — FBSim (Dag+Δ) + §5.5 convergence optimizations (default)
      * ``none``   — skip double simulation (GM-F variant: prefilter only)
    sim_passes: pass budget (paper fixes N=4); None = exact fixpoint.
    """
    oracle = oracle or EdgeOracle(graph)

    # ---- phase (a): node selection
    t0 = time.perf_counter()
    sim: Optional[SimResult] = None
    if use_prefilter:
        fb0 = prefilter(graph, q)
    else:
        fb0 = match_sets(graph, q)
    if sim_algo == "none":
        cos = fb0
    else:
        if sim_algo == "bas":
            sim = fb_sim_bas(graph, q, oracle, max_passes=sim_passes,
                             method=check_method, fb0=fb0)
        elif sim_algo == "dag":
            sim = fb_sim(graph, q, oracle, max_passes=sim_passes,
                         method=check_method, use_change_flags=False)
        else:
            sim = fb_sim(graph, q, oracle, max_passes=sim_passes,
                         method=check_method, use_change_flags=True)
        cos = sim.fb
        if use_prefilter:
            cos = [a & b for a, b in zip(cos, fb0)]
    t1 = time.perf_counter()

    # ---- phase (b): node expansion
    fwd: List[Dict[int, np.ndarray]] = []
    bwd: List[Dict[int, np.ndarray]] = []
    n = graph.n
    for e in q.edges:
        f: Dict[int, np.ndarray] = {}
        b: Dict[int, np.ndarray] = {}
        src_idx = bitset.to_indices(cos[e.src], n)
        dst_bits = cos[e.dst]
        if expand_method == "interval" and intervals is not None and e.kind == DESC:
            dst_idx = bitset.to_indices(dst_bits, n)
            order = np.argsort(intervals.begin[dst_idx])
            dst_sorted = dst_idx[order]
            begins = intervals.begin[dst_sorted]
            for v in src_idx:
                # early expansion termination: stop once begin(v_q) > end(v_p)
                hi = int(np.searchsorted(begins, intervals.end[int(v)],
                                         side="right"))
                cand = dst_sorted[:hi]
                row = oracle.fwd_row(int(v), e.kind)
                sel = cand[bitset.unpack(row, n)[cand]]
                f[int(v)] = bitset.from_indices(sel, n)
        else:
            for v in src_idx:
                f[int(v)] = oracle.fwd_row(int(v), e.kind) & dst_bits
        # drop empty rows and build the reverse index
        f = {v: r for v, r in f.items() if bitset.any_set(r)}
        cols = np.zeros(bitset.n_words(n), dtype=np.uint64)
        for r in f.values():
            cols |= r
        for u in bitset.to_indices(cols, n):
            b[int(u)] = oracle.bwd_row(int(u), e.kind) & cos[e.src]
        fwd.append(f)
        bwd.append(b)
    t2 = time.perf_counter()

    return RIG(query=q, n_graph=n, cos=cos, fwd=fwd, bwd=bwd, sim=sim,
               build_select_s=t1 - t0, build_expand_s=t2 - t1)
