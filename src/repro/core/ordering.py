"""Search-order strategies for MJoin (§6.1, Table 3).

* ``JO``  — greedy join-based ordering [21] driven by *RIG statistics*:
  start at the query node with the smallest candidate set; repeatedly append
  the unselected node adjacent to the prefix with the smallest |cos|.
* ``RI``  — structure-only ordering [8]: maximize edge constraints to the
  prefix, as early as possible; ties broken by connectivity to unvisited
  neighbourhood, then by degree.
* ``BJ``  — dynamic-programming optimal left-deep plan over estimated join
  costs (exponential in |V_Q|; the paper shows it does not scale past ~10
  nodes — we guard with a node cap).
"""

from __future__ import annotations

from itertools import combinations
from typing import List, Optional

import numpy as np

from .query import PatternQuery
from .rig import RIG


def _adjacent(q: PatternQuery, a: int, b: int) -> bool:
    return any((e.src == a and e.dst == b) or (e.src == b and e.dst == a)
               for e in q.edges)


def order_jo(rig: RIG) -> List[int]:
    q = rig.query
    sizes = [rig.cos_size(i) for i in range(q.n)]
    order = [int(np.argmin(sizes))]
    remaining = set(range(q.n)) - set(order)
    while remaining:
        frontier = [r for r in remaining if any(_adjacent(q, r, s) for s in order)]
        if not frontier:                     # disconnected pattern guard
            frontier = list(remaining)
        nxt = min(frontier, key=lambda r: (sizes[r], r))
        order.append(nxt)
        remaining.discard(nxt)
    return order


def order_ri(q: PatternQuery) -> List[int]:
    """RI [8]: data-independent; prefers nodes maximally constrained by the
    already-ordered prefix (then by future connectivity, then degree)."""
    deg = [len(q.neighbors(i)) for i in range(q.n)]
    order = [int(np.argmax(deg))]
    remaining = set(range(q.n)) - set(order)
    while remaining:
        def key(r: int):
            to_prefix = sum(1 for s in order if _adjacent(q, r, s))
            to_future = sum(1 for s in remaining if s != r and _adjacent(q, r, s))
            return (-to_prefix, -to_future, -deg[r], r)
        nxt = min(remaining, key=key)
        order.append(nxt)
        remaining.discard(nxt)
    return order


def order_bj(rig: RIG, max_nodes: int = 14) -> Optional[List[int]]:
    """DP over subsets for an optimal left-deep plan; cost model = sum of
    estimated intermediate cardinalities with independence-style selectivity
    per connecting edge.  Returns None beyond ``max_nodes`` (the paper's
    scalability point about BJ)."""
    q = rig.query
    n = q.n
    if n > max_nodes:
        return None
    sizes = np.array([max(rig.cos_size(i), 1) for i in range(n)], dtype=np.float64)
    # per-edge selectivity estimate: |occ(e)| / (|cos(src)| * |cos(dst)|)
    sel = {}
    for ei, e in enumerate(q.edges):
        occ = rig.edge_count(ei)
        denom = sizes[e.src] * sizes[e.dst]
        sel[(e.src, e.dst)] = float(occ) / denom if denom else 0.0

    def extend_card(card: float, subset: frozenset, nxt: int) -> float:
        c = card * sizes[nxt]
        for (a, b), s in sel.items():
            if (a in subset and b == nxt) or (b in subset and a == nxt):
                c *= s
        return c

    # DP: best (cost, card, order) per subset
    best = {}
    for v in range(n):
        best[frozenset([v])] = (sizes[v], sizes[v], [v])
    for size in range(1, n):
        layer = [s for s in best if len(s) == size]
        for subset in layer:
            cost, card, order = best[subset]
            for nxt in range(n):
                if nxt in subset:
                    continue
                if size and not any(_adjacent(q, nxt, s) for s in subset):
                    if size < n - 1:   # delay cartesian products
                        continue
                ncard = extend_card(card, subset, nxt)
                ncost = cost + ncard
                key = subset | {nxt}
                if key not in best or ncost < best[key][0]:
                    best[key] = (ncost, ncard, order + [nxt])
    full = frozenset(range(n))
    return best[full][2] if full in best else order_jo(rig)


def get_order(rig: RIG, strategy: str = "jo") -> List[int]:
    if strategy == "jo":
        return order_jo(rig)
    if strategy == "ri":
        return order_ri(rig.query)
    if strategy == "bj":
        o = order_bj(rig)
        return o if o is not None else order_jo(rig)
    raise ValueError(f"unknown ordering strategy: {strategy}")
