"""Packed-bitset algebra used by the host-faithful path.

The paper implements its candidate sets and adjacency lists as roaring
bitmaps (§5.5 "Implementation").  Roaring's value proposition is CPU-cache
friendly *compressed* set algebra; for the host-faithful reproduction we use
flat packed ``uint64`` words (numpy), which provide the same AND/OR/ANDNOT
semantics with vectorized word-wise ops.  The TPU path (``repro.kernels``)
re-implements the same algebra with on-the-fly unpacking into MXU tiles.

Conventions
-----------
* A *bitset over a universe of size n* is a ``uint64[ceil(n/64)]`` array,
  little-endian bit order (bit ``i`` lives in word ``i >> 6`` at position
  ``i & 63``).
* A *bit matrix* is ``uint64[n, W]`` — one packed row per universe element
  (e.g. packed adjacency rows, packed reachability rows).
"""

from __future__ import annotations

import numpy as np

WORD = 64


def n_words(n: int) -> int:
    """Number of 64-bit words needed for a universe of size ``n``."""
    return (n + WORD - 1) // WORD


def pack(mask: np.ndarray) -> np.ndarray:
    """Pack a boolean array (..., n) into uint64 words (..., ceil(n/64)).

    Little-endian within each byte and across bytes, so that
    ``bit i -> word[i // 64] >> (i % 64) & 1``.
    """
    mask = np.asarray(mask, dtype=bool)
    n = mask.shape[-1]
    pad_bits = (-n) % (8 * 8)  # pad to whole uint64 words
    if pad_bits:
        pad_shape = mask.shape[:-1] + (pad_bits,)
        mask = np.concatenate([mask, np.zeros(pad_shape, dtype=bool)], axis=-1)
    bytes_ = np.ascontiguousarray(np.packbits(mask, axis=-1,
                                              bitorder="little"))
    return bytes_.view(np.uint64)


def unpack(words: np.ndarray, n: int) -> np.ndarray:
    """Inverse of :func:`pack`: uint64 words (..., W) -> bool (..., n)."""
    bytes_ = np.ascontiguousarray(words).view(np.uint8)
    bits = np.unpackbits(bytes_, axis=-1, bitorder="little")
    return bits[..., :n].astype(bool)


def empty(n: int) -> np.ndarray:
    return np.zeros(n_words(n), dtype=np.uint64)


def full(n: int) -> np.ndarray:
    out = np.full(n_words(n), np.uint64(0xFFFFFFFFFFFFFFFF), dtype=np.uint64)
    tail = n % WORD
    if tail:
        out[-1] = np.uint64((1 << tail) - 1)
    return out


def from_indices(idx: np.ndarray, n: int) -> np.ndarray:
    """Bitset with exactly the bits in ``idx`` set."""
    mask = np.zeros(n, dtype=bool)
    mask[np.asarray(idx, dtype=np.int64)] = True
    return pack(mask)


def to_indices(words: np.ndarray, n: int) -> np.ndarray:
    """Sorted array of set-bit positions."""
    return np.nonzero(unpack(words, n))[0]


def count(words: np.ndarray) -> int:
    """Popcount over all words (supports matrices; sums everything)."""
    return int(np.bitwise_count(words).sum())


def count_rows(words: np.ndarray) -> np.ndarray:
    """Per-row popcount for a bit matrix (n, W) -> int64 (n,)."""
    return np.bitwise_count(words).sum(axis=-1).astype(np.int64)


def any_set(words: np.ndarray) -> bool:
    return bool(words.any())


def get(words: np.ndarray, i: int) -> bool:
    return bool((words[i >> 6] >> np.uint64(i & 63)) & np.uint64(1))


def set_bit(words: np.ndarray, i: int) -> None:
    words[i >> 6] |= np.uint64(1) << np.uint64(i & 63)


def clear_bit(words: np.ndarray, i: int) -> None:
    words[i >> 6] &= ~(np.uint64(1) << np.uint64(i & 63))


def intersect_any(a: np.ndarray, b: np.ndarray) -> bool:
    """True iff a ∩ b ≠ ∅  (no materialization)."""
    return bool(np.bitwise_and(a, b).any())


def intersect_many(rows: np.ndarray) -> np.ndarray:
    """AND-reduce k packed rows (k, W) -> (W,).

    This is the host analogue of the ``intersect`` Pallas kernel: the
    multiway-join candidate computation of MJoin (Alg. 5 lines 5-7).
    """
    if rows.shape[0] == 0:
        raise ValueError("intersect_many needs at least one row")
    return np.bitwise_and.reduce(rows, axis=0)


def union_rows(matrix: np.ndarray, idx: np.ndarray) -> np.ndarray:
    """OR-reduce selected rows of a bit matrix: ∪_{v in idx} matrix[v].

    The paper's ``bitBat`` batch operation (§5.5) unions the adjacency
    bitmaps of all surviving candidates in one pass.
    """
    if len(idx) == 0:
        return np.zeros(matrix.shape[1], dtype=np.uint64)
    return np.bitwise_or.reduce(matrix[np.asarray(idx, dtype=np.int64)], axis=0)


def gather_columns(matrix: np.ndarray, rows: np.ndarray, cols: np.ndarray,
                   n: int, chunk_bytes: int = 1 << 25) -> np.ndarray:
    """Gather + column-compact a packed bit matrix in one chunked pass:
    ``(n, n_words(n))[rows] -> (len(rows), n_words(len(cols)))``.

    Output row r has bit j set iff ``matrix[rows[r]]`` has bit ``cols[j]``
    set — i.e. the selected rows re-expressed over the compact universe
    ``cols`` (the candidate-local id spaces of the RIG).  Both the row
    gather and the dense unpack happen per chunk so the transient slab
    stays bounded (~``chunk_bytes``).
    """
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    r = len(rows)
    if len(cols) == 0 or r == 0:
        return np.zeros((r, n_words(len(cols))), dtype=np.uint64)
    out = np.empty((r, n_words(len(cols))), dtype=np.uint64)
    step = max(1, chunk_bytes // max(n, 1))
    for lo in range(0, r, step):
        hi = min(lo + step, r)
        out[lo:hi] = pack(unpack(matrix[rows[lo:hi]], n)[:, cols])
    return out


def transpose(matrix: np.ndarray, n_cols: int,
              chunk_bytes: int = 1 << 25) -> np.ndarray:
    """Packed transpose: (R, n_words(n_cols)) -> (n_cols, n_words(R)).

    Bit (i, j) of the result equals bit (j, i) of the input.  Processed in
    64-bit-aligned column blocks so the dense transient stays bounded.
    """
    r = matrix.shape[0]
    out = np.empty((n_cols, n_words(r)), dtype=np.uint64)
    if n_cols == 0:
        return out
    if r == 0:
        out[:] = 0
        return out
    step_w = max(1, chunk_bytes // max(r * WORD, 1))       # words per block
    for lo_w in range(0, matrix.shape[1], step_w):
        hi_w = min(lo_w + step_w, matrix.shape[1])
        dense = unpack(matrix[:, lo_w:hi_w], (hi_w - lo_w) * WORD)
        lo, hi = lo_w * WORD, min(hi_w * WORD, n_cols)
        out[lo:hi] = pack(np.ascontiguousarray(dense.T[: hi - lo]))
    return out


def matvec_any(matrix: np.ndarray, vec: np.ndarray) -> np.ndarray:
    """Boolean mat-vec: out[i] = (matrix[i] ∩ vec) ≠ ∅, for all rows at once.

    out is a *bool* array (n,).  This is the whole-pass batched form of the
    paper's existence check: for every node v, "does v have a neighbour
    inside ``vec``?".  The TPU path lowers this onto the MXU via ``bitmm``.
    """
    return np.bitwise_and(matrix, vec[None, :]).any(axis=1)
