"""MJoin — multiway intersection-based occurrence enumeration (Alg. 5, §6).

Backtracking over a search order; at recursion level *i* the candidate set
for query node q_i is the intersection of

* ``cos(q_i)`` (the RIG node set), and
* one RIG adjacency row per already-bound neighbour of q_i,

realized as packed-bitset ANDs — a true multiway join with no binary-join
intermediate results.  Worst-case optimal (Thm. 2/3: runtime within the AGM
bound of the RIG edge relations; space O(n · MaxNq)).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from . import bitset
from .rig import RIG

DEFAULT_LIMIT = 10_000_000   # paper §7.1: stop after 10^7 matches


@dataclass
class MJoinStats:
    results: int = 0
    expanded: int = 0            # partial assignments explored
    intersections: int = 0
    truncated: bool = False      # hit the result limit
    enumerate_s: float = 0.0


@dataclass
class MJoinResult:
    count: int
    tuples: Optional[np.ndarray]     # (k, n_query) int64, in *query-node* order
    stats: MJoinStats
    order: List[int]


def mjoin(rig: RIG, order: List[int], limit: Optional[int] = DEFAULT_LIMIT,
          materialize: bool = True, max_tuples: int = 1_000_000) -> MJoinResult:
    """Enumerate (or count) the occurrences encoded by ``rig``.

    ``limit`` bounds the number of results visited (None = exhaustive);
    ``max_tuples`` bounds materialization only (counting continues).
    """
    q = rig.query
    n = q.n
    t0 = time.perf_counter()
    stats = MJoinStats()

    if rig.is_empty():
        return MJoinResult(0, np.empty((0, n), dtype=np.int64) if materialize
                           else None, stats, order)

    pos = {qi: i for i, qi in enumerate(order)}
    # constraints[i]: list of (prefix_position, edge_index, is_forward)
    #   is_forward=True  => edge (order[j] -> order[i]): row = rig.fwd[e][t_j]
    #   is_forward=False => edge (order[i] -> order[j]): row = rig.bwd[e][t_j]
    constraints: List[List[tuple]] = [[] for _ in range(n)]
    for ei, e in enumerate(q.edges):
        ps, pd = pos[e.src], pos[e.dst]
        if ps < pd:
            constraints[pd].append((ps, ei, True))
        else:
            constraints[ps].append((pd, ei, False))

    nW = bitset.n_words(rig.n_graph)
    t = np.full(n, -1, dtype=np.int64)           # assignment in *order* positions
    cand_lists: List[np.ndarray] = [np.empty(0, np.int64)] * n
    cursors = np.zeros(n, dtype=np.int64)
    out: List[np.ndarray] = []
    count = 0

    def candidates(i: int) -> np.ndarray:
        qi = order[i]
        acc = rig.cos[qi]
        for (j, ei, isf) in constraints[i]:
            adj = rig.fwd[ei] if isf else rig.bwd[ei]
            row = adj.get(int(t[j]))
            if row is None:
                return np.empty(0, dtype=np.int64)
            acc = acc & row
            stats.intersections += 1
            if not acc.any():
                return np.empty(0, dtype=np.int64)
        return bitset.to_indices(acc, rig.n_graph)

    i = 0
    cand_lists[0] = candidates(0)
    cursors[0] = 0
    while i >= 0:
        if limit is not None and count >= limit:
            stats.truncated = True
            break
        lst = cand_lists[i]
        c = cursors[i]
        if c >= len(lst):
            i -= 1
            if i >= 0:
                cursors[i] += 1
            continue
        t[i] = lst[c]
        stats.expanded += 1
        if i == n - 1:
            count += 1
            if materialize and len(out) < max_tuples:
                tup = np.empty(n, dtype=np.int64)
                tup[np.array(order)] = t          # back to query-node order
                out.append(tup)
            cursors[i] += 1
            continue
        i += 1
        cand_lists[i] = candidates(i)
        cursors[i] = 0

    stats.results = count
    stats.enumerate_s = time.perf_counter() - t0
    tuples = (np.stack(out) if out else np.empty((0, n), dtype=np.int64)) \
        if materialize else None
    return MJoinResult(count=count, tuples=tuples, stats=stats, order=order)
