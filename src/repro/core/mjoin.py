"""MJoin — multiway intersection-based occurrence enumeration (Alg. 5, §6).

At enumeration position *i* the candidate set for query node q_i is the
intersection of ``cos(q_i)`` (the RIG node set) and one RIG adjacency row
per already-bound neighbour of q_i — a true multiway join with no binary
intermediate results.  Worst-case optimal (Thm. 2/3: runtime within the
AGM bound of the RIG edge relations).

Two enumeration strategies over the compact candidate-local RIG layout:

* ``backtrack`` — the paper's one-tuple-at-a-time depth-first search.
  ``cos`` is the all-ones set in local space, so each level is K gathered
  rows AND-reduced (K = bound neighbours of q_i).
* ``frontier`` / ``frontier-device`` — level-synchronous batched
  enumeration: an ``(F, level)`` table of partial assignments is extended
  one position at a time; the K constraint rows of the *whole frontier*
  are gathered into ``(F, K, W)`` and AND-reduced + popcounted in one call
  (numpy host path, or the ``intersect`` Pallas kernel on device).
  Frontier slabs bound the transient gather memory; both strategies
  enumerate in the same lexicographic order, so ``limit`` / ``max_tuples``
  / truncation semantics are preserved exactly.
"""

from __future__ import annotations

import time
import warnings
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from . import bitset
from .rig import RIG

DEFAULT_LIMIT = 10_000_000   # paper §7.1: stop after 10^7 matches
ENUM_METHODS = ("backtrack", "frontier", "frontier-device")

_FRONTIER_SLAB = 8192        # frontier rows per gather slab (memory bound)
_MAT_INIT = 1024             # initial materialization buffer rows


@dataclass
class MJoinStats:
    results: int = 0
    expanded: int = 0            # partial assignments explored
    intersections: int = 0       # constraint-row ANDs (per partial, per row)
    truncated: bool = False      # hit the result limit
    enumerate_s: float = 0.0
    method: str = "backtrack"    # strategy that actually ran
    frontier_peak: int = 0       # widest frontier level (frontier methods)
    device_calls: int = 0        # intersect-kernel dispatches (device method)


@dataclass
class MJoinResult:
    count: int
    tuples: Optional[np.ndarray]     # (k, n_query) int64, in *query-node* order
    stats: MJoinStats
    order: List[int]


class FrontierOverflow(RuntimeError):
    """Raised when a frontier level exceeds ``max_frontier`` rows; the
    driver falls back to the constant-space backtracking strategy."""


# ------------------------------------------------------------------ helpers
def _constraints(q, order: List[int]) -> List[List[Tuple[int, int, bool]]]:
    """constraints[i]: list of (prefix_position, edge_index, is_forward).

    is_forward=True  => edge (order[j] -> order[i]): row = rig.fwd[e][t_j]
    is_forward=False => edge (order[i] -> order[j]): row = rig.bwd[e][t_j]
    """
    pos = {qi: i for i, qi in enumerate(order)}
    cons: List[List[Tuple[int, int, bool]]] = [[] for _ in range(q.n)]
    for ei, e in enumerate(q.edges):
        ps, pd = pos[e.src], pos[e.dst]
        if ps < pd:
            cons[pd].append((ps, ei, True))
        else:
            cons[ps].append((pd, ei, False))
    return cons


def _to_query_order(assign: np.ndarray, order: List[int],
                    cand: List[np.ndarray]) -> np.ndarray:
    """Local-id rows in order-position layout -> global-id tuples in
    query-node order (vectorized over all rows per position)."""
    k = assign.shape[0]
    out = np.empty((k, len(order)), dtype=np.int64)
    for p, qi in enumerate(order):
        out[:, qi] = cand[qi][assign[:, p]]
    return out


_DEVICE = None
_DEVICE_FAILED = False


def _device_intersector():
    """The jax/Pallas frontier executor, or None if jax is unavailable."""
    global _DEVICE, _DEVICE_FAILED
    if _DEVICE is None and not _DEVICE_FAILED:
        try:
            from ..jaxgm.frontier import DeviceIntersector
            _DEVICE = DeviceIntersector()
        except Exception as e:                      # pragma: no cover - env
            _DEVICE_FAILED = True
            warnings.warn(
                f"frontier-device unavailable ({type(e).__name__}: {e}); "
                f"falling back to the host frontier path", RuntimeWarning,
                stacklevel=3)
    return _DEVICE


# ---------------------------------------------------------------- backtrack
def _mjoin_backtrack(rig: RIG, order: List[int], cons, limit,
                     materialize: bool, max_tuples: int,
                     stats: MJoinStats) -> Tuple[int, Optional[np.ndarray]]:
    n = rig.query.n
    sizes = [rig.cos_size(qi) for qi in order]
    all_ids = [np.arange(s, dtype=np.int64) for s in sizes]
    empty = np.empty(0, dtype=np.int64)

    t = np.full(n, -1, dtype=np.int64)       # local ids, order positions
    cand_lists: List[np.ndarray] = [empty] * n
    cursors = np.zeros(n, dtype=np.int64)
    count = 0

    # pre-sized growable materialization buffer (local ids, order layout)
    buf = np.empty((min(_MAT_INIT, max_tuples), n), dtype=np.int64)
    n_mat = 0

    def candidates(i: int) -> np.ndarray:
        cs = cons[i]
        if not cs:
            return all_ids[i]
        j, ei, isf = cs[0]
        acc = (rig.fwd[ei] if isf else rig.bwd[ei])[t[j]]
        stats.intersections += 1
        if len(cs) > 1:
            acc = acc.copy()
            for (j, ei, isf) in cs[1:]:
                acc &= (rig.fwd[ei] if isf else rig.bwd[ei])[t[j]]
                stats.intersections += 1
                if not acc.any():
                    return empty
        elif not acc.any():
            return empty
        return bitset.to_indices(acc, sizes[i])

    i = 0
    cand_lists[0] = candidates(0)
    cursors[0] = 0
    while i >= 0:
        if limit is not None and count >= limit:
            stats.truncated = True
            break
        lst = cand_lists[i]
        c = cursors[i]
        if c >= len(lst):
            i -= 1
            if i >= 0:
                cursors[i] += 1
            continue
        t[i] = lst[c]
        stats.expanded += 1
        if i == n - 1:
            count += 1
            if materialize and n_mat < max_tuples:
                if n_mat == len(buf):                  # amortized growth
                    buf = np.vstack([buf, np.empty_like(buf)])
                buf[n_mat] = t
                n_mat += 1
            cursors[i] += 1
            continue
        i += 1
        cand_lists[i] = candidates(i)
        cursors[i] = 0

    tuples = _to_query_order(buf[:n_mat], order, rig.cand) \
        if materialize else None
    return count, tuples


# ----------------------------------------------------------------- frontier
def _slab_intersect(rig: RIG, cs, slab: np.ndarray,
                    intersector, stats: MJoinStats):
    """Gather the K constraint rows for one frontier slab and AND-reduce.

    Returns ``(acc, counts)``: the packed candidate rows (f, W) plus, on
    the device path, the kernel's fused per-row popcounts (None on the
    host path — computed lazily only where needed).  ``cs`` is non-empty
    (K >= 1); each constraint contributes one gathered row per frontier
    entry.
    """
    stats.intersections += len(cs) * len(slab)
    if intersector is not None:
        rows = np.stack([(rig.fwd[ei] if isf else rig.bwd[ei])[slab[:, j]]
                         for (j, ei, isf) in cs], axis=1)    # (f, K, W)
        acc, counts = intersector(rows)
        stats.device_calls += 1
        return acc, counts
    j, ei, isf = cs[0]
    acc = (rig.fwd[ei] if isf else rig.bwd[ei])[slab[:, j]]  # gather = copy
    for (j, ei, isf) in cs[1:]:
        acc &= (rig.fwd[ei] if isf else rig.bwd[ei])[slab[:, j]]
    return acc, None


def _mjoin_frontier(rig: RIG, order: List[int], cons, limit,
                    materialize: bool, max_tuples: int, stats: MJoinStats,
                    device: bool, max_frontier: int
                    ) -> Tuple[int, Optional[np.ndarray]]:
    n = rig.query.n
    sizes = [rig.cos_size(qi) for qi in order]
    intersector = _device_intersector() if device else None
    if device and intersector is None:
        stats.method = "frontier"                    # jax missing: host path

    # number of results to visit / to materialize
    mat_cap = max_tuples if limit is None else min(max_tuples, limit)
    mat_blocks: List[np.ndarray] = []
    n_mat = 0
    count = 0

    frontier = np.arange(sizes[0], dtype=np.int64)[:, None]   # (F, 1)
    stats.frontier_peak = len(frontier)
    stats.expanded += len(frontier)

    if n == 1:
        count = sizes[0]
        if limit is not None and count >= limit:
            count = limit
            stats.truncated = True
        if materialize:
            mat_blocks.append(frontier[:min(count, mat_cap)])
            n_mat = len(mat_blocks[0])
    else:
        for i in range(1, n):
            last = i == n - 1
            n_i = sizes[i]
            cs = cons[i]
            new_parts: List[np.ndarray] = []
            new_rows = 0
            done = False
            # slab rows bounded by both the row count and the dense unpack
            # width, so the per-slab transient stays ~32 MB even for huge
            # candidate sets
            slab_rows = max(1, min(_FRONTIER_SLAB,
                                   (1 << 25) // max(n_i, 1)))
            for lo in range(0, len(frontier), slab_rows):
                slab = frontier[lo:lo + slab_rows]
                counts = None
                if cs:
                    acc, counts = _slab_intersect(rig, cs, slab,
                                                  intersector, stats)
                    bits = None
                else:                      # disconnected pattern: cartesian
                    acc = None
                    bits = np.ones((len(slab), n_i), dtype=bool)
                if last:
                    if counts is None:
                        counts = (bitset.count_rows(acc) if acc is not None
                                  else np.full(len(slab), n_i,
                                               dtype=np.int64))
                    slab_total = int(counts.sum())
                    want = min(mat_cap - n_mat, slab_total) \
                        if materialize else 0
                    if want > 0:
                        if bits is None:
                            bits = bitset.unpack(acc, n_i)
                        rid, cid = np.nonzero(bits)
                        block = np.concatenate(
                            [slab[rid[:want]],
                             cid[:want, None].astype(np.int64)], axis=1)
                        mat_blocks.append(block)
                        n_mat += len(block)
                    count += slab_total
                    stats.expanded += slab_total
                    if limit is not None and count >= limit:
                        stats.expanded -= count - limit
                        count = limit
                        stats.truncated = True
                        done = True
                        break
                else:
                    if bits is None:
                        bits = bitset.unpack(acc, n_i)
                    rid, cid = np.nonzero(bits)
                    if len(rid):
                        new_parts.append(np.concatenate(
                            [slab[rid], cid[:, None].astype(np.int64)],
                            axis=1))
                        new_rows += len(rid)
                        # enforce the bound *while* accumulating — before
                        # the oversized level is ever materialized whole
                        if new_rows > max_frontier:
                            raise FrontierOverflow(
                                f"frontier level {i} exceeds "
                                f"max_frontier={max_frontier} rows")
            if done or last:
                break
            frontier = (np.vstack(new_parts) if new_parts
                        else np.empty((0, i + 1), dtype=np.int64))
            stats.frontier_peak = max(stats.frontier_peak, len(frontier))
            stats.expanded += len(frontier)
            if len(frontier) == 0:
                break

    tuples = None
    if materialize:
        assign = (np.vstack(mat_blocks) if mat_blocks
                  else np.empty((0, n), dtype=np.int64))
        tuples = _to_query_order(assign, order, rig.cand)
    return count, tuples


# ---------------------------------------------------------------------- API
def mjoin(rig: RIG, order: List[int], limit: Optional[int] = DEFAULT_LIMIT,
          materialize: bool = True, max_tuples: int = 1_000_000,
          method: str = "backtrack",
          max_frontier: int = 1 << 25) -> MJoinResult:
    """Enumerate (or count) the occurrences encoded by ``rig``.

    ``limit`` bounds the number of results visited (None = exhaustive);
    ``max_tuples`` bounds materialization only (counting continues);
    ``method`` picks the enumeration strategy (see module docstring) —
    a frontier level wider than ``max_frontier`` rows falls back to
    ``backtrack`` to keep memory bounded.
    """
    if method not in ENUM_METHODS:
        raise ValueError(f"unknown enum method: {method!r} "
                         f"(expected one of {ENUM_METHODS})")
    q = rig.query
    n = q.n
    t0 = time.perf_counter()
    stats = MJoinStats(method=method)

    if rig.is_empty():
        stats.enumerate_s = time.perf_counter() - t0
        return MJoinResult(0, np.empty((0, n), dtype=np.int64) if materialize
                           else None, stats, order)
    if limit is not None and limit <= 0:     # visit budget exhausted upfront
        stats.truncated = True
        stats.enumerate_s = time.perf_counter() - t0
        return MJoinResult(0, np.empty((0, n), dtype=np.int64) if materialize
                           else None, stats, order)

    cons = _constraints(q, order)
    if method == "backtrack":
        count, tuples = _mjoin_backtrack(rig, order, cons, limit,
                                         materialize, max_tuples, stats)
    else:
        try:
            count, tuples = _mjoin_frontier(
                rig, order, cons, limit, materialize, max_tuples, stats,
                device=(method == "frontier-device"),
                max_frontier=max_frontier)
        except FrontierOverflow:
            stats = MJoinStats(method="backtrack")   # strategy that ran
            count, tuples = _mjoin_backtrack(rig, order, cons, limit,
                                             materialize, max_tuples, stats)

    stats.results = count
    stats.enumerate_s = time.perf_counter() - t0
    return MJoinResult(count=count, tuples=tuples, stats=stats, order=order)
