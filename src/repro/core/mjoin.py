"""MJoin — multiway intersection-based occurrence enumeration (Alg. 5, §6).

At enumeration position *i* the candidate set for query node q_i is the
intersection of ``cos(q_i)`` (the RIG node set) and one RIG adjacency row
per already-bound neighbour of q_i — a true multiway join with no binary
intermediate results.  Worst-case optimal (Thm. 2/3: runtime within the
AGM bound of the RIG edge relations).

Two enumeration strategies over the compact candidate-local RIG layout:

* ``backtrack`` — the paper's one-tuple-at-a-time depth-first search.
  ``cos`` is the all-ones set in local space, so each level is K gathered
  rows AND-reduced (K = bound neighbours of q_i).
* ``frontier`` / ``frontier-device`` — level-synchronous batched
  enumeration: an ``(F, level)`` table of partial assignments is extended
  one position at a time; the K constraint rows of the *whole frontier*
  are gathered into ``(F, K, W)`` and AND-reduced + popcounted in one call
  (numpy host path, or the ``intersect`` Pallas kernel on device).
  Frontier slabs bound the transient gather memory; both strategies
  enumerate in the same lexicographic order, so ``limit`` / ``max_tuples``
  / truncation semantics are preserved exactly.
* ``frontier-device-resident`` — the packed RIG matrices are uploaded to
  the device **once** (:class:`repro.jaxgm.frontier.ResidentIntersector`)
  and each level ships only ``(F, K)`` int32 constraint-row indices; the
  fused ``gather_intersect`` kernel does gather + AND + popcount on
  device, and frontier expansion returns compact (row, column) pair pages
  instead of dense boolean slabs.  Enumeration is *paged depth-first over
  level-synchronous pages*: a level wider than ``max_frontier`` is split
  into in-order pages that are recursed one at a time — same lexicographic
  order, bounded memory, and no fallback-to-backtrack (this method never
  raises :class:`FrontierOverflow`).

Both strategies are implemented as *block generators* over the shared
constraint machinery, which gives three consumption modes on one code
path:

* :func:`mjoin` — the classic one-shot API (count + optional tuples);
* :func:`iter_tuples` — a chunked streaming API (:class:`MJoinStream`)
  that yields fixed-size ndarray chunks lazily, in the same lexicographic
  order as one-shot enumeration, with ``limit`` pushdown: a consumer that
  stops early (or hits the limit mid-chunk) never visits the tail — the
  backtrack search simply pauses, and the frontier path reads no further
  last-level slabs (observable via ``MJoinStats.intersections`` /
  ``device_calls``);
* :func:`mjoin_batched` — cross-query counting: several queries'
  frontier enumerations run as coroutines under one scheduler that pads
  and stacks their pending ``(F, K, W)`` constraint gathers into a single
  ``(ΣF, K, W)`` slab per round — one device dispatch shared by the whole
  batch instead of per-query dispatches.
"""

from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from . import bitset
from .rig import RIG
from .slabgeom import padded_rows_cap
from ..obs.trace import NULL_TRACER
from ..robust.errors import BreakerOpen, DeadlineExceeded, DeviceFailure

DEFAULT_LIMIT = 10_000_000   # paper §7.1: stop after 10^7 matches
ENUM_METHODS = ("backtrack", "frontier", "frontier-device",
                "frontier-device-resident")

_FRONTIER_SLAB = 8192        # frontier rows per gather slab (memory bound)
_INF_CAP = 1 << 62           # "materialize everything" sentinel
_DEADLINE_STEPS = 1024       # backtrack loop iterations between clock reads


@dataclass
class MJoinStats:
    results: int = 0
    expanded: int = 0            # partial assignments explored
    intersections: int = 0       # constraint-row ANDs (per partial, per row)
    truncated: bool = False      # hit the result limit
    enumerate_s: float = 0.0
    method: str = "backtrack"    # strategy that actually ran
    frontier_peak: int = 0       # widest frontier level (frontier methods)
    device_calls: int = 0        # intersect-kernel dispatches (device method)
    # observability (PR 6): per-level frontier widths (frontier methods,
    # level 0 = the root candidate set), wall time inside the device
    # intersector (fenced), and the final local->global materialization.
    frontier_levels: List[int] = field(default_factory=list)
    device_s: float = 0.0
    materialize_s: float = 0.0
    # resource governance (PR 7): a budget deadline noticed at a slab/block
    # boundary stops enumeration cleanly — the counted/yielded prefix is a
    # valid lexicographic truncation; each degradation-ladder step taken
    # (device -> host-intersect, full -> chunked slabs, frontier ->
    # backtrack) is recorded in order.
    deadline_exceeded: bool = False
    degradations: List[str] = field(default_factory=list)
    # resident path (PR 8): one-time RIG upload accounting, device pair
    # pages shipped, and levels routed to the host intersect because the
    # frontier was below the padded-dispatch break-even (F < threshold)
    resident_uploads: int = 0
    resident_bytes: int = 0
    resident_upload_s: float = 0.0
    resident_pages: int = 0
    small_frontier_host_routed: int = 0
    # transfer ledger (PR 10): host<->device bytes moved by THIS
    # enumeration (uploads, slab ships, index vectors, pair/row readback),
    # measured as intersector-counter deltas around each dispatch so
    # breaker retries and degraded attempts are included.  The process-wide
    # per-site breakdown lives in repro.obs.ledger.
    h2d_bytes: int = 0
    d2h_bytes: int = 0


@dataclass
class MJoinResult:
    count: int
    tuples: Optional[np.ndarray]     # (k, n_query) int64, in *query-node* order
    stats: MJoinStats
    order: List[int]


class FrontierOverflow(RuntimeError):
    """Raised when a frontier level exceeds ``max_frontier`` rows; the
    driver falls back to the constant-space backtracking strategy."""


# ------------------------------------------------------------------ helpers
def _constraints(q, order: List[int]) -> List[List[Tuple[int, int, bool]]]:
    """constraints[i]: list of (prefix_position, edge_index, is_forward).

    is_forward=True  => edge (order[j] -> order[i]): row = rig.fwd[e][t_j]
    is_forward=False => edge (order[i] -> order[j]): row = rig.bwd[e][t_j]
    """
    pos = {qi: i for i, qi in enumerate(order)}
    cons: List[List[Tuple[int, int, bool]]] = [[] for _ in range(q.n)]
    for ei, e in enumerate(q.edges):
        ps, pd = pos[e.src], pos[e.dst]
        if ps < pd:
            cons[pd].append((ps, ei, True))
        else:
            cons[ps].append((pd, ei, False))
    return cons


def _to_query_order(assign: np.ndarray, order: List[int],
                    cand: List[np.ndarray]) -> np.ndarray:
    """Local-id rows in order-position layout -> global-id tuples in
    query-node order (vectorized over all rows per position)."""
    k = assign.shape[0]
    out = np.empty((k, len(order)), dtype=np.int64)
    for p, qi in enumerate(order):
        out[:, qi] = cand[qi][assign[:, p]]
    return out


_DEVICE = None
_DEVICE_FAILED = False


def device_intersector():
    """The jax/Pallas frontier executor, or None if jax is unavailable."""
    global _DEVICE, _DEVICE_FAILED
    if _DEVICE is None and not _DEVICE_FAILED:
        try:
            from ..jaxgm.frontier import DeviceIntersector
            _DEVICE = DeviceIntersector()
        except Exception as e:                      # pragma: no cover - env
            _DEVICE_FAILED = True
            warnings.warn(
                f"frontier-device unavailable ({type(e).__name__}: {e}); "
                f"falling back to the host frontier path", RuntimeWarning,
                stacklevel=3)
    return _DEVICE


def resident_intersector(rig: RIG, stats: Optional[MJoinStats] = None):
    """The RIG's device-resident executor, built (and uploaded) on first
    use and cached on ``rig.resident`` — one upload per RIG, shared by
    every enumeration over it.  Returns None if jax is unavailable.

    With ``stats``, upload accounting is recorded: ``resident_uploads``
    counts only *fresh* uploads (a cache hit contributes bytes but no
    upload), so engine counters reflect real transfers.
    """
    global _DEVICE_FAILED
    res = getattr(rig, "resident", None)
    if res is not None and getattr(res, "closed", False):
        res = rig.resident = None       # torn down (evicted): rebuild
    if res is None and not _DEVICE_FAILED:
        try:
            from ..jaxgm.frontier import ResidentIntersector
            res = ResidentIntersector.build(rig)
        except Exception as e:                      # pragma: no cover - env
            _DEVICE_FAILED = True
            warnings.warn(
                f"frontier-device-resident unavailable "
                f"({type(e).__name__}: {e}); falling back to the host "
                f"frontier path", RuntimeWarning, stacklevel=3)
            return None
        rig.resident = res
        if stats is not None:
            stats.resident_uploads += 1
            stats.resident_upload_s += res.upload_s
            stats.h2d_bytes += res.nbytes
    if res is not None and stats is not None:
        stats.resident_bytes = res.nbytes
    return res


class _XferDelta:
    """Record an intersector's cumulative h2d/d2h counter movement into
    ``stats`` around a dispatch (context manager; exception-safe so failed
    attempts still account the bytes they shipped)."""

    __slots__ = ("res", "stats", "_h", "_d")

    def __init__(self, res, stats: Optional[MJoinStats]):
        self.res, self.stats = res, stats

    def __enter__(self):
        self._h = getattr(self.res, "h2d_bytes", 0)
        self._d = getattr(self.res, "d2h_bytes", 0)
        return self

    def __exit__(self, *exc):
        if self.stats is not None and self.res is not None:
            self.stats.h2d_bytes += getattr(self.res, "h2d_bytes", 0) - self._h
            self.stats.d2h_bytes += getattr(self.res, "d2h_bytes", 0) - self._d
        return False


# ---------------------------------------------------------------- backtrack
def _backtrack_blocks(rig: RIG, order: List[int], cons, limit,
                      stats: MJoinStats, mat_cap: int, block: int = 1024,
                      budget=None) -> Iterator[Tuple[Optional[np.ndarray], int]]:
    """Depth-first enumeration as a lazy block generator.

    Yields ``(rows, visited)`` pairs: ``rows`` is an ``(k <= block, n)``
    int64 array of completed assignments (local ids, order-position layout;
    ``None`` once ``mat_cap`` assignments have been materialized) and
    ``visited`` the number of results visited since the previous yield
    (materialization may lag counting when ``mat_cap`` < limit).  The
    search state is suspended between yields, so a consumer that stops
    early never visits the tail.
    """
    n = rig.query.n
    sizes = [rig.cos_size(qi) for qi in order]
    all_ids = [np.arange(s, dtype=np.int64) for s in sizes]
    empty = np.empty(0, dtype=np.int64)

    t = np.full(n, -1, dtype=np.int64)       # local ids, order positions
    cand_lists: List[np.ndarray] = [empty] * n
    cursors = np.zeros(n, dtype=np.int64)
    count = 0

    buf = (np.empty((block, n), dtype=np.int64) if mat_cap > 0 else None)
    k = 0          # rows in buf
    visited = 0    # results since last yield
    n_mat = 0      # total rows materialized

    def candidates(i: int) -> np.ndarray:
        cs = cons[i]
        if not cs:
            return all_ids[i]
        j, ei, isf = cs[0]
        acc = (rig.fwd[ei] if isf else rig.bwd[ei])[t[j]]
        stats.intersections += 1
        if len(cs) > 1:
            acc = acc.copy()
            for (j, ei, isf) in cs[1:]:
                acc &= (rig.fwd[ei] if isf else rig.bwd[ei])[t[j]]
                stats.intersections += 1
                if not acc.any():
                    return empty
        elif not acc.any():
            return empty
        return bitset.to_indices(acc, sizes[i])

    # cooperative deadline: one clock read per _DEADLINE_STEPS loop
    # iterations, so a blown budget is noticed within a bounded slice of
    # work while the un-governed path pays only an int compare
    steps = 0
    i = 0
    cand_lists[0] = candidates(0)
    cursors[0] = 0
    while i >= 0:
        if limit is not None and count >= limit:
            stats.truncated = True
            break
        if budget is not None:
            steps += 1
            if steps >= _DEADLINE_STEPS:
                steps = 0
                if budget.expired():
                    stats.deadline_exceeded = True
                    stats.truncated = True
                    break
        lst = cand_lists[i]
        c = cursors[i]
        if c >= len(lst):
            i -= 1
            if i >= 0:
                cursors[i] += 1
            continue
        t[i] = lst[c]
        stats.expanded += 1
        if i == n - 1:
            count += 1
            visited += 1
            if buf is not None and n_mat < mat_cap:
                buf[k] = t
                k += 1
                n_mat += 1
            if visited >= block:
                yield (buf[:k].copy() if k else None), visited
                k = 0
                visited = 0
            cursors[i] += 1
            continue
        i += 1
        cand_lists[i] = candidates(i)
        cursors[i] = 0
    if visited:
        yield (buf[:k].copy() if k else None), visited


def _mjoin_backtrack(rig: RIG, order: List[int], cons, limit,
                     materialize: bool, max_tuples: int,
                     stats: MJoinStats, budget=None
                     ) -> Tuple[int, Optional[np.ndarray]]:
    """Returns ``(count, assign)`` — assign in *local* order-position
    layout (``None`` when not materializing); the caller converts to
    query-node order under the materialize phase."""
    mat_cap = max_tuples if materialize else 0
    blocks: List[np.ndarray] = []
    count = 0
    for blk, visited in _backtrack_blocks(rig, order, cons, limit, stats,
                                          mat_cap, budget=budget):
        if blk is not None:
            blocks.append(blk)
        count += visited
    assign = None
    if materialize:
        assign = (np.vstack(blocks) if blocks
                  else np.empty((0, rig.query.n), dtype=np.int64))
    return count, assign


# ----------------------------------------------------------------- frontier
def _slab_intersect(rig: RIG, cs, slab: np.ndarray,
                    intersector, stats: MJoinStats, breaker=None,
                    small_rows: int = 0):
    """Gather the K constraint rows for one frontier slab and AND-reduce.

    Returns ``(acc, counts)``: the packed candidate rows (f, W) plus, on
    the device path, the kernel's fused per-row popcounts (None on the
    host path — computed lazily only where needed).  ``cs`` is non-empty
    (K >= 1); each constraint contributes one gathered row per frontier
    entry.

    With a ``breaker``, the device dispatch is governed: transient
    failures retry inside :meth:`CircuitBreaker.call`, and a dispatch that
    still fails (or an open breaker, which refuses before touching the
    device) degrades this slab — and effectively the query — to the fused
    numpy path, recorded once as the ``host-intersect`` ladder step.
    Results are identical either way.

    ``small_rows`` is the sub-threshold host routing bound: a slab with
    fewer rows than it skips the device entirely (the kernel pads every
    dispatch to >= 128 rows, so tiny frontiers pay the full padded
    dispatch for almost no work — BENCH data puts the break-even around
    the padding floor).  Routed slabs are counted in
    ``stats.small_frontier_host_routed``.
    """
    stats.intersections += len(cs) * len(slab)
    if intersector is not None and small_rows and len(slab) < small_rows:
        stats.small_frontier_host_routed += 1
        intersector = None
    if intersector is not None:
        rows = np.stack([(rig.fwd[ei] if isf else rig.bwd[ei])[slab[:, j]]
                         for (j, ei, isf) in cs], axis=1)    # (f, K, W)
        t0 = time.perf_counter()
        try:
            with _XferDelta(intersector, stats):
                if breaker is not None:
                    acc, counts = breaker.call(lambda: intersector(rows))
                else:
                    acc, counts = intersector(rows)
        except (DeviceFailure, BreakerOpen):
            stats.device_s += time.perf_counter() - t0
            if "host-intersect" not in stats.degradations:
                stats.degradations.append("host-intersect")
            acc = np.bitwise_and.reduce(rows, axis=1)
            return acc, bitset.count_rows(acc)
        stats.device_s += time.perf_counter() - t0
        stats.device_calls += 1
        return acc, counts
    j, ei, isf = cs[0]
    acc = (rig.fwd[ei] if isf else rig.bwd[ei])[slab[:, j]]  # gather = copy
    for (j, ei, isf) in cs[1:]:
        acc &= (rig.fwd[ei] if isf else rig.bwd[ei])[slab[:, j]]
    return acc, None


def _frontier_events(rig: RIG, order: List[int], cons, limit,
                     stats: MJoinStats, device: bool, max_frontier: int,
                     mat_cap: int, external: bool = False,
                     slab_rows: Optional[int] = None, budget=None,
                     breaker=None, small_rows: int = 0):
    """Level-synchronous frontier enumeration as an event generator.

    Yields two event kinds:

    * ``("need", rows)`` — only when ``external``: a pending ``(F, K, W)``
      constraint gather; the driver must resume the generator with
      ``send((acc, counts))`` (the AND-reduced rows and their per-row
      popcounts).  This is the hook the cross-query batcher uses to fuse
      several queries' gathers into one device dispatch.
    * ``("out", rows, visited)`` — a block of completed assignments at the
      last level: ``rows`` is ``(k, n)`` int64 in order-position layout
      (``None`` when the materialization budget ``mat_cap`` is exhausted
      or zero), ``visited`` the number of results this slab contributed
      after limit clipping.  Last-level slabs are processed lazily, one
      per event, so a consumer that stops early reads no further slabs.

    Raises :class:`FrontierOverflow` — always before the first ``"out"``
    event, since overflow can only occur while building a non-last level —
    when a level exceeds ``max_frontier`` rows.
    """
    n = rig.query.n
    sizes = [rig.cos_size(qi) for qi in order]
    intersector = None
    if device and not external:
        intersector = device_intersector()
        if intersector is None:
            stats.method = "frontier"                # jax missing: host path

    n_mat = 0
    count = 0
    frontier = np.arange(sizes[0], dtype=np.int64)[:, None]   # (F, 1)
    stats.frontier_peak = len(frontier)
    stats.frontier_levels.append(len(frontier))
    stats.expanded += len(frontier)

    if n == 1:
        total = sizes[0]
        if limit is not None and total >= limit:
            total = limit
            stats.truncated = True
        blk = frontier[:min(total, mat_cap)] if mat_cap > 0 else None
        yield ("out", blk, total)
        return

    for i in range(1, n):
        last = i == n - 1
        n_i = sizes[i]
        cs = cons[i]
        new_parts: List[np.ndarray] = []
        new_rows = 0
        # slab rows bounded by both the row count and the dense unpack
        # width, so the per-slab transient stays ~32 MB even for huge
        # candidate sets
        srows = slab_rows or max(1, min(_FRONTIER_SLAB,
                                        (1 << 25) // max(n_i, 1)))
        if budget is not None:
            # budget-tightened slab height — the "smaller chunks"
            # degradation step.  The device intersector pads every
            # dispatch (F -> pow2 >= 128, K -> pow2, W -> 128-lane
            # multiples), so when a device dispatch is possible the cap
            # must bound the *padded* allocation, not the logical gather
            # transient — on ragged slabs padding can exceed it by >2x.
            if intersector is not None and budget.max_slab_bytes is not None:
                cap = padded_rows_cap(budget.max_slab_bytes,
                                      max(1, len(cs)), bitset.n_words(n_i),
                                      srows)
                if cap == 0:
                    # even the minimal 128-row padded dispatch blows the
                    # cap: this query degrades to the host intersect
                    intersector = None
                    cap = budget.slab_cap_rows(
                        max(1, len(cs)) * bitset.n_words(n_i) * 8)
                    if "host-intersect" not in stats.degradations:
                        stats.degradations.append("host-intersect")
            else:
                cap = budget.slab_cap_rows(
                    max(1, len(cs)) * bitset.n_words(n_i) * 8)
            if cap is not None and cap < srows:
                srows = cap
                if "chunked-slabs" not in stats.degradations:
                    stats.degradations.append("chunked-slabs")
        for lo in range(0, len(frontier), srows):
            if budget is not None and budget.expired():
                stats.deadline_exceeded = True
                stats.truncated = True
                return
            slab = frontier[lo:lo + srows]
            counts = None
            if cs:
                if external:
                    rows = np.stack(
                        [(rig.fwd[ei] if isf else rig.bwd[ei])[slab[:, j]]
                         for (j, ei, isf) in cs], axis=1)     # (f, K, W)
                    stats.intersections += len(cs) * len(slab)
                    acc, counts = yield ("need", rows)
                    stats.device_calls += 1
                else:
                    acc, counts = _slab_intersect(rig, cs, slab,
                                                  intersector, stats,
                                                  breaker=breaker,
                                                  small_rows=small_rows)
                bits = None
            else:                      # disconnected pattern: cartesian
                acc = None
                bits = np.ones((len(slab), n_i), dtype=bool)
            if last:
                if counts is None:
                    counts = (bitset.count_rows(acc) if acc is not None
                              else np.full(len(slab), n_i, dtype=np.int64))
                slab_total = int(counts.sum())
                want = min(mat_cap - n_mat, slab_total) if mat_cap > 0 else 0
                blk = None
                if want > 0:
                    if bits is None:
                        bits = bitset.unpack(acc, n_i)
                    rid, cid = np.nonzero(bits)
                    blk = np.concatenate(
                        [slab[rid[:want]],
                         cid[:want, None].astype(np.int64)], axis=1)
                    n_mat += len(blk)
                count += slab_total
                stats.expanded += slab_total
                visited = slab_total
                hit_limit = False
                if limit is not None and count >= limit:
                    stats.expanded -= count - limit
                    visited = slab_total - (count - limit)
                    count = limit
                    stats.truncated = True
                    hit_limit = True
                yield ("out", blk, visited)
                if hit_limit:
                    return
            else:
                if bits is None:
                    bits = bitset.unpack(acc, n_i)
                rid, cid = np.nonzero(bits)
                if len(rid):
                    new_parts.append(np.concatenate(
                        [slab[rid], cid[:, None].astype(np.int64)],
                        axis=1))
                    new_rows += len(rid)
                    # enforce the bound *while* accumulating — before
                    # the oversized level is ever materialized whole
                    if new_rows > max_frontier:
                        raise FrontierOverflow(
                            f"frontier level {i} exceeds "
                            f"max_frontier={max_frontier} rows")
        if last:
            return
        frontier = (np.vstack(new_parts) if new_parts
                    else np.empty((0, i + 1), dtype=np.int64))
        stats.frontier_peak = max(stats.frontier_peak, len(frontier))
        stats.frontier_levels.append(len(frontier))
        stats.expanded += len(frontier)
        if len(frontier) == 0:
            return


# ------------------------------------------------------ resident frontier
def _resident_frontier_events(rig: RIG, order: List[int], cons, limit,
                              stats: MJoinStats, max_frontier: int,
                              mat_cap: int, slab_rows: Optional[int] = None,
                              budget=None, breaker=None,
                              small_rows: int = 0):
    """Paged device-resident frontier enumeration (event generator).

    Yields the same ``("out", rows, visited)`` events as
    :func:`_frontier_events` but executes each level against the
    device-resident RIG (:func:`resident_intersector`): the host ships
    only ``(F, K)`` int32 constraint-row indices per slab, and both the
    gather + AND + popcount and the set-bit expansion run on device —
    result pages come back as compact (row, column) pairs.

    A level wider than ``max_frontier`` is *paged*, not abandoned: full
    pages of child rows are recursed depth-first in order (page p's
    completions all precede page p+1's by construction), which preserves
    the exact lexicographic order of the level-synchronous path while
    bounding live frontier memory to ~``max_frontier`` rows per level.
    This generator therefore never raises :class:`FrontierOverflow`.

    Degradation ladder: a failed device dispatch (or open breaker) — at
    either the intersect or the expand step — degrades the remaining
    enumeration to the host gather + numpy intersect (``host-intersect``),
    and slabs below ``small_rows`` are host-routed pre-emptively (the
    padded dispatch floor makes them device-unprofitable).  Without jax
    the whole enumeration delegates to the host frontier path.
    """
    n = rig.query.n
    sizes = [rig.cos_size(qi) for qi in order]
    res = resident_intersector(rig, stats)
    if res is None:
        stats.method = "frontier"                    # jax missing: host path
        yield from _frontier_events(rig, order, cons, limit, stats,
                                    device=False, max_frontier=max_frontier,
                                    mat_cap=mat_cap, slab_rows=slab_rows,
                                    budget=budget, breaker=breaker)
        return

    page_rows = max(1, max_frontier)
    state = {"count": 0, "n_mat": 0, "done": False, "dev_ok": True}
    level_rows = [0] * n

    root = np.arange(sizes[0], dtype=np.int64)[:, None]       # (F, 1)
    if n == 1:
        stats.frontier_peak = len(root)
        stats.frontier_levels.append(len(root))
        stats.expanded += len(root)
        total = sizes[0]
        if limit is not None and total >= limit:
            total = limit
            stats.truncated = True
        blk = root[:min(total, mat_cap)] if mat_cap > 0 else None
        yield ("out", blk, total)
        return

    def _host_acc(cs, slab):
        j, ei, isf = cs[0]
        acc = (rig.fwd[ei] if isf else rig.bwd[ei])[slab[:, j]]
        for (j, ei, isf) in cs[1:]:
            acc &= (rig.fwd[ei] if isf else rig.bwd[ei])[slab[:, j]]
        return acc

    def _degrade():
        state["dev_ok"] = False
        if "host-intersect" not in stats.degradations:
            stats.degradations.append("host-intersect")

    def intersect_slab(cs, slab, w64):
        """Dispatch one slab: ``(handle, acc_host, counts)`` — exactly one
        of handle/acc_host is set; counts only on the device path."""
        stats.intersections += len(cs) * len(slab)
        if state["dev_ok"] and not (small_rows and len(slab) < small_rows):
            t0 = time.perf_counter()
            try:
                with _XferDelta(res, stats):
                    if breaker is not None:
                        handle, counts = breaker.call(
                            lambda: res.intersect(cs, slab, w64))
                    else:
                        handle, counts = res.intersect(cs, slab, w64)
            except (DeviceFailure, BreakerOpen):
                stats.device_s += time.perf_counter() - t0
                _degrade()
            else:
                stats.device_s += time.perf_counter() - t0
                stats.device_calls += 1
                return handle, None, counts
        elif state["dev_ok"]:
            stats.small_frontier_host_routed += 1
        return None, _host_acc(cs, slab), None

    def slab_pairs(cs, slab, handle, acc, n_i, want):
        """First ``want`` set-bit (row, column) pairs of one dispatched
        slab, lexicographic; device pair page when possible."""
        if handle is not None:
            t0 = time.perf_counter()
            try:
                with _XferDelta(res, stats):
                    if breaker is not None:
                        rid, cid = breaker.call(
                            lambda: res.expand(handle, n_i, want))
                    else:
                        rid, cid = res.expand(handle, n_i, want)
            except (DeviceFailure, BreakerOpen):
                stats.device_s += time.perf_counter() - t0
                _degrade()
                acc = _host_acc(cs, slab)
            else:
                stats.device_s += time.perf_counter() - t0
                stats.resident_pages += 1
                return rid, cid
        bits = bitset.unpack(acc, n_i)
        rid, cid = np.nonzero(bits)
        return rid[:want], cid[:want]

    def expand(frontier, i):
        """Extend an ``(F, i)`` prefix page at level ``i`` (recursive)."""
        last = i == n - 1
        n_i = sizes[i]
        cs = cons[i]
        w64 = bitset.n_words(n_i)
        srows = slab_rows or max(1, min(_FRONTIER_SLAB,
                                        (1 << 25) // max(n_i, 1)))
        if budget is not None and cs:
            cap = None
            if state["dev_ok"] and budget.max_slab_bytes is not None:
                # charge the *padded* dispatch transient (index upload +
                # AND output), same geometry the executor allocates
                cap = res.rows_cap(budget.max_slab_bytes, len(cs), srows)
                if cap == 0:
                    _degrade()
            if not state["dev_ok"] or budget.max_slab_bytes is None:
                cap = budget.slab_cap_rows(
                    len(cs) * bitset.n_words(n_i) * 8)
            if cap is not None and cap < srows:
                srows = cap
                if "chunked-slabs" not in stats.degradations:
                    stats.degradations.append("chunked-slabs")
        pend: List[np.ndarray] = []
        pend_rows = 0
        for lo in range(0, len(frontier), srows):
            if budget is not None and budget.expired():
                stats.deadline_exceeded = True
                stats.truncated = True
                state["done"] = True
                return
            slab = frontier[lo:lo + srows]
            if cs:
                handle, acc, counts = intersect_slab(cs, slab, w64)
            else:                          # disconnected pattern: cartesian
                handle = acc = counts = None
            if last:
                if counts is None:
                    counts = (bitset.count_rows(acc) if cs
                              else np.full(len(slab), n_i, dtype=np.int64))
                slab_total = int(counts.sum())
                want = (min(mat_cap - state["n_mat"], slab_total)
                        if mat_cap > 0 else 0)
                blk = None
                if want > 0:
                    if cs:
                        rid, cid = slab_pairs(cs, slab, handle, acc,
                                              n_i, want)
                    else:
                        rid = np.repeat(np.arange(len(slab)), n_i)[:want]
                        cid = np.tile(np.arange(n_i), len(slab))[:want]
                    blk = np.concatenate(
                        [slab[rid], cid[:, None].astype(np.int64)], axis=1)
                    state["n_mat"] += len(blk)
                state["count"] += slab_total
                stats.expanded += slab_total
                visited = slab_total
                if limit is not None and state["count"] >= limit:
                    over = state["count"] - limit
                    stats.expanded -= over
                    visited = slab_total - over
                    state["count"] = limit
                    stats.truncated = True
                    state["done"] = True
                yield ("out", blk, visited)
                if state["done"]:
                    return
                continue
            # intermediate level: child rows, paged
            if cs:
                if handle is not None:
                    total = int(counts.sum())
                    rid, cid = slab_pairs(cs, slab, handle, acc, n_i, total)
                else:
                    bits = bitset.unpack(acc, n_i)
                    rid, cid = np.nonzero(bits)
            else:
                rid = np.repeat(np.arange(len(slab)), n_i)
                cid = np.tile(np.arange(n_i), len(slab))
            if len(rid):
                child = np.concatenate(
                    [slab[rid], cid[:, None].astype(np.int64)], axis=1)
                level_rows[i] += len(child)
                stats.expanded += len(child)
                pend.append(child)
                pend_rows += len(child)
                stats.frontier_peak = max(stats.frontier_peak, pend_rows)
            # flush full pages in order: page p's completions all precede
            # page p+1's, so recursion preserves lexicographic order
            while pend_rows >= page_rows:
                cat = pend[0] if len(pend) == 1 else np.vstack(pend)
                page, rest = cat[:page_rows], cat[page_rows:]
                pend = [rest] if len(rest) else []
                pend_rows = len(rest)
                yield from expand(page, i + 1)
                if state["done"]:
                    return
        if pend_rows:
            cat = pend[0] if len(pend) == 1 else np.vstack(pend)
            yield from expand(cat, i + 1)

    try:
        for lo in range(0, len(root), page_rows):
            page = root[lo:lo + page_rows]
            level_rows[0] += len(page)
            stats.expanded += len(page)
            stats.frontier_peak = max(stats.frontier_peak, len(page))
            yield from expand(page, 1)
            if state["done"]:
                return
    finally:
        lvls = level_rows[:n - 1]
        while len(lvls) > 1 and lvls[-1] == 0:
            lvls.pop()
        stats.frontier_levels = lvls


def _mjoin_frontier(rig: RIG, order: List[int], cons, limit,
                    materialize: bool, max_tuples: int, stats: MJoinStats,
                    device: bool, max_frontier: int, budget=None,
                    breaker=None, small_rows: int = 0
                    ) -> Tuple[int, Optional[np.ndarray]]:
    mat_cap = 0
    if materialize:
        mat_cap = max_tuples if limit is None else min(max_tuples, limit)
    blocks: List[np.ndarray] = []
    count = 0
    for _, blk, visited in _frontier_events(rig, order, cons, limit, stats,
                                            device, max_frontier, mat_cap,
                                            budget=budget, breaker=breaker,
                                            small_rows=small_rows):
        if blk is not None and len(blk):
            blocks.append(blk)
        count += visited
    assign = None
    if materialize:
        assign = (np.vstack(blocks) if blocks
                  else np.empty((0, rig.query.n), dtype=np.int64))
    return count, assign


def _mjoin_resident(rig: RIG, order: List[int], cons, limit,
                    materialize: bool, max_tuples: int, stats: MJoinStats,
                    max_frontier: int, budget=None, breaker=None,
                    small_rows: int = 0) -> Tuple[int, Optional[np.ndarray]]:
    mat_cap = 0
    if materialize:
        mat_cap = max_tuples if limit is None else min(max_tuples, limit)
    blocks: List[np.ndarray] = []
    count = 0
    for _, blk, visited in _resident_frontier_events(
            rig, order, cons, limit, stats, max_frontier, mat_cap,
            budget=budget, breaker=breaker, small_rows=small_rows):
        if blk is not None and len(blk):
            blocks.append(blk)
        count += visited
    assign = None
    if materialize:
        assign = (np.vstack(blocks) if blocks
                  else np.empty((0, rig.query.n), dtype=np.int64))
    return count, assign


# ---------------------------------------------------------------------- API
def mjoin(rig: RIG, order: List[int], limit: Optional[int] = DEFAULT_LIMIT,
          materialize: bool = True, max_tuples: int = 1_000_000,
          method: str = "backtrack",
          max_frontier: int = 1 << 25, trace=NULL_TRACER,
          budget=None, breaker=None,
          small_frontier_rows: int = 0) -> MJoinResult:
    """Enumerate (or count) the occurrences encoded by ``rig``.

    ``limit`` bounds the number of results visited (None = exhaustive);
    ``max_tuples`` bounds materialization only (counting continues);
    ``method`` picks the enumeration strategy (see module docstring) —
    a frontier level wider than ``max_frontier`` rows falls back to
    ``backtrack`` to keep memory bounded, except under
    ``frontier-device-resident`` where such a level is *paged* through
    in ``max_frontier``-row pages instead (no fallback, same order).
    ``small_frontier_rows`` routes device slabs below that many rows
    through the host intersect (the padded dispatch floor makes tiny
    slabs device-unprofitable); 0 disables the routing.  ``trace``
    records the ``enumerate`` / ``materialize`` phases as spans when
    profiling.

    ``budget`` (an armed :class:`repro.robust.Budget`) adds cooperative
    governance: its deadline is checked at slab/block boundaries (a blown
    deadline yields the partial prefix with ``stats.deadline_exceeded``),
    its ``max_frontier_rows``/``max_slab_bytes`` tighten the frontier
    bounds (degrading to smaller slabs or backtracking, recorded in
    ``stats.degradations``).  ``breaker`` governs device dispatches on the
    ``frontier-device`` path (retry, then host fallback).
    """
    if method not in ENUM_METHODS:
        raise ValueError(f"unknown enum method: {method!r} "
                         f"(expected one of {ENUM_METHODS})")
    if budget is not None:
        max_frontier = budget.frontier_cap(max_frontier)
    q = rig.query
    n = q.n
    t0 = time.perf_counter()
    stats = MJoinStats(method=method)

    if rig.is_empty() or (limit is not None and limit <= 0):
        stats.truncated = limit is not None and limit <= 0 \
            and not rig.is_empty()
        stats.enumerate_s = time.perf_counter() - t0
        trace.span("enumerate").__enter__().set(
            method=method, results=0, empty_rig=rig.is_empty(),
            truncated=stats.truncated).__exit__(None, None, None)
        trace.span("materialize").__enter__().set(
            rows=0).__exit__(None, None, None)
        return MJoinResult(0, np.empty((0, n), dtype=np.int64) if materialize
                           else None, stats, order)

    cons = _constraints(q, order)
    with trace.span("enumerate") as esp:
        if method == "backtrack":
            count, assign = _mjoin_backtrack(rig, order, cons, limit,
                                             materialize, max_tuples, stats,
                                             budget=budget)
        else:
            try:
                if method == "frontier-device-resident":
                    # paged: never raises FrontierOverflow itself, but the
                    # no-jax delegation to the host frontier path can
                    count, assign = _mjoin_resident(
                        rig, order, cons, limit, materialize, max_tuples,
                        stats, max_frontier=max_frontier, budget=budget,
                        breaker=breaker, small_rows=small_frontier_rows)
                else:
                    count, assign = _mjoin_frontier(
                        rig, order, cons, limit, materialize, max_tuples,
                        stats, device=(method == "frontier-device"),
                        max_frontier=max_frontier, budget=budget,
                        breaker=breaker, small_rows=small_frontier_rows)
            except FrontierOverflow:
                degr = stats.degradations + ["backtrack"]
                old = stats
                stats = MJoinStats(method="backtrack",   # strategy that ran
                                   degradations=degr,
                                   # bytes already moved before the overflow
                                   # stay on the query's record
                                   h2d_bytes=old.h2d_bytes,
                                   d2h_bytes=old.d2h_bytes,
                                   resident_uploads=old.resident_uploads,
                                   resident_bytes=old.resident_bytes,
                                   resident_upload_s=old.resident_upload_s)
                esp.set(overflow_fallback=True)
                count, assign = _mjoin_backtrack(rig, order, cons, limit,
                                                 materialize, max_tuples,
                                                 stats, budget=budget)
        if trace.enabled:
            esp.set(method=stats.method, results=count,
                    expanded=stats.expanded,
                    intersections=stats.intersections,
                    truncated=stats.truncated,
                    frontier_levels=list(stats.frontier_levels),
                    frontier_peak=stats.frontier_peak,
                    device_calls=stats.device_calls,
                    device_s=stats.device_s)

    tuples = None
    with trace.span("materialize") as msp:
        if materialize:
            t_m = time.perf_counter()
            tuples = _to_query_order(assign, order, rig.cand)
            stats.materialize_s = time.perf_counter() - t_m
        if trace.enabled:
            msp.set(rows=0 if tuples is None else len(tuples),
                    materialized=materialize)

    stats.results = count
    stats.enumerate_s = (time.perf_counter() - t0) - stats.materialize_s
    return MJoinResult(count=count, tuples=tuples, stats=stats, order=order)


# ----------------------------------------------------------------- streaming
class MJoinStream:
    """Chunked lazy enumeration over one RIG (created by :func:`iter_tuples`).

    Iterating yields ``(chunk_size, n_query)`` int64 arrays — global node
    ids in query-node order, byte-identical to the corresponding slice of
    one-shot ``mjoin(...).tuples`` — in the same lexicographic order; every
    chunk except the last has exactly ``chunk_size`` rows.  Enumeration
    state advances only as chunks are consumed (``limit`` pushdown):
    stopping early leaves the tail unvisited, which is observable in the
    live ``stats`` counters.  The stream is single-pass; ``count`` tracks
    tuples yielded so far and ``stats.truncated`` is set the moment the
    limit is hit (the final chunk is cut at exactly ``limit`` rows).
    """

    def __init__(self, rig: RIG, order: List[int], *, chunk_size: int = 1024,
                 limit: Optional[int] = DEFAULT_LIMIT,
                 method: str = "backtrack", max_frontier: int = 1 << 25,
                 slab_rows: Optional[int] = None, budget=None, breaker=None,
                 small_frontier_rows: int = 0):
        if method not in ENUM_METHODS:
            raise ValueError(f"unknown enum method: {method!r} "
                             f"(expected one of {ENUM_METHODS})")
        if chunk_size <= 0:
            raise ValueError(f"chunk_size must be positive, got {chunk_size}")
        self.rig = rig
        self.order = order
        self.chunk_size = chunk_size
        self.limit = limit
        self.method = method
        self.max_frontier = (max_frontier if budget is None
                             else budget.frontier_cap(max_frontier))
        self.slab_rows = slab_rows
        self.budget = budget
        self.breaker = breaker
        self.small_frontier_rows = small_frontier_rows
        self.stats = MJoinStats(method=method)
        self.count = 0               # tuples yielded so far
        self._it = self._chunks()

    # single-pass iterable
    def __iter__(self) -> "MJoinStream":
        return self

    def __next__(self) -> np.ndarray:
        return next(self._it)

    def close(self) -> None:
        """Stop enumeration early (drops any suspended search state)."""
        self._it.close()

    # ------------------------------------------------------------ internals
    def _blocks(self):
        """Local-layout assignment blocks, with the frontier -> backtrack
        overflow fallback (safe: overflow precedes the first output)."""
        stats = self.stats
        cons = _constraints(self.rig.query, self.order)
        if self.method != "backtrack":
            mat_cap = self.limit if self.limit is not None else _INF_CAP
            if self.method == "frontier-device-resident":
                gen = _resident_frontier_events(
                    self.rig, self.order, cons, self.limit, stats,
                    max_frontier=self.max_frontier, mat_cap=mat_cap,
                    slab_rows=self.slab_rows, budget=self.budget,
                    breaker=self.breaker,
                    small_rows=self.small_frontier_rows)
            else:
                gen = _frontier_events(
                    self.rig, self.order, cons, self.limit, stats,
                    device=(self.method == "frontier-device"),
                    max_frontier=self.max_frontier, mat_cap=mat_cap,
                    slab_rows=self.slab_rows, budget=self.budget,
                    breaker=self.breaker,
                    small_rows=self.small_frontier_rows)
            try:
                try:
                    first = next(gen)
                except StopIteration:
                    return
                except FrontierOverflow:
                    stats.method = "backtrack"
                    stats.expanded = 0
                    stats.intersections = 0
                    stats.frontier_peak = 0
                    stats.device_calls = 0
                    stats.frontier_levels = []
                    stats.device_s = 0.0
                    if "backtrack" not in stats.degradations:
                        stats.degradations.append("backtrack")
                else:
                    yield first[1]
                    for ev in gen:
                        yield ev[1]
                    return
            finally:
                gen.close()
        for blk, _ in _backtrack_blocks(self.rig, self.order, cons,
                                        self.limit, stats, mat_cap=_INF_CAP,
                                        block=self.chunk_size,
                                        budget=self.budget):
            yield blk

    def _chunks(self):
        # t0 = start of the currently-unaccounted work interval; None while
        # suspended at a yield (that interval is already accounted), so the
        # finally clause never re-counts it — nor the consumer's own time
        # between receiving a chunk and closing the stream.
        stats = self.stats
        t0: Optional[float] = time.perf_counter()
        try:
            if self.rig.is_empty():
                return
            if self.limit is not None and self.limit <= 0:
                stats.truncated = True
                return
            pend: List[np.ndarray] = []
            pend_rows = 0
            for blk in self._blocks():
                if blk is None or not len(blk):
                    continue
                pend.append(blk)
                pend_rows += len(blk)
                while pend_rows >= self.chunk_size:
                    cat = pend[0] if len(pend) == 1 else np.vstack(pend)
                    out, rest = (cat[:self.chunk_size],
                                 cat[self.chunk_size:])
                    pend = [rest] if len(rest) else []
                    pend_rows = len(rest)
                    self.count += len(out)
                    stats.results = self.count
                    stats.enumerate_s += time.perf_counter() - t0
                    t0 = None
                    yield _to_query_order(out, self.order, self.rig.cand)
                    t0 = time.perf_counter()
            if pend_rows:
                cat = pend[0] if len(pend) == 1 else np.vstack(pend)
                self.count += len(cat)
                stats.results = self.count
                stats.enumerate_s += time.perf_counter() - t0
                t0 = None
                yield _to_query_order(cat, self.order, self.rig.cand)
                t0 = time.perf_counter()
            if (self.budget is not None and stats.deadline_exceeded
                    and self.budget.raise_on_error):
                raise DeadlineExceeded(
                    f"deadline exceeded after {self.count} streamed tuple(s)")
        finally:
            stats.results = self.count
            if t0 is not None:
                stats.enumerate_s += time.perf_counter() - t0


def iter_tuples(rig: RIG, order: List[int], *, chunk_size: int = 1024,
                limit: Optional[int] = DEFAULT_LIMIT,
                method: str = "backtrack", max_frontier: int = 1 << 25,
                slab_rows: Optional[int] = None, budget=None,
                breaker=None, small_frontier_rows: int = 0) -> MJoinStream:
    """Streaming counterpart of :func:`mjoin`: a lazy, chunked enumerator.

    ``np.vstack(list(iter_tuples(rig, order, chunk_size=k)))`` equals
    ``mjoin(rig, order, materialize=True).tuples`` for every ``k`` and
    every ``method``; chunks arrive in lexicographic order and enumeration
    work is done on demand (see :class:`MJoinStream`).  ``slab_rows``
    overrides the frontier gather slab height (testing / tuning hook).
    ``budget`` / ``breaker`` add cooperative governance as in :func:`mjoin`;
    a blown deadline ends the stream after the partial prefix (raising
    :class:`DeadlineExceeded` instead when ``budget.raise_on_error``).
    """
    return MJoinStream(rig, order, chunk_size=chunk_size, limit=limit,
                       method=method, max_frontier=max_frontier,
                       slab_rows=slab_rows, budget=budget, breaker=breaker,
                       small_frontier_rows=small_frontier_rows)


# -------------------------------------------------------- cross-query batch
def stack_slabs(blocks: Sequence[np.ndarray]
                ) -> Tuple[np.ndarray, List[Tuple[int, int, int, int]]]:
    """Pad + stack per-query ``(F_i, K_i, W_i)`` uint64 constraint slabs
    into one ``(ΣF, maxK, maxW)`` block for a single fused dispatch.

    Padding is AND-exact: extra K rows are all-ones (the AND identity) and
    real rows are zero-extended beyond their own W words, so the fused
    AND-reduce + popcount of the big block restricted to each span equals
    the per-query result.  Returns ``(big, spans)`` with spans of
    ``(row_offset, F_i, K_i, W_i)``.
    """
    f_tot = sum(b.shape[0] for b in blocks)
    k_max = max(b.shape[1] for b in blocks)
    w_max = max(b.shape[2] for b in blocks)
    big = np.full((f_tot, k_max, w_max), np.uint64(0xFFFFFFFFFFFFFFFF),
                  dtype=np.uint64)
    spans: List[Tuple[int, int, int, int]] = []
    off = 0
    for b in blocks:
        f, k, w = b.shape
        big[off:off + f, :k, :w] = b
        big[off:off + f, :k, w:] = 0
        spans.append((off, f, k, w))
        off += f
    return big, spans


def _host_intersect_block(big: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Numpy AND-reduce + per-row popcount of a stacked ``(F, K, W)`` slab
    (the host stand-in for the ``intersect`` kernel on the batched path)."""
    acc = np.bitwise_and.reduce(big, axis=1)
    return acc, bitset.count_rows(acc)


class _BatchJob:
    __slots__ = ("gen", "stats", "count", "reply", "active_s")

    def __init__(self, gen, stats):
        self.gen = gen
        self.stats = stats
        self.count = 0
        self.reply = None
        self.active_s = 0.0      # this job's own share of the batch time


def mjoin_batched(jobs: Sequence[Tuple[RIG, List[int], Optional[int]]],
                  *, intersector=None, max_frontier: int = 1 << 25,
                  budgets: Optional[Sequence] = None, breaker=None
                  ) -> Tuple[List[MJoinResult], int]:
    """Count several queries' occurrences with *cross-query micro-batched*
    frontier dispatches.

    ``jobs`` is a sequence of ``(rig, order, limit)``.  Every job runs the
    level-synchronous frontier enumeration as a coroutine; each scheduler
    round collects one pending ``(F, K, W)`` constraint gather per active
    job, pads and stacks them (:func:`stack_slabs`) into a single
    ``(ΣF, K, W)`` slab, resolves it with **one** call to ``intersector``
    (the ``intersect`` Pallas kernel wrapper; numpy AND+popcount when
    ``None``), and scatters the per-job results back.  Per-job counts,
    truncation, and stats semantics match ``mjoin(..., materialize=False)``
    exactly; a job whose frontier overflows ``max_frontier`` falls back to
    backtracking on its own, without stalling the batch.

    ``budgets`` (parallel to ``jobs``, entries may be None) adds per-job
    governance: each armed budget's deadline/frontier caps apply to that
    job only — a blown deadline completes the job with its partial count
    (``stats.deadline_exceeded``) while the rest of the batch continues.
    ``breaker`` governs the fused dispatch; when a dispatch fails for good
    (or the breaker is open) the whole batch degrades to the numpy
    intersect for the remaining rounds, recorded per job as the
    ``host-intersect`` ladder step.

    Returns ``(results, dispatches)`` — dispatches is the number of fused
    slab calls actually issued (the quantity micro-batching minimizes).
    """
    method = "frontier-device" if intersector is not None else "frontier"
    results: List[Optional[MJoinResult]] = [None] * len(jobs)
    active = {}
    dispatches = 0

    def _budget(idx: int):
        return budgets[idx] if budgets is not None else None

    for idx, (rig, order, limit) in enumerate(jobs):
        stats = MJoinStats(method=method)
        if rig.is_empty() or (limit is not None and limit <= 0):
            stats.truncated = limit is not None and limit <= 0 \
                and not rig.is_empty()
            results[idx] = MJoinResult(0, None, stats, order)
            continue
        b = _budget(idx)
        mf = max_frontier if b is None else b.frontier_cap(max_frontier)
        cons = _constraints(rig.query, order)
        gen = _frontier_events(rig, order, cons, limit, stats, device=False,
                               max_frontier=mf, mat_cap=0,
                               external=True, budget=b)
        active[idx] = _BatchJob(gen, stats)

    while active:
        requests = {}
        for idx, job in list(active.items()):
            rig, order, limit = jobs[idx]
            t0 = time.perf_counter()
            try:
                while True:
                    ev = job.gen.send(job.reply)
                    job.reply = None
                    if ev[0] == "need":
                        requests[idx] = ev[1]
                        break
                    job.count += ev[2]
                job.active_s += time.perf_counter() - t0
            except StopIteration:
                job.stats.results = job.count
                job.stats.enumerate_s = (job.active_s
                                         + time.perf_counter() - t0)
                results[idx] = MJoinResult(job.count, None, job.stats, order)
                del active[idx]
            except FrontierOverflow:
                degr = job.stats.degradations + ["backtrack"]
                stats = MJoinStats(method="backtrack", degradations=degr,
                                   h2d_bytes=job.stats.h2d_bytes,
                                   d2h_bytes=job.stats.d2h_bytes)
                cons = _constraints(rig.query, order)
                count, _ = _mjoin_backtrack(rig, order, cons, limit,
                                            materialize=False, max_tuples=0,
                                            stats=stats, budget=_budget(idx))
                stats.results = count
                stats.enumerate_s = (job.active_s
                                     + time.perf_counter() - t0)
                results[idx] = MJoinResult(count, None, stats, order)
                del active[idx]
        if requests:
            idxs = list(requests)
            big, spans = stack_slabs([requests[i] for i in idxs])
            t0 = time.perf_counter()
            isect0 = intersector            # pre-degrade reference
            h2d0 = getattr(isect0, "h2d_bytes", 0)
            d2h0 = getattr(isect0, "d2h_bytes", 0)
            if intersector is not None:
                try:
                    if breaker is not None:
                        acc, counts = breaker.call(
                            lambda: intersector(big))
                    else:
                        acc, counts = intersector(big)
                except (DeviceFailure, BreakerOpen):
                    # degrade the whole batch for its remaining rounds:
                    # results are identical, just computed on the host
                    intersector = None
                    for i in idxs:
                        d = active[i].stats.degradations
                        if "host-intersect" not in d:
                            d.append("host-intersect")
                    acc, counts = _host_intersect_block(big)
            else:
                acc, counts = _host_intersect_block(big)
            share = (time.perf_counter() - t0) / len(idxs)
            # the ledger holds the exact fused-dispatch bytes; per-job stats
            # get an equal share (the padded fused slab is not separable
            # per job), mirroring the device_s share above
            h2d_share = (getattr(isect0, "h2d_bytes", 0)
                         - h2d0) // len(idxs)
            d2h_share = (getattr(isect0, "d2h_bytes", 0)
                         - d2h0) // len(idxs)
            dispatches += 1
            for i, (off, f, k, w) in zip(idxs, spans):
                active[i].active_s += share
                active[i].stats.device_s += share
                active[i].stats.h2d_bytes += h2d_share
                active[i].stats.d2h_bytes += d2h_share
                active[i].reply = (np.ascontiguousarray(acc[off:off + f, :w]),
                                   counts[off:off + f])
    return results, dispatches  # type: ignore[return-value]
