"""Frontier-vectorized MJoin (TPU adaptation of Alg. 5).

``jax.lax`` control flow cannot express unbounded recursion, so the
backtracking enumeration becomes a *level-synchronous frontier expansion*:
a fixed-capacity table of partial assignments is extended one query node at
a time (following the search order), where each extension is the same
multiway packed-bitset intersection as the paper's — ``cos(q_i)`` AND one
RIG adjacency row per bound neighbour — realized as flat gathers over the
stacked packed matrices plus word-wise ANDs (the ``intersect`` kernel's
semantics).  Intermediate results remain intersections (never joins), so
the "no exploding intermediates" property carries over; a capacity overflow
is *detected and reported* rather than silently truncated.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..kernels import packed
from .device_graph import DeviceGraph, stacked_matrices
from .encoding import PAD, QueryTensor


class MJoinCount(NamedTuple):
    count: jax.Array          # int32 — exact iff not overflowed
    overflowed: jax.Array     # bool
    frontier: jax.Array       # (capacity, max_q) int32 — last-level partials
    alive: jax.Array          # (capacity,) bool


def _inverse_order(order: jax.Array, max_q: int) -> jax.Array:
    # PAD entries clip onto index 0 — use a min-scatter so duplicate writes
    # from padding cannot clobber a real node's position.
    inv = jnp.full(max_q, max_q + 1, jnp.int32)     # unreachable position
    pos = jnp.arange(max_q, dtype=jnp.int32)
    safe = jnp.clip(order, 0, max_q - 1)
    updates = jnp.where(order >= 0, pos, max_q + 1)
    return inv.at[safe].min(updates)


@partial(jax.jit, static_argnames=("capacity", "materialize"))
def mjoin_count(dg: DeviceGraph, qt: QueryTensor, fb: jax.Array,
                order: jax.Array, *, capacity: int = 4096,
                materialize: bool = False) -> MJoinCount:
    """Count (and optionally materialize up to ``capacity``) occurrences.

    fb: (max_q, n_pad) bool — the double-simulation candidate sets;
    order: (max_q,) int32 search order (PAD beyond n_nodes).
    """
    np_, max_q, max_e = dg.n_pad, qt.max_q, qt.max_e
    w = dg.n_words
    mats_flat = stacked_matrices(dg).reshape(4 * np_, w)
    fb_words = packed.pack(fb)                       # (max_q, W)
    inv = _inverse_order(order, max_q)

    assign = jnp.full((capacity, max_q), PAD, jnp.int32)
    alive = jnp.zeros(capacity, bool).at[0].set(True)
    total = jnp.int32(0)
    overflow = jnp.bool_(False)

    for i in range(max_q):                           # static levels
        qi = jnp.clip(order[i], 0, max_q - 1)
        active = i < qt.n_nodes
        is_last = i == qt.n_nodes - 1

        cand = jnp.broadcast_to(jnp.take(fb_words, qi, axis=0)[None, :],
                                (capacity, w))
        for e in range(max_e):                       # static edges
            src, dst, kind = qt.edge_src[e], qt.edge_dst[e], qt.edge_kind[e]
            valid = kind >= 0
            psrc = jnp.take(inv, jnp.clip(src, 0, max_q - 1))
            pdst = jnp.take(inv, jnp.clip(dst, 0, max_q - 1))
            f_app = valid & (pdst == i) & (psrc < i)   # src bound -> fwd row
            b_app = valid & (psrc == i) & (pdst < i)   # dst bound -> bwd row
            applies = f_app | b_app
            jpos = jnp.where(f_app, psrc, pdst)
            mat_id = jnp.where(f_app, 0, 2) + jnp.clip(kind, 0, 1)
            t_col = jnp.take(assign, jnp.clip(jpos, 0, max_q - 1), axis=1)
            row_idx = mat_id * np_ + jnp.clip(t_col, 0, np_ - 1)
            rows = jnp.take(mats_flat, row_idx, axis=0)          # (F, W)
            cand = jnp.where(applies, cand & rows, cand)

        cand = jnp.where(alive[:, None], cand, jnp.uint32(0))
        counts = packed.popcount(cand).sum(axis=1)               # (F,)
        level_total = counts.sum()
        total = total + jnp.where(active & is_last, level_total, 0)

        # --- expand (all non-last active levels; last too if materializing)
        bits = packed.unpack(cand, np_)                          # (F, Np)
        flat = bits.reshape(-1)
        take = jnp.argsort(~flat, stable=True)[:capacity]
        valid_new = jnp.take(flat, take)
        parent = (take // np_).astype(jnp.int32)
        node = (take % np_).astype(jnp.int32)
        new_assign = jnp.take(assign, parent, axis=0).at[:, i].set(
            jnp.where(valid_new, node, PAD))
        do_expand = active & (~is_last | jnp.bool_(materialize))
        overflow = overflow | (active & ~is_last & (level_total > capacity))
        assign = jnp.where(do_expand, new_assign, assign)
        alive = jnp.where(do_expand, valid_new, alive)

    return MJoinCount(count=total, overflowed=overflow,
                      frontier=assign, alive=alive)


def decode_tuples(res: MJoinCount, order, n_nodes: int):
    """Host-side: frontier rows -> occurrence tuples in query-node order."""
    import numpy as np
    assign = np.asarray(res.frontier)[np.asarray(res.alive)]
    order = np.asarray(order)[:n_nodes]
    out = np.full((assign.shape[0], n_nodes), -1, dtype=np.int64)
    for pos, qnode in enumerate(order):
        out[:, int(qnode)] = assign[:, pos]
    return out
