# TPU-adapted twin of repro.core: packed-matrix double simulation, frontier
# MJoin, vmapped query batches, and the shard_map distributed pipeline.
from .device_graph import DeviceGraph, from_host, stacked_matrices
from .encoding import QueryTensor, encode_batch, encode_query, jo_order
from .enumerate import MJoinCount, decode_tuples, mjoin_count
from .frontier import DeviceIntersector
from .matcher import JaxGM, JaxMatchResult
from .simulation import double_simulation, fb_sizes, rig_edge_counts

__all__ = [
    "DeviceGraph", "from_host", "stacked_matrices",
    "QueryTensor", "encode_query", "encode_batch", "jo_order",
    "double_simulation", "fb_sizes", "rig_edge_counts",
    "mjoin_count", "MJoinCount", "decode_tuples", "DeviceIntersector",
    "JaxGM", "JaxMatchResult",
]
