"""Distributed GM: SUMMA-style sharded double simulation + serving step.

Layout over the production mesh (``("data","model")`` per pod, plus a
leading ``"pod"`` axis across pods):

* packed matrices (A, R, Aᵀ, Rᵀ): rows sharded over ``("pod","data")``,
  packed word-columns sharded over ``"model"`` — 2-D block layout; a 2²⁰-node
  graph is 128 GB packed ⇒ 256 MB/chip on 512 chips.
* FB candidate matrix: node dimension sharded over ``"model"`` (aligned with
  the matrices' column blocks), replicated over ``("pod","data")``.
* one simulation pass =
    local blocked ``bitmm`` on the (row-block × word-block) tile
    → ``psum`` over ``model``  (contraction over node columns)
    → ``all_gather`` over ``("pod","data")`` (rebuild full Y)
    → slice this shard's node range, apply edge masks locally.

The enumeration phase deliberately stays *pod-local*: after double
simulation the RIG is tiny (paper Fig. 9: ≈0.4% of the data graph), so
candidates are compacted (top-K per query node) and handed to the
single-pod frontier enumerator — the distributed phase is the filter, as
in the paper's architecture.  ``gm_serve_step`` is the unit the multi-pod
dry-run lowers and the roofline analyses.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels import packed
from ..kernels.ops import _bitmm_blocked
from .compat import shard_map
from .device_graph import DeviceGraph
from .encoding import QueryTensor

ROW_AXES = ("pod", "data")     # matrix rows (only axes present in the mesh)
COL_AXIS = "model"             # packed word columns / FB node dim


def _axes(mesh: Mesh):
    row_axes = tuple(a for a in ROW_AXES if a in mesh.axis_names)
    assert COL_AXIS in mesh.axis_names
    return row_axes, COL_AXIS


class ShardedGraphSpecs(NamedTuple):
    """ShapeDtypeStructs + shardings for the packed graph (dry-run inputs)."""
    mats: jax.ShapeDtypeStruct         # (4, Np, Np/32) uint32
    labels: jax.ShapeDtypeStruct       # (Np,) int32
    mats_sharding: NamedSharding
    labels_sharding: NamedSharding


def graph_specs(n_pad: int, mesh: Mesh) -> ShardedGraphSpecs:
    row_axes, col = _axes(mesh)
    w = n_pad // 32
    return ShardedGraphSpecs(
        mats=jax.ShapeDtypeStruct((4, n_pad, w), jnp.uint32),
        labels=jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        mats_sharding=NamedSharding(mesh, P(None, row_axes, col)),
        labels_sharding=NamedSharding(mesh, P(col)),
    )


# --------------------------------------------------------------- sim pass
def _local_pass(mats_blk, fb_blk, qt: QueryTensor, *, row_axes, col_axis,
                n_pad: int, block_k: int, unroll: bool = False,
                pack_y: bool = False):
    """shard_map body for one Jacobi double-simulation pass over a BATCH of
    queries.  mats_blk: (4, rows_l, w_l) uint32; fb_blk: (B, max_q, np_l)
    bool.  Returns the pruned fb_blk.

    ``pack_y`` (§Perf H4): the all-gathered Y is pure bits; packing to
    uint32 before the gather cuts its wire bytes 8× (bool is 1 byte on the
    wire) at the cost of one pack/unpack pair of VPU ops per pass.
    """
    b, max_q, np_l = fb_blk.shape
    rows_l = mats_blk.shape[1]

    # contraction operand: all queries' FB side by side -> one matmul/matrix
    x = fb_blk.transpose(2, 0, 1).reshape(np_l, b * max_q).astype(jnp.float32)
    ys = []
    for m in range(4):                                   # A, R, At, Rt
        part = _bitmm_blocked(mats_blk[m], x, threshold=False,
                              block_k=min(block_k, np_l), unroll=unroll)
        ys.append(part)
    y = jnp.stack(ys)                                    # (4, rows_l, B*max_q)
    y = jax.lax.psum(y, col_axis)                        # contract node cols
    y = y > 0
    if pack_y:
        yw = packed.pack(y)                              # (4, rows_l, BQ/32)
        for ax in reversed(row_axes):
            yw = jax.lax.all_gather(yw, ax, axis=1, tiled=True)
        y = packed.unpack(yw, b * max_q)                 # (4, Np, B*max_q)
    else:
        for ax in reversed(row_axes):                    # rebuild full rows
            y = jax.lax.all_gather(y, ax, axis=1, tiled=True)
    col_id = jax.lax.axis_index(col_axis)
    y_mine = jax.lax.dynamic_slice_in_dim(y, col_id * np_l, np_l, axis=1)
    y_mine = y_mine.reshape(4, np_l, b, max_q).transpose(0, 2, 3, 1)
    # (4, B, max_q, np_l): [fwd-child, fwd-desc, bwd-child, bwd-desc]

    def apply_masks(fb_q, y_q, qt_q):
        keep = jnp.ones_like(fb_q)
        for e in range(qt_q.max_e):
            src = qt_q.edge_src[e]
            dst = qt_q.edge_dst[e]
            kind = qt_q.edge_kind[e]
            valid = kind >= 0
            k = jnp.clip(kind, 0, 1)
            m_f = jnp.take(y_q[k], dst, axis=0)          # (np_l,)
            m_b = jnp.take(y_q[2 + k], src, axis=0)
            oh_s = jax.nn.one_hot(src, qt_q.max_q, dtype=bool)
            oh_d = jax.nn.one_hot(dst, qt_q.max_q, dtype=bool)
            keep &= ~oh_s[:, None] | m_f[None, :] | ~valid
            keep &= ~oh_d[:, None] | m_b[None, :] | ~valid
        return fb_q & keep

    y_by_query = y_mine.transpose(1, 0, 2, 3)            # (B, 4, max_q, np_l)
    return jax.vmap(apply_masks)(fb_blk, y_by_query, qt)


def sharded_double_simulation(mats: jax.Array, labels: jax.Array,
                              qts: QueryTensor, mesh: Mesh, *,
                              n_passes: int = 4, block_k: int = 4096,
                              unroll: bool = False,
                              pack_y: bool = False) -> jax.Array:
    """FB for a batch of queries: (B, max_q, n_pad) bool, node dim sharded
    over the ``model`` axis.  ``qts`` leaves carry a leading batch dim."""
    row_axes, col = _axes(mesh)
    n_pad = mats.shape[1]

    fb0 = (qts.labels[:, :, None] == labels[None, None, :]) & \
        (qts.labels[:, :, None] >= 0)                      # (B, max_q, Np)

    body = functools.partial(_local_pass, row_axes=row_axes, col_axis=col,
                             n_pad=n_pad, block_k=block_k, unroll=unroll,
                             pack_y=pack_y)
    qt_specs = jax.tree.map(lambda _: P(), qts)

    pass_sharded = shard_map(
        lambda m, f, q: body(m, f, q),
        mesh=mesh,
        in_specs=(P(None, row_axes, col), P(None, None, col), qt_specs),
        out_specs=P(None, None, col),
        check_vma=False,
    )
    fb = fb0
    for _ in range(n_passes):
        fb = pass_sharded(mats, fb, qts)
    return fb


# -------------------------------------------------------------- serve step
class ServeStepOut(NamedTuple):
    fb_sizes: jax.Array        # (B, max_q) int32   |cos(q)|
    edge_counts: jax.Array     # (B, max_e) float32 RIG edge cardinalities
    candidates: jax.Array      # (B, max_q, top_k) int32 compacted RIG handoff


def gm_serve_step(mats: jax.Array, labels: jax.Array, qts: QueryTensor,
                  mesh: Mesh, *, n_passes: int = 4, top_k: int = 4096,
                  block_k: int = 4096, unroll: bool = False,
                  pack_y: bool = False) -> ServeStepOut:
    """The distributed query-serving step (dry-run unit).

    double simulation (n_passes) → RIG statistics → candidate compaction
    (top-K node ids per query node, the pod-local enumeration handoff).
    """
    fb = sharded_double_simulation(mats, labels, qts, mesh,
                                   n_passes=n_passes, block_k=block_k,
                                   unroll=unroll, pack_y=pack_y)
    sizes = fb.sum(axis=2).astype(jnp.int32)               # (B, max_q)

    # RIG edge counts: one more sum-semantics pass over fwd matrices
    row_axes, col = _axes(mesh)
    n_pad = mats.shape[1]
    b, max_q, _ = fb.shape

    def count_body(mats_blk, fb_blk, qts_):
        bq = fb_blk.shape[0] * fb_blk.shape[1]
        np_l = fb_blk.shape[2]
        x = fb_blk.transpose(2, 0, 1).reshape(np_l, bq).astype(jnp.float32)
        cnt = jnp.stack([
            _bitmm_blocked(mats_blk[0], x, threshold=False,
                           block_k=min(block_k, np_l), unroll=unroll),
            _bitmm_blocked(mats_blk[1], x, threshold=False,
                           block_k=min(block_k, np_l), unroll=unroll),
        ])                                               # (2, rows_l, B*max_q)
        cnt = jax.lax.psum(cnt, col)
        for ax in reversed(row_axes):
            cnt = jax.lax.all_gather(cnt, ax, axis=1, tiled=True)
        col_id = jax.lax.axis_index(col)
        mine = jax.lax.dynamic_slice_in_dim(cnt, col_id * np_l, np_l, axis=1)
        mine = mine.reshape(2, np_l, fb_blk.shape[0], max_q)
        mine = mine.transpose(2, 0, 3, 1)                # (B, 2, max_q, np_l)

        def per_query(fb_q, cnt_q, qt_q):
            out = []
            for e in range(qt_q.max_e):
                src, dst, kind = (qt_q.edge_src[e], qt_q.edge_dst[e],
                                  qt_q.edge_kind[e])
                valid = kind >= 0
                per_node = jnp.take(cnt_q[jnp.clip(kind, 0, 1)], dst, axis=0)
                masked = jnp.where(fb_q[src], per_node, 0.0)
                out.append(jnp.where(valid, masked.sum(), 0.0))
            return jnp.stack(out)

        partial_counts = jax.vmap(per_query)(fb_blk, mine, qts_)
        return jax.lax.psum(partial_counts, col)         # sum node shards

    qt_specs = jax.tree.map(lambda _: P(), qts)
    edge_counts = shard_map(
        count_body, mesh=mesh,
        in_specs=(P(None, row_axes, col), P(None, None, col), qt_specs),
        out_specs=P(),
        check_vma=False,
    )(mats, fb, qts)

    # candidate compaction (§Perf H6): a *global* top_k over the sharded
    # 1M-node axis makes XLA all-gather + sort the whole (B, max_q, N)
    # score tensor (tens of GB of temp).  Exact alternative: every member
    # of the global top-K is in its own shard's local top-K, so take a
    # local top-K per model shard inside shard_map, all-gather the (small)
    # (n_shards · K) id/flag lists, and merge with one tiny top_k.
    def compact_body(fb_blk):
        np_l = fb_blk.shape[2]
        col_id = jax.lax.axis_index(col)
        scores = fb_blk.astype(jnp.int32) * (np_l + 1) - \
            jnp.arange(np_l, dtype=jnp.int32)[None, None, :] % (np_l + 1)
        s_loc, idx_loc = jax.lax.top_k(scores, min(top_k, np_l))
        gid = idx_loc + col_id * np_l
        flag = jnp.take_along_axis(fb_blk, idx_loc, axis=2)
        gid = jnp.where(flag, gid, -1)
        # gather all shards' lists (small: n_shards × K ints per (b, q))
        gid_all = jax.lax.all_gather(gid, col, axis=2, tiled=True)
        flag_all = jax.lax.all_gather(flag, col, axis=2, tiled=True)
        merged_scores = jnp.where(flag_all, n_pad - gid_all, -1)
        _, take = jax.lax.top_k(merged_scores, top_k)
        out = jnp.take_along_axis(gid_all, take, axis=2)
        return out.astype(jnp.int32)

    candidates = shard_map(
        compact_body, mesh=mesh,
        in_specs=(P(None, None, col),),
        out_specs=P(),                      # replicated (it is small)
        check_vma=False,
    )(fb)
    return ServeStepOut(fb_sizes=sizes, edge_counts=edge_counts,
                        candidates=candidates)


# ------------------------------------------------------------ host helpers
def shard_graph_arrays(dg: DeviceGraph, mesh: Mesh):
    """Place a real DeviceGraph onto the mesh (multi-device CPU tests)."""
    specs = graph_specs(dg.n_pad, mesh)
    mats = jnp.stack([dg.adj, dg.reach, dg.adj_t, dg.reach_t])
    mats = jax.device_put(mats, specs.mats_sharding)
    labels = jax.device_put(dg.labels, specs.labels_sharding)
    return mats, labels
