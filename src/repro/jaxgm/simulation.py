"""Batched double simulation on device (TPU adaptation of §5.2–§5.5).

The key restructuring vs the paper's CPU algorithms: one pass evaluates
*all* query edges with four packed matmuls (child/descendant × forward/
backward) instead of per-edge bitmap sweeps —

    Y_f^child = (A · FBᵀ)  > 0        Y_f^desc = (R · FBᵀ)  > 0
    Y_b^child = (Aᵀ · FBᵀ) > 0        Y_b^desc = (Rᵀ · FBᵀ) > 0

then every edge (p, q, kind) contributes two elementwise masks

    FB'(p) &= Y_f^kind[:, q]          FB'(q) &= Y_b^kind[:, p]

applied jointly (Jacobi style).  The largest double simulation is unique
(§5.2), and Jacobi iteration converges to the same fixpoint as the paper's
Gauss-Seidel sweeps; a truncated pass budget (paper: N=4) keeps FB a sound
over-approximation either way.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels import ops, packed
from .device_graph import DeviceGraph
from .encoding import QueryTensor


def initial_fb(dg: DeviceGraph, qt: QueryTensor) -> jax.Array:
    """FB⁰ = match sets: label agreement (padding never matches)."""
    return (qt.labels[:, None] == dg.labels[None, :]) & (qt.labels[:, None] >= 0)


def _edge_masks(dg: DeviceGraph, qt: QueryTensor, fb: jax.Array,
                impl: str) -> jax.Array:
    """One Jacobi double-simulation pass: returns the pruned FB."""
    fbT = fb.T.astype(jnp.float32)                       # (Np, max_q)
    y = [ops.bitmm(m, fbT, impl=impl)                    # each (Np, max_q) bool
         for m in (dg.adj, dg.reach, dg.adj_t, dg.reach_t)]
    y_f = jnp.stack(y[:2])                               # (2, Np, max_q) child/desc
    y_b = jnp.stack(y[2:])

    max_q = qt.max_q
    keep = jnp.ones_like(fb)
    for e in range(qt.max_e):                            # static unroll
        src, dst, kind = qt.edge_src[e], qt.edge_dst[e], qt.edge_kind[e]
        valid = kind >= 0
        k = jnp.clip(kind, 0, 1)
        # forward: nodes in FB(src) need a kind-successor inside FB(dst)
        m_f = jnp.take(y_f[k], dst, axis=1)              # (Np,)
        oh_src = jax.nn.one_hot(src, max_q, dtype=bool)
        keep &= ~oh_src[:, None] | m_f[None, :] | ~valid
        # backward: nodes in FB(dst) need a kind-predecessor inside FB(src)
        m_b = jnp.take(y_b[k], src, axis=1)
        oh_dst = jax.nn.one_hot(dst, max_q, dtype=bool)
        keep &= ~oh_dst[:, None] | m_b[None, :] | ~valid
    return fb & keep


@partial(jax.jit, static_argnames=("n_passes", "impl", "exact"))
def double_simulation(dg: DeviceGraph, qt: QueryTensor, *, n_passes: int = 4,
                      impl: str = "auto", exact: bool = False) -> jax.Array:
    """FB (max_q, n_pad) bool.  ``exact=True`` iterates to the fixpoint with
    a while_loop (CPU/tests); otherwise runs the static ``n_passes`` budget
    (lowerable for the dry-run, matches the paper's N=4 truncation)."""
    fb0 = initial_fb(dg, qt)
    if exact:
        def cond(state):
            fb, prev_count, count = state
            return count != prev_count

        def body(state):
            fb, _, count = state
            fb = _edge_masks(dg, qt, fb, impl)
            return fb, count, fb.sum()

        fb, _, _ = jax.lax.while_loop(
            cond, body, (fb0, jnp.int32(-1), fb0.sum().astype(jnp.int32)))
        return fb
    fb = fb0
    for _ in range(n_passes):
        fb = _edge_masks(dg, qt, fb, impl)
    return fb


def fb_sizes(fb: jax.Array) -> jax.Array:
    """|cos(q)| per query node: (max_q,) int32."""
    return fb.sum(axis=1).astype(jnp.int32)


def rig_edge_counts(dg: DeviceGraph, qt: QueryTensor, fb: jax.Array,
                    impl: str = "auto") -> jax.Array:
    """Per query edge: number of RIG edges (occurrences within cos sets) —
    the paper's RIG size statistic, computed with sum-semantics bitmm:
    |E_e| = Σ_{v∈cos(src)} |row_kind(v) ∩ cos(dst)|."""
    fbT = fb.T.astype(jnp.float32)
    cnt_child = ops.bitmm(dg.adj, fbT, threshold=False, impl=impl)
    cnt_desc = ops.bitmm(dg.reach, fbT, threshold=False, impl=impl)
    out = []
    for e in range(qt.max_e):
        src, dst, kind = qt.edge_src[e], qt.edge_dst[e], qt.edge_kind[e]
        valid = kind >= 0
        per_node = jnp.where(kind == 1,
                             jnp.take(cnt_desc, dst, axis=1),
                             jnp.take(cnt_child, dst, axis=1))     # (Np,)
        masked = jnp.where(fb[src], per_node, 0.0)
        out.append(jnp.where(valid, masked.sum(), 0.0))
    # float32 accumulate (exact for counts < 2^24 per edge); int64 would
    # silently truncate to int32 without the x64 flag.
    return jnp.stack(out).astype(jnp.float32)
