"""Device executor for the host-driven frontier MJoin.

``repro.core.mjoin`` enumerates with host-side gathers; the per-level
AND-reduce + popcount over the gathered ``(F, K, W)`` frontier block is the
arithmetic hot spot, and this module routes it through the ``intersect``
Pallas kernel (``repro.kernels.intersect``).  The host path packs into
uint64 words while the TPU kernel operates on uint32 lanes — the two
layouts are bit-compatible little-endian, so the conversion is a view.

Inputs are padded to kernel block multiples: F to the next power of two
(>= 128, so interpret-mode retraces stay bounded to O(log F) distinct
shapes), W to a multiple of 128 lanes, and K to the next power of two
using all-ones rows (the AND identity — needed by the cross-request
micro-batched path, where the fused ``(ΣF, K, W)`` slabs built by
``repro.core.mjoin.mjoin_batched`` mix queries with different constraint
counts round to round).  Off TPU the kernel runs in interpreter mode —
correct but slow, used by the equivalence tests.
"""

from __future__ import annotations

import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.intersect import intersect_pallas

__all__ = ["DeviceIntersector"]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pow2_at_least(x: int, floor: int = 128) -> int:
    p = floor
    while p < x:
        p *= 2
    return p


class DeviceIntersector:
    """AND-reduce + popcount one ``(F, K, W)`` uint64 frontier block.

    Callable: ``rows (F, K, W64) uint64 -> (and_rows (F, W64) uint64,
    counts (F,) int64)``.  ``interpret=None`` auto-detects: compiled on
    TPU backends, interpreter elsewhere.
    """

    def __init__(self, interpret: Optional[bool] = None):
        self.interpret = (jax.default_backend() != "tpu"
                          if interpret is None else interpret)
        self.calls = 0
        self.kernel_s = 0.0       # fenced wall time inside the kernel

    def __call__(self, rows_u64: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray]:
        f, k, w64 = rows_u64.shape
        w = 2 * w64                                     # uint32 words
        rows = np.ascontiguousarray(rows_u64).view(np.uint32)
        rows = rows.reshape(f, k, w)
        fp, wp = _pow2_at_least(f), _round_up(max(w, 128), 128)
        kp = _pow2_at_least(k, floor=1)
        if fp != f or wp != w or kp != k:
            padded = np.zeros((fp, kp, wp), dtype=np.uint32)
            padded[:f, :k, :w] = rows
            if kp != k:          # AND-identity rows keep real lanes intact
                padded[:f, k:, :w] = np.uint32(0xFFFFFFFF)
            rows = padded
        bw = max(d for d in (512, 256, 128) if wp % d == 0)
        # fence with block_until_ready so kernel_s is true device time, not
        # async-dispatch latency (the conversion below would hide the wait)
        t0 = time.perf_counter()
        and32, counts = intersect_pallas(jnp.asarray(rows), bf=128, bw=bw,
                                         interpret=self.interpret)
        jax.block_until_ready((and32, counts))
        self.kernel_s += time.perf_counter() - t0
        self.calls += 1
        and_rows = np.ascontiguousarray(
            np.asarray(and32)[:f, :w]).view(np.uint64)
        return and_rows, np.asarray(counts)[:f].astype(np.int64)
