"""Device executors for the host-driven frontier MJoin.

``repro.core.mjoin`` enumerates with host-side frontier tables; this
module holds the two device execution paths for the per-level constraint
work:

* :class:`DeviceIntersector` — the *slab-shipping* path
  (``frontier-device``): the host gathers the ``(F, K, W)`` constraint
  rows, ships the slab, and the device AND-reduces + popcounts it.
* :class:`ResidentIntersector` — the *resident* path
  (``frontier-device-resident``): every packed RIG adjacency matrix is
  concatenated and uploaded **once** after ``BuildRIG``
  (:func:`repro.jaxgm.device_graph.pack_resident_rig`); each level then
  ships only the ``(F, K)`` int32 constraint-row indices and the fused
  ``gather_intersect`` kernel does the gather + AND + popcount on device.
  Frontier expansion (set-bit -> (row, column) pairs) also runs on device
  (:func:`repro.kernels.gather_intersect.expand_pairs`), so the host
  receives compact pair pages instead of dense boolean slabs.

Both executors resolve a common ``mode``:

* ``"pallas"``    — the compiled TPU kernels (default on TPU backends);
* ``"xla"``       — the same contractions as plain jitted XLA (default
  elsewhere: orders of magnitude faster than the Pallas interpreter and
  still measures the real transfer gap between the two paths);
* ``"interpret"`` — the Pallas kernels under the interpreter (CI
  equivalence tests for the kernel logic itself).

Set the module global ``DEFAULT_MODE`` to pin a mode process-wide (the
equivalence suite sets ``"interpret"``).

Executables are compiled **ahead of time** per shape and the compile wall
time is recorded in ``compile_s``, separately from ``kernel_s`` — the
fenced per-call device time.  Earlier versions folded first-call
compilation into ``kernel_s``, skewing traces and BENCH rows.

Padding geometry (F to the next pow2 >= 128, W to a multiple of 128
uint32 lanes, K to pow2 with all-ones AND-identity rows) comes from
:mod:`repro.core.slabgeom` — the same formulas budget enforcement uses,
so ``Budget.max_slab_bytes`` bounds the *real* device allocation
(``peak_slab_bytes`` / ``peak_dispatch_bytes`` expose it).
"""

from __future__ import annotations

import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.slabgeom import (padded_slab_bytes, padded_slab_shape,
                             pow2_at_least, resident_dispatch_bytes,
                             resident_rows_cap, round_up)
from ..kernels.gather_intersect import (expand_pairs, gather_intersect_pallas,
                                        gather_intersect_xla)
from ..kernels.intersect import intersect_pallas, intersect_xla
from ..obs.ledger import get_ledger

__all__ = ["DeviceIntersector", "ResidentIntersector", "resolve_mode",
           "DEFAULT_MODE", "resident_fingerprint"]

# process-wide mode pin: None = auto (pallas on TPU, xla elsewhere)
DEFAULT_MODE: Optional[str] = None

_MODES = ("pallas", "xla", "interpret")


def resolve_mode(mode: Optional[str] = None) -> str:
    mode = DEFAULT_MODE if mode is None else mode
    if mode is None:
        mode = "pallas" if jax.default_backend() == "tpu" else "xla"
    if mode not in _MODES:
        raise ValueError(f"unknown device mode: {mode!r} "
                         f"(expected one of {_MODES})")
    return mode


def resident_fingerprint(rig) -> tuple:
    """Shape signature of a RIG's packed matrices.  BuildRIG is
    deterministic per (graph, canonical query), so a cached
    :class:`ResidentIntersector` whose fingerprint matches a freshly built
    RIG was packed from identical matrices and can be re-attached without
    re-uploading."""
    return (tuple(m.shape for m in rig.fwd),
            tuple(m.shape for m in rig.bwd))


class DeviceIntersector:
    """AND-reduce + popcount one ``(F, K, W)`` uint64 frontier block.

    Callable: ``rows (F, K, W64) uint64 -> (and_rows (F, W64) uint64,
    counts (F,) int64)``.  ``interpret`` is a legacy alias: ``True`` pins
    the interpreter, ``False`` the compiled Pallas kernel; prefer
    ``mode`` (see module docstring).
    """

    def __init__(self, interpret: Optional[bool] = None,
                 mode: Optional[str] = None):
        if mode is None and interpret is not None:
            mode = "interpret" if interpret else "pallas"
        self.mode = resolve_mode(mode)
        self.calls = 0
        self.kernel_s = 0.0       # fenced per-call device time (no compile)
        self.compile_s = 0.0      # one-time AOT compile time per shape
        self.peak_slab_bytes = 0  # largest padded slab actually allocated
        self.h2d_bytes = 0        # cumulative host->device slab traffic
        self.d2h_bytes = 0        # cumulative device->host readback traffic
        # ledger attribution key; the slab intersector is a process-global
        # singleton shared across graphs, so callers may retag per dispatch
        self.ledger_key = "-"
        self._compiled = {}

    @property
    def interpret(self) -> bool:
        return self.mode == "interpret"

    def _executor(self, fp: int, kp: int, wp: int):
        key = (fp, kp, wp)
        fn = self._compiled.get(key)
        if fn is None:
            spec = jax.ShapeDtypeStruct((fp, kp, wp), jnp.uint32)
            t0 = time.perf_counter()
            if self.mode == "xla":
                fn = intersect_xla.lower(spec).compile()
            else:
                bw = max(d for d in (512, 256, 128) if wp % d == 0)
                fn = intersect_pallas.lower(
                    spec, bf=128, bw=bw,
                    interpret=self.mode == "interpret").compile()
            self.compile_s += time.perf_counter() - t0
            self._compiled[key] = fn
        return fn

    def __call__(self, rows_u64: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray]:
        f, k, w64 = rows_u64.shape
        w = 2 * w64                                     # uint32 lanes
        rows = np.ascontiguousarray(rows_u64).view(np.uint32)
        rows = rows.reshape(f, k, w)
        fp, kp, wp = padded_slab_shape(f, k, w64)
        if (fp, kp, wp) != (f, k, w):
            padded = np.zeros((fp, kp, wp), dtype=np.uint32)
            padded[:f, :k, :w] = rows
            if kp != k:          # AND-identity rows keep real lanes intact
                padded[:f, k:, :w] = np.uint32(0xFFFFFFFF)
            rows = padded
        self.peak_slab_bytes = max(self.peak_slab_bytes,
                                   padded_slab_bytes(f, k, w64))
        # rows is padded, so rows.nbytes == padded_slab_bytes(f, k, w64):
        # the ledger charge equals the dispatched bytes by construction
        self.h2d_bytes += rows.nbytes
        get_ledger().transfers.h2d("slab_ship", rows.nbytes,
                                   self.ledger_key)
        fn = self._executor(fp, kp, wp)
        # fence with block_until_ready so kernel_s is true device time, not
        # async-dispatch latency (the conversion below would hide the wait)
        t0 = time.perf_counter()
        and32, counts = fn(jnp.asarray(rows))
        jax.block_until_ready((and32, counts))
        self.kernel_s += time.perf_counter() - t0
        self.calls += 1
        and_np = np.asarray(and32)
        counts_np = np.asarray(counts)
        d2h = and_np.nbytes + counts_np.nbytes
        self.d2h_bytes += d2h
        get_ledger().transfers.d2h("slab_ship", d2h, self.ledger_key)
        and_rows = np.ascontiguousarray(and_np[:f, :w]).view(np.uint64)
        return and_rows, counts_np[:f].astype(np.int64)


class _ResidentSlab:
    """Opaque handle for one dispatched slab: the padded AND rows, still
    on device, awaiting an optional :meth:`ResidentIntersector.expand`."""

    __slots__ = ("acc", "f")

    def __init__(self, acc, f: int):
        self.acc = acc
        self.f = f


class ResidentIntersector:
    """Device-resident RIG executor (see module docstring).

    Built once per RIG via :meth:`build` (cached on ``rig.resident`` by
    ``repro.core.mjoin.resident_intersector``); ``nbytes`` is the resident
    matrix footprint and ``upload_s`` the fenced one-time upload.
    """

    # device->host pair pages are sliced from this bucket granularity so
    # expand retraces stay bounded
    PAGE_BUCKET = 1024

    def __init__(self, matrix32: np.ndarray, fwd_off: List[int],
                 bwd_off: List[int], zero_row: int,
                 mode: Optional[str] = None, key: str = "-"):
        self.mode = resolve_mode(mode)
        self.key = key
        t0 = time.perf_counter()
        self.matrix = jnp.asarray(matrix32)
        jax.block_until_ready(self.matrix)
        self.upload_s = time.perf_counter() - t0
        self.nbytes = int(self.matrix.size) * 4
        ledger = get_ledger()
        ledger.transfers.h2d("resident_upload", self.nbytes, key)
        # the packed matrix stays device-resident until close(): charge the
        # resident ledger now, credit on close (conservation invariant)
        self._alloc = ledger.resident.charge(key, self.nbytes)
        self.w_lanes = int(self.matrix.shape[1])
        self.fwd_off = fwd_off
        self.bwd_off = bwd_off
        self.zero_row = zero_row
        self.fingerprint: Optional[tuple] = None
        self.calls = 0            # gather-intersect dispatches
        self.expand_calls = 0     # pair-page dispatches
        self.h2d_bytes = 0        # cumulative host->device index traffic
        self.d2h_bytes = 0        # cumulative device->host readback traffic
        self.kernel_s = 0.0       # fenced per-call device time (no compile)
        self.compile_s = 0.0      # one-time AOT compile time per shape
        self.peak_dispatch_bytes = 0
        self._compiled = {}

    @classmethod
    def build(cls, rig, mode: Optional[str] = None) -> "ResidentIntersector":
        from .device_graph import pack_resident_rig
        matrix32, fwd_off, bwd_off, zero_row = pack_resident_rig(rig)
        res = cls(matrix32, fwd_off, bwd_off, zero_row, mode=mode,
                  key=getattr(rig, "graph_key", "-"))
        res.fingerprint = resident_fingerprint(rig)
        return res

    @property
    def closed(self) -> bool:
        return self._alloc is None

    def close(self) -> int:
        """Release the device-resident matrix and credit the ledger.
        Idempotent; returns the bytes credited (0 if already closed)."""
        credited = get_ledger().resident.credit(self._alloc)
        self._alloc = None
        matrix, self.matrix = getattr(self, "matrix", None), None
        self._compiled = {}
        if matrix is not None:
            try:
                matrix.delete()
            except Exception:
                pass            # already deleted / backend shutting down
        return credited

    def __del__(self):
        # GC safety net: an executor dropped without close() must still
        # credit the ledger or the conservation invariant drifts
        try:
            self.close()
        except Exception:
            pass

    def rows_cap(self, max_bytes: int, k: int, at_most: int) -> int:
        """Largest slab height whose padded dispatch transient fits
        ``max_bytes`` (0 = infeasible: route the level through the host)."""
        return resident_rows_cap(max_bytes, k, self.w_lanes, at_most)

    # ------------------------------------------------------------ executors
    def _intersect_exec(self, fp: int, k: int, w32: int):
        key = ("isect", fp, k, w32)
        fn = self._compiled.get(key)
        if fn is None:
            mspec = jax.ShapeDtypeStruct(self.matrix.shape, jnp.uint32)
            ispec = jax.ShapeDtypeStruct((fp, k), jnp.int32)
            t0 = time.perf_counter()
            if self.mode == "xla":
                fn = gather_intersect_xla.lower(mspec, ispec,
                                                w32=w32).compile()
            else:
                fn = gather_intersect_pallas.lower(
                    mspec, ispec, w32=w32, bf=8,
                    interpret=self.mode == "interpret").compile()
            self.compile_s += time.perf_counter() - t0
            self._compiled[key] = fn
        return fn

    def _expand_exec(self, fp: int, w32: int, n_i: int, size: int):
        key = ("expand", fp, w32, n_i, size)
        fn = self._compiled.get(key)
        if fn is None:
            aspec = jax.ShapeDtypeStruct((fp, w32), jnp.uint32)
            t0 = time.perf_counter()
            fn = expand_pairs.lower(aspec, n_i=n_i, size=size).compile()
            self.compile_s += time.perf_counter() - t0
            self._compiled[key] = fn
        return fn

    # ------------------------------------------------------------------ API
    def intersect(self, cs, slab: np.ndarray, w64: int
                  ) -> Tuple[_ResidentSlab, np.ndarray]:
        """One level dispatch for one frontier slab.

        ``cs`` is the level's constraint list ``(prefix_pos, edge, isf)``
        (as built by ``repro.core.mjoin._constraints``), ``slab`` the
        ``(F, i)`` frontier rows, ``w64`` the level's packed row width in
        uint64 words.  Ships only the ``(F, K)`` int32 index matrix;
        returns the on-device AND rows (handle) plus host popcounts.
        """
        f, k = len(slab), len(cs)
        idx = np.empty((f, k), dtype=np.int32)
        for c, (j, ei, isf) in enumerate(cs):
            off = self.fwd_off[ei] if isf else self.bwd_off[ei]
            idx[:, c] = off + slab[:, j]
        fp = pow2_at_least(f)
        if fp != f:
            # padding rows gather the dedicated all-zero resident row, so
            # padded AND rows are zero: counts and expands never see them
            pad = np.full((fp - f, k), self.zero_row, dtype=np.int32)
            idx = np.vstack([idx, pad])
        w32 = 2 * w64
        fn = self._intersect_exec(fp, k, w32)
        # idx is padded, so idx.nbytes == pow2_at_least(f) * k * 4: charged
        # bytes equal shipped bytes
        self.h2d_bytes += idx.nbytes
        get_ledger().transfers.h2d("index_vectors", idx.nbytes, self.key)
        self.peak_dispatch_bytes = max(
            self.peak_dispatch_bytes,
            resident_dispatch_bytes(f, k, self.w_lanes))
        t0 = time.perf_counter()
        acc, counts = fn(self.matrix, jnp.asarray(idx))
        jax.block_until_ready((acc, counts))
        self.kernel_s += time.perf_counter() - t0
        self.calls += 1
        counts_np = np.asarray(counts)
        self.d2h_bytes += counts_np.nbytes
        get_ledger().transfers.d2h("index_vectors", counts_np.nbytes,
                                   self.key)
        return (_ResidentSlab(acc, f),
                counts_np[:f].astype(np.int64))

    def expand(self, handle: _ResidentSlab, n_i: int, want: int
               ) -> Tuple[np.ndarray, np.ndarray]:
        """First ``want`` set-bit ``(row, column)`` pairs of a dispatched
        slab, in row-major (= lexicographic) order — computed on device,
        shipped as one compact page."""
        if want <= 0:
            z = np.empty(0, dtype=np.int64)
            return z, z
        if self.mode == "xla":
            # Plain-XLA mode means no accelerator: fetch the packed AND
            # rows and extract on the host, where unpackbits + nonzero is
            # an order of magnitude faster than XLA's serialized nonzero
            # lowering.  Pallas/interpret modes extract on device, where
            # shipping the compact (row, col) page beats shipping rows.
            t0 = time.perf_counter()
            lanes = (n_i + 31) // 32          # fetch only the live lanes
            rows = np.asarray(handle.acc[:handle.f, :lanes])
            self.d2h_bytes += rows.nbytes
            get_ledger().transfers.d2h("pair_extract_d2h", rows.nbytes,
                                       self.key)
            bits = np.unpackbits(np.ascontiguousarray(rows).view(np.uint8),
                                 axis=1, bitorder="little")[:, :n_i]
            rid, cid = np.nonzero(bits)
            self.kernel_s += time.perf_counter() - t0
            self.expand_calls += 1
            return rid[:want].astype(np.int64), cid[:want].astype(np.int64)
        size = round_up(want, self.PAGE_BUCKET)
        fp, w32 = handle.acc.shape
        fn = self._expand_exec(int(fp), int(w32), n_i, size)
        t0 = time.perf_counter()
        rid, cid = fn(handle.acc)
        jax.block_until_ready((rid, cid))
        self.kernel_s += time.perf_counter() - t0
        self.expand_calls += 1
        rid_np, cid_np = np.asarray(rid), np.asarray(cid)
        page = rid_np.nbytes + cid_np.nbytes   # full pages ship, then slice
        self.d2h_bytes += page
        get_ledger().transfers.d2h("pair_extract_d2h", page, self.key)
        return (rid_np[:want].astype(np.int64),
                cid_np[:want].astype(np.int64))
