"""Version compatibility shims for the jax API surface we use.

``jax.shard_map`` (with its ``check_vma`` flag) graduated out of
``jax.experimental.shard_map`` (where the flag was called ``check_rep``)
in newer jax releases; this module exposes one ``shard_map`` that works on
both, so the distributed pipeline imports from here instead of pinning a
jax version.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    if "check_vma" in kwargs:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)
