"""Tensor encoding of pattern queries (fixed max_q / max_e padding).

Encoding queries as flat int arrays makes the whole matcher a function of
arrays only — so a *batch of queries* is just stacked tensors and the
pipeline ``vmap``s over it (the serving driver's batching axis).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.query import CHILD, DESC, PatternQuery

PAD = -1


@jax.tree_util.register_pytree_node_class
@dataclass
class QueryTensor:
    labels: jax.Array      # int32 (max_q,), PAD on padding
    edge_src: jax.Array    # int32 (max_e,)
    edge_dst: jax.Array    # int32 (max_e,)
    edge_kind: jax.Array   # int32 (max_e,): 0 child, 1 desc, PAD padding
    n_nodes: jax.Array     # int32 scalar
    n_edges: jax.Array     # int32 scalar

    def tree_flatten(self):
        return ((self.labels, self.edge_src, self.edge_dst, self.edge_kind,
                 self.n_nodes, self.n_edges), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def max_q(self) -> int:
        return self.labels.shape[-1]

    @property
    def max_e(self) -> int:
        return self.edge_src.shape[-1]


def encode_query(q: PatternQuery, max_q: int, max_e: int) -> QueryTensor:
    assert q.n <= max_q, f"query has {q.n} nodes > max_q={max_q}"
    assert q.m <= max_e, f"query has {q.m} edges > max_e={max_e}"
    labels = np.full(max_q, PAD, dtype=np.int32)
    labels[:q.n] = q.labels
    src = np.full(max_e, 0, dtype=np.int32)
    dst = np.full(max_e, 0, dtype=np.int32)
    kind = np.full(max_e, PAD, dtype=np.int32)
    for i, e in enumerate(q.edges):
        src[i], dst[i], kind[i] = e.src, e.dst, e.kind
    return QueryTensor(labels=jnp.asarray(labels), edge_src=jnp.asarray(src),
                       edge_dst=jnp.asarray(dst), edge_kind=jnp.asarray(kind),
                       n_nodes=jnp.int32(q.n), n_edges=jnp.int32(q.m))


def encode_batch(queries: Sequence[PatternQuery], max_q: int,
                 max_e: int) -> QueryTensor:
    qts = [encode_query(q, max_q, max_e) for q in queries]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *qts)


def query_adjacency(qt: QueryTensor) -> jax.Array:
    """Undirected (max_q, max_q) bool adjacency of the pattern."""
    max_q = qt.max_q
    valid = qt.edge_kind >= 0
    a = jnp.zeros((max_q, max_q), bool)
    a = a.at[qt.edge_src, qt.edge_dst].max(valid)
    a = a.at[qt.edge_dst, qt.edge_src].max(valid)
    return a


def jo_order(qt: QueryTensor, fb_sizes: jax.Array) -> jax.Array:
    """Device-side JO ordering (§6.1): greedy smallest-candidate-set-first
    with connectivity to the prefix.  fb_sizes: (max_q,) int32 candidate-set
    cardinalities from the double simulation.  Returns (max_q,) int32 order
    (positions >= n_nodes hold arbitrary leftover nodes)."""
    max_q = qt.max_q
    adj = query_adjacency(qt)
    real = jnp.arange(max_q) < qt.n_nodes
    INF = jnp.iinfo(jnp.int32).max      # NB: int64 silently truncates w/o x64
    sizes = jnp.where(real, jnp.minimum(fb_sizes, INF - 1), INF)

    def step(state, i):
        selected, order = state
        touching = (adj & selected[None, :]).any(axis=1)
        eligible = (~selected) & real & jnp.where(i == 0, True, touching)
        # fall back to any unselected real node (disconnected guard)
        any_elig = eligible.any()
        fallback = (~selected) & real
        elig = jnp.where(any_elig, eligible, fallback)
        cost = jnp.where(elig, sizes, INF)
        nxt = jnp.argmin(cost).astype(jnp.int32)
        selected = selected.at[nxt].set(real[nxt])
        order = order.at[i].set(jnp.where(i < qt.n_nodes, nxt, PAD))
        return (selected, order), None

    sel0 = jnp.zeros(max_q, bool)
    ord0 = jnp.full(max_q, PAD, jnp.int32)
    (_, order), _ = jax.lax.scan(step, (sel0, ord0), jnp.arange(max_q))
    return order
