"""JaxGM — the device-side GM pipeline (single query and vmapped batches).

match(query) = encode → double simulation → JO order (device) → frontier
MJoin.  A batch of queries is the same function under ``vmap`` over the
QueryTensor leaves — the packed graph matrices are closed over (shared).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import DataGraph
from ..core.query import PatternQuery
from . import device_graph
from .device_graph import DeviceGraph
from .encoding import QueryTensor, encode_batch, encode_query, jo_order
from .enumerate import MJoinCount, decode_tuples, mjoin_count
from .simulation import double_simulation, fb_sizes, rig_edge_counts


@dataclass
class JaxMatchResult:
    count: int
    overflowed: bool
    fb_sizes: np.ndarray          # |cos(q)| per query node
    tuples: Optional[np.ndarray] = None


def _pipeline(dg: DeviceGraph, qt: QueryTensor, *, n_passes: int,
              exact_sim: bool, capacity: int, impl: str,
              materialize: bool) -> tuple:
    fb = double_simulation(dg, qt, n_passes=n_passes, impl=impl,
                           exact=exact_sim)
    sizes = fb_sizes(fb)
    order = jo_order(qt, sizes)
    res = mjoin_count(dg, qt, fb, order, capacity=capacity,
                      materialize=materialize)
    return res, sizes, order


class JaxGM:
    """Device matcher bound to one data graph."""

    def __init__(self, graph: DataGraph, *, max_q: int = 8, max_e: int = 16,
                 block: int = 512, capacity: int = 4096, n_passes: int = 4,
                 exact_sim: bool = False, impl: str = "auto",
                 closure_on_device: bool = False,
                 use_transitive_reduction: bool = True):
        self.graph = graph
        self.max_q, self.max_e = max_q, max_e
        self.capacity, self.n_passes = capacity, n_passes
        self.exact_sim, self.impl = exact_sim, impl
        self.use_tr = use_transitive_reduction
        self.dg = device_graph.from_host(graph, block=block,
                                         closure_on_device=closure_on_device,
                                         impl=impl)
        self._single = partial(_pipeline, n_passes=n_passes,
                               exact_sim=exact_sim, capacity=capacity,
                               impl=impl)
        self._batched = jax.vmap(
            lambda qt: self._single(self.dg, qt, materialize=False),
            in_axes=(0,))

    def _prep(self, q: PatternQuery) -> tuple:
        if self.use_tr:
            q = q.transitive_reduction()
        return q, encode_query(q, self.max_q, self.max_e)

    def match(self, q: PatternQuery,
              materialize: bool = False) -> JaxMatchResult:
        q, qt = self._prep(q)
        res, sizes, order = self._single(self.dg, qt, materialize=materialize)
        tuples = None
        if materialize:
            tuples = decode_tuples(res, order, q.n)
        return JaxMatchResult(count=int(res.count),
                              overflowed=bool(res.overflowed),
                              fb_sizes=np.asarray(sizes)[:q.n],
                              tuples=tuples)

    def match_batch(self, queries: Sequence[PatternQuery]) -> List[JaxMatchResult]:
        prepped = [self._prep(q) for q in queries]
        qts = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[qt for _, qt in prepped])
        res, sizes, order = self._batched(qts)
        out = []
        for i, (q, _) in enumerate(prepped):
            out.append(JaxMatchResult(
                count=int(res.count[i]), overflowed=bool(res.overflowed[i]),
                fb_sizes=np.asarray(sizes[i])[:q.n]))
        return out

    def rig_stats(self, q: PatternQuery):
        """(fb sizes, per-edge RIG edge counts) — Fig. 9 statistics."""
        q, qt = self._prep(q)
        fb = double_simulation(self.dg, qt, n_passes=self.n_passes,
                               impl=self.impl, exact=self.exact_sim)
        return (np.asarray(fb_sizes(fb))[:q.n],
                np.asarray(rig_edge_counts(self.dg, qt, fb, impl=self.impl))[:q.m])
