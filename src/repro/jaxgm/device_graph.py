"""Device-resident packed data graph for the TPU-adapted matcher.

Holds the four packed operand matrices of the §5.5 bitset algebra —
adjacency, adjacency-transpose, reachability closure, closure-transpose —
as ``uint32`` words padded to a block multiple, plus node labels.  Built
either from a host :class:`~repro.core.graph.DataGraph` (closure from the
host index) or entirely on device (closure via the ``closure`` kernel /
blocked squaring).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import bitset as hostbits
from ..core.graph import DataGraph
from ..kernels import ops, packed

PAD_LABEL = -2  # label id of padding nodes: never matches any query label


@jax.tree_util.register_pytree_node_class
@dataclass
class DeviceGraph:
    n: int                      # real node count
    n_pad: int                  # padded universe (multiple of block)
    labels: jax.Array           # int32 (n_pad,), PAD_LABEL on padding
    adj: jax.Array              # uint32 (n_pad, n_pad/32) children rows
    adj_t: jax.Array            # parents rows
    reach: jax.Array            # descendant rows (≺, path len >= 1)
    reach_t: jax.Array          # ancestor rows

    # --- pytree plumbing (n/n_pad are static aux data) ---
    def tree_flatten(self):
        return ((self.labels, self.adj, self.adj_t, self.reach, self.reach_t),
                (self.n, self.n_pad))

    @classmethod
    def tree_unflatten(cls, aux, children):
        labels, adj, adj_t, reach, reach_t = children
        n, n_pad = aux
        return cls(n=n, n_pad=n_pad, labels=labels, adj=adj, adj_t=adj_t,
                   reach=reach, reach_t=reach_t)

    @property
    def n_words(self) -> int:
        return self.n_pad // 32


def _repack_pad(words64: np.ndarray, n: int, n_pad: int) -> np.ndarray:
    """Host uint64-packed rows over universe n -> uint32 rows over n_pad."""
    dense = hostbits.unpack(words64, n)
    rows = dense.shape[0]
    out = np.zeros((n_pad, n_pad), dtype=bool)
    out[:rows, :n] = dense
    return np.asarray(packed.pack(jnp.asarray(out)))


def from_host(graph: DataGraph, block: int = 512,
              closure_on_device: bool = False,
              impl: str = "auto") -> DeviceGraph:
    n = graph.n
    n_pad = ((n + block - 1) // block) * block
    labels = np.full(n_pad, PAD_LABEL, dtype=np.int32)
    labels[:n] = graph.labels

    adj = _repack_pad(graph.adj_bits(), n, n_pad)
    adj_t = _repack_pad(graph.adj_bits_t(), n, n_pad)
    if closure_on_device:
        reach = np.asarray(ops.transitive_closure(jnp.asarray(adj), impl=impl))
        dense = np.asarray(packed.unpack(jnp.asarray(reach), n_pad))
        reach_t = np.asarray(packed.pack(jnp.asarray(dense.T)))
    else:
        ridx = graph.reachability()
        reach = _repack_pad(ridx.reach_bits, n, n_pad)
        reach_t = _repack_pad(ridx.bits_t(), n, n_pad)
    return DeviceGraph(n=n, n_pad=n_pad,
                       labels=jnp.asarray(labels),
                       adj=jnp.asarray(adj), adj_t=jnp.asarray(adj_t),
                       reach=jnp.asarray(reach), reach_t=jnp.asarray(reach_t))


def stacked_matrices(dg: DeviceGraph) -> jax.Array:
    """(4, n_pad, W) stacked [adj, reach, adj_t, reach_t] — lets the
    enumerator pick the operand with one flat gather:
    matrix id = 2 * is_backward + (kind == DESC)."""
    return jnp.stack([dg.adj, dg.reach, dg.adj_t, dg.reach_t], axis=0)
