"""Device-resident packed data graph for the TPU-adapted matcher.

Holds the four packed operand matrices of the §5.5 bitset algebra —
adjacency, adjacency-transpose, reachability closure, closure-transpose —
as ``uint32`` words padded to a block multiple, plus node labels.  Built
either from a host :class:`~repro.core.graph.DataGraph` (closure from the
host index) or entirely on device (closure via the ``closure`` kernel /
blocked squaring).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import bitset as hostbits
from ..core.graph import DataGraph
from ..kernels import ops, packed
from ..obs.ledger import get_ledger

PAD_LABEL = -2  # label id of padding nodes: never matches any query label


@jax.tree_util.register_pytree_node_class
@dataclass
class DeviceGraph:
    n: int                      # real node count
    n_pad: int                  # padded universe (multiple of block)
    labels: jax.Array           # int32 (n_pad,), PAD_LABEL on padding
    adj: jax.Array              # uint32 (n_pad, n_pad/32) children rows
    adj_t: jax.Array            # parents rows
    reach: jax.Array            # descendant rows (≺, path len >= 1)
    reach_t: jax.Array          # ancestor rows

    # --- pytree plumbing (n/n_pad are static aux data) ---
    def tree_flatten(self):
        return ((self.labels, self.adj, self.adj_t, self.reach, self.reach_t),
                (self.n, self.n_pad))

    @classmethod
    def tree_unflatten(cls, aux, children):
        labels, adj, adj_t, reach, reach_t = children
        n, n_pad = aux
        return cls(n=n, n_pad=n_pad, labels=labels, adj=adj, adj_t=adj_t,
                   reach=reach, reach_t=reach_t)

    @property
    def n_words(self) -> int:
        return self.n_pad // 32


def _repack_pad(words64: np.ndarray, n: int, n_pad: int) -> np.ndarray:
    """Host uint64-packed rows over universe n -> uint32 rows over n_pad."""
    dense = hostbits.unpack(words64, n)
    rows = dense.shape[0]
    out = np.zeros((n_pad, n_pad), dtype=bool)
    out[:rows, :n] = dense
    return np.asarray(packed.pack(jnp.asarray(out)))


def from_host(graph: DataGraph, block: int = 512,
              closure_on_device: bool = False,
              impl: str = "auto") -> DeviceGraph:
    n = graph.n
    n_pad = ((n + block - 1) // block) * block
    labels = np.full(n_pad, PAD_LABEL, dtype=np.int32)
    labels[:n] = graph.labels

    adj = _repack_pad(graph.adj_bits(), n, n_pad)
    adj_t = _repack_pad(graph.adj_bits_t(), n, n_pad)
    if closure_on_device:
        reach = np.asarray(ops.transitive_closure(jnp.asarray(adj), impl=impl))
        dense = np.asarray(packed.unpack(jnp.asarray(reach), n_pad))
        reach_t = np.asarray(packed.pack(jnp.asarray(dense.T)))
    else:
        ridx = graph.reachability()
        reach = _repack_pad(ridx.reach_bits, n, n_pad)
        reach_t = _repack_pad(ridx.bits_t(), n, n_pad)
    dg = DeviceGraph(n=n, n_pad=n_pad,
                     labels=jnp.asarray(labels),
                     adj=jnp.asarray(adj), adj_t=jnp.asarray(adj_t),
                     reach=jnp.asarray(reach), reach_t=jnp.asarray(reach_t))
    shipped = (labels.nbytes + adj.nbytes + adj_t.nbytes
               + reach.nbytes + reach_t.nbytes)
    get_ledger().transfers.h2d("label_build", shipped,
                               getattr(graph, "graph_key", "-"))
    return dg


def stacked_matrices(dg: DeviceGraph) -> jax.Array:
    """(4, n_pad, W) stacked [adj, reach, adj_t, reach_t] — lets the
    enumerator pick the operand with one flat gather:
    matrix id = 2 * is_backward + (kind == DESC)."""
    return jnp.stack([dg.adj, dg.reach, dg.adj_t, dg.reach_t], axis=0)


def pack_resident_rig(rig):
    """Concatenate a RIG's per-edge packed adjacency into one uint32
    matrix for the resident gather-intersect path.

    Every ``rig.fwd[e]`` / ``rig.bwd[e]`` uint64 matrix is re-viewed as
    little-endian uint32 lanes (bit-compatible with the host packing) and
    stacked row-wise into ``(R, W)`` with ``W`` = the widest edge's lane
    count rounded to 128; rows are zero-extended beyond their true width,
    so AND/popcount over the common width is exact.  A dedicated all-zero
    row is appended last — index padding targets it so padded dispatch
    rows contribute nothing.

    Returns ``(matrix32, fwd_off, bwd_off, zero_row)``: constraint row
    ``(edge e, forward, local src id i)`` lives at ``fwd_off[e] + i``
    (``bwd_off[e] + i`` for backward rows).

    Resident footprint: ``(Σ_e |cos(src_e)| + |cos(dst_e)| + 1) * W * 4``
    bytes — linear in RIG nodes per edge, not in enumerated frontiers.
    """
    mats = list(rig.fwd) + list(rig.bwd)
    w_lanes = 128
    for m in mats:
        w_lanes = max(w_lanes, 2 * m.shape[1])
    w_lanes = -(-w_lanes // 128) * 128
    rows = sum(m.shape[0] for m in mats) + 1          # + the all-zero row
    matrix = np.zeros((rows, w_lanes), dtype=np.uint32)
    fwd_off: list = []
    bwd_off: list = []
    off = 0
    for offs, group in ((fwd_off, rig.fwd), (bwd_off, rig.bwd)):
        for m in group:
            offs.append(off)
            s, w64 = m.shape
            if s:
                matrix[off:off + s, :2 * w64] = np.ascontiguousarray(
                    m).view(np.uint32).reshape(s, 2 * w64)
            off += s
    return matrix, fwd_off, bwd_off, rows - 1
