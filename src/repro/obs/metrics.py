"""Process-wide metrics registry: counters, gauges, histograms with labels.

One :class:`MetricsRegistry` holds every metric of a subsystem (the module
global :data:`REGISTRY` is the process-wide default; each
:class:`~repro.engine.engine.Engine` owns its own so per-engine counters
stay isolated and testable).  Metrics are keyed by ``(name, labels)`` —
``registry.counter("cache_hits", cache="plan")`` returns the same
:class:`Counter` object on every call, so hot paths can either hold the
object or go through the registry.

``snapshot()`` takes an *atomic* point-in-time copy under the registry
lock — the fix for torn reads when concurrent streams finalize while other
queries mutate shared counters (see ``EngineStats``).  Exporters
(Prometheus text, JSON) live in :mod:`repro.obs.export`.
"""

from __future__ import annotations

import math
import threading
from typing import Any, Dict, Iterator, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "REGISTRY", "get_registry", "metric_key", "escape_label_value"]

LabelItems = Tuple[Tuple[str, str], ...]


def escape_label_value(v: str) -> str:
    """Prometheus text-exposition escaping for label values: backslash,
    double-quote and newline must be escaped or the series line is
    unparseable (canonical query keys can contain any of them)."""
    return (v.replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def metric_key(name: str, labels: LabelItems) -> str:
    """Prometheus-style series key: ``name{k="v",...}`` (no braces when
    unlabeled); label values are exposition-escaped."""
    if not labels:
        return name
    inner = ",".join(f'{k}="{escape_label_value(v)}"' for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically-increasing counter (``value`` is writable only through
    the engine's backward-compatible dict view)."""

    __slots__ = ("name", "labels", "value")
    kind = "counter"

    def __init__(self, name: str, labels: LabelItems = ()):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def key(self) -> str:
        return metric_key(self.name, self.labels)


class Gauge:
    """Point-in-time value (set/add)."""

    __slots__ = ("name", "labels", "value")
    kind = "gauge"

    def __init__(self, name: str, labels: LabelItems = ()):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def add(self, v: float) -> None:
        self.value += v

    def key(self) -> str:
        return metric_key(self.name, self.labels)


# Default histogram buckets: log-spaced, wide enough for both sub-ms phase
# timings (seconds) and RIG/result sizes (counts).
_DEFAULT_BUCKETS = tuple(10.0 ** e for e in range(-6, 7))


class Histogram:
    """Cumulative-bucket histogram (le-style, like Prometheus)."""

    __slots__ = ("name", "labels", "buckets", "bucket_counts", "count",
                 "total", "vmin", "vmax")
    kind = "histogram"

    def __init__(self, name: str, labels: LabelItems = (),
                 buckets: Optional[Tuple[float, ...]] = None):
        self.name = name
        self.labels = labels
        self.buckets = tuple(buckets) if buckets else _DEFAULT_BUCKETS
        self.bucket_counts = [0] * (len(self.buckets) + 1)  # +inf overflow
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.bucket_counts[i] += 1
                return
        self.bucket_counts[-1] += 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def key(self) -> str:
        return metric_key(self.name, self.labels)

    def quantile(self, q: float) -> Optional[float]:
        """Bucket-interpolated quantile estimate (``None`` when empty).

        Linear interpolation inside the winning cumulative bucket, with
        both bucket edges clamped to the *observed* ``[vmin, vmax]`` — so
        a single-sample histogram reports the sample itself, and the
        decade-wide default buckets can't report a value outside the data.
        For guaranteed relative error use
        :class:`repro.obs.sketch.QuantileSketch`; this estimate's error is
        bounded by the bucket width."""
        if self.count == 0:
            return None
        q = min(1.0, max(0.0, q))
        rank = q * self.count
        cum = 0
        lower = self.vmin
        for i, b in enumerate(self.buckets):
            c = self.bucket_counts[i]
            if c and cum + c >= rank:
                lo = max(lower, self.vmin)
                hi = min(b, self.vmax)
                if hi < lo:
                    hi = lo
                return lo + (hi - lo) * ((rank - cum) / c)
            cum += c
            lower = b
        return self.vmax                       # +Inf overflow bucket

    def summary(self) -> Dict[str, Any]:
        return {"count": self.count, "sum": self.total, "mean": self.mean,
                "min": None if self.count == 0 else self.vmin,
                "max": None if self.count == 0 else self.vmax,
                "p50": self.quantile(0.5), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}


class MetricsRegistry:
    """Get-or-create registry of labeled metrics with atomic snapshots."""

    def __init__(self) -> None:
        self._metrics: "Dict[Tuple[str, LabelItems], Any]" = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------- factories
    def _get_or_create(self, cls, name: str, labels: Dict[str, Any],
                       **kw) -> Any:
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.get(key)
                if m is None:
                    m = cls(name, key[1], **kw)
                    self._metrics[key] = m
        if not isinstance(m, cls):
            raise TypeError(f"metric {key[0]!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._get_or_create(Counter, name, labels)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._get_or_create(Gauge, name, labels)

    def histogram(self, name: str,
                  buckets: Optional[Tuple[float, ...]] = None,
                  **labels: Any) -> Histogram:
        return self._get_or_create(Histogram, name, labels, buckets=buckets)

    # ------------------------------------------------------------- inspection
    def __iter__(self) -> Iterator[Any]:
        return iter(list(self._metrics.values()))

    def __len__(self) -> int:
        return len(self._metrics)

    def snapshot(self, prefix: Optional[str] = None) -> Dict[str, Any]:
        """Atomic point-in-time copy: series key -> scalar (counter/gauge)
        or summary dict (histogram).  Taken under the registry lock, so a
        caller sees one consistent cut even while other threads mutate."""
        with self._lock:
            metrics: List[Any] = [m for m in self._metrics.values()
                                  if prefix is None
                                  or m.name.startswith(prefix)]
            out: Dict[str, Any] = {}
            for m in metrics:
                out[m.key()] = (m.summary() if isinstance(m, Histogram)
                                else m.value)
        return out

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()


REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry."""
    return REGISTRY
