"""Rotating sliding-window aggregation for serving telemetry.

A :class:`WindowedAggregator` turns the per-request phase timings the
engine already measures into *time-local* series: every observation lands
in the current fixed-width window (aligned to ``window_s`` boundaries of
the injected clock), and each window keeps one
:class:`~repro.obs.sketch.QuantileSketch` per series plus request/error
counts.  ``summary()`` reports per-window QPS, error rate and p50/p95/p99
for every series, and a merged cut over everything retained — the merged
quantiles come from sketch merges, not re-ingestion, so they carry the
same relative-error guarantee as the per-window ones.

Unlike the cumulative :class:`~repro.obs.metrics.Histogram` series
(which answer "since process start"), windows answer the serving
questions: what is p99 *right now*, did the error rate spike *this
window*.  The clock is injectable (same pattern as
:class:`repro.robust.breaker.CircuitBreaker`), so rotation boundaries are
unit-testable without sleeping.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Mapping, Optional

from .sketch import QuantileSketch

__all__ = ["WindowedAggregator"]


class _Window:
    """One fixed-width time slot: per-series sketches + request counts."""

    __slots__ = ("t0", "requests", "errors", "series")

    def __init__(self, t0: float):
        self.t0 = t0
        self.requests = 0
        self.errors = 0
        self.series: Dict[str, QuantileSketch] = {}


class WindowedAggregator:
    """Fixed-width rotating windows of per-series quantile sketches.

    ``observe(phases, error=...)`` records one request: each
    ``series -> seconds`` entry lands in that series' sketch of the
    current window.  Windows rotate lazily on observation/summary (no
    timer thread); at most ``n_windows`` closed windows are retained
    besides the current one.
    """

    def __init__(self, window_s: float = 10.0, n_windows: int = 6,
                 relative_accuracy: float = 0.01,
                 clock: Callable[[], float] = time.monotonic):
        if window_s <= 0:
            raise ValueError(f"window_s must be positive, got {window_s}")
        self.window_s = float(window_s)
        self.n_windows = int(n_windows)
        self.relative_accuracy = relative_accuracy
        self.clock = clock
        self._lock = threading.Lock()
        self._current: Optional[_Window] = None
        self._closed: List[_Window] = []
        self.total_requests = 0            # lifetime, across rotations

    # ------------------------------------------------------------- rotation
    def _aligned(self, now: float) -> float:
        return (now // self.window_s) * self.window_s

    def _advance(self, now: float) -> _Window:
        """Rotate (under the caller's lock) so the current window covers
        ``now``.  A clock jump over several widths closes the old window
        and opens one aligned at ``now`` — intervening empty windows are
        not materialized (each window records its own ``t0``, so gaps stay
        visible in the summary)."""
        t0 = self._aligned(now)
        cur = self._current
        if cur is None:
            self._current = cur = _Window(t0)
        elif t0 > cur.t0:
            self._closed.append(cur)
            if len(self._closed) > self.n_windows:
                del self._closed[:len(self._closed) - self.n_windows]
            self._current = cur = _Window(t0)
        return cur

    # ------------------------------------------------------------ recording
    def observe(self, phases: Mapping[str, float],
                error: bool = False) -> None:
        """Record one request: ``phases`` maps series name (``"total"``,
        ``"exec"``, ...) to its measured seconds."""
        now = self.clock()
        with self._lock:
            win = self._advance(now)
            win.requests += 1
            self.total_requests += 1
            if error:
                win.errors += 1
            for name, v in phases.items():
                sk = win.series.get(name)
                if sk is None:
                    sk = win.series[name] = QuantileSketch(
                        self.relative_accuracy)
                sk.add(v)

    # -------------------------------------------------------------- summary
    def _window_dict(self, win: _Window, span_s: float) -> Dict[str, Any]:
        span_s = max(span_s, 1e-9)
        return {
            "t0": win.t0,
            "requests": win.requests,
            "errors": win.errors,
            "qps": win.requests / span_s,
            "error_rate": (win.errors / win.requests if win.requests
                           else 0.0),
            "series": {name: sk.summary()
                       for name, sk in sorted(win.series.items())},
        }

    def summary(self) -> Dict[str, Any]:
        """Per-window cuts (oldest -> newest, current window last) plus a
        ``merged`` view over everything retained.  The current window's
        QPS uses its elapsed fraction, not the full width, so a summary
        taken mid-window is not biased low."""
        now = self.clock()
        with self._lock:
            cur = self._advance(now)
            windows = [self._window_dict(w, self.window_s)
                       for w in self._closed]
            windows.append(self._window_dict(cur, now - cur.t0))
            merged_series: Dict[str, QuantileSketch] = {}
            requests = errors = 0
            for w in self._closed + [cur]:
                requests += w.requests
                errors += w.errors
                for name, sk in w.series.items():
                    tgt = merged_series.get(name)
                    if tgt is None:
                        merged_series[name] = tgt = QuantileSketch(
                            self.relative_accuracy)
                    tgt.merge(sk)
            oldest_t0 = (self._closed[0].t0 if self._closed else cur.t0)
            elapsed = max(now - oldest_t0, 1e-9)
        return {
            "window_s": self.window_s,
            "windows": windows,
            "merged": {
                "elapsed_s": elapsed,
                "requests": requests,
                "errors": errors,
                "qps": requests / elapsed,
                "error_rate": errors / requests if requests else 0.0,
                "series": {name: sk.summary()
                           for name, sk in sorted(merged_series.items())},
            },
        }

    def summary_line(self, series: str = "total") -> str:
        """One compact human line for periodic printing (the server's
        ``--stats-interval``):

            qps=42.1 err=0.0% total p50=1.1ms p95=3.0ms p99=7.2ms (n=421, 2 windows)
        """
        s = self.summary()
        m = s["merged"]
        sk = m["series"].get(series) or {}

        def ms(v: Optional[float]) -> str:
            return "-" if v is None else f"{v * 1e3:.1f}ms"

        return (f"qps={m['qps']:.1f} err={m['error_rate'] * 100:.1f}% "
                f"{series} p50={ms(sk.get('p50'))} p95={ms(sk.get('p95'))} "
                f"p99={ms(sk.get('p99'))} (n={m['requests']}, "
                f"{len(s['windows'])} windows)")

    # ------------------------------------------------------------ inspection
    def window_count(self) -> int:
        """Retained windows (closed + current, 0 before any observation)."""
        with self._lock:
            return len(self._closed) + (1 if self._current is not None
                                        else 0)

    def clear(self) -> None:
        with self._lock:
            self._current = None
            self._closed = []
            self.total_requests = 0
