"""Structured per-request event records for the flight recorder.

One :class:`QueryEvent` per executed request — the durable, queryable
sibling of the transient ``EngineStats`` object: canonical query key, plan
backend / enum method, phase timings, degradation-ladder steps,
budget/breaker outcomes and the typed status, all JSON-safe scalars.  The
engine emits one for every request on *all three* execution modes
(one-shot, streamed, batched), whether or not the query was profiled.

:class:`BreakerEvent` records circuit-breaker state transitions (the
recorder auto-dumps when one lands on ``open``), and :class:`ServerEvent`
records ``QueryServer`` lifecycle actions that never reach the engine —
admission rejections, journal re-dispatches, terminal give-ups.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, ClassVar, Dict, List

__all__ = ["EVENT_SCHEMA_VERSION", "QueryEvent", "BreakerEvent",
           "ServerEvent", "event_dict"]

EVENT_SCHEMA_VERSION = 2    # v2: ledger byte tags on QueryEvent


def event_dict(event: Any) -> Dict[str, Any]:
    """Normalize anything recordable (an event dataclass or a plain dict)
    into a JSON-ready dict with a ``kind`` discriminator."""
    if isinstance(event, dict):
        return event
    return event.to_dict()


@dataclass
class QueryEvent:
    """One executed request, as the flight recorder stores it."""

    kind: ClassVar[str] = "query"

    ts: float = field(default_factory=time.time)   # wall clock (JSONL reads)
    query_id: int = 0
    key: str = ""                  # canonical query key
    backend: str = ""              # host | device
    enum_method: str = ""
    status: str = "ok"             # stable taxonomy string
    error_type: str = ""           # exception class when status != ok
    count: int = 0
    partial: bool = False
    deadline_exceeded: bool = False
    truncated: bool = False
    overflow_fallback: bool = False
    degradations: List[str] = field(default_factory=list)
    attempts: int = 1
    streamed: bool = False
    chunks: int = 0
    shared_exec: bool = False
    plan_cache_hit: bool = False
    label_cache_hit: bool = False
    rig_nodes: int = 0
    rig_edges: int = 0
    # transfer ledger (PR 10): bytes this request moved host<->device and
    # the device-resident RIG footprint it executed against (0 off-device)
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    resident_bytes: int = 0
    parse_s: float = 0.0
    plan_s: float = 0.0
    exec_s: float = 0.0
    total_s: float = 0.0

    @classmethod
    def from_stats(cls, stats: Any, key: str, count: int) -> "QueryEvent":
        """Build from one finished query's ``EngineStats``."""
        return cls(
            query_id=stats.query_id, key=key, backend=stats.backend,
            enum_method=stats.enum_method, status=stats.status,
            error_type=getattr(stats, "error_type", ""), count=count,
            partial=stats.partial, deadline_exceeded=stats.deadline_exceeded,
            truncated=stats.truncated,
            overflow_fallback=stats.overflow_fallback,
            degradations=list(stats.degradations), attempts=stats.attempts,
            streamed=stats.streamed, chunks=stats.chunks,
            shared_exec=stats.shared_exec,
            plan_cache_hit=stats.plan_cache_hit,
            label_cache_hit=stats.label_cache_hit,
            rig_nodes=stats.rig_nodes, rig_edges=stats.rig_edges,
            h2d_bytes=getattr(stats, "h2d_bytes", 0),
            d2h_bytes=getattr(stats, "d2h_bytes", 0),
            resident_bytes=getattr(stats, "resident_bytes", 0),
            parse_s=stats.parse_s, plan_s=stats.plan_s,
            exec_s=stats.exec_s, total_s=stats.total_s)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "kind": self.kind, "ts": self.ts, "query_id": self.query_id,
            "key": self.key, "backend": self.backend,
            "enum_method": self.enum_method, "status": self.status,
            "error_type": self.error_type, "count": self.count,
            "partial": self.partial,
            "deadline_exceeded": self.deadline_exceeded,
            "truncated": self.truncated,
            "overflow_fallback": self.overflow_fallback,
            "degradations": list(self.degradations),
            "attempts": self.attempts, "streamed": self.streamed,
            "chunks": self.chunks, "shared_exec": self.shared_exec,
            "plan_cache_hit": self.plan_cache_hit,
            "label_cache_hit": self.label_cache_hit,
            "rig_nodes": self.rig_nodes, "rig_edges": self.rig_edges,
            "h2d_bytes": self.h2d_bytes, "d2h_bytes": self.d2h_bytes,
            "resident_bytes": self.resident_bytes,
            "parse_s": self.parse_s, "plan_s": self.plan_s,
            "exec_s": self.exec_s, "total_s": self.total_s,
        }


@dataclass
class BreakerEvent:
    """One circuit-breaker state transition."""

    kind: ClassVar[str] = "breaker"

    old_state: str = ""
    new_state: str = ""
    consecutive_failures: int = 0
    ts: float = field(default_factory=time.time)

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "ts": self.ts,
                "old_state": self.old_state, "new_state": self.new_state,
                "consecutive_failures": self.consecutive_failures}


@dataclass
class ServerEvent:
    """One ``QueryServer`` lifecycle action that bypassed the engine."""

    kind: ClassVar[str] = "server"

    action: str = ""               # reject | redispatch | failed
    rid: int = -1
    attempts: int = 0
    detail: str = ""
    ts: float = field(default_factory=time.time)

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "ts": self.ts, "action": self.action,
                "rid": self.rid, "attempts": self.attempts,
                "detail": self.detail}
