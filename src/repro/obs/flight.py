"""Bounded ring-buffer flight recorder with tail-based exemplar sampling.

A :class:`FlightRecorder` keeps the last ``capacity`` structured event
records (:mod:`repro.obs.events`) in a lock-guarded ring buffer — cheap
enough to stay armed on every request — and dumps them as JSONL on demand
(:meth:`dump_jsonl`) or automatically when something goes wrong:

* **breaker open** — the engine's :class:`~repro.robust.breaker
  .CircuitBreaker` is bound to the recorder; a transition to ``open``
  triggers an auto-dump (the records *leading up to* the incident are
  exactly what a ring buffer preserves);
* **deadline-rate spike** — the recorder tracks the deadline-exceeded
  fraction over the most recent ``rate_window`` query events; crossing
  ``deadline_rate_threshold`` triggers an auto-dump.  Dumps are debounced
  (``min_dump_interval_s``) so a sustained incident produces one file, not
  one per request.

**Tail-based exemplar sampling** keeps *rich* traces for exactly the
requests worth keeping: the slowest ``exemplar_k`` queries (a min-heap on
``total_s``) and every failed query (bounded separately).  The span tree is
materialized lazily — the trace provider callback runs only when an event
actually qualifies — so the common fast+successful request never pays for
trace serialization and ``profile=True`` stays opt-in.
"""

from __future__ import annotations

import heapq
import json
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

from .events import EVENT_SCHEMA_VERSION, event_dict

__all__ = ["FlightRecorder"]

TraceProvider = Callable[[], Optional[Dict[str, Any]]]


class FlightRecorder:
    """Always-on bounded recorder of structured per-request events."""

    def __init__(self, capacity: int = 2048, exemplar_k: int = 8,
                 max_failed_exemplars: int = 32,
                 deadline_rate_threshold: float = 0.5,
                 rate_window: int = 32, rate_min_events: int = 16,
                 min_dump_interval_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.capacity = capacity
        self.exemplar_k = exemplar_k
        self.deadline_rate_threshold = deadline_rate_threshold
        self.rate_min_events = rate_min_events
        self.min_dump_interval_s = min_dump_interval_s
        self.clock = clock
        self._buf: "deque[Any]" = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._seq = 0
        # slowest-k exemplars: min-heap of (total_s, seq, event, trace)
        self._slow: List[tuple] = []
        self._failed: "deque[tuple]" = deque(maxlen=max_failed_exemplars)
        self._recent: "deque[int]" = deque(maxlen=rate_window)
        self._recent_deadlines = 0
        self._autodump_path: Optional[str] = None
        self._last_dump_at: Optional[float] = None
        self.recorded = 0                   # lifetime events (ring overwrites)
        self.autodumps = 0
        self.last_dump_reason: Optional[str] = None

    # ------------------------------------------------------------ recording
    def record(self, event: Any) -> None:
        """Append one event (a dataclass from :mod:`repro.obs.events` or a
        plain dict) to the ring buffer."""
        with self._lock:
            self._buf.append(event)
            self.recorded += 1

    def record_query(self, event: Any,
                     trace_provider: Optional[TraceProvider] = None) -> None:
        """Append one query event, apply tail-based exemplar sampling, and
        run the deadline-rate spike detector.

        ``trace_provider`` is invoked *only* when the event qualifies as an
        exemplar (slowest-k admit, or failed), so the warm path never pays
        for span-tree serialization."""
        total_s = float(getattr(event, "total_s", 0.0))
        status = getattr(event, "status", "ok")
        failed = status != "ok"
        spike = False
        with self._lock:
            self._buf.append(event)
            self.recorded += 1
            self._seq += 1
            # deadline-rate tracker: O(1) running fraction over the last
            # rate_window query events
            flag = 1 if getattr(event, "deadline_exceeded", False) else 0
            if len(self._recent) == self._recent.maxlen:
                self._recent_deadlines -= self._recent[0]
            self._recent.append(flag)
            self._recent_deadlines += flag
            if (flag and len(self._recent) >= self.rate_min_events
                    and self._recent_deadlines
                    >= self.deadline_rate_threshold * len(self._recent)):
                spike = True
            # tail-based exemplars
            if failed:
                trace = trace_provider() if trace_provider else None
                self._failed.append((total_s, self._seq, event, trace))
            elif (len(self._slow) < self.exemplar_k
                    or total_s > self._slow[0][0]):
                trace = trace_provider() if trace_provider else None
                heapq.heappush(self._slow,
                               (total_s, self._seq, event, trace))
                if len(self._slow) > self.exemplar_k:
                    heapq.heappop(self._slow)
        if spike:
            self.maybe_autodump("deadline_rate_spike")

    # ------------------------------------------------------------ inspection
    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)

    def events(self) -> List[Dict[str, Any]]:
        """Snapshot of the buffered events as dicts (oldest first)."""
        with self._lock:
            return [event_dict(e) for e in self._buf]

    def exemplars(self) -> Dict[str, List[Dict[str, Any]]]:
        """Current exemplars: ``slowest`` (descending ``total_s``) and
        ``failed`` (arrival order), each with its retained span tree."""
        with self._lock:
            slow = sorted(self._slow, key=lambda t: -t[0])
            failed = list(self._failed)
        return {
            "slowest": [{"total_s": t, "event": event_dict(e),
                         "trace": tr} for t, _, e, tr in slow],
            "failed": [{"total_s": t, "event": event_dict(e),
                        "trace": tr} for t, _, e, tr in failed],
        }

    def deadline_rate(self) -> float:
        """Deadline-exceeded fraction over the recent-events window."""
        with self._lock:
            return (self._recent_deadlines / len(self._recent)
                    if self._recent else 0.0)

    # -------------------------------------------------------------- dumping
    def dump_jsonl(self, path: str, reason: str = "manual") -> int:
        """Write a JSONL dump: one meta line, then one line per buffered
        event, then one line per exemplar.  Returns lines written."""
        events = self.events()
        ex = self.exemplars()
        with self._lock:
            meta = {
                "kind": "meta", "schema_version": EVENT_SCHEMA_VERSION,
                "reason": reason, "dumped_at": time.time(),
                "events": len(events), "recorded": self.recorded,
                "capacity": self.capacity, "autodumps": self.autodumps,
            }
            self.last_dump_reason = reason
        lines = 1 + len(events)
        with open(path, "w") as f:
            f.write(json.dumps(meta, sort_keys=True) + "\n")
            for e in events:
                f.write(json.dumps(e, sort_keys=True) + "\n")
            for group in ("slowest", "failed"):
                for item in ex[group]:
                    rec = {"kind": "exemplar", "class": group}
                    rec.update(item)
                    f.write(json.dumps(rec, sort_keys=True) + "\n")
                    lines += 1
        return lines

    def arm_autodump(self, path: str) -> "FlightRecorder":
        """Arm incident auto-dumps to ``path`` (breaker-open transitions
        and deadline-rate spikes both write there, debounced)."""
        self._autodump_path = path
        return self

    def maybe_autodump(self, reason: str) -> bool:
        """Dump to the armed path unless within the debounce interval.
        A no-op (returns False) when no path is armed."""
        path = self._autodump_path
        if path is None:
            return False
        now = self.clock()
        with self._lock:
            if (self._last_dump_at is not None
                    and now - self._last_dump_at < self.min_dump_interval_s):
                return False
            self._last_dump_at = now
            self.autodumps += 1
        self.dump_jsonl(path, reason=reason)
        return True
