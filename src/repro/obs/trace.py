"""Hierarchical span tracing for the query lifecycle.

A :class:`Tracer` records a tree of :class:`Span`s — named, wall-clock
timed, arbitrarily nested, with structured attributes — mirroring one
query's pipeline (parse → canonicalize → plan → labels → rig → enumerate
→ materialize).  The engine creates one tracer per profiled query and the
core layers (``repro.core``, ``repro.jaxgm``) accept a ``trace=`` argument
so their phases land as child spans with *measured* timestamps, not
reconstructed ones.

Two tracer flavours share one calling convention:

* :class:`Tracer` — records spans.  ``with trace.span("plan") as sp:``
  opens a child of the innermost open span; ``sp.set(backend="host")``
  attaches attributes; ``trace.add(name, duration_s=...)`` records a
  phase whose work happened elsewhere (a fused batch dispatch's per-query
  share, a lazily-finalized stream).
* :data:`NULL_TRACER` — the disabled path.  ``span()`` returns one shared
  immutable :class:`_NullSpan` singleton: no span objects, no attribute
  dicts, no timestamps are ever allocated, so un-profiled queries pay a
  few no-op method calls and nothing else.  ``Tracer.enabled`` lets hot
  loops skip even attribute construction (``if trace.enabled: ...``).
"""

from __future__ import annotations

import time
from typing import Any, Dict, Iterator, List, Optional

__all__ = ["Span", "Tracer", "NullTracer", "NULL_TRACER"]


class Span:
    """One timed, named node of a trace tree."""

    __slots__ = ("name", "t0", "t1", "attrs", "children", "_tracer",
                 "_duration")

    def __init__(self, name: str, tracer: Optional["Tracer"] = None,
                 t0: Optional[float] = None, t1: Optional[float] = None,
                 duration_s: Optional[float] = None,
                 attrs: Optional[Dict[str, Any]] = None):
        self.name = name
        self._tracer = tracer
        self.t0 = t0
        self.t1 = t1
        self._duration = duration_s
        self.attrs: Dict[str, Any] = attrs or {}
        self.children: List["Span"] = []

    # ------------------------------------------------------- context manager
    def __enter__(self) -> "Span":
        self.t0 = time.perf_counter()
        if self._tracer is not None:
            self._tracer._push(self)
        return self

    def __exit__(self, exc_type=None, exc=None, tb=None) -> bool:
        self.t1 = time.perf_counter()
        if exc_type is not None:
            # a span terminated by an exception carries its cause: the
            # class name plus — for the typed QueryError taxonomy — the
            # stable status string, so failed-request exemplars and
            # error-tagged traces explain themselves
            self.attrs["error"] = exc_type.__name__
            status = getattr(exc, "status", None)
            if isinstance(status, str):
                self.attrs["status"] = status
        if self._tracer is not None:
            self._tracer._pop(self)
        return False

    # --------------------------------------------------------------- content
    @property
    def duration_s(self) -> float:
        if self._duration is not None:
            return self._duration
        if self.t0 is None:
            return 0.0
        t1 = self.t1 if self.t1 is not None else time.perf_counter()
        return t1 - self.t0

    def set(self, **attrs: Any) -> "Span":
        """Attach structured attributes; returns self for chaining."""
        self.attrs.update(attrs)
        return self

    # ------------------------------------------------------------- traversal
    def iter(self) -> Iterator["Span"]:
        """Pre-order traversal of this span and all descendants."""
        yield self
        for c in self.children:
            yield from c.iter()

    def find(self, name: str) -> Optional["Span"]:
        """First span named ``name`` in pre-order (self included)."""
        for s in self.iter():
            if s.name == name:
                return s
        return None

    def find_all(self, name: str) -> List["Span"]:
        return [s for s in self.iter() if s.name == name]

    def phase_names(self) -> List[str]:
        return [s.name for s in self.iter()]

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"name": self.name,
                             "duration_s": self.duration_s}
        if self.attrs:
            d["attrs"] = {k: _jsonable(v) for k, v in self.attrs.items()}
        if self.children:
            d["children"] = [c.to_dict() for c in self.children]
        return d

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, {self.duration_s * 1e3:.2f}ms, "
                f"{len(self.children)} children)")


def _jsonable(v: Any) -> Any:
    """Coerce numpy scalars/arrays and tuples into JSON-friendly values."""
    if hasattr(v, "tolist"):
        return v.tolist()
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


class Tracer:
    """Span recorder for one traced operation (typically one query).

    Spans opened with ``with tracer.span(name):`` nest under the innermost
    open span; the first span opened becomes ``root``.  ``finish()``
    force-closes anything still open (used by lazily-finalized streams)
    and returns the root.
    """

    enabled = True

    def __init__(self, root_name: Optional[str] = None):
        self.root: Optional[Span] = None
        self._stack: List[Span] = []
        if root_name is not None:
            self.span(root_name).__enter__()

    # ------------------------------------------------------------- recording
    def span(self, name: str, **attrs: Any) -> Span:
        return Span(name, tracer=self, attrs=attrs or None)

    def add(self, name: str, duration_s: float = 0.0, **attrs: Any) -> Span:
        """Record an already-completed phase as a child of the innermost
        open span (or as a root-level child)."""
        now = time.perf_counter()
        sp = Span(name, t0=now - duration_s, t1=now, duration_s=duration_s,
                  attrs=attrs or None)
        self._attach(sp)
        return sp

    def current(self) -> Optional[Span]:
        return self._stack[-1] if self._stack else None

    def finish(self) -> Optional[Span]:
        """Close all open spans (innermost first) and return the root."""
        while self._stack:
            self._stack[-1].__exit__(None, None, None)
        return self.root

    # ------------------------------------------------------------- internals
    def _attach(self, sp: Span) -> None:
        if self._stack:
            self._stack[-1].children.append(sp)
        elif self.root is None:
            self.root = sp
        elif self.root is not None:
            self.root.children.append(sp)

    def _push(self, sp: Span) -> None:
        self._attach(sp)
        self._stack.append(sp)

    def _pop(self, sp: Span) -> None:
        # tolerate out-of-order exits (generator finalization): pop through
        while self._stack:
            top = self._stack.pop()
            if top is sp:
                break


class _NullSpan:
    """The shared do-nothing span.  Immutable; every :data:`NULL_TRACER`
    call returns this same object, so the disabled path never allocates."""

    __slots__ = ()
    name = ""
    attrs: Dict[str, Any] = {}
    children: tuple = ()
    t0 = t1 = None
    duration_s = 0.0

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs: Any) -> "_NullSpan":
        return self

    def iter(self):
        return iter(())

    def find(self, name: str) -> None:
        return None

    def find_all(self, name: str) -> list:
        return []

    def to_dict(self) -> dict:
        return {}


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: a singleton that hands out :data:`_NULL_SPAN`."""

    enabled = False
    root = None

    def span(self, name: str, **attrs: Any) -> _NullSpan:
        return _NULL_SPAN

    def add(self, name: str, duration_s: float = 0.0,
            **attrs: Any) -> _NullSpan:
        return _NULL_SPAN

    def current(self) -> None:
        return None

    def finish(self) -> None:
        return None


NULL_TRACER = NullTracer()
