# Observability subsystem: hierarchical query-lifecycle span tracing
# (trace), a process-wide metrics registry with counters / gauges /
# histograms (metrics), and exporters — JSON trace dumps, Prometheus-style
# text, and a compact terminal trace tree (export).  The tracer has a
# zero-allocation no-op path (NULL_TRACER) so instrumented hot paths cost
# nothing when profiling is off.
from .export import prometheus_text, render_trace, trace_to_json
from .metrics import (REGISTRY, Counter, Gauge, Histogram, MetricsRegistry,
                      get_registry)
from .trace import NULL_TRACER, NullTracer, Span, Tracer

__all__ = [
    "Span", "Tracer", "NullTracer", "NULL_TRACER",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "get_registry",
    "trace_to_json", "render_trace", "prometheus_text",
]
