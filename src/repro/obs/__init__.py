# Observability subsystem: hierarchical query-lifecycle span tracing
# (trace), a process-wide metrics registry with counters / gauges /
# histograms (metrics), DDSketch-style relative-error quantile sketches
# (sketch) feeding a rotating sliding-window aggregator with per-window
# QPS / error-rate / p50-p95-p99 (window), a bounded ring-buffer flight
# recorder of structured per-request events with tail-based exemplar
# sampling and incident auto-dumps (events + flight), and exporters —
# JSON trace dumps, Prometheus-style text, and a compact terminal trace
# tree (export).  The tracer has a zero-allocation no-op path
# (NULL_TRACER) so instrumented hot paths cost nothing when profiling is
# off, and the always-on telemetry (events + windows) is bounded-memory
# by construction.
from .events import BreakerEvent, QueryEvent, ServerEvent
from .export import prometheus_text, render_trace, trace_to_json
from .flight import FlightRecorder
from .ledger import (LEDGER, Ledger, ResidentLedger, TransferLedger,
                     get_ledger)
from .metrics import (REGISTRY, Counter, Gauge, Histogram, MetricsRegistry,
                      get_registry)
from .sketch import QuantileSketch
from .trace import NULL_TRACER, NullTracer, Span, Tracer
from .window import WindowedAggregator

__all__ = [
    "Span", "Tracer", "NullTracer", "NULL_TRACER",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "get_registry",
    "QuantileSketch", "WindowedAggregator",
    "QueryEvent", "BreakerEvent", "ServerEvent", "FlightRecorder",
    "trace_to_json", "render_trace", "prometheus_text",
    "TransferLedger", "ResidentLedger", "Ledger", "LEDGER", "get_ledger",
]
