"""DDSketch-style streaming quantile sketch with bounded relative error.

A :class:`QuantileSketch` ingests a stream of floats and answers
``quantile(q)`` with a *relative-error* guarantee: the returned estimate is
within ``relative_accuracy`` of the exact value at the target rank, for any
value distribution and any stream order.  That property (unlike the fixed
log-buckets of :class:`~repro.obs.metrics.Histogram`, whose decade buckets
can be off by 10x inside a bucket) is what makes windowed p50/p95/p99
latency series trustworthy.

Implementation is the classic logarithmic bucketing (Masson et al.,
"DDSketch: a fast and fully-mergeable quantile sketch with relative-error
guarantees", VLDB 2019): values map to bucket ``ceil(log_gamma(v))`` with
``gamma = (1+a)/(1-a)``; every value in bucket ``k`` lies in
``(gamma^(k-1), gamma^k]`` and the bucket's representative
``2*gamma^k/(gamma+1)`` is within ``a`` (relatively) of all of them.
Buckets are a sparse dict, so memory is O(distinct magnitudes) — about
``log(vmax/vmin)/log(gamma)`` entries regardless of stream length.  Zeros
and negatives get their own stores (negatives are sketched on ``-v``), so
arbitrary float streams are safe.

Sketches with the same accuracy merge losslessly (:meth:`merge`), which the
sliding-window aggregator uses to answer "p99 over the last minute" from
per-window sketches without re-ingesting anything.
"""

from __future__ import annotations

import math
from typing import Dict, Optional

__all__ = ["QuantileSketch"]


class QuantileSketch:
    """Relative-error streaming quantiles (DDSketch bucketing, sparse)."""

    __slots__ = ("relative_accuracy", "_gamma", "_log_gamma", "_pos",
                 "_neg", "_zeros", "count", "total", "vmin", "vmax")

    def __init__(self, relative_accuracy: float = 0.01):
        if not 0.0 < relative_accuracy < 1.0:
            raise ValueError("relative_accuracy must be in (0, 1), got "
                             f"{relative_accuracy}")
        self.relative_accuracy = relative_accuracy
        self._gamma = (1.0 + relative_accuracy) / (1.0 - relative_accuracy)
        self._log_gamma = math.log(self._gamma)
        self._pos: Dict[int, int] = {}     # bucket key -> count (v > 0)
        self._neg: Dict[int, int] = {}     # bucket key of -v      (v < 0)
        self._zeros = 0
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    # --------------------------------------------------------------- ingest
    def _key(self, v: float) -> int:
        return math.ceil(math.log(v) / self._log_gamma)

    def _rep(self, key: int) -> float:
        # geometric "middle" of (gamma^(k-1), gamma^k]: within
        # relative_accuracy of every value the bucket can hold
        return 2.0 * self._gamma ** key / (self._gamma + 1.0)

    def add(self, v: float, n: int = 1) -> None:
        if n <= 0 or v != v:                       # drop NaN, keep the
            return                                 # stream un-poisoned
        self.count += n
        self.total += v * n
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if v > 0.0:
            k = self._key(v)
            self._pos[k] = self._pos.get(k, 0) + n
        elif v < 0.0:
            k = self._key(-v)
            self._neg[k] = self._neg.get(k, 0) + n
        else:
            self._zeros += n

    def merge(self, other: "QuantileSketch") -> "QuantileSketch":
        """Fold ``other`` into this sketch (exact: same-bucket counts add).
        Both sketches must share one ``relative_accuracy``."""
        if other.relative_accuracy != self.relative_accuracy:
            raise ValueError(
                "cannot merge sketches with different accuracies "
                f"({self.relative_accuracy} vs {other.relative_accuracy})")
        for k, c in other._pos.items():
            self._pos[k] = self._pos.get(k, 0) + c
        for k, c in other._neg.items():
            self._neg[k] = self._neg.get(k, 0) + c
        self._zeros += other._zeros
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        return self

    # ---------------------------------------------------------------- query
    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> Optional[float]:
        """Value estimate at quantile ``q`` in [0, 1], or ``None`` when
        empty.  The estimate is within ``relative_accuracy`` (relatively)
        of the exact order statistic ``sorted(xs)[floor(q * (n - 1))]``."""
        if self.count == 0:
            return None
        q = min(1.0, max(0.0, q))
        rank = q * (self.count - 1)
        cum = 0
        # negatives first, most negative first (descending magnitude key)
        for k in sorted(self._neg, reverse=True):
            cum += self._neg[k]
            if cum > rank:
                return max(-self._rep(k), self.vmin)
        cum += self._zeros
        if self._zeros and cum > rank:
            return 0.0
        for k in sorted(self._pos):
            cum += self._pos[k]
            if cum > rank:
                # clamp into the observed range: exact extremes beat the
                # bucket representative at the edges
                return min(max(self._rep(k), self.vmin), self.vmax)
        return self.vmax                   # fp rounding on rank: top bucket

    def quantiles(self, qs=(0.5, 0.95, 0.99)) -> Dict[str, Optional[float]]:
        """The standard latency cut: ``{"p50": ..., "p95": ..., "p99": ...}``
        (keys derived from ``qs``)."""
        return {f"p{100 * q:g}": self.quantile(q) for q in qs}

    def summary(self) -> Dict[str, object]:
        out: Dict[str, object] = {
            "count": self.count, "sum": self.total, "mean": self.mean,
            "min": None if self.count == 0 else self.vmin,
            "max": None if self.count == 0 else self.vmax,
        }
        out.update(self.quantiles())
        return out

    def __len__(self) -> int:
        return self.count

    def __repr__(self) -> str:
        return (f"QuantileSketch(a={self.relative_accuracy}, n={self.count}, "
                f"buckets={len(self._pos) + len(self._neg)})")
