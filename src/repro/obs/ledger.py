"""Device memory & transfer ledger.

The paper's central claim is that the RIG is *lightweight* — built
on-the-fly per query, never persisted — which makes host<->device byte
movement and device-resident footprint the real serving costs.  This
module is the process-wide accounting substrate for both:

* :class:`TransferLedger` — byte-exact counters for every h2d / d2h
  transfer, attributed to a named *site* (which dispatch path moved the
  bytes) and a *key* (which graph / tenant they were moved for).  Charged
  bytes equal dispatched bytes: every charge is computed from the same
  :mod:`repro.core.slabgeom` padded-shape geometry the kernels dispatch
  with, at the exact point the transfer happens.
* :class:`ResidentLedger` — live device-resident allocations (the packed
  resident-RIG matrices) with charge/credit semantics and a conservation
  invariant: ``charged_bytes - credited_bytes == live_bytes()`` at all
  times.  A process-wide high-watermark gauge records the worst-case
  resident footprint ever reached.

Both ledgers keep authoritative plain-int state under a lock (cheap
enough for dispatch-rate call sites — device dispatch dwarfs a dict
update) and *publish* into a :class:`~repro.obs.metrics.MetricsRegistry`
on demand, so exposition (``Engine.metrics_text`` / ``prometheus_text``)
always reflects the current totals without the hot path touching metric
objects.

Sites
-----
======================  ====================================================
``slab_ship``           padded ``(F, K, W)`` uint64 constraint slabs shipped
                        by the slab-path :class:`DeviceIntersector` (h2d),
                        and the AND-row / count readback (d2h)
``resident_upload``     one-time packed resident-RIG matrix upload
``index_vectors``       per-level ``(F, K)`` int32 row-index vectors shipped
                        by the resident path (h2d) and count readback (d2h)
``pair_extract_d2h``    device-expand pair pages / accumulator rows fetched
                        back to the host (d2h only)
``label_build``         :func:`device_graph.from_host` label / adjacency /
                        reachability matrix uploads
======================  ====================================================

The transfer side has an arm/disarm lever (:attr:`TransferLedger.enabled`)
so the CI smoke gate can measure ledger-armed overhead against a disarmed
run.  The resident side is *always* armed: charge/credit are rare
lifecycle events (upload / evict) and disarming them would break the
conservation invariant.
"""

from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Optional, Tuple

from .metrics import MetricsRegistry

__all__ = ["SITES", "TransferLedger", "ResidentLedger", "Ledger",
           "LEDGER", "get_ledger"]

#: Known transfer / allocation sites (unknown sites are accepted but these
#: are the ones the engine's dispatch paths charge).
SITES = ("slab_ship", "resident_upload", "index_vectors",
         "pair_extract_d2h", "label_build")

#: Attribution key used when the caller has no graph/tenant identity.
ANON_KEY = "-"


class TransferLedger:
    """Byte counters for h2d / d2h traffic per (site, key)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        # (site, key) -> [bytes, calls]
        self._h2d: Dict[Tuple[str, str], List[int]] = {}
        self._d2h: Dict[Tuple[str, str], List[int]] = {}
        self.enabled: bool = True

    # ------------------------------------------------------------- record
    def h2d(self, site: str, nbytes: int, key: str = ANON_KEY) -> None:
        if not self.enabled or nbytes <= 0:
            return
        with self._lock:
            cell = self._h2d.setdefault((site, key), [0, 0])
            cell[0] += int(nbytes)
            cell[1] += 1

    def d2h(self, site: str, nbytes: int, key: str = ANON_KEY) -> None:
        if not self.enabled or nbytes <= 0:
            return
        with self._lock:
            cell = self._d2h.setdefault((site, key), [0, 0])
            cell[0] += int(nbytes)
            cell[1] += 1

    # -------------------------------------------------------------- query
    @staticmethod
    def _total(table: Dict[Tuple[str, str], List[int]],
               site: Optional[str], key: Optional[str], field: int) -> int:
        return sum(cell[field] for (s, k), cell in table.items()
                   if (site is None or s == site)
                   and (key is None or k == key))

    def h2d_bytes(self, site: Optional[str] = None,
                  key: Optional[str] = None) -> int:
        with self._lock:
            return self._total(self._h2d, site, key, 0)

    def d2h_bytes(self, site: Optional[str] = None,
                  key: Optional[str] = None) -> int:
        with self._lock:
            return self._total(self._d2h, site, key, 0)

    def h2d_calls(self, site: Optional[str] = None,
                  key: Optional[str] = None) -> int:
        with self._lock:
            return self._total(self._h2d, site, key, 1)

    def d2h_calls(self, site: Optional[str] = None,
                  key: Optional[str] = None) -> int:
        with self._lock:
            return self._total(self._d2h, site, key, 1)

    def rows(self) -> List[Tuple[str, str, str, int, int]]:
        """Snapshot: ``(direction, site, key, bytes, calls)`` tuples."""
        with self._lock:
            out = [("h2d", s, k, c[0], c[1])
                   for (s, k), c in self._h2d.items()]
            out += [("d2h", s, k, c[0], c[1])
                    for (s, k), c in self._d2h.items()]
        return sorted(out)

    def reset(self) -> None:
        with self._lock:
            self._h2d.clear()
            self._d2h.clear()

    # ------------------------------------------------------------ publish
    def publish(self, registry: MetricsRegistry) -> None:
        """Sync cumulative totals into ``registry`` (per-site counters,
        aggregated over keys to keep exposition cardinality bounded; the
        per-key breakdown stays available programmatically)."""
        with self._lock:
            per_site: Dict[Tuple[str, str], List[int]] = {}
            for (s, _k), cell in self._h2d.items():
                agg = per_site.setdefault(("h2d", s), [0, 0])
                agg[0] += cell[0]
                agg[1] += cell[1]
            for (s, _k), cell in self._d2h.items():
                agg = per_site.setdefault(("d2h", s), [0, 0])
                agg[0] += cell[0]
                agg[1] += cell[1]
        for (direction, site), (nbytes, calls) in sorted(per_site.items()):
            c = registry.counter(f"ledger_{direction}_bytes", site=site)
            c.value = nbytes
            c = registry.counter(f"ledger_{direction}_calls", site=site)
            c.value = calls


class ResidentLedger:
    """Live device-resident allocations with conservation accounting."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._next_id = 0
        # alloc id -> (key, nbytes)
        self._live: Dict[int, Tuple[str, int]] = {}
        self.charged_bytes = 0
        self.credited_bytes = 0
        self.watermark_bytes = 0
        # keys ever published, so a fully-credited graph's gauge drops to 0
        # instead of silently freezing at its last value
        self._published_keys: set = set()

    # ----------------------------------------------------- charge / credit
    def charge(self, key: str, nbytes: int) -> int:
        """Record ``nbytes`` becoming device-resident for ``key``; returns
        an allocation id to later :meth:`credit`."""
        nbytes = int(nbytes)
        with self._lock:
            self._next_id += 1
            aid = self._next_id
            self._live[aid] = (key, nbytes)
            self.charged_bytes += nbytes
            live = self.charged_bytes - self.credited_bytes
            if live > self.watermark_bytes:
                self.watermark_bytes = live
            return aid

    def credit(self, alloc_id: Optional[int]) -> int:
        """Record the allocation being freed; idempotent (crediting an
        unknown/already-credited id is a no-op returning 0)."""
        if alloc_id is None:
            return 0
        with self._lock:
            entry = self._live.pop(alloc_id, None)
            if entry is None:
                return 0
            self.credited_bytes += entry[1]
            return entry[1]

    # -------------------------------------------------------------- query
    def live_bytes(self, key: Optional[str] = None) -> int:
        with self._lock:
            return sum(n for k, n in self._live.values()
                       if key is None or k == key)

    def per_key(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        with self._lock:
            for k, n in self._live.values():
                out[k] = out.get(k, 0) + n
        return out

    def conserved(self) -> bool:
        """The ledger invariant: every charged byte is either still live
        or has been credited back."""
        with self._lock:
            live = sum(n for _k, n in self._live.values())
            return self.charged_bytes - self.credited_bytes == live

    def reset(self) -> None:
        with self._lock:
            self._live.clear()
            self.charged_bytes = 0
            self.credited_bytes = 0
            self.watermark_bytes = 0
            self._published_keys.clear()

    # ------------------------------------------------------------ publish
    def publish(self, registry: MetricsRegistry) -> None:
        per_key = self.per_key()
        with self._lock:
            charged, credited = self.charged_bytes, self.credited_bytes
            watermark = self.watermark_bytes
            self._published_keys.update(per_key)
            keys = sorted(self._published_keys)
        c = registry.counter("ledger_resident_charged_bytes")
        c.value = charged
        c = registry.counter("ledger_resident_credited_bytes")
        c.value = credited
        registry.gauge("ledger_resident_watermark_bytes").set(watermark)
        registry.gauge("ledger_resident_live_bytes").set(
            charged - credited)
        for k in keys:
            registry.gauge("ledger_resident_live_bytes",
                           graph=k).set(per_key.get(k, 0))


class Ledger:
    """The pair of ledgers behind one handle (``get_ledger()``)."""

    def __init__(self) -> None:
        self.transfers = TransferLedger()
        self.resident = ResidentLedger()

    def publish(self, registry: MetricsRegistry) -> None:
        self.transfers.publish(registry)
        self.resident.publish(registry)

    def reset(self) -> None:
        self.transfers.reset()
        self.resident.reset()

    def arm(self) -> None:
        self.transfers.enabled = True

    def disarm(self) -> None:
        """Disable transfer recording (the dispatch-rate path).  Resident
        charge/credit stay armed — they are rare lifecycle events and the
        conservation invariant must hold regardless."""
        self.transfers.enabled = False

    def rollup(self, key: str) -> Dict[str, int]:
        """Per-graph/tenant byte rollup for ``key`` (serving surface)."""
        return {
            "h2d_bytes": self.transfers.h2d_bytes(key=key),
            "d2h_bytes": self.transfers.d2h_bytes(key=key),
            "resident_live_bytes": self.resident.live_bytes(key=key),
            "resident_watermark_bytes": self.resident.watermark_bytes,
        }


#: Process-global ledger.  Device memory and the intersector singletons are
#: process-wide, so their accounting is too (mirroring ``obs.metrics.REGISTRY``).
LEDGER = Ledger()


def get_ledger() -> Ledger:
    return LEDGER
