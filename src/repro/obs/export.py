"""Exporters for traces and metrics.

* :func:`trace_to_json` — one span tree as a JSON document (the CI
  profile-smoke artifact format).
* :func:`render_trace` — a compact per-query tree for terminal display
  (``Engine.execute(..., profile=True)`` then ``render_trace(res.trace)``).
* :func:`prometheus_text` — the classic ``# TYPE`` + series-per-line text
  exposition of a :class:`~repro.obs.metrics.MetricsRegistry`, served by
  ``launch/serve.py`` as its ``/metrics``-style dump.
"""

from __future__ import annotations

import json
from typing import Any, List, Optional

from .metrics import Histogram, MetricsRegistry, metric_key
from .trace import Span

__all__ = ["trace_to_json", "render_trace", "prometheus_text"]

TRACE_SCHEMA_VERSION = 1


def trace_to_json(span: Span, indent: Optional[int] = 2) -> str:
    payload = {"schema_version": TRACE_SCHEMA_VERSION,
               "trace": span.to_dict() if span is not None else None}
    return json.dumps(payload, indent=indent, sort_keys=True)


def _fmt_val(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    if isinstance(v, (list, tuple)) and len(v) > 8:
        return f"[{', '.join(str(x) for x in v[:8])}, ...x{len(v)}]"
    return str(v)


def _fmt_attrs(span: Span, max_items: int = 6) -> str:
    if not span.attrs:
        return ""
    items = list(span.attrs.items())
    shown = "  ".join(f"{k}={_fmt_val(v)}" for k, v in items[:max_items])
    more = f"  +{len(items) - max_items} attrs" if len(items) > max_items \
        else ""
    return f"  {shown}{more}"


def render_trace(span: Optional[Span], max_attrs: int = 6) -> str:
    """Compact per-query trace tree, one span per line::

        query 35.62ms  key=... backend=host
        ├─ parse 0.08ms
        ├─ plan 0.21ms  backend=host enum=frontier cached=False
        ...
    """
    if span is None:
        return "(no trace: run with profile=True)"
    lines: List[str] = []

    def walk(s: Span, prefix: str, connector: str) -> None:
        lines.append(f"{prefix}{connector}{s.name} "
                     f"{s.duration_s * 1e3:.2f}ms{_fmt_attrs(s, max_attrs)}")
        child_prefix = prefix
        if connector:
            child_prefix += "│  " if connector.startswith("├") else "   "
        for i, c in enumerate(s.children):
            last = i == len(s.children) - 1
            walk(c, child_prefix, "└─ " if last else "├─ ")

    walk(span, "", "")
    return "\n".join(lines)


def prometheus_text(registry: MetricsRegistry) -> str:
    """Prometheus-style text exposition (sorted, stable).

    Histograms emit the full conformant family — cumulative ``_bucket``
    series ending in the mandatory ``le="+Inf"`` (equal to ``_count``),
    plus ``_sum`` and ``_count`` — and additionally ``_quantile`` gauge
    lines carrying the bucket-interpolated p50/p95/p99 estimates, so a
    scrape-less consumer (the CI artifact, a log line) gets latency
    quantiles without doing ``histogram_quantile`` itself.  Label values
    are exposition-escaped by :func:`~repro.obs.metrics.metric_key`."""
    lines: List[str] = []
    metrics = sorted(registry, key=lambda m: (m.name, m.labels))
    seen_type = set()
    for m in metrics:
        if m.name not in seen_type:
            lines.append(f"# TYPE {m.name} {m.kind}")
            seen_type.add(m.name)
        if isinstance(m, Histogram):
            cum = 0
            for b, c in zip(m.buckets, m.bucket_counts):
                cum += c
                labels = m.labels + (("le", f"{b:g}"),)
                lines.append(f"{metric_key(m.name + '_bucket', labels)} "
                             f"{cum}")
            cum += m.bucket_counts[-1]
            labels = m.labels + (("le", "+Inf"),)
            lines.append(f"{metric_key(m.name + '_bucket', labels)} {cum}")
            lines.append(f"{metric_key(m.name + '_sum', m.labels)} "
                         f"{m.total:g}")
            lines.append(f"{metric_key(m.name + '_count', m.labels)} "
                         f"{m.count}")
            qname = m.name + "_quantile"
            for q in (0.5, 0.95, 0.99):
                v = m.quantile(q)
                if v is None:          # empty histogram: no quantile family
                    continue
                if qname not in seen_type:
                    lines.append(f"# TYPE {qname} gauge")
                    seen_type.add(qname)
                labels = m.labels + (("quantile", f"{q:g}"),)
                lines.append(f"{metric_key(qname, labels)} {v:g}")
        else:
            v = m.value
            lines.append(f"{m.key()} {v:g}" if isinstance(v, float)
                         else f"{m.key()} {v}")
    return "\n".join(lines) + ("\n" if lines else "")
