"""Engine benchmark: cold-vs-warm cache latency + planner throughput.

Measures what the ``repro.engine`` subsystem buys over driving the matcher
core directly:

* **cold vs warm** — first query on a freshly resident graph pays label
  construction (reachability closure, packed adjacency, interval labels)
  and planning; repeat queries hit both caches.  Warm latency must be
  strictly below cold (the acceptance criterion for the label cache).
* **planner vs fixed backend** — a mixed workload executed (a) with the
  planner choosing per query, (b) forced onto the host matcher.  (A forced
  device run is informative on real accelerators; under quick/CPU mode the
  jit cost swamps it, so it is gated behind --full.)
* **streaming** — time-to-first-chunk of ``execute_stream`` vs the full
  one-shot materialization on a warm engine (the streaming API's latency
  win), plus the full-drain cost (its overhead bound).
* **batched execute_many vs sequential loop** — a serving-style warm
  workload (a few hot query shapes, many requests) run as N ``execute``
  calls vs one ``execute_many``; the batch path groups by canonical form
  and answers repeats from one execution.

Standalone run writes the machine-readable baseline ``BENCH_engine.json``:

  PYTHONPATH=src python -m benchmarks.bench_engine [--quick|--full] [--out PATH]
"""

from __future__ import annotations

import time
from typing import List

from repro.data.graphs import random_labeled_graph
from repro.engine import Engine, EngineOptions

from ._harness import bench_main
from .common import Row, bench_queries


def _fresh_engine(n, seed=0, **opts):
    g = random_labeled_graph(n, avg_degree=3.0, n_labels=8, seed=seed)
    defaults = dict(materialize=False, device_min_nodes=10**9)
    defaults.update(opts)
    return Engine(g, options=EngineOptions(**defaults)), g


def _time_one(eng, q) -> float:
    t0 = time.perf_counter()
    eng.execute(q)
    return time.perf_counter() - t0


def run(quick: bool = True) -> List[Row]:
    n = 1000 if quick else 10_000
    rows: List[Row] = []

    # ---- cold vs warm cache latency -------------------------------------
    eng, g = _fresh_engine(n)
    text = "(a:L0)-/->(b:L1)-//->(c:L2)"
    cold_s = _time_one(eng, text)
    warm_runs = [_time_one(eng, text) for _ in range(5)]
    warm_s = sorted(warm_runs)[len(warm_runs) // 2]
    ctx = eng.context()
    assert ctx.label_builds == 1, "warm path must not rebuild labels"
    assert warm_s < cold_s, "warm latency must be strictly below cold"
    rows.append(Row("engine_cold_query", cold_s * 1e6,
                    {"graph_nodes": n, "label_build_ms":
                     round(ctx.label_build_s * 1e3, 2)}))
    rows.append(Row("engine_warm_query", warm_s * 1e6,
                    {"graph_nodes": n,
                     "speedup": round(cold_s / warm_s, 1)}))

    # warm with *isomorphic* (renamed) queries: plan cache by canonical form
    iso = "(y:L1)-//->(z:L2), (x:L0)-/->(y)"
    iso_s = _time_one(eng, iso)
    r = eng.execute(iso)
    assert r.stats.plan_cache_hit
    rows.append(Row("engine_warm_isomorphic", iso_s * 1e6,
                    {"plan_cache_hit": True}))

    # warm profiled query: per-phase breakdown from the lifecycle trace
    # (also the measured cost of running with profile=True on a warm path)
    prof = eng.execute(text, profile=True)
    phase_us = {f"us_{s.name}": round(s.duration_s * 1e6, 1)
                for s in prof.trace.children}
    rows.append(Row("engine_warm_profiled", prof.stats.total_s * 1e6,
                    {"unprofiled_us": round(warm_s * 1e6, 1), **phase_us}))

    # ---- streaming: first-chunk latency vs one-shot materialization -----
    eng, g = _fresh_engine(n, seed=1, materialize=True)
    # 4-hop descendant chain: tens of thousands of results in quick mode
    big = "(a:L0)-//->(b:L0)-//->(c:L0)-//->(d:L0)"
    eng.execute(big)                          # warm labels + plan + RIG stats
    full = eng.execute(big)
    full_s = min(_time_one(eng, big) for _ in range(3))
    # prefix consumer: reads one chunk and stops — the tail is never
    # enumerated or materialized (64 resident rows instead of the full set)
    first_s = float("inf")
    first_rows = 0
    for _ in range(3):
        t0 = time.perf_counter()
        stream = eng.execute_stream(big, chunk_size=64)
        first = next(iter(stream), None)
        first_s = min(first_s, time.perf_counter() - t0)
        first_rows = 0 if first is None else len(first)
        stream.close()
    drain_s = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        drained = eng.execute_stream(big)
        total = sum(len(c) for c in drained)
        drain_s = min(drain_s, time.perf_counter() - t0)
    rows.append(Row("engine_stream_first_chunk", first_s * 1e6,
                    {"chunk_rows": first_rows,
                     "enum_method": stream.stats.enum_method,
                     "result_set": full.count,
                     "oneshot_us": round(full_s * 1e6, 1),
                     "first_chunk_speedup": round(full_s / max(first_s, 1e-9),
                                                  1)}))
    rows.append(Row("engine_stream_drain", drain_s * 1e6,
                    {"tuples": total,
                     "chunk_size": drained.stats.chunk_size,
                     "oneshot_us": round(full_s * 1e6, 1)}))

    # ---- resident enumerator: device-capable warm execute + stream ------
    # same graph + query as the streaming section so the host rows above
    # are the direct baseline; upload happens once per query RIG, paged
    # pair pages feed the stream (no slab shipping per level)
    eng_r, _ = _fresh_engine(n, seed=1, materialize=True,
                             force_enum="frontier-device-resident",
                             frontier_device=True)
    eng_r.execute(big)                    # warm labels + plan + jit caches
    res_s = min(_time_one(eng_r, big) for _ in range(3))
    r = eng_r.execute(big)
    t0 = time.perf_counter()
    drained = eng_r.execute_stream(big)
    res_total = sum(len(c) for c in drained)
    res_drain_s = time.perf_counter() - t0
    assert res_total == full.count        # byte-path equivalence smoke
    rows.append(Row("engine_resident_warm", res_s * 1e6, {
        "enum_method": r.stats.enum_method,
        "resident_uploads": eng_r.counters["resident_uploads"],
        "resident_dispatches": eng_r.counters["resident_dispatches"],
        "small_frontier_host_routed":
            eng_r.counters["small_frontier_host_routed"],
        "host_warm_us": round(full_s * 1e6, 1)}))
    rows.append(Row("engine_resident_stream_drain", res_drain_s * 1e6, {
        "tuples": res_total,
        "host_drain_us": round(drain_s * 1e6, 1)}))

    # ---- micro-batched execute_many vs sequential loop ------------------
    # serving-style warm workload: a few hot query shapes, many requests
    distinct = ["(a:L0)-//->(b:L1)", "(a:L1)-//->(b:L2)",
                "(a:L2)-/->(b:L3)-//->(c:L4)", "(a:L5)-//->(b:L6)"]
    requests = [distinct[i % len(distinct)] for i in range(16)]
    eng, _ = _fresh_engine(n, seed=2)
    for q in distinct:                        # warm labels + plans
        eng.execute(q)
    t0 = time.perf_counter()
    for q in requests:
        eng.execute(q)
    loop_s = time.perf_counter() - t0
    shared_before = eng.counters["shared_exec"]
    t0 = time.perf_counter()
    batch = eng.execute_many(requests)
    many_s = time.perf_counter() - t0
    assert all(r.count == s.count
               for r, s in zip(batch, [eng.execute(q) for q in requests]))
    rows.append(Row("engine_many_vs_loop", many_s / len(requests) * 1e6,
                    {"requests": len(requests),
                     "distinct": len(distinct),
                     "shared_exec": eng.counters["shared_exec"]
                     - shared_before,
                     "loop_us_per_query": round(loop_s / len(requests) * 1e6,
                                                1),
                     "speedup_vs_loop": round(loop_s / max(many_s, 1e-9),
                                              1)}))

    # ---- planner vs fixed backend throughput ----------------------------
    workload = bench_queries(
        random_labeled_graph(n, avg_degree=3.0, n_labels=8, seed=0),
        qtype="H", n=6 if quick else 12, seed=0)
    modes = {"planner": {}, "fixed_host": {"force_backend": "host"}}
    if not quick:
        modes["fixed_device"] = {"force_backend": "device",
                                 "device_impl": "reference",
                                 "device_min_nodes": 0}
    for mode, opts in modes.items():
        eng, _ = _fresh_engine(n, **opts)
        eng.execute(workload[0])          # absorb cold label build
        t0 = time.perf_counter()
        results = eng.execute_many(workload)
        dt = time.perf_counter() - t0
        qps = len(workload) / dt
        backends = {}
        for res in results:
            backends[res.stats.backend] = backends.get(res.stats.backend,
                                                       0) + 1
        rows.append(Row(f"engine_many_{mode}", dt / len(workload) * 1e6,
                        {"qps": round(qps, 1), "queries": len(workload),
                         **{f"exec_{k}": v for k, v in backends.items()}}))
    return rows


def main() -> None:
    bench_main("engine", run, default_out="BENCH_engine.json",
               quick_default=True)


if __name__ == "__main__":
    main()
