"""Fig. 10/11 — effect of pattern transitive reduction: GM vs GM-NR on
D-queries constructed with redundant descendant edges."""

from __future__ import annotations

from typing import List

from repro.core import GM, GMOptions
from repro.core.query import DESC, PatternQuery, QueryEdge
from repro.data.queries import random_query_from_graph

from .common import Row, bench_graph, timeit


def _with_transitive_edges(q: PatternQuery) -> PatternQuery:
    """Add the implied descendant edges back (full form) so reduction has
    something to remove — mirrors Fig. 10's redundant D-queries."""
    return q.full_form()


def run(quick: bool = True) -> List[Row]:
    n = 1500 if quick else 50_000
    graph = bench_graph(n=n, avg_degree=2.5, n_labels=8, seed=12)
    rows: List[Row] = []
    for i in range(4 if quick else 10):
        base = random_query_from_graph(graph, 4 + i % 2, qtype="D",
                                       seed=40 + i, extra_edge_prob=0.1)
        q = _with_transitive_edges(base)
        gm = GM(graph, GMOptions(limit=50_000, materialize=False))
        gm_nr = GM(graph, GMOptions(limit=50_000, materialize=False,
                                    use_transitive_reduction=False))
        tr = q.transitive_reduction()
        us = timeit(lambda: gm.match(q), repeats=1)
        rows.append(Row(f"fig11_GM_{base.name}", us,
                        {"edges": q.m, "tr_edges": tr.m}))
        us = timeit(lambda: gm_nr.match(q), repeats=1)
        rows.append(Row(f"fig11_GM-NR_{base.name}", us, {"edges": q.m}))
    return rows
