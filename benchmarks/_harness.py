"""Shared measurement + baseline-JSON harness for the ``bench_*`` modules.

One place for the three things every benchmark used to hand-roll:

* **timing** — :func:`measure` runs a callable N times, recording every
  repeat as a child span of one :class:`repro.obs.Tracer` tree, and
  returns median/min seconds plus the trace (so a benchmark can print the
  same phase tree the engine's ``profile=True`` produces).  The legacy
  :func:`timeit` (median microseconds) is a thin wrapper kept for the
  per-figure modules.
* **rows** — :class:`Row` is the common ``name,us_per_call,derived`` CSV
  record consumed by ``run.py``.
* **baselines** — :func:`write_json` emits the machine-readable
  ``BENCH_*.json`` files with a ``schema_version`` field so downstream
  tooling (CI comparisons, the profile smoke check) can detect layout
  changes, and :func:`bench_main` is the shared argparse front end for the
  modules that write them.
"""

from __future__ import annotations

import argparse
import json
import subprocess
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.obs import Span, Tracer, render_trace

__all__ = ["BENCH_SCHEMA_VERSION", "Row", "Measurement", "measure",
           "timeit", "bench_payload", "write_json", "bench_main",
           "render_trace", "git_sha"]

# bump when the BENCH_*.json layout changes; version 2 added this field,
# version 3 added provenance (git_sha + timestamp) for the regression gate
BENCH_SCHEMA_VERSION = 3


def git_sha(short: int = 12) -> str:
    """Commit SHA of the working tree, or ``"unknown"`` outside a repo.

    Stamped into every baseline so ``BENCH_history.jsonl`` rows are
    attributable to a commit even after the JSON files are overwritten."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", f"--short={short}", "HEAD"],
            capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: Dict[str, Any] = field(default_factory=dict)

    def csv(self) -> str:
        d = ";".join(f"{k}={v}" for k, v in self.derived.items())
        return f"{self.name},{self.us_per_call:.1f},{d}"


@dataclass
class Measurement:
    """One timed configuration: robust statistics + the repeat span tree."""

    median_s: float
    min_s: float
    trace: Span

    @property
    def median_us(self) -> float:
        return self.median_s * 1e6


def measure(fn: Callable, repeats: int = 3, name: str = "bench",
            warmup: int = 0, **attrs: Any) -> Measurement:
    """Time ``fn`` over ``repeats`` runs (after ``warmup`` untimed ones).

    Every repeat is a child span of one tracer tree, so the caller can
    render or serialize the measurement exactly like an engine trace."""
    for _ in range(warmup):
        fn()
    tr = Tracer(name)
    durs: List[float] = []
    for i in range(repeats):
        with tr.span("rep", i=i) as sp:
            fn()
        durs.append(sp.duration_s)
    root = tr.finish()
    durs_sorted = sorted(durs)
    med = durs_sorted[len(durs_sorted) // 2]
    root.set(median_us=round(med * 1e6, 1), repeats=repeats, **attrs)
    return Measurement(median_s=med, min_s=durs_sorted[0], trace=root)


def timeit(fn: Callable, repeats: int = 3) -> float:
    """Median wall time in microseconds (legacy surface)."""
    return measure(fn, repeats=repeats).median_us


def bench_payload(bench: str, mode: str, rows: List[Row]) -> Dict[str, Any]:
    return {
        "schema_version": BENCH_SCHEMA_VERSION,
        "bench": bench,
        "mode": mode,
        "git_sha": git_sha(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "rows": [{"name": r.name, "us_per_call": round(r.us_per_call, 1),
                  "derived": r.derived} for r in rows],
    }


def write_json(path: str, bench: str, mode: str, rows: List[Row]) -> None:
    with open(path, "w") as f:
        json.dump(bench_payload(bench, mode, rows), f, indent=2,
                  sort_keys=True)
        f.write("\n")


def bench_main(bench: str, run: Callable[..., List[Row]], *,
               default_out: str, quick_default: bool = True,
               device_flag: bool = False,
               argv: Optional[List[str]] = None) -> List[Row]:
    """Shared CLI for the baseline-writing benchmarks: parses
    ``--quick/--full[/--device] --out``, runs, prints the CSV, writes the
    versioned JSON baseline.  ``quick_default`` selects which mode an
    unflagged invocation means (the engine bench defaults quick, the mjoin
    bench defaults full)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sizes, CI smoke mode")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes")
    if device_flag:
        ap.add_argument("--device", action="store_true",
                        help="also run the frontier-device (Pallas) path")
    ap.add_argument("--out", default=default_out)
    args = ap.parse_args(argv)
    assert not (args.quick and args.full), "--quick and --full conflict"
    quick = (not args.full) if quick_default else args.quick

    kw: Dict[str, Any] = {"quick": quick}
    if device_flag:
        kw["device"] = args.device
    t0 = time.perf_counter()
    rows = run(**kw)
    dt = time.perf_counter() - t0
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
    mode = "quick" if quick else "full"
    write_json(args.out, bench, mode, rows)
    print(f"# wrote {args.out} ({mode}, {len(rows)} rows, {dt:.1f}s)")
    return rows
