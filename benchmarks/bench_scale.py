"""Fig. 7 — scalability: query time on increasingly larger graph subsets
(DBLP-profile), GM vs TM vs JM."""

from __future__ import annotations

from typing import List

from repro.core import GM, GMOptions
from repro.core.baselines import JMBudgetExceeded, TMTimeout, jm_match, tm_match

from .common import Row, bench_graph, bench_queries, timeit


def run(quick: bool = True) -> List[Row]:
    sizes = (500, 1000, 2000, 4000) if quick else (20_000, 50_000, 100_000,
                                                   300_000)
    rows: List[Row] = []
    for n in sizes:
        graph = bench_graph(n=n, avg_degree=3.3, n_labels=20, kind="uniform",
                            seed=11)
        gm = GM(graph, GMOptions(limit=100_000, materialize=False))
        for q in bench_queries(graph, qtype="H", n=2 if quick else 4, seed=4):
            us = timeit(lambda: gm.match(q), repeats=1)
            rows.append(Row(f"fig7_GM_n{n}_{q.name}", us, {"n": n}))
            for name, fn, exc in (("JM", jm_match, JMBudgetExceeded),
                                  ("TM", tm_match, TMTimeout)):
                try:
                    us = timeit(lambda: fn(graph, q, budget_rows=200_000),
                                repeats=1)
                    rows.append(Row(f"fig7_{name}_n{n}_{q.name}", us,
                                    {"n": n}))
                except exc:
                    rows.append(Row(f"fig7_{name}_n{n}_{q.name}", -1,
                                    {"n": n, "fail": 1}))
    return rows
