"""Bench regression gate: compare fresh BENCH_*.json rows to a baseline.

Rows are matched by ``name``; each match gets a slowdown ratio
``fresh_us / base_us - 1`` and a verdict:

* ``ok``            — within the tolerance band (or faster)
* ``fail``          — an *asserted* row slowed past ``--tolerance``
* ``informational`` — a non-asserted row (or any row when the baseline
  and fresh run used different modes — a committed ``--full`` baseline
  cannot gate a CI ``--quick`` run, so the whole comparison downgrades)
* ``new`` / ``missing`` — a row present on only one side

Only asserted rows (``--assert-rows a,b``) can fail the gate; everything
else is reported for trend-watching.  Rows whose baseline time sits under
``--min-us`` are never failed either — at a few microseconds per call the
ratio is timer noise, not regression signal.  Every comparison can append
one JSONL line (ts, git_sha, bench, mode, per-row timings + verdicts) to
``BENCH_history.jsonl`` so CI accumulates a perf trajectory across
commits even though the JSON baselines are point-in-time snapshots.

Usage::

    PYTHONPATH=src python -m benchmarks.regress \
        --baseline BENCH_engine.json --fresh BENCH_engine_fresh.json \
        --assert-rows engine_warm_query,engine_many_vs_loop \
        --tolerance 2.0 --history BENCH_history.jsonl
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Any, Dict, List, Optional, Sequence

from ._harness import git_sha

__all__ = ["load_payload", "compare", "append_history", "main"]

HISTORY_SCHEMA_VERSION = 1


def load_payload(path: str) -> Dict[str, Any]:
    with open(path) as f:
        payload = json.load(f)
    if "rows" not in payload:
        raise ValueError(f"{path}: not a BENCH payload (no 'rows')")
    return payload


def _row_map(payload: Dict[str, Any]) -> Dict[str, Dict[str, Any]]:
    return {r["name"]: r for r in payload.get("rows", [])}


def compare(baseline: Dict[str, Any], fresh: Dict[str, Any], *,
            tolerance: float = 0.5,
            assert_rows: Sequence[str] = (),
            min_us: float = 50.0) -> Dict[str, Any]:
    """Compare two BENCH payloads; returns a report dict.

    ``tolerance`` is the allowed fractional slowdown for asserted rows
    (0.5 = fresh may be up to 50% slower than baseline).  ``min_us`` is a
    noise floor: asserted rows whose baseline is faster than this are
    reported but cannot fail.
    """
    base_rows = _row_map(baseline)
    fresh_rows = _row_map(fresh)
    mode_mismatch = baseline.get("mode") != fresh.get("mode")
    asserted = set(assert_rows)

    rows: List[Dict[str, Any]] = []
    failures: List[str] = []
    for name in list(base_rows) + [n for n in fresh_rows
                                   if n not in base_rows]:
        b = base_rows.get(name)
        f = fresh_rows.get(name)
        row: Dict[str, Any] = {"name": name}
        if b is None:
            row.update(verdict="new", fresh_us=f["us_per_call"])
        elif f is None:
            row.update(verdict="missing", base_us=b["us_per_call"])
            if name in asserted and not mode_mismatch:
                row["verdict"] = "fail"
                failures.append(f"{name}: asserted row missing from fresh run")
        else:
            base_us = b["us_per_call"]
            fresh_us = f["us_per_call"]
            slowdown = (fresh_us / base_us - 1.0) if base_us > 0 else 0.0
            row.update(base_us=base_us, fresh_us=fresh_us,
                       slowdown=round(slowdown, 4))
            gated = (name in asserted and not mode_mismatch
                     and base_us >= min_us)
            if slowdown <= tolerance:
                row["verdict"] = "ok"
            elif gated:
                row["verdict"] = "fail"
                failures.append(
                    f"{name}: {base_us:.1f}us -> {fresh_us:.1f}us "
                    f"(+{slowdown * 100:.0f}%, tolerance "
                    f"+{tolerance * 100:.0f}%)")
            else:
                row["verdict"] = "informational"
        rows.append(row)

    return {
        "bench": fresh.get("bench", baseline.get("bench", "?")),
        "mode": fresh.get("mode", "?"),
        "baseline_mode": baseline.get("mode", "?"),
        "mode_mismatch": mode_mismatch,
        "tolerance": tolerance,
        "min_us": min_us,
        "asserted": sorted(asserted),
        "rows": rows,
        "failures": failures,
        "ok": not failures,
    }


def append_history(path: str, report: Dict[str, Any],
                   fresh: Dict[str, Any]) -> Dict[str, Any]:
    """Append one JSONL trajectory line for this comparison."""
    line = {
        "schema_version": HISTORY_SCHEMA_VERSION,
        "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "git_sha": fresh.get("git_sha") or git_sha(),
        "bench": report["bench"],
        "mode": report["mode"],
        "ok": report["ok"],
        "mode_mismatch": report["mode_mismatch"],
        "rows": [{k: r[k] for k in
                  ("name", "verdict", "base_us", "fresh_us", "slowdown")
                  if k in r}
                 for r in report["rows"]],
    }
    with open(path, "a") as f:
        f.write(json.dumps(line, sort_keys=True) + "\n")
    return line


def _print_report(report: Dict[str, Any]) -> None:
    head = (f"[regress] bench={report['bench']} "
            f"mode={report['baseline_mode']}->{report['mode']} "
            f"tolerance=+{report['tolerance'] * 100:.0f}%")
    if report["mode_mismatch"]:
        head += "  (mode mismatch: all rows informational)"
    print(head)
    for r in report["rows"]:
        base = f"{r['base_us']:>10.1f}" if "base_us" in r else " " * 10
        fresh = f"{r['fresh_us']:>10.1f}" if "fresh_us" in r else " " * 10
        delta = (f"{r['slowdown'] * 100:+7.1f}%"
                 if "slowdown" in r else " " * 8)
        print(f"  {r['name']:<28} {base} {fresh} {delta}  {r['verdict']}")
    for msg in report["failures"]:
        print(f"[regress] FAIL {msg}")
    if report["ok"]:
        print("[regress] PASS")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_*.json to compare against")
    ap.add_argument("--fresh", required=True,
                    help="freshly produced BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="allowed fractional slowdown (0.5 = +50%%)")
    ap.add_argument("--assert-rows", default="",
                    help="comma-separated row names that may fail the gate")
    ap.add_argument("--min-us", type=float, default=50.0,
                    help="baseline noise floor; faster rows never fail")
    ap.add_argument("--history", default="",
                    help="append a JSONL trajectory line to this path")
    args = ap.parse_args(argv)

    baseline = load_payload(args.baseline)
    fresh = load_payload(args.fresh)
    assert_rows = [r for r in args.assert_rows.split(",") if r]
    report = compare(baseline, fresh, tolerance=args.tolerance,
                     assert_rows=assert_rows, min_us=args.min_us)
    _print_report(report)
    if args.history:
        append_history(args.history, report, fresh)
        print(f"[regress] appended trajectory line to {args.history}")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
