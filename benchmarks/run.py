"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call = -1 marks a failure
case: JM OOM / TM timeout, mirroring the paper's unsolved-query accounting).

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig8a,...]
"""

from __future__ import annotations

import argparse
import sys
import time

from . import (bench_childcheck, bench_engine, bench_kernels, bench_labels,
               bench_mjoin, bench_ordering, bench_queries, bench_rig,
               bench_scale, bench_simulation, bench_transred)

MODULES = {
    "engine": bench_engine,
    "mjoin": bench_mjoin,
    "fig4_5_tab2_queries": bench_queries,
    "fig6_labels": bench_labels,
    "fig7_scale": bench_scale,
    "fig8a_childcheck": bench_childcheck,
    "fig8b_simulation": bench_simulation,
    "fig9_rig": bench_rig,
    "fig10_11_transred": bench_transred,
    "tab3_ordering": bench_ordering,
    "kernels": bench_kernels,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow); default is quick mode")
    ap.add_argument("--only", default=None,
                    help="comma-separated module keys to run")
    args = ap.parse_args()
    keys = list(MODULES) if not args.only else args.only.split(",")

    print("name,us_per_call,derived")
    for key in keys:
        mod = MODULES[key]
        t0 = time.time()
        try:
            rows = mod.run(quick=not args.full)
        except Exception as e:   # a bench failure should not hide the rest
            print(f"{key},-1,error={type(e).__name__}:{e}", flush=True)
            continue
        for r in rows:
            print(r.csv(), flush=True)
        print(f"# {key}: {len(rows)} rows in {time.time() - t0:.1f}s",
              file=sys.stderr, flush=True)


if __name__ == "__main__":
    main()
