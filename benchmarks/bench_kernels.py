"""Kernel-path microbenches (new, TPU adaptation): packed bitmm / closure /
intersect vs their dense jnp references — CPU timings exercise the blocked
implementations; the Pallas kernels are the TPU deployment path (validated
in interpret mode by tests/kernels)."""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, packed, ref

from .common import Row, timeit


def run(quick: bool = True) -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    n = 4096 if quick else 16384
    b = 32
    dense = rng.random((n, n)) < 0.01
    words = jnp.asarray(np.asarray(packed.pack(jnp.asarray(dense))))
    x = jnp.asarray(rng.random((n, b)) < 0.2, jnp.float32)

    for impl in ("blocked", "reference"):
        out = ops.bitmm(words, x, impl=impl)
        jax.block_until_ready(out)
        us = timeit(lambda: jax.block_until_ready(
            ops.bitmm(words, x, impl=impl)), repeats=3)
        rows.append(Row(f"kern_bitmm_{impl}_n{n}", us,
                        {"n": n, "b": b, "GF": 2 * n * n * b / 1e9}))

    m = 1024 if quick else 4096
    cdense = rng.random((m, m)) < 0.01
    cw = jnp.asarray(np.asarray(packed.pack(jnp.asarray(cdense))))
    for impl in ("blocked", "reference"):
        out = ops.closure_step(cw, impl=impl)
        jax.block_until_ready(out)
        us = timeit(lambda: jax.block_until_ready(
            ops.closure_step(cw, impl=impl)), repeats=3)
        rows.append(Row(f"kern_closure_{impl}_n{m}", us, {"n": m}))

    f, k, w = 4096, 4, 512
    rows_in = jnp.asarray(rng.integers(0, 2**32, (f, k, w),
                                       dtype=np.uint64).astype(np.uint32))
    out = ops.intersect(rows_in, impl="reference")
    jax.block_until_ready(out)
    us = timeit(lambda: jax.block_until_ready(
        ops.intersect(rows_in, impl="reference")), repeats=3)
    rows.append(Row(f"kern_intersect_f{f}_k{k}", us, {"f": f, "k": k}))
    return rows
