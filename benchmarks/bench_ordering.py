"""Table 3 — search-ordering strategies: JO vs RI vs BJ on H-queries."""

from __future__ import annotations

from typing import List

from repro.core import GM, GMOptions

from .common import Row, bench_graph, bench_queries, timeit


def run(quick: bool = True) -> List[Row]:
    n = 1500 if quick else 50_000
    graph = bench_graph(n=n, avg_degree=3.0, n_labels=8, seed=13)
    rows: List[Row] = []
    for q in bench_queries(graph, qtype="H", n=5 if quick else 10, seed=14):
        for strategy in ("jo", "ri", "bj"):
            gm = GM(graph, GMOptions(limit=50_000, materialize=False,
                                     ordering=strategy))
            res = gm.match(q)
            us = timeit(lambda: gm.match(q), repeats=1)
            rows.append(Row(f"tab3_{strategy.upper()}_{q.name}", us,
                            {"count": res.count, "order": "-".join(
                                map(str, res.order))}))
    return rows
