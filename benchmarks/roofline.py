"""Roofline derivation from the dry-run manifest (§Roofline).

Hardware model (TPU v5e-like, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  The dry-run records *per-device* quantities from the
partitioned module (cost_analysis + the HLO collective census), so:

    compute    = flops_per_device        / peak_flops
    memory     = hbm_bytes_per_device    / hbm_bw
    collective = wire_bytes_per_device   / link_bw

(equivalently global/(chips·BW) — the global quantities are per-device ×
chips).  The MODEL_FLOPS/HLO_FLOPs ratio flags remat/padding/dispatch waste.

  PYTHONPATH=src python -m benchmarks.roofline [--manifest results/dryrun.json]
      [--csv results/roofline.csv]
"""

from __future__ import annotations

import argparse
import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link


def analyze(rec: dict) -> dict:
    n_dev = rec["n_devices"]
    flops_dev = rec["cost"].get("flops", 0.0)
    bytes_dev = rec["cost"].get("bytes accessed", 0.0)
    wire_dev = rec.get("collective_wire_bytes_per_device", 0.0)
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = wire_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    hlo_global = flops_dev * n_dev
    model = rec.get("model_flops", 0.0)
    useful = model / hlo_global if hlo_global else 0.0
    # roofline fraction: useful model flops per second at the bound, vs peak
    step_time = bound
    mfu = model / (n_dev * PEAK_FLOPS * step_time) if step_time else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "devices": n_dev,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_s": bound,
        "model_flops": model,
        "hlo_flops_global": hlo_global,
        "useful_ratio": useful,
        "roofline_fraction": mfu,
        "hbm_args_GB_per_dev": rec["memory"]["argument_size_in_bytes"] / 1e9,
        "hbm_temp_GB_per_dev": rec["memory"]["temp_size_in_bytes"] / 1e9,
        "fits_16GB": (rec["memory"]["argument_size_in_bytes"]
                      + rec["memory"]["temp_size_in_bytes"]
                      + rec["memory"]["output_size_in_bytes"]) < 16e9,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--manifest", default="results/dryrun.json")
    ap.add_argument("--csv", default="results/roofline.csv")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    with open(args.manifest) as f:
        records = json.load(f)
    rows = [analyze(r) for r in records if r.get("status") == "ok"
            and (args.mesh is None or r["mesh"] == args.mesh)]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))

    hdr = (f"{'arch':<18}{'shape':<15}{'mesh':<9}{'compute':>10}{'memory':>10}"
           f"{'collect':>10}  {'dominant':<11}{'useful':>7}{'roofl%':>8}"
           f"{'fits':>6}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:<18}{r['shape']:<15}{r['mesh']:<9}"
              f"{r['t_compute_s']:>10.2e}{r['t_memory_s']:>10.2e}"
              f"{r['t_collective_s']:>10.2e}  {r['dominant']:<11}"
              f"{r['useful_ratio']:>7.2f}{100 * r['roofline_fraction']:>7.1f}%"
              f"{'  ok' if r['fits_16GB'] else ' OOM!':>6}")

    if args.csv:
        os.makedirs(os.path.dirname(args.csv) or ".", exist_ok=True)
        import csv
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
        print(f"\nwrote {args.csv} ({len(rows)} cells)")


if __name__ == "__main__":
    main()
