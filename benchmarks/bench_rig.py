"""Fig. 9 — RIG size, construction time and total query time for GM vs
GM-S (no prefilter — the default GM here) vs GM-F (prefilter only, no
double simulation).  RIG size reported as % of data-graph size."""

from __future__ import annotations

from typing import List

from repro.core import GM, GMOptions

from .common import Row, bench_graph, bench_queries, timeit


def run(quick: bool = True) -> List[Row]:
    n = 1500 if quick else 75_000
    graph = bench_graph(n=n, avg_degree=3.0, n_labels=8, seed=10)
    gsize = graph.n + graph.n_edges
    variants = {
        "GM": GMOptions(limit=50_000, materialize=False),
        "GM-S": GMOptions(limit=50_000, materialize=False,
                          use_prefilter=False),
        "GM-F": GMOptions(limit=50_000, materialize=False, sim_algo="none",
                          use_prefilter=True),
    }
    rows: List[Row] = []
    for q in bench_queries(graph, qtype="H", n=4 if quick else 12, seed=11):
        for name, opt in variants.items():
            gm = GM(graph, opt)
            res = gm.match(q)
            rig_size = res.rig_nodes + res.rig_edges
            us = timeit(lambda: gm.match(q), repeats=1)
            rows.append(Row(f"fig9_{name}_{q.name}", us, {
                "rig_pct": round(100.0 * rig_size / gsize, 3),
                "rig_nodes": res.rig_nodes,
                "match_ms": round(res.matching_s * 1e3, 2),
                "enum_ms": round(res.enumerate_s * 1e3, 2),
                "count": res.count}))
    return rows
