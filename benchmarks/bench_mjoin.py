"""MJoin enumeration benchmark: backtrack vs frontier (vs frontier-device).

Measures the two halves of the tentpole data path on an enumeration-heavy
workload (>= 10^5 occurrences in standalone mode):

* **RIG build** — vectorized node expansion into the compact
  candidate-local bit matrices (one batched gather + column-compact per
  query edge);
* **enumeration** — the paper's one-tuple-at-a-time backtracking vs the
  frontier-batched enumerator ((F, K, W) gathers, AND-reduce + popcount),
  both counting-only and materializing.

Standalone run writes the machine-readable baseline ``BENCH_mjoin.json``:

  PYTHONPATH=src python -m benchmarks.bench_mjoin [--quick] [--device] \
      [--out PATH]

``--device`` adds the frontier-device path (the intersect Pallas kernel;
interpreter mode off-TPU — only meaningful on real accelerators) and the
frontier-device-resident path (RIG uploaded once, per-level dispatches
ship only (F, K) index vectors; ``h2d_kb_per_run`` records the measured
transfer volume of each).
CI runs quick mode as a smoke step (artifact uploaded, no perf assertion).
"""

from __future__ import annotations

import time
from typing import List

from repro.core.mjoin import mjoin
from repro.core.ordering import get_order
from repro.core.rig import build_rig
from repro.data.graphs import random_labeled_graph
from repro.data.queries import random_query_from_graph
from repro.obs.ledger import get_ledger

from ._harness import bench_main
from .common import Row


def _workload(quick: bool):
    """A dense-answer workload: few labels + descendant edges fan out the
    candidate sets, so enumeration (not RIG build) dominates."""
    n = 600 if quick else 4000
    graph = random_labeled_graph(n, avg_degree=3.0, n_labels=2,
                                 kind="powerlaw", seed=11)
    graph.reachability()
    graph.adj_bits(), graph.adj_bits_t()
    q = random_query_from_graph(graph, n_nodes=4, qtype="D", seed=23,
                                extra_edge_prob=0.3)
    return graph, q


def run(quick: bool = True, device: bool = False) -> List[Row]:
    graph, q = _workload(quick)
    qr = q.transitive_reduction()
    rows: List[Row] = []

    # ---- RIG build (vectorized expansion) -------------------------------
    t0 = time.perf_counter()
    rig = build_rig(graph, qr)
    build_s = time.perf_counter() - t0
    order = get_order(rig, "jo")
    rows.append(Row("mjoin_build_rig", build_s * 1e6,
                    {"rig_nodes": rig.n_nodes(), "rig_edges": rig.n_edges(),
                     "graph_nodes": graph.n}))

    # ---- enumeration ----------------------------------------------------
    limit = None
    methods = ["backtrack", "frontier"]
    if device:
        methods += ["frontier-device", "frontier-device-resident"]
    timings = {}
    counts = {}
    shipped = {}

    ledger = get_ledger().transfers

    def _h2d(method):
        """Cumulative host->device traffic of the method's transfer
        ledger site: ``slab_ship`` for frontier-device's (F, K, W)
        uploads, ``index_vectors`` for the resident path's (F, K) index
        shipping (the one-off ``resident_upload`` matrix transfer is
        reported separately as ``resident_kb``)."""
        if method == "frontier-device":
            return ledger.h2d_bytes(site="slab_ship")
        if method == "frontier-device-resident":
            return ledger.h2d_bytes(site="index_vectors")
        return 0

    for method in methods:
        for mat in (False, True):
            reps = []
            ship0 = _h2d(method)
            for _ in range(2 if quick else 3):
                t0 = time.perf_counter()
                res = mjoin(rig, order, limit=limit, materialize=mat,
                            max_tuples=1_000_000, method=method)
                reps.append(time.perf_counter() - t0)
            dt = sorted(reps)[len(reps) // 2]
            shipped_run = (_h2d(method) - ship0) / len(reps)
            tag = f"mjoin_{method}" + ("_mat" if mat else "_count")
            timings[tag] = dt
            counts[tag] = res.count
            derived = {
                "results": res.count,
                "ran": res.stats.method,
                "truncated": res.stats.truncated,
                "frontier_peak": res.stats.frontier_peak,
                "results_per_s": round(res.count / max(dt, 1e-9))}
            if res.stats.device_calls:
                derived["device_calls"] = res.stats.device_calls
                derived["device_ms"] = round(res.stats.device_s * 1e3, 2)
            if shipped_run:
                shipped[tag] = shipped_run
                derived["h2d_kb_per_run"] = round(shipped_run / 1024, 1)
            if method == "frontier-device-resident" and rig.resident:
                derived["resident_kb"] = round(rig.resident.nbytes / 1024, 1)
                derived["resident_upload_ms"] = round(
                    rig.resident.upload_s * 1e3, 2)
                derived["resident_pages"] = res.stats.resident_pages
            rows.append(Row(tag, dt * 1e6, derived))

    assert len({counts[f"mjoin_{m}_count"] for m in methods}) == 1, counts
    for mode in ("count", "mat"):
        bt, fr = timings[f"mjoin_backtrack_{mode}"], \
            timings[f"mjoin_frontier_{mode}"]
        derived = {"frontier_over_backtrack": round(bt / max(fr, 1e-9), 2)}
        if device:
            # the resident enumerator keeps the RIG on device and ships
            # (F, K) index vectors instead of (F, K, W) packed slabs; the
            # per-run transfer ratio is the machine-independent win (on a
            # CPU-only host both paths end in the same numpy extraction,
            # so wall-clock parity there is expected)
            dv = timings[f"mjoin_frontier-device_{mode}"]
            rs = timings[f"mjoin_frontier-device-resident_{mode}"]
            derived["resident_over_device_time"] = round(dv / max(rs, 1e-9),
                                                         2)
            sd = shipped.get(f"mjoin_frontier-device_{mode}", 0)
            sr = shipped.get(f"mjoin_frontier-device-resident_{mode}", 0)
            if sd and sr:
                derived["resident_over_device"] = round(sd / sr, 2)
        rows.append(Row(f"mjoin_speedup_{mode}", 0.0, derived))
    return rows


def main() -> None:
    bench_main("mjoin", run, default_out="BENCH_mjoin.json",
               quick_default=False, device_flag=True)


if __name__ == "__main__":
    main()
