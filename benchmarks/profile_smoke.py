"""CI profile smoke: lifecycle traces exist, disabled tracing stays free.

Three checks, designed to run on every CI push:

1. **coverage** — one profiled query per execution mode (one-shot,
   streaming, ``execute_many``) must return a span tree containing every
   lifecycle phase (parse → canonicalize → plan → labels → rig →
   enumerate → materialize);
2. **overhead** — warm ``profile=False`` latency is re-measured and
   compared against the ``engine_warm_query`` row of a freshly produced
   ``BENCH_engine.json`` from the same runner (the preceding CI bench
   step): the disabled-tracing path must stay within ``--max-overhead``
   (default 5%).  Cross-machine baselines are meaningless for a wall-clock
   bound, so a missing/foreign baseline downgrades the check to a report;
3. **governance overhead** — the warm path is re-measured with a generous
   armed :class:`~repro.robust.Budget` (deadline + memory caps set but
   never exercised) against the ungoverned path *in the same process*:
   the cooperative checks (one monotonic read per slab/level) must cost
   under ``--max-governance-overhead`` (default 3%).  Same-process A/B, so
   this gate needs no baseline file and always enforces under
   ``--enforce``;
4. **telemetry overhead** — the always-on serving telemetry (one
   :class:`~repro.obs.QueryEvent` into the flight recorder plus four
   sketch inserts into the windowed aggregator per request) is A/B'd the
   same way by toggling ``eng.telemetry`` call-by-call; the armed path
   must stay under ``--max-telemetry-overhead`` (default 3%), and the
   recorder's ring is dumped to ``--flight-out`` as a JSONL artifact;
5. **device timing attribution** (jax only) — ``DeviceIntersector`` /
   ``ResidentIntersector`` must book one-time Pallas/XLA compiles to
   ``compile_s`` and keep ``kernel_s`` as pure fenced per-call device
   time: a repeat dispatch on an already-compiled shape must not grow
   ``compile_s``, and per-call ``kernel_s`` must stay far below the
   shape's compile cost (the regression this guards: the first dispatch
   used to fold its jit into ``kernel_s`` and poison profiles);
6. **ledger attribution & overhead** (jax only) — the transfer ledger must
   show the resident path's architectural win: per-run ``index_vectors``
   traffic at least ``--min-ledger-ratio`` (default 50x) below the slab
   path's ``slab_ship`` traffic, both read as ledger site deltas around
   identical enumerations; and arming the ledger (two dict bumps under a
   lock per dispatch) must cost under ``--max-ledger-overhead`` (default
   3%) vs the disarmed path, A/B'd call-by-call with
   ``LEDGER.arm()``/``disarm()`` around the same compiled dispatch;
7. **artifact** — the one-shot trace tree plus the measurements land in a
   versioned JSON file for upload.

  PYTHONPATH=src python -m benchmarks.profile_smoke \
      [--baseline BENCH_engine.json] [--out TRACE_profile_smoke.json] \
      [--flight-out FLIGHT_profile_smoke.jsonl] \
      [--max-overhead 0.05] [--max-governance-overhead 0.03] \
      [--max-telemetry-overhead 0.03] [--min-ledger-ratio 50] \
      [--max-ledger-overhead 0.03]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from repro.data.graphs import random_labeled_graph
from repro.engine import Budget, Engine, EngineOptions, render_trace
from repro.obs.ledger import get_ledger

LIFECYCLE = {"parse", "canonicalize", "plan", "labels", "rig", "enumerate",
             "materialize"}

# mirror bench_engine's quick-mode cold/warm workload so the committed and
# CI-produced engine_warm_query rows are directly comparable
GRAPH_NODES = 1000
QUERY = "(a:L0)-/->(b:L1)-//->(c:L2)"


def _require_lifecycle(trace, mode: str) -> None:
    assert trace is not None, f"{mode}: profile=True returned no trace"
    missing = LIFECYCLE - set(trace.phase_names())
    assert not missing, f"{mode}: trace missing lifecycle spans {missing}"


def _median_warm_us(eng, query, repeats: int = 40, **kw) -> float:
    """Best-of-3 medians of the warm unprofiled path, in microseconds —
    robust against one noisy scheduling window."""
    meds = []
    for _ in range(3):
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            eng.execute(query, **kw)
            ts.append(time.perf_counter() - t0)
        ts.sort()
        meds.append(ts[len(ts) // 2])
    return min(meds) * 1e6


def _paired_warm_us(eng, query, budget, repeats: int = 60):
    """Interleaved governed/ungoverned warm medians (microseconds).
    Alternating call-by-call makes both variants sample the same noise and
    drift, so the ratio isolates the governance checks themselves."""
    gov, ungov = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        eng.execute(query, budget=budget)
        t1 = time.perf_counter()
        eng.execute(query)
        t2 = time.perf_counter()
        gov.append(t1 - t0)
        ungov.append(t2 - t1)
    gov.sort()
    ungov.sort()
    return gov[len(gov) // 2] * 1e6, ungov[len(ungov) // 2] * 1e6


def _paired_telemetry_us(eng, query, repeats: int = 60):
    """Interleaved telemetry-armed/disarmed warm medians (microseconds),
    flipping the engine's live ``telemetry`` toggle call-by-call so both
    variants sample the same noise window."""
    armed, off = [], []
    for _ in range(repeats):
        eng.telemetry = True
        t0 = time.perf_counter()
        eng.execute(query)
        t1 = time.perf_counter()
        eng.telemetry = False
        eng.execute(query)
        t2 = time.perf_counter()
        armed.append(t1 - t0)
        off.append(t2 - t1)
    eng.telemetry = True
    armed.sort()
    off.sort()
    return armed[len(armed) // 2] * 1e6, off[len(off) // 2] * 1e6


def _paired_ledger_us(dispatch, repeats: int = 60):
    """Interleaved ledger-armed/disarmed dispatch medians (microseconds).
    The only difference between variants is whether the per-dispatch
    byte charges land in the transfer ledger."""
    led = get_ledger()
    armed, off = [], []
    try:
        for _ in range(repeats):
            led.arm()
            t0 = time.perf_counter()
            dispatch()
            t1 = time.perf_counter()
            led.disarm()
            dispatch()
            t2 = time.perf_counter()
            armed.append(t1 - t0)
            off.append(t2 - t1)
    finally:
        led.arm()
    armed.sort()
    off.sort()
    return armed[len(armed) // 2] * 1e6, off[len(off) // 2] * 1e6


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_engine.json",
                    help="bench baseline with an engine_warm_query row, "
                         "produced on THIS machine")
    ap.add_argument("--out", default="TRACE_profile_smoke.json")
    ap.add_argument("--flight-out", default="FLIGHT_profile_smoke.jsonl",
                    help="dump the flight recorder's ring here as JSONL")
    ap.add_argument("--max-overhead", type=float, default=0.05,
                    help="max allowed disabled-tracing warm regression "
                         "vs the baseline (fraction)")
    ap.add_argument("--max-governance-overhead", type=float, default=0.03,
                    help="max allowed warm cost of an armed-but-unexercised "
                         "budget vs the ungoverned path (fraction, "
                         "same-process A/B)")
    ap.add_argument("--max-telemetry-overhead", type=float, default=0.03,
                    help="max allowed warm cost of the always-on telemetry "
                         "(event record + window sketches) vs the disarmed "
                         "path (fraction, same-process A/B)")
    ap.add_argument("--min-ledger-ratio", type=float, default=50.0,
                    help="min required slab_ship/index_vectors per-run h2d "
                         "ratio between the slab and resident device paths "
                         "(ledger site deltas; jax only)")
    ap.add_argument("--max-ledger-overhead", type=float, default=0.03,
                    help="max allowed dispatch cost of the armed transfer "
                         "ledger vs the disarmed path (fraction, "
                         "same-process A/B; jax only)")
    ap.add_argument("--enforce", action="store_true",
                    help="fail (exit 1) when the overhead bound is "
                         "exceeded; default reports only")
    args = ap.parse_args()

    g = random_labeled_graph(GRAPH_NODES, avg_degree=3.0, n_labels=8,
                             seed=0)
    eng = Engine(g, options=EngineOptions(materialize=False,
                                          device_min_nodes=10 ** 9))

    # ---- 1. lifecycle coverage across all three execution modes ---------
    res = eng.execute(QUERY, profile=True)
    _require_lifecycle(res.trace, "execute")
    stream = eng.execute_stream(QUERY, profile=True, chunk_size=256)
    n_stream = sum(len(c) for c in stream)
    _require_lifecycle(stream.trace, "execute_stream")
    batch = eng.execute_many([QUERY, QUERY], profile=True)
    for b in batch:
        _require_lifecycle(b.trace, "execute_many")
    assert batch[1].stats.shared_exec, "duplicate should share execution"
    print("[profile-smoke] lifecycle spans present in all three modes "
          f"(count={res.count}, streamed={n_stream})")
    print(render_trace(res.trace))

    # ---- 2. disabled-tracing overhead vs same-runner baseline -----------
    warm_us = _median_warm_us(eng, QUERY)
    baseline_us = None
    try:
        with open(args.baseline) as f:
            payload = json.load(f)
        for row in payload.get("rows", []):
            if row["name"] == "engine_warm_query":
                baseline_us = float(row["us_per_call"])
                break
    except (OSError, ValueError):
        pass
    overhead = None
    ok = True
    if baseline_us:
        overhead = warm_us / baseline_us - 1.0
        ok = overhead <= args.max_overhead
        print(f"[profile-smoke] warm unprofiled: {warm_us:.1f}us vs "
              f"baseline {baseline_us:.1f}us -> overhead "
              f"{overhead * 100:+.1f}% (bound {args.max_overhead * 100:.0f}%"
              f"{'' if args.enforce else ', report-only'})")
    else:
        print(f"[profile-smoke] no engine_warm_query baseline in "
              f"{args.baseline!r}; measured warm unprofiled "
              f"{warm_us:.1f}us (overhead check skipped)")

    # ---- 3. governance overhead (same-process A/B) ----------------------
    # a generous armed budget: every knob set, none ever exercised, so the
    # measured delta is purely the cooperative checks on the warm path.
    # The two variants are interleaved call-by-call: separate measurement
    # blocks are biased by warm-up drift (the process keeps speeding up),
    # which would be misread as governance cost.
    governed = Budget(deadline_s=3600.0, max_rig_bytes=1 << 40,
                      max_frontier_rows=1 << 30, max_slab_bytes=1 << 40)
    gov_us, ungov_us = _paired_warm_us(eng, QUERY, governed)
    gov_overhead = gov_us / ungov_us - 1.0
    gov_ok = gov_overhead <= args.max_governance_overhead
    print(f"[profile-smoke] warm governed: {gov_us:.1f}us vs ungoverned "
          f"{ungov_us:.1f}us -> governance overhead "
          f"{gov_overhead * 100:+.1f}% "
          f"(bound {args.max_governance_overhead * 100:.0f}%"
          f"{'' if args.enforce else ', report-only'})")

    # ---- 4. telemetry overhead (same-process A/B) -----------------------
    # same interleaving rationale: the only difference between variants is
    # the live `telemetry` toggle, i.e. one QueryEvent into the ring plus
    # four sketch inserts into the current window.
    tel_us, notel_us = _paired_telemetry_us(eng, QUERY)
    tel_overhead = tel_us / notel_us - 1.0
    tel_ok = tel_overhead <= args.max_telemetry_overhead
    print(f"[profile-smoke] warm telemetry-armed: {tel_us:.1f}us vs "
          f"disarmed {notel_us:.1f}us -> telemetry overhead "
          f"{tel_overhead * 100:+.1f}% "
          f"(bound {args.max_telemetry_overhead * 100:.0f}%"
          f"{'' if args.enforce else ', report-only'})")
    if args.flight_out:
        eng.flight.dump_jsonl(args.flight_out, reason="profile_smoke")
        print(f"[profile-smoke] wrote {args.flight_out} "
              f"({len(eng.flight)} events in ring, "
              f"{len(eng.flight.exemplars()['slowest'])} slow exemplars)")

    # ---- 5. device timing attribution: kernel_s excludes compile --------
    try:
        import numpy as np

        from repro.jaxgm.frontier import DeviceIntersector
    except ImportError:
        print("[profile-smoke] jax unavailable; device timing attribution "
              "check skipped")
    else:
        di = DeviceIntersector(mode="xla")
        slab = np.ones((64, 2, 2), dtype=np.uint64)
        di(slab)                                 # first call: compiles
        c1, k1 = di.compile_s, di.kernel_s
        assert c1 > 0, "first dispatch must record its compile"
        di(slab)                                 # repeat: cached executable
        k2 = di.kernel_s - k1
        assert di.compile_s == c1, \
            "repeat dispatch on a compiled shape must not recompile"
        assert k2 < c1, \
            "per-call kernel_s must exclude the shape's compile time"
        print(f"[profile-smoke] device timing attribution: compile "
              f"{c1 * 1e3:.1f}ms (once), repeat kernel {k2 * 1e3:.2f}ms")

    # ---- 6. ledger: resident transfer win + armed overhead --------------
    ledger_ratio = None
    ledger_ratio_ok = True
    ledger_overhead = None
    ledger_ok = True
    try:
        import jax  # noqa: F401

        from repro.core.mjoin import mjoin
        from repro.core.ordering import get_order
        from repro.core.rig import build_rig
        from repro.data.queries import random_query_from_graph
        from repro.jaxgm.frontier import DeviceIntersector
    except ImportError:
        print("[profile-smoke] jax unavailable; ledger attribution checks "
              "skipped")
    else:
        # the architectural win, read off the ledger: the slab path ships
        # padded (F, K, W) bit matrices per level, the resident path ships
        # (F, K) int32 index vectors against the uploaded matrix.  Both
        # enumerate the same workload, so the per-run site deltas are
        # directly comparable.
        led = get_ledger().transfers
        gl = random_labeled_graph(600, avg_degree=3.0, n_labels=2,
                                  kind="powerlaw", seed=11)
        gl.reachability()
        gl.adj_bits(), gl.adj_bits_t()
        ql = random_query_from_graph(gl, n_nodes=4, qtype="D", seed=23,
                                     extra_edge_prob=0.3)
        rigl = build_rig(gl, ql.transitive_reduction())
        orderl = get_order(rigl, "jo")

        def _enum(method):
            return mjoin(rigl, orderl, materialize=False,
                         max_tuples=1_000_000, method=method)

        _enum("frontier-device")                 # warm the compile cache
        s0 = led.h2d_bytes(site="slab_ship")
        _enum("frontier-device")
        slab_run = led.h2d_bytes(site="slab_ship") - s0
        _enum("frontier-device-resident")        # cold: books the upload
        i0 = led.h2d_bytes(site="index_vectors")
        _enum("frontier-device-resident")
        idx_run = led.h2d_bytes(site="index_vectors") - i0
        rigl.release_resident()
        assert slab_run and idx_run, \
            "device enumerations must book ledger transfers"
        ledger_ratio = slab_run / idx_run
        ledger_ratio_ok = ledger_ratio >= args.min_ledger_ratio
        print(f"[profile-smoke] ledger attribution: slab path ships "
              f"{slab_run / 1024:.1f}KB/run vs resident "
              f"{idx_run / 1024:.1f}KB/run -> {ledger_ratio:.0f}x "
              f"(bound >={args.min_ledger_ratio:.0f}x"
              f"{'' if args.enforce else ', report-only'})")

        # armed-vs-disarmed cost of the booking itself, on one compiled
        # dispatch shape (interleaved: same rationale as the gates above)
        dil = DeviceIntersector(mode="xla")
        slabl = np.ones((64, 2, 4), dtype=np.uint64)
        dil(slabl)                               # compile once
        led_us, unled_us = _paired_ledger_us(lambda: dil(slabl))
        ledger_overhead = led_us / unled_us - 1.0
        ledger_ok = ledger_overhead <= args.max_ledger_overhead
        print(f"[profile-smoke] dispatch ledger-armed: {led_us:.1f}us vs "
              f"disarmed {unled_us:.1f}us -> ledger overhead "
              f"{ledger_overhead * 100:+.1f}% "
              f"(bound {args.max_ledger_overhead * 100:.0f}%"
              f"{'' if args.enforce else ', report-only'})")

    # profiled cost is informational: profiling is opt-in per query
    t0 = time.perf_counter()
    for _ in range(10):
        eng.execute(QUERY, profile=True)
    prof_us = (time.perf_counter() - t0) / 10 * 1e6
    print(f"[profile-smoke] warm profiled: {prof_us:.1f}us "
          f"({prof_us / warm_us:.2f}x unprofiled)")

    # ---- 7. artifact ----------------------------------------------------
    artifact = {
        "schema_version": 3,
        "trace": res.trace.to_dict(),
        "warm_unprofiled_us": round(warm_us, 1),
        "warm_profiled_us": round(prof_us, 1),
        "baseline_us": baseline_us,
        "overhead": None if overhead is None else round(overhead, 4),
        "max_overhead": args.max_overhead,
        "warm_governed_us": round(gov_us, 1),
        "governance_overhead": round(gov_overhead, 4),
        "max_governance_overhead": args.max_governance_overhead,
        "warm_telemetry_us": round(tel_us, 1),
        "telemetry_overhead": round(tel_overhead, 4),
        "max_telemetry_overhead": args.max_telemetry_overhead,
        "ledger_ratio": None if ledger_ratio is None
        else round(ledger_ratio, 1),
        "min_ledger_ratio": args.min_ledger_ratio,
        "ledger_overhead": None if ledger_overhead is None
        else round(ledger_overhead, 4),
        "max_ledger_overhead": args.max_ledger_overhead,
        "count": res.count,
    }
    with open(args.out, "w") as f:
        json.dump(artifact, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[profile-smoke] wrote {args.out}")

    failed = []
    if not ok:
        failed.append("disabled-tracing overhead above bound")
    if not gov_ok:
        failed.append("governance overhead above bound")
    if not tel_ok:
        failed.append("telemetry overhead above bound")
    if not ledger_ratio_ok:
        failed.append("resident transfer ratio below bound")
    if not ledger_ok:
        failed.append("ledger overhead above bound")
    if failed and args.enforce:
        for msg in failed:
            print(f"[profile-smoke] FAIL: {msg}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
