"""Shared workload builders for the paper-figure benchmarks.

Every bench module exposes ``run(quick: bool) -> list[Row]``; ``run.py``
executes them all and prints ``name,us_per_call,derived`` CSV (one line per
measured configuration), mirroring the paper's per-query reporting.
``Row`` and the timing helpers live in :mod:`benchmarks._harness` (built on
``repro.obs``) and are re-exported here for the per-figure modules.
"""

from __future__ import annotations

from repro.data.graphs import random_labeled_graph
from repro.data.queries import random_query_from_graph, template_queries

from ._harness import Measurement, Row, measure, timeit  # noqa: F401


_GRAPH_CACHE: dict = {}


def bench_graph(n=2000, avg_degree=3.0, n_labels=8, kind="powerlaw", seed=0):
    key = (n, avg_degree, n_labels, kind, seed)
    if key not in _GRAPH_CACHE:
        g = random_labeled_graph(n, avg_degree=avg_degree, n_labels=n_labels,
                                 kind=kind, seed=seed)
        g.reachability()          # build the index once, like BFL in §7.1
        g.adj_bits(), g.adj_bits_t()
        _GRAPH_CACHE[key] = g
    return _GRAPH_CACHE[key]


def bench_queries(graph, qtype="H", n=8, seed=0):
    """Mostly subgraph-sampled queries (guaranteed satisfiable, like the
    paper's biology sets) plus a few label-randomized templates (these can
    have empty answers — the paper's HQ19 case, caught early by the RIG)."""
    qs = [random_query_from_graph(graph, 4 + i % 3, qtype=qtype,
                                  seed=seed + 10 + i,
                                  extra_edge_prob=0.4)
          for i in range(max(n - 2, 1))]
    qs += template_queries(graph, qtype=qtype, seed=seed)[:2]
    return qs[:n]
