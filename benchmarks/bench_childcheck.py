"""Fig. 8(a) — child-constraint checking methods: binSearch vs bitIter vs
bitBat (+ the TPU path's batched-matmul form of bitBat)."""

from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np

from repro.core.simulation import fb_sim_bas
from repro.kernels import ops, packed

from .common import Row, bench_graph, bench_queries, timeit


def run(quick: bool = True) -> List[Row]:
    n = 2000 if quick else 20_000
    graph = bench_graph(n=n, avg_degree=4.0, n_labels=8, seed=5)
    queries = bench_queries(graph, qtype="C", n=4 if quick else 12, seed=6)
    rows: List[Row] = []
    for q in queries:
        for method in ("binsearch", "bititer", "bitbat"):
            us = timeit(lambda: fb_sim_bas(graph, q, method=method,
                                           max_passes=4), repeats=2)
            res = fb_sim_bas(graph, q, method=method, max_passes=4)
            rows.append(Row(f"fig8a_{method}_{q.name}", us,
                            {"pruned": res.pruned}))
        # TPU-path form: one batched matmul per pass direction (bitmm)
        adj = graph.adj_bits()
        w32 = packed.pack_numpy_u64_to_u32(adj)
        n_pad = ((graph.n + 511) // 512) * 512
        aw = np.zeros((n_pad, n_pad // 32), np.uint32)
        aw[:graph.n, :w32.shape[1]] = w32
        fb = np.zeros((n_pad, q.n), np.float32)
        for i in range(q.n):
            fb[:graph.n, i] = graph.label_mask(q.labels[i])
        aw_j, fb_j = jnp.asarray(aw), jnp.asarray(fb)
        out = ops.bitmm(aw_j, fb_j, impl="blocked")
        out.block_until_ready()
        us = timeit(lambda: ops.bitmm(aw_j, fb_j,
                                      impl="blocked").block_until_ready(),
                    repeats=2)
        rows.append(Row(f"fig8a_bitmm_{q.name}", us, {"form": "matmul"}))
    return rows
