"""Fig. 4 / Fig. 5 / Table 2 — GM vs TM vs JM across H/C/D query sets.

Reports per-query evaluation time for the three algorithms plus their
failure modes (JM out-of-memory budget, TM tree-solution budget), matching
the paper's solved/unsolved accounting.
"""

from __future__ import annotations

from typing import List

from repro.core import GM, GMOptions
from repro.core.baselines import (JMBudgetExceeded, TMTimeout, jm_match,
                                  tm_match)

from .common import Row, bench_graph, bench_queries, timeit


LIMIT = 100_000          # result cap (paper uses 10^7; scaled for quick mode)


def run(quick: bool = True) -> List[Row]:
    n = 1200 if quick else 20_000
    budget = 200_000 if quick else 5_000_000
    rows: List[Row] = []
    for qtype in ("C", "H", "D"):
        graph = bench_graph(n=n, avg_degree=2.5, n_labels=8, seed=3)
        gm = GM(graph, GMOptions(limit=LIMIT, materialize=False))
        queries = bench_queries(graph, qtype=qtype,
                                n=6 if quick else 20, seed=1)
        for q in queries:
            res = gm.match(q)
            us = timeit(lambda: gm.match(q), repeats=1)
            rows.append(Row(f"fig4_GM_{qtype}_{q.name}", us,
                            {"count": res.count, "rig": res.rig_nodes,
                             "solved": 1}))
            try:
                jm = jm_match(graph, q, budget_rows=budget)
                us = timeit(lambda: jm_match(graph, q, budget_rows=budget),
                            repeats=1)
                rows.append(Row(f"fig4_JM_{qtype}_{q.name}", us,
                                {"count": jm.count, "solved": 1,
                                 "max_inter": jm.max_intermediate}))
            except JMBudgetExceeded:
                rows.append(Row(f"fig4_JM_{qtype}_{q.name}", -1,
                                {"solved": 0, "fail": "OOM"}))
            try:
                tm = tm_match(graph, q, budget_rows=budget)
                us = timeit(lambda: tm_match(graph, q, budget_rows=budget),
                            repeats=1)
                rows.append(Row(f"fig4_TM_{qtype}_{q.name}", us,
                                {"count": tm.count, "solved": 1,
                                 "tree_sols": tm.tree_solutions}))
            except TMTimeout:
                rows.append(Row(f"fig4_TM_{qtype}_{q.name}", -1,
                                {"solved": 0, "fail": "TO"}))
    return rows
