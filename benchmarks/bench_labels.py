"""Fig. 6 — query time vs number of distinct labels (email-profile graph,
fixed size, |L| ∈ {5, 10, 15, 20})."""

from __future__ import annotations

from typing import List

from repro.core import GM, GMOptions
from repro.core.baselines import JMBudgetExceeded, TMTimeout, jm_match, tm_match

from .common import Row, bench_graph, bench_queries, timeit


def run(quick: bool = True) -> List[Row]:
    n = 1500 if quick else 50_000
    rows: List[Row] = []
    for n_labels in (5, 10, 15, 20):
        graph = bench_graph(n=n, avg_degree=1.6, n_labels=n_labels,
                            kind="powerlaw", seed=7)
        gm = GM(graph, GMOptions(limit=100_000, materialize=False))
        for q in bench_queries(graph, qtype="H", n=3 if quick else 6, seed=2):
            us = timeit(lambda: gm.match(q), repeats=1)
            rows.append(Row(f"fig6_GM_L{n_labels}_{q.name}", us,
                            {"labels": n_labels}))
            for name, fn, exc in (("JM", jm_match, JMBudgetExceeded),
                                  ("TM", tm_match, TMTimeout)):
                try:
                    us = timeit(lambda: fn(graph, q, budget_rows=200_000),
                                repeats=1)
                    rows.append(Row(f"fig6_{name}_L{n_labels}_{q.name}", us,
                                    {"labels": n_labels}))
                except exc:
                    rows.append(Row(f"fig6_{name}_L{n_labels}_{q.name}", -1,
                                    {"labels": n_labels, "fail": 1}))
    return rows
