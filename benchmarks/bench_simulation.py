"""Fig. 8(b) — double-simulation construction: Bas vs Dag vs DagMap
(+ convergence pass counts)."""

from __future__ import annotations

from typing import List

from repro.core.simulation import fb_sim, fb_sim_bas

from .common import Row, bench_graph, bench_queries, timeit


def run(quick: bool = True) -> List[Row]:
    n = 2000 if quick else 20_000
    graph = bench_graph(n=n, avg_degree=3.0, n_labels=8, seed=8)
    rows: List[Row] = []
    for q in bench_queries(graph, qtype="H", n=5 if quick else 12, seed=9):
        variants = (
            ("Bas", lambda: fb_sim_bas(graph, q)),
            ("Dag", lambda: fb_sim(graph, q, use_change_flags=False)),
            ("DagMap", lambda: fb_sim(graph, q, use_change_flags=True)),
        )
        for name, fn in variants:
            res = fn()
            us = timeit(fn, repeats=2)
            rows.append(Row(f"fig8b_{name}_{q.name}", us,
                            {"passes": res.passes, "checks": res.checks,
                             "pruned": res.pruned}))
    return rows
