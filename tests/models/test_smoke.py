"""Per-arch smoke tests: every assigned architecture instantiates a REDUCED
config and runs one real forward/train step on CPU (shape + finiteness)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_ids, get_config


@pytest.mark.parametrize("arch_id", all_arch_ids())
def test_smoke(arch_id):
    cfg = get_config(arch_id)
    out = cfg.smoke(seed=0)
    assert out["finite"], out
    if "loss" in out:
        assert np.isfinite(out["loss"])


def test_lm_smoke_shapes():
    cfg = get_config("yi-34b")
    out = cfg.smoke(seed=1)
    assert out["logits_shape"] == (2, 16, 256)
    assert out["decode_shape"] == (2, 1, 256)


def test_moe_smoke_runs_routing():
    out = get_config("deepseek-moe-16b").smoke(seed=2)
    assert out["finite"]


def test_lm_loss_decreases_under_training():
    """A few steps of AdamW on the tiny config must reduce loss."""
    import jax
    from repro.data.tokens import TokenPipeline, TokenPipelineConfig
    from repro.models import transformer as tf
    from repro.train import optimizer as opt

    cfg = get_config("qwen2-7b").smoke_config()
    params = tf.init_params(cfg, jax.random.key(0))
    state = opt.init_state(params)
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                           weight_decay=0.0)
    pipe = TokenPipeline(TokenPipelineConfig(vocab=cfg.vocab, batch=8,
                                             seq_len=32, seed=0))
    step = jax.jit(lambda p, s, b: opt.apply_updates(
        p, jax.grad(tf.loss_fn)(p, b, cfg), s, ocfg))
    first = float(tf.loss_fn(params, jax.tree.map(jnp.asarray,
                                                  pipe.batch_at(0)), cfg))
    for i in range(40):
        batch = jax.tree.map(jnp.asarray, pipe.batch_at(i))
        params, state, _ = step(params, state, batch)
    last = float(tf.loss_fn(params, jax.tree.map(jnp.asarray,
                                                 pipe.batch_at(100)), cfg))
    assert last < first - 0.2, (first, last)


def test_gnn_sampled_batch_trains():
    import jax
    from repro.data.sampler import random_csr_graph, sampled_batch
    from repro.models import gnn
    from repro.train import optimizer as opt

    arch = get_config("graphsage-reddit")
    cfg = arch.smoke_config()
    g = random_csr_graph(400, avg_deg=6, d_feat=cfg.d_feat,
                         n_classes=cfg.n_classes, seed=0)
    params = gnn.init_params(cfg, jax.random.key(0))
    state = opt.init_state(params)
    ocfg = opt.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=40,
                           weight_decay=0.0)
    batch0 = jax.tree.map(jnp.asarray, sampled_batch(g, 32, (5, 3), 0))
    step = jax.jit(lambda p, s, b: opt.apply_updates(
        p, jax.grad(gnn.loss_fn)(p, b, cfg), s, ocfg))
    first = float(gnn.loss_fn(params, batch0, cfg))
    for i in range(25):
        b = jax.tree.map(jnp.asarray, sampled_batch(g, 32, (5, 3), i))
        params, state, _ = step(params, state, b)
    last = float(gnn.loss_fn(params, batch0, cfg))
    assert np.isfinite(last) and last < first, (first, last)


def test_din_loss_decreases():
    import jax
    from repro.data.recsys_data import din_batch
    from repro.models import recsys
    from repro.train import optimizer as opt

    cfg = get_config("din").smoke_config()
    params = recsys.init_params(cfg, jax.random.key(0))
    state = opt.init_state(params)
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=60,
                           weight_decay=0.0)

    def make(i):
        return jax.tree.map(jnp.asarray, din_batch(
            64, cfg.seq_len, cfg.n_items, cfg.n_cates, cfg.n_user_feats,
            cfg.user_feat_vocab, step=i))

    step = jax.jit(lambda p, s, b: opt.apply_updates(
        p, jax.grad(recsys.loss_fn)(p, b, cfg), s, ocfg))
    first = float(recsys.loss_fn(params, make(1000), cfg))
    for i in range(40):
        params, state, _ = step(params, state, make(i))
    last = float(recsys.loss_fn(params, make(1000), cfg))
    assert last < first, (first, last)


def test_decode_matches_forward():
    """Decode with a KV cache must reproduce teacher-forced logits."""
    import jax
    from repro.models import transformer as tf

    cfg = get_config("qwen2-7b").smoke_config()   # has qkv_bias + GQA
    params = tf.init_params(cfg, jax.random.key(3))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 10)), jnp.int32)
    full = tf.forward(params, tokens, cfg)        # (2, 10, V)
    cache = tf.init_cache(cfg, 2, 16)
    outs = []
    for i in range(10):
        logits, cache = tf.decode_step(params, cache, tokens[:, i:i + 1], cfg)
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)
