"""§Perf variants must be *exact* re-implementations: flash attention ==
dense attention; chunked CE == plain CE (forward and gradients)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tf


def _cfg(**kw):
    base = get_config("yi-34b").smoke_config()
    return dataclasses.replace(base, n_layers=2, d_model=32, n_heads=4,
                               n_kv=2, d_head=8, d_ff=64, vocab=128, **kw)


def test_flash_attention_matches_dense():
    cfg_d = _cfg()
    cfg_f = _cfg(flash_attention=True, kv_chunk=8)
    params = tf.init_params(cfg_d, jax.random.key(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg_d.vocab, (2, 32)), jnp.int32)
    out_d = tf.forward(params, tokens, cfg_d)
    out_f = tf.forward(params, tokens, cfg_f)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_d),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_grads_match():
    cfg_d = _cfg()
    cfg_f = _cfg(flash_attention=True, kv_chunk=8)
    params = tf.init_params(cfg_d, jax.random.key(1))
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, 128, (2, 32)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 128, (2, 32)), jnp.int32)}
    g_d = jax.grad(tf.loss_fn)(params, batch, cfg_d)
    g_f = jax.grad(tf.loss_fn)(params, batch, cfg_f)
    for a, b in zip(jax.tree.leaves(g_d), jax.tree.leaves(g_f)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=5e-2, atol=5e-3)


def test_chunked_loss_matches_plain():
    cfg_p = _cfg()
    cfg_c = _cfg(chunked_loss=True, loss_chunk=8)
    params = tf.init_params(cfg_p, jax.random.key(2))
    rng = np.random.default_rng(2)
    batch = {"tokens": jnp.asarray(rng.integers(0, 128, (2, 32)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 128, (2, 32)), jnp.int32)}
    l_p = float(tf.loss_fn(params, batch, cfg_p))
    l_c = float(tf.loss_fn(params, batch, cfg_c))
    assert abs(l_p - l_c) < 1e-4, (l_p, l_c)
    g_p = jax.grad(tf.loss_fn)(params, batch, cfg_p)
    g_c = jax.grad(tf.loss_fn)(params, batch, cfg_c)
    for a, b in zip(jax.tree.leaves(g_p), jax.tree.leaves(g_c)):
        np.testing.assert_allclose(np.asarray(b, np.float32),
                                   np.asarray(a, np.float32),
                                   rtol=2e-3, atol=1e-4)


def test_combined_variants_train_step_finite():
    cfg = _cfg(flash_attention=True, kv_chunk=8, chunked_loss=True,
               loss_chunk=8)
    params = tf.init_params(cfg, jax.random.key(3))
    rng = np.random.default_rng(3)
    batch = {"tokens": jnp.asarray(rng.integers(0, 128, (2, 32)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 128, (2, 32)), jnp.int32)}
    loss, grads = jax.value_and_grad(tf.loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))
