"""Query-language round-trip and parse-error tests."""

import numpy as np
import pytest

from repro.core import CHILD, DESC, PatternQuery, QueryEdge, query
from repro.core.query import paper_example_query
from repro.data.graphs import random_labeled_graph
from repro.data.queries import random_query_from_graph, template_queries
from repro.engine import QueryParseError, Vocab, fmt, parse
from repro.testing import given, settings, st


# ------------------------------------------------------------- round trips
def _strip_name(q: PatternQuery) -> PatternQuery:
    return PatternQuery(labels=list(q.labels), edges=list(q.edges))


def _arbitrary_query(rng: np.random.Generator, n_max: int = 7) -> PatternQuery:
    """A structurally arbitrary (possibly disconnected, multi-segment)
    normalized pattern — broader than the subgraph-sampled generators."""
    n = int(rng.integers(1, n_max + 1))
    labels = [int(x) for x in rng.integers(0, 5, size=n)]
    edges = [(s, d, int(rng.integers(0, 2)))
             for s in range(n) for d in range(n)
             if s != d and rng.random() < 0.3]
    return query(labels, edges)


def test_round_trip_simple_chain():
    q = parse("(a:L0)-/->(b:L1)-//->(c:L2)")
    assert q.labels == [0, 1, 2]
    assert q.edges == [QueryEdge(0, 1, CHILD), QueryEdge(1, 2, DESC)]
    assert fmt(q) == "(a:L0)-/->(b:L1)-//->(c:L2)"
    assert parse(fmt(q)) == q


def test_round_trip_paper_example():
    q = _strip_name(paper_example_query())
    assert parse(fmt(q)) == q


def test_round_trip_needs_declarations():
    # the only edge points 1 -> 0: chain emission alone would re-index
    q = query(labels=[3, 5], edges=[(1, 0, CHILD)])
    q = _strip_name(q)
    text = fmt(q)
    assert parse(text) == q
    assert text.startswith("(a:L3)")          # node 0 declared first


def test_round_trip_single_node():
    q = PatternQuery(labels=[2], edges=[])
    assert fmt(q) == "(a:L2)"
    assert parse(fmt(q)) == q


def test_round_trip_templates_and_random():
    g = random_labeled_graph(200, avg_degree=3.0, n_labels=6, seed=0)
    qs = template_queries(g, qtype="H", seed=1)
    qs += [random_query_from_graph(g, 3 + i % 3, qtype=["C", "H", "D"][i % 3],
                                   seed=i) for i in range(9)]
    for q in qs:
        q = _strip_name(q)
        assert parse(fmt(q)) == q, fmt(q)


@given(st.integers(0, 10_000), st.sampled_from(["C", "H", "D"]),
       st.integers(3, 6))
@settings(max_examples=25, deadline=None)
def test_round_trip_property(seed, qtype, n_nodes):
    g = random_labeled_graph(150, avg_degree=3.0, n_labels=5, seed=0)
    q = _strip_name(random_query_from_graph(g, n_nodes, qtype=qtype,
                                            seed=seed))
    assert parse(fmt(q)) == q


@given(st.integers(0, 10**6))
@settings(max_examples=50, deadline=None)
def test_round_trip_property_arbitrary_structure(seed):
    """parse(fmt(q)) == q for arbitrary generated patterns, including
    disconnected ones and shapes whose chain decomposition needs explicit
    node declarations."""
    q = _arbitrary_query(np.random.default_rng(seed))
    assert parse(fmt(q)) == q, fmt(q)


@pytest.mark.parametrize("seed", range(25))
def test_round_trip_arbitrary_structure_examples(seed):
    # the bare-interpreter (no hypothesis) slice of the property above
    q = _arbitrary_query(np.random.default_rng(seed))
    assert parse(fmt(q)) == q, fmt(q)


def test_reverse_edge_syntax():
    q = parse("(a:L0)<-/-(b:L1)<-//-(c:L2)")
    assert q.edges == [QueryEdge(1, 0, CHILD), QueryEdge(2, 1, DESC)]


def test_re_mention_merges_and_child_subsumes_desc():
    q = parse("(a:L0)-/->(b:L1), (a)-//->(b)")
    # PatternQuery dedups: child subsumes descendant on the same pair
    assert q.edges == [QueryEdge(0, 1, CHILD)]


def test_named_vocab_round_trip():
    v = Vocab(names=["Person", "City", "Country"])
    q = parse("(a:Person)-/->(b:City)-//->(c:Country)", vocab=v)
    assert q.labels == [0, 1, 2]
    assert fmt(q, vocab=v) == "(a:Person)-/->(b:City)-//->(c:Country)"
    assert parse(fmt(q, vocab=v), vocab=v) == q


# ------------------------------------------------------------ parse errors
def _err(text, vocab=None):
    with pytest.raises(QueryParseError) as ei:
        parse(text, vocab=vocab)
    return str(ei.value)


def test_error_unknown_label():
    msg = _err("(a:Person)-/->(b:City)", vocab=Vocab(names=["City"]))
    assert "unknown label 'Person'" in msg
    assert "City" in msg                       # lists known labels
    assert "^" in msg                          # caret display


def test_error_label_out_of_graph_space():
    g = random_labeled_graph(50, n_labels=4, seed=0)
    msg = _err("(a:L7)-/->(b:L0)", vocab=Vocab.for_graph(g))
    assert "unknown label 'L7'" in msg


def test_error_missing_label_on_first_mention():
    msg = _err("(a)-/->(b:L1)")
    assert "needs a label on first mention" in msg


def test_error_relabeled_node():
    msg = _err("(a:L0)-/->(b:L1), (a:L2)-//->(b)")
    assert "relabeled" in msg


def test_error_bad_edge_token():
    msg = _err("(a:L0)-/=>(b:L1)")
    assert "unexpected character" in msg


def test_error_self_loop():
    msg = _err("(a:L0)-/->(a)")
    assert "self-loop" in msg


def test_error_dangling_edge():
    msg = _err("(a:L0)-/->")
    assert "expected '('" in msg


def test_error_empty():
    with pytest.raises(QueryParseError):
        parse("   ")


def test_error_missing_comma():
    msg = _err("(a:L0)-/->(b:L1) (c:L2)-/->(b)")
    assert "','" in msg


def test_vocab_rejects_invalid_names():
    with pytest.raises(ValueError, match="not a valid identifier"):
        Vocab(names=["my label"])
    with pytest.raises(ValueError, match="shadows the generic"):
        Vocab(names={"L0": 1})
    Vocab(names={"L1": 1})                     # consistent generic name: ok
