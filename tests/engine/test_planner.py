"""Canonicalization and planner-selection tests."""

import numpy as np
import pytest

from repro.core import CHILD, DESC, query
from repro.core.query import PatternQuery, paper_example_query
from repro.data.graphs import random_labeled_graph
from repro.data.queries import random_query_from_graph
from repro.engine import DeviceCaps, GraphStats, Planner, RigStats
from repro.engine import canonical_form, canonical_key, parse
from repro.engine.planner import (STREAM_CHUNK_MAX, STREAM_CHUNK_MIN, Plan)
from repro.testing import given, settings, st


# --------------------------------------------------------- canonical form
def test_canonical_key_invariant_under_renaming():
    q1 = parse("(a:L0)-/->(b:L1)-//->(c:L2)")
    q2 = parse("(x:L1)<-/-(w:L0), (x)-//->(y:L2)")   # same pattern, renamed
    assert canonical_key(q1) == canonical_key(q2)


def test_canonical_key_reduces_transitive_edges():
    q = paper_example_query()
    assert canonical_key(q) == canonical_key(q.full_form())


def test_canonical_key_distinguishes_kinds_and_labels():
    a = canonical_key(query([0, 1], [(0, 1, CHILD)]))
    b = canonical_key(query([0, 1], [(0, 1, DESC)]))
    c = canonical_key(query([0, 2], [(0, 1, CHILD)]))
    assert len({a, b, c}) == 3


def test_canonical_form_is_isomorphic():
    q = parse("(a:L1)-/->(b:L0), (c:L1)-/->(b)")
    cq, perm = canonical_form(q)
    assert sorted(cq.labels) == sorted(q.labels)
    assert cq.m == q.transitive_reduction().m
    # perm maps old -> new consistently
    for e in q.transitive_reduction().edges:
        assert any(ce.src == perm[e.src] and ce.dst == perm[e.dst]
                   and ce.kind == e.kind for ce in cq.edges)


def test_canonical_form_idempotent():
    q = parse("(a:L0)-//->(b:L1), (c:L0)-/->(b), (a)-//->(c)")
    cq, _ = canonical_form(q)
    cq2, _ = canonical_form(cq)
    assert cq == cq2


def _relabeled(q: PatternQuery, perm) -> PatternQuery:
    """Apply a node renaming (perm[old] = new) and re-normalize."""
    labels = [0] * q.n
    for old, new in enumerate(perm):
        labels[new] = q.labels[old]
    return query(labels, [(perm[e.src], perm[e.dst], e.kind)
                          for e in q.edges])


def _random_small_query(rng: np.random.Generator) -> PatternQuery:
    g = random_labeled_graph(100, avg_degree=3.0, n_labels=4,
                             seed=int(rng.integers(0, 50)))
    n = int(rng.integers(2, 6))
    return random_query_from_graph(g, n, qtype=["C", "H", "D"][n % 3],
                                   seed=int(rng.integers(0, 10**6)))


def _random_dag_query(rng: np.random.Generator) -> PatternQuery:
    """Random *acyclic* pattern (edges go index-upward only): the class for
    which the transitive reduction — and therefore the full cache key — is
    unique up to isomorphism."""
    n = int(rng.integers(2, 7))
    labels = [int(x) for x in rng.integers(0, 4, size=n)]
    edges = [(s, d, int(rng.integers(0, 2)))
             for s in range(n) for d in range(s + 1, n)
             if rng.random() < 0.4]
    edges = edges or [(0, n - 1, DESC)]
    return query(labels, edges)


def _check_relabel_invariance(rng, reduce):
    q = _random_dag_query(rng) if reduce else _random_small_query(rng)
    q2 = _relabeled(q, rng.permutation(q.n).tolist())
    assert canonical_key(q, reduce=reduce) == canonical_key(q2,
                                                            reduce=reduce)


@given(st.integers(0, 10**6))
@settings(max_examples=50, deadline=None)
def test_canonical_key_invariant_under_relabeling_property(seed):
    """Node-relabeled isomorphic queries share one canonical key: exactly
    for any pattern with n <= 6 when no reduction is applied, and
    end-to-end (TR + canonicalization — the plan-cache key) for acyclic
    patterns, where the transitive reduction is unique.  (Isomorphic
    *cyclic* patterns may reduce to non-isomorphic forms and cost a
    duplicate cache entry — a documented, harmless miss.)"""
    rng = np.random.default_rng(seed)
    _check_relabel_invariance(rng, reduce=False)
    _check_relabel_invariance(rng, reduce=True)


@pytest.mark.parametrize("seed", range(20))
def test_canonical_key_invariant_under_relabeling_examples(seed):
    # the bare-interpreter (no hypothesis) slice of the property above
    rng = np.random.default_rng(seed)
    _check_relabel_invariance(rng, reduce=False)
    _check_relabel_invariance(rng, reduce=True)


# --------------------------------------------------------------- planning
def _stats(n_graph, n_labels=8, avg_degree=3.0, seed=0):
    g = random_labeled_graph(n_graph, avg_degree=avg_degree,
                             n_labels=n_labels, seed=seed)
    return GraphStats.collect(g)


def test_backend_small_graph_goes_host():
    p = Planner(_stats(100)).plan(parse("(a:L0)-/->(b:L1)-//->(c:L2)"))
    assert p.backend == "host"
    assert any("below device threshold" in r for r in p.reasons)


def test_backend_large_graph_goes_device():
    p = Planner(_stats(2000)).plan(parse("(a:L0)-/->(b:L1)-//->(c:L2)"))
    assert p.backend == "device"


def test_backend_wide_query_goes_host_even_on_large_graph():
    labels = list(range(8)) + [0]
    edges = [(i, i + 1, CHILD) for i in range(8)]          # 9 nodes > max_q=8
    p = Planner(_stats(2000)).plan(query(labels, edges))
    assert p.backend == "host"
    assert any("exceeds device caps" in r for r in p.reasons)


def test_backend_forced():
    p = Planner(_stats(100), force_backend="device").plan(
        parse("(a:L0)-/->(b:L1)"))
    assert p.backend == "device"


def test_sim_algo_tiny_vs_regular():
    planner = Planner(_stats(100))
    assert planner.plan(parse("(a:L0)-/->(b:L1)")).sim_algo == "bas"
    q = parse("(a:L0)-/->(b:L1)-//->(c:L2), (a)-//->(d:L3)-/->(c)")
    assert planner.plan(q).sim_algo == "dagmap"


def test_check_method_sparse_huge_graph():
    s = _stats(1000)
    s.n = 1 << 18                         # pretend: huge graph ...
    s.label_counts = {l: 10 for l in s.label_counts}   # ... sparse labels
    p = Planner(s).plan(parse("(a:L0)-/->(b:L1)-//->(c:L2)"))
    assert p.check_method == "bititer"
    assert Planner(_stats(1000)).plan(
        parse("(a:L0)-/->(b:L1)-//->(c:L2)")).check_method == "bitbat"


def test_cost_model_orders_by_label_frequency():
    s = _stats(1000)
    rare = min(s.label_counts, key=s.label_counts.get)
    common = max(s.label_counts, key=s.label_counts.get)
    q_rare = query([rare, rare], [(0, 1, DESC)])
    q_common = query([common, common], [(0, 1, DESC)])
    assert (s.estimate_cost(q_rare) < s.estimate_cost(q_common))


def test_refine_tiny_rig_switches_to_host():
    planner = Planner(_stats(2000))
    q = parse("(a:L0)-/->(b:L1)-//->(c:L2)")
    plan = planner.plan(q)
    assert plan.backend == "device"
    rig = RigStats()
    rig.observe(rig_nodes=5, rig_edges=4, sim_passes=2, matching_s=0.0,
                enumerate_s=0.0, count=1)
    refined = planner.refine(plan, q, rig)
    assert refined.backend == "host"
    # ... but an explicitly forced backend is never overridden
    forced = Planner(_stats(2000), force_backend="device")
    assert forced.refine(forced.plan(q), q, rig).backend == "device"


def test_refine_keeps_device_for_large_rig():
    planner = Planner(_stats(2000))
    q = parse("(a:L0)-/->(b:L1)-//->(c:L2)")
    plan = planner.plan(q)
    rig = RigStats()
    rig.observe(rig_nodes=900, rig_edges=4000, sim_passes=2, matching_s=0.0,
                enumerate_s=0.0, count=12345)
    assert planner.refine(plan, q, rig).backend == "device"


def test_plan_gm_options_realize_choices():
    p = Planner(_stats(100)).plan(parse("(a:L0)-/->(b:L1)-//->(c:L2)"))
    opts = p.gm_options(materialize=True)
    assert opts.sim_algo == p.sim_algo
    assert opts.check_method == p.check_method
    assert opts.materialize
    assert not opts.use_transitive_reduction   # engine reduces before GM


# ------------------------------------------------------------- enum method
def test_enum_method_small_card_backtracks():
    p = Planner(_stats(1000)).plan(parse("(a:L0)-/->(b:L1)"))
    assert p.enum_method == "backtrack"


def test_enum_method_large_card_goes_frontier():
    s = _stats(1000)
    s.label_counts = {l: 400 for l in s.label_counts}   # dense match sets
    p = Planner(s).plan(query([0, 1, 2], [(0, 1, DESC), (1, 2, DESC)]))
    assert p.est_card >= 4096
    assert p.enum_method == "frontier"
    assert any("frontier" in r for r in p.reasons)


def test_refine_large_rig_picks_frontier():
    planner = Planner(_stats(2000))
    q = parse("(a:L0)-/->(b:L1)-//->(c:L2)")
    plan = planner.plan(q)
    assert plan.enum_method == "backtrack"
    rig = RigStats()
    rig.observe(rig_nodes=900, rig_edges=4000, sim_passes=2, matching_s=0.0,
                enumerate_s=0.0, count=100)
    refined = planner.refine(plan, q, rig)
    assert refined.enum_method == "frontier"
    # realized in GMOptions
    assert refined.gm_options().enum_method == "frontier"


def test_refine_many_results_picks_frontier():
    planner = Planner(_stats(2000))
    q = parse("(a:L0)-/->(b:L1)-//->(c:L2)")
    rig = RigStats()
    rig.observe(rig_nodes=50, rig_edges=200, sim_passes=2, matching_s=0.0,
                enumerate_s=0.0, count=1_000_000)
    assert planner.refine(planner.plan(q), q, rig).enum_method == "frontier"


def test_refine_tiny_rig_reverts_to_backtrack():
    s = _stats(1000)
    s.label_counts = {l: 400 for l in s.label_counts}
    planner = Planner(s)
    q = query([0, 1, 2], [(0, 1, DESC), (1, 2, DESC)])
    plan = planner.plan(q)
    assert plan.enum_method == "frontier"
    rig = RigStats()
    rig.observe(rig_nodes=8, rig_edges=10, sim_passes=2, matching_s=0.0,
                enumerate_s=0.0, count=3)
    assert planner.refine(plan, q, rig).enum_method == "backtrack"


# -------------------------------------------------------------- chunk size
def test_pick_chunk_size_bounds_and_monotonicity():
    planner = Planner(_stats(1000))
    assert planner.pick_chunk_size(0) == STREAM_CHUNK_MIN
    assert planner.pick_chunk_size(10**12) == STREAM_CHUNK_MAX
    sizes = [planner.pick_chunk_size(x) for x in (10, 1e3, 1e5, 1e7)]
    assert sizes == sorted(sizes)
    assert all(s & (s - 1) == 0 for s in sizes)        # powers of two


def test_plan_and_refine_set_chunk_size():
    planner = Planner(_stats(1000))
    q = parse("(a:L0)-/->(b:L1)-//->(c:L2)")
    plan = planner.plan(q)
    assert plan.chunk_size == planner.pick_chunk_size(plan.est_card)
    rig = RigStats()
    rig.observe(rig_nodes=50, rig_edges=200, sim_passes=2, matching_s=0.0,
                enumerate_s=0.0, count=1_000_000)
    refined = planner.refine(plan, q, rig)
    assert refined.chunk_size == planner.pick_chunk_size(1_000_000)


def test_force_enum():
    planner = Planner(_stats(1000), force_enum="frontier")
    q = parse("(a:L0)-/->(b:L1)")
    plan = planner.plan(q)
    assert plan.enum_method == "frontier"
    rig = RigStats()
    rig.observe(rig_nodes=2, rig_edges=1, sim_passes=1, matching_s=0.0,
                enumerate_s=0.0, count=1)
    assert planner.refine(plan, q, rig).enum_method == "frontier"


def test_batch_group_lanes():
    s = _stats(2000)
    q = parse("(a:L0)-/->(b:L1)-//->(c:L2)")
    assert Planner(s).plan(q).batch_group() == "device"
    assert Planner(s, force_backend="host").plan(q).batch_group() == "host"
    fd = Planner(s, force_backend="host",
                 force_enum="frontier-device").plan(q)
    assert fd.batch_group() == "frontier-device"


def test_frontier_device_caps_flag():
    s = _stats(2000)
    q = parse("(a:L0)-/->(b:L1)-//->(c:L2)")
    rig = RigStats()
    rig.observe(rig_nodes=900, rig_edges=4000, sim_passes=2, matching_s=0.0,
                enumerate_s=0.0, count=100)
    # estimated resident footprint fits the default device budget: the
    # frontier upgrade keeps the whole index on device ...
    planner = Planner(s, caps=DeviceCaps(frontier_device=True))
    plan = planner.refine(planner.plan(q), q, rig)
    assert plan.enum_method == "frontier-device-resident"
    assert plan.small_frontier_rows > 0
    # on the host backend the resident method batches in the
    # frontier-device lane (same per-level scheduler, different transport)
    lane = Plan(backend="host", sim_algo="dagmap", check_method="bitbat",
                enum_method="frontier-device-resident")
    assert lane.batch_group() == "frontier-device"
    # ... while an over-budget estimate falls back to per-level slabs
    tight = Planner(s, caps=DeviceCaps(frontier_device=True,
                                       resident_max_bytes=1024))
    assert tight.refine(tight.plan(q), q, rig).enum_method == \
        "frontier-device"
