"""Engine facade: GM-equivalence, caching behaviour, batched execution."""

import numpy as np
import pytest

from repro.core import GM, GMOptions
from repro.data.graphs import random_labeled_graph
from repro.data.queries import random_query_from_graph
from repro.engine import Engine, EngineOptions, QueryParseError, fmt, parse
from repro.testing import given, settings, st


def _host_engine(g, **kw):
    # device_min_nodes high: keep these tests on the host path (fast, no jit)
    return Engine(g, options=EngineOptions(device_min_nodes=10**9,
                                           materialize=False, **kw))


# ----------------------------------------------------- acceptance: GM parity
def test_execute_text_equals_hand_built_query():
    """Acceptance: Engine.execute('(a:L0)-/->(b:L1)-//->(c:L2)') returns the
    same match count as the equivalent PatternQuery run through GM."""
    g = random_labeled_graph(400, avg_degree=3.0, n_labels=4, seed=7)
    eng = _host_engine(g)
    text = "(a:L0)-/->(b:L1)-//->(c:L2)"
    res = eng.execute(text)
    want = GM(g, GMOptions(materialize=False)).match(parse(text)).count
    assert res.count == want > 0


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("qtype", ["C", "H", "D"])
def test_engine_matches_gm_on_random_graphs(seed, qtype):
    g = random_labeled_graph(250, avg_degree=3.0, n_labels=5, seed=seed)
    gm = GM(g, GMOptions(materialize=False))
    eng = _host_engine(g)
    for i in range(3):
        q = random_query_from_graph(g, 3 + i, qtype=qtype, seed=10 * seed + i)
        res = eng.execute(fmt(q))              # through the text pipeline
        assert res.count == gm.match(q).count


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_engine_matches_gm_property(seed):
    g = random_labeled_graph(150, avg_degree=3.0, n_labels=4, seed=1)
    q = random_query_from_graph(g, 3 + seed % 3,
                                qtype=["C", "H", "D"][seed % 3], seed=seed)
    eng = _host_engine(g)
    assert eng.execute(q).count == \
        GM(g, GMOptions(materialize=False)).match(q).count


def test_execute_materializes_tuples():
    g = random_labeled_graph(200, avg_degree=3.0, n_labels=4, seed=3)
    eng = Engine(g, options=EngineOptions(device_min_nodes=10**9))
    res = eng.execute("(a:L0)-//->(b:L1)")
    assert res.tuples is not None and res.tuples.shape == (res.count, 2)


# ------------------------------------------------------------- label cache
def test_label_cache_second_query_skips_construction():
    g = random_labeled_graph(300, avg_degree=3.0, n_labels=5, seed=0)
    eng = _host_engine(g)
    r1 = eng.execute("(a:L0)-//->(b:L1)")
    assert not r1.stats.label_cache_hit        # cold: labels built here
    ctx = eng.context()
    assert ctx.label_builds == 1
    oracle_before = ctx.oracle
    intervals_before = ctx.intervals
    r2 = eng.execute("(a:L2)-/->(b:L3)-//->(c:L4)")   # different query!
    assert r2.stats.label_cache_hit
    assert ctx.label_builds == 1               # no re-construction
    assert ctx.oracle is oracle_before         # same reachability labeling
    assert ctx.intervals is intervals_before   # same interval labels
    assert eng.counters["label_builds"] == 1


def test_label_cache_per_graph():
    g1 = random_labeled_graph(200, n_labels=4, seed=0)
    g2 = random_labeled_graph(200, n_labels=4, seed=1)
    eng = _host_engine(g1)
    eng.execute("(a:L0)-//->(b:L1)")
    r = eng.execute("(a:L0)-//->(b:L1)", graph=g2)
    assert not r.stats.label_cache_hit         # g2 is cold
    assert eng.counters["label_builds"] == 2
    assert eng.execute("(a:L1)-/->(b:L2)", graph=g2).stats.label_cache_hit


# -------------------------------------------------------------- plan cache
def test_plan_cache_hits_on_isomorphic_requery():
    g = random_labeled_graph(300, avg_degree=3.0, n_labels=5, seed=0)
    eng = _host_engine(g)
    r1 = eng.execute("(a:L0)-/->(b:L1)-//->(c:L2)")
    assert not r1.stats.plan_cache_hit
    # same pattern, different node names and segment order
    r2 = eng.execute("(y:L1)-//->(z:L2), (x:L0)-/->(y)")
    assert r2.stats.plan_cache_hit
    assert r2.count == r1.count
    info = eng.cache_info()
    assert info["plan_entries"] == 1 and info["plan_hits"] == 1


def test_plan_cache_lru_eviction():
    g = random_labeled_graph(100, n_labels=6, seed=0)
    eng = Engine(g, options=EngineOptions(device_min_nodes=10**9,
                                          materialize=False,
                                          plan_cache_size=2))
    for la, lb in [(0, 1), (1, 2), (2, 3)]:
        eng.execute(f"(a:L{la})-/->(b:L{lb})")
    info = eng.cache_info()
    assert info["plan_entries"] == 2 and info["plan_evictions"] == 1


# ------------------------------------------------------------ execute_many
def test_execute_many_matches_singles():
    g = random_labeled_graph(250, avg_degree=3.0, n_labels=5, seed=2)
    eng = _host_engine(g)
    qs = [random_query_from_graph(g, 3 + i % 2, qtype=["C", "H", "D"][i % 3],
                                  seed=i) for i in range(6)]
    qs.append("(a:L0)-//->(b:L1)")             # mixed text + objects
    batch = eng.execute_many(qs)
    gm = GM(g, GMOptions(materialize=False))
    for q, r in zip(qs, batch):
        qq = parse(q) if isinstance(q, str) else q
        assert r.count == gm.match(qq).count
    assert all(r.stats.label_cache_hit for r in batch[1:])


def test_engine_stats_recorded():
    g = random_labeled_graph(200, n_labels=4, seed=0)
    eng = _host_engine(g)
    r = eng.execute("(a:L0)-//->(b:L1)")
    s = r.stats
    assert s.backend == "host"
    assert s.total_s > 0 and s.exec_s > 0
    assert s.rig_nodes >= 0 and s.sim_passes >= 1
    assert eng.counters["queries"] == 1
    assert eng.counters["host_exec"] == 1


# ----------------------------------------------------------------- errors
def test_engine_rejects_label_outside_graph_space():
    g = random_labeled_graph(100, n_labels=3, seed=0)
    eng = _host_engine(g)
    with pytest.raises(QueryParseError, match="unknown label"):
        eng.execute("(a:L5)-/->(b:L0)")


def test_engine_named_labels():
    g = random_labeled_graph(200, n_labels=3, seed=0)
    eng = Engine(g, label_names=["Red", "Green", "Blue"],
                 options=EngineOptions(device_min_nodes=10**9,
                                       materialize=False))
    r = eng.execute("(a:Red)-//->(b:Blue)")
    want = eng.execute("(a:L0)-//->(b:L2)")    # generic spelling still works
    assert r.count == want.count


def test_execute_many_per_item_timing():
    g = random_labeled_graph(150, n_labels=4, seed=0)
    eng = _host_engine(g)
    batch = eng.execute_many(["(a:L0)-//->(b:L1)"] * 4)
    for r in batch:
        s = r.stats
        assert s.total_s == pytest.approx(s.parse_s + s.plan_s + s.exec_s)


def test_server_records_rejection_reason():
    from repro.launch.serve import QueryServer
    g = random_labeled_graph(100, n_labels=4, seed=0)
    srv = QueryServer(g)
    assert not srv.submit(7, "(a:L0)-/=>(b:L1)")
    assert "unexpected character" in srv.rejected[7]
    assert srv.stats["rejected"] == 1


def test_vocab_is_per_resident_graph():
    g1 = random_labeled_graph(100, n_labels=3, seed=0)
    g2 = random_labeled_graph(100, n_labels=8, seed=1)
    eng = _host_engine(g1)
    # L5 is invalid for g1 but valid for g2 — parse must use g2's vocab
    r = eng.execute("(a:L5)-/->(b:L0)", graph=g2)
    assert r.count >= 0
    with pytest.raises(QueryParseError, match="unknown label"):
        eng.execute("(a:L5)-/->(b:L0)")        # still rejected on g1


def test_malformed_query_does_not_pay_label_build():
    g = random_labeled_graph(200, n_labels=3, seed=0)
    eng = _host_engine(g)
    with pytest.raises(QueryParseError):
        eng.execute("(a:L9)-/->(b:L0)")        # cold engine, bad label
    assert eng.context().label_builds == 0     # no wasted construction
    with pytest.raises(QueryParseError):
        eng.execute_many(["(a:L0)-/->(b:L1)", "(((", ])
    assert eng.context().label_builds == 0


def test_resident_eviction_purges_plan_cache():
    eng = Engine(options=EngineOptions(device_min_nodes=10**9,
                                       materialize=False,
                                       max_resident_graphs=1))
    g1 = random_labeled_graph(100, n_labels=3, seed=0)
    g2 = random_labeled_graph(100, n_labels=3, seed=1)
    eng.execute("(a:L0)-/->(b:L1)", graph=g1)
    assert eng.cache_info()["plan_entries"] == 1
    eng.execute("(a:L0)-/->(b:L1)", graph=g2)  # evicts g1's residency
    assert eng.cache_info()["resident_graphs"] == 1
    assert eng.cache_info()["plan_entries"] == 1   # g1's entry purged


def test_engine_surfaces_enum_method():
    g = random_labeled_graph(400, avg_degree=3.0, n_labels=4, seed=7)
    eng = _host_engine(g)
    res = eng.execute("(a:L0)-/->(b:L1)-//->(c:L2)")
    assert res.stats.enum_method == res.plan.enum_method
    assert res.stats.enum_method in ("backtrack", "frontier",
                                     "frontier-device")


# ---------------------------------------------------------------- streaming
def test_execute_stream_matches_execute():
    g = random_labeled_graph(400, avg_degree=3.0, n_labels=4, seed=7)
    eng = Engine(g, options=EngineOptions(device_min_nodes=10**9))
    text = "(a:L0)-/->(b:L1)-//->(c:L2)"
    ref = eng.execute(text)
    for chunk in (1, 3, 64):
        st = eng.execute_stream(text, chunk_size=chunk)
        chunks = list(st)
        cat = (np.vstack(chunks) if chunks
               else np.empty((0, 3), dtype=np.int64))
        assert np.array_equal(cat, ref.tuples)
        assert st.count == ref.count == st.stats.count
        assert st.stats.streamed and st.stats.chunks == len(chunks)
        assert all(len(c) == chunk for c in chunks[:-1])


def test_execute_stream_truncated_at_limit_mid_chunk():
    """Regression: a limit hit mid-chunk must report truncated=True and
    yield *exactly* `limit` rows (no over-yield from the last slab)."""
    g = random_labeled_graph(400, avg_degree=3.0, n_labels=4, seed=7)
    text = "(a:L0)-/->(b:L1)-//->(c:L2)"
    for enum in ("backtrack", "frontier"):
        eng = Engine(g, options=EngineOptions(device_min_nodes=10**9,
                                              force_enum=enum))
        full = eng.execute(text)
        assert full.count > 10
        st = eng.execute_stream(text, chunk_size=64, limit=10)
        chunks = list(st)
        assert sum(len(c) for c in chunks) == 10 == st.stats.count
        assert st.stats.truncated
        assert np.array_equal(np.vstack(chunks), full.tuples[:10])
        # limit >= count: complete stream, not truncated
        st2 = eng.execute_stream(text, chunk_size=64, limit=full.count + 1)
        assert sum(len(c) for c in list(st2)) == full.count
        assert not st2.stats.truncated


def test_execute_stream_early_close_records_partial_stats():
    g = random_labeled_graph(400, avg_degree=3.0, n_labels=4, seed=7)
    eng = Engine(g, options=EngineOptions(device_min_nodes=10**9))
    text = "(a:L0)-/->(b:L1)-//->(c:L2)"
    with eng.execute_stream(text, chunk_size=4) as st:
        first = next(iter(st))
        assert len(first) == 4
    # context exit closes the stream: stats recorded for the prefix only
    assert st.stats.count == 4 and st.stats.chunks == 1
    assert eng.counters["stream_queries"] == 1
    assert eng.counters["queries"] == 1


def test_execute_stream_uses_planner_chunk_size():
    g = random_labeled_graph(300, avg_degree=3.0, n_labels=5, seed=0)
    eng = _host_engine(g)
    st = eng.execute_stream("(a:L0)-//->(b:L1)")
    assert st.stats.chunk_size == st.plan.chunk_size > 0
    list(st)
    st2 = eng.execute_stream("(a:L0)-//->(b:L1)", chunk_size=7)
    assert st2.stats.chunk_size == 7
    list(st2)


# ------------------------------------------- execute_many: grouping/sharing
def test_execute_many_dedup_shares_one_execution():
    g = random_labeled_graph(250, avg_degree=3.0, n_labels=5, seed=2)
    eng = _host_engine(g)
    text = "(a:L0)-//->(b:L1)"
    iso = "(y:L1)<-//-(x:L0)"                  # isomorphic spelling
    batch = eng.execute_many([text, text, iso, "(a:L2)-/->(b:L3)"])
    want = eng.execute(text).count
    assert [r.count for r in batch[:3]] == [want] * 3
    assert not batch[0].stats.shared_exec
    assert batch[1].stats.shared_exec and batch[2].stats.shared_exec
    assert not batch[3].stats.shared_exec
    # one host execution for the three isomorphic requests, one for the 4th,
    # plus the `want` reference execution above
    assert eng.counters["shared_exec"] == 2
    assert eng.counters["host_exec"] == 3


def test_execute_many_groups_by_resident_graph():
    g1 = random_labeled_graph(200, n_labels=4, seed=0)
    g2 = random_labeled_graph(200, n_labels=4, seed=1)
    eng = _host_engine(g1)
    text = "(a:L0)-//->(b:L1)"
    batch = eng.execute_many([text, (text, g2), text, ("(a:L1)-/->(b:L2)", g2)])
    assert batch[0].count == eng.execute(text).count
    assert batch[1].count == eng.execute(text, graph=g2).count
    assert batch[2].stats.shared_exec           # dedup within g1's group
    assert not batch[1].stats.shared_exec       # g2 is a different group
    assert eng.counters["label_builds"] == 2    # one cold build per graph
    assert batch[2].count == batch[0].count


def test_execute_many_micro_batches_frontier_device():
    g = random_labeled_graph(250, avg_degree=3.0, n_labels=5, seed=2)
    ref = _host_engine(g)
    eng = Engine(g, options=EngineOptions(
        device_min_nodes=10**9, materialize=False,
        force_enum="frontier-device", frontier_device=True))
    qs = ["(a:L0)-//->(b:L1)", "(a:L1)-//->(b:L2)", "(a:L2)-//->(b:L3)"]
    batch = eng.execute_many(qs)
    for q, r in zip(qs, batch):
        assert r.count == ref.execute(q).count
        assert r.stats.enum_method == "frontier-device"
        assert r.stats.backend == "host"
    assert eng.counters["frontier_batches"] == 1
    # fused dispatches, not one per query per level
    assert 1 <= eng.counters["frontier_batch_dispatches"] < len(qs)


# ------------------------------------------------ plan-cache stat snapshots
def test_engine_stats_snapshot_plan_cache_counters():
    g = random_labeled_graph(100, n_labels=6, seed=0)
    eng = Engine(g, options=EngineOptions(device_min_nodes=10**9,
                                          materialize=False,
                                          plan_cache_size=2))
    batch = eng.execute_many(["(a:L0)-/->(b:L1)", "(a:L0)-/->(b:L1)",
                              "(a:L1)-/->(b:L2)", "(a:L2)-/->(b:L3)"])
    info = eng.cache_info()
    last = batch[-1].stats
    assert last.plan_cache_hits == info["plan_hits"] == 1     # the duplicate
    assert last.plan_cache_misses == info["plan_misses"] == 3
    assert last.plan_cache_evictions == info["plan_evictions"] == 1
    # snapshots are monotone across the batch
    assert batch[0].stats.plan_cache_misses <= last.plan_cache_misses
    r = eng.execute("(a:L2)-/->(b:L3)")        # still resident: a hit
    assert r.stats.plan_cache_hits == 2


def test_engine_refines_enum_method_from_observed_rig():
    from repro.engine.planner import (FRONTIER_MIN_RESULTS,
                                      FRONTIER_RIG_NODES)
    g = random_labeled_graph(1200, avg_degree=3.0, n_labels=2, seed=11)
    eng = _host_engine(g)
    text = "(a:L0)-//->(b:L1)-//->(c:L0)"
    first = eng.execute(text)
    second = eng.execute(text)                  # plan-cache hit -> refine
    assert second.stats.plan_cache_hit
    assert second.count == first.count
    if (first.stats.rig_nodes >= FRONTIER_RIG_NODES
            or first.count >= FRONTIER_MIN_RESULTS):
        assert second.stats.enum_method in ("frontier", "frontier-device")


# ------------------------------------------------- resident enumerator path
def test_engine_resident_enum_counters_and_parity():
    g = random_labeled_graph(800, avg_degree=3.0, n_labels=2, seed=7)
    ref = _host_engine(g)
    eng = Engine(g, options=EngineOptions(
        device_min_nodes=10**9, materialize=False,
        force_enum="frontier-device-resident", frontier_device=True))
    # the last level's frontier is a few hundred rows (device-dispatched);
    # the earlier levels stay under the 128-row small-frontier threshold
    text = "(a:L0)-//->(b:L1)-//->(c:L0)-//->(d:L1)"
    res = eng.execute(text)
    assert res.count == ref.execute(text).count
    assert res.stats.enum_method == "frontier-device-resident"
    assert eng.counters["resident_uploads"] == 1
    assert eng.counters["resident_dispatches"] >= 1
    # the planner's small-frontier routing threshold keeps sub-128-row
    # slabs (here: the first constrained level) on the host intersect
    assert eng.counters["small_frontier_host_routed"] >= 1
    snap = eng.metrics.snapshot()
    assert "engine_resident_uploads" in snap
    assert "engine_small_frontier_host_routed" in snap
    # repeat execution on the same engine: the plan-cache entry kept the
    # uploaded executor, so the rebuilt (identical) RIG reattaches it and
    # skips the re-upload — the warm run ships only per-level index
    # vectors, a fraction of the cold run's matrix upload
    warm = eng.execute(text)
    assert eng.counters["resident_uploads"] == 1
    assert warm.count == res.count
    assert warm.stats.h2d_bytes < res.stats.h2d_bytes
    assert warm.stats.resident_bytes > 0      # footprint it ran against


def test_execute_stream_resident_end_to_end():
    """Acceptance: a device-planned query streams end-to-end with chunks
    byte-identical to host (one-shot) order."""
    g = random_labeled_graph(800, avg_degree=3.0, n_labels=2, seed=7)
    host = Engine(g, options=EngineOptions(device_min_nodes=10**9))
    eng = Engine(g, options=EngineOptions(
        device_min_nodes=10**9, force_enum="frontier-device-resident",
        frontier_device=True))
    text = "(a:L0)-//->(b:L1)-//->(c:L0)-//->(d:L1)"
    want = host.execute(text)
    with eng.execute_stream(text, chunk_size=64) as s:
        chunks = list(s)
    got = (np.vstack(chunks) if chunks
           else np.empty((0, 3), dtype=np.int64))
    assert want.tuples is not None
    assert np.array_equal(got, want.tuples)
    assert s.stats.enum_method == "frontier-device-resident"
    assert s.stats.streamed and s.count == want.count
