"""Flight-recorder tests: ring bounds, tail-based exemplar sampling,
JSONL dumps, incident auto-dumps, and the always-on engine/server
telemetry threading (one event per request in every execution mode)."""

from __future__ import annotations

import json

import pytest

from repro.data.graphs import random_labeled_graph
from repro.data.queries import random_query_from_graph
from repro.engine import Engine, EngineOptions
from repro.obs import FlightRecorder, QueryEvent
from repro.obs.events import ServerEvent


def qe(total_s=0.001, status="ok", deadline=False, **kw):
    return QueryEvent(total_s=total_s, status=status,
                      deadline_exceeded=deadline, **kw)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


# ------------------------------------------------------------- ring buffer
class TestRing:
    def test_bounded_capacity_keeps_newest(self):
        fr = FlightRecorder(capacity=4)
        for i in range(10):
            fr.record_query(qe(query_id=i))
        assert len(fr) == 4
        assert [e["query_id"] for e in fr.events()] == [6, 7, 8, 9]
        assert fr.recorded == 10            # lifetime count survives wrap

    def test_mixed_event_kinds(self):
        fr = FlightRecorder()
        fr.record_query(qe())
        fr.record(ServerEvent(action="reject", rid=3))
        kinds = [e["kind"] for e in fr.events()]
        assert kinds == ["query", "server"]


# --------------------------------------------------------------- exemplars
class TestExemplars:
    def test_slowest_k_retained(self):
        fr = FlightRecorder(exemplar_k=3)
        for i in range(20):
            fr.record_query(qe(total_s=0.001 * (i + 1), query_id=i))
        slow = fr.exemplars()["slowest"]
        assert [s["event"]["query_id"] for s in slow] == [19, 18, 17]
        assert slow[0]["total_s"] == pytest.approx(0.020)

    def test_failed_always_retained(self):
        fr = FlightRecorder(exemplar_k=2, max_failed_exemplars=4)
        for i in range(6):
            fr.record_query(qe(total_s=1e-6, status="injected_fault",
                               query_id=i))
        failed = fr.exemplars()["failed"]
        assert len(failed) == 4             # bounded, newest kept
        assert [f["event"]["query_id"] for f in failed] == [2, 3, 4, 5]

    def test_trace_provider_called_lazily(self):
        calls = []

        def provider():
            calls.append(1)
            return {"name": "query"}

        fr = FlightRecorder(exemplar_k=1)
        fr.record_query(qe(total_s=1.0), trace_provider=provider)
        assert len(calls) == 1              # admitted: provider ran
        fr.record_query(qe(total_s=0.001), trace_provider=provider)
        assert len(calls) == 1              # too fast: provider skipped
        fr.record_query(qe(total_s=0.5, status="transient"),
                        trace_provider=provider)
        assert len(calls) == 2              # failed: always an exemplar
        assert fr.exemplars()["slowest"][0]["trace"] == {"name": "query"}


# ------------------------------------------------------------------- dumps
class TestDumps:
    def test_dump_jsonl_roundtrip(self, tmp_path):
        fr = FlightRecorder(exemplar_k=2)
        for i in range(5):
            fr.record_query(qe(total_s=0.001 * (i + 1), query_id=i))
        fr.record_query(qe(status="deadline_exceeded", deadline=True))
        path = tmp_path / "flight.jsonl"
        lines = fr.dump_jsonl(str(path), reason="test")
        recs = [json.loads(line) for line in path.read_text().splitlines()]
        assert len(recs) == lines
        meta = recs[0]
        assert meta["kind"] == "meta" and meta["reason"] == "test"
        assert meta["events"] == 6
        events = [r for r in recs if r["kind"] == "query"]
        assert len(events) == 6
        ex = [r for r in recs if r["kind"] == "exemplar"]
        assert {r["class"] for r in ex} == {"slowest", "failed"}
        assert fr.last_dump_reason == "test"

    def test_autodump_debounce(self, tmp_path):
        clk = FakeClock()
        fr = FlightRecorder(min_dump_interval_s=30.0, clock=clk)
        path = tmp_path / "auto.jsonl"
        assert not fr.maybe_autodump("x")       # not armed: no-op
        fr.arm_autodump(str(path))
        assert fr.maybe_autodump("breaker_open")
        assert not fr.maybe_autodump("breaker_open")   # debounced
        clk.t += 31.0
        assert fr.maybe_autodump("breaker_open")
        assert fr.autodumps == 2

    def test_deadline_rate_spike_triggers_autodump(self, tmp_path):
        fr = FlightRecorder(deadline_rate_threshold=0.5, rate_window=8,
                            rate_min_events=8)
        path = tmp_path / "spike.jsonl"
        fr.arm_autodump(str(path))
        for _ in range(8):
            fr.record_query(qe())
        assert fr.autodumps == 0
        # half the recent window blows its deadline -> spike
        for _ in range(4):
            fr.record_query(qe(status="deadline_exceeded", deadline=True))
        assert fr.autodumps == 1
        assert fr.deadline_rate() == pytest.approx(0.5)
        meta = json.loads(path.read_text().splitlines()[0])
        assert meta["reason"] == "deadline_rate_spike"

    def test_rate_window_is_sliding(self):
        fr = FlightRecorder(rate_window=4, rate_min_events=4)
        for _ in range(4):
            fr.record_query(qe(deadline=True, status="deadline_exceeded"))
        assert fr.deadline_rate() == 1.0
        for _ in range(4):
            fr.record_query(qe())
        assert fr.deadline_rate() == 0.0    # old flags aged out exactly


# ------------------------------------------------------- engine threading
@pytest.fixture
def engine():
    g = random_labeled_graph(250, avg_degree=3.0, n_labels=6, seed=3)
    return Engine(g, options=EngineOptions(device_min_nodes=10 ** 9)), g


def _query(g, seed=5, n=4):
    return random_query_from_graph(g, n, qtype="H", seed=seed)


class TestEngineTelemetry:
    def test_all_three_modes_emit_events(self, engine):
        eng, g = engine
        q = _query(g)
        r = eng.execute(q)
        assert len(eng.flight) == 1
        s = eng.execute_stream(q, chunk_size=16)
        assert len(eng.flight) == 1         # stream event lands at finalize
        total = sum(len(c) for c in s)
        assert len(eng.flight) == 2
        eng.execute_many([q, _query(g, seed=6, n=3), q])
        events = eng.flight.events()
        assert len(events) == 5             # duplicates get their own events
        assert all(e["kind"] == "query" for e in events)
        one, stream = events[0], events[1]
        assert one["count"] == r.count and one["status"] == "ok"
        assert one["key"] and one["backend"] == "host"
        assert stream["streamed"] is True and stream["count"] == total
        assert any(e["shared_exec"] for e in events[2:])
        # the windows saw the same five requests
        assert eng.windows.summary()["merged"]["requests"] == 5
        assert eng.windows.summary()["merged"]["series"]["total"]["count"] \
            == 5

    def test_telemetry_toggle_disables_recording(self, engine):
        eng, g = engine
        eng.flight.events()                  # materialize (no-op) then count
        before = len(eng.flight)
        eng.telemetry = False
        try:
            eng.execute(_query(g, seed=7))
        finally:
            eng.telemetry = True
        assert len(eng.flight) == before

    def test_failed_query_event_has_error_type(self):
        from repro.robust import faults

        g = random_labeled_graph(120, avg_degree=2.5, n_labels=5, seed=7)
        eng = Engine(g, options=EngineOptions(device_min_nodes=10 ** 9))
        with faults.inject(faults.every("label_build", 1)):
            res = eng.execute("(a:L0)-/->(b:L1)")
        faults.uninstall()
        assert res.stats.status == "injected_fault"
        ev = eng.flight.events()[-1]
        assert ev["status"] == "injected_fault"
        assert ev["error_type"] == "InjectedFault"
        # failed requests are always exemplars, with a span tree attached
        failed = eng.flight.exemplars()["failed"]
        assert len(failed) == 1
        assert failed[0]["trace"]["attrs"]["status"] == "injected_fault"

    def test_exemplar_trace_synthesized_when_unprofiled(self, engine):
        eng, g = engine
        eng.execute(_query(g, seed=11))
        slow = eng.flight.exemplars()["slowest"]
        assert slow
        tree = slow[0]["trace"]
        assert tree["name"] == "query"
        assert tree["attrs"]["synthesized"] is True
        assert {c["name"] for c in tree["children"]} \
            <= {"parse", "plan", "exec"}

    def test_exemplar_trace_real_when_profiled(self):
        g = random_labeled_graph(120, avg_degree=2.5, n_labels=5, seed=9)
        eng = Engine(g, options=EngineOptions(device_min_nodes=10 ** 9))
        eng.execute(_query(g, seed=8, n=3), profile=True)
        tree = eng.flight.exemplars()["slowest"][0]["trace"]
        assert "synthesized" not in tree.get("attrs", {})
        assert {c["name"] for c in tree["children"]} >= {"parse", "plan",
                                                         "labels", "rig"}

    def test_breaker_transitions_land_in_recorder(self, tmp_path):
        from repro.engine import CircuitBreaker
        from repro.robust import faults

        g = random_labeled_graph(300, avg_degree=3.0, n_labels=4, seed=2)
        br = CircuitBreaker(sleep=lambda s: None, failure_threshold=3)
        eng = Engine(g, options=EngineOptions(device_min_nodes=0,
                                              materialize=False,
                                              force_backend="device",
                                              breaker=br))
        path = tmp_path / "incident.jsonl"
        eng.flight.arm_autodump(str(path))
        with faults.inject(faults.every("device_dispatch", 1)):
            eng.execute("(a:L0)-/->(b:L1)")
        faults.uninstall()
        kinds = [e["kind"] for e in eng.flight.events()]
        assert "breaker" in kinds
        trans = [e for e in eng.flight.events() if e["kind"] == "breaker"]
        assert trans[-1]["new_state"] == "open"
        # the open transition auto-dumped the ring
        assert path.exists()
        meta = json.loads(path.read_text().splitlines()[0])
        assert meta["reason"] == "breaker_open"


# --------------------------------------------------------- server threading
class TestServerTelemetry:
    def _server(self, **kw):
        from repro.launch.serve import QueryServer

        g = random_labeled_graph(200, avg_degree=3.0, n_labels=4, seed=3)
        eng = Engine(g, options=EngineOptions(device_min_nodes=10 ** 9,
                                              materialize=False))
        return QueryServer(g, engine=eng, **kw), g

    def test_chaos_run_drops_no_records(self):
        """Under injected worker deaths every request still resolves
        terminally, and the recorder holds one query event per engine
        execution plus a server event per redispatch/give-up."""
        from repro.robust import faults

        srv, g = self._server(max_attempts=3, batch_size=4)
        n = 12
        for i in range(n):
            q = random_query_from_graph(g, 3, qtype="C", seed=i)
            assert srv.submit(i, q)
        # deterministic chaos: dispatches 1, 2 and 4 lose their worker
        with faults.inject(faults.nth("journal_dispatch", 1, 2, 4)):
            srv.drain()
        faults.uninstall()
        done = [r for r in srv.journal.values() if r.status == "done"]
        failed = [r for r in srv.journal.values() if r.status == "failed"]
        assert len(done) + len(failed) == n      # no request lost
        events = srv.flight.events()
        by_kind = {}
        for e in events:
            by_kind.setdefault(e["kind"], []).append(e)
        # every served request produced a query event
        assert len(by_kind["query"]) >= len(done)
        redis = [e for e in by_kind["server"]
                 if e["action"] == "redispatch"]
        assert len(redis) >= 1                   # the chaos actually bit
        gaveup = [e for e in by_kind["server"] if e["action"] == "failed"]
        assert len(gaveup) == len(failed)
        assert "qps=" in srv.stats_line()

    def test_rejections_recorded(self):
        srv, g = self._server(queue_limit=2)
        assert not srv.submit(0, "(a:L0)-/->(")    # parse error
        srv.submit(1, "(a:L0)-/->(b:L1)")
        srv.submit(2, "(a:L0)-/->(b:L2)")
        assert not srv.submit(3, "(a:L0)-/->(b:L3)")   # queue full
        rejects = [e for e in srv.flight.events()
                   if e["kind"] == "server" and e["action"] == "reject"]
        assert [e["rid"] for e in rejects] == [0, 3]
        assert rejects[0]["detail"] == "parse error"
        assert "queue full" in rejects[1]["detail"]

    def test_explicit_worker_loss_records_redispatch(self):
        srv, g = self._server(max_attempts=2)
        srv.submit(0, "(a:L0)-/->(b:L1)")
        srv.step(fail=True)
        redis = [e for e in srv.flight.events()
                 if e["kind"] == "server" and e["action"] == "redispatch"]
        assert len(redis) == 1
        assert redis[0]["detail"] == "simulated worker loss"
        srv.drain()
        assert srv.journal[0].status == "done"
