"""Observability tests: span tracer, metrics registry, exporters, and the
engine's lifecycle traces across all three execution modes."""

from __future__ import annotations

import json
import tracemalloc

import pytest

from repro.data.graphs import random_labeled_graph
from repro.data.queries import random_query_from_graph
from repro.engine import Engine, EngineOptions
from repro.obs import (NULL_TRACER, MetricsRegistry, Span, Tracer,
                       prometheus_text, render_trace, trace_to_json)
from repro.obs.trace import _NULL_SPAN

LIFECYCLE = {"parse", "canonicalize", "plan", "labels", "rig", "enumerate",
             "materialize"}


# --------------------------------------------------------------- span tracer
class TestTracer:
    def test_nesting_structure(self):
        tr = Tracer("root")
        with tr.span("a"):
            with tr.span("b"):
                tr.add("c", duration_s=0.5)
            with tr.span("d"):
                pass
        root = tr.finish()
        assert root.name == "root"
        assert [s.name for s in root.children] == ["a"]
        a = root.children[0]
        assert [s.name for s in a.children] == ["b", "d"]
        assert a.children[0].children[0].name == "c"
        assert root.phase_names() == ["root", "a", "b", "c", "d"]

    def test_timing_monotonicity(self):
        tr = Tracer("root")
        with tr.span("outer") as outer:
            for _ in range(3):
                with tr.span("inner"):
                    sum(range(1000))
        root = tr.finish()
        inners = root.find_all("inner")
        assert len(inners) == 3
        # each span's duration is non-negative and children nest within
        # the parent both in time and in total duration
        for s in root.iter():
            assert s.duration_s >= 0.0
            assert s.t0 is not None and s.t1 is not None and s.t1 >= s.t0
        assert sum(s.duration_s for s in inners) <= outer.duration_s + 1e-9
        for s in inners:
            assert s.t0 >= outer.t0 - 1e-9 and s.t1 <= outer.t1 + 1e-9
        # children are recorded in start order
        t0s = [s.t0 for s in inners]
        assert t0s == sorted(t0s)

    def test_synthesized_duration_override(self):
        tr = Tracer("root")
        sp = tr.add("phase", duration_s=1.25, foo="bar")
        assert sp.duration_s == 1.25
        assert tr.finish().find("phase").attrs["foo"] == "bar"

    def test_attrs_and_serialization(self):
        import numpy as np

        tr = Tracer("q")
        with tr.span("s") as sp:
            sp.set(arr=np.arange(3), scalar=np.int64(7), t=(1, 2))
        root = tr.finish()
        d = root.to_dict()
        s = json.loads(json.dumps(d))     # round-trips as plain JSON
        assert s["children"][0]["attrs"]["arr"] == [0, 1, 2]
        assert s["children"][0]["attrs"]["scalar"] == 7

    def test_finish_closes_open_spans(self):
        tr = Tracer("root")
        tr.span("left-open").__enter__()
        root = tr.finish()
        assert root.find("left-open").t1 is not None

    def test_noop_identity_and_zero_allocation(self):
        # every call hands back the same singleton...
        assert NULL_TRACER.span("x") is NULL_TRACER.span("y") is _NULL_SPAN
        assert NULL_TRACER.add("x") is _NULL_SPAN
        assert NULL_TRACER.enabled is False
        with NULL_TRACER.span("x") as sp:
            assert sp.set(a=1) is sp
        # ...and the disabled path allocates nothing across many calls
        def loop():
            for _ in range(1000):
                with NULL_TRACER.span("phase") as s:
                    s.set()

        loop()                                   # warm up caches
        tracemalloc.start()
        loop()
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        assert peak < 4096, f"no-op tracer allocated {peak} bytes"


# ----------------------------------------------------------------- registry
class TestMetrics:
    def test_counter_gauge_histogram(self):
        reg = MetricsRegistry()
        c = reg.counter("hits", cache="plan")
        c.inc()
        c.inc(2)
        assert reg.counter("hits", cache="plan") is c     # get-or-create
        assert c.value == 3
        g = reg.gauge("depth")
        g.set(4.0)
        g.add(-1.0)
        assert g.value == 3.0
        h = reg.histogram("lat")
        for v in (0.001, 0.01, 0.01, 10.0):
            h.observe(v)
        assert h.count == 4 and h.vmin == 0.001 and h.vmax == 10.0
        assert h.mean == pytest.approx(10.021 / 4)

    def test_kind_mismatch_raises(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(TypeError):
            reg.gauge("x")

    def test_snapshot_isolation(self):
        """A snapshot is a frozen copy: later mutations don't leak in, and
        two registries never share series."""
        reg = MetricsRegistry()
        c = reg.counter("n")
        c.inc(5)
        snap = reg.snapshot()
        c.inc(100)
        assert snap["n"] == 5
        assert reg.snapshot()["n"] == 105
        other = MetricsRegistry()
        other.counter("n").inc(1)
        assert reg.snapshot()["n"] == 105
        assert other.snapshot()["n"] == 1

    def test_snapshot_prefix_and_histogram_summary(self):
        reg = MetricsRegistry()
        reg.counter("engine_queries").inc(2)
        reg.histogram("rig_nodes").observe(42)
        snap = reg.snapshot("engine_")
        assert snap == {"engine_queries": 2}
        full = reg.snapshot()
        assert full["rig_nodes"]["count"] == 1
        assert full["rig_nodes"]["max"] == 42

    def test_prometheus_text(self):
        reg = MetricsRegistry()
        reg.counter("hits", cache="plan").inc(7)
        reg.histogram("lat", buckets=(0.1, 1.0)).observe(0.05)
        text = prometheus_text(reg)
        assert '# TYPE hits counter' in text
        assert 'hits{cache="plan"} 7' in text
        assert 'lat_bucket{le="0.1"} 1' in text
        assert 'lat_bucket{le="+Inf"} 1' in text
        assert 'lat_count 1' in text
        assert 'lat_sum 0.05' in text

    def test_histogram_quantiles_interpolated(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat", buckets=(1.0, 10.0, 100.0))
        for v in (0.5, 2.0, 3.0, 5.0, 50.0):
            h.observe(v)
        assert h.quantile(0.0) == pytest.approx(0.5)
        assert h.quantile(1.0) == pytest.approx(50.0)
        # the median rank lands in the (1, 10] bucket, interpolated within
        med = h.quantile(0.5)
        assert 1.0 <= med <= 10.0
        s = h.summary()
        assert s["p50"] == pytest.approx(med)
        assert set(s) >= {"count", "mean", "min", "max",
                          "p50", "p95", "p99"}
        assert reg.histogram("empty").quantile(0.5) is None

    def test_histogram_quantile_clamps_to_observed_range(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat", buckets=(1.0, 1000.0))
        # all mass in one wide bucket: interpolation must stay inside the
        # observed [vmin, vmax], not wander across the bucket
        for v in (4.0, 5.0, 6.0):
            h.observe(v)
        for q in (0.01, 0.5, 0.99):
            assert 4.0 <= h.quantile(q) <= 6.0

    def test_prometheus_quantile_gauges(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat", buckets=(0.1, 1.0), op="read")
        for v in (0.05, 0.2, 0.7):
            h.observe(v)
        text = prometheus_text(reg)
        assert '# TYPE lat_quantile gauge' in text
        assert text.count('# TYPE lat_quantile gauge') == 1
        for q in ("0.5", "0.95", "0.99"):
            assert f'lat_quantile{{op="read",quantile="{q}"}}' in text
        # empty histograms emit no quantile lines
        reg2 = MetricsRegistry()
        reg2.histogram("lat")
        assert "_quantile" not in prometheus_text(reg2)

    def test_label_value_escaping(self):
        from repro.obs.metrics import escape_label_value

        assert escape_label_value('a"b\\c\nd') == 'a\\"b\\\\c\\nd'
        reg = MetricsRegistry()
        reg.counter("hits", path='a\nb"c\\d').inc()
        text = prometheus_text(reg)
        assert 'hits{path="a\\nb\\"c\\\\d"} 1' in text
        # the raw newline never splits the series line
        [line] = [ln for ln in text.splitlines() if ln.startswith("hits{")]
        assert line.endswith("} 1")


# ------------------------------------------------------------ engine traces
@pytest.fixture(scope="module")
def engine():
    g = random_labeled_graph(250, avg_degree=3.0, n_labels=6, seed=3)
    return Engine(g, options=EngineOptions(device_min_nodes=10 ** 9)), g


def _query(g, seed=5, n=4):
    return random_query_from_graph(g, n, qtype="H", seed=seed)


class TestEngineTraces:
    def test_execute_profile_covers_lifecycle(self, engine):
        eng, g = engine
        r = eng.execute(_query(g), profile=True)
        assert r.trace is not None
        assert LIFECYCLE <= set(r.trace.phase_names())
        # the rig span carries its real children from build_rig
        rig = r.trace.find("rig")
        assert {"select", "expand", "order"} <= {c.name for c in
                                                 rig.children}
        en = r.trace.find("enumerate")
        assert en.attrs["results"] == r.count
        # rendering and JSON export work on a real trace
        assert "enumerate" in render_trace(r.trace)
        payload = json.loads(trace_to_json(r.trace))
        assert payload["schema_version"] >= 1
        assert payload["trace"]["name"] == "query"

    def test_execute_unprofiled_has_no_trace(self, engine):
        eng, g = engine
        r = eng.execute(_query(g))
        assert r.trace is None

    def test_stream_profile_covers_lifecycle(self, engine):
        eng, g = engine
        ref = eng.execute(_query(g)).count
        s = eng.execute_stream(_query(g), profile=True, chunk_size=16)
        assert s.trace is None              # not finalized yet
        total = sum(len(c) for c in s)
        assert total == ref
        assert s.trace is not None
        names = set(s.trace.phase_names())
        assert LIFECYCLE <= names
        en = s.trace.find("enumerate")
        assert en.attrs["completed"] is True
        assert en.attrs["chunks"] == s.stats.chunks
        assert s.trace.find("materialize").attrs["streamed"] is True

    def test_stream_early_close_still_finalizes_trace(self, engine):
        eng, g = engine
        s = eng.execute_stream(_query(g), profile=True, chunk_size=4)
        next(iter(s))
        s.close()
        assert s.trace is not None
        assert s.trace.find("enumerate").attrs["completed"] is False

    def test_execute_many_profile_covers_lifecycle(self, engine):
        eng, g = engine
        qs = [_query(g), _query(g), _query(g, seed=6, n=3)]
        batch = eng.execute_many(qs, profile=True)
        assert any(b.stats.shared_exec for b in batch)
        for b in batch:
            assert b.trace is not None
            assert LIFECYCLE <= set(b.trace.phase_names()), \
                (b.stats.shared_exec,
                 sorted(set(b.trace.phase_names())))
        # unprofiled batch stays trace-free
        for b in eng.execute_many(qs):
            assert b.trace is None

    def test_error_terminated_span_is_tagged(self):
        from repro.robust import faults
        from repro.robust.errors import InjectedFault

        tr = Tracer("q")
        with pytest.raises(InjectedFault):
            with tr.span("labels"):
                with faults.inject(faults.every("label_build", 1)):
                    faults.maybe_fail("label_build")
        faults.uninstall()
        root = tr.finish()
        sp = root.find("labels")
        assert sp.attrs["error"] == "InjectedFault"
        assert sp.attrs["status"] == "injected_fault"

    def test_fault_injected_query_yields_error_tagged_trace(self):
        """A profiled query killed mid-phase by an injected fault must
        return an error-tagged span tree: the failing span (and the root)
        carry the exception class and the stable status string."""
        from repro.robust import faults

        g = random_labeled_graph(120, avg_degree=2.5, n_labels=5, seed=7)
        eng = Engine(g, options=EngineOptions(device_min_nodes=10 ** 9))
        with faults.inject(faults.every("label_build", 1)):
            res = eng.execute("(a:L0)-/->(b:L1)", profile=True)
        faults.uninstall()
        assert res.stats.status == "injected_fault"
        assert res.trace is not None
        labels = res.trace.find("labels")
        assert labels.attrs["error"] == "InjectedFault"
        assert labels.attrs["status"] == "injected_fault"
        assert res.trace.attrs["error"] == "InjectedFault"
        assert res.trace.attrs["status"] == "injected_fault"

    def test_trace_timing_totals(self, engine):
        eng, g = engine
        r = eng.execute(_query(g, seed=9), profile=True)
        child_sum = sum(c.duration_s for c in r.trace.children)
        assert child_sum <= r.trace.duration_s + 1e-6
        assert r.trace.duration_s <= r.stats.total_s + 0.05


# ------------------------------------------------------------- engine metrics
class TestEngineMetrics:
    def test_counters_view_and_registry_agree(self):
        g = random_labeled_graph(120, avg_degree=2.5, n_labels=5, seed=7)
        eng = Engine(g, options=EngineOptions(device_min_nodes=10 ** 9))
        q = _query(g, seed=8, n=3)
        eng.execute(q)
        eng.execute(q)
        assert eng.counters["queries"] == 2
        snap = eng.metrics_snapshot("engine_")
        assert snap["engine_queries"] == 2
        assert snap["engine_host_exec"] == eng.counters["host_exec"]
        # dict-style surface still works
        assert dict(eng.counters.items())["queries"] == 2
        assert "queries" in eng.counters
        text = eng.metrics_text()
        assert "engine_queries 2" in text
        assert 'cache_hits{cache="plan"}' in text

    def test_plan_cache_snapshot_is_per_query_atomic(self):
        """The per-query plan-cache counters are captured at prepare time:
        a stream that finalizes *after* later queries ran must report the
        cache state of its own access, not the later one."""
        g = random_labeled_graph(120, avg_degree=2.5, n_labels=5, seed=7)
        eng = Engine(g, options=EngineOptions(device_min_nodes=10 ** 9))
        qa = _query(g, seed=8, n=3)
        qb = _query(g, seed=9, n=3)
        eng.execute(qa)                      # miss #1
        s = eng.execute_stream(qa)           # hit #1, finalized later
        hits_at_prepare = eng._plan_cache.hits
        eng.execute(qb)                      # miss #2
        eng.execute(qb)                      # hit #2
        eng.execute(qb)                      # hit #3
        for _ in s:                          # now finalize the stream
            pass
        assert s.stats.plan_cache_hits == hits_at_prepare == 1
        assert s.stats.plan_cache_misses == 1
        # the later queries see their own (larger) snapshots
        assert eng.execute(qb).stats.plan_cache_hits == 4

    def test_label_cache_metrics(self):
        g = random_labeled_graph(120, avg_degree=2.5, n_labels=5, seed=7)
        eng = Engine(g, options=EngineOptions(device_min_nodes=10 ** 9))
        q = _query(g, seed=8, n=3)
        r1 = eng.execute(q, profile=True)
        r2 = eng.execute(q, profile=True)
        assert not r1.stats.label_cache_hit
        assert r2.stats.label_cache_hit
        lab1, lab2 = r1.trace.find("labels"), r2.trace.find("labels")
        assert {c.name for c in lab1.children} == \
            {"reachability", "adjacency", "intervals"}
        assert lab2.children == [] and lab2.attrs["cached"] is True


# ------------------------------------------------- governance metrics (PR 7)
class TestGovernanceMetrics:
    def test_deadline_and_degradation_counters(self):
        from repro.engine import Budget

        g = random_labeled_graph(1500, avg_degree=8.0, n_labels=1, seed=1)
        eng = Engine(g, options=EngineOptions(device_min_nodes=10 ** 9,
                                              materialize=False,
                                              force_enum="backtrack",
                                              limit=None))
        eng.execute("(a:L0)-/->(b:L0)")          # warm labels
        snap0 = eng.metrics_snapshot("engine_")
        assert snap0["engine_deadline_exceeded"] == 0
        res = eng.execute("(a:L0)-//->(b:L0)-//->(c:L0)",
                          budget=Budget(deadline_s=0.05))
        assert res.stats.status == "deadline_exceeded"
        snap = eng.metrics_snapshot("engine_")
        assert snap["engine_deadline_exceeded"] == 1
        assert "engine_budget_degradations" in snap
        assert "engine_transient_retries" in snap
        text = eng.metrics_text()
        assert "engine_deadline_exceeded 1" in text

    def test_breaker_gauge_and_retry_counter(self):
        from repro.engine import CircuitBreaker
        from repro.robust import faults
        from repro.robust.breaker import STATE_VALUES

        g = random_labeled_graph(300, avg_degree=3.0, n_labels=4, seed=2)
        br = CircuitBreaker(sleep=lambda s: None, failure_threshold=3)
        eng = Engine(g, options=EngineOptions(device_min_nodes=0,
                                              materialize=False,
                                              force_backend="device",
                                              breaker=br))
        snap = eng.metrics_snapshot("engine_")
        assert snap["engine_breaker_state"] == STATE_VALUES["closed"]
        assert snap["engine_device_retries"] == 0
        with faults.inject(faults.every("device_dispatch", 1)):
            eng.execute("(a:L0)-/->(b:L1)")      # host fallback, breaker opens
        faults.uninstall()
        snap = eng.metrics_snapshot("engine_")
        assert snap["engine_breaker_state"] == STATE_VALUES["open"]
        assert snap["engine_device_retries"] >= 1
        assert snap["engine_budget_degradations"] >= 1   # the "host" step
        assert "engine_breaker_state" in eng.metrics_text()

    def test_server_failed_counter(self):
        from repro.launch.serve import QueryServer

        g = random_labeled_graph(200, avg_degree=3.0, n_labels=4, seed=3)
        eng = Engine(g, options=EngineOptions(device_min_nodes=10 ** 9,
                                              materialize=False))
        srv = QueryServer(g, engine=eng, max_attempts=1)
        srv.submit(0, "(a:L0)-/->(b:L1)")
        srv.step(fail=True)                      # the only attempt is lost
        srv.drain()
        assert srv.journal[0].status == "failed"
        snap = eng.metrics_snapshot("server_")
        assert snap["server_failed"] == 1
        assert "server_failed 1" in srv.metrics_text()


# ------------------------------------------------------------------- explain
class TestExplain:
    def test_explain_static_and_stable(self):
        g = random_labeled_graph(150, avg_degree=2.5, n_labels=5, seed=11)
        eng = Engine(g, options=EngineOptions(device_min_nodes=10 ** 9))
        q = _query(g, seed=12, n=3)
        first = eng.explain(q)
        assert "plan" in first and "enumerate" in first
        assert eng.counters["queries"] == 0        # explain does not execute
        # once the plan is cached, repeat calls print identically
        second, third = eng.explain(q), eng.explain(q)
        assert second == third
        assert "[cached plan]" in second
        # execution doesn't change explain's structure, only observed stats
        eng.execute(q)
        after = eng.explain(q)
        assert "observed:" in after
        assert eng.explain(q) == after

    def test_explain_text_query(self):
        g = random_labeled_graph(150, avg_degree=2.5, n_labels=5, seed=11)
        eng = Engine(g, options=EngineOptions(device_min_nodes=10 ** 9))
        out = eng.explain("(a:L0)-/->(b:L1)")
        assert "backend=" in out and "├─ parse" in out


# ------------------------------------------------- ledger exposition (PR 10)
class TestLedgerExposition:
    def test_metrics_text_has_ledger_and_misestimation_series(self):
        from repro.obs.ledger import LEDGER
        LEDGER.reset()
        g = random_labeled_graph(200, avg_degree=2.5, n_labels=4, seed=2)
        eng = Engine(g, options=EngineOptions(device_min_nodes=10 ** 9))
        q = _query(g, seed=4, n=3)
        eng.execute(q)
        eng.execute(q)                     # warm: ratios recorded twice
        text = eng.metrics_text()
        # ledger series are published into the engine registry on dump
        assert "ledger_resident_charged_bytes" in text
        assert "ledger_resident_credited_bytes" in text
        assert "ledger_resident_live_bytes" in text
        assert "ledger_resident_watermark_bytes" in text
        assert "cache_resident_evicted_bytes" in text
        # misestimation histograms carry observations for every reconciled
        # quantity (resident_bytes only when a resident execution happened)
        assert ('planner_misestimation_ratio_count{quantity="cardinality"}'
                in text)
        assert ('planner_misestimation_ratio_count{quantity="rig_nodes"}'
                in text)
        snap = eng.metrics_snapshot()
        key = 'planner_misestimation_ratio{quantity="cardinality"}'
        assert snap[key]["count"] == 2

    def test_query_events_carry_byte_tags(self):
        from repro.obs.ledger import LEDGER
        LEDGER.reset()
        g = random_labeled_graph(200, avg_degree=2.5, n_labels=4, seed=2)
        eng = Engine(g, options=EngineOptions(device_min_nodes=10 ** 9))
        eng.execute(_query(g, seed=4, n=3))
        ev = eng.flight.events()[-1]
        for field in ("h2d_bytes", "d2h_bytes", "resident_bytes"):
            assert field in ev and ev[field] == 0      # host-only execution

    def test_explain_analyze_renders_estimates_and_transfers(self):
        from repro.obs.ledger import LEDGER
        LEDGER.reset()
        g = random_labeled_graph(200, avg_degree=2.5, n_labels=4, seed=2)
        eng = Engine(g, options=EngineOptions(device_min_nodes=10 ** 9))
        q = _query(g, seed=4, n=3)
        eng.execute(q)
        out = eng.explain_analyze(q)      # executes, then reconciles
        assert "estimates" in out and "warm plan" in out
        for quantity in ("cardinality", "rig_nodes", "rig_edges"):
            assert quantity in out
        assert "x" in out                  # at least one obs/est ratio
        assert "decisions" in out
        assert "transfers" in out and "graph ledger" in out
        assert eng.counters["queries"] == 2
