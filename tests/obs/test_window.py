"""WindowedAggregator tests: rotation boundaries under an injected clock,
retention, QPS / error-rate arithmetic, and the merged summary."""

from __future__ import annotations

import pytest

from repro.obs import WindowedAggregator


class FakeClock:
    def __init__(self, t: float = 1000.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


@pytest.fixture
def clk():
    return FakeClock()


def agg(clk, **kw):
    kw.setdefault("window_s", 10.0)
    kw.setdefault("n_windows", 3)
    return WindowedAggregator(clock=clk, **kw)


class TestRotation:
    def test_empty_aggregator(self, clk):
        w = agg(clk)
        assert w.window_count() == 0
        s = w.summary()
        assert s["merged"]["requests"] == 0
        assert s["merged"]["error_rate"] == 0.0
        assert s["windows"][-1]["series"] == {}
        assert "p50=-" in w.summary_line()

    def test_single_sample(self, clk):
        w = agg(clk)
        w.observe({"total": 0.002})
        assert w.window_count() == 1
        s = w.summary()
        assert s["merged"]["requests"] == 1
        assert s["merged"]["series"]["total"]["p99"] == \
            pytest.approx(0.002, rel=0.01)

    def test_observations_align_to_window_boundary(self, clk):
        clk.t = 1007.5                       # mid-window
        w = agg(clk)
        w.observe({"total": 0.001})
        assert w.summary()["windows"][-1]["t0"] == 1000.0

    def test_boundary_rotation(self, clk):
        clk.t = 1009.999
        w = agg(clk)
        w.observe({"total": 0.001})
        clk.t = 1010.0                       # first tick of the next window
        w.observe({"total": 0.002})
        s = w.summary()
        assert [win["t0"] for win in s["windows"]] == [1000.0, 1010.0]
        assert [win["requests"] for win in s["windows"]] == [1, 1]

    def test_same_window_no_rotation(self, clk):
        w = agg(clk)
        for dt in (0.0, 3.0, 9.999):
            clk.t = 1000.0 + dt
            w.observe({"total": 0.001})
        assert w.window_count() == 1
        assert w.summary()["windows"][-1]["requests"] == 3

    def test_clock_jump_skips_empty_windows(self, clk):
        w = agg(clk)
        w.observe({"total": 0.001})
        clk.t += 50.0                        # five widths later
        w.observe({"total": 0.002})
        s = w.summary()
        # the gap is visible through t0, not materialized as empty windows
        assert [win["t0"] for win in s["windows"]] == [1000.0, 1050.0]

    def test_retention_cap(self, clk):
        w = agg(clk, n_windows=3)
        for i in range(8):
            clk.t = 1000.0 + 10.0 * i
            w.observe({"total": 0.001 * (i + 1)})
        s = w.summary()
        assert len(s["windows"]) == 4        # 3 closed + current
        assert [win["t0"] for win in s["windows"]] == \
            [1040.0, 1050.0, 1060.0, 1070.0]
        # merged covers only what is retained
        assert s["merged"]["requests"] == 4
        assert w.total_requests == 8         # lifetime counter keeps all

    def test_summary_rotates_without_observation(self, clk):
        w = agg(clk)
        w.observe({"total": 0.001})
        clk.t += 25.0
        s = w.summary()
        # the old window closed; current is empty
        assert s["windows"][-1]["requests"] == 0
        assert s["windows"][0]["requests"] == 1


class TestRates:
    def test_qps_uses_elapsed_fraction_for_current_window(self, clk):
        clk.t = 1000.0
        w = agg(clk)
        for _ in range(10):
            w.observe({"total": 0.001})
        clk.t = 1002.0                       # 2s into a 10s window
        s = w.summary()
        assert s["windows"][-1]["qps"] == pytest.approx(5.0)

    def test_closed_window_qps_uses_full_width(self, clk):
        w = agg(clk)
        for _ in range(20):
            w.observe({"total": 0.001})
        clk.t += 10.0
        w.observe({"total": 0.001})
        s = w.summary()
        assert s["windows"][0]["qps"] == pytest.approx(2.0)

    def test_error_rate(self, clk):
        w = agg(clk)
        for i in range(8):
            w.observe({"total": 0.001}, error=(i % 4 == 0))
        s = w.summary()
        assert s["windows"][-1]["errors"] == 2
        assert s["windows"][-1]["error_rate"] == pytest.approx(0.25)
        assert s["merged"]["error_rate"] == pytest.approx(0.25)


class TestSeries:
    def test_multiple_series_per_observation(self, clk):
        w = agg(clk)
        w.observe({"parse": 0.0001, "exec": 0.001, "total": 0.0012})
        win = w.summary()["windows"][-1]
        assert set(win["series"]) == {"exec", "parse", "total"}

    def test_merged_quantiles_across_windows(self, clk):
        w = agg(clk, n_windows=6)
        # 100 fast in window 1, 100 slow in window 2: merged p50 must sit
        # between the two modes, per-window p50s at the modes
        for _ in range(100):
            w.observe({"total": 0.001})
        clk.t += 10.0
        for _ in range(100):
            w.observe({"total": 0.1})
        s = w.summary()
        w1, w2 = s["windows"]
        assert w1["series"]["total"]["p50"] == pytest.approx(0.001, rel=0.02)
        assert w2["series"]["total"]["p50"] == pytest.approx(0.1, rel=0.02)
        merged = s["merged"]["series"]["total"]
        assert merged["count"] == 200
        assert merged["p50"] == pytest.approx(0.001, rel=0.02)
        assert merged["p99"] == pytest.approx(0.1, rel=0.02)

    def test_summary_line_format(self, clk):
        w = agg(clk)
        for _ in range(5):
            w.observe({"total": 0.002}, error=False)
        w.observe({"total": 0.002}, error=True)
        clk.t += 1.0
        line = w.summary_line()
        assert "qps=" in line and "err=16.7%" in line
        assert "p50=2.0ms" in line and "p99=2.0ms" in line
        assert "(n=6, 1 windows)" in line

    def test_clear(self, clk):
        w = agg(clk)
        w.observe({"total": 0.001})
        w.clear()
        assert w.window_count() == 0
        assert w.total_requests == 0

    def test_invalid_width_rejected(self, clk):
        with pytest.raises(ValueError):
            WindowedAggregator(window_s=0.0, clock=clk)
