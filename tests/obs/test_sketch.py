"""QuantileSketch tests: the relative-error guarantee (property-based and
example-based), merge exactness, and degenerate streams."""

from __future__ import annotations

import math
import random

import pytest

from repro.obs import QuantileSketch
from repro.testing import HAVE_HYPOTHESIS, given, settings, st

QS = (0.0, 0.01, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0)


def exact_quantile(xs, q):
    """The order statistic the sketch promises to approximate."""
    s = sorted(xs)
    return s[math.floor(q * (len(s) - 1))]


def assert_within_bound(sk, xs, qs=QS, slack=1e-9):
    a = sk.relative_accuracy
    for q in qs:
        exact = exact_quantile(xs, q)
        est = sk.quantile(q)
        err = abs(est - exact) / max(abs(exact), 1e-300)
        if exact == 0.0:
            assert est == 0.0, (q, est)
        else:
            assert err <= a + slack, (q, exact, est, err)


class TestRelativeErrorBound:
    @pytest.mark.parametrize("accuracy", [0.001, 0.01, 0.05])
    def test_lognormal_stream(self, accuracy):
        rng = random.Random(0)
        xs = [rng.lognormvariate(0.0, 3.0) for _ in range(5000)]
        sk = QuantileSketch(accuracy)
        for x in xs:
            sk.add(x)
        assert_within_bound(sk, xs)

    def test_latency_like_stream(self):
        # microseconds to minutes, heavy right tail: the serving shape
        rng = random.Random(1)
        xs = [10 ** rng.uniform(-6, 2) for _ in range(3000)]
        sk = QuantileSketch(0.01)
        for x in xs:
            sk.add(x)
        assert_within_bound(sk, xs)

    def test_mixed_signs_and_zeros(self):
        rng = random.Random(2)
        xs = ([rng.uniform(-100, -0.001) for _ in range(500)]
              + [0.0] * 100
              + [rng.uniform(0.001, 100) for _ in range(500)])
        rng.shuffle(xs)
        sk = QuantileSketch(0.01)
        for x in xs:
            sk.add(x)
        assert_within_bound(sk, xs)

    def test_duplicates_collapse_to_exact(self):
        sk = QuantileSketch(0.01)
        sk.add(5.0, n=1000)
        for q in QS:
            assert sk.quantile(q) == pytest.approx(5.0, rel=0.01)
        # min/max clamp makes the single-value case exact
        assert sk.quantile(0.0) == 5.0
        assert sk.quantile(1.0) == 5.0

    @given(st.lists(st.floats(min_value=1e-9, max_value=1e12,
                              allow_nan=False, allow_infinity=False),
                    min_size=1, max_size=300))
    @settings(max_examples=200, deadline=None)
    def test_property_positive_streams(self, xs):
        sk = QuantileSketch(0.01)
        for x in xs:
            sk.add(x)
        assert_within_bound(sk, xs)

    @given(st.lists(st.floats(min_value=-1e6, max_value=1e6,
                              allow_nan=False, allow_infinity=False),
                    min_size=1, max_size=200))
    @settings(max_examples=100, deadline=None)
    def test_property_any_sign(self, xs):
        # keep exact zeros but drop subnormal magnitudes, where the bucket
        # representative itself underflows and no relative bound can hold
        xs = [x for x in xs if x == 0.0 or abs(x) >= 1e-12] or [0.0]
        sk = QuantileSketch(0.01)
        for x in xs:
            sk.add(x)
        assert_within_bound(sk, xs)


class TestMergeAndEdges:
    def test_merge_equals_single_sketch(self):
        rng = random.Random(3)
        xs = [rng.lognormvariate(0, 2) for _ in range(2000)]
        whole = QuantileSketch(0.01)
        parts = [QuantileSketch(0.01) for _ in range(4)]
        for i, x in enumerate(xs):
            whole.add(x)
            parts[i % 4].add(x)
        merged = parts[0]
        for p in parts[1:]:
            merged.merge(p)
        assert merged.count == whole.count == len(xs)
        assert merged.total == pytest.approx(whole.total)
        for q in QS:
            assert merged.quantile(q) == whole.quantile(q)  # bucket-exact

    def test_merge_accuracy_mismatch_raises(self):
        with pytest.raises(ValueError):
            QuantileSketch(0.01).merge(QuantileSketch(0.02))

    def test_empty_returns_none(self):
        sk = QuantileSketch(0.01)
        assert sk.quantile(0.5) is None
        assert sk.quantiles() == {"p50": None, "p95": None, "p99": None}
        assert sk.summary()["min"] is None
        assert len(sk) == 0

    def test_single_sample(self):
        sk = QuantileSketch(0.01)
        sk.add(0.0042)
        for q in QS:
            assert sk.quantile(q) == pytest.approx(0.0042, rel=0.01)

    def test_nan_and_nonpositive_counts_dropped(self):
        sk = QuantileSketch(0.01)
        sk.add(float("nan"))
        sk.add(1.0, n=0)
        sk.add(1.0, n=-5)
        assert len(sk) == 0
        sk.add(1.0)
        assert len(sk) == 1

    def test_invalid_accuracy_rejected(self):
        for bad in (0.0, 1.0, -0.1, 2.0):
            with pytest.raises(ValueError):
                QuantileSketch(bad)

    def test_summary_and_quantile_labels(self):
        sk = QuantileSketch(0.01)
        for v in (1.0, 2.0, 3.0):
            sk.add(v)
        s = sk.summary()
        assert s["count"] == 3
        assert s["mean"] == pytest.approx(2.0)
        assert set(s) >= {"p50", "p95", "p99", "min", "max"}
        assert sk.quantiles((0.5,)) == {"p50": sk.quantile(0.5)}

    def test_memory_stays_sublinear(self):
        # sparse buckets: ~log(vmax/vmin)/log(gamma) entries, not O(n)
        sk = QuantileSketch(0.01)
        rng = random.Random(4)
        for _ in range(50_000):
            sk.add(10 ** rng.uniform(-3, 3))
        n_buckets = len(sk._pos) + len(sk._neg)
        assert n_buckets < 800, n_buckets

    def test_hypothesis_shim_visibility(self):
        # the property tests above silently skip without hypothesis; keep
        # that visible rather than mysterious
        assert HAVE_HYPOTHESIS in (True, False)
