"""Device memory & transfer ledger (PR 10).

Three layers of guarantees:

* **unit semantics** — charge/credit idempotency, watermark, per-key
  gauges, arm/disarm scope, registry publication;
* **byte exactness** — every h2d charge the device intersectors record
  equals the :mod:`repro.core.slabgeom` padded geometry of the dispatch
  (charged bytes == dispatched bytes, for every enum method and device
  mode including the Pallas interpreter);
* **conservation** — ``charged - credited == live`` holds across random
  interleavings of execute / evict / fault-injected sequences in all
  three engine execution modes (deterministic sweeps plus a
  hypothesis-driven program when the library is available).
"""

import numpy as np
import pytest

from repro.core.mjoin import mjoin
from repro.core.ordering import get_order
from repro.core.rig import build_rig
from repro.core.slabgeom import (padded_slab_bytes, padded_slab_shape,
                                 pow2_at_least)
from repro.data.graphs import random_labeled_graph
from repro.data.queries import random_query_from_graph
from repro.engine import Engine, EngineOptions
from repro.obs.ledger import (LEDGER, Ledger, ResidentLedger, TransferLedger,
                              get_ledger)
from repro.obs.metrics import MetricsRegistry
from repro.robust import faults
from repro.robust.errors import QueryError
from repro.testing import HAVE_HYPOTHESIS, given, settings, st


@pytest.fixture(autouse=True)
def _fresh_ledger():
    """Every test starts from a clean process-wide ledger and leaves no
    resident allocations behind (the conservation invariant is global)."""
    LEDGER.reset()
    LEDGER.arm()
    yield
    LEDGER.reset()
    LEDGER.arm()


# ---------------------------------------------------------------- unit level
def test_transfer_ledger_sites_and_keys():
    t = TransferLedger()
    t.h2d("slab_ship", 100, "g1")
    t.h2d("slab_ship", 50, "g2")
    t.h2d("index_vectors", 8, "g1")
    t.d2h("slab_ship", 30, "g1")
    assert t.h2d_bytes() == 158
    assert t.h2d_bytes(site="slab_ship") == 150
    assert t.h2d_bytes(key="g1") == 108
    assert t.h2d_bytes(site="slab_ship", key="g2") == 50
    assert t.h2d_calls(site="slab_ship") == 2
    assert t.d2h_bytes() == 30
    assert t.d2h_calls() == 1
    # zero / negative charges are ignored (no empty series)
    t.h2d("slab_ship", 0, "g1")
    assert t.h2d_calls(site="slab_ship") == 2
    rows = t.rows()
    assert ("h2d", "slab_ship", "g1", 100, 1) in rows
    assert ("d2h", "slab_ship", "g1", 30, 1) in rows


def test_transfer_ledger_disarm_stops_recording():
    led = Ledger()
    led.transfers.h2d("slab_ship", 10)
    led.disarm()
    led.transfers.h2d("slab_ship", 999)
    led.transfers.d2h("slab_ship", 999)
    assert led.transfers.h2d_bytes() == 10
    led.arm()
    led.transfers.h2d("slab_ship", 5)
    assert led.transfers.h2d_bytes() == 15
    # the resident side stays armed through disarm: conservation must
    # hold regardless of the transfer lever
    led.disarm()
    aid = led.resident.charge("g", 64)
    assert led.resident.live_bytes() == 64
    assert led.resident.credit(aid) == 64
    assert led.resident.conserved()


def test_resident_ledger_charge_credit_watermark():
    r = ResidentLedger()
    a = r.charge("g1", 1000)
    b = r.charge("g2", 500)
    assert r.live_bytes() == 1500
    assert r.live_bytes(key="g1") == 1000
    assert r.watermark_bytes == 1500
    assert r.per_key() == {"g1": 1000, "g2": 500}
    assert r.credit(a) == 1000
    # idempotent: a double credit is a no-op, not a negative balance
    assert r.credit(a) == 0
    assert r.credit(None) == 0
    assert r.live_bytes() == 500
    assert r.watermark_bytes == 1500          # high-water never recedes
    assert r.conserved()
    c = r.charge("g1", 2000)
    assert r.watermark_bytes == 2500
    r.credit(b), r.credit(c)
    assert r.live_bytes() == 0 and r.conserved()


def test_ledger_publish_and_rollup():
    led = Ledger()
    led.transfers.h2d("slab_ship", 100, "g1")
    led.transfers.d2h("index_vectors", 20, "g1")
    aid = led.resident.charge("g1", 4096)
    reg = MetricsRegistry()
    led.publish(reg)
    snap = reg.snapshot()
    assert snap['ledger_h2d_bytes{site="slab_ship"}'] == 100
    assert snap['ledger_h2d_calls{site="slab_ship"}'] == 1
    assert snap['ledger_d2h_bytes{site="index_vectors"}'] == 20
    assert snap["ledger_resident_charged_bytes"] == 4096
    assert snap["ledger_resident_live_bytes"] == 4096
    assert snap['ledger_resident_live_bytes{graph="g1"}'] == 4096
    assert snap["ledger_resident_watermark_bytes"] == 4096
    roll = led.rollup("g1")
    assert roll == {"h2d_bytes": 100, "d2h_bytes": 20,
                    "resident_live_bytes": 4096,
                    "resident_watermark_bytes": 4096}
    # crediting everything drops the per-graph gauge to 0 (not frozen)
    led.resident.credit(aid)
    led.publish(reg)
    snap = reg.snapshot()
    assert snap["ledger_resident_live_bytes"] == 0
    assert snap['ledger_resident_live_bytes{graph="g1"}'] == 0
    assert snap["ledger_resident_credited_bytes"] == 4096


# -------------------------------------------------- byte exactness (device)
jax = pytest.importorskip("jax")


def _workload(n=700, seed=5):
    g = random_labeled_graph(n, avg_degree=3.0, n_labels=2, seed=seed)
    g.reachability()
    g.adj_bits(), g.adj_bits_t()
    q = random_query_from_graph(g, n_nodes=3, qtype="D", seed=seed)
    return g, q.transitive_reduction()


@pytest.mark.parametrize("mode", ["xla", "interpret"])
def test_device_intersector_charges_padded_slab_bytes(mode):
    from repro.jaxgm.frontier import DeviceIntersector
    di = DeviceIntersector(mode=mode)
    di.ledger_key = "gx"
    led = get_ledger().transfers
    rng = np.random.default_rng(0)
    total_h2d = 0
    for f, k, w64 in ((5, 3, 2), (130, 1, 1), (64, 4, 3)):
        rows = rng.integers(0, 2**63, size=(f, k, w64), dtype=np.uint64)
        h0 = led.h2d_bytes(site="slab_ship")
        d0 = led.d2h_bytes(site="slab_ship")
        and_rows, counts = di(rows)
        # charged h2d equals the slabgeom padded allocation exactly
        assert (led.h2d_bytes(site="slab_ship") - h0
                == padded_slab_bytes(f, k, w64))
        # d2h is the padded AND-row page plus the counts vector
        fp, _kp, wp = padded_slab_shape(f, k, w64)
        dd = led.d2h_bytes(site="slab_ship") - d0
        assert fp * wp * 4 < dd <= fp * wp * 4 + fp * 8
        assert and_rows.shape == (f, w64) and len(counts) == f
        total_h2d += padded_slab_bytes(f, k, w64)
    # the intersector's own cumulative counter agrees with the ledger
    assert di.h2d_bytes == total_h2d == led.h2d_bytes(site="slab_ship",
                                                      key="gx")
    assert di.d2h_bytes == led.d2h_bytes(site="slab_ship", key="gx")


@pytest.mark.parametrize("mode", ["xla", "interpret"])
def test_resident_intersector_upload_and_index_bytes(mode):
    from repro.jaxgm import frontier as fr
    g, qr = _workload()
    g.graph_key = "tenant-a"
    rig = build_rig(g, qr)
    led = get_ledger()
    old = fr.DEFAULT_MODE
    fr.DEFAULT_MODE = mode
    try:
        res = fr.ResidentIntersector.build(rig)
    finally:
        fr.DEFAULT_MODE = old
    try:
        # upload charge: exactly the packed uint32 matrix footprint, on
        # both the transfer ledger and the resident ledger, per key
        assert res.nbytes == int(res.matrix.size) * 4
        assert led.transfers.h2d_bytes(site="resident_upload",
                                       key="tenant-a") == res.nbytes
        assert led.resident.live_bytes(key="tenant-a") == res.nbytes
        assert led.resident.watermark_bytes == res.nbytes
        # per-level dispatch: the padded (F, K) int32 index vector
        cs = [(0, 0, True)]
        w64 = rig.fwd[0].shape[1]               # level's packed row width
        slab = np.arange(5, dtype=np.int64).reshape(5, 1)
        h0 = led.transfers.h2d_bytes(site="index_vectors")
        res.intersect(cs, slab, w64)
        charged = led.transfers.h2d_bytes(site="index_vectors") - h0
        assert charged == pow2_at_least(len(slab)) * len(cs) * 4
        assert res.h2d_bytes == charged
        assert led.transfers.d2h_bytes(site="index_vectors") > 0
    finally:
        freed = res.close()
    assert freed == res.nbytes
    assert res.closed and res.close() == 0       # close is idempotent
    assert led.resident.live_bytes() == 0 and led.resident.conserved()


@pytest.mark.parametrize("method", ["backtrack", "frontier",
                                    "frontier-device",
                                    "frontier-device-resident"])
def test_mjoin_stats_bytes_match_ledger(method):
    """Per-query MJoinStats byte deltas reconcile with the process ledger,
    and the host-only enumerators move zero bytes."""
    g, qr = _workload()
    g.graph_key = "gm"
    rig = build_rig(g, qr)
    order = get_order(rig, "jo")
    led = get_ledger().transfers
    h0, d0 = led.h2d_bytes(), led.d2h_bytes()
    s0 = led.h2d_bytes(site="slab_ship")
    res = mjoin(rig, order, materialize=False, method=method)
    dh, dd = led.h2d_bytes() - h0, led.d2h_bytes() - d0
    if method in ("backtrack", "frontier"):
        assert res.stats.h2d_bytes == 0 and dh == 0
        assert res.stats.d2h_bytes == 0 and dd == 0
    elif method == "frontier-device":
        assert res.stats.h2d_bytes == dh > 0
        assert res.stats.d2h_bytes == dd > 0
        # the shared slab intersector attributes under its (engine-set)
        # ledger key; a direct mjoin call lands on the anonymous key but
        # the site total still reconciles byte-for-byte
        assert dh == led.h2d_bytes(site="slab_ship") - s0
    else:
        # the per-query stats fold the one-time upload plus the per-level
        # index vectors — exactly what the ledger charged under this key
        assert res.stats.h2d_bytes == dh > 0
        upload = led.h2d_bytes(site="resident_upload", key="gm")
        idx = led.h2d_bytes(site="index_vectors", key="gm")
        assert dh == upload + idx and upload > 0
        rig.release_resident()
    assert get_ledger().resident.conserved()


def test_resident_release_is_conserving():
    g, qr = _workload()
    rig = build_rig(g, qr)
    order = get_order(rig, "jo")
    mjoin(rig, order, materialize=False, method="frontier-device-resident")
    led = get_ledger().resident
    assert led.live_bytes() > 0
    freed = rig.release_resident()
    assert freed > 0 and rig.resident is None
    assert led.live_bytes() == 0 and led.conserved()
    assert rig.release_resident() == 0           # idempotent


# -------------------------------------------------- engine-level conservation
def _engine(g, **kw):
    opts = dict(frontier_device=True, force_backend="host",
                force_enum="frontier-device-resident", materialize=False,
                device_min_nodes=10**9)
    opts.update(kw)
    return Engine(g, options=EngineOptions(**opts))


_QUERIES = ["(a:L0)-//->(b:L1)", "(a:L1)-//->(b:L0)",
            "(a:L0)-/->(b:L1)-//->(c:L0)",
            "(a:L1)-//->(b:L0)-//->(c:L1)"]


def _run_program(eng, ops):
    """Interpret one op program against ``eng``; after every op the
    conservation invariant must hold."""
    led = get_ledger().resident
    for kind, arg in ops:
        try:
            if kind == "execute":
                eng.execute(_QUERIES[arg % len(_QUERIES)])
            elif kind == "stream":
                with eng.execute_stream(_QUERIES[arg % len(_QUERIES)],
                                        chunk_size=16) as s:
                    for j, _chunk in enumerate(s):
                        if arg % 2 and j >= 1:
                            break                # early close mid-iteration
            elif kind == "many":
                eng.execute_many([_QUERIES[(arg + i) % len(_QUERIES)]
                                  for i in range(3)])
            elif kind == "evict":
                eng._plan_cache.clear()
            elif kind == "fault":
                with faults.inject(faults.every("device_dispatch", k=1,
                                                times=2)):
                    eng.execute(_QUERIES[arg % len(_QUERIES)])
        except QueryError:
            pass
        assert led.conserved(), f"conservation broken after {kind}"


_OPS = ("execute", "stream", "many", "evict", "fault")


def test_conservation_deterministic_program():
    g = random_labeled_graph(700, avg_degree=3.0, n_labels=2, seed=9)
    eng = _engine(g)
    rng = np.random.default_rng(42)
    ops = [(_OPS[rng.integers(len(_OPS))], int(rng.integers(8)))
           for _ in range(24)]
    # make sure every op kind appears at least once
    ops += [(k, 1) for k in _OPS]
    _run_program(eng, ops)
    led = get_ledger()
    eng._plan_cache.clear()
    assert led.resident.live_bytes() == 0
    assert led.resident.conserved()
    # charged == credited after full teardown
    assert (led.resident.charged_bytes
            == led.resident.credited_bytes > 0)


@pytest.mark.parametrize("mode", ["execute", "stream", "many"])
def test_conservation_each_exec_mode(mode):
    g = random_labeled_graph(700, avg_degree=3.0, n_labels=2, seed=9)
    eng = _engine(g)
    _run_program(eng, [(mode, i) for i in range(6)] + [("evict", 0),
                                                       (mode, 1)])
    eng._plan_cache.clear()
    assert get_ledger().resident.live_bytes() == 0


def test_conservation_under_plan_cache_capacity_pressure():
    """A 2-entry plan cache churns resident executors through capacity
    evictions; every eviction credits the ledger."""
    g = random_labeled_graph(700, avg_degree=3.0, n_labels=2, seed=9)
    eng = _engine(g, plan_cache_size=2)
    led = get_ledger().resident
    for i in range(10):
        eng.execute(_QUERIES[i % len(_QUERIES)])
        assert led.conserved()
    evicted = eng.metrics.counter("cache_resident_evicted_bytes").value
    assert evicted > 0
    # at most plan_cache_size executors are live at any point
    assert led.live_bytes() <= 2 * max(
        e[1] for e in led._live.values()) if led._live else True
    eng._plan_cache.clear()
    assert led.live_bytes() == 0 and led.conserved()


if HAVE_HYPOTHESIS:
    _G = random_labeled_graph(600, avg_degree=3.0, n_labels=2, seed=13)

    @given(st.lists(st.tuples(st.sampled_from(_OPS),
                              st.integers(min_value=0, max_value=7)),
                    min_size=1, max_size=12))
    @settings(max_examples=15, deadline=None)
    def test_conservation_property(ops):
        LEDGER.reset()
        eng = _engine(_G, plan_cache_size=3)
        _run_program(eng, ops)
        eng._plan_cache.clear()
        assert get_ledger().resident.live_bytes() == 0
        assert get_ledger().resident.conserved()
