"""End-to-end behaviour tests for the paper's system."""

import numpy as np
import pytest

from repro.core import GM, GMOptions
from repro.core.bruteforce import answer_set, brute_force_answers
from repro.core.graph import paper_example_graph
from repro.core.query import paper_example_query
from repro.data.graphs import random_labeled_graph
from repro.data.queries import random_query_from_graph


def test_paper_running_example_end_to_end():
    """Fig. 1: build the graph, run the full GM pipeline, check the answer
    against brute force and the occurrence-set definition."""
    g = paper_example_graph()
    q = paper_example_query()
    res = GM(g).match(q)
    want = answer_set(brute_force_answers(g, q))
    assert answer_set(res.tuples) == want
    assert res.count == len(want) > 0
    assert res.rig_nodes > 0 and res.rig_edges > 0
    # os(q) ⊆ cos(q): every answer node survives in the RIG candidate sets
    for i in range(q.n):
        occ = set(np.unique(res.tuples[:, i]).tolist())
        cos = set(res.rig.cos_indices(i).tolist())
        assert occ <= cos


def test_query_server_survives_worker_failure():
    """Serving loop: journal + re-dispatch; all requests answered and
    counts equal the host matcher's."""
    from repro.launch.serve import QueryServer

    graph = random_labeled_graph(300, avg_degree=3.0, n_labels=6, seed=0)
    server = QueryServer(graph, batch_size=4, capacity=8192)
    queries = {}
    for i in range(8):
        q = random_query_from_graph(graph, 3 + i % 2,
                                    qtype=["C", "H", "D"][i % 3], seed=i)
        queries[i] = q
        assert server.submit(i, q)
    server.step(fail=True)          # a worker dies mid-batch
    server.drain()
    gm = GM(graph, GMOptions(materialize=False))
    for i, q in queries.items():
        r = server.journal[i]
        assert r.done, f"request {i} not served"
        assert r.count == gm.match(q).count
    assert server.stats["redispatched"] > 0


def test_training_end_to_end_with_crash_resume(tmp_path):
    """Tiny LM trained through a simulated crash: loss decreases and the
    resumed run is bit-identical to an uninterrupted one."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data.tokens import TokenPipeline, TokenPipelineConfig
    from repro.models import transformer as tf
    from repro.train import (AdamWConfig, ElasticConfig, ElasticTrainer,
                             SimulatedFailure)
    from repro.train import optimizer as opt

    cfg = get_config("qwen2-7b").smoke_config()
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=4, total_steps=40,
                       weight_decay=0.0)
    pipe = TokenPipeline(TokenPipelineConfig(vocab=cfg.vocab, batch=8,
                                             seq_len=32, seed=0))

    def init_state():
        params = tf.init_params(cfg, jax.random.key(0))
        return {"params": params, "opt": opt.init_state(params)}

    @jax.jit
    def step(state, batch):
        loss, grads = jax.value_and_grad(tf.loss_fn)(state["params"], batch,
                                                     cfg)
        params, ostate, m = opt.apply_updates(state["params"], grads,
                                              state["opt"], ocfg)
        m["loss"] = loss
        return {"params": params, "opt": ostate}, m

    def make(d):
        return ElasticTrainer(
            step_fn=step,
            make_batch=lambda i: jax.tree.map(jnp.asarray, pipe.batch_at(i)),
            init_state=init_state,
            cfg=ElasticConfig(checkpoint_dir=str(d), checkpoint_every=10,
                              async_save=False),
            get_step=lambda s: int(s["opt"]["step"]))

    t = make(tmp_path / "a")
    t.start_or_resume()
    out = t.run(30)
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0]
    w_straight = np.asarray(t.state["params"]["embed"], np.float32)

    t2 = make(tmp_path / "b")
    t2.start_or_resume()
    with pytest.raises(SimulatedFailure):
        t2.run(30, fail_at=10)
    t3 = make(tmp_path / "b")
    info = t3.start_or_resume()
    assert info["resumed"]
    t3.run(30)
    w_resumed = np.asarray(t3.state["params"]["embed"], np.float32)
    np.testing.assert_allclose(w_resumed, w_straight, rtol=1e-5, atol=1e-6)
