"""Bench regression-gate tests: tolerance bands, asserted rows, mode
mismatch downgrade, the history trajectory, and baseline provenance."""

from __future__ import annotations

import copy
import json

import pytest

from benchmarks.regress import (append_history, compare, load_payload,
                                main as regress_main)


def payload(mode="quick", **rows):
    return {
        "schema_version": 3, "bench": "engine", "mode": mode,
        "git_sha": "feedbeefcafe",
        "timestamp": "2026-01-01T00:00:00Z",
        "rows": [{"name": k, "us_per_call": float(v), "derived": {}}
                 for k, v in rows.items()],
    }


BASE = payload(warm=1000.0, cold=40000.0, tiny=4.0)


class TestCompare:
    def test_unchanged_rows_pass(self):
        r = compare(BASE, copy.deepcopy(BASE), tolerance=0.5,
                    assert_rows=["warm", "cold", "tiny"])
        assert r["ok"]
        assert all(row["verdict"] == "ok" for row in r["rows"])

    def test_within_tolerance_passes(self):
        fresh = payload(warm=1400.0, cold=40000.0, tiny=4.0)
        r = compare(BASE, fresh, tolerance=0.5, assert_rows=["warm"])
        assert r["ok"]
        assert r["rows"][0]["slowdown"] == pytest.approx(0.4)

    def test_synthetic_2x_slowdown_fails(self):
        fresh = payload(warm=2000.0, cold=40000.0, tiny=4.0)
        r = compare(BASE, fresh, tolerance=0.5, assert_rows=["warm"])
        assert not r["ok"]
        assert r["rows"][0]["verdict"] == "fail"
        assert "warm" in r["failures"][0]

    def test_unasserted_slowdown_is_informational(self):
        fresh = payload(warm=1000.0, cold=400000.0, tiny=4.0)
        r = compare(BASE, fresh, tolerance=0.5, assert_rows=["warm"])
        assert r["ok"]
        assert r["rows"][1]["verdict"] == "informational"

    def test_noise_floor_never_fails(self):
        # a 4us row regressing 10x is timer noise, not signal
        fresh = payload(warm=1000.0, cold=40000.0, tiny=40.0)
        r = compare(BASE, fresh, tolerance=0.5,
                    assert_rows=["tiny"], min_us=50.0)
        assert r["ok"]
        assert r["rows"][2]["verdict"] == "informational"

    def test_mode_mismatch_downgrades_everything(self):
        fresh = payload(mode="full", warm=9000.0, cold=40000.0, tiny=4.0)
        r = compare(BASE, fresh, tolerance=0.5, assert_rows=["warm"])
        assert r["ok"] and r["mode_mismatch"]
        assert r["rows"][0]["verdict"] == "informational"

    def test_new_and_missing_rows(self):
        fresh = payload(warm=1000.0, cold=40000.0, fresh_only=7.0)
        r = compare(BASE, fresh, assert_rows=[])
        verdicts = {row["name"]: row["verdict"] for row in r["rows"]}
        assert verdicts["tiny"] == "missing"
        assert verdicts["fresh_only"] == "new"
        assert r["ok"]                       # neither was asserted

    def test_asserted_missing_row_fails(self):
        fresh = payload(warm=1000.0, cold=40000.0)
        r = compare(BASE, fresh, assert_rows=["tiny"])
        assert not r["ok"]
        assert "missing" in r["failures"][0]

    def test_speedup_is_ok(self):
        fresh = payload(warm=200.0, cold=40000.0, tiny=4.0)
        r = compare(BASE, fresh, tolerance=0.5, assert_rows=["warm"])
        assert r["ok"]
        assert r["rows"][0]["slowdown"] < 0


class TestHistoryAndCli:
    def test_history_appends_jsonl(self, tmp_path):
        hist = tmp_path / "BENCH_history.jsonl"
        fresh = payload(warm=1100.0, cold=40000.0, tiny=4.0)
        r = compare(BASE, fresh, assert_rows=["warm"])
        append_history(str(hist), r, fresh)
        append_history(str(hist), r, fresh)
        lines = [json.loads(line) for line in
                 hist.read_text().splitlines()]
        assert len(lines) == 2
        assert lines[0]["git_sha"] == "feedbeefcafe"
        assert lines[0]["bench"] == "engine" and lines[0]["ok"]
        names = {row["name"] for row in lines[0]["rows"]}
        assert names == {"warm", "cold", "tiny"}

    def test_cli_pass_and_fail_exit_codes(self, tmp_path, capsys):
        base_p = tmp_path / "base.json"
        fresh_p = tmp_path / "fresh.json"
        hist_p = tmp_path / "hist.jsonl"
        base_p.write_text(json.dumps(BASE))
        fresh_p.write_text(json.dumps(copy.deepcopy(BASE)))
        rc = regress_main(["--baseline", str(base_p),
                           "--fresh", str(fresh_p),
                           "--assert-rows", "warm,cold",
                           "--history", str(hist_p)])
        assert rc == 0
        assert "PASS" in capsys.readouterr().out
        fresh_p.write_text(json.dumps(
            payload(warm=5000.0, cold=40000.0, tiny=4.0)))
        rc = regress_main(["--baseline", str(base_p),
                           "--fresh", str(fresh_p),
                           "--assert-rows", "warm",
                           "--history", str(hist_p)])
        assert rc == 1
        assert "FAIL" in capsys.readouterr().out
        assert len(hist_p.read_text().splitlines()) == 2

    def test_load_payload_rejects_non_bench_json(self, tmp_path):
        p = tmp_path / "x.json"
        p.write_text('{"foo": 1}')
        with pytest.raises(ValueError):
            load_payload(str(p))

    def test_committed_baselines_load(self):
        from pathlib import Path

        # the real committed artifacts stay consumable by the gate
        root = Path(__file__).resolve().parents[1]
        for name in ("BENCH_engine.json", "BENCH_mjoin.json"):
            p = load_payload(str(root / name))
            assert p["rows"] and p["mode"] in ("quick", "full")
            r = compare(p, copy.deepcopy(p),
                        assert_rows=[p["rows"][0]["name"]])
            assert r["ok"]
