import numpy as np
import pytest
from repro.testing import given, settings, st

from repro.core.bruteforce import answer_set, brute_force_answers
from repro.core.query import CHILD, DESC, PatternQuery, QueryEdge, query
from repro.data.graphs import random_labeled_graph
from repro.data.queries import random_query_from_graph


def test_paper_fig2_transitive_reduction():
    # Fig. 2(a): edges 0//1? Paper: 0/1 child, 1//3, 3//2, 0//2 (redundant).
    q = query(labels=[0, 1, 2, 3],
              edges=[(0, 1, CHILD), (1, 3, DESC), (3, 2, DESC), (0, 2, DESC)])
    tr = q.transitive_reduction()
    assert QueryEdge(0, 2, DESC) not in tr.edges
    assert QueryEdge(0, 1, CHILD) in tr.edges
    assert len(tr.edges) == 3


def test_full_form_ir1_ir2():
    q = query(labels=[0, 1, 2], edges=[(0, 1, CHILD), (1, 2, DESC)])
    ff = q.full_form()
    # IR1+IR2: 0//2 inferable
    assert QueryEdge(0, 2, DESC) in ff.edges
    # child edge preserved
    assert QueryEdge(0, 1, CHILD) in ff.edges


def test_child_edges_never_removed():
    q = query(labels=[0, 1, 2],
              edges=[(0, 1, CHILD), (1, 2, CHILD), (0, 2, CHILD)])
    tr = q.transitive_reduction()
    assert len(tr.edges) == 3


def test_child_path_justifies_removal():
    q = query(labels=[0, 1, 2],
              edges=[(0, 1, CHILD), (1, 2, CHILD), (0, 2, DESC)])
    tr = q.transitive_reduction()
    assert QueryEdge(0, 2, DESC) not in tr.edges
    assert len(tr.edges) == 2


def test_dag_decomposition_covers_edges():
    q = query(labels=[0, 1, 2, 3],
              edges=[(0, 1, DESC), (1, 2, DESC), (2, 0, DESC), (2, 3, CHILD)])
    dag, back = q.dag_decomposition()
    assert dag.is_dag()
    assert len(dag.edges) + len(back) == q.m
    assert set(dag.edges) | set(back) == set(q.edges)


def test_topological_order():
    q = query(labels=[0, 1, 2], edges=[(0, 1, CHILD), (1, 2, CHILD)])
    assert q.topological_order() == [0, 1, 2]
    qc = query(labels=[0, 1], edges=[(0, 1, CHILD), (1, 0, CHILD)])
    assert qc.topological_order() is None
    assert not qc.is_dag()


def test_connectivity():
    q = query(labels=[0, 1, 2], edges=[(0, 1, CHILD), (1, 2, DESC)])
    assert q.is_connected()


def test_dedup_child_subsumes_desc():
    q = query(labels=[0, 1], edges=[(0, 1, CHILD), (0, 1, DESC)])
    assert q.m == 1 and q.edges[0].kind == CHILD


@given(st.integers(0, 500))
@settings(max_examples=20, deadline=None)
def test_transitive_reduction_preserves_answers(seed):
    """§4: a query and its transitive reduction are equivalent — identical
    answers on any data graph."""
    graph = random_labeled_graph(30, avg_degree=1.8, n_labels=3,
                                 kind="uniform", seed=seed)
    q = random_query_from_graph(graph, n_nodes=4, qtype="D", seed=seed,
                                extra_edge_prob=0.8)
    tr = q.transitive_reduction()
    a1 = answer_set(brute_force_answers(graph, q))
    a2 = answer_set(brute_force_answers(graph, tr))
    assert a1 == a2
    assert len(tr.edges) <= len(q.edges)
