import numpy as np
import pytest
from repro.testing import given, settings, st

from repro.core import bitset


@given(st.integers(1, 300), st.integers(0, 2**32 - 1))
@settings(max_examples=50, deadline=None)
def test_pack_unpack_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random(n) < 0.4
    assert np.array_equal(bitset.unpack(bitset.pack(mask), n), mask)


@given(st.integers(1, 257))
@settings(max_examples=30, deadline=None)
def test_full_empty(n):
    assert bitset.count(bitset.full(n)) == n
    assert bitset.count(bitset.empty(n)) == 0
    assert np.array_equal(bitset.to_indices(bitset.full(n), n), np.arange(n))


def test_bit_manipulation():
    n = 130
    b = bitset.empty(n)
    bitset.set_bit(b, 0)
    bitset.set_bit(b, 63)
    bitset.set_bit(b, 64)
    bitset.set_bit(b, 129)
    assert bitset.get(b, 129) and bitset.get(b, 64)
    assert not bitset.get(b, 1)
    bitset.clear_bit(b, 64)
    assert not bitset.get(b, 64)
    assert sorted(bitset.to_indices(b, n)) == [0, 63, 129]


@given(st.integers(1, 200), st.integers(0, 2**31))
@settings(max_examples=50, deadline=None)
def test_set_algebra_matches_python_sets(n, seed):
    rng = np.random.default_rng(seed)
    a = rng.random(n) < 0.3
    b = rng.random(n) < 0.3
    sa, sb = set(np.nonzero(a)[0]), set(np.nonzero(b)[0])
    pa, pb = bitset.pack(a), bitset.pack(b)
    assert set(bitset.to_indices(pa & pb, n)) == (sa & sb)
    assert set(bitset.to_indices(pa | pb, n)) == (sa | sb)
    assert bitset.intersect_any(pa, pb) == bool(sa & sb)
    assert bitset.count(pa) == len(sa)


@given(st.integers(2, 100), st.integers(0, 2**31))
@settings(max_examples=30, deadline=None)
def test_matvec_any_matches_naive(n, seed):
    rng = np.random.default_rng(seed)
    mat = rng.random((n, n)) < 0.2
    vec = rng.random(n) < 0.3
    packed = bitset.pack(mat)
    got = bitset.matvec_any(packed, bitset.pack(vec))
    want = (mat & vec[None, :]).any(axis=1)
    assert np.array_equal(got, want)


def test_union_rows_and_intersect_many():
    rng = np.random.default_rng(0)
    n = 150
    mat = rng.random((10, n)) < 0.3
    packed = bitset.pack(mat)
    got = bitset.union_rows(packed, np.array([1, 4, 7]))
    want = mat[[1, 4, 7]].any(axis=0)
    assert np.array_equal(bitset.unpack(got, n), want)
    got2 = bitset.intersect_many(packed[[0, 2, 3]])
    want2 = mat[[0, 2, 3]].all(axis=0)
    assert np.array_equal(bitset.unpack(got2, n), want2)
