import networkx as nx
import numpy as np
import pytest
from repro.testing import given, settings, st

from repro.core import bitset
from repro.core.graph import paper_example_graph
from repro.core.reachability import (BFL, IntervalLabels, ReachabilityIndex,
                                     strongly_connected_components)
from repro.data.graphs import random_labeled_graph


def _nx_reach(graph):
    g = nx.DiGraph()
    g.add_nodes_from(range(graph.n))
    g.add_edges_from(map(tuple, graph.edges))
    on_cycle = set()
    for scc in nx.strongly_connected_components(g):
        if len(scc) > 1:
            on_cycle |= scc
    want = np.zeros((graph.n, graph.n), dtype=bool)
    for u in range(graph.n):
        for v in nx.descendants(g, u):
            want[u, v] = True
        # ≺ includes u itself exactly when u lies on a cycle (path len >= 1)
        if u in on_cycle or g.has_edge(u, u):
            want[u, u] = True
    return want


@pytest.mark.parametrize("kind", ["uniform", "powerlaw", "dag"])
@pytest.mark.parametrize("n", [10, 60, 150])
def test_closure_matches_networkx(kind, n):
    graph = random_labeled_graph(n, avg_degree=2.5, n_labels=4, kind=kind,
                                 seed=n)
    idx = ReachabilityIndex.build(graph)
    assert np.array_equal(idx.dense(), _nx_reach(graph))


@given(st.integers(2, 60), st.integers(0, 2**31))
@settings(max_examples=25, deadline=None)
def test_closure_property(n, seed):
    graph = random_labeled_graph(n, avg_degree=2.0, n_labels=3,
                                 kind="uniform", seed=seed)
    idx = ReachabilityIndex.build(graph)
    assert np.array_equal(idx.dense(), _nx_reach(graph))


def test_scc_topological_numbering():
    graph = random_labeled_graph(120, avg_degree=2.0, n_labels=4,
                                 kind="uniform", seed=7)
    comp, k = strongly_connected_components(graph)
    # comp ids must be a valid topological order of the condensation
    for (u, v) in graph.edges:
        cu, cv = comp[u], comp[v]
        if cu != cv:
            assert cu < cv


def test_transpose_consistency():
    graph = random_labeled_graph(80, avg_degree=3.0, n_labels=4, seed=3)
    idx = ReachabilityIndex.build(graph)
    dense = idx.dense()
    dense_t = bitset.unpack(idx.bits_t(), graph.n)
    assert np.array_equal(dense_t, dense.T)


def test_interval_labels_no_false_negatives():
    # On DAGs: end[u] < begin[v] must imply NOT u ≺ v.
    for seed in range(5):
        graph = random_labeled_graph(100, avg_degree=2.5, n_labels=4,
                                     kind="dag", seed=seed)
        idx = ReachabilityIndex.build(graph)
        iv = IntervalLabels.build(graph)
        reach = idx.dense()
        for u in range(graph.n):
            for v in np.nonzero(reach[u])[0]:
                assert not iv.cannot_reach(u, int(v)), (u, v)


@pytest.mark.parametrize("kind", ["uniform", "powerlaw", "dag"])
def test_bfl_exactness(kind):
    graph = random_labeled_graph(90, avg_degree=2.5, n_labels=4, kind=kind,
                                 seed=11)
    idx = ReachabilityIndex.build(graph)
    bfl = BFL.build(graph, bits=128)
    reach = idx.dense()
    for u in range(0, graph.n, 3):
        for v in range(0, graph.n, 3):
            assert bfl.reaches(u, v) == reach[u, v], (u, v)


def test_paper_example_reachability():
    g = paper_example_graph()
    idx = ReachabilityIndex.build(g)
    # a1 -> b1 -> c2 -> e1 : a1 ≺ e1
    assert idx.reaches(0, 13)
    # e1 is a sink
    assert not idx.dense()[13].any()
