"""Streaming-enumeration equivalence: chunked == one-shot, lazily.

The chunked generator API (``iter_tuples`` / ``MJoinStream``) must be a
drop-in replacement for one-shot ``mjoin``: concatenating its chunks
reproduces ``solve()``'s tuples byte-for-byte — same lexicographic order,
same counts, same truncation — for every ``enum_method`` and every chunk
size, while enumeration work is done *on demand* (early-stopping consumers
read no further frontier slabs, observable in the stats counters).  The
cross-query batcher (``mjoin_batched``) must agree with per-query counting
while fusing the per-level constraint gathers into shared dispatches.
"""

import numpy as np
import pytest

from repro.core.bruteforce import answer_set, brute_force_answers
from repro.core.mjoin import (_host_intersect_block, iter_tuples, mjoin,
                              mjoin_batched, stack_slabs)
from repro.core.ordering import get_order
from repro.core.query import CHILD, query
from repro.core.rig import build_rig
from repro.data.graphs import random_labeled_graph
from repro.data.queries import random_query_from_graph
from repro.testing import given, settings, st

try:
    import jax  # noqa: F401
    HAVE_JAX = True
except ImportError:                                   # bare interpreter
    HAVE_JAX = False

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")

HOST_METHODS = ("backtrack", "frontier")
ALL_METHODS = HOST_METHODS + (("frontier-device",) if HAVE_JAX else ())
CHUNK_SIZES = (1, 3, 64)


def _rig_order(graph, q):
    rig = build_rig(graph, q.transitive_reduction())
    order = (list(range(q.n)) if rig.is_empty() else get_order(rig, "jo"))
    return rig, order


def _collect(stream):
    chunks = list(stream)
    n = stream.rig.query.n
    cat = (np.vstack(chunks) if chunks
           else np.empty((0, n), dtype=np.int64))
    return chunks, cat


def _assert_stream_equals_solve(graph, q, methods=None, chunks=CHUNK_SIZES,
                                limit=None):
    rig, order = _rig_order(graph, q)
    ref = mjoin(rig, order, limit=limit, max_tuples=10**9)
    for method in methods or ALL_METHODS:
        for k in chunks:
            stream = iter_tuples(rig, order, chunk_size=k, limit=limit,
                                 method=method)
            got_chunks, got = _collect(stream)
            assert np.array_equal(got, ref.tuples), (method, k)
            assert stream.count == ref.count, (method, k)
            assert stream.stats.truncated == ref.stats.truncated
            # fixed-size chunks: every chunk but the last has exactly k rows
            assert all(len(c) == k for c in got_chunks[:-1]), (method, k)
            if got_chunks:
                assert 0 < len(got_chunks[-1]) <= k
    return ref


# ------------------------------------------------- chunked == one-shot
@pytest.mark.parametrize("qtype", ["C", "H", "D"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_stream_equals_solve_all_methods(qtype, seed):
    graph = random_labeled_graph(55, avg_degree=2.4, n_labels=4, seed=seed)
    q = random_query_from_graph(graph, n_nodes=4, qtype=qtype, seed=seed + 20)
    ref = _assert_stream_equals_solve(graph, q)
    # sanity: the reference agrees with brute force
    assert answer_set(ref.tuples) == answer_set(brute_force_answers(graph, q))


@given(st.integers(0, 10_000), st.sampled_from(["C", "H", "D"]),
       st.sampled_from(CHUNK_SIZES), st.sampled_from(ALL_METHODS))
@settings(max_examples=20, deadline=None)
def test_stream_equivalence_property(seed, qtype, chunk, method):
    graph = random_labeled_graph(40, avg_degree=2.0, n_labels=5,
                                 kind="uniform", seed=seed % 89)
    q = random_query_from_graph(graph, n_nodes=3 + seed % 3, qtype=qtype,
                                seed=seed)
    rig, order = _rig_order(graph, q)
    ref = mjoin(rig, order, limit=None)
    stream = iter_tuples(rig, order, chunk_size=chunk, limit=None,
                         method=method)
    _, got = _collect(stream)
    assert np.array_equal(got, ref.tuples)
    assert stream.count == ref.count


@needs_jax
@pytest.mark.parametrize("seed", [0, 1])
def test_stream_device_interpret_equivalence(seed):
    graph = random_labeled_graph(60, avg_degree=2.5, n_labels=3, seed=seed)
    q = random_query_from_graph(graph, n_nodes=4, qtype="H", seed=seed + 5)
    _assert_stream_equals_solve(graph, q, methods=("frontier-device",),
                                chunks=(3, 64))


# ------------------------------------------------------- limit semantics
def test_stream_limit_mid_chunk_exact():
    graph = random_labeled_graph(80, avg_degree=3.0, n_labels=2, seed=3)
    q = random_query_from_graph(graph, n_nodes=3, qtype="D", seed=4)
    rig, order = _rig_order(graph, q)
    full = mjoin(rig, order, limit=None)
    assert full.count > 70
    for method in ALL_METHODS:
        for lim in (1, 10, full.count, full.count + 1):
            stream = iter_tuples(rig, order, chunk_size=64, limit=lim,
                                 method=method)
            _, got = _collect(stream)
            want = min(lim, full.count)
            # no over-yield from the last slab: exactly `limit` rows out
            assert len(got) == want, (method, lim)
            assert stream.count == want
            assert np.array_equal(got, full.tuples[:want])
            assert stream.stats.truncated == (full.count >= lim)


def test_stream_limit_zero():
    graph = random_labeled_graph(40, avg_degree=2.0, n_labels=2, seed=1)
    q = random_query_from_graph(graph, n_nodes=3, qtype="C", seed=2)
    rig, order = _rig_order(graph, q)
    for method in HOST_METHODS:
        stream = iter_tuples(rig, order, chunk_size=4, limit=0,
                             method=method)
        assert list(stream) == []
        assert stream.count == 0 and stream.stats.truncated


# --------------------------------------------------- laziness / pushdown
def test_stream_early_stop_skips_frontier_slabs():
    graph = random_labeled_graph(80, avg_degree=3.0, n_labels=2, seed=3)
    q = random_query_from_graph(graph, n_nodes=3, qtype="D", seed=4)
    rig, order = _rig_order(graph, q)
    # tiny slabs so the last level needs many gather rounds
    full = iter_tuples(rig, order, chunk_size=8, limit=None,
                       method="frontier", slab_rows=4)
    list(full)
    early = iter_tuples(rig, order, chunk_size=8, limit=None,
                        method="frontier", slab_rows=4)
    next(iter(early))
    early.close()
    assert early.stats.intersections < full.stats.intersections
    # a limit has the same effect without the consumer stopping by itself
    limited = iter_tuples(rig, order, chunk_size=8, limit=8,
                          method="frontier", slab_rows=4)
    list(limited)
    assert limited.stats.truncated
    assert limited.stats.intersections < full.stats.intersections


@needs_jax
def test_stream_early_stop_skips_device_dispatches():
    graph = random_labeled_graph(80, avg_degree=3.0, n_labels=2, seed=3)
    q = random_query_from_graph(graph, n_nodes=3, qtype="D", seed=4)
    rig, order = _rig_order(graph, q)
    full = iter_tuples(rig, order, chunk_size=8, limit=None,
                       method="frontier-device", slab_rows=4)
    list(full)
    early = iter_tuples(rig, order, chunk_size=8, limit=None,
                        method="frontier-device", slab_rows=4)
    next(iter(early))
    early.close()
    assert full.stats.device_calls > 1
    assert early.stats.device_calls < full.stats.device_calls


def test_stream_backtrack_early_stop_suspends_search():
    graph = random_labeled_graph(80, avg_degree=3.0, n_labels=2, seed=3)
    q = random_query_from_graph(graph, n_nodes=3, qtype="D", seed=4)
    rig, order = _rig_order(graph, q)
    full = iter_tuples(rig, order, chunk_size=4, method="backtrack")
    list(full)
    early = iter_tuples(rig, order, chunk_size=4, method="backtrack")
    next(iter(early))
    early.close()
    assert early.stats.expanded < full.stats.expanded


# ------------------------------------------------------------ edge cases
def test_stream_empty_rig():
    graph = random_labeled_graph(50, avg_degree=2.0, n_labels=3, seed=5)
    q = query(labels=[0, 99], edges=[(0, 1, CHILD)])
    rig, order = _rig_order(graph, q)
    for method in HOST_METHODS:
        stream = iter_tuples(rig, order, chunk_size=4, method=method)
        assert list(stream) == []
        assert stream.count == 0 and not stream.stats.truncated


def test_stream_single_node_query():
    graph = random_labeled_graph(40, avg_degree=2.0, n_labels=3, seed=6)
    q = query(labels=[1], edges=[])
    _assert_stream_equals_solve(graph, q, methods=HOST_METHODS)
    _assert_stream_equals_solve(graph, q, methods=HOST_METHODS, limit=2)


def test_stream_disconnected_pattern():
    graph = random_labeled_graph(30, avg_degree=2.0, n_labels=3, seed=7)
    q = query(labels=[0, 1], edges=[])                  # cartesian product
    _assert_stream_equals_solve(graph, q, methods=HOST_METHODS)


def test_stream_overflow_falls_back_to_backtrack():
    graph = random_labeled_graph(80, avg_degree=3.0, n_labels=2, seed=3)
    q = random_query_from_graph(graph, n_nodes=3, qtype="D", seed=4)
    rig, order = _rig_order(graph, q)
    ref = mjoin(rig, order, limit=None)
    stream = iter_tuples(rig, order, chunk_size=16, limit=None,
                         method="frontier", max_frontier=2)
    _, got = _collect(stream)
    assert stream.stats.method == "backtrack"           # fell back
    assert np.array_equal(got, ref.tuples)


def test_stream_rejects_bad_arguments():
    graph = random_labeled_graph(20, avg_degree=2.0, n_labels=2, seed=0)
    q = random_query_from_graph(graph, n_nodes=3, qtype="C", seed=1)
    rig, order = _rig_order(graph, q)
    with pytest.raises(ValueError):
        iter_tuples(rig, order, method="nope")
    with pytest.raises(ValueError):
        iter_tuples(rig, order, chunk_size=0)


# ----------------------------------------------------- cross-query batch
def _batch_jobs(graph, queries, limit=None):
    jobs = []
    for q in queries:
        rig, order = _rig_order(graph, q)
        jobs.append((rig, order, limit))
    return jobs


def test_mjoin_batched_matches_singles():
    graph = random_labeled_graph(60, avg_degree=2.5, n_labels=3, seed=1)
    qs = [random_query_from_graph(graph, n_nodes=n, qtype=t, seed=s)
          for n, t, s in [(3, "C", 2), (4, "H", 3), (3, "D", 4), (4, "D", 5)]]
    jobs = _batch_jobs(graph, qs)
    results, dispatches = mjoin_batched(jobs)
    assert dispatches >= 1
    per_query_calls = 0
    for (rig, order, _), res in zip(jobs, results):
        one = mjoin(rig, order, limit=None, materialize=False,
                    method="frontier")
        assert res.count == one.count
        assert res.stats.truncated == one.stats.truncated
        per_query_calls += max(res.stats.device_calls, 1)
    # micro-batching: fused dispatches, not one per query per level
    assert dispatches < per_query_calls


def test_mjoin_batched_respects_per_job_limits():
    graph = random_labeled_graph(80, avg_degree=3.0, n_labels=2, seed=3)
    q = random_query_from_graph(graph, n_nodes=3, qtype="D", seed=4)
    rig, order = _rig_order(graph, q)
    full = mjoin(rig, order, limit=None, materialize=False).count
    assert full > 10
    results, _ = mjoin_batched([(rig, order, 5), (rig, order, None),
                                (rig, order, full + 1)])
    assert [r.count for r in results] == [5, full, full]
    assert [r.stats.truncated for r in results] == [True, False, False]


def test_mjoin_batched_empty_rig_and_overflow_jobs():
    graph = random_labeled_graph(60, avg_degree=2.5, n_labels=3, seed=1)
    q_empty = query(labels=[0, 99], edges=[(0, 1, CHILD)])
    q_big = random_query_from_graph(graph, n_nodes=3, qtype="D", seed=4)
    rig_e, order_e = _rig_order(graph, q_empty)
    rig_b, order_b = _rig_order(graph, q_big)
    want = mjoin(rig_b, order_b, limit=None, materialize=False).count
    results, _ = mjoin_batched([(rig_e, order_e, None),
                                (rig_b, order_b, None)],
                               max_frontier=2)       # forces overflow
    assert results[0].count == 0
    assert results[1].count == want
    assert results[1].stats.method == "backtrack"    # per-job fallback


@needs_jax
def test_mjoin_batched_device_intersector():
    from repro.core.mjoin import device_intersector
    graph = random_labeled_graph(60, avg_degree=2.5, n_labels=3, seed=1)
    qs = [random_query_from_graph(graph, n_nodes=3, qtype=t, seed=s)
          for t, s in [("C", 2), ("H", 3), ("D", 4)]]
    jobs = _batch_jobs(graph, qs)
    host_res, host_disp = mjoin_batched(jobs)
    inter = device_intersector()
    assert inter is not None
    before = inter.calls
    dev_res, dev_disp = mjoin_batched(jobs, intersector=inter)
    assert inter.calls - before == dev_disp          # one kernel call each
    for h, d in zip(host_res, dev_res):
        assert h.count == d.count
    assert dev_res[0].stats.method == "frontier-device"


def test_stack_slabs_is_and_exact():
    rng = np.random.default_rng(0)
    blocks = [rng.integers(0, 2**63, size=(f, k, w), dtype=np.uint64)
              for f, k, w in [(3, 1, 2), (5, 3, 1), (2, 2, 4)]]
    big, spans = stack_slabs(blocks)
    acc, counts = _host_intersect_block(big)
    for b, (off, f, k, w) in zip(blocks, spans):
        want = np.bitwise_and.reduce(b, axis=1)
        assert np.array_equal(acc[off:off + f, :w], want)
        assert np.array_equal(counts[off:off + f],
                              np.bitwise_count(want).sum(axis=1))
        # padding contributes no bits beyond each job's own words
        assert not acc[off:off + f, w:].any()


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_mjoin_batched_property(seed):
    graph = random_labeled_graph(40, avg_degree=2.0, n_labels=4,
                                 kind="uniform", seed=seed % 53)
    qs = [random_query_from_graph(graph, n_nodes=3 + (seed + i) % 2,
                                  qtype=["C", "H", "D"][(seed + i) % 3],
                                  seed=seed + 7 * i) for i in range(3)]
    jobs = _batch_jobs(graph, qs)
    results, _ = mjoin_batched(jobs)
    for (rig, order, _), res in zip(jobs, results):
        assert res.count == mjoin(rig, order, limit=None,
                                  materialize=False).count
