import numpy as np
import pytest
from repro.testing import given, settings, st

from repro.core import bitset
from repro.core.bruteforce import brute_force_answers
from repro.core.graph import paper_example_graph
from repro.core.query import paper_example_query
from repro.core.simulation import (EdgeOracle, fb_sim, fb_sim_bas, fb_sim_dag,
                                   match_sets)
from repro.data.graphs import random_labeled_graph
from repro.data.queries import random_query_from_graph


def _occurrence_sets(graph, q):
    """os(q) per query node from the brute-force answer."""
    ans = brute_force_answers(graph, q)
    out = []
    for i in range(q.n):
        mask = np.zeros(graph.n, dtype=bool)
        if len(ans):
            mask[np.unique(ans[:, i])] = True
        out.append(mask)
    return out


@pytest.mark.parametrize("algo", ["bas", "dag"])
@pytest.mark.parametrize("method", ["binsearch", "bititer", "bitbat"])
def test_soundness_os_subset_fb_subset_ms(algo, method):
    graph = random_labeled_graph(60, avg_degree=2.5, n_labels=3, seed=1)
    q = random_query_from_graph(graph, n_nodes=4, qtype="H", seed=2)
    fn = fb_sim_bas if algo == "bas" else fb_sim
    res = fn(graph, q, method=method)
    os_ = _occurrence_sets(graph, q)
    ms = match_sets(graph, q)
    for i in range(q.n):
        fb = bitset.unpack(res.fb[i], graph.n)
        assert (~fb[~bitset.unpack(ms[i], graph.n)]).all() or \
            not fb[~bitset.unpack(ms[i], graph.n)].any()   # FB ⊆ ms
        assert not (os_[i] & ~fb).any(), f"os(q{i}) ⊄ FB(q{i})"  # os ⊆ FB


@given(st.integers(0, 300))
@settings(max_examples=15, deadline=None)
def test_fixpoint_is_order_independent(seed):
    """Double simulation is the unique largest relation — FBSimBas and
    FBSim(Dag+Δ) must converge to identical fixpoints."""
    graph = random_labeled_graph(50, avg_degree=2.2, n_labels=3, seed=seed)
    q = random_query_from_graph(graph, n_nodes=4, qtype="H", seed=seed + 1)
    r1 = fb_sim_bas(graph, q, max_passes=None, method="bitbat")
    r2 = fb_sim(graph, q, max_passes=None, method="bitbat")
    assert r1.converged and r2.converged
    for a, b in zip(r1.fb, r2.fb):
        assert np.array_equal(a, b)


@given(st.integers(0, 300))
@settings(max_examples=15, deadline=None)
def test_check_methods_agree(seed):
    graph = random_labeled_graph(50, avg_degree=2.2, n_labels=3, seed=seed)
    q = random_query_from_graph(graph, n_nodes=4, qtype="H", seed=seed + 7)
    results = [fb_sim_bas(graph, q, method=m).fb
               for m in ("binsearch", "bititer", "bitbat")]
    for fb in results[1:]:
        for a, b in zip(results[0], fb):
            assert np.array_equal(a, b)


def test_truncated_passes_still_sound():
    graph = random_labeled_graph(60, avg_degree=2.5, n_labels=3, seed=5)
    q = random_query_from_graph(graph, n_nodes=5, qtype="H", seed=6)
    res = fb_sim(graph, q, max_passes=1)
    os_ = _occurrence_sets(graph, q)
    for i in range(q.n):
        fb = bitset.unpack(res.fb[i], graph.n)
        assert not (os_[i] & ~fb).any()


def test_dag_converges_in_one_pass_for_tree_patterns():
    # §5.4: when Q is a tree, a single Dag pass reaches the fixpoint
    # (detected at pass 2 with no change).
    graph = random_labeled_graph(80, avg_degree=2.5, n_labels=3, seed=9)
    q = random_query_from_graph(graph, n_nodes=4, qtype="H", seed=10,
                                extra_edge_prob=0.0)
    res = fb_sim_dag(graph, q, method="bitbat", use_change_flags=False)
    assert res.converged and res.passes <= 2


def test_paper_example_simulation_nonempty():
    g = paper_example_graph()
    q = paper_example_query()
    res = fb_sim(g, q)
    assert res.converged
    assert all(bitset.count(b) > 0 for b in res.fb)
