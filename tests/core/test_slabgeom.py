"""Padding geometry (repro.core.slabgeom) and the padded-budget fix.

The device intersector pads every dispatch (F -> pow2 >= 128 rows,
K -> pow2, W -> 128-lane multiples).  ``Budget.max_slab_bytes`` used to
charge the *logical* (F, K, W) slab size, so a deliberately ragged slab
(tiny K and W) could allocate many times the cap on device.  The cap now
bounds the padded allocation via :func:`slabgeom.padded_rows_cap`.
"""

import numpy as np
import pytest

from repro.core import slabgeom
from repro.core.mjoin import device_intersector, mjoin
from repro.core.ordering import get_order
from repro.core.rig import build_rig
from repro.data.graphs import random_labeled_graph
from repro.data.queries import random_query_from_graph
from repro.robust import Budget

try:
    import jax  # noqa: F401
    HAVE_JAX = True
except ImportError:
    HAVE_JAX = False

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")


# ---------------------------------------------------------- pure geometry
def test_round_up_and_pow2():
    assert slabgeom.round_up(0, 128) == 0
    assert slabgeom.round_up(1, 128) == 128
    assert slabgeom.round_up(128, 128) == 128
    assert slabgeom.round_up(129, 128) == 256
    assert slabgeom.pow2_at_least(0) == 128       # row floor
    assert slabgeom.pow2_at_least(128) == 128
    assert slabgeom.pow2_at_least(129) == 256
    assert slabgeom.pow2_at_least(3, floor=1) == 4


def test_padded_slab_shape_floors():
    fp, kp, wp = slabgeom.padded_slab_shape(5, 3, 1)
    assert fp == 128 and kp == 4 and wp == 128    # 2*w64=2 lanes -> 128
    fp, kp, wp = slabgeom.padded_slab_shape(200, 2, 70)
    assert fp == 256 and kp == 2 and wp == 256    # 140 lanes -> 256


def test_padded_bytes_vs_logical_on_ragged_slab():
    # K=1, W=1 word: logical 8 B/row, padded 128 uint32 lanes = 512 B/row
    logical = 100 * 1 * 1 * 8
    padded = slabgeom.padded_slab_bytes(100, 1, 1)
    assert padded == 128 * 1 * 128 * 4
    assert padded > 30 * logical                  # the overspend being fixed


def test_padded_rows_cap():
    # minimal dispatch (128 rows, K=1, W=1) is exactly 64 KiB
    assert slabgeom.padded_slab_bytes(128, 1, 1) == 65536
    assert slabgeom.padded_rows_cap(65536, 1, 1, 10_000) == 128
    assert slabgeom.padded_rows_cap(65535, 1, 1, 10_000) == 0   # infeasible
    assert slabgeom.padded_rows_cap(2 * 65536, 1, 1, 10_000) == 256
    # at_most clips below the floor without zeroing
    assert slabgeom.padded_rows_cap(1 << 30, 1, 1, 100) == 100


def test_resident_dispatch_geometry():
    # per padded row: K idx + W lanes + 1 count, 4 B each
    assert slabgeom.resident_dispatch_bytes(100, 2, 128) \
        == 128 * (2 + 128 + 1) * 4
    assert slabgeom.resident_rows_cap(
        slabgeom.resident_dispatch_bytes(128, 2, 128), 2, 128, 10_000) == 128
    assert slabgeom.resident_rows_cap(100, 2, 128, 10_000) == 0


# ------------------------------------------------- padded budget regression
@needs_jax
def test_ragged_slab_budget_charges_padded_shape():
    """Satellite regression: with max_slab_bytes set to exactly the minimal
    padded dispatch, the governed frontier-device path must keep every
    dispatch within the cap (the old logical charge allowed ~64x more
    rows) and record the chunked-slabs degradation."""
    graph = random_labeled_graph(80, avg_degree=3.0, n_labels=2, seed=3)
    q = random_query_from_graph(graph, n_nodes=3, qtype="D", seed=4)
    rig = build_rig(graph, q.transitive_reduction())
    order = get_order(rig, "jo")
    ref = mjoin(rig, order, limit=None)

    di = device_intersector()
    assert di is not None
    cap = slabgeom.padded_slab_bytes(128, 1, rig.fwd[0].shape[1])
    # pick the cap from the widest level actually dispatched: K can be 1
    # or 2 here, so allow the minimal dispatch of the larger K as well
    cap = max(cap, slabgeom.padded_slab_bytes(128, 2, rig.fwd[0].shape[1]))

    di.peak_slab_bytes = 0
    b = Budget(max_slab_bytes=cap).start()
    got = mjoin(rig, order, limit=None, method="frontier-device", budget=b)
    assert got.count == ref.count
    assert np.array_equal(got.tuples, ref.tuples)
    assert got.stats.device_calls > 0             # stayed on device...
    assert di.peak_slab_bytes <= cap              # ...inside the cap
    # the logical charge would have allowed far taller slabs than the
    # padded cap permits, so the run must have chunked
    assert "chunked-slabs" in got.stats.degradations


@needs_jax
def test_infeasible_padded_cap_degrades_to_host():
    """A cap below even the minimal 128-row padded dispatch cannot be
    honoured on device: the query degrades to the host intersect."""
    graph = random_labeled_graph(80, avg_degree=3.0, n_labels=2, seed=3)
    q = random_query_from_graph(graph, n_nodes=3, qtype="D", seed=4)
    rig = build_rig(graph, q.transitive_reduction())
    order = get_order(rig, "jo")
    ref = mjoin(rig, order, limit=None)
    b = Budget(max_slab_bytes=1024).start()
    got = mjoin(rig, order, limit=None, method="frontier-device", budget=b)
    assert got.count == ref.count
    assert np.array_equal(got.tuples, ref.tuples)
    assert got.stats.device_calls == 0
    assert "host-intersect" in got.stats.degradations
