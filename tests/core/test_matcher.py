"""End-to-end GM correctness: GM == brute force == JM == TM, across query
types, structures, and option variants (the central soundness+completeness
property of the whole paper pipeline)."""

import numpy as np
import pytest
from repro.testing import given, settings, st

from repro.core import GM, GMOptions, match
from repro.core.baselines import jm_match, tm_match
from repro.core.bruteforce import answer_set, brute_force_answers
from repro.core.graph import paper_example_graph
from repro.core.query import CHILD, DESC, paper_example_query, query
from repro.data.graphs import random_labeled_graph
from repro.data.queries import (random_query_from_graph, template_queries)


def _check(graph, q, **opts):
    got = match(graph, q, limit=None, **opts)
    want = answer_set(brute_force_answers(graph, q))
    assert got.count == len(want), f"{q}"
    if got.count <= 1_000_000:   # tuples are materialized up to this cap
        assert answer_set(got.tuples) == want, f"{q}"
    return got


def test_paper_example():
    g = paper_example_graph()
    q = paper_example_query()
    got = _check(g, q)
    assert got.count > 0


@pytest.mark.parametrize("qtype", ["C", "H", "D"])
@pytest.mark.parametrize("seed", [0, 1])
def test_gm_matches_bruteforce_templates(qtype, seed):
    graph = random_labeled_graph(60, avg_degree=2.2, n_labels=5, seed=seed)
    for q in template_queries(graph, qtype=qtype, seed=seed)[:8]:
        _check(graph, q)


@given(st.integers(0, 10_000), st.sampled_from(["C", "H", "D"]),
       st.integers(3, 5))
@settings(max_examples=15, deadline=None)
def test_gm_matches_bruteforce_random(seed, qtype, qsize):
    # small graphs with several labels keep exhaustive answers tractable
    graph = random_labeled_graph(40, avg_degree=2.0, n_labels=5,
                                 kind="uniform", seed=seed % 97)
    q = random_query_from_graph(graph, n_nodes=qsize, qtype=qtype, seed=seed)
    _check(graph, q)


@pytest.mark.parametrize("variant", [
    dict(sim_algo="bas"),
    dict(sim_algo="dag"),
    dict(sim_algo="none", use_prefilter=True),       # GM-F
    dict(use_prefilter=True),                         # GM + prefilter
    dict(use_transitive_reduction=False),             # GM-NR
    dict(ordering="ri"),
    dict(ordering="bj"),
    dict(sim_passes=None),                            # exact fixpoint
    dict(sim_passes=1),
    dict(check_method="bititer"),
])
def test_gm_variants_all_correct(variant):
    graph = random_labeled_graph(50, avg_degree=2.2, n_labels=4, seed=42)
    q = random_query_from_graph(graph, n_nodes=5, qtype="H", seed=43)
    _check(graph, q, **variant)


@given(st.integers(0, 5000))
@settings(max_examples=12, deadline=None)
def test_jm_tm_gm_agree(seed):
    graph = random_labeled_graph(40, avg_degree=2.0, n_labels=4, seed=seed % 53)
    q = random_query_from_graph(graph, n_nodes=4, qtype="H", seed=seed)
    want = answer_set(brute_force_answers(graph, q))
    gm = match(graph, q, limit=None)
    jm = jm_match(graph, q)
    tm = tm_match(graph, q)
    assert answer_set(gm.tuples) == want
    assert answer_set(jm.tuples) == want
    assert answer_set(tm.tuples) == want


def test_result_limit_truncation():
    graph = random_labeled_graph(80, avg_degree=3.0, n_labels=2, seed=3)
    q = random_query_from_graph(graph, n_nodes=3, qtype="D", seed=4)
    full = match(graph, q, limit=None)
    if full.count > 5:
        part = match(graph, q, limit=5)
        assert part.truncated and part.count == 5


def test_empty_answer_detected_early():
    # a label that does not exist in the graph -> empty RIG, zero cost
    graph = random_labeled_graph(50, avg_degree=2.0, n_labels=3, seed=5)
    q = query(labels=[0, 99], edges=[(0, 1, CHILD)])
    got = match(graph, q, limit=None)
    assert got.count == 0 and got.rig_nodes >= 0


def test_cyclic_query_handled():
    graph = random_labeled_graph(60, avg_degree=3.0, n_labels=2, seed=6)
    q = query(labels=[0, 1, 0],
              edges=[(0, 1, DESC), (1, 2, DESC), (2, 0, DESC)])
    got = match(graph, q, limit=None)
    want = answer_set(brute_force_answers(graph, q))
    assert answer_set(got.tuples) == want
