"""Enumeration-strategy equivalence: backtrack == frontier == brute force.

The frontier enumerator must be a drop-in replacement for the paper's
backtracking MJoin — same result sets, same counts, and (because both
enumerate in the same lexicographic order over the compact candidate ids)
exactly the same truncation behaviour under ``limit`` / ``max_tuples``.
The device variant routes the AND+popcount step through the ``intersect``
Pallas kernel (interpreter mode off-TPU) and must agree bit-for-bit.
"""

import numpy as np
import pytest

from repro.core import match
from repro.core.bruteforce import answer_set, brute_force_answers
from repro.core.graph import paper_example_graph
from repro.core.mjoin import mjoin
from repro.core.ordering import get_order
from repro.core.query import CHILD, paper_example_query, query
from repro.core.rig import build_rig
from repro.data.graphs import random_labeled_graph
from repro.data.queries import random_query_from_graph
from repro.testing import given, settings, st

HOST_METHODS = ("backtrack", "frontier")


def _assert_equivalent(graph, q, methods=HOST_METHODS, **opts):
    want = answer_set(brute_force_answers(graph, q))
    for m in methods:
        got = match(graph, q, limit=None, enum_method=m, **opts)
        assert got.count == len(want), (m, got.count, len(want))
        assert answer_set(got.tuples) == want, m
    return len(want)


@pytest.mark.parametrize("qtype", ["C", "H", "D"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_frontier_matches_backtrack_and_bruteforce(qtype, seed):
    graph = random_labeled_graph(55, avg_degree=2.4, n_labels=4, seed=seed)
    q = random_query_from_graph(graph, n_nodes=4, qtype=qtype,
                                seed=seed + 20)
    _assert_equivalent(graph, q)


def test_paper_example_all_methods():
    g = paper_example_graph()
    n = _assert_equivalent(g, paper_example_query())
    assert n > 0


@pytest.mark.parametrize("variant", [
    dict(expand_method="interval"),              # §5.5 early termination
    dict(ordering="ri"),
    dict(sim_algo="none", use_prefilter=True),   # GM-F
])
def test_frontier_under_build_variants(variant):
    graph = random_labeled_graph(50, avg_degree=2.5, n_labels=4, seed=42)
    q = random_query_from_graph(graph, n_nodes=5, qtype="H", seed=43)
    _assert_equivalent(graph, q, **variant)


def test_truncation_semantics_identical():
    graph = random_labeled_graph(80, avg_degree=3.0, n_labels=2, seed=3)
    q = random_query_from_graph(graph, n_nodes=3, qtype="D", seed=4)
    full = match(graph, q, limit=None)
    assert full.count > 10
    for lim in (1, 5, full.count, full.count + 1):
        bt = match(graph, q, limit=lim, enum_method="backtrack")
        fr = match(graph, q, limit=lim, enum_method="frontier")
        assert bt.count == fr.count
        assert bt.truncated == fr.truncated
        # same lexicographic enumeration order -> identical prefixes
        assert np.array_equal(bt.tuples, fr.tuples)


def test_max_tuples_caps_materialization_not_count():
    graph = random_labeled_graph(80, avg_degree=3.0, n_labels=2, seed=3)
    q = random_query_from_graph(graph, n_nodes=3, qtype="D", seed=4)
    full = match(graph, q, limit=None)
    assert full.count > 7
    for m in HOST_METHODS:
        got = match(graph, q, limit=None, enum_method=m, max_tuples=7)
        assert got.count == full.count          # counting continues
        assert got.tuples.shape == (7, q.n)
        assert np.array_equal(got.tuples, full.tuples[:7])


def test_empty_rig_all_methods():
    graph = random_labeled_graph(50, avg_degree=2.0, n_labels=3, seed=5)
    q = query(labels=[0, 99], edges=[(0, 1, CHILD)])
    for m in HOST_METHODS:
        got = match(graph, q, limit=None, enum_method=m)
        assert got.count == 0
        assert got.tuples.shape == (0, 2)


def test_single_node_query():
    graph = random_labeled_graph(40, avg_degree=2.0, n_labels=3, seed=6)
    q = query(labels=[1], edges=[])
    want = answer_set(brute_force_answers(graph, q))
    for m in HOST_METHODS:
        got = match(graph, q, limit=None, enum_method=m)
        assert got.count == len(want)
        assert answer_set(got.tuples) == want
        part = match(graph, q, limit=2, enum_method=m)
        assert part.count == min(2, len(want))


def test_counting_mode_no_materialization():
    graph = random_labeled_graph(60, avg_degree=2.5, n_labels=3, seed=7)
    q = random_query_from_graph(graph, n_nodes=4, qtype="H", seed=8)
    ref = match(graph, q, limit=None)
    for m in HOST_METHODS:
        got = match(graph, q, limit=None, enum_method=m, materialize=False)
        assert got.tuples is None and got.count == ref.count


def test_frontier_overflow_falls_back_to_backtrack():
    graph = random_labeled_graph(80, avg_degree=3.0, n_labels=2, seed=3)
    q = random_query_from_graph(graph, n_nodes=3, qtype="D", seed=4)
    rig = build_rig(graph, q.transitive_reduction())
    order = get_order(rig, "jo")
    ref = mjoin(rig, order, limit=None)
    tiny = mjoin(rig, order, limit=None, method="frontier", max_frontier=2)
    assert tiny.stats.method == "backtrack"      # fell back
    assert tiny.count == ref.count
    assert np.array_equal(tiny.tuples, ref.tuples)


def test_mjoin_rejects_unknown_method():
    graph = random_labeled_graph(20, avg_degree=2.0, n_labels=2, seed=0)
    q = random_query_from_graph(graph, n_nodes=3, qtype="C", seed=1)
    rig = build_rig(graph, q.transitive_reduction())
    with pytest.raises(ValueError):
        mjoin(rig, get_order(rig, "jo"), method="nope")


def test_enum_method_surfaced_in_match_result():
    graph = random_labeled_graph(40, avg_degree=2.0, n_labels=3, seed=9)
    q = random_query_from_graph(graph, n_nodes=3, qtype="C", seed=9)
    for m in HOST_METHODS:
        assert match(graph, q, enum_method=m).enum_method == m


@given(st.integers(0, 10_000), st.sampled_from(["C", "H", "D"]),
       st.integers(3, 5))
@settings(max_examples=20, deadline=None)
def test_frontier_equivalence_random(seed, qtype, qsize):
    graph = random_labeled_graph(40, avg_degree=2.0, n_labels=5,
                                 kind="uniform", seed=seed % 89)
    q = random_query_from_graph(graph, n_nodes=qsize, qtype=qtype, seed=seed)
    want = answer_set(brute_force_answers(graph, q))
    bt = match(graph, q, limit=None, enum_method="backtrack")
    fr = match(graph, q, limit=None, enum_method="frontier")
    assert answer_set(bt.tuples) == want
    assert bt.count == fr.count == len(want)
    assert np.array_equal(bt.tuples, fr.tuples)   # identical order, too


# ------------------------------------------------------------- device path
try:
    import jax  # noqa: F401
    HAVE_JAX = True
except ImportError:                                   # bare interpreter
    HAVE_JAX = False

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")


@needs_jax
@pytest.mark.parametrize("seed", [0, 1])
def test_frontier_device_interpret_equivalence(seed):
    graph = random_labeled_graph(60, avg_degree=2.5, n_labels=3, seed=seed)
    q = random_query_from_graph(graph, n_nodes=4, qtype="H", seed=seed + 5)
    want = answer_set(brute_force_answers(graph, q))
    got = match(graph, q, limit=None, enum_method="frontier-device")
    assert got.count == len(want)
    assert answer_set(got.tuples) == want
    bt = match(graph, q, limit=None, enum_method="backtrack")
    assert np.array_equal(got.tuples, bt.tuples)


@needs_jax
def test_frontier_device_truncation():
    graph = random_labeled_graph(60, avg_degree=3.0, n_labels=2, seed=3)
    q = random_query_from_graph(graph, n_nodes=3, qtype="D", seed=4)
    full = match(graph, q, limit=None)
    if full.count > 5:
        dv = match(graph, q, limit=5, enum_method="frontier-device")
        bt = match(graph, q, limit=5, enum_method="backtrack")
        assert dv.count == 5 and dv.truncated
        assert np.array_equal(dv.tuples, bt.tuples)


# ------------------------------------------------- resident device path
RESIDENT = "frontier-device-resident"


@needs_jax
@pytest.mark.parametrize("qtype", ["C", "H", "D"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_resident_matches_backtrack_and_bruteforce(qtype, seed):
    graph = random_labeled_graph(55, avg_degree=2.4, n_labels=4, seed=seed)
    q = random_query_from_graph(graph, n_nodes=4, qtype=qtype,
                                seed=seed + 20)
    _assert_equivalent(graph, q, methods=("backtrack", RESIDENT))


@needs_jax
def test_resident_truncation_limit_mid_page():
    """A ``limit`` landing inside a device result page must cut the final
    block at exactly ``limit`` rows, byte-identical to backtrack."""
    graph = random_labeled_graph(80, avg_degree=3.0, n_labels=2, seed=3)
    q = random_query_from_graph(graph, n_nodes=3, qtype="D", seed=4)
    full = match(graph, q, limit=None)
    assert full.count > 10
    for lim in (1, 3, full.count // 2, full.count, full.count + 1):
        bt = match(graph, q, limit=lim, enum_method="backtrack")
        rs = match(graph, q, limit=lim, enum_method=RESIDENT)
        assert bt.count == rs.count
        assert bt.truncated == rs.truncated
        assert np.array_equal(bt.tuples, rs.tuples)


@needs_jax
def test_resident_pages_instead_of_backtrack_fallback():
    """Where plain frontier overflows ``max_frontier`` and falls back to
    backtracking, the resident enumerator pages level-by-level: same
    tuples, no strategy change, no overflow degradation."""
    graph = random_labeled_graph(80, avg_degree=3.0, n_labels=2, seed=3)
    q = random_query_from_graph(graph, n_nodes=3, qtype="D", seed=4)
    rig = build_rig(graph, q.transitive_reduction())
    order = get_order(rig, "jo")
    ref = mjoin(rig, order, limit=None)
    host = mjoin(rig, order, limit=None, method="frontier", max_frontier=2)
    assert host.stats.method == "backtrack"          # the old behaviour
    paged = mjoin(rig, order, limit=None, method=RESIDENT, max_frontier=2)
    assert paged.stats.method == RESIDENT            # no fallback
    assert "backtrack" not in paged.stats.degradations
    assert paged.count == ref.count
    assert np.array_equal(paged.tuples, ref.tuples)


@needs_jax
def test_resident_max_tuples_caps_materialization_not_count():
    graph = random_labeled_graph(80, avg_degree=3.0, n_labels=2, seed=3)
    q = random_query_from_graph(graph, n_nodes=3, qtype="D", seed=4)
    full = match(graph, q, limit=None)
    got = match(graph, q, limit=None, enum_method=RESIDENT, max_tuples=7)
    assert got.count == full.count
    assert got.tuples.shape == (7, q.n)
    assert np.array_equal(got.tuples, full.tuples[:7])


@needs_jax
def test_resident_stream_chunks_byte_identical():
    from repro.core.mjoin import iter_tuples
    graph = random_labeled_graph(80, avg_degree=3.0, n_labels=2, seed=3)
    q = random_query_from_graph(graph, n_nodes=3, qtype="D", seed=4)
    qr = q.transitive_reduction()
    rig = build_rig(graph, qr)
    order = get_order(rig, "jo")
    ref = mjoin(rig, order, limit=None)
    for chunk in (1, 7, 64):
        got = list(iter_tuples(rig, order, chunk_size=chunk, limit=None,
                               method=RESIDENT, max_frontier=4))
        assert all(len(c) == chunk for c in got[:-1])
        assert np.array_equal(np.vstack(got), ref.tuples)


@needs_jax
def test_resident_deadline_yields_partial_prefix():
    from repro.robust import Budget
    graph = random_labeled_graph(80, avg_degree=3.0, n_labels=2, seed=3)
    q = random_query_from_graph(graph, n_nodes=3, qtype="D", seed=4)
    qr = q.transitive_reduction()
    rig = build_rig(graph, qr)
    order = get_order(rig, "jo")
    full = mjoin(rig, order, limit=None)
    t = [0.0]

    def clk():
        t[0] += 0.02
        return t[0]

    b = Budget(deadline_s=0.05).start(clock=clk)
    got = mjoin(rig, order, limit=None, method=RESIDENT, max_frontier=2,
                budget=b)
    assert got.stats.deadline_exceeded and got.stats.truncated
    assert got.count < full.count
    assert np.array_equal(got.tuples, full.tuples[:got.count])


@needs_jax
def test_resident_interpret_mode_equivalence(monkeypatch):
    """CI's Pallas-kernel coverage: the fused gather+AND+popcount and the
    pair-expansion kernels in interpreter mode, byte-identical output."""
    import repro.jaxgm.frontier as frontier
    monkeypatch.setattr(frontier, "DEFAULT_MODE", "interpret")
    graph = random_labeled_graph(40, avg_degree=2.2, n_labels=3, seed=11)
    q = random_query_from_graph(graph, n_nodes=3, qtype="H", seed=12)
    bt = match(graph, q, limit=None, enum_method="backtrack")
    rs = match(graph, q, limit=None, enum_method=RESIDENT)
    assert rs.count == bt.count
    assert np.array_equal(rs.tuples, bt.tuples)
    assert rs.resident_dispatches > 0                # the kernel really ran


@needs_jax
def test_resident_small_frontier_host_routing():
    """Slabs below the threshold stay on the host (padded-dispatch floor),
    with the routing observable and results unchanged."""
    graph = random_labeled_graph(60, avg_degree=2.5, n_labels=3, seed=7)
    q = random_query_from_graph(graph, n_nodes=4, qtype="H", seed=8)
    bt = match(graph, q, limit=None, enum_method="backtrack")
    rs = match(graph, q, limit=None, enum_method=RESIDENT,
               small_frontier_rows=1 << 20)
    assert rs.count == bt.count
    assert np.array_equal(rs.tuples, bt.tuples)
    assert rs.small_frontier_host_routed > 0
    assert rs.resident_dispatches == 0               # everything re-routed


@needs_jax
def test_resident_device_failure_degrades_to_host():
    from repro.robust import CircuitBreaker, faults
    graph = random_labeled_graph(60, avg_degree=2.5, n_labels=3, seed=7)
    q = random_query_from_graph(graph, n_nodes=4, qtype="H", seed=8)
    qr = q.transitive_reduction()
    rig = build_rig(graph, qr)
    order = get_order(rig, "jo")
    ref = mjoin(rig, order, limit=None)
    with faults.inject(faults.every("device_dispatch", 1)):   # all attempts
        got = mjoin(rig, order, limit=None, method=RESIDENT,
                    breaker=CircuitBreaker())
    assert "host-intersect" in got.stats.degradations
    assert got.count == ref.count
    assert np.array_equal(got.tuples, ref.tuples)


@needs_jax
@given(st.integers(0, 10_000), st.sampled_from(["C", "H", "D"]),
       st.integers(2, 128))
@settings(max_examples=15, deadline=None)
def test_resident_equivalence_random(seed, qtype, max_frontier):
    """Randomized paging: any page size yields backtrack's exact output."""
    graph = random_labeled_graph(40, avg_degree=2.0, n_labels=5,
                                 kind="uniform", seed=seed % 89)
    q = random_query_from_graph(graph, n_nodes=4, qtype=qtype, seed=seed)
    bt = match(graph, q, limit=None, enum_method="backtrack")
    rs = match(graph, q, limit=None, enum_method=RESIDENT)
    assert bt.count == rs.count
    assert np.array_equal(bt.tuples, rs.tuples)
    rig = build_rig(graph, q.transitive_reduction())
    if not rig.is_empty():
        order = get_order(rig, "jo")
        ref = mjoin(rig, order, limit=None)
        paged = mjoin(rig, order, limit=None, method=RESIDENT,
                      max_frontier=max_frontier)
        assert paged.count == ref.count
        assert np.array_equal(paged.tuples, ref.tuples)
