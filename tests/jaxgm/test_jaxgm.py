"""Device path == host path: the central cross-implementation property."""

import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, st

from repro.core import bitset as hostbits
from repro.core import match
from repro.core.bruteforce import answer_set, brute_force_answers
from repro.core.simulation import fb_sim
from repro.data.graphs import random_labeled_graph
from repro.data.queries import random_query_from_graph, template_queries
from repro.jaxgm import (JaxGM, double_simulation, encode_query, from_host,
                         jo_order)
from repro.jaxgm.simulation import fb_sizes


def _graph(seed, n=60, labels=4, deg=2.2, kind="uniform"):
    return random_labeled_graph(n, avg_degree=deg, n_labels=labels,
                                kind=kind, seed=seed)


def test_initial_fb_matches_match_sets():
    g = _graph(0)
    q = random_query_from_graph(g, 4, qtype="H", seed=1)
    dg = from_host(g, block=128)
    qt = encode_query(q, 8, 16)
    from repro.jaxgm.simulation import initial_fb
    fb0 = np.asarray(initial_fb(dg, qt))
    for i in range(q.n):
        want = np.zeros(dg.n_pad, bool)
        want[:g.n] = g.label_mask(q.labels[i])
        assert np.array_equal(fb0[i], want)
    assert not fb0[q.n:].any()   # padding rows empty


@given(st.integers(0, 400), st.sampled_from(["C", "H", "D"]))
@settings(max_examples=12, deadline=None)
def test_device_sim_fixpoint_equals_host_fixpoint(seed, qtype):
    g = _graph(seed % 83)
    q = random_query_from_graph(g, 4, qtype=qtype, seed=seed)
    host = fb_sim(g, q, max_passes=None)
    assert host.converged
    dg = from_host(g, block=128)
    qt = encode_query(q, 8, 16)
    fb = np.asarray(double_simulation(dg, qt, exact=True, impl="reference"))
    for i in range(q.n):
        want = hostbits.unpack(host.fb[i], g.n)
        assert np.array_equal(fb[i, :g.n], want), f"q{i}"
        assert not fb[i, g.n:].any()


def test_truncated_device_sim_is_sound():
    g = _graph(11)
    q = random_query_from_graph(g, 5, qtype="H", seed=12)
    ans = brute_force_answers(g, q)
    dg = from_host(g, block=128)
    qt = encode_query(q, 8, 16)
    fb = np.asarray(double_simulation(dg, qt, n_passes=1, impl="reference"))
    for i in range(q.n):
        if len(ans):
            occ = np.unique(ans[:, i])
            assert fb[i, occ].all()


@given(st.integers(0, 500), st.sampled_from(["C", "H", "D"]),
       st.integers(3, 5))
@settings(max_examples=15, deadline=None)
def test_jaxgm_count_equals_host_gm(seed, qtype, qsize):
    g = _graph(seed % 71, n=50, labels=5)
    q = random_query_from_graph(g, qsize, qtype=qtype, seed=seed)
    host = match(g, q, limit=None)
    jgm = JaxGM(g, block=128, capacity=8192, exact_sim=True, impl="reference")
    dev = jgm.match(q)
    if dev.overflowed:
        # dense queries may exceed the frontier capacity — the designed
        # outcome is a truthful overflow flag (serving falls back to the
        # host enumerator), not a wrong count.
        assert host.count > 8192
    else:
        assert dev.count == host.count


def test_jaxgm_materialized_tuples_match_bruteforce():
    g = _graph(3, n=40, labels=5)
    q = random_query_from_graph(g, 4, qtype="H", seed=4)
    want = answer_set(brute_force_answers(g, q))
    jgm = JaxGM(g, block=128, capacity=8192, exact_sim=True, impl="reference")
    dev = jgm.match(q, materialize=True)
    assert not dev.overflowed
    got = set(map(tuple, dev.tuples))
    assert got == want


def test_jaxgm_batch_vmap_matches_single():
    g = _graph(5, n=50, labels=4)
    queries = [random_query_from_graph(g, k, qtype=t, seed=s)
               for (k, t, s) in [(3, "C", 1), (4, "H", 2), (4, "D", 3),
                                 (5, "H", 4)]]
    jgm = JaxGM(g, block=128, capacity=8192, exact_sim=True, impl="reference")
    singles = [jgm.match(q).count for q in queries]
    batch = [r.count for r in jgm.match_batch(queries)]
    assert singles == batch


def test_overflow_flag_raised_on_tiny_capacity():
    g = _graph(6, n=60, labels=2, deg=3.0)
    q = random_query_from_graph(g, 4, qtype="D", seed=7)
    host = match(g, q, limit=None)
    jgm = JaxGM(g, block=128, capacity=8, exact_sim=True, impl="reference")
    dev = jgm.match(q)
    if host.count > 8:
        assert dev.overflowed


def test_closure_on_device_matches_host():
    g = _graph(8, n=70)
    jgm_host = JaxGM(g, block=128, impl="reference")
    jgm_dev = JaxGM(g, block=128, impl="reference", closure_on_device=True)
    assert np.array_equal(np.asarray(jgm_host.dg.reach),
                          np.asarray(jgm_dev.dg.reach))


def test_jo_order_prefers_small_sets_and_connectivity():
    g = _graph(9)
    q = random_query_from_graph(g, 5, qtype="H", seed=10)
    qt = encode_query(q, 8, 16)
    sizes = jnp.asarray([5, 1, 7, 3, 2, 0, 0, 0], jnp.int32)
    order = np.asarray(jo_order(qt, sizes))[:q.n]
    assert sorted(order.tolist()) == list(range(q.n))
    assert order[0] == int(np.argmin(np.asarray(sizes)[:q.n]))
    # every subsequent node touches the prefix (q is connected)
    for i in range(1, q.n):
        prefix = set(order[:i].tolist())
        assert any(nb in prefix for nb in q.neighbors(int(order[i])))


def test_rig_stats_match_host_rig():
    from repro.core.rig import build_rig
    g = _graph(13, n=50)
    q = random_query_from_graph(g, 4, qtype="H", seed=14)
    qr = q.transitive_reduction()
    jgm = JaxGM(g, block=128, exact_sim=True, impl="reference")
    sizes, edge_counts = jgm.rig_stats(q)
    rig = build_rig(g, qr, sim_passes=None)
    assert list(sizes) == [rig.cos_size(i) for i in range(qr.n)]
    host_edges = [rig.edge_count(e) for e in range(qr.m)]
    assert list(edge_counts) == host_edges
