"""Distributed pipeline == single-device pipeline, on 8 simulated devices.

XLA fixes the device count at first jax import, so these tests run their
body in a subprocess with ``--xla_force_host_platform_device_count=8``.
"""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))


def _run(body: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_sharded_sim_matches_single_device():
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    assert len(jax.devices()) == 8, jax.devices()
    from jax.sharding import Mesh
    from repro.data.graphs import random_labeled_graph
    from repro.data.queries import random_query_from_graph
    from repro.jaxgm import from_host, encode_query, double_simulation
    from repro.jaxgm.distributed import (sharded_double_simulation,
                                         shard_graph_arrays)

    g = random_labeled_graph(200, avg_degree=2.5, n_labels=4, seed=0)
    dg = from_host(g, block=256)
    queries = [random_query_from_graph(g, k, qtype=t, seed=s)
               for (k, t, s) in [(4, "H", 1), (3, "C", 2)]]
    qts = jax.tree.map(lambda *xs: jnp.stack(xs),
                       *[encode_query(q, 8, 16) for q in queries])

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    mats, labels = shard_graph_arrays(dg, mesh)
    fb_dist = np.asarray(sharded_double_simulation(mats, labels, qts, mesh,
                                                   n_passes=4, block_k=64))
    for i, q in enumerate(queries):
        qt = encode_query(q, 8, 16)
        fb_single = np.asarray(double_simulation(dg, qt, n_passes=4,
                                                 impl="reference"))
        assert np.array_equal(fb_dist[i], fb_single), f"query {i}"
    print("SIM-OK")
    """)


def test_sharded_serve_step_and_multipod():
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    from repro.data.graphs import random_labeled_graph
    from repro.data.queries import random_query_from_graph
    from repro.jaxgm import from_host, encode_query, double_simulation
    from repro.jaxgm.simulation import fb_sizes, rig_edge_counts
    from repro.jaxgm.distributed import gm_serve_step, shard_graph_arrays

    g = random_labeled_graph(200, avg_degree=2.5, n_labels=4, seed=3)
    dg = from_host(g, block=256)
    queries = [random_query_from_graph(g, 4, qtype="H", seed=7),
               random_query_from_graph(g, 4, qtype="D", seed=8)]
    qts = jax.tree.map(lambda *xs: jnp.stack(xs),
                       *[encode_query(q, 8, 16) for q in queries])

    # multi-pod mesh: ("pod", "data", "model")
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    mats, labels = shard_graph_arrays(dg, mesh)
    out = gm_serve_step(mats, labels, qts, mesh, n_passes=4, top_k=64,
                        block_k=64)

    for i, q in enumerate(queries):
        qt = encode_query(q, 8, 16)
        fb = double_simulation(dg, qt, n_passes=4, impl="reference")
        assert np.array_equal(np.asarray(out.fb_sizes[i]),
                              np.asarray(fb_sizes(fb))), f"sizes q{i}"
        want_edges = np.asarray(rig_edge_counts(dg, qt, fb, impl="reference"))
        np.testing.assert_allclose(np.asarray(out.edge_counts[i]),
                                   want_edges), f"edges q{i}"
        # candidate compaction: exact when |cos| <= top_k
        fbn = np.asarray(fb)
        for qi in range(q.n):
            ids = set(np.nonzero(fbn[qi])[0].tolist())
            got = set(x for x in np.asarray(out.candidates[i, qi]).tolist()
                      if x >= 0)
            if len(ids) <= 64:
                assert got == ids, f"cand q{i} node {qi}"
    print("SERVE-OK")
    """)
