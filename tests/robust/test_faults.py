"""Chaos suite for resource-governed execution (repro.robust).

Covers the three layers of the governance stack:

* the fault harness itself (deterministic triggers, scoped install),
* the circuit breaker / budget state machines (injected clock + sleep, so
  no test ever really waits),
* end-to-end chaos: for every injection site, a faulted run must either
  surface the typed error or — when a degradation path exists — return
  counts identical to the fault-free run.  The RIG is runtime state, so
  every recovery is recompute; equality of counts is the proof.
"""

import pytest

from repro.data.graphs import random_labeled_graph
from repro.engine import (Budget, CircuitBreaker, DeadlineExceeded, Engine,
                          EngineOptions, ResourceExhausted)
from repro.launch.serve import QueryServer
from repro.robust import faults
from repro.robust.breaker import CLOSED, HALF_OPEN, OPEN
from repro.robust.errors import (BreakerOpen, DeviceFailure, InjectedFault,
                                 QueryError, TransientError)
from repro.testing import HAVE_HYPOTHESIS, given, settings, st


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    """Every test starts and ends with no fault plan installed."""
    faults.uninstall()
    yield
    faults.uninstall()


def _graph(seed=0, n=300):
    return random_labeled_graph(n, avg_degree=3.0, n_labels=4, seed=seed)


def _host_engine(g, **kw):
    return Engine(g, options=EngineOptions(device_min_nodes=10**9,
                                           materialize=False, **kw))


QUERY = "(a:L0)-/->(b:L1)-//->(c:L2)"


# ===================================================== fault harness itself
class TestFaultHarness:
    def test_no_plan_is_free_noop(self):
        faults.maybe_fail("rig_expand")            # must not raise
        assert faults.call_count("rig_expand") == 0

    def test_nth_fires_on_exact_call_numbers(self):
        with faults.inject(faults.nth("rig_expand", 2, 4)) as plan:
            fired = []
            for i in range(1, 6):
                try:
                    faults.maybe_fail("rig_expand")
                except InjectedFault as e:
                    fired.append(i)
                    assert e.site == "rig_expand" and e.call_no == i
            assert fired == [2, 4]
            assert plan.calls["rig_expand"] == 5

    def test_every_k(self):
        with faults.inject(faults.every("label_build", 3)):
            fired = [i for i in range(1, 10)
                     if _fires("label_build")]
            assert fired == [3, 6, 9]

    def test_times_bounds_total_fires(self):
        with faults.inject(faults.every("label_build", 1, times=2)):
            fired = [i for i in range(1, 6) if _fires("label_build")]
            assert fired == [1, 2]

    def test_probability_is_deterministic_per_seed(self):
        def draw(seed):
            with faults.inject(faults.probability("rig_expand", 0.5,
                                                  seed=seed)):
                return [i for i in range(1, 33) if _fires("rig_expand")]
        a, b = draw(7), draw(7)
        assert a == b and 0 < len(a) < 32
        assert draw(8) != a

    def test_inject_scopes_the_plan(self):
        with faults.inject(faults.every("rig_expand", 1)):
            with pytest.raises(InjectedFault):
                faults.maybe_fail("rig_expand")
        faults.maybe_fail("rig_expand")            # plan gone: no raise

    def test_unknown_site_rejected(self):
        with pytest.raises(ValueError):
            faults.nth("not_a_site", 1)

    def test_sites_do_not_interfere(self):
        with faults.inject(faults.every("label_build", 1)):
            faults.maybe_fail("device_dispatch")   # other site: no raise
            with pytest.raises(InjectedFault):
                faults.maybe_fail("label_build")

    def test_injected_fault_is_transient(self):
        assert issubclass(InjectedFault, TransientError)
        assert not issubclass(DeadlineExceeded, TransientError)
        assert not issubclass(BreakerOpen, TransientError)


def _fires(site):
    try:
        faults.maybe_fail(site)
        return False
    except InjectedFault:
        return True


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10**6),
           p=st.floats(0.05, 0.95))
    def test_probability_replays_exactly(seed, p):
        """Property: the seeded probability trigger is a pure function of
        (seed, p, call number) — two fresh specs fire identically."""
        def draw():
            spec = faults.probability("rig_expand", p, seed=seed)
            return [n for n in range(1, 65) if spec.should_fire(n)]
        assert draw() == draw()


# ============================================== breaker state machine (unit)
class FakeClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t


def _breaker(**kw):
    clk = FakeClock()
    sleeps = []
    br = CircuitBreaker(clock=clk, sleep=sleeps.append, **kw)
    return br, clk, sleeps


class TestCircuitBreaker:
    def test_retry_then_success(self):
        br, _, sleeps = _breaker(max_retries=2)
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("boom")
            return "ok"

        assert br.call(flaky) == "ok"
        assert calls["n"] == 2 and br.retries == 1 and len(sleeps) == 1
        assert br.state == CLOSED and br.consecutive_failures == 0

    def test_consecutive_failures_open_the_breaker(self):
        br, _, _ = _breaker(failure_threshold=3, max_retries=0)
        for _ in range(3):
            with pytest.raises(DeviceFailure):
                br.call(_always_boom)
        assert br.state == OPEN and br.opened == 1

    def test_open_refuses_without_touching_device(self):
        br, _, _ = _breaker(failure_threshold=1, max_retries=0)
        with pytest.raises(DeviceFailure):
            br.call(_always_boom)
        assert br.state == OPEN
        touched = {"n": 0}

        def fn():
            touched["n"] += 1
            return "ok"

        with pytest.raises(BreakerOpen):
            br.call(fn)
        assert touched["n"] == 0

    def test_half_open_probe_success_recloses(self):
        br, clk, _ = _breaker(failure_threshold=1, max_retries=0,
                              reset_after_s=30.0)
        with pytest.raises(DeviceFailure):
            br.call(_always_boom)
        assert br.state == OPEN
        clk.t += 31.0                       # reset window passes
        assert br.call(lambda: "probe-ok") == "probe-ok"
        assert br.state == CLOSED
        assert br.call(lambda: "ok") == "ok"   # traffic flows again

    def test_half_open_probe_failure_reopens(self):
        br, clk, _ = _breaker(failure_threshold=1, max_retries=2,
                              reset_after_s=30.0)
        with pytest.raises(DeviceFailure):
            br.call(_always_boom)
        clk.t += 31.0
        assert br.allow() and br.state == HALF_OPEN
        with pytest.raises(DeviceFailure):
            br.call(_always_boom)           # probe gets exactly ONE attempt
        assert br.state == OPEN and br.opened == 2
        with pytest.raises(BreakerOpen):
            br.call(lambda: "nope")         # window restarted

    def test_backoff_never_sleeps_past_deadline(self):
        br, clk, sleeps = _breaker(max_retries=3, backoff_base_s=10.0)
        b = Budget(deadline_s=0.5).start(clock=clk)
        with pytest.raises(DeviceFailure):
            br.call(_always_boom, budget=b)
        assert sleeps and all(s <= 0.5 for s in sleeps)

    def test_fault_site_fires_per_attempt(self):
        br, _, _ = _breaker(max_retries=2)
        with faults.inject(faults.every("device_dispatch", 1)) as plan:
            with pytest.raises(DeviceFailure):
                br.call(lambda: "never-reached")
            assert plan.calls["device_dispatch"] == 3   # 1 + 2 retries


def _always_boom():
    raise RuntimeError("boom")


# ======================================================== budget semantics
class TestBudget:
    def test_start_arms_a_copy_not_the_template(self):
        template = Budget(deadline_s=5.0)
        armed = template.start()
        assert armed.armed and not template.armed
        assert armed is not template

    def test_deadline_with_injected_clock(self):
        clk = FakeClock()
        b = Budget(deadline_s=2.0).start(clock=clk)
        assert not b.expired() and b.remaining_s() == pytest.approx(2.0)
        clk.t += 2.5
        assert b.expired()
        with pytest.raises(DeadlineExceeded):
            b.check_deadline("rig_expand[0]")

    def test_charge_rig_raises_over_cap(self):
        b = Budget(max_rig_bytes=100).start()
        b.charge_rig(60)
        with pytest.raises(ResourceExhausted):
            b.charge_rig(60)

    def test_caps(self):
        b = Budget(max_frontier_rows=10, max_slab_bytes=1024).start()
        assert b.frontier_cap(1 << 20) == 10
        assert b.frontier_cap(4) == 4              # tightens only
        assert b.slab_cap_rows(256) == 4
        assert Budget().start().slab_cap_rows(256) is None


# ========================================== end-to-end chaos, per fault site
class TestEngineChaos:
    def test_rig_expand_fault_recomputes_to_identical_count(self):
        g = _graph()
        want = _host_engine(g).execute(QUERY).count
        eng = _host_engine(g)
        with faults.inject(faults.nth("rig_expand", 1)) as plan:
            res = eng.execute(QUERY, budget=Budget(max_attempts=2))
            assert plan.calls["rig_expand"] >= 1
        assert res.count == want and res.stats.status == "ok"
        assert res.stats.attempts == 2

    def test_rig_expand_fault_without_retries_is_typed(self):
        eng = _host_engine(_graph())
        with faults.inject(faults.every("rig_expand", 1)):
            res = eng.execute(QUERY, budget=Budget(max_attempts=2))
            assert res.stats.status == "injected_fault"
            assert res.stats.partial and res.count == 0
            with pytest.raises(QueryError):
                eng.execute(QUERY, budget=Budget(max_attempts=2,
                                                 raise_on_error=True))

    def test_label_build_fault_rebuilds_transactionally(self):
        g = _graph(seed=1)
        want = _host_engine(g).execute(QUERY).count
        eng = _host_engine(g)
        with faults.inject(faults.nth("label_build", 1)):
            res = eng.execute(QUERY, budget=Budget(max_attempts=2))
        assert res.count == want
        # the failed attempt left nothing half-built: exactly one committed
        # build, and the warm path reuses it
        assert eng.context().label_builds == 1
        eng.execute(QUERY)
        assert eng.context().label_builds == 1

    def test_device_dispatch_fault_falls_back_to_host(self):
        g = _graph(seed=2)
        want = _host_engine(g).execute(QUERY).count
        br = CircuitBreaker(sleep=lambda s: None, failure_threshold=3)
        eng = Engine(g, options=EngineOptions(
            device_min_nodes=0, materialize=False,
            force_backend="device", breaker=br))
        with faults.inject(faults.every("device_dispatch", 1)) as plan:
            res = eng.execute(QUERY)
            # the injected fault fires before fn(), so the device was never
            # touched; the engine recomputed on the host
            assert plan.calls["device_dispatch"] >= 1
            assert res.count == want
            assert res.stats.status == "ok" and res.stats.backend == "host"
            assert "host" in res.stats.degradations
            # 3 failed attempts in one call tripped the threshold
            assert br.state == OPEN
            # while open, dispatches are refused outright — still correct
            res2 = eng.execute(QUERY)
            assert res2.count == want and "host" in res2.stats.degradations
        assert br.retries >= 1

    def test_breaker_recloses_after_faults_stop(self):
        g = _graph(seed=2)
        clk = FakeClock()
        br = CircuitBreaker(sleep=lambda s: None, failure_threshold=1,
                            max_retries=0, reset_after_s=30.0, clock=clk)
        eng = Engine(g, options=EngineOptions(
            device_min_nodes=0, materialize=False,
            force_backend="device", breaker=br))
        want = _host_engine(g).execute(QUERY).count
        with faults.inject(faults.every("device_dispatch", 1)):
            assert eng.execute(QUERY).count == want
            assert br.state == OPEN
        clk.t += 31.0                       # faults gone, window passed:
        res = eng.execute(QUERY)            # the probe dispatch succeeds
        assert br.state == CLOSED
        assert res.count == want and res.stats.backend == "device"
        assert "host" not in res.stats.degradations

    def test_journal_dispatch_fault_redispatches_to_same_counts(self):
        g = _graph(seed=3)
        queries = [QUERY, "(a:L1)-//->(b:L2)", "(a:L0)-/->(b:L3)"]
        ref = QueryServer(g, engine=_host_engine(g))
        for i, q in enumerate(queries):
            ref.submit(i, q)
        ref.drain()
        want = [ref.journal[i].count for i in range(len(queries))]

        srv = QueryServer(g, engine=_host_engine(g), max_attempts=3)
        for i, q in enumerate(queries):
            srv.submit(i, q)
        with faults.inject(faults.nth("journal_dispatch", 1)):
            srv.drain()
        got = [srv.journal[i].count for i in range(len(queries))]
        assert got == want
        assert all(srv.journal[i].status == "done"
                   for i in range(len(queries)))
        assert srv.stats["redispatched"] >= 1

    def test_unrelenting_worker_death_goes_terminal_failed(self):
        g = _graph(seed=3)
        srv = QueryServer(g, engine=_host_engine(g), max_attempts=2)
        srv.submit(0, QUERY)
        with faults.inject(faults.every("journal_dispatch", 1)):
            srv.drain()
        r = srv.journal[0]
        assert r.status == "failed" and not r.done
        assert srv.stats["failed"] == 1 and srv.stats["served"] == 0


# =========================================================== budget, engine
class TestEngineBudgets:
    def test_deadline_partial_status(self):
        g = random_labeled_graph(1500, avg_degree=8.0, n_labels=1, seed=1)
        eng = _host_engine(g, force_enum="backtrack", limit=None)
        q = "(a:L0)-//->(b:L0)-//->(c:L0)"
        eng.execute("(a:L0)-/->(b:L0)")      # warm labels
        res = eng.execute(q, budget=Budget(deadline_s=0.05))
        assert res.stats.status == "deadline_exceeded"
        assert res.stats.partial and res.stats.deadline_exceeded

    def test_deadline_raises_in_strict_mode(self):
        g = random_labeled_graph(1500, avg_degree=8.0, n_labels=1, seed=1)
        eng = _host_engine(g, force_enum="backtrack", limit=None)
        eng.execute("(a:L0)-/->(b:L0)")
        with pytest.raises(DeadlineExceeded):
            eng.execute("(a:L0)-//->(b:L0)-//->(c:L0)",
                        budget=Budget(deadline_s=0.05, raise_on_error=True))

    def test_rig_memory_cap_is_typed(self):
        eng = _host_engine(_graph())
        res = eng.execute(QUERY, budget=Budget(max_rig_bytes=16))
        assert res.stats.status == "resource_exhausted" and res.count == 0
        with pytest.raises(ResourceExhausted):
            eng.execute(QUERY, budget=Budget(max_rig_bytes=16,
                                             raise_on_error=True))

    def test_ungoverned_execution_unchanged(self):
        g = _graph(seed=4)
        eng = _host_engine(g)
        res = eng.execute(QUERY)
        assert res.stats.status == "ok" and not res.stats.partial
        assert res.stats.degradations == []
        # a generous budget changes nothing about the answer
        res2 = _host_engine(g).execute(QUERY, budget=Budget(deadline_s=60.0))
        assert res2.count == res.count and res2.stats.status == "ok"
