"""Training-runtime tests: optimizer, checkpointing, compression, elastic."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import (AdamWConfig, Checkpointer, ElasticConfig,
                         ElasticTrainer, SimulatedFailure, compression_ratio,
                         make_int8_compressor)
from repro.train import optimizer as opt
from repro.train.compression import init_error_state


# ----------------------------------------------------------------- optimizer
def test_adamw_matches_analytic_first_step():
    # On the first step AdamW moves each coord by ~lr * sign(grad) (bias
    # correction makes mhat/sqrt(vhat) == sign for any gradient).
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0,
                      warmup_steps=0, schedule="constant")
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    grads = {"w": jnp.array([0.5, -0.25, 2.0])}
    state = opt.init_state(params)
    new, state, m = opt.apply_updates(params, grads, state, cfg)
    np.testing.assert_allclose(
        np.asarray(new["w"]),
        np.asarray(params["w"]) - 0.1 * np.sign(np.asarray(grads["w"])),
        rtol=1e-4)


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                      schedule="constant")
    target = jnp.asarray(np.linspace(-2, 2, 16), jnp.float32)
    params = {"w": jnp.zeros(16)}
    state = opt.init_state(params)
    loss = lambda p: jnp.sum(jnp.square(p["w"] - target))
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = opt.apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_grad_clip_and_schedule():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=10,
                      total_steps=100, schedule="cosine")
    s0 = opt.schedule_lr(cfg, jnp.asarray(1))
    s_mid = opt.schedule_lr(cfg, jnp.asarray(10))
    s_end = opt.schedule_lr(cfg, jnp.asarray(100))
    assert float(s0) < float(s_mid)
    assert float(s_end) <= float(s_mid)
    assert float(s_end) >= cfg.lr * cfg.min_lr_ratio * 0.99


# ---------------------------------------------------------------- checkpoint
def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.standard_normal((4, 5)), jnp.float32),
            "nested": {"b": jnp.asarray(rng.integers(0, 9, 7), jnp.int32),
                       "c": jnp.asarray(rng.standard_normal(3), jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = _tree(1)
    ck.save(10, tree, extra={"note": "x"})
    restored, meta = ck.restore(tree)
    assert meta["step"] == 10 and meta["extra"]["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_retention_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s))
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_async_and_atomic(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    tree = _tree(2)
    ck.save_async(5, tree)
    ck.wait()
    restored, meta = ck.restore(tree)
    assert meta["step"] == 5
    # no stray tmp dirs
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]


def test_checkpoint_restore_with_sharding(tmp_path):
    # single-device "resharding": restore with explicit shardings
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    ck = Checkpointer(str(tmp_path))
    tree = _tree(3)
    ck.save(1, tree)
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    restored, _ = ck.restore(tree, shardings=shardings)
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(tree["a"]))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        ck.restore({"a": jnp.zeros((3, 3))})


# --------------------------------------------------------------- compression
def test_int8_quantization_error_bounded():
    comp = make_int8_compressor(block=64)
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((130,)), jnp.float32)}
    out, err = comp(g, None)
    # elementwise error bounded by scale/2 = max|block|/254
    rel = np.abs(np.asarray(out["w"]) - np.asarray(g["w"])).max()
    assert rel <= np.abs(np.asarray(g["w"])).max() / 127.0 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    # constant gradient: with error feedback the *average* applied update
    # converges to the true gradient
    comp = make_int8_compressor(block=32)
    g = {"w": jnp.asarray(np.full(64, 0.0123), jnp.float32)}
    err = None
    total = np.zeros(64)
    n = 50
    for _ in range(n):
        out, err = comp(g, err)
        total += np.asarray(out["w"])
    np.testing.assert_allclose(total / n, 0.0123, rtol=1e-2)


def test_training_converges_with_compression():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                      schedule="constant")
    comp = make_int8_compressor(block=32)
    target = jnp.asarray(np.linspace(-1, 1, 32), jnp.float32)
    params = {"w": jnp.zeros(32)}
    state = opt.init_state(params)
    err = init_error_state(params)
    loss = lambda p: jnp.sum(jnp.square(p["w"] - target))
    for _ in range(300):
        g = jax.grad(loss)(params)
        g, err = comp(g, err)
        params, state, _ = opt.apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_compression_ratio_about_8x():
    params = {"w": jnp.zeros((1024, 64))}
    r = compression_ratio(params, block=256)
    assert 0.25 < r < 0.27       # 1/4 of fp32 bytes + scale overhead


# ------------------------------------------------------------------ elastic
def _make_trainer(tmp_path, ckpt_every=5, lr=0.05):
    cfg = AdamWConfig(lr=lr, weight_decay=0.0, warmup_steps=0,
                      schedule="constant")
    target = jnp.asarray(np.linspace(-1, 1, 8), jnp.float32)

    def init_state():
        params = {"w": jnp.zeros(8)}
        return {"params": params, "opt": opt.init_state(params)}

    def loss(p, batch):
        return jnp.sum(jnp.square(p["w"] - target)) + 0.0 * batch.sum()

    @jax.jit
    def step(state, batch):
        g = jax.grad(loss)(state["params"], batch)
        params, ostate, m = opt.apply_updates(state["params"], g,
                                              state["opt"], cfg)
        return {"params": params, "opt": ostate}, m

    return ElasticTrainer(
        step_fn=step,
        make_batch=lambda i: jnp.asarray([float(i)]),
        init_state=init_state,
        cfg=ElasticConfig(checkpoint_dir=str(tmp_path),
                          checkpoint_every=ckpt_every, async_save=False),
        get_step=lambda s: int(s["opt"]["step"]))


def test_elastic_restart_reaches_same_result(tmp_path):
    # uninterrupted run
    t1 = _make_trainer(tmp_path / "a")
    t1.start_or_resume()
    r1 = t1.run(20)
    w_straight = np.asarray(t1.state["params"]["w"])

    # interrupted at step 10, resumed by a fresh trainer
    t2 = _make_trainer(tmp_path / "b")
    t2.start_or_resume()
    with pytest.raises(SimulatedFailure):
        t2.run(20, fail_at=10)
    t3 = _make_trainer(tmp_path / "b")
    info = t3.start_or_resume()
    assert info["resumed"] and info["step"] == 10
    t3.run(20)
    w_resumed = np.asarray(t3.state["params"]["w"])
    np.testing.assert_allclose(w_resumed, w_straight, rtol=1e-5, atol=1e-6)


def test_straggler_journal_flags_slow_steps():
    from repro.train.elastic import StepJournal
    j = StepJournal()
    for i in range(20):
        j.record(i, 0.01, factor=3.0)
    assert j.record(99, 0.2, factor=3.0)
    assert 99 in j.flags
