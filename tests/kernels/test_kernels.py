"""Pallas kernel correctness sweeps (interpret mode) vs the ref.py oracles.

Every kernel is exercised across shapes (including tile-boundary and
non-square cases), densities and block sizes; results are exact-integer /
boolean so assertions are equality, not allclose-with-tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, packed, ref
from repro.kernels.bitmm import bitmm_pallas
from repro.kernels.closure import closure_step_pallas
from repro.kernels.intersect import intersect_pallas


def _rand_packed(rng, m, k, density=0.2):
    dense = rng.random((m, k)) < density
    words = np.asarray(packed.pack(jnp.asarray(dense)))
    return dense, jnp.asarray(words)


# ------------------------------------------------------------------- packed
@pytest.mark.parametrize("n", [1, 31, 32, 33, 255, 1024])
def test_pack_unpack_roundtrip(n):
    rng = np.random.default_rng(n)
    mask = jnp.asarray(rng.random((3, n)) < 0.3)
    words = packed.pack(mask)
    assert words.dtype == jnp.uint32
    out = packed.unpack(words, n)
    assert np.array_equal(np.asarray(out), np.asarray(mask))


def test_popcount():
    words = jnp.asarray([[0, 1, 3, 0xFFFFFFFF]], dtype=jnp.uint32)
    assert int(packed.popcount(words).sum()) == 0 + 1 + 2 + 32


def test_u64_u32_bridge():
    from repro.core import bitset as hb
    rng = np.random.default_rng(0)
    mask = rng.random(300) < 0.4
    w64 = hb.pack(mask)
    w32 = packed.pack_numpy_u64_to_u32(w64)
    got = packed.unpack(jnp.asarray(w32), 300)
    assert np.array_equal(np.asarray(got), mask)


# -------------------------------------------------------------------- bitmm
@pytest.mark.parametrize("m,k,b", [(128, 256, 8), (256, 1024, 16),
                                   (512, 2048, 4), (128, 128, 128)])
@pytest.mark.parametrize("threshold", [True, False])
def test_bitmm_pallas_vs_ref(m, k, b, threshold):
    rng = np.random.default_rng(m + k + b)
    dense, words = _rand_packed(rng, m, k)
    x = jnp.asarray(rng.random((k, b)) < 0.3, dtype=jnp.float32)
    want = ref.bitmm_ref(words, x, threshold=threshold)
    got = bitmm_pallas(words, x, threshold=threshold, bm=128, bk=128,
                       interpret=True)
    if threshold:
        assert np.array_equal(np.asarray(got) > 0, np.asarray(want))
    else:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bm,bk", [(64, 64), (128, 256), (256, 1024)])
def test_bitmm_block_shapes(bm, bk):
    rng = np.random.default_rng(bm * bk)
    m, k, b = 256, 1024, 8
    dense, words = _rand_packed(rng, m, k, density=0.05)
    x = jnp.asarray(rng.random((k, b)) < 0.5, dtype=jnp.float32)
    want = ref.bitmm_ref(words, x)
    got = bitmm_pallas(words, x, bm=bm, bk=bk, interpret=True)
    assert np.array_equal(np.asarray(got) > 0, np.asarray(want))


@pytest.mark.parametrize("impl", ["blocked", "reference"])
def test_bitmm_impls_agree(impl):
    rng = np.random.default_rng(7)
    m, k, b = 128, 512, 8
    _, words = _rand_packed(rng, m, k)
    x = jnp.asarray(rng.random((k, b)) < 0.4, dtype=jnp.float32)
    want = np.asarray(ref.bitmm_ref(words, x))
    got = np.asarray(ops.bitmm(words, x, impl=impl))
    assert np.array_equal(got, want)


def test_bitmm_empty_and_full():
    m, k, b = 128, 256, 8
    zero = jnp.zeros((m, k // 32), jnp.uint32)
    ones = jnp.full((m, k // 32), 0xFFFFFFFF, jnp.uint32)
    x = jnp.ones((k, b), jnp.float32)
    assert not np.asarray(bitmm_pallas(zero, x, interpret=True)).any()
    got = np.asarray(bitmm_pallas(ones, x, threshold=False, interpret=True))
    np.testing.assert_allclose(got, k)


# ------------------------------------------------------------------ closure
@pytest.mark.parametrize("n", [128, 256, 512])
def test_closure_step_vs_ref(n):
    rng = np.random.default_rng(n)
    _, words = _rand_packed(rng, n, n, density=0.02)
    want = np.asarray(ref.closure_step_ref(words))
    got = np.asarray(closure_step_pallas(words, bm=128, bn=128, bk=128,
                                         interpret=True))
    assert np.array_equal(got, want)


def test_full_closure_matches_host_reachability():
    from repro.core.reachability import ReachabilityIndex
    from repro.data.graphs import random_labeled_graph
    from repro.kernels import packed as pk

    graph = random_labeled_graph(100, avg_degree=2.5, n_labels=3, seed=3)
    n_pad = 128
    dense = np.zeros((n_pad, n_pad), dtype=bool)
    dense[:graph.n, :graph.n] = graph.adjacency_matrix()
    words = pk.pack(jnp.asarray(dense))
    closed = ops.transitive_closure(words, impl="reference")
    got = np.asarray(pk.unpack(closed, n_pad))[:graph.n, :graph.n]
    want = ReachabilityIndex.build(graph).dense()
    assert np.array_equal(got, want)


# ---------------------------------------------------------------- intersect
@pytest.mark.parametrize("f,k,w", [(128, 2, 16), (256, 4, 64), (128, 1, 128)])
def test_intersect_pallas_vs_ref(f, k, w):
    rng = np.random.default_rng(f + k + w)
    rows = jnp.asarray(
        rng.integers(0, 2**32, size=(f, k, w), dtype=np.uint64).astype(np.uint32))
    want_rows, want_counts = ref.intersect_ref(rows)
    got_rows, got_counts = intersect_pallas(rows, bf=128, bw=16, interpret=True)
    assert np.array_equal(np.asarray(got_rows), np.asarray(want_rows))
    assert np.array_equal(np.asarray(got_counts), np.asarray(want_counts))


def test_intersect_disjoint_rows_count_zero():
    f, w = 128, 16
    a = np.zeros((f, 2, w), dtype=np.uint32)
    a[:, 0] = 0xAAAAAAAA
    a[:, 1] = 0x55555555
    got_rows, got_counts = intersect_pallas(jnp.asarray(a), interpret=True)
    assert not np.asarray(got_rows).any()
    assert not np.asarray(got_counts).any()
