"""Pallas kernel correctness sweeps (interpret mode) vs the ref.py oracles.

Every kernel is exercised across shapes (including tile-boundary and
non-square cases), densities and block sizes; results are exact-integer /
boolean so assertions are equality, not allclose-with-tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, packed, ref
from repro.kernels.bitmm import bitmm_pallas
from repro.kernels.closure import closure_step_pallas
from repro.kernels.intersect import intersect_pallas


def _rand_packed(rng, m, k, density=0.2):
    dense = rng.random((m, k)) < density
    words = np.asarray(packed.pack(jnp.asarray(dense)))
    return dense, jnp.asarray(words)


# ------------------------------------------------------------------- packed
@pytest.mark.parametrize("n", [1, 31, 32, 33, 255, 1024])
def test_pack_unpack_roundtrip(n):
    rng = np.random.default_rng(n)
    mask = jnp.asarray(rng.random((3, n)) < 0.3)
    words = packed.pack(mask)
    assert words.dtype == jnp.uint32
    out = packed.unpack(words, n)
    assert np.array_equal(np.asarray(out), np.asarray(mask))


def test_popcount():
    words = jnp.asarray([[0, 1, 3, 0xFFFFFFFF]], dtype=jnp.uint32)
    assert int(packed.popcount(words).sum()) == 0 + 1 + 2 + 32


def test_u64_u32_bridge():
    from repro.core import bitset as hb
    rng = np.random.default_rng(0)
    mask = rng.random(300) < 0.4
    w64 = hb.pack(mask)
    w32 = packed.pack_numpy_u64_to_u32(w64)
    got = packed.unpack(jnp.asarray(w32), 300)
    assert np.array_equal(np.asarray(got), mask)


# -------------------------------------------------------------------- bitmm
@pytest.mark.parametrize("m,k,b", [(128, 256, 8), (256, 1024, 16),
                                   (512, 2048, 4), (128, 128, 128)])
@pytest.mark.parametrize("threshold", [True, False])
def test_bitmm_pallas_vs_ref(m, k, b, threshold):
    rng = np.random.default_rng(m + k + b)
    dense, words = _rand_packed(rng, m, k)
    x = jnp.asarray(rng.random((k, b)) < 0.3, dtype=jnp.float32)
    want = ref.bitmm_ref(words, x, threshold=threshold)
    got = bitmm_pallas(words, x, threshold=threshold, bm=128, bk=128,
                       interpret=True)
    if threshold:
        assert np.array_equal(np.asarray(got) > 0, np.asarray(want))
    else:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bm,bk", [(64, 64), (128, 256), (256, 1024)])
def test_bitmm_block_shapes(bm, bk):
    rng = np.random.default_rng(bm * bk)
    m, k, b = 256, 1024, 8
    dense, words = _rand_packed(rng, m, k, density=0.05)
    x = jnp.asarray(rng.random((k, b)) < 0.5, dtype=jnp.float32)
    want = ref.bitmm_ref(words, x)
    got = bitmm_pallas(words, x, bm=bm, bk=bk, interpret=True)
    assert np.array_equal(np.asarray(got) > 0, np.asarray(want))


@pytest.mark.parametrize("impl", ["blocked", "reference"])
def test_bitmm_impls_agree(impl):
    rng = np.random.default_rng(7)
    m, k, b = 128, 512, 8
    _, words = _rand_packed(rng, m, k)
    x = jnp.asarray(rng.random((k, b)) < 0.4, dtype=jnp.float32)
    want = np.asarray(ref.bitmm_ref(words, x))
    got = np.asarray(ops.bitmm(words, x, impl=impl))
    assert np.array_equal(got, want)


def test_bitmm_empty_and_full():
    m, k, b = 128, 256, 8
    zero = jnp.zeros((m, k // 32), jnp.uint32)
    ones = jnp.full((m, k // 32), 0xFFFFFFFF, jnp.uint32)
    x = jnp.ones((k, b), jnp.float32)
    assert not np.asarray(bitmm_pallas(zero, x, interpret=True)).any()
    got = np.asarray(bitmm_pallas(ones, x, threshold=False, interpret=True))
    np.testing.assert_allclose(got, k)


# ------------------------------------------------------------------ closure
@pytest.mark.parametrize("n", [128, 256, 512])
def test_closure_step_vs_ref(n):
    rng = np.random.default_rng(n)
    _, words = _rand_packed(rng, n, n, density=0.02)
    want = np.asarray(ref.closure_step_ref(words))
    got = np.asarray(closure_step_pallas(words, bm=128, bn=128, bk=128,
                                         interpret=True))
    assert np.array_equal(got, want)


def test_full_closure_matches_host_reachability():
    from repro.core.reachability import ReachabilityIndex
    from repro.data.graphs import random_labeled_graph
    from repro.kernels import packed as pk

    graph = random_labeled_graph(100, avg_degree=2.5, n_labels=3, seed=3)
    n_pad = 128
    dense = np.zeros((n_pad, n_pad), dtype=bool)
    dense[:graph.n, :graph.n] = graph.adjacency_matrix()
    words = pk.pack(jnp.asarray(dense))
    closed = ops.transitive_closure(words, impl="reference")
    got = np.asarray(pk.unpack(closed, n_pad))[:graph.n, :graph.n]
    want = ReachabilityIndex.build(graph).dense()
    assert np.array_equal(got, want)


# ---------------------------------------------------------------- intersect
@pytest.mark.parametrize("f,k,w", [(128, 2, 16), (256, 4, 64), (128, 1, 128)])
def test_intersect_pallas_vs_ref(f, k, w):
    rng = np.random.default_rng(f + k + w)
    rows = jnp.asarray(
        rng.integers(0, 2**32, size=(f, k, w), dtype=np.uint64).astype(np.uint32))
    want_rows, want_counts = ref.intersect_ref(rows)
    got_rows, got_counts = intersect_pallas(rows, bf=128, bw=16, interpret=True)
    assert np.array_equal(np.asarray(got_rows), np.asarray(want_rows))
    assert np.array_equal(np.asarray(got_counts), np.asarray(want_counts))


def test_intersect_disjoint_rows_count_zero():
    f, w = 128, 16
    a = np.zeros((f, 2, w), dtype=np.uint32)
    a[:, 0] = 0xAAAAAAAA
    a[:, 1] = 0x55555555
    got_rows, got_counts = intersect_pallas(jnp.asarray(a), interpret=True)
    assert not np.asarray(got_rows).any()
    assert not np.asarray(got_counts).any()


# --------------------------------------------------------- gather_intersect
def _gather_ref(matrix, idx):
    """Numpy oracle: per-row gather + AND-reduce + popcount (uint32)."""
    rows = matrix[np.asarray(idx)]                     # (F, K, W)
    acc = rows[:, 0]
    for i in range(1, rows.shape[1]):
        acc = acc & rows[:, i]
    counts = np.array([int(np.unpackbits(
        r.view(np.uint8)).sum()) for r in acc], dtype=np.int32)
    return acc, counts


@pytest.mark.parametrize("f,k,w", [(1, 1, 128), (5, 2, 128), (16, 3, 256),
                                   (33, 4, 128)])
def test_gather_intersect_pallas_vs_ref(f, k, w):
    from repro.kernels.gather_intersect import (gather_intersect_pallas,
                                                gather_intersect_xla)
    rng = np.random.default_rng(f * 100 + k)
    matrix = rng.integers(0, 1 << 32, size=(40, w), dtype=np.uint32)
    matrix[-1] = 0                                     # the zero row
    idx = rng.integers(0, 40, size=(f, k)).astype(np.int32)
    want_rows, want_counts = _gather_ref(matrix, idx)
    for fn in (gather_intersect_xla,
               lambda m, i, w32: gather_intersect_pallas(
                   m, i, w32=w32, interpret=True)):
        got_rows, got_counts = fn(jnp.asarray(matrix), jnp.asarray(idx),
                                  w32=w)
        got_rows = np.asarray(got_rows)[:f]            # rows stay padded
        assert np.array_equal(got_rows, want_rows)
        assert np.array_equal(np.asarray(got_counts)[:f], want_counts)


def test_gather_intersect_zero_row_padding_is_inert():
    """Padded dispatch rows target the all-zero matrix row: their AND and
    popcount must both be zero, never garbage."""
    from repro.kernels.gather_intersect import gather_intersect_pallas
    matrix = np.full((8, 128), 0xFFFFFFFF, dtype=np.uint32)
    matrix[-1] = 0
    idx = np.full((3, 2), 7, dtype=np.int32)           # all -> zero row
    rows, counts = gather_intersect_pallas(jnp.asarray(matrix),
                                           jnp.asarray(idx), w32=128,
                                           interpret=True)
    # the kernel returns padded rows; the caller's contract is [:f]
    assert not np.asarray(rows)[:3].any()
    assert not np.asarray(counts)[:3].any()


def test_expand_pairs_bit_order_and_limit():
    """expand_pairs must agree with the host little-endian unpack order
    and clip to the first `size` pairs (lexicographic pushdown)."""
    from repro.core import bitset
    from repro.kernels.gather_intersect import expand_pairs
    rng = np.random.default_rng(9)
    n_i = 70                                           # ragged tail
    w64 = bitset.n_words(n_i)
    host_rows = rng.integers(0, 1 << 63, size=(6, w64), dtype=np.uint64)
    host_rows &= bitset.tail_mask(n_i) if hasattr(bitset, "tail_mask") \
        else host_rows
    bits = bitset.unpack(host_rows, n_i)
    want_r, want_c = np.nonzero(bits)
    rows32 = np.ascontiguousarray(host_rows).view(np.uint32)
    total = len(want_r)
    for size in (total, total + 5, max(1, total // 2)):
        rid, cid = expand_pairs(jnp.asarray(rows32), n_i=n_i, size=size)
        k = min(size, total)
        assert np.array_equal(np.asarray(rid)[:k], want_r[:k])
        assert np.array_equal(np.asarray(cid)[:k], want_c[:k])
