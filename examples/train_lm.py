"""Train a small LM (reduced qwen2-7b config) for a few hundred steps with
the full runtime: AdamW, cosine schedule, checkpointing, crash + resume.

  PYTHONPATH=src python examples/train_lm.py
"""

import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models import transformer as tf
from repro.train import (AdamWConfig, ElasticConfig, ElasticTrainer,
                         SimulatedFailure)
from repro.train import optimizer as opt


def main(steps: int = 300):
    ckpt_dir = tempfile.mkdtemp(prefix="repro_lm_")
    cfg = get_config("qwen2-7b").smoke_config()
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=steps,
                       weight_decay=0.01)
    pipe = TokenPipeline(TokenPipelineConfig(vocab=cfg.vocab, batch=16,
                                             seq_len=64, seed=0))

    def init_state():
        params = tf.init_params(cfg, jax.random.key(0))
        return {"params": params, "opt": opt.init_state(params)}

    @jax.jit
    def step(state, batch):
        loss, grads = jax.value_and_grad(tf.loss_fn)(state["params"], batch,
                                                     cfg)
        params, ostate, m = opt.apply_updates(state["params"], grads,
                                              state["opt"], ocfg)
        m["loss"] = loss
        return {"params": params, "opt": ostate}, m

    def make(trainer_dir):
        return ElasticTrainer(
            step_fn=step,
            make_batch=lambda i: jax.tree.map(jnp.asarray, pipe.batch_at(i)),
            init_state=init_state,
            cfg=ElasticConfig(checkpoint_dir=trainer_dir,
                              checkpoint_every=50),
            get_step=lambda s: int(s["opt"]["step"]))

    trainer = make(ckpt_dir)
    trainer.start_or_resume()
    try:
        trainer.run(steps, fail_at=steps // 2)   # inject a crash halfway
    except SimulatedFailure as e:
        print(f"!! {e} — restarting from checkpoint")
    trainer2 = make(ckpt_dir)
    info = trainer2.start_or_resume()
    print(f"resumed={info['resumed']} at step {info['step']}")
    out = trainer2.run(steps)
    losses = [m["loss"] for m in out["metrics"]]
    print(f"final step {out['final_step']}: loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f}")
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    assert losses[-1] < losses[0]
    print("loss decreased across crash+resume ✓")


if __name__ == "__main__":
    main()
