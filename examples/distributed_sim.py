"""Distributed double simulation on 8 simulated devices (2×4 mesh):
shard_map SUMMA-style passes == single-device matcher, then the full
gm_serve_step (simulation + RIG stats + candidate compaction).

  PYTHONPATH=src python examples/distributed_sim.py
(sets its own XLA device-count flag; run as a fresh process)
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax                                     # noqa: E402
import jax.numpy as jnp                        # noqa: E402
import numpy as np                             # noqa: E402

from repro.data.graphs import random_labeled_graph          # noqa: E402
from repro.data.queries import random_query_from_graph      # noqa: E402
from repro.jaxgm import (double_simulation, encode_query,    # noqa: E402
                         from_host)
from repro.jaxgm.distributed import (gm_serve_step,          # noqa: E402
                                     shard_graph_arrays)


def main():
    print(f"devices: {len(jax.devices())}")
    g = random_labeled_graph(512, avg_degree=3.0, n_labels=6, seed=0)
    dg = from_host(g, block=256)
    queries = [random_query_from_graph(g, 4, qtype=t, seed=s)
               for t, s in [("H", 1), ("C", 2), ("D", 3), ("H", 4)]]
    qts = jax.tree.map(lambda *xs: jnp.stack(xs),
                       *[encode_query(q, 8, 16) for q in queries])

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    mats, labels = shard_graph_arrays(dg, mesh)
    out = gm_serve_step(mats, labels, qts, mesh, n_passes=4, top_k=128,
                        block_k=64)
    print("per-query |cos| sizes:", np.asarray(out.fb_sizes)[:, :4])
    print("per-query RIG edge counts:",
          np.asarray(out.edge_counts)[:, :4].astype(int))

    # verify against the single-device matcher
    for i, q in enumerate(queries):
        qt = encode_query(q, 8, 16)
        fb = double_simulation(dg, qt, n_passes=4, impl="reference")
        want = np.asarray(fb.sum(axis=1), np.int32)
        got = np.asarray(out.fb_sizes[i])
        assert np.array_equal(got, want), (i, got, want)
    print("distributed == single-device ✓")


if __name__ == "__main__":
    main()
